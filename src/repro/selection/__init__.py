"""Client-selection policy subsystem: participation as a POLICY operand.

The paper samples clients uniformly; this package makes per-round
participation the output of a stateful selection policy while preserving
every executor guarantee the repo is built on. The protocol has three
parts, mirroring the comm subsystem's compressor design:

**Switch index.** A policy is described host-side by
``SelectionPolicy`` (name, participation fraction, hyperparameters, seed)
and enters the executor as ``PolicyParams`` — jnp scalars only, with the
policy choice an int32 ``policy_id`` dispatched by ``jax.lax.switch``
inside the scanned round body (``policies.round_select``). Changing the
policy or any hyperparameter changes operand DATA, never the trace: all
four policies (uniform / power_of_choice / ucb / shapley) run through ONE
compiled executor per (algorithm, problem-structure, rounds).

**State leaves.** Policy memory (``PolicyState``: selection counts, UCB
value estimates, Shapley contribution tables, last probe/mask, round
counter — all float32, client-count-shaped) rides the executor scan carry
as ordinary pytree leaves beside the algorithm state, and comes back per
cell in sweep results for inspection.

**Key-stream discipline.** Selection randomness is a stream SEPARATE from
the algorithm's round keys: per-round raw keys derived host-side as
``split(fold_in(PRNGKey(sel_seed), fold), R)`` with the per-cell fold
``p·S + s`` — the exact derivation of ``CommConfig.round_masks``, which is
what makes the uniform policy bitwise-reproduce the precomputed
mask-schedule path at equal seeds. Probing policies fold a domain tag into
each round key for their value-oracle subkeys, so probe and mask draws
never collide.

The per-round mask feeds the comm bits ledger unchanged (the closed forms
in ``repro.comm.config`` apply to whatever set the policy picked); probing
policies additionally bill one float32 uplink per client per round
(``policies.probe_bits``). ``sweep.run_selection_sweep`` runs policies ×
problems × seeds × stepsizes grids on the vmapped AND sharded engines,
bitwise identical cell-for-cell.
"""
from repro.selection.policies import (SelectionPolicy, probe_bits,
                                      probe_values, round_select, top_s_mask)
from repro.selection.state import (POLICY_IDS, PROBING_POLICIES,
                                   PolicyParams, PolicyState, init_state,
                                   make_params)
from repro.selection.sweep import (SelectionSweepResult,
                                   run_selection_sweep,
                                   selection_grid_operands)

__all__ = [
    "POLICY_IDS", "PROBING_POLICIES", "PolicyParams", "PolicyState",
    "SelectionPolicy", "SelectionSweepResult", "init_state", "make_params",
    "probe_bits", "probe_values", "round_select", "run_selection_sweep",
    "selection_grid_operands", "top_s_mask",
]
