"""Policy operands and state leaves for the client-selection subsystem.

``PolicyParams`` is the executor OPERAND: every field is a jnp scalar so a
whole policies × problems × seeds grid reuses one compiled executor — the
policy choice is an int32 switch index (``policy_id``) dispatched by
``jax.lax.switch`` inside the scanned round body, exactly like the comm
``Compressor``'s ``comp_id``. Changing the policy or any hyperparameter
changes DATA, never the trace.

``PolicyState`` is the per-run policy memory, carried through the executor
scan as ordinary pytree leaves next to the algorithm state.  All leaves are
float32 and sized by the client count only, so every policy shares one
structure (uniform simply leaves the probe/value tables untouched):

* ``counts``     [N] — how many rounds each client has been selected
* ``values``     [N] — UCB running mean of observed per-client rewards
                       (loss reduction over the round the client served in)
* ``contrib``    [N] — EMA of GTG-style marginal-contribution estimates
                       (greedy-Shapley score table)
* ``last_probe`` [N] — the previous round's probed per-client loss values
* ``last_mask``  [N] — the previous round's participation mask
* ``t``          []  — rounds elapsed
"""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp

# lax.switch branch order — must match the branch list in
# ``policies.round_select``
POLICY_IDS = {
    "uniform": 0,
    "power_of_choice": 1,
    "ucb": 2,
    "shapley": 3,
}

#: policies that broadcast a value probe to all N clients each round (and
#: are billed for the returned scalars — see ``policies.probe_bits``)
PROBING_POLICIES = ("power_of_choice", "ucb", "shapley")


class PolicyParams(NamedTuple):
    """Traced policy hyperparameters — scan-invariant executor operands."""

    policy_id: jnp.ndarray  # int32 switch index into POLICY_IDS
    s_sel: jnp.ndarray      # int32 clients selected per round
    ucb_c: jnp.ndarray      # float32 UCB exploration coefficient
    ema: jnp.ndarray        # float32 EMA rate for Shapley contributions


def make_params(policy: str, s_sel: int, ucb_c: float = 1.0,
                ema: float = 0.5) -> PolicyParams:
    if policy not in POLICY_IDS:
        raise ValueError(
            f"unknown selection policy {policy!r}; "
            f"known: {sorted(POLICY_IDS)}")
    return PolicyParams(
        policy_id=jnp.asarray(POLICY_IDS[policy], jnp.int32),
        s_sel=jnp.asarray(s_sel, jnp.int32),
        ucb_c=jnp.asarray(ucb_c, jnp.float32),
        ema=jnp.asarray(ema, jnp.float32),
    )


class PolicyState(NamedTuple):
    """Per-run policy memory, scanned as pytree leaves (all float32)."""

    counts: jnp.ndarray      # [N]
    values: jnp.ndarray      # [N]
    contrib: jnp.ndarray     # [N]
    last_probe: jnp.ndarray  # [N]
    last_mask: jnp.ndarray   # [N]
    t: jnp.ndarray           # []


def init_state(num_clients: int) -> PolicyState:
    z = jnp.zeros((num_clients,), jnp.float32)
    return PolicyState(counts=z, values=z, contrib=z, last_probe=z,
                       last_mask=z, t=jnp.zeros((), jnp.float32))
