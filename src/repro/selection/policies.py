"""Selection policies: per-round participation masks from a traced switch.

``round_select`` is the in-executor entry point.  It consumes one raw
per-round selection key (the ``sel_keys`` scan operand, derived host-side
from the policy's ``sel_seed`` — a stream SEPARATE from the algorithm's
round keys, so adding a policy never perturbs algorithm randomness), probes
the clients when the policy calls for it, and dispatches on
``params.policy_id`` through ``jax.lax.switch``:

* ``uniform`` (0) — draws ``uniform(sel_key, (N,))`` and keeps the S
  smallest by double-argsort rank, the EXACT construction of
  ``CommConfig.round_masks``; with matching seed/fold derivation the
  trajectory is bitwise identical to the precomputed mask-schedule path.
  Never probes, bills zero probe bits.
* ``power_of_choice`` (1) — probes every client's stochastic loss value at
  the current iterate and keeps the top-S by loss (Cho et al.'s
  power-of-choice, with the candidate set widened to all N).
* ``ucb`` (2) — a UCB bandit over per-client loss reductions: the reward
  observed for last round's participants is ``last_probe - probe`` (how much
  their own loss fell over the round they served in), folded into a
  running mean; the score is mean + ``ucb_c``·sqrt(log(t+1)/counts), with
  never-selected clients forced to +inf (stable argsort then yields an
  index-order round-robin warm start).
* ``shapley`` (3) — greedy selection on GTG-style marginal-contribution
  estimates: the round's global loss drop is allocated over last round's
  participants efficiency-preservingly (equal split of the global gain plus
  each participant's centered own-loss deviation), EMA'd into a per-client
  contribution table, top-S by contribution.

Every branch performs the same bookkeeping (counts += mask, last_mask =
mask, t += 1) so state invariants hold policy-independently:
``counts.sum() == S·R`` and ``t == R`` after R rounds.

Key-stream discipline: the uniform branch consumes the raw per-round
``sel_key`` verbatim (bitwise parity with ``CommConfig.round_masks``
requires it); probing branches derive their oracle keys from
``fold_in(sel_key, _PROBE_KEY_TAG)`` so the two streams never collide.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.selection.state import (POLICY_IDS, PROBING_POLICIES, PolicyParams,
                                   PolicyState, init_state, make_params)

#: domain-separation tag for the probe key stream ('sl')
_PROBE_KEY_TAG = 0x736C


def _smallest_s_mask(v, s):
    """Mask keeping the ``s`` smallest entries of ``v`` (float32 0/1).

    Double-argsort ranks, the same construction as
    ``CommConfig.round_masks`` — jnp.argsort is stable, so ties break in
    index order deterministically across engines.
    """
    ranks = jnp.argsort(jnp.argsort(v))
    return (ranks < s).astype(jnp.float32)


def top_s_mask(score, s):
    """Mask keeping the ``s`` LARGEST scores (ties → lowest index first)."""
    return _smallest_s_mask(-score, s)


def probe_values(problem, x, key):
    """Stochastic loss value of every client at ``x`` — one oracle call per
    client on an independent subkey. [N] float32."""
    n = problem.num_clients
    keys = jax.random.split(key, n)
    cids = jnp.arange(n, dtype=jnp.int32)
    return jax.vmap(lambda i, kk: problem.value_oracle(x, i, kk))(cids, keys)


def probe_bits(params: PolicyParams, num_clients: int):
    """Uplink bits billed for the value probe: one float32 scalar from each
    of the N clients for probing policies, zero for uniform.  The probe
    evaluates at the model clients already hold from the round's broadcast,
    so no extra model downlink is charged (the standard power-of-choice
    accounting convention)."""
    return jnp.where(params.policy_id == POLICY_IDS["uniform"],
                     jnp.float32(0.0), jnp.float32(32.0 * num_clients))


def round_select(problem, x, pstate: PolicyState, params: PolicyParams, key):
    """One selection step: ``(mask [N] float32, new PolicyState)``.

    ``key`` is the round's raw selection key (row of the ``sel_keys``
    operand).  Dispatch is a ``lax.switch`` over ``params.policy_id`` —
    all branches share one output structure, so the policy choice is pure
    data and never re-traces the executor.
    """
    n = problem.num_clients
    s = params.s_sel

    v = probe_values(problem, x, jax.random.fold_in(key, _PROBE_KEY_TAG))

    def bookkeep(mask, probe, values, contrib):
        return PolicyState(
            counts=pstate.counts + mask, values=values, contrib=contrib,
            last_probe=probe, last_mask=mask, t=pstate.t + 1.0)

    def _uniform(_v):
        # raw key, double-argsort rank: bitwise CommConfig.round_masks
        u = jax.random.uniform(key, (n,))
        mask = _smallest_s_mask(u, s)
        return mask, bookkeep(mask, pstate.last_probe, pstate.values,
                              pstate.contrib)

    def _power_of_choice(v):
        mask = top_s_mask(v, s)
        return mask, bookkeep(mask, v, pstate.values, pstate.contrib)

    def _ucb(v):
        served = pstate.last_mask
        reward = pstate.last_probe - v
        cnt = jnp.maximum(pstate.counts, 1.0)
        values = jnp.where(served > 0,
                           pstate.values + (reward - pstate.values) / cnt,
                           pstate.values)
        t = pstate.t + 1.0
        bonus = params.ucb_c * jnp.sqrt(jnp.log(t + 1.0) / cnt)
        score = jnp.where(pstate.counts < 0.5, jnp.inf, values + bonus)
        mask = top_s_mask(score, s)
        return mask, bookkeep(mask, v, values, pstate.contrib)

    def _shapley(v):
        served = pstate.last_mask
        s_prev = jnp.maximum(jnp.sum(served), 1.0)
        gain = jnp.mean(pstate.last_probe) - jnp.mean(v)
        own = (pstate.last_probe - v) * served
        own_mean = jnp.sum(own) / s_prev
        marginal = (gain / s_prev + (own - own_mean)) * served
        contrib = jnp.where(served > 0,
                            (1.0 - params.ema) * pstate.contrib
                            + params.ema * marginal,
                            pstate.contrib)
        score = jnp.where(pstate.counts < 0.5, jnp.inf, contrib)
        mask = top_s_mask(score, s)
        return mask, bookkeep(mask, v, pstate.values, contrib)

    return jax.lax.switch(params.policy_id,
                          [_uniform, _power_of_choice, _ucb, _shapley], v)


@dataclasses.dataclass(frozen=True)
class SelectionPolicy:
    """Host-side policy description; everything traced goes through
    ``params()``/``init_state()``/``sel_keys()`` as operands."""

    policy: str = "uniform"
    participation: float = 1.0
    ucb_c: float = 1.0
    ema: float = 0.5
    sel_seed: int = 0

    def __post_init__(self):
        if self.policy not in POLICY_IDS:
            raise ValueError(
                f"unknown selection policy {self.policy!r}; "
                f"known: {sorted(POLICY_IDS)}")
        if not 0.0 < self.participation <= 1.0:
            raise ValueError("participation must be in (0, 1]")
        if self.ucb_c < 0:
            raise ValueError("ucb_c must be >= 0")
        if not 0.0 < self.ema <= 1.0:
            raise ValueError("ema must be in (0, 1]")

    @property
    def name(self) -> str:
        tag = self.policy
        if self.participation < 1.0:
            tag += f"@{self.participation:g}"
        return tag

    @property
    def probing(self) -> bool:
        return self.policy in PROBING_POLICIES

    def clients_per_round(self, num_clients: int) -> int:
        return max(1, round(self.participation * num_clients))

    def params(self, num_clients: int) -> PolicyParams:
        return make_params(self.policy, self.clients_per_round(num_clients),
                           ucb_c=self.ucb_c, ema=self.ema)

    def init_state(self, num_clients: int) -> PolicyState:
        return init_state(num_clients)

    def sel_keys(self, rounds: int, fold: int = 0):
        """[rounds, 2] raw per-round selection keys — the scan operand.

        Derivation is EXACTLY ``CommConfig.round_masks``'s (fold_in the
        per-cell fold into PRNGKey(seed), split into rounds) — that is what
        makes the uniform policy bitwise-reproduce the precomputed
        mask-schedule path at ``sel_seed == mask_seed``.  It is also
        policy-INDEPENDENT: every policy at the same (seed, fold) consumes
        the same randomness, so policy comparisons are paired."""
        key = jax.random.fold_in(jax.random.PRNGKey(self.sel_seed), fold)
        return jax.random.split(key, rounds)

    def round_masks(self, rounds: int, num_clients: int, fold: int = 0):
        """Host-side replay of the uniform policy's masks (for parity
        checks against the precomputed mask-schedule path).  Adaptive
        policies depend on in-run probe values and cannot be replayed."""
        if self.policy != "uniform":
            raise ValueError(
                f"round_masks is only defined for the uniform policy "
                f"(got {self.policy!r}: adaptive masks depend on the run)")
        s = self.clients_per_round(num_clients)
        keys = self.sel_keys(rounds, fold)

        def one_round(k):
            u = jax.random.uniform(k, (num_clients,))
            return _smallest_s_mask(u, s)

        return jax.vmap(one_round)(keys)
