"""Selection sweeps: policies × problems × seeds × stepsizes, one compile.

``run_selection_sweep`` is the subsystem's grid entry point. The policies
axis rides the flattened cells axis exactly like problems and seeds do —
``c = (q·P + p)·S + s`` — with the policy hyperparameters stacked into ONE
``PolicyParams`` pytree (O(Q) operands) gathered per cell by an int32
``qidx``, mirroring the O(P) indexed problem layout. Swapping the policy
list, like swapping problems or seeds, is pure operand data: zero
re-traces.

``mesh=`` routes the identical per-cell computation through the sharded
engine (``repro.dist.grid.run_selection_sweep_sharded``), bitwise identical
cell-for-cell including the bits ledgers — both engines consume the SAME
host-derived operands built by ``selection_grid_operands``.

Communication accounting composes unchanged: the per-round policy mask
feeds the comm ledger exactly like a precomputed schedule row, so
``bits_up``/``bits_down`` follow the closed forms in ``repro.comm.config``
(plus the probe uplink for probing policies). The participation axis is
owned by the POLICY here — the ``comm`` config must keep
``participation=1.0``.
"""
from __future__ import annotations

import dataclasses
import types
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import chain as chain_lib
from repro.core import runner as runner_lib
from repro.core import sweep as sweep_lib
from repro.core import tree_math as tm
from repro.selection.policies import SelectionPolicy


@dataclasses.dataclass
class SelectionSweepResult:
    """Grid results with axes [policy, problem, seed, eta, ...].

    ``masks`` is the per-round participation record [Q, P, S, E, R, N]
    emitted by the scan (what the validity/bits tests check);
    ``policy_state`` is the final ``PolicyState`` pytree with [Q, P, S, E]
    leading axes. ``cumulative_bits``/``bits_to_target`` turn histories
    into bits-to-target frontiers.
    """

    history: jnp.ndarray
    final_sub: jnp.ndarray
    x_hat: object
    bits_up: jnp.ndarray
    bits_down: jnp.ndarray
    masks: jnp.ndarray
    policy_state: object
    policies: Tuple[str, ...]
    problems: Tuple[str, ...]
    seeds: Tuple[int, ...]
    etas: Tuple[float, ...]
    selected_initial: Optional[jnp.ndarray] = None
    diagnostics: Optional[dict] = None  # per-round obs taps, [Q,P,S,E,R]

    def cumulative_bits(self) -> np.ndarray:
        """Cumulative up+down bits per round, [Q, P, S, E, R] float64 (the
        meters are exact in float32 per round; the large sums are not)."""
        up = np.asarray(self.bits_up, np.float64)
        down = np.asarray(self.bits_down, np.float64)
        return np.cumsum(up + down, axis=-1)

    def bits_to_target(self, target: float) -> np.ndarray:
        """Bits spent until suboptimality first drops to ``target``,
        [Q, P, S, E] float64; +inf where the run never reaches it."""
        sub = np.asarray(self.history, np.float64)
        cum = self.cumulative_bits()
        hit = sub <= float(target)
        reached = hit.any(axis=-1)
        first = np.argmax(hit, axis=-1)
        bits = np.take_along_axis(cum, first[..., None], axis=-1)[..., 0]
        return np.where(reached, bits, np.inf)

    def frontier(self, targets: Sequence[float]) -> dict:
        """{target: bits_to_target array} over a target grid."""
        return {float(t): self.bits_to_target(t) for t in targets}


def _normalize_policies(policies) -> Tuple[SelectionPolicy, ...]:
    out = []
    for q in policies:
        if isinstance(q, SelectionPolicy):
            out.append(q)
        elif isinstance(q, str):
            out.append(SelectionPolicy(policy=q))
        else:
            raise TypeError(
                f"policies= entries must be SelectionPolicy or policy-name "
                f"strings, got {type(q).__name__}")
    if not out:
        raise ValueError("run_selection_sweep needs at least one policy")
    return tuple(out)


def selection_grid_operands(algo_or_chain, problem, x0, rounds: int, *,
                            policies, seeds, etas, eta_mode, comm, problems,
                            eval_output: bool = True):
    """Host-side operand derivation SHARED by the vmapped and sharded
    engines — both consume these exact per-cell values, which is what makes
    ``mesh=`` bitwise identical."""
    from repro.comm import config as comm_cfg

    is_chain = isinstance(algo_or_chain, chain_lib.Chain)
    eta_mode = sweep_lib._resolve_eta_mode(algo_or_chain, eta_mode)
    policies = _normalize_policies(policies)
    seeds = tuple(int(s) for s in seeds)
    etas = tuple(float(e) for e in etas)
    if not seeds:
        raise ValueError("run_selection_sweep needs at least one seed")

    if comm is None:
        from repro.comm import CommConfig

        comm = CommConfig()
    if comm.participation < 1.0:
        raise ValueError(
            "run_selection_sweep owns the participation axis through its "
            "policies; pass a CommConfig with participation=1.0 (the "
            "policy's mask replaces the config's mask schedule)")
    stages = algo_or_chain.stages if is_chain else (algo_or_chain,)
    for st in stages:
        comm_cfg.reject_algo_participation(getattr(st, "s", 0), st.name)

    if problems is None:
        spec = runner_lib.as_spec(problem)
        if spec is None:
            raise TypeError(
                "run_selection_sweep needs spec-backed problems (the "
                "policy/problem stacks are gathered per cell)")
        from repro.data import spec as spec_lib

        stacked, prob_names = spec_lib.stack_specs([spec]), (spec.name,)
    else:
        stacked, prob_names = sweep_lib._as_stacked_specs(problems)
    n_probs = len(prob_names)
    n_seeds = len(seeds)
    n_pols = len(policies)
    n_clients = int(stacked.num_clients)
    x0_stack = sweep_lib._normalize_x0_stack(x0, stacked, n_probs)

    pol_stack = jax.tree.map(
        lambda *xs: jnp.stack(xs), *[q.params(n_clients) for q in policies])
    pst_stack = jax.tree.map(
        lambda *xs: jnp.stack(xs),
        *[q.init_state(n_clients) for q in policies])
    qidx, pidx = sweep_lib.policy_index_operands(n_pols, n_probs, n_seeds)

    keys = jnp.stack([jax.random.PRNGKey(s) for s in seeds])
    keys_c = jnp.tile(keys, (n_pols * n_probs, 1))
    n_sched = (algo_or_chain.schedule_len(rounds) if is_chain else rounds)
    # selection keys: policy-INDEPENDENT fold p·S + s, so every policy at a
    # given (problem, seed) cell consumes the same randomness (paired
    # comparisons) and the uniform policy replays the comm mask-schedule
    # fold convention exactly
    sel_keys_c = jnp.stack([
        q.sel_keys(n_sched, fold=p * n_seeds + s)
        for q in policies for p in range(n_probs) for s in range(n_seeds)])

    etas_arr = jnp.asarray(etas, jnp.float32)
    eta_sched = (algo_or_chain.eta_schedule(rounds) if is_chain else None)
    comm0 = comm.init_state(n_clients, tm.tree_index(x0_stack, 0))

    return types.SimpleNamespace(
        is_chain=is_chain, eta_mode=eta_mode, policies=policies,
        pol_names=tuple(q.name for q in policies), seeds=seeds, etas=etas,
        stacked=stacked, prob_names=prob_names, x0_stack=x0_stack,
        pol_stack=pol_stack, pst_stack=pst_stack, qidx=qidx, pidx=pidx,
        keys_c=keys_c, sel_keys_c=sel_keys_c, etas_arr=etas_arr,
        eta_sched=eta_sched, comm0=comm0, n_pols=n_pols, n_probs=n_probs,
        n_seeds=n_seeds, n_clients=n_clients, eval_output=eval_output)


def _grid_shape(ops, outs):
    shape = (ops.n_pols, ops.n_probs, ops.n_seeds)
    return jax.tree.map(lambda l: l.reshape(shape + l.shape[1:]), outs)


def run_selection_sweep(algo_or_chain, problem, x0, rounds: int, *,
                        policies, seeds: Sequence[int],
                        etas: Sequence[float] = (1.0,),
                        eta_mode: Optional[str] = None, comm=None,
                        problems=None, eval_output: bool = True,
                        mesh=None, telemetry=None) -> SelectionSweepResult:
    """Thin keyword shim over ``core.sweep.run()`` for the policy grid
    family — ``core.sweep.SweepRequest`` documents the operand axes."""
    return sweep_lib.run(sweep_lib.SweepRequest(
        algo_or_chain=algo_or_chain, problem=problem, x0=x0, rounds=rounds,
        seeds=seeds, etas=etas, policies=tuple(policies),
        eta_mode=eta_mode, comm=comm, problems=problems,
        eval_output=eval_output, mesh=mesh, telemetry=telemetry))


def _run_selection_sweep(algo_or_chain, problem, x0, rounds: int, *,
                         policies, seeds: Sequence[int],
                         etas: Sequence[float] = (1.0,),
                         eta_mode: Optional[str] = None, comm=None,
                         problems=None, eval_output: bool = True,
                         mesh=None, telemetry=None) -> SelectionSweepResult:
    """The policies × problems × seeds × stepsizes grid family, ONE
    compiled call per executor structure (see ``core.sweep.run``).

    ``policies`` is a sequence of ``SelectionPolicy`` (or policy-name
    strings); ``problems`` follows the grid family's semantics (None keeps
    a singleton problem axis from ``problem``). ``comm`` configures the
    compressed ledger (participation must stay 1.0 — the policy owns who
    participates). ``mesh`` shards the flattened cells axis (bitwise
    identical to the vmapped path, including bits_up/bits_down).
    """
    if mesh is not None:
        from repro.dist import grid as dist_grid

        return dist_grid.run_selection_sweep_sharded(
            algo_or_chain, problem, x0, rounds, policies=policies,
            seeds=seeds, etas=etas, eta_mode=eta_mode, comm=comm,
            problems=problems, eval_output=eval_output, mesh=mesh,
            telemetry=telemetry)

    ops = selection_grid_operands(
        algo_or_chain, problem, x0, rounds, policies=policies, seeds=seeds,
        etas=etas, eta_mode=eta_mode, comm=comm, problems=problems,
        eval_output=eval_output)

    if ops.is_chain:
        fn = sweep_lib._sweep_fn_selection_chain(
            algo_or_chain, ops.stacked, rounds, telemetry)
        outs, taps = sweep_lib._split_taps(_grid_shape(ops, fn(
            ops.stacked, ops.x0_stack, ops.pol_stack, ops.pst_stack,
            ops.pidx, ops.qidx, ops.keys_c, ops.etas_arr, ops.eta_sched,
            ops.sel_keys_c, ops.comm0)), telemetry)
        (x_hat, history, final, kept, bits_up, bits_down, masks,
         pstate) = outs
        return SelectionSweepResult(
            history=history, final_sub=final, x_hat=x_hat, bits_up=bits_up,
            bits_down=bits_down, masks=masks, policy_state=pstate,
            policies=ops.pol_names, problems=ops.prob_names, seeds=ops.seeds,
            etas=ops.etas, selected_initial=kept, diagnostics=taps)

    fn = sweep_lib._sweep_fn_selection_algo(
        algo_or_chain, ops.stacked, rounds, eval_output, ops.eta_mode,
        telemetry)
    outs, taps = sweep_lib._split_taps(_grid_shape(
        ops, fn(ops.stacked, ops.x0_stack, ops.pol_stack, ops.pst_stack,
                ops.pidx, ops.qidx, ops.keys_c, ops.etas_arr, ops.sel_keys_c,
                ops.comm0)), telemetry)
    x_hat, history, final, bits_up, bits_down, masks, pstate = outs
    return SelectionSweepResult(
        history=history, final_sub=final, x_hat=x_hat, bits_up=bits_up,
        bits_down=bits_down, masks=masks, policy_state=pstate,
        policies=ops.pol_names, problems=ops.prob_names, seeds=ops.seeds,
        etas=ops.etas, diagnostics=taps)
