"""Deterministic synthetic token streams for LM training/serving.

An order-2 Markov "language": next-token logits are a fixed random function of
the previous two tokens. This gives a learnable (non-uniform-entropy) stream —
losses visibly drop during the example training runs — while staying fully
offline and reproducible. Client heterogeneity for federated LM runs comes
from per-client transition-temperature and topic-shift parameters.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class TokenStreamConfig:
    vocab_size: int = 1024
    seq_len: int = 256
    batch_size: int = 8
    num_clients: int = 1
    heterogeneity: float = 0.0  # 0 = identical clients
    seed: int = 0


class SyntheticTokenStream:
    """Stateless batch sampler: (client_id, step) -> batch, deterministic."""

    def __init__(self, cfg: TokenStreamConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        v = min(cfg.vocab_size, 512)  # transition table over a core vocab
        self.core = v
        self.table = jnp.asarray(
            rng.normal(size=(v, v)).astype(np.float32)
        )  # order-1 core table
        self.client_shift = jnp.asarray(
            rng.normal(size=(cfg.num_clients, v)).astype(np.float32)
        )

    def batch(self, client_id: int, step: int):
        cfg = self.cfg
        key = jax.random.fold_in(
            jax.random.fold_in(jax.random.PRNGKey(cfg.seed + 1), client_id), step
        )

        def gen_one(k):
            def body(carry, kk):
                prev = carry
                logits = self.table[prev] + cfg.heterogeneity * self.client_shift[client_id]
                tok = jax.random.categorical(kk, logits)
                return tok, tok

            k0, kseq = jax.random.split(k)
            first = jax.random.randint(k0, (), 0, self.core)
            _, toks = jax.lax.scan(body, first, jax.random.split(kseq, cfg.seq_len))
            return toks

        keys = jax.random.split(key, cfg.batch_size)
        tokens = jax.vmap(gen_one)(keys)  # [B, S] in [0, core)
        return {"tokens": tokens.astype(jnp.int32)}


def lm_batch_specs(batch_size: int, seq_len: int):
    """ShapeDtypeStructs for an LM training batch (used by the dry-run)."""
    return {
        "tokens": jax.ShapeDtypeStruct((batch_size, seq_len), jnp.int32),
    }
