"""Client partitioners, including the paper's "X% homogeneous" shuffling
scheme (§6 / App. I.1).

The paper controls heterogeneity by shuffling the first X% of each class's
samples uniformly across clients, and assigning the remaining (100−X)% of
classes 2i−2 and 2i−1 to client i. 100% homogeneous is *not* ζ = 0 (sampling
randomness remains) — exactly as the paper notes.
"""
from __future__ import annotations

import numpy as np


def shuffled_heterogeneity(
    features: np.ndarray,  # [num_classes, per_class, ...]
    *,
    homogeneous_frac: float,
    num_clients: int,
    seed: int = 0,
):
    """Returns (client_features [N, n_i, ...], client_labels [N, n_i]).

    Requires num_classes == 2 * num_clients (paper: 10 digits, 5 clients).
    """
    rng = np.random.default_rng(seed)
    num_classes, per_class = features.shape[:2]
    assert num_classes == 2 * num_clients, "paper scheme: 2 classes per client"
    n_hom = int(round(homogeneous_frac * per_class))

    # homogeneous pool: first n_hom of every class, shuffled, split evenly
    pool_x = features[:, :n_hom].reshape((-1,) + features.shape[2:])
    pool_y = np.repeat(np.arange(num_classes), n_hom)
    perm = rng.permutation(pool_x.shape[0])
    pool_x, pool_y = pool_x[perm], pool_y[perm]
    # make divisible
    per_client_pool = pool_x.shape[0] // num_clients
    pool_x = pool_x[: per_client_pool * num_clients]
    pool_y = pool_y[: per_client_pool * num_clients]
    pool_x = pool_x.reshape((num_clients, per_client_pool) + features.shape[2:])
    pool_y = pool_y.reshape(num_clients, per_client_pool)

    # heterogeneous remainder: client i gets classes 2i, 2i+1 (0-based)
    client_x, client_y = [], []
    for i in range(num_clients):
        xs = [pool_x[i]]
        ys = [pool_y[i]]
        for c in (2 * i, 2 * i + 1):
            xs.append(features[c, n_hom:])
            ys.append(np.full(per_class - n_hom, c))
        client_x.append(np.concatenate(xs, axis=0))
        client_y.append(np.concatenate(ys, axis=0))

    n_min = min(x.shape[0] for x in client_x)
    client_x = np.stack([x[:n_min] for x in client_x])
    client_y = np.stack([y[:n_min] for y in client_y])
    return client_x, client_y


def dirichlet_partition(labels: np.ndarray, *, num_clients: int, alpha: float, seed: int = 0):
    """Standard Dirichlet(α) label-skew partition; returns index lists."""
    rng = np.random.default_rng(seed)
    classes = np.unique(labels)
    client_idx = [[] for _ in range(num_clients)]
    for c in classes:
        idx = np.where(labels == c)[0]
        rng.shuffle(idx)
        props = rng.dirichlet(np.full(num_clients, alpha))
        cuts = (np.cumsum(props) * len(idx)).astype(int)[:-1]
        for i, part in enumerate(np.split(idx, cuts)):
            client_idx[i].extend(part.tolist())
    return [np.asarray(ix) for ix in client_idx]


def by_class_partition(labels: np.ndarray, *, num_clients: int):
    """Maximally heterogeneous: contiguous class blocks per client."""
    classes = np.unique(labels)
    per = max(1, len(classes) // num_clients)
    client_idx = []
    for i in range(num_clients):
        cs = classes[i * per: (i + 1) * per]
        client_idx.append(np.where(np.isin(labels, cs))[0])
    return client_idx
