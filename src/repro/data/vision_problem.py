"""Nonconvex federated vision problems (paper §6 Table 3 / Fig. 2 substrate).

Builds a FederatedProblem over a small MLP/logistic classifier on the
synthetic prototype-image datasets, partitioned with the paper's
"X% homogeneous" scheme. Parameters are pytrees — the same Algos 2–7 run
unchanged on these (that is the point of the pytree-based core).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.data import partition, synthetic_vision
from repro.data.problems import FederatedProblem


def _mlp_init(key, dims):
    params = {}
    ks = jax.random.split(key, len(dims) - 1)
    for i, (a, b) in enumerate(zip(dims[:-1], dims[1:])):
        params[f"w{i}"] = jax.random.normal(ks[i], (a, b)) * (1.0 / a) ** 0.5
        params[f"b{i}"] = jnp.zeros((b,))
    return params


def _mlp_apply(params, x):
    n = len(params) // 2
    h = x
    for i in range(n):
        h = h @ params[f"w{i}"] + params[f"b{i}"]
        if i < n - 1:
            h = jax.nn.relu(h)
    return h


def make_vision_problem(
    key,
    *,
    num_clients: int = 5,
    homogeneous_frac: float = 0.5,
    num_classes: int = 10,
    per_class: int = 200,
    side: int = 14,
    hidden: int = 64,
    batch: int = 32,
    l2: float = 1e-4,
    seed: int = 0,
):
    """Returns (FederatedProblem, accuracy_fn, init_params)."""
    data = synthetic_vision.make_prototype_images(
        num_classes=num_classes, per_class=per_class, side=side, seed=seed)
    cx, cy = partition.shuffled_heterogeneity(
        data, homogeneous_frac=homogeneous_frac, num_clients=num_clients,
        seed=seed)
    features = jnp.asarray(cx)  # [N, n_i, d]
    labels = jnp.asarray(cy, jnp.int32)
    n_clients, n_per, d = features.shape
    dims = (d, hidden, num_classes) if hidden else (d, num_classes)

    def _loss_on(params, X, y):
        logits = _mlp_apply(params, X)
        ls = jax.nn.log_softmax(logits)
        nll = -jnp.mean(jnp.take_along_axis(ls, y[:, None], axis=1))
        reg = 0.5 * l2 * sum(jnp.sum(p**2) for p in jax.tree.leaves(params))
        return nll + reg

    def client_loss(params, i):
        return _loss_on(params, features[i], labels[i])

    def global_loss(params):
        return jnp.mean(jax.vmap(lambda X, y: _loss_on(params, X, y))(features, labels))

    def grad_oracle(params, i, rng):
        idx = jax.random.randint(rng, (batch,), 0, n_per)
        return jax.grad(_loss_on)(params, features[i][idx], labels[i][idx])

    def value_oracle(params, i, rng):
        idx = jax.random.randint(rng, (batch,), 0, n_per)
        return _loss_on(params, features[i][idx], labels[i][idx])

    def init_params(rng):
        return _mlp_init(rng, dims)

    def accuracy(params):
        logits = _mlp_apply(params, features.reshape(-1, d))
        pred = jnp.argmax(logits, -1)
        return jnp.mean((pred == labels.reshape(-1)).astype(jnp.float32))

    problem = FederatedProblem(
        num_clients=n_clients,
        grad_oracle=grad_oracle,
        value_oracle=value_oracle,
        client_loss=client_loss,
        global_loss=global_loss,
        init_params=init_params,
        mu=l2,
        beta=10.0,  # rough
        f_star=None,
        name=f"vision(hom={homogeneous_frac},hidden={hidden})",
    )
    return problem, accuracy, init_params
