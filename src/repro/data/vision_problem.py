"""Nonconvex federated vision problems (paper §6 Table 3 / Fig. 2 substrate).

Builds the ``vision`` ``ProblemSpec`` family over a small MLP/logistic
classifier on the synthetic prototype-image datasets, partitioned with the
paper's "X% homogeneous" scheme. Parameters are pytrees — the same Algos 2–7
run unchanged on these (that is the point of the pytree-based core), and
since PR 4 the comm subsystem (compressed uplinks, error feedback, bits
accounting) runs leaf-wise on them too.

``vision_spec`` is the primary constructor: specs built at different
``homogeneous_frac`` (the Table 3 heterogeneity axis) share one static
structure, so ``spec.stack_specs`` + ``core.sweep.run_sweep(problems=...)``
runs the whole grid through ONE compiled executor (``benchmarks/
table3_vision.py``). ``make_vision_problem`` keeps the legacy
``(problem, accuracy, init_params)`` signature as a spec-backed shim.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import tree_math as tm
from repro.data import partition, synthetic_vision
from repro.data.problems import problem_from_spec
from repro.data.spec import FAMILY_VISION, ProblemSpec, _consts, _vision_apply


def _mlp_init(key, dims):
    params = {}
    ks = jax.random.split(key, len(dims) - 1)
    for i, (a, b) in enumerate(zip(dims[:-1], dims[1:])):
        params[f"w{i}"] = jax.random.normal(ks[i], (a, b)) * (1.0 / a) ** 0.5
        params[f"b{i}"] = jnp.zeros((b,))
    return params


def _mlp_apply(params, x):
    return _vision_apply(params, x)


def vision_spec(
    key,
    *,
    num_clients: int = 5,
    homogeneous_frac: float = 0.5,
    num_classes: int = 10,
    per_class: int = 200,
    side: int = 14,
    hidden: int = 64,
    batch: int = 32,
    l2: float = 1e-4,
    seed: int = 0,
    name: str = "vision",
) -> ProblemSpec:
    """The Table 3 problem as a ``vision``-family spec.

    ``key`` seeds the deterministic MLP init baked into ``x0``; ``seed``
    drives the synthetic dataset + partition. The default ``name`` is
    deliberately constant-free so a ``homogeneous_frac`` grid of specs
    shares one treedef (and therefore one compiled executor) — only ARRAY
    leaves (the shards) vary across the grid.
    """
    data = synthetic_vision.make_prototype_images(
        num_classes=num_classes, per_class=per_class, side=side, seed=seed)
    cx, cy = partition.shuffled_heterogeneity(
        data, homogeneous_frac=homogeneous_frac, num_clients=num_clients,
        seed=seed)
    features = jnp.asarray(cx)  # [N, n_i, d]
    labels = jnp.asarray(cy, jnp.int32)
    n_clients, n_per, d = features.shape
    dims = (d, hidden, num_classes) if hidden else (d, num_classes)

    x0 = _mlp_init(key, dims)
    return ProblemSpec(
        family=FAMILY_VISION, num_clients=n_clients,
        dim=int(tm.tree_size(x0)), batch=batch, arch=tuple(dims), name=name,
        data=dict(features=features, labels=labels),
        consts=_consts(mu=l2, beta=10.0),  # rough β, as the legacy builder
        x0=x0, x_star=tm.tree_zeros_like(x0),
    )


def vision_accuracy(spec: ProblemSpec):
    """Pooled classification accuracy on the spec's shards — ``fn(params)``."""
    features = spec.data["features"]
    labels = spec.data["labels"]
    d = features.shape[-1]

    def accuracy(params):
        logits = _mlp_apply(params, features.reshape(-1, d))
        pred = jnp.argmax(logits, -1)
        return jnp.mean((pred == labels.reshape(-1)).astype(jnp.float32))

    return accuracy


def make_vision_problem(
    key,
    *,
    num_clients: int = 5,
    homogeneous_frac: float = 0.5,
    num_classes: int = 10,
    per_class: int = 200,
    side: int = 14,
    hidden: int = 64,
    batch: int = 32,
    l2: float = 1e-4,
    seed: int = 0,
):
    """Returns (FederatedProblem, accuracy_fn, init_params) — spec-backed.

    The shim's oracles ARE the vision spec's family oracles, so the executor
    operand path and the legacy closure path (``problems.without_spec``) run
    identical math; the returned problem carries its spec, so Table 3
    harnesses batch it through ``run_sweep(problems=...)``. ``init_params``
    keeps the legacy behavior of a fresh MLP init per PRNG key (the spec's
    own ``x0`` is the init at the builder's ``key``).
    """
    spec = vision_spec(
        key, num_clients=num_clients, homogeneous_frac=homogeneous_frac,
        num_classes=num_classes, per_class=per_class, side=side,
        hidden=hidden, batch=batch, l2=l2, seed=seed)
    problem = problem_from_spec(
        spec, name=f"vision(hom={homogeneous_frac},hidden={hidden})")
    dims = spec.arch

    def init_params(rng):
        return _mlp_init(rng, dims)

    return problem, vision_accuracy(spec), init_params
