"""Closure-free problem specifications: problems as executor OPERANDS.

``ProblemSpec`` is the data-driven redesign of the problem layer: a problem
is a registered JAX pytree whose *dynamic* content is arrays only — curvature
``A``, client offsets ``b_i``/``δ_i``, data shards ``X, y``, and the paper's
constants (μ, β, ζ, ζ_F, σ, σ_F, F*) as array leaves — plus a small *static*
part (the family tag, client/dimension counts, the minibatch size, the
perturbation-base id, the vision family's layer widths). Oracles are
dispatched through one family table keyed by the static tag
(``lax.switch``-style: the dispatch is resolved at trace time because the
tag is pytree metadata, so there is exactly one branch per family, never one
per instance).

The family table (see ``FAMILIES``):

  * ``quadratic`` — strongly convex federated quadratic, exact ζ; flat [D]
    params (data: per-client curvature/offsets).
  * ``perturbed`` — F_i = base(x) + ζ⟨u_i, x⟩ over a registered base id
    (general convex / PL); flat [D] params.
  * ``logreg``    — L2 logistic regression on data shards; flat [D] params.
  * ``vision``    — nonconvex MLP classification on synthetic image shards
    (paper Table 3): params are a PYTREE of layer weights/biases whose
    widths live in the static ``arch`` metadata, so the whole
    "X% homogeneous" heterogeneity grid (``data.vision_problem``) shares one
    compiled executor and batches through ``run_sweep(problems=...)`` —
    including ``comm=`` (the comm layer is leaf-wise).

Why: the executors in ``core.runner``/``core.chain``/``core.sweep`` compile
once per cache key. With the legacy closure problems (``data.problems``),
arrays were *closed over* Python callables, so the cache key had to be the
instance identity — every (ζ, σ, instance) point of the Tables 1–4 grids
re-traced. A ``ProblemSpec`` instead rides INTO the compiled executor as an
operand: the cache key is ``cache_key()`` (family tag + static fields + leaf
shapes/dtypes, never instance identity), so

  * re-running any same-shaped instance reuses the compile (warm ζ grids),
  * ``stack_specs`` batches a whole ζ × σ × family-instance grid into one
    stacked spec that ``core.sweep.run_sweep(problems=...)`` vmaps through a
    single compiled call, and
  * the executor cache stores ``(key, fn)`` only — no problem objects are
    pinned, so client data shards die with their last user reference.

Interface: a spec duck-types the oracle surface the algorithms and executors
use — ``num_clients`` (static), ``grad_oracle(x, i, key)``,
``value_oracle(x, i, key)``, ``client_loss(x, i)``, ``global_loss(x)``,
``init_params(key)`` and the constants — so Algos 2–7 run unchanged on a
traced spec. ``data.problems`` keeps ``FederatedProblem`` as a thin
deprecation shim wrapping a spec (bit-exact with the spec path — tested).

Noise handling: σ and σ_F are *operands* (a noise grid must not re-trace),
so the oracles add noise unconditionally; at σ = 0 the added term is exactly
``0.0 · n`` which is the float zero, keeping σ = 0 runs bitwise equal to the
legacy conditional-noise closures.
"""
from __future__ import annotations

import dataclasses
import hashlib
import warnings
from typing import Callable, NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import tree_math as tm

# ---------------------------------------------------------------------------
# the family table
# ---------------------------------------------------------------------------

FAMILY_QUADRATIC = "quadratic"
FAMILY_PERTURBED = "perturbed"
FAMILY_LOGREG = "logreg"
FAMILY_VISION = "vision"

CONST_KEYS = ("mu", "beta", "zeta", "zeta_f", "sigma", "sigma_f", "f_star")


class _Family(NamedTuple):
    """One row of the oracle dispatch table (all take the spec first)."""

    grad: Callable  # (spec, x, i, key) -> grad
    value: Callable  # (spec, x, i, key) -> scalar
    client_loss: Callable  # (spec, x, i) -> scalar
    global_loss: Callable  # (spec, x) -> scalar


# -- quadratic: F_i(x) = 0.5 x^T A_i x − b_i^T x (shared/spread curvature) --
#
# Inner products are written sum(b * x), not jnp.dot: XLA:CPU lowers a
# BATCHED dot (GEMV) with a batch-size-dependent reduction blocking, which
# would make vmapped grids of different batch sizes — in particular the
# device-sharded sweep (repro.dist), whose per-shard batch is 1/n_dev of
# the global one — differ from the single-device engine in the last ulp.
# Elementwise-multiply-then-sum lowers to a batch-invariant row reduction,
# keeping sharded and vmapped sweeps bitwise identical (tested).

def _quad_client_loss(spec, x, i):
    d = spec.data
    return 0.5 * jnp.sum(d["a_i"][i] * x**2) - jnp.sum(d["b"][i] * x)


def _quad_global_loss(spec, x):
    d = spec.data
    return 0.5 * jnp.sum(d["a_bar"] * x**2) - jnp.sum(d["b_bar"] * x)


def _quad_grad(spec, x, i, key):
    d = spec.data
    g = d["a_i"][i] * x - d["b"][i]
    noise = jax.random.normal(key, (spec.dim,))
    return g + (spec.sigma / jnp.sqrt(spec.dim)) * noise


def _quad_value(spec, x, i, key):
    v = _quad_client_loss(spec, x, i)
    return v + spec.sigma_f * jax.random.normal(key, ())


# -- perturbed: F_i(x) = base(x) + ζ⟨u_i, x⟩, Σu_i = 0 ----------------------
#
# The base objective is a *registered* callable addressed by the static
# ``base_id`` tag — the only non-array ingredient of any family, kept out of
# the dynamic data so specs stay arrays-only pytrees.

_BASE_REGISTRY: dict = {}


def register_base(name: str, fn: Callable, *, overwrite: bool = False):
    """Register a perturbation base objective under a static id.

    The id is spec metadata (part of the executor cache key): two specs with
    the same id share compiled executors, so the registered function must be
    pure and stable for the life of the process.
    """
    if not overwrite and name in _BASE_REGISTRY and _BASE_REGISTRY[name] is not fn:
        raise ValueError(f"base id {name!r} is already registered; pass "
                         f"overwrite=True to replace it")
    _BASE_REGISTRY[name] = fn
    return name


def _fingerprint_value(v) -> bytes:
    """A value-sensitive fingerprint for closure cells / defaults: arrays
    hash by their full bytes (repr truncates large arrays, which would
    conflate different data), everything else by repr."""
    try:
        arr = np.asarray(v)
        if arr.dtype != object:
            return (arr.tobytes() + str(arr.shape).encode()
                    + str(arr.dtype).encode())
    except Exception:
        pass
    return repr(v).encode()


def base_id_for(fn: Callable) -> str:
    """Auto-register a base callable, deduplicating by code AND data
    identity.

    Two functions with identical bytecode, constants, captured closure
    values and defaults get the SAME id (so re-building a problem in a loop
    reuses one compiled executor); closures over *different* values — e.g.
    a parameterized base built in a loop — get distinct ids, as do distinct
    functions sharing a qualname.
    """
    if isinstance(fn, str):
        if fn not in _BASE_REGISTRY:
            raise KeyError(f"unknown base id {fn!r}; register_base() it first")
        return fn
    code = getattr(fn, "__code__", None)
    if code is None:
        raise TypeError(f"base must be a plain function, got {type(fn)}")
    h = hashlib.sha1(code.co_code + repr(code.co_consts).encode())
    for cell in fn.__closure__ or ():
        h.update(_fingerprint_value(cell.cell_contents))
    for default in fn.__defaults__ or ():
        h.update(_fingerprint_value(default))
    name = f"fn:{getattr(fn, '__qualname__', 'base')}:{h.hexdigest()[:12]}"
    _BASE_REGISTRY.setdefault(name, fn)
    return name


def _logcosh_base(x):
    # 1-smooth, convex, minimized at 0 with value 0
    return jnp.sum(jnp.log(jnp.cosh(x)))


def _pl_sin2_base(x):
    # classic PL-but-nonconvex: μ = 1/32, β = 8
    return jnp.sum(x**2 + 3.0 * jnp.sin(x) ** 2)


register_base("logcosh", _logcosh_base)
register_base("pl_sin2", _pl_sin2_base)


def _pert_base(spec):
    return _BASE_REGISTRY[spec.base_id]


def _pert_client_loss(spec, x, i):
    # sum(u*x), not dot: batch-invariant lowering (see the quadratic note)
    return _pert_base(spec)(x) + spec.zeta * jnp.sum(spec.data["u"][i] * x)


def _pert_global_loss(spec, x):
    return _pert_base(spec)(x)


def _pert_grad(spec, x, i, key):
    g = jax.grad(_pert_base(spec))(x) + spec.zeta * spec.data["u"][i]
    noise = jax.random.normal(key, (spec.dim,))
    return g + (spec.sigma / jnp.sqrt(spec.dim)) * noise


def _pert_value(spec, x, i, key):
    v = _pert_client_loss(spec, x, i)
    return v + spec.sigma_f * jax.random.normal(key, ())


# -- logreg: L2-regularized logistic regression on data shards --------------

def _logreg_loss_on(spec, w, X, y):
    logits = X @ w
    # numerically stable BCE-with-logits (same op order as the legacy closure)
    per = (jnp.maximum(logits, 0.0) - logits * y
           + jnp.log1p(jnp.exp(-jnp.abs(logits))))
    return jnp.mean(per) + 0.5 * spec.mu * jnp.sum(w**2)  # μ IS the L2 weight


def _logreg_client_loss(spec, w, i):
    d = spec.data
    return _logreg_loss_on(spec, w, d["features"][i], d["labels"][i])


def _logreg_global_loss(spec, w):
    d = spec.data
    losses = jax.vmap(
        lambda X, y: _logreg_loss_on(spec, w, X, y))(d["features"], d["labels"])
    return jnp.mean(losses)


def _logreg_batch(spec, i, key):
    d = spec.data
    n_per = d["features"].shape[1]
    idx = jax.random.randint(key, (spec.batch,), 0, n_per)
    return d["features"][i][idx], d["labels"][i][idx]


def _logreg_grad(spec, w, i, key):
    X, y = _logreg_batch(spec, i, key)
    return jax.grad(_logreg_loss_on, argnums=1)(spec, w, X, y)


def _logreg_value(spec, w, i, key):
    X, y = _logreg_batch(spec, i, key)
    v = _logreg_loss_on(spec, w, X, y)
    return v + spec.sigma_f * jax.random.normal(key, ())


# -- vision: nonconvex MLP classification on synthetic image shards ---------
#
# The Table 3 family: parameters are a PYTREE (layer weights/biases, the
# layer widths recorded in the static ``arch`` metadata), client data are
# image shards from ``data.synthetic_vision`` partitioned with the paper's
# "X% homogeneous" scheme. μ doubles as the L2 weight (like logreg);
# softmax cross-entropy + L2 is the objective. The forward pass derives its
# depth from the params pytree structure — static under trace, so one
# compiled executor serves every same-arch instance (a whole
# heterogeneity grid).

def _vision_apply(params, x):
    n = len(params) // 2
    h = x
    for i in range(n):
        h = h @ params[f"w{i}"] + params[f"b{i}"]
        if i < n - 1:
            h = jax.nn.relu(h)
    return h


def _vision_loss_on(spec, params, X, y):
    logits = _vision_apply(params, X)
    ls = jax.nn.log_softmax(logits)
    nll = -jnp.mean(jnp.take_along_axis(ls, y[:, None], axis=1))
    reg = 0.5 * spec.mu * sum(jnp.sum(p**2) for p in jax.tree.leaves(params))
    return nll + reg


def _vision_client_loss(spec, params, i):
    d = spec.data
    return _vision_loss_on(spec, params, d["features"][i], d["labels"][i])


def _vision_global_loss(spec, params):
    d = spec.data
    losses = jax.vmap(
        lambda X, y: _vision_loss_on(spec, params, X, y)
    )(d["features"], d["labels"])
    return jnp.mean(losses)


def _vision_batch(spec, i, key):
    d = spec.data
    n_per = d["features"].shape[1]
    idx = jax.random.randint(key, (spec.batch,), 0, n_per)
    return d["features"][i][idx], d["labels"][i][idx]


def _vision_grad(spec, params, i, key):
    X, y = _vision_batch(spec, i, key)
    return jax.grad(_vision_loss_on, argnums=1)(spec, params, X, y)


def _vision_value(spec, params, i, key):
    X, y = _vision_batch(spec, i, key)
    v = _vision_loss_on(spec, params, X, y)
    return v + spec.sigma_f * jax.random.normal(key, ())


FAMILIES: dict = {
    FAMILY_QUADRATIC: _Family(_quad_grad, _quad_value,
                              _quad_client_loss, _quad_global_loss),
    FAMILY_PERTURBED: _Family(_pert_grad, _pert_value,
                              _pert_client_loss, _pert_global_loss),
    FAMILY_LOGREG: _Family(_logreg_grad, _logreg_value,
                           _logreg_client_loss, _logreg_global_loss),
    FAMILY_VISION: _Family(_vision_grad, _vision_value,
                           _vision_client_loss, _vision_global_loss),
}


# ---------------------------------------------------------------------------
# the spec pytree
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ProblemSpec:
    """A federated problem as pure data (a registered JAX pytree).

    Dynamic (pytree leaves — executor operands, batchable with vmap):
      ``data``    family-specific arrays (see the family builders),
      ``consts``  the paper's constants as float32 scalars
                  (μ, β, ζ, ζ_F, σ, σ_F, F*; F* is 0 when unknown —
                  see ``f_star_known``),
      ``x0``      the deterministic initial point,
      ``x_star``  a global optimum (zeros when unknown — ``x_star_known``).

    Static (pytree metadata — part of every executor cache key):
      ``family`` / ``num_clients`` / ``dim`` / ``base_id`` / ``batch`` /
      ``arch`` (layer widths of the vision family's MLP — input, hidden…,
      classes; ``()`` elsewhere) / ``f_star_known`` / ``x_star_known`` /
      ``name``.

    The same spec type serves unbatched instances and stacked grids: a spec
    produced by ``stack_specs`` simply has a leading axis on every leaf.
    """

    # static metadata
    family: str
    num_clients: int
    dim: int
    base_id: str = ""
    batch: int = 0
    arch: tuple = ()
    f_star_known: bool = False
    x_star_known: bool = False
    name: str = "spec"
    # dynamic leaves
    data: dict = dataclasses.field(default_factory=dict)
    consts: dict = dataclasses.field(default_factory=dict)
    x0: Optional[jnp.ndarray] = None
    x_star: Optional[jnp.ndarray] = None

    # this attribute is how the executors recognize a spec without importing
    # this module (no isinstance — keeps core free of data-layer imports)
    is_problem_spec = True

    # -- oracle surface (duck-types FederatedProblem) ----------------------
    def grad_oracle(self, x, i, key):
        return FAMILIES[self.family].grad(self, x, i, key)

    def value_oracle(self, x, i, key):
        return FAMILIES[self.family].value(self, x, i, key)

    def client_loss(self, x, i):
        return FAMILIES[self.family].client_loss(self, x, i)

    def global_loss(self, x):
        return FAMILIES[self.family].global_loss(self, x)

    def init_params(self, key):
        del key  # deterministic init, as the legacy builders
        return self.x0

    # -- constants ---------------------------------------------------------
    @property
    def mu(self):
        return self.consts["mu"]

    @property
    def beta(self):
        return self.consts["beta"]

    @property
    def zeta(self):
        return self.consts["zeta"]

    @property
    def zeta_f(self):
        return self.consts["zeta_f"]

    @property
    def sigma(self):
        return self.consts["sigma"]

    @property
    def sigma_f(self):
        return self.consts["sigma_f"]

    @property
    def f_star(self):
        """F(x*) when known, else None (mirrors the shim's Optional field)."""
        return self.consts["f_star"] if self.f_star_known else None

    @property
    def f_star_leaf(self):
        """The F* OPERAND the executors subtract — 0.0 when unknown, so
        histories of unknown-F* problems are raw objective values."""
        return self.consts["f_star"]

    # -- conveniences ------------------------------------------------------
    def kappa(self):
        mu = float(self.consts["mu"])
        return float(self.consts["beta"]) / mu if mu > 0 else float("inf")

    def suboptimality(self, params):
        f = self.global_loss(params)
        if not self.f_star_known:
            warnings.warn(
                f"problem {self.name!r} has no known F*: suboptimality() "
                f"returns the RAW objective F(x) (F* treated as 0). Solve or "
                f"supply f_star for true gaps.", stacklevel=2)
            return f
        return f - self.consts["f_star"]

    def global_grad(self, params):
        return jax.grad(self.global_loss)(params)

    def delta(self, x0):
        """Initial suboptimality gap Δ (Assumption B.9)."""
        return float(self.suboptimality(x0))

    def dist_sq(self, x0):
        """Initial distance D² (Assumption B.10), if x* is known."""
        if not self.x_star_known:
            return None
        return float(tm.tree_sq_norm(tm.tree_sub(x0, self.x_star)))

    # -- executor cache identity -------------------------------------------
    def cache_key(self):
        """Structural identity: family/static tags + leaf shapes & dtypes.

        Deliberately EXCLUDES array values and object identity — any
        same-shaped instance of the family reuses the compiled executor.
        """
        leaves, treedef = jax.tree_util.tree_flatten(self)
        return (treedef, tuple(
            (jnp.shape(l), jnp.result_type(l).name) for l in leaves))


jax.tree_util.register_dataclass(
    ProblemSpec,
    data_fields=["data", "consts", "x0", "x_star"],
    meta_fields=["family", "num_clients", "dim", "base_id", "batch", "arch",
                 "f_star_known", "x_star_known", "name"],
)


def is_spec(obj) -> bool:
    return getattr(obj, "is_problem_spec", False)


def _consts(mu=0.0, beta=1.0, zeta=0.0, zeta_f=0.0, sigma=0.0, sigma_f=0.0,
            f_star=0.0):
    vals = dict(mu=mu, beta=beta, zeta=zeta, zeta_f=zeta_f, sigma=sigma,
                sigma_f=sigma_f, f_star=f_star)
    return {k: jnp.asarray(0.0 if vals[k] is None else vals[k], jnp.float32)
            for k in CONST_KEYS}


def stack_specs(specs: Sequence[ProblemSpec]) -> ProblemSpec:
    """Stack same-family, same-shape specs into ONE spec with a leading
    problem axis on every leaf — the operand ``run_sweep(problems=...)``
    vmaps over. Static metadata must match exactly (it is the treedef)."""
    specs = list(specs)
    if not specs:
        raise ValueError("stack_specs needs at least one spec")
    td0 = jax.tree_util.tree_structure(specs[0])
    for s in specs[1:]:
        td = jax.tree_util.tree_structure(s)
        if td != td0:
            raise ValueError(
                f"cannot stack specs with different static structure:\n"
                f"  {td0}\n  {td}\n(same family, clients, dim, base and "
                f"batch are required — a grid varies ARRAY leaves only)")
    shapes0 = [jnp.shape(l) for l in jax.tree_util.tree_leaves(specs[0])]
    for s in specs[1:]:
        shapes = [jnp.shape(l) for l in jax.tree_util.tree_leaves(s)]
        if shapes != shapes0:
            raise ValueError("cannot stack specs with different leaf shapes")
    return jax.tree.map(lambda *xs: jnp.stack(xs), *specs)


def spec_count(spec: ProblemSpec) -> int:
    """Leading problem-axis length of a stacked spec (1 for a plain spec)."""
    mu = spec.consts["mu"]
    return int(mu.shape[0]) if jnp.ndim(mu) > 0 else 1


# ---------------------------------------------------------------------------
# family builders (the spec-native constructors)
# ---------------------------------------------------------------------------

def _spread_directions(key, num_clients, dim):
    """Unit-norm directions u_i with Σ u_i = 0 and max ||u_i|| = 1."""
    u = jax.random.normal(key, (num_clients, dim))
    u = u - jnp.mean(u, axis=0, keepdims=True)
    norms = jnp.linalg.norm(u, axis=1)
    u = u / jnp.maximum(jnp.max(norms), 1e-12)
    return u


def quadratic_spec(
    key,
    *,
    num_clients: int = 8,
    dim: int = 16,
    mu: float = 0.1,
    beta: float = 1.0,
    zeta: float = 0.0,
    sigma: float = 0.0,
    sigma_f: float = 0.0,
    init_scale: float = 5.0,
    curvature_spread: float = 0.0,
    name: str = "quadratic",
) -> ProblemSpec:
    """Strongly convex federated quadratic with *exact* ζ, as a spec.

    Same construction as the legacy ``problems.quadratic_problem`` (shared
    A = diag(eigs in [μ, β]); b_i = b̄ + ζ·u_i with Σu_i = 0, max||u_i|| = 1,
    optional curvature spread); see that docstring for the ζ/ζ_F semantics.
    The default ``name`` is deliberately constant-free so a ζ/σ grid of specs
    shares one treedef (and therefore one compiled executor).
    """
    k_eig, k_b, k_u, k_c, k_x0 = jax.random.split(key, 5)
    eigs = jnp.linspace(mu, beta, dim)
    b_bar = jax.random.normal(k_b, (dim,))
    u = _spread_directions(k_u, num_clients, dim)
    b = b_bar[None, :] + zeta * u  # [N, dim]

    if curvature_spread > 0:
        d_i = _spread_directions(k_c, num_clients, dim)  # Σ = 0, max-norm 1
        scale_i = jnp.clip(1.0 + curvature_spread * d_i, 0.2, 2.0)
        a_i = eigs[None, :] * scale_i  # [N, dim]
        a_bar = jnp.mean(a_i, axis=0)
    else:
        a_i = jnp.broadcast_to(eigs[None, :], (num_clients, dim))
        a_bar = eigs

    x_star = b_bar / a_bar
    f_star = float(0.5 * jnp.sum(a_bar * x_star**2) - jnp.dot(b_bar, x_star))

    x0_dir = jax.random.normal(k_x0, (dim,))
    x0 = x_star + init_scale * x0_dir / jnp.linalg.norm(x0_dir)

    # ζ_F on the init_scale ball (scale hint, as the legacy builder)
    zeta_f = float(zeta * (init_scale + jnp.linalg.norm(x_star)))

    zeta_eff = zeta
    if curvature_spread > 0:
        radius = init_scale + float(jnp.linalg.norm(x_star))
        spread_norm = float(jnp.max(jnp.linalg.norm(a_i - a_bar[None], axis=1)))
        zeta_eff = zeta + spread_norm * radius

    return ProblemSpec(
        family=FAMILY_QUADRATIC, num_clients=num_clients, dim=dim,
        f_star_known=True, x_star_known=True, name=name,
        data=dict(a_i=jnp.asarray(a_i), a_bar=jnp.asarray(a_bar),
                  b=jnp.asarray(b), b_bar=jnp.asarray(b_bar)),
        consts=_consts(mu=mu, beta=beta, zeta=zeta_eff, zeta_f=zeta_f,
                       sigma=sigma, sigma_f=sigma_f, f_star=f_star),
        x0=jnp.asarray(x0), x_star=jnp.asarray(x_star),
    )


def perturbed_spec(
    key,
    base,
    *,
    dim: int,
    num_clients: int = 8,
    mu: float = 0.0,
    beta: float = 1.0,
    zeta: float = 0.0,
    sigma: float = 0.0,
    sigma_f: float = 0.0,
    f_star: Optional[float] = None,
    x_star=None,
    init_scale: float = 3.0,
    name: str = "perturbed",
) -> ProblemSpec:
    """F_i(x) = base(x) + ζ⟨u_i, x⟩ with Σu_i = 0, as a spec.

    ``base`` is a registered base id (str) or a plain function (auto-
    registered — see ``base_id_for``). The global objective is exactly the
    base, so general-convex and PL federated problems get exact ζ.
    """
    base_id = base_id_for(base)
    k_u, k_x0 = jax.random.split(key)
    u = _spread_directions(k_u, num_clients, dim)

    x0_dir = jax.random.normal(k_x0, (dim,))
    x0 = init_scale * x0_dir / jnp.linalg.norm(x0_dir)
    if x_star is not None:
        x0 = x_star + x0

    return ProblemSpec(
        family=FAMILY_PERTURBED, num_clients=num_clients, dim=dim,
        base_id=base_id, f_star_known=f_star is not None,
        x_star_known=x_star is not None, name=name,
        data=dict(u=jnp.asarray(u)),
        consts=_consts(mu=mu, beta=beta, zeta=zeta, sigma=sigma,
                       sigma_f=sigma_f, f_star=f_star),
        x0=jnp.asarray(x0),
        x_star=(jnp.asarray(x_star) if x_star is not None
                else jnp.zeros((dim,), jnp.float32)),
    )


def general_convex_spec(key, **kw):
    """Smooth general-convex base: log-cosh (1-smooth, not strongly convex)."""
    dim = kw.pop("dim", 16)
    name = kw.pop("name", "general_convex")
    return perturbed_spec(
        key, "logcosh", dim=dim, mu=0.0, beta=1.0, f_star=0.0,
        x_star=jnp.zeros((dim,)), name=name, **kw)


def pl_spec(key, **kw):
    """Nonconvex μ-PL base: f(t) = t² + 3 sin²(t); μ = 1/32, β = 8."""
    dim = kw.pop("dim", 8)
    name = kw.pop("name", "pl")
    return perturbed_spec(
        key, "pl_sin2", dim=dim, mu=1.0 / 32.0, beta=8.0, f_star=0.0,
        x_star=jnp.zeros((dim,)), name=name, **kw)


def solve_logreg_optimum(features, labels, l2: float, *, iters: int = 100,
                         tol: float = 1e-12):
    """(x*, F*) of the federated L2-logistic objective by float64 Newton.

    The per-client shards have equal sizes ([N, n, d]), so the client-mean of
    sample-means equals the mean over all pooled samples; Newton on the
    pooled objective with the exact Hessian converges to ~machine-ε in a
    handful of steps — the "high-precision" F* Table 2 needs for true
    suboptimality reporting.
    """
    X = np.asarray(features, np.float64)
    y = np.asarray(labels, np.float64)
    n_clients, n_per, d = X.shape
    Xf = X.reshape(-1, d)
    yf = y.reshape(-1)
    m = float(len(yf))
    w = np.zeros(d)
    for _ in range(iters):
        z = Xf @ w
        p = 0.5 * (1.0 + np.tanh(0.5 * z))  # overflow-stable sigmoid
        g = Xf.T @ (p - yf) / m + l2 * w
        if float(np.linalg.norm(g)) < tol:
            break
        h = (Xf * (p * (1.0 - p))[:, None]).T @ Xf / m + l2 * np.eye(d)
        w = w - np.linalg.solve(h, g)
    z = Xf @ w
    per = np.maximum(z, 0.0) - z * yf + np.log1p(np.exp(-np.abs(z)))
    f_star = float(per.mean() + 0.5 * l2 * float(w @ w))
    return w, f_star


def logreg_spec(
    key,
    *,
    features,  # [N_clients, n_i, d] per-client design matrices
    labels,  # [N_clients, n_i] in {0,1}
    l2: float = 0.1,
    oracle_batch_frac: float = 0.01,
    sigma_f: float = 0.0,
    estimate_zeta: bool = False,
    zeta_probes: int = 8,
    zeta_probe_radius: float = 1.0,
    solve_f_star: bool = True,
    name: str = "logreg",
) -> ProblemSpec:
    """Federated L2-regularized logistic regression, as a spec.

    One oracle call = one minibatch of ``oracle_batch_frac`` of the client's
    local data. ``solve_f_star`` (default) populates F*/x* by the float64
    Newton solve — Table 2 then reports TRUE suboptimality instead of raw
    loss. ``estimate_zeta`` measures ζ/ζ_F via ``core.heterogeneity`` probes
    around the init point (``key`` seeds the probes).
    """
    features = jnp.asarray(features)
    labels = jnp.asarray(labels, features.dtype)
    num_clients, n_per, dim = features.shape
    batch = max(1, int(round(oracle_batch_frac * n_per)))
    # β of logreg ≤ 0.25·max||x||² + l2 ; report a sound bound
    beta = float(0.25 * jnp.max(jnp.sum(features**2, axis=-1)) + l2)

    if solve_f_star:
        x_star, f_star = solve_logreg_optimum(features, labels, l2)
        x_star = jnp.asarray(x_star, features.dtype)
    else:
        x_star, f_star = jnp.zeros((dim,), features.dtype), None

    spec = ProblemSpec(
        family=FAMILY_LOGREG, num_clients=num_clients, dim=dim, batch=batch,
        f_star_known=f_star is not None, x_star_known=f_star is not None,
        name=name,
        data=dict(features=features, labels=labels),
        consts=_consts(mu=l2, beta=beta, sigma_f=sigma_f, f_star=f_star),
        x0=jnp.zeros((dim,), features.dtype),  # paper initializes at 0
        x_star=x_star,
    )
    if estimate_zeta:
        from repro.core import heterogeneity

        spec = heterogeneity.with_measured_heterogeneity(
            spec, key, probes=zeta_probes, radius=zeta_probe_radius)
    return spec
