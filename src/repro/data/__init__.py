"""Federated data substrate: problem specs, partitioners, synthetic datasets.

``spec`` (ProblemSpec — problems as executor operands) is the primary
problem API; ``problems`` keeps the legacy closure interface as a shim.
"""
from repro.data import partition, problems, spec, synthetic_vision, tokens

__all__ = ["partition", "problems", "spec", "synthetic_vision", "tokens"]
