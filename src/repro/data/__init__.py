"""Federated data substrate: problems, partitioners, synthetic datasets."""
from repro.data import partition, problems, synthetic_vision, tokens

__all__ = ["partition", "problems", "synthetic_vision", "tokens"]
