"""Synthetic image-classification datasets (the container is offline, so the
paper's MNIST/EMNIST/CIFAR experiments run on statistically similar synthetic
stand-ins: Gaussian class prototypes + structured noise).

The generator is deterministic in (seed, shape) so experiments reproduce.
"""
from __future__ import annotations

import numpy as np


def make_prototype_images(
    *,
    num_classes: int = 10,
    per_class: int = 500,
    side: int = 14,
    noise: float = 0.35,
    seed: int = 0,
):
    """[num_classes, per_class, side*side] float32 in ~[0, 1].

    Each class is a smooth random prototype; samples are prototype + blurred
    noise, giving a linearly-separable-but-noisy task akin to MNIST digits.
    """
    rng = np.random.default_rng(seed)
    d = side * side
    # smooth prototypes: low-frequency random fields
    freq = rng.normal(size=(num_classes, 4, 4))
    protos = np.zeros((num_classes, side, side), dtype=np.float32)
    xs = np.linspace(0, 1, side)
    for c in range(num_classes):
        img = np.zeros((side, side))
        for i in range(4):
            for j in range(4):
                img += freq[c, i, j] * np.outer(
                    np.sin(np.pi * (i + 1) * xs), np.sin(np.pi * (j + 1) * xs)
                )
        protos[c] = img
    protos = (protos - protos.min()) / (protos.max() - protos.min() + 1e-9)

    data = np.empty((num_classes, per_class, d), dtype=np.float32)
    for c in range(num_classes):
        eps = rng.normal(scale=noise, size=(per_class, side, side))
        data[c] = np.clip(protos[c][None] + eps, 0.0, 1.0).reshape(per_class, d)
    return data


def binary_labels_even_odd(labels: np.ndarray) -> np.ndarray:
    """Paper App. I.1: even classes → 0, odd classes → 1."""
    return (labels % 2).astype(np.float32)


def make_emnist_like(
    *, num_classes: int = 62, per_class: int = 120, side: int = 14, seed: int = 1
):
    return make_prototype_images(
        num_classes=num_classes, per_class=per_class, side=side, seed=seed
    )
