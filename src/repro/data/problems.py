"""Federated optimization problems — the legacy closure API over ProblemSpec.

The paper's setting (§2): ``F(x) = (1/N) Σ_i F_i(x)`` with β-smooth client
objectives (Assumption B.4), heterogeneity ζ² = max_i sup_x ||∇F − ∇F_i||²
(B.5), stochastic gradient/value oracles with variance σ²/σ_F² (B.6/B.7) and
function-value deviation ζ_F (B.8). Every problem exposes *exact* constants
(μ, β, ζ, Δ, D, F*), which lets tests and benchmarks compare measured
suboptimality against the executable rate bounds in ``repro.core.theory``.

API status — **``repro.data.spec.ProblemSpec`` is the primary problem API**.
A spec is a pytree of arrays (curvature, client offsets, data shards, and
the constants as leaves) whose oracles dispatch through a static family
table, so the single-compile executors in ``core.runner``/``core.chain``/
``core.sweep`` take the problem as an OPERAND: any same-shaped instance
reuses one compile, and ``run_sweep(problems=...)`` vmaps a whole
ζ × σ × instance grid through one compiled call (see
``examples/problem_sweep.py``).

``FederatedProblem`` remains as a thin deprecation shim: the builders here
(``quadratic_problem``/``perturbed_problem``/``logreg_problem``/…) construct
a spec and wrap it — the shim's callables ARE the spec's family oracles, so
shim-built and spec-built runs are bit-exact (tested in
``tests/test_problem_spec.py``). Executors unwrap the ``.spec`` attribute
and run the operand path; a hand-built ``FederatedProblem`` with custom
closures (no spec — e.g. ``data.vision_problem``) still works through the
legacy per-instance executor path.

Two constructions give exact ζ control:

  * ``quadratic_problem``: shared curvature A, client-specific linear terms
    b_i ⇒ ∇F_i − ∇F = b̄ − b_i is *constant in x*, so ζ is exact.
  * ``perturbed_problem``: F_i(x) = F(x) + ⟨δ_i, x⟩ with Σδ_i = 0 ⇒ the global
    objective is exactly the base F (convex / PL / nonconvex as desired) while
    clients are ζ-heterogeneous.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.core import tree_math as tm
from repro.data.spec import (  # re-exported: spec is the primary API
    ProblemSpec, general_convex_spec, logreg_spec, perturbed_spec, pl_spec,
    quadratic_spec, register_base, solve_logreg_optimum, stack_specs,
)

__all__ = [
    "FederatedProblem", "ProblemSpec", "problem_from_spec", "without_spec",
    "quadratic_problem", "perturbed_problem", "general_convex_problem",
    "pl_problem", "logreg_problem", "quadratic_spec", "perturbed_spec",
    "general_convex_spec", "pl_spec", "logreg_spec", "stack_specs",
    "register_base", "solve_logreg_optimum",
]


@dataclasses.dataclass(frozen=True)
class FederatedProblem:
    """Deprecation shim: a federated problem as closures over arrays.

    Prefer ``ProblemSpec`` (``repro.data.spec``) — executors treat specs as
    operands and never re-trace per instance. Shims built by the module
    builders carry their spec in ``.spec`` and get the operand path
    automatically; ``spec=None`` marks a legacy hand-closure problem, which
    executors compile per instance (identity-keyed, weakly referenced).

    Oracles follow the paper's client query model: one call = one stochastic
    sample; algorithms average K calls per round (Algo 7 ``Grad``).
    """

    num_clients: int
    # stochastic oracles ---------------------------------------------------
    grad_oracle: Callable  # (params, client_id, key) -> grad pytree
    value_oracle: Callable  # (params, client_id, key) -> scalar
    # exact quantities (for evaluation / theory) ---------------------------
    client_loss: Callable  # (params, client_id) -> F_i(params), exact
    global_loss: Callable  # (params,) -> F(params), exact
    init_params: Callable  # (key,) -> params pytree
    # problem constants ----------------------------------------------------
    mu: float = 0.0  # strong convexity / PL constant (0 => general convex)
    beta: float = 1.0  # smoothness
    zeta: float = 0.0  # heterogeneity (exact where construction permits)
    zeta_f: float = 0.0  # function-value heterogeneity (B.8)
    sigma: float = 0.0  # gradient oracle std (B.6)
    sigma_f: float = 0.0  # value oracle std (B.7)
    f_star: Optional[float] = None  # F(x*) if known
    x_star: Optional[jnp.ndarray] = None  # a global optimum if known
    name: str = "problem"
    spec: Optional[ProblemSpec] = None  # the operand form (None = legacy)

    # convenience ----------------------------------------------------------
    def kappa(self):
        return self.beta / self.mu if self.mu > 0 else float("inf")

    def suboptimality(self, params):
        f = self.global_loss(params)
        if self.f_star is None:
            warnings.warn(
                f"problem {self.name!r} has no known F*: suboptimality() "
                f"returns the RAW objective F(x) (F* treated as 0). Solve or "
                f"supply f_star for true gaps.", stacklevel=2)
            return f
        return f - self.f_star

    def global_grad(self, params):
        return jax.grad(self.global_loss)(params)

    def delta(self, x0):
        """Initial suboptimality gap Δ (Assumption B.9)."""
        return float(self.suboptimality(x0))

    def dist_sq(self, x0):
        """Initial distance D² (Assumption B.10), if x* is known."""
        if self.x_star is None:
            return None
        return float(tm.tree_sq_norm(tm.tree_sub(x0, self.x_star)))


def problem_from_spec(spec: ProblemSpec, *, name: Optional[str] = None
                      ) -> FederatedProblem:
    """Wrap a spec in the legacy ``FederatedProblem`` interface.

    The callables are the spec's own family oracles (bound methods capturing
    the spec), so any code path — shim closures or spec operands — runs the
    identical math. Executors unwrap ``.spec`` and use the operand path.
    """
    f_star = float(spec.consts["f_star"]) if spec.f_star_known else None
    return FederatedProblem(
        num_clients=spec.num_clients,
        grad_oracle=spec.grad_oracle,
        value_oracle=spec.value_oracle,
        client_loss=spec.client_loss,
        global_loss=spec.global_loss,
        init_params=spec.init_params,
        mu=float(spec.consts["mu"]),
        beta=float(spec.consts["beta"]),
        zeta=float(spec.consts["zeta"]),
        zeta_f=float(spec.consts["zeta_f"]),
        sigma=float(spec.consts["sigma"]),
        sigma_f=float(spec.consts["sigma_f"]),
        f_star=f_star,
        x_star=spec.x_star if spec.x_star_known else None,
        name=name or spec.name,
        spec=spec,
    )


def without_spec(problem: FederatedProblem) -> FederatedProblem:
    """The problem with its spec detached — executors then take the legacy
    per-instance closure path. Exists for the spec↔closure equivalence tests
    and as an escape hatch while the closure path is deprecated."""
    return dataclasses.replace(problem, spec=None)


# ---------------------------------------------------------------------------
# builders (legacy signatures, spec-backed)
# ---------------------------------------------------------------------------

def quadratic_problem(
    key,
    *,
    num_clients: int = 8,
    dim: int = 16,
    mu: float = 0.1,
    beta: float = 1.0,
    zeta: float = 0.0,
    sigma: float = 0.0,
    sigma_f: float = 0.0,
    init_scale: float = 5.0,
    curvature_spread: float = 0.0,
) -> FederatedProblem:
    """Strongly convex federated quadratic with *exact* ζ (spec-backed shim).

    Shared A = diag(eigs in [μ, β]); b_i = b̄ + ζ·u_i, Σu_i = 0, max||u_i|| = 1
    ⇒ ∇F_i(x) − ∇F(x) = ζ·u_i  (independent of x) ⇒ ζ² exactly Assumption B.5.

    ``curvature_spread`` > 0 additionally spreads the client curvatures
    (A_i = A·(1 + s·d_i), Σd_i = 0). FedAvg's fixed point then moves AWAY from
    x* (its drift no longer cancels by symmetry — the regime where Algo 1's
    selection step earns its keep); ζ becomes position-dependent (the paper's
    Def. 5.3 (ζ, c)-heterogeneity) and the reported ``zeta`` is the value at
    radius ``init_scale`` around x*.
    """
    spec = quadratic_spec(
        key, num_clients=num_clients, dim=dim, mu=mu, beta=beta, zeta=zeta,
        sigma=sigma, sigma_f=sigma_f, init_scale=init_scale,
        curvature_spread=curvature_spread)
    return problem_from_spec(
        spec, name=f"quadratic(mu={mu},beta={beta},zeta={zeta})")


def perturbed_problem(
    key,
    base_loss,
    *,
    dim: int,
    num_clients: int = 8,
    mu: float = 0.0,
    beta: float = 1.0,
    zeta: float = 0.0,
    sigma: float = 0.0,
    sigma_f: float = 0.0,
    f_star: Optional[float] = None,
    x_star=None,
    init_scale: float = 3.0,
    name: str = "perturbed",
) -> FederatedProblem:
    """F_i(x) = base(x) + ζ⟨u_i, x⟩ with Σu_i=0 ⇒ global F == base exactly.

    ``base_loss`` may be a registered base id (str) or a plain function
    (auto-registered into the spec family table — see ``spec.base_id_for``).
    """
    spec = perturbed_spec(
        key, base_loss, dim=dim, num_clients=num_clients, mu=mu, beta=beta,
        zeta=zeta, sigma=sigma, sigma_f=sigma_f, f_star=f_star,
        x_star=x_star, init_scale=init_scale, name=name)
    return problem_from_spec(spec, name=name)


def general_convex_problem(key, **kw):
    """Smooth general-convex base: log-cosh (1-smooth, not strongly convex)."""
    spec = general_convex_spec(key, **kw)
    return problem_from_spec(spec, name="general_convex(logcosh)")


def pl_problem(key, **kw):
    """Nonconvex μ-PL base: f(t) = t² + 3 sin²(t) summed over coords.

    Classic PL-but-nonconvex example; PL constant μ = 1/32, smoothness β = 8.
    """
    spec = pl_spec(key, **kw)
    return problem_from_spec(spec, name="pl(x^2+3sin^2)")


def logreg_problem(
    key,
    *,
    features,  # [N_clients, n_i, d] per-client design matrices
    labels,  # [N_clients, n_i] in {0,1}
    l2: float = 0.1,
    oracle_batch_frac: float = 0.01,
    sigma_f: float = 0.0,
    estimate_zeta: bool = False,
    zeta_probes: int = 8,
    zeta_probe_radius: float = 1.0,
    solve_f_star: bool = True,
) -> FederatedProblem:
    """Federated L2-regularized logistic regression on pre-partitioned data.

    One oracle call = one minibatch of ``oracle_batch_frac`` of the client's
    local data (the paper's convex experiments use 1% minibatches).

    ``solve_f_star`` (default True) populates F*/x* via a high-precision
    float64 Newton solve, so suboptimality reporting is a true gap instead of
    the raw loss. ``estimate_zeta=True`` measures ζ/ζ_F via
    ``core.heterogeneity`` probes around the init point (``key`` seeds them).
    """
    spec = logreg_spec(
        key, features=features, labels=labels, l2=l2,
        oracle_batch_frac=oracle_batch_frac, sigma_f=sigma_f,
        estimate_zeta=estimate_zeta, zeta_probes=zeta_probes,
        zeta_probe_radius=zeta_probe_radius, solve_f_star=solve_f_star)
    return problem_from_spec(spec, name=f"logreg(l2={l2})")
