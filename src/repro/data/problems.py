"""Federated optimization problems with controllable heterogeneity.

The paper's setting (§2): ``F(x) = (1/N) Σ_i F_i(x)`` with

  * β-smooth client objectives (Assumption B.4),
  * heterogeneity ζ² = max_i sup_x ||∇F(x) − ∇F_i(x)||² (Assumption B.5),
  * stochastic gradient oracle with variance ≤ σ² (B.6),
  * stochastic function-value oracle with variance ≤ σ_F² and deviation ζ_F (B.7/B.8).

Every problem here exposes *exact* problem constants (μ, β, ζ, Δ, D, F*), which
is what lets the tests and benchmarks compare measured suboptimality against
the executable rate bounds in ``repro.core.theory``.

Two constructions give exact ζ control:

  * ``quadratic_problem``: shared curvature A, client-specific linear terms
    b_i ⇒ ∇F_i − ∇F = b̄ − b_i is *constant in x*, so ζ is exact.
  * ``perturbed_problem``: F_i(x) = F(x) + ⟨δ_i, x⟩ with Σδ_i = 0 ⇒ the global
    objective is exactly the base F (convex / PL / nonconvex as desired) while
    clients are ζ-heterogeneous.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.core import tree_math as tm


@dataclasses.dataclass(frozen=True)
class FederatedProblem:
    """A federated optimization problem (static; close arrays over callables).

    Oracles follow the paper's client query model: one call = one stochastic
    sample; algorithms average K calls per round (Algo 7 ``Grad``).
    """

    num_clients: int
    # stochastic oracles ---------------------------------------------------
    grad_oracle: Callable  # (params, client_id, key) -> grad pytree
    value_oracle: Callable  # (params, client_id, key) -> scalar
    # exact quantities (for evaluation / theory) ---------------------------
    client_loss: Callable  # (params, client_id) -> F_i(params), exact
    global_loss: Callable  # (params,) -> F(params), exact
    init_params: Callable  # (key,) -> params pytree
    # problem constants ----------------------------------------------------
    mu: float = 0.0  # strong convexity / PL constant (0 => general convex)
    beta: float = 1.0  # smoothness
    zeta: float = 0.0  # heterogeneity (exact where construction permits)
    zeta_f: float = 0.0  # function-value heterogeneity (B.8)
    sigma: float = 0.0  # gradient oracle std (B.6)
    sigma_f: float = 0.0  # value oracle std (B.7)
    f_star: Optional[float] = None  # F(x*) if known
    x_star: Optional[jnp.ndarray] = None  # a global optimum if known
    name: str = "problem"

    # convenience ----------------------------------------------------------
    def kappa(self):
        return self.beta / self.mu if self.mu > 0 else float("inf")

    def suboptimality(self, params):
        f = self.global_loss(params)
        return f - (self.f_star if self.f_star is not None else 0.0)

    def global_grad(self, params):
        return jax.grad(self.global_loss)(params)

    def delta(self, x0):
        """Initial suboptimality gap Δ (Assumption B.9)."""
        return float(self.suboptimality(x0))

    def dist_sq(self, x0):
        """Initial distance D² (Assumption B.10), if x* is known."""
        if self.x_star is None:
            return None
        return float(tm.tree_sq_norm(tm.tree_sub(x0, self.x_star)))


# ---------------------------------------------------------------------------
# Quadratic problems: F_i(x) = 0.5 x^T A x - b_i^T x   (shared curvature)
# ---------------------------------------------------------------------------

def _spread_directions(key, num_clients, dim):
    """Unit-norm directions u_i with Σ u_i = 0 and max ||u_i|| = 1."""
    u = jax.random.normal(key, (num_clients, dim))
    u = u - jnp.mean(u, axis=0, keepdims=True)
    # normalize so the largest has norm exactly 1
    norms = jnp.linalg.norm(u, axis=1)
    u = u / jnp.maximum(jnp.max(norms), 1e-12)
    return u


def quadratic_problem(
    key,
    *,
    num_clients: int = 8,
    dim: int = 16,
    mu: float = 0.1,
    beta: float = 1.0,
    zeta: float = 0.0,
    sigma: float = 0.0,
    sigma_f: float = 0.0,
    init_scale: float = 5.0,
    curvature_spread: float = 0.0,
) -> FederatedProblem:
    """Strongly convex federated quadratic with *exact* ζ.

    Shared A = diag(eigs in [μ, β]); b_i = b̄ + ζ·u_i, Σu_i = 0, max||u_i|| = 1
    ⇒ ∇F_i(x) − ∇F(x) = ζ·u_i  (independent of x) ⇒ ζ² exactly Assumption B.5.

    ``curvature_spread`` > 0 additionally spreads the client curvatures
    (A_i = A·(1 + s·d_i), Σd_i = 0). FedAvg's fixed point then moves AWAY from
    x* (its drift no longer cancels by symmetry — the regime where Algo 1's
    selection step earns its keep); ζ becomes position-dependent (the paper's
    Def. 5.3 (ζ, c)-heterogeneity) and the reported ``zeta`` is the value at
    radius ``init_scale`` around x*.
    """
    k_eig, k_b, k_u, k_c, k_x0 = jax.random.split(key, 5)
    eigs = jnp.linspace(mu, beta, dim)
    b_bar = jax.random.normal(k_b, (dim,))
    u = _spread_directions(k_u, num_clients, dim)
    b = b_bar[None, :] + zeta * u  # [N, dim]

    if curvature_spread > 0:
        d_i = _spread_directions(k_c, num_clients, dim)  # Σ = 0, max-norm 1
        scale_i = jnp.clip(1.0 + curvature_spread * d_i, 0.2, 2.0)
        a_i = eigs[None, :] * scale_i  # [N, dim]
        a_bar = jnp.mean(a_i, axis=0)
    else:
        a_i = jnp.broadcast_to(eigs[None, :], (num_clients, dim))
        a_bar = eigs

    x_star = b_bar / a_bar
    f_star = float(0.5 * jnp.sum(a_bar * x_star**2) - jnp.dot(b_bar, x_star))

    def client_loss(x, i):
        return 0.5 * jnp.sum(a_i[i] * x**2) - jnp.dot(b[i], x)

    def global_loss(x):
        return 0.5 * jnp.sum(a_bar * x**2) - jnp.dot(b_bar, x)

    def grad_oracle(x, i, rng):
        g = a_i[i] * x - b[i]
        if sigma > 0:
            g = g + (sigma / jnp.sqrt(dim)) * jax.random.normal(rng, (dim,))
        return g

    def value_oracle(x, i, rng):
        v = client_loss(x, i)
        if sigma_f > 0:
            v = v + sigma_f * jax.random.normal(rng, ())
        return v

    x0_dir = jax.random.normal(k_x0, (dim,))
    x0_base = x_star + init_scale * x0_dir / jnp.linalg.norm(x0_dir)

    def init_params(rng):
        del rng
        return x0_base

    # ζ_F: sup_x |F_i - F| = sup |⟨b̄-b_i, x⟩| unbounded; report on the unit
    # D-ball around x*: ζ_F ≈ ζ·(D + ||x*||) — used only as a scale hint.
    zeta_f = float(zeta * (init_scale + jnp.linalg.norm(x_star)))

    zeta_eff = zeta
    if curvature_spread > 0:
        # ζ at radius init_scale around x* (Def. 5.3 style)
        radius = init_scale + float(jnp.linalg.norm(x_star))
        spread_norm = float(jnp.max(jnp.linalg.norm(a_i - a_bar[None], axis=1)))
        zeta_eff = zeta + spread_norm * radius

    return FederatedProblem(
        num_clients=num_clients,
        grad_oracle=grad_oracle,
        value_oracle=value_oracle,
        client_loss=client_loss,
        global_loss=global_loss,
        init_params=init_params,
        mu=mu,
        beta=beta,
        zeta=zeta_eff,
        zeta_f=zeta_f,
        sigma=sigma,
        sigma_f=sigma_f,
        f_star=f_star,
        x_star=x_star,
        name=f"quadratic(mu={mu},beta={beta},zeta={zeta})",
    )


# ---------------------------------------------------------------------------
# Linear-perturbation problems: F_i = F + <delta_i, x>, Σ delta_i = 0
# ---------------------------------------------------------------------------

def perturbed_problem(
    key,
    base_loss: Callable,
    *,
    dim: int,
    num_clients: int = 8,
    mu: float = 0.0,
    beta: float = 1.0,
    zeta: float = 0.0,
    sigma: float = 0.0,
    sigma_f: float = 0.0,
    f_star: Optional[float] = None,
    x_star=None,
    init_scale: float = 3.0,
    name: str = "perturbed",
) -> FederatedProblem:
    """F_i(x) = base(x) + ζ⟨u_i, x⟩ with Σu_i=0 ⇒ global F == base exactly.

    Lets us build *general convex* (μ=0) and *PL nonconvex* federated problems
    with exact heterogeneity: ∇F_i − ∇F = ζ·u_i.
    """
    k_u, k_x0 = jax.random.split(key)
    u = _spread_directions(k_u, num_clients, dim)

    def client_loss(x, i):
        return base_loss(x) + zeta * jnp.dot(u[i], x)

    def global_loss(x):
        return base_loss(x)

    base_grad = jax.grad(base_loss)

    def grad_oracle(x, i, rng):
        g = base_grad(x) + zeta * u[i]
        if sigma > 0:
            g = g + (sigma / jnp.sqrt(dim)) * jax.random.normal(rng, (dim,))
        return g

    def value_oracle(x, i, rng):
        v = client_loss(x, i)
        if sigma_f > 0:
            v = v + sigma_f * jax.random.normal(rng, ())
        return v

    x0_dir = jax.random.normal(k_x0, (dim,))
    x0_base = init_scale * x0_dir / jnp.linalg.norm(x0_dir)
    if x_star is not None:
        x0_base = x_star + x0_base

    def init_params(rng):
        del rng
        return x0_base

    return FederatedProblem(
        num_clients=num_clients,
        grad_oracle=grad_oracle,
        value_oracle=value_oracle,
        client_loss=client_loss,
        global_loss=global_loss,
        init_params=init_params,
        mu=mu,
        beta=beta,
        zeta=zeta,
        sigma=sigma,
        sigma_f=sigma_f,
        f_star=f_star,
        x_star=x_star,
        name=name,
    )


def general_convex_problem(key, **kw):
    """Smooth general-convex base: log-cosh (1-smooth, not strongly convex)."""
    dim = kw.pop("dim", 16)

    def base(x):
        # logcosh is 1-smooth, convex, minimized at 0 with value 0
        return jnp.sum(jnp.log(jnp.cosh(x)))

    return perturbed_problem(
        key, base, dim=dim, mu=0.0, beta=1.0, f_star=0.0,
        x_star=jnp.zeros((dim,)), name="general_convex(logcosh)", **kw,
    )


def pl_problem(key, **kw):
    """Nonconvex μ-PL base: f(t) = t² + 3 sin²(t) summed over coords.

    Classic PL-but-nonconvex example; PL constant μ = 1/32, smoothness β = 8.
    """
    dim = kw.pop("dim", 8)

    def base(x):
        return jnp.sum(x**2 + 3.0 * jnp.sin(x) ** 2)

    return perturbed_problem(
        key, base, dim=dim, mu=1.0 / 32.0, beta=8.0, f_star=0.0,
        x_star=jnp.zeros((dim,)), name="pl(x^2+3sin^2)", **kw,
    )


# ---------------------------------------------------------------------------
# Federated regularized logistic regression (paper §6 / App I.1)
# ---------------------------------------------------------------------------

def logreg_problem(
    key,
    *,
    features,  # [N_clients, n_i, d] per-client design matrices
    labels,  # [N_clients, n_i] in {0,1}
    l2: float = 0.1,
    oracle_batch_frac: float = 0.01,
    sigma_f: float = 0.0,
    estimate_zeta: bool = False,
    zeta_probes: int = 8,
    zeta_probe_radius: float = 1.0,
) -> FederatedProblem:
    """Federated L2-regularized logistic regression on pre-partitioned data.

    One oracle call = one minibatch of ``oracle_batch_frac`` of the client's
    local data (the paper's convex experiments use 1% minibatches).

    ``estimate_zeta=True`` measures the heterogeneity constants via
    ``core.heterogeneity`` instead of reporting the vacuous ζ = 0: ζ (and
    ζ_F) are maximized over the init point plus ``zeta_probes`` random
    points in a ``zeta_probe_radius`` ball around it (``key`` seeds the
    probes) — a lower bound on the Assumption B.5 sup, which is what the
    theory-vs-measured comparisons need to be non-trivial on real data.
    """
    num_clients, n_per, dim = features.shape
    batch = max(1, int(round(oracle_batch_frac * n_per)))

    def _loss_on(w, X, y):
        logits = X @ w
        # numerically stable BCE-with-logits
        per = jnp.maximum(logits, 0.0) - logits * y + jnp.log1p(jnp.exp(-jnp.abs(logits)))
        return jnp.mean(per) + 0.5 * l2 * jnp.sum(w**2)

    def client_loss(w, i):
        return _loss_on(w, features[i], labels[i])

    def global_loss(w):
        losses = jax.vmap(lambda X, y: _loss_on(w, X, y))(features, labels)
        return jnp.mean(losses)

    def _batch(i, rng):
        idx = jax.random.randint(rng, (batch,), 0, n_per)
        return features[i][idx], labels[i][idx]

    def grad_oracle(w, i, rng):
        X, y = _batch(i, rng)
        return jax.grad(_loss_on)(w, X, y)

    def value_oracle(w, i, rng):
        X, y = _batch(i, rng)
        v = _loss_on(w, X, y)
        if sigma_f > 0:
            v = v + sigma_f * jax.random.normal(rng, ())
        return v

    def init_params(rng):
        del rng
        return jnp.zeros((dim,))  # paper initializes at 0 (App I.1)

    # β of logreg ≤ 0.25·max||x||² + l2 ; report a sound bound
    beta = float(0.25 * jnp.max(jnp.sum(features**2, axis=-1)) + l2)

    problem = FederatedProblem(
        num_clients=num_clients,
        grad_oracle=grad_oracle,
        value_oracle=value_oracle,
        client_loss=client_loss,
        global_loss=global_loss,
        init_params=init_params,
        mu=l2,
        beta=beta,
        zeta=0.0,  # vacuous unless estimate_zeta is set
        sigma_f=sigma_f,
        f_star=None,
        name=f"logreg(l2={l2})",
    )
    if estimate_zeta:
        from repro.core import heterogeneity

        x_init = init_params(None)
        keys = jax.random.split(key, max(zeta_probes, 1))
        probes = [x_init] + [
            x_init + zeta_probe_radius * jax.random.normal(k, (dim,))
            / jnp.sqrt(float(dim))
            for k in keys[:zeta_probes]
        ]
        zeta = float(heterogeneity.estimate_zeta(problem, probes))
        zeta_f = float(max(float(heterogeneity.zeta_f_at(problem, x))
                           for x in probes))
        problem = dataclasses.replace(problem, zeta=zeta, zeta_f=zeta_f)
    return problem
