"""The model zoo's unified network: dense / MoE / MLA / SSM / hybrid decoder
LMs, enc-dec (audio), and VLM (prefix-LM), built from scanned block stacks.

Public surface:
  init_model(cfg, key)                     -> params
  forward(params, cfg, batch, *, ctx)      -> (logits, aux_loss)
  lm_loss(params, cfg, batch)              -> (loss, metrics)
  prefill(params, cfg, batch, caches)      -> (last_logits, caches)
  decode_step(params, cfg, tokens, caches, pos [, cross])
  init_caches / cache_specs(cfg, batch, max_len)
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import blocks
from repro.models.layers import attention as attn_lib
from repro.models.layers import common, mla as mla_lib, ssm as ssm_lib
from repro.sharding import logical

MTP_WEIGHT = 0.3  # deepseek-v3 MTP loss weight


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init_model(cfg, key):
    dtype = cfg.param_dtype()
    keys = jax.random.split(key, 16)
    params = {"embed": common.init_embedding(keys[0], cfg.vocab_size, cfg.d_model, dtype)}

    if cfg.arch_type == "hybrid":
        init_fn, _ = blocks.make_block(cfg, "mamba")
        params["seg0"] = blocks.init_stack(keys[1], init_fn, cfg.num_layers)
        sh_init, _ = blocks.make_shared_attn_block(cfg)
        params["shared_block"] = sh_init(keys[2])
    else:
        for i, (kind, count) in enumerate(cfg.block_kinds()):
            init_fn, _ = blocks.make_block(cfg, kind)
            params[f"seg{i}"] = blocks.init_stack(keys[1 + i], init_fn, count)

    if cfg.encoder is not None:
        enc_cfg = _encoder_block_cfg(cfg)
        enc_init, _ = blocks.make_block(enc_cfg, "attn_dense")
        params["encoder"] = {
            "segments": blocks.init_stack(keys[5], enc_init, cfg.encoder.num_layers),
            "final_norm": common.init_rmsnorm(cfg.d_model, dtype),
        }
        # decoder cross-attention stack (one per decoder layer)
        cross_init = functools.partial(
            attn_lib.init_attention, d_model=cfg.d_model, acfg=cfg.attention, dtype=dtype
        )
        params["cross"] = blocks.init_stack(
            keys[6], lambda k: {"attn": cross_init(k), "norm": common.init_rmsnorm(cfg.d_model, dtype)},
            cfg.num_layers,
        )

    if cfg.frontend is not None:
        params["frontend_proj"] = {
            "proj": common.dense_init(keys[7], (cfg.frontend.dim, cfg.d_model), dtype)
        }

    params["final_norm"] = common.init_rmsnorm(cfg.d_model, dtype)
    if not cfg.tie_embeddings:
        params["lm_head"] = {
            "lm_head": common.dense_init(keys[8], (cfg.d_model, cfg.vocab_size), dtype)
        }
    if cfg.mtp:
        mtp_block_init, _ = blocks.make_block(cfg, "attn_dense")
        params["mtp"] = {
            "proj": common.dense_init(keys[9], (2 * cfg.d_model, cfg.d_model), dtype),
            "block": mtp_block_init(keys[10]),
            "norm": common.init_rmsnorm(cfg.d_model, dtype),
        }
    return params


def _encoder_block_cfg(cfg):
    import dataclasses

    return dataclasses.replace(
        cfg, attention=cfg.encoder.attention, d_ff=cfg.encoder.d_ff, mla=None,
        moe=None, dense_d_ff=0,
    )


def count_params(params) -> int:
    return sum(x.size for x in jax.tree.leaves(params))


# ---------------------------------------------------------------------------
# segment execution
# ---------------------------------------------------------------------------

def _run_segments(params, cfg, x, ctx, caches=None, *, collect_caches=False):
    """Run all decoder segments. caches: dict seg name -> stacked cache (or None).

    Returns (x, new_caches, aux)."""
    aux_total = jnp.asarray(0.0, jnp.float32)
    new_caches = {}
    if cfg.arch_type == "hybrid":
        x, nc, aux = _run_hybrid(params, cfg, x, ctx, caches)
        new_caches = nc
        aux_total += aux
    else:
        offset = 0
        for i, (kind, count) in enumerate(cfg.block_kinds()):
            _, apply_fn = blocks.make_block(cfg, kind)
            meta = None
            if cfg.attention is not None and cfg.mla is None:
                meta = blocks._meta_theta_window(cfg, count, offset)
            seg_params = params[f"seg{i}"]
            if cfg.encoder is not None:
                meta = {**(meta or {}), "cross": ctx_cross_kv(ctx)}
                apply_fn = _wrap_encdec(cfg, apply_fn)
                seg_params = {**seg_params, "xattn": params["cross"]}
            seg_cache = caches.get(f"seg{i}") if caches else None
            x, nc, aux = blocks.apply_stack(
                seg_params, x, ctx, apply_fn, caches=seg_cache, meta=meta,
                remat=cfg.remat and ctx.mode == "train",
                unroll=not cfg.scan_layers,
            )
            if collect_caches or seg_cache is not None:
                new_caches[f"seg{i}"] = nc
            aux_total += aux
            offset += count
    return x, new_caches, aux_total


def ctx_cross_kv(ctx):
    return getattr(ctx, "cross_kv", None)


def _wrap_encdec(cfg, base_apply):
    """Adds cross-attention (meta['cross']) after self-attention in each block."""

    def apply(p, x, cache, meta, ctx):
        self_meta = {k: v for k, v in meta.items() if k != "cross"} or None
        self_cache = cache["self"] if cache is not None else None
        x, new_self, aux = base_apply(
            {k: v for k, v in p.items() if k != "xattn"}, x, self_cache, self_meta, ctx
        )
        cross = meta["cross"]
        if cross is not None:
            h = attn_lib.cross_attention(
                p["xattn"]["attn"],
                common.rmsnorm(p["xattn"]["norm"], x, cfg.norm_eps),
                cross, acfg=cfg.attention, norm_eps=cfg.norm_eps,
            )
            x = x + h
        new_cache = {"self": new_self} if cache is not None else None
        return x, new_cache, aux

    return apply


def _run_hybrid(params, cfg, x, ctx, caches=None):
    """Zamba2: scan groups of ``period`` Mamba layers + one shared-attn block."""
    period = cfg.hybrid.period
    total = cfg.num_layers
    n_groups = total // period
    head_n = n_groups * period
    _, mamba_apply = blocks.make_block(cfg, "mamba")
    _, shared_apply = blocks.make_shared_attn_block(cfg)
    shared_p = params["shared_block"]

    mp = params["seg0"]
    head_p = jax.tree.map(lambda t: t[:head_n].reshape((n_groups, period) + t.shape[1:]), mp)
    tail_p = jax.tree.map(lambda t: t[head_n:], mp)

    m_caches = caches.get("mamba") if caches else None
    s_caches = caches.get("shared") if caches else None
    head_c = tail_c = None
    if m_caches is not None:
        head_c = jax.tree.map(
            lambda t: t[:head_n].reshape((n_groups, period) + t.shape[1:]), m_caches)
        tail_c = jax.tree.map(lambda t: t[head_n:], m_caches)

    def group_body(carry, xs):
        gp, gc_m, gc_s = xs
        y, new_m, aux = blocks.apply_stack(
            gp, carry, ctx, mamba_apply,
            caches=gc_m if m_caches is not None else None,
            unroll=not cfg.scan_layers,
        )
        y, new_s = shared_apply(shared_p, y, gc_s if s_caches is not None else None, ctx)
        return y, (new_m if m_caches is not None else 0,
                   new_s if s_caches is not None else 0, aux)

    body = group_body
    if cfg.remat and ctx.mode == "train":
        body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)

    xs = (head_p,
          head_c if m_caches is not None else jnp.zeros((n_groups,)),
          s_caches if s_caches is not None else jnp.zeros((n_groups,)))
    if cfg.scan_layers:
        x, (new_head_c, new_s_c, auxs) = jax.lax.scan(body, x, xs)
    else:
        outs = []
        for gi in range(n_groups):
            sl = jax.tree.map(lambda t: t[gi], xs)
            x, out = body(x, sl)
            outs.append(out)
        new_head_c, new_s_c, auxs = jax.tree.map(lambda *ts: jnp.stack(ts), *outs)

    new_caches = {}
    if total > head_n:
        x, new_tail_c, aux_t = blocks.apply_stack(
            tail_p, x, ctx, mamba_apply,
            caches=tail_c if m_caches is not None else None,
            unroll=not cfg.scan_layers,
        )
    else:
        new_tail_c, aux_t = None, 0.0
    if m_caches is not None:
        flat_head = jax.tree.map(
            lambda t: t.reshape((head_n,) + t.shape[2:]), new_head_c)
        if new_tail_c is not None:
            new_caches["mamba"] = jax.tree.map(
                lambda a, b: jnp.concatenate([a, b], axis=0), flat_head, new_tail_c)
        else:
            new_caches["mamba"] = flat_head
    if s_caches is not None:
        new_caches["shared"] = new_s_c
    return x, new_caches, jnp.sum(auxs) + aux_t


# ---------------------------------------------------------------------------
# forward passes
# ---------------------------------------------------------------------------

def _encode(params, cfg, frames):
    """Audio/enc-dec encoder over frontend embeddings [B, T, front_dim]."""
    x = jnp.einsum("btf,fd->btd", frames, params["frontend_proj"]["proj"])
    x = logical(x, ("batch", "seq", "embed"))
    enc_cfg = _encoder_block_cfg(cfg)
    _, enc_apply = blocks.make_block(enc_cfg, "attn_dense")
    ctx = blocks.Ctx(positions=jnp.arange(frames.shape[1], dtype=jnp.int32),
                     mode="train", causal=False)
    meta = blocks._meta_theta_window(enc_cfg, cfg.encoder.num_layers)
    x, _, _ = blocks.apply_stack(
        params["encoder"]["segments"], x, ctx, enc_apply, meta=meta,
        remat=cfg.remat, unroll=not cfg.scan_layers,
    )
    return common.rmsnorm(params["encoder"]["final_norm"], x, cfg.norm_eps)


def _cross_kv_from_encoder(params, cfg, enc_out):
    """Per-decoder-layer cross K/V, stacked on the layer axis."""

    def one(p):
        return attn_lib.encoder_kv(p["attn"], enc_out, acfg=cfg.attention)

    return jax.vmap(one, in_axes=0)(params["cross"])


def _embed_inputs(params, cfg, batch):
    """Token (+frontend) embedding. Returns (x, prefix_len)."""
    x = common.embed(params["embed"], batch["tokens"])
    prefix_len = None
    if cfg.frontend is not None and cfg.frontend.kind == "vision" \
            and "image_embeds" in batch:  # decode steps run past the prefix
        img = jnp.einsum("bpf,fd->bpd", batch["image_embeds"].astype(x.dtype),
                         params["frontend_proj"]["proj"])
        x = jnp.concatenate([img, x], axis=1)
        if cfg.frontend.prefix_bidirectional:
            prefix_len = cfg.frontend.seq
    return logical(x, ("batch", "seq", "embed")), prefix_len


def forward(params, cfg, batch, *, mode="train", caches=None, cache_pos=None,
            moe_groups=1):
    """Full forward. Returns (logits, new_caches, aux_loss)."""
    x, prefix_len = _embed_inputs(params, cfg, batch)
    seq = x.shape[1]
    if cache_pos is None:
        positions = jnp.arange(seq, dtype=jnp.int32)
    else:
        positions = jnp.full((x.shape[0], seq), cache_pos, jnp.int32)

    ctx = blocks.Ctx(positions=positions, mode=mode, cache_pos=cache_pos,
                     prefix_len=prefix_len, moe_groups=moe_groups)
    if cfg.encoder is not None:
        if "cross_kv" in (batch or {}):
            ctx.cross_kv = batch["cross_kv"]
        else:
            enc_out = _encode(params, cfg, batch["frames"].astype(x.dtype))
            ctx.cross_kv = _cross_kv_from_encoder(params, cfg, enc_out)

    x, new_caches, aux = _run_segments(params, cfg, x, ctx, caches)
    h = common.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = common.unembed(
        params["embed"], h,
        lm_head=params["lm_head"]["lm_head"] if not cfg.tie_embeddings else None,
    )
    return logits, new_caches, aux, h


def _mtp_loss(params, cfg, h, tokens):
    """DeepSeek multi-token prediction: predict t+2 from (h_t, emb(t+1))."""
    emb_next = common.embed(params["embed"], tokens[:, 1:])  # [B, S-1, d]
    h_in = jnp.concatenate([h[:, :-1], emb_next], axis=-1)
    x = jnp.einsum("bsd,dk->bsk", h_in, params["mtp"]["proj"])
    _, apply_fn = blocks.make_block(cfg, "attn_dense")
    ctx = blocks.Ctx(positions=jnp.arange(x.shape[1], dtype=jnp.int32), mode="train")
    x, _, _ = apply_fn(params["mtp"]["block"], x, None, None, ctx)
    x = common.rmsnorm(params["mtp"]["norm"], x, cfg.norm_eps)
    logits = common.unembed(
        params["embed"], x,
        lm_head=params["lm_head"]["lm_head"] if not cfg.tie_embeddings else None,
    )
    # position j predicts token j+2
    return common.cross_entropy(logits[:, :-1], tokens[:, 2:])


def lm_loss(params, cfg, batch, *, moe_groups=1):
    """Next-token LM loss (+aux +MTP). Returns (loss, metrics)."""
    logits, _, aux, h = forward(params, cfg, batch, mode="train", moe_groups=moe_groups)
    tokens = batch["tokens"]
    if cfg.frontend is not None and cfg.frontend.kind == "vision":
        text_logits = logits[:, cfg.frontend.seq:, :]
    else:
        text_logits = logits
    xent = common.cross_entropy(text_logits[:, :-1], tokens[:, 1:])
    loss = xent + aux
    metrics = {"xent": xent, "aux": aux}
    if cfg.mtp:
        mtp = _mtp_loss(params, cfg, h, tokens)
        loss = loss + MTP_WEIGHT * mtp
        metrics["mtp"] = mtp
    return loss, metrics


# ---------------------------------------------------------------------------
# caches / serving
# ---------------------------------------------------------------------------

def _stack_specs(make_one, num_layers):
    one = make_one()
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct((num_layers,) + s.shape, s.dtype), one)


def cache_specs(cfg, batch: int, max_len: int):
    """ShapeDtypeStructs for the decode caches of this architecture."""
    dtype = cfg.param_dtype()
    specs = {}
    if cfg.arch_type == "hybrid":
        specs["mamba"] = _stack_specs(
            lambda: ssm_lib.ssm_cache_spec(batch, cfg.d_model, cfg.ssm, dtype),
            cfg.num_layers)
        n_groups = cfg.num_layers // cfg.hybrid.period
        specs["shared"] = _stack_specs(
            lambda: attn_lib.cache_spec(batch, max_len, cfg.hybrid.shared_attn, dtype),
            n_groups)
        return specs
    for i, (kind, count) in enumerate(cfg.block_kinds()):
        if kind == "mamba":
            spec = _stack_specs(
                lambda: ssm_lib.ssm_cache_spec(batch, cfg.d_model, cfg.ssm, dtype), count)
        elif cfg.mla is not None:
            spec = _stack_specs(
                lambda: mla_lib.mla_cache_spec(batch, max_len, cfg.mla, dtype), count)
        else:
            spec = _stack_specs(
                lambda: attn_lib.cache_spec(batch, max_len, cfg.attention, dtype), count)
        if cfg.encoder is not None:
            spec = {"self": spec}
        specs[f"seg{i}"] = spec
    return specs


def init_caches(cfg, batch: int, max_len: int):
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                        cache_specs(cfg, batch, max_len))


def cross_kv_specs(cfg, batch: int):
    """Specs for precomputed encoder cross K/V (enc-dec decode input)."""
    a = cfg.attention
    t = cfg.frontend.seq
    dtype = cfg.param_dtype()
    return {
        "k": jax.ShapeDtypeStruct((cfg.num_layers, batch, t, a.num_kv_heads, a.head_dim), dtype),
        "v": jax.ShapeDtypeStruct((cfg.num_layers, batch, t, a.num_kv_heads, a.head_dim), dtype),
    }


def prefill(params, cfg, batch, caches, *, moe_groups=1):
    logits, new_caches, _, _ = forward(
        params, cfg, batch, mode="prefill", caches=caches, moe_groups=moe_groups)
    return logits[:, -1:, :], new_caches


def decode_step(params, cfg, tokens, caches, pos, *, cross_kv=None, moe_groups=1):
    """One decode step: tokens [B, 1] + caches at position ``pos``.

    Returns (logits [B, 1, V], new_caches)."""
    batch = {"tokens": tokens}
    if cross_kv is not None:
        batch["cross_kv"] = cross_kv
    logits, new_caches, _, _ = forward(
        params, cfg, batch, mode="decode", caches=caches, cache_pos=pos,
        moe_groups=moe_groups)
    return logits, new_caches
