"""Step builders + abstract input specs for every (arch × input shape).

``input_specs`` returns ShapeDtypeStruct stand-ins (weak-type-correct,
shardable, no device allocation) — the dry-run lowers against these.

Step functions (all functional, jit-friendly):
  train_step(params, opt_state, batch)            -> (params, opt_state, metrics)
  prefill_step(params, batch, caches)             -> (last_logits, caches)
  serve_step(params, caches, tokens, pos [,cross])-> (logits, caches)
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import InputShape, ModelConfig
from repro.models import transformer
from repro.optim import Optimizer


# ---------------------------------------------------------------------------
# abstract inputs
# ---------------------------------------------------------------------------

def _text_len(cfg: ModelConfig, seq_len: int) -> int:
    """VLM shapes budget the image patches inside seq_len."""
    if cfg.frontend is not None and cfg.frontend.kind == "vision":
        return max(16, seq_len - cfg.frontend.seq)
    return seq_len


def batch_specs(cfg: ModelConfig, shape: InputShape):
    """Training / prefill batch ShapeDtypeStructs."""
    b, s = shape.global_batch, shape.seq_len
    specs = {"tokens": jax.ShapeDtypeStruct((b, _text_len(cfg, s)), jnp.int32)}
    if cfg.frontend is not None and cfg.frontend.kind == "vision":
        specs["image_embeds"] = jax.ShapeDtypeStruct(
            (b, cfg.frontend.seq, cfg.frontend.dim), jnp.bfloat16
            if cfg.dtype == "bfloat16" else jnp.float32)
    if cfg.encoder is not None:
        specs["frames"] = jax.ShapeDtypeStruct(
            (b, cfg.frontend.seq, cfg.frontend.dim), jnp.bfloat16
            if cfg.dtype == "bfloat16" else jnp.float32)
    return specs


def decode_specs(cfg: ModelConfig, shape: InputShape):
    """(tokens, caches, pos[, cross_kv]) specs for a serve_step."""
    b, s = shape.global_batch, shape.seq_len
    specs = {
        "tokens": jax.ShapeDtypeStruct((b, 1), jnp.int32),
        "caches": transformer.cache_specs(cfg, b, s),
        "pos": jax.ShapeDtypeStruct((), jnp.int32),
    }
    if cfg.encoder is not None:
        specs["cross_kv"] = transformer.cross_kv_specs(cfg, b)
    return specs


def input_specs(cfg: ModelConfig, shape: InputShape):
    if shape.kind in ("train", "prefill"):
        base = batch_specs(cfg, shape)
        if shape.kind == "prefill":
            return {"batch": base, "caches": transformer.cache_specs(
                cfg, shape.global_batch, shape.seq_len)}
        return {"batch": base}
    return decode_specs(cfg, shape)


def concrete_batch(cfg: ModelConfig, shape: InputShape, key):
    """Real arrays matching batch_specs (for smoke tests / examples)."""
    specs = batch_specs(cfg, shape)
    ks = jax.random.split(key, len(specs))
    out = {}
    for k, (name, spec) in zip(ks, specs.items()):
        if jnp.issubdtype(spec.dtype, jnp.integer):
            out[name] = jax.random.randint(k, spec.shape, 0, cfg.vocab_size, spec.dtype)
        else:
            out[name] = jax.random.normal(k, spec.shape, spec.dtype)
    return out


# ---------------------------------------------------------------------------
# step builders
# ---------------------------------------------------------------------------

def make_train_step(cfg: ModelConfig, optimizer: Optimizer, *, moe_groups: int = 1):
    def loss_fn(params, batch):
        return transformer.lm_loss(params, cfg, batch, moe_groups=moe_groups)

    def train_step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
        params, opt_state = optimizer.update(grads, opt_state, params)
        metrics = dict(metrics, loss=loss)
        return params, opt_state, metrics

    return train_step


def make_eval_loss(cfg: ModelConfig, *, moe_groups: int = 1):
    def eval_loss(params, batch):
        loss, _ = transformer.lm_loss(params, cfg, batch, moe_groups=moe_groups)
        return loss

    return eval_loss


def make_prefill_step(cfg: ModelConfig, *, moe_groups: int = 1):
    def prefill_step(params, batch, caches):
        return transformer.prefill(params, cfg, batch, caches, moe_groups=moe_groups)

    return prefill_step


def make_serve_step(cfg: ModelConfig, *, moe_groups: int = 1):
    if cfg.encoder is not None:
        def serve_step(params, caches, tokens, pos, cross_kv):
            return transformer.decode_step(
                params, cfg, tokens, caches, pos, cross_kv=cross_kv,
                moe_groups=moe_groups)
    else:
        def serve_step(params, caches, tokens, pos):
            return transformer.decode_step(
                params, cfg, tokens, caches, pos, moe_groups=moe_groups)
    return serve_step


# ---------------------------------------------------------------------------
# analytic parameter / FLOP accounting (roofline MODEL_FLOPS)
# ---------------------------------------------------------------------------

def param_count(cfg: ModelConfig) -> int:
    import math

    params = jax.eval_shape(lambda k: transformer.init_model(cfg, k),
                            jax.ShapeDtypeStruct((2,), jnp.uint32))
    return sum(math.prod(l.shape) for l in jax.tree.leaves(params))


def active_param_count(cfg: ModelConfig) -> int:
    """Activated parameters per token (MoE: only top-k + shared experts)."""
    total = param_count(cfg)
    if cfg.moe is None:
        return total
    m = cfg.moe
    moe_layers = cfg.num_layers - m.first_dense_layers
    per_expert = 3 * cfg.d_model * m.d_expert
    routed_total = moe_layers * m.num_experts * per_expert
    routed_active = moe_layers * m.top_k * per_expert
    return total - routed_total + routed_active


def model_flops(cfg: ModelConfig, shape: InputShape) -> float:
    """MODEL_FLOPS = 6·N·D (dense) or 6·N_active·D (MoE); D = tokens processed.

    For decode shapes, D = global_batch (one token per sequence); training
    counts fwd+bwd (6·N·D), inference counts 2·N·D.
    """
    n = active_param_count(cfg)
    if shape.kind == "train":
        d = shape.global_batch * shape.seq_len
        return 6.0 * n * d
    if shape.kind == "prefill":
        d = shape.global_batch * shape.seq_len
        return 2.0 * n * d
    d = shape.global_batch  # decode: one new token per sequence
    return 2.0 * n * d
