from repro.models import blocks, model_zoo, transformer

__all__ = ["blocks", "model_zoo", "transformer"]
