"""Decoder/encoder block assembly and scan-over-layers machinery.

Layers are grouped into structurally-homogeneous segments; each segment's
parameters are stacked on a leading [L] axis and executed with ``jax.lax.scan``
(HLO size O(1) in depth — essential for compiling 61-layer models against 512
host devices). Metadata-only per-layer variation (sliding window size, rope
theta) rides along the scan as stacked arrays.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.models.layers import attention as attn_lib
from repro.models.layers import common, mla as mla_lib, moe as moe_lib, ssm as ssm_lib


@dataclasses.dataclass
class Ctx:
    """Per-call runtime context threaded through blocks."""

    positions: Any  # [S] (train/prefill) or [B, 1] (decode)
    mode: str = "train"  # train | prefill | decode
    cache_pos: Any = None  # scalar int (decode)
    prefix_len: Any = None  # prefix-LM boundary (paligemma)
    moe_groups: int = 1
    causal: bool = True


def _meta_theta_window(cfg, num_layers, offset=0):
    """Per-layer (theta, window) arrays implementing local:global patterns."""
    a = cfg.attention
    thetas, windows = [], []
    for i in range(offset, offset + num_layers):
        if a is not None and a.local_global_period > 0:
            is_global = (i + 1) % a.local_global_period == 0
            thetas.append(a.rope_theta if is_global else a.rope_theta_local)
            windows.append(0 if is_global else a.sliding_window)
        elif a is not None:
            thetas.append(a.rope_theta)
            windows.append(a.sliding_window)
        else:
            thetas.append(10_000.0)
            windows.append(0)
    return {
        "theta": jnp.asarray(thetas, jnp.float32),
        "window": jnp.asarray(windows, jnp.int32),
    }


# ---------------------------------------------------------------------------
# Block definitions: init(key) -> params; apply(params, x, cache, meta, ctx)
# ---------------------------------------------------------------------------

def make_block(cfg, kind: str):
    dtype = cfg.param_dtype()
    d = cfg.d_model

    def init_attn_part(key):
        if cfg.mla is not None:
            return {"mla": mla_lib.init_mla(key, d, cfg.mla, dtype)}
        return {"attn": attn_lib.init_attention(key, d, cfg.attention, dtype)}

    def apply_attn_part(p, x, cache, meta, ctx):
        if cfg.mla is not None:
            return mla_lib.mla_attention(
                p["mla"], x, mcfg=cfg.mla, positions=ctx.positions,
                causal=ctx.causal, prefix_len=ctx.prefix_len, cache=cache,
                cache_pos=ctx.cache_pos, norm_eps=cfg.norm_eps,
            )
        window = meta["window"] if meta is not None else None
        theta = meta["theta"] if meta is not None else cfg.attention.rope_theta
        return attn_lib.attention(
            p["attn"], x, acfg=cfg.attention, positions=ctx.positions,
            theta=theta, window=window, causal=ctx.causal,
            prefix_len=ctx.prefix_len, cache=cache, cache_pos=ctx.cache_pos,
            norm_eps=cfg.norm_eps,
        )

    if kind == "attn_dense":
        ff = cfg.dense_d_ff or cfg.d_ff

        def init(key):
            k1, k2 = jax.random.split(key)
            p = init_attn_part(k1)
            p.update({
                "norm1": common.init_rmsnorm(d, dtype),
                "norm2": common.init_rmsnorm(d, dtype),
                "mlp": common.init_mlp(k2, d, ff, dtype),
            })
            return p

        def apply(p, x, cache, meta, ctx):
            h, new_cache = apply_attn_part(p, common.rmsnorm(p["norm1"], x, cfg.norm_eps), cache, meta, ctx)
            x = x + h
            x = x + common.mlp(p["mlp"], common.rmsnorm(p["norm2"], x, cfg.norm_eps), cfg.act)
            return x, new_cache, jnp.asarray(0.0, jnp.float32)

        return init, apply

    if kind == "attn_moe":
        def init(key):
            k1, k2 = jax.random.split(key)
            p = init_attn_part(k1)
            p.update({
                "norm1": common.init_rmsnorm(d, dtype),
                "norm2": common.init_rmsnorm(d, dtype),
                "moe": moe_lib.init_moe(k2, d, cfg.moe, dtype),
            })
            return p

        def apply(p, x, cache, meta, ctx):
            h, new_cache = apply_attn_part(p, common.rmsnorm(p["norm1"], x, cfg.norm_eps), cache, meta, ctx)
            x = x + h
            m, aux = moe_lib.moe_apply(
                p["moe"], common.rmsnorm(p["norm2"], x, cfg.norm_eps),
                mcfg=cfg.moe, act=cfg.act, routing_groups=ctx.moe_groups,
            )
            return x + m, new_cache, aux.astype(jnp.float32)

        return init, apply

    if kind == "mamba":
        def init(key):
            return {
                "norm": common.init_rmsnorm(d, dtype),
                "mamba": ssm_lib.init_mamba(key, d, cfg.ssm, dtype),
            }

        def apply(p, x, cache, meta, ctx):
            h, new_cache = ssm_lib.mamba_apply(
                p["mamba"], common.rmsnorm(p["norm"], x, cfg.norm_eps),
                scfg=cfg.ssm, d_model=d, cache=cache, decode=(ctx.mode == "decode"),
            )
            return x + h, new_cache, jnp.asarray(0.0, jnp.float32)

        return init, apply

    raise ValueError(f"unknown block kind {kind!r}")


def make_shared_attn_block(cfg):
    """Zamba2's single shared transformer block (attention + MLP), re-applied
    with the same weights every ``cfg.hybrid.period`` Mamba layers."""
    dtype = cfg.param_dtype()
    d = cfg.d_model
    acfg = cfg.hybrid.shared_attn
    ff = cfg.hybrid.shared_d_ff or cfg.d_ff

    def init(key):
        k1, k2 = jax.random.split(key)
        return {
            "norm1": common.init_rmsnorm(d, dtype),
            "attn": attn_lib.init_attention(k1, d, acfg, dtype),
            "norm2": common.init_rmsnorm(d, dtype),
            "mlp": common.init_mlp(k2, d, ff, dtype),
        }

    def apply(p, x, cache, ctx):
        h, new_cache = attn_lib.attention(
            p["attn"], common.rmsnorm(p["norm1"], x, cfg.norm_eps), acfg=acfg,
            positions=ctx.positions, theta=acfg.rope_theta, window=None,
            causal=ctx.causal, cache=cache, cache_pos=ctx.cache_pos,
            norm_eps=cfg.norm_eps,
        )
        x = x + h
        x = x + common.mlp(p["mlp"], common.rmsnorm(p["norm2"], x, cfg.norm_eps), cfg.act)
        return x, new_cache

    return init, apply


# ---------------------------------------------------------------------------
# Scanned segment execution
# ---------------------------------------------------------------------------

def init_stack(key, init_fn, num_layers: int):
    keys = jax.random.split(key, num_layers)
    return jax.vmap(init_fn)(keys)


def apply_stack(stacked_params, x, ctx, apply_fn, *, caches=None, meta=None,
                remat=False, unroll: bool = False):
    """Scan a homogeneous block stack. caches/meta are [L, ...] stacked (or None).

    Returns (x, new_caches, aux_sum).
    """
    num_layers = jax.tree.leaves(stacked_params)[0].shape[0]
    has_cache = caches is not None
    has_meta = meta is not None

    def body(carry, xs):
        p, c, m = xs
        y, new_c, aux = apply_fn(p, carry, c if has_cache else None, m if has_meta else None, ctx)
        return y, (new_c if has_cache else 0, aux)

    if remat:
        body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)

    xs = (
        stacked_params,
        caches if has_cache else jnp.zeros((num_layers,)),
        meta if has_meta else jnp.zeros((num_layers,)),
    )
    if unroll:
        new_caches, auxs = [], []
        for i in range(num_layers):
            sl = jax.tree.map(lambda t: t[i], xs)
            x, (nc, aux) = body(x, sl)
            new_caches.append(nc)
            auxs.append(aux)
        new_c = jax.tree.map(lambda *ts: jnp.stack(ts), *new_caches) if has_cache else None
        return x, new_c, jnp.sum(jnp.stack(auxs))
    x, (new_caches, auxs) = jax.lax.scan(body, x, xs)
    return x, (new_caches if has_cache else None), jnp.sum(auxs)
