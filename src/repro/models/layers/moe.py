"""Token-choice top-k mixture-of-experts with static per-expert capacity and
group-local routing.

Routing: every token picks its top-k experts by router probability; every
expert keeps its top-C tokens per *routing group* (C = T_g·top_k/E·cf) ranked
by router weight — the standard shardable capacity formulation (tokens beyond
capacity are dropped and flow through the residual connection).

``routing_groups`` is set by the launcher to the number of data shards so a
group never crosses a data-parallel boundary: the token→expert gather then
runs shard-locally (activations are replicated over the model axis) and the
expert→token combine is a partial-sum that GSPMD turns into one all-reduce
over the model axis — the expert-parallel collective that §Roofline measures.

Also provides DeepSeek's shared expert(s) and Arctic's parallel dense
residual MLP, plus the switch-style load-balancing auxiliary loss.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import common
from repro.sharding import logical


def init_moe(key, d_model, mcfg, dtype):
    ks = jax.random.split(key, 8)
    e, f = mcfg.num_experts, mcfg.d_expert
    params = {
        "router": common.dense_init(ks[0], (d_model, e), dtype),
        "we_gate": common.dense_init(ks[1], (e, d_model, f), dtype, fan_in=d_model),
        "we_in": common.dense_init(ks[2], (e, d_model, f), dtype, fan_in=d_model),
        "we_out": common.dense_init(ks[3], (e, f, d_model), dtype, fan_in=f),
    }
    if mcfg.num_shared_experts > 0:
        params["shared"] = common.init_mlp(
            ks[4], d_model, f * mcfg.num_shared_experts, dtype
        )
    if mcfg.dense_residual_d_ff > 0:
        params["dense_residual"] = common.init_mlp(
            ks[5], d_model, mcfg.dense_residual_d_ff, dtype
        )
    return params


def _capacity(tokens_per_group: int, mcfg) -> int:
    cap = int(tokens_per_group * mcfg.top_k * mcfg.capacity_factor / mcfg.num_experts)
    return max(1, min(cap, tokens_per_group))


def moe_apply(params, x, *, mcfg, act="silu", routing_groups: int = 1):
    """x: [B, S, d] -> (out [B, S, d], aux_loss scalar)."""
    b, s, d = x.shape
    t = b * s
    e = mcfg.num_experts
    g = routing_groups if t % routing_groups == 0 else 1
    tg = t // g
    xf = x.reshape(g, tg, d)
    xf = logical(xf, ("batch", None, "embed"))

    # ---- routing (fp32) ----------------------------------------------------
    logits = jnp.einsum("gtd,de->gte", xf, params["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    top_vals, top_idx = jax.lax.top_k(probs, mcfg.top_k)  # [G, Tg, k]
    top_vals = top_vals / jnp.sum(top_vals, axis=-1, keepdims=True)

    # load-balance aux loss (Switch-style): E·Σ_e f_e·p_e
    assign_onehot = jax.nn.one_hot(top_idx, e, dtype=jnp.float32)  # [G,Tg,k,E]
    frac_tokens = jnp.mean(jnp.sum(assign_onehot, axis=2), axis=(0, 1))  # [E]
    frac_probs = jnp.mean(probs, axis=(0, 1))
    aux = e * jnp.sum(frac_tokens * frac_probs) * mcfg.aux_loss_weight

    # per-(token, expert) gate within each group
    gates_te = jnp.einsum("gtk,gtke->gte", top_vals, assign_onehot)  # [G,Tg,E]

    # ---- per-expert top-C token selection (capacity), group-local ----------
    c = _capacity(tg, mcfg)
    gates_et = jnp.swapaxes(gates_te, 1, 2)  # [G, E, Tg]
    sel_gate, sel_idx = jax.lax.top_k(gates_et, c)  # [G, E, C]
    keep = sel_gate > 0.0

    xe = jnp.take_along_axis(
        xf[:, None, :, :],  # [G, 1, Tg, d]
        sel_idx[..., None],  # [G, E, C, 1]
        axis=2,
    )  # [G, E, C, d]
    xe = logical(xe, ("batch", "experts", "capacity", "embed"))

    # ---- expert computation (grouped SwiGLU) --------------------------------
    gate = jnp.einsum("gecd,edf->gecf", xe, params["we_gate"])
    h = jnp.einsum("gecd,edf->gecf", xe, params["we_in"])
    h = logical(common._act(act)(gate) * h, ("batch", "experts", "capacity", "ff"))
    ye = jnp.einsum("gecf,efd->gecd", h, params["we_out"])
    ye = ye * (sel_gate * keep).astype(ye.dtype)[..., None]
    ye = logical(ye, ("batch", "experts", "capacity", "embed"))

    # ---- combine back to token space (scatter-add per group) ---------------
    def combine_group(y_g, idx_g):
        return jnp.zeros((tg, d), y_g.dtype).at[idx_g.reshape(-1)].add(
            y_g.reshape(e * c, d), mode="drop"
        )

    out = jax.vmap(combine_group)(ye, sel_idx)  # [G, Tg, d]
    out = out.reshape(b, s, d)
    out = logical(out, ("batch", "seq", "embed"))

    # ---- shared expert / dense residual (always-on paths) ------------------
    if "shared" in params:
        out = out + common.mlp(params["shared"], x, act)
    if "dense_residual" in params:
        out = out + common.mlp(params["dense_residual"], x, act)
    return out, aux
