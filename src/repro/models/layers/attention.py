"""Grouped-query attention with RoPE, QK-norm, causal / sliding-window /
prefix-LM masks, KV caches for decode, and cross-attention (enc-dec).

Long sequences use *query-block-chunked* attention (lax.scan over query
blocks) so the [Q, T] score tensor never materializes — the XLA analogue of
the Pallas flash kernel in ``repro.kernels.flash_attention`` (which is the
TPU-targeted implementation of this same computation).

Masks are position-arithmetic so a scanned layer stack can vary
window/theta per layer via scanned metadata (gemma3's 5:1 local:global).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import common
from repro.sharding import logical

Q_BLOCK = 256  # query-chunk size for blocked attention
CHUNK_THRESHOLD = 1024  # use blocked attention above this query length


def init_attention(key, d_model, acfg, dtype):
    kq, kk, kv, ko = jax.random.split(key, 4)
    h, kvh, hd = acfg.num_heads, acfg.num_kv_heads, acfg.head_dim
    return {
        "wq": common.dense_init(kq, (d_model, h, hd), dtype),
        "wk": common.dense_init(kk, (d_model, kvh, hd), dtype),
        "wv": common.dense_init(kv, (d_model, kvh, hd), dtype),
        "wo": common.dense_init(ko, (h, hd, d_model), dtype, fan_in=h * hd),
    }


def mask_bias(q_pos, k_pos, *, causal: bool, window=None, prefix_len=None, k_valid=None):
    """Additive mask bias [Q, K] (or [B, Q, K]) from query/key positions."""
    q = q_pos[..., :, None]
    k = k_pos[..., None, :]
    ok = jnp.ones(jnp.broadcast_shapes(q.shape, k.shape), dtype=bool)
    if causal:
        allowed = k <= q
        if prefix_len is not None:
            both_prefix = (q < prefix_len) & (k < prefix_len)
            allowed = allowed | both_prefix
        ok &= allowed
    if window is not None:
        in_window = (q - k) < window
        ok = ok & jnp.where(window > 0, in_window, True)
    if k_valid is not None:
        ok &= k_valid[..., None, :]
    return jnp.where(ok, 0.0, -1e30).astype(jnp.float32)


def attend(q, k, v, bias, *, scale):
    """q: [B,Q,H,hd], k/v: [B,T,KV,hd], bias broadcastable to [B,Q,T]."""
    b, qlen, h, hd = q.shape
    kvh = k.shape[2]
    g = h // kvh
    qg = q.reshape(b, qlen, kvh, g, hd)
    scores = jnp.einsum("bqkgh,btkh->bkgqt", qg, k).astype(jnp.float32) * scale
    bias = jnp.broadcast_to(bias, (b,) + bias.shape[-2:])
    scores = scores + bias[:, None, None, :, :]
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgqt,btkh->bqkgh", probs, v)
    return out.reshape(b, qlen, h, v.shape[-1])  # v head dim may differ (MLA)


def attend_chunked(q, k, v, *, scale, bias_fn, q_block=Q_BLOCK):
    """Blocked attention: lax.scan over query chunks; bias_fn(block_start)
    returns the [q_block, T] bias for that chunk. Keeps peak memory at
    O(q_block · T) instead of O(Q · T)."""
    b, qlen, h, hd = q.shape
    assert qlen % q_block == 0 and qlen > q_block, "caller guards chunking"
    nb = qlen // q_block
    qb = q.reshape(b, nb, q_block, h, hd).transpose(1, 0, 2, 3, 4)
    starts = jnp.arange(nb) * q_block

    def one(_, xs):
        start, qblk = xs
        bias = bias_fn(start)  # [q_block, T]
        out = attend(qblk, k, v, bias[None], scale=scale)
        return None, out

    _, outs = jax.lax.scan(one, None, (starts, qb))
    # note: output head dim follows v (may differ from q's for MLA)
    return outs.transpose(1, 0, 2, 3, 4).reshape(b, qlen, h, outs.shape[-1])


def attention(
    params,
    x,
    *,
    acfg,
    positions,
    theta,
    window=None,
    causal=True,
    prefix_len=None,
    cache=None,
    cache_pos=None,
    norm_eps=1e-6,
):
    """Self-attention. Modes:
      * train:    cache=None                       -> (out, None)
      * prefill:  cache=empty, cache_pos=None      -> (out, filled cache)
      * decode:   cache=filled, cache_pos=pos      -> (out, updated cache), x is [B,1,d]

    ``positions`` is [S] (train/prefill, shared across batch) or [B,1] (decode).
    """
    hd = acfg.head_dim
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"])
    q = logical(q, ("batch", "seq", "heads", "head_dim"))
    k = logical(k, ("batch", "seq", "kv_heads", "head_dim"))
    v = logical(v, ("batch", "seq", "kv_heads", "head_dim"))

    if acfg.qk_norm:
        q = common.head_rmsnorm(q, norm_eps)
        k = common.head_rmsnorm(k, norm_eps)
    rp = positions if positions.ndim > 1 else positions[None, :]
    q = common.rope(q, jnp.broadcast_to(rp, (q.shape[0], q.shape[1])), theta)
    k = common.rope(k, jnp.broadcast_to(rp, (k.shape[0], k.shape[1])), theta)
    scale = acfg.softmax_scale or (1.0 / hd**0.5)

    new_cache = None
    if cache is not None and cache_pos is not None:
        ck = jax.lax.dynamic_update_slice_in_dim(
            cache["k"], k.astype(cache["k"].dtype), cache_pos, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(
            cache["v"], v.astype(cache["v"].dtype), cache_pos, axis=1)
        ck = logical(ck, ("batch", "cache_seq", "kv_heads", "head_dim"))
        cv = logical(cv, ("batch", "cache_seq", "kv_heads", "head_dim"))
        new_cache = {"k": ck, "v": cv}
        t = ck.shape[1]
        k_pos = jnp.arange(t)[None, :]
        k_valid = jnp.arange(t)[None, :] <= cache_pos
        bias = mask_bias(positions, k_pos, causal=causal, window=window, k_valid=k_valid)
        out = attend(q, ck, cv, bias, scale=scale)
    else:
        if cache is not None:  # prefill into an empty cache
            ck = jax.lax.dynamic_update_slice_in_dim(
                cache["k"], k.astype(cache["k"].dtype), 0, axis=1)
            cv = jax.lax.dynamic_update_slice_in_dim(
                cache["v"], v.astype(cache["v"].dtype), 0, axis=1)
            ck = logical(ck, ("batch", "cache_seq", "kv_heads", "head_dim"))
            cv = logical(cv, ("batch", "cache_seq", "kv_heads", "head_dim"))
            new_cache = {"k": ck, "v": cv}
        pos1d = positions if positions.ndim == 1 else positions[0]
        qlen = q.shape[1]
        if qlen > CHUNK_THRESHOLD and qlen % Q_BLOCK == 0:
            def bias_fn(start):
                qp = jax.lax.dynamic_slice_in_dim(pos1d, start, Q_BLOCK)
                return mask_bias(qp, pos1d, causal=causal, window=window,
                                 prefix_len=prefix_len)

            out = attend_chunked(q, k, v, scale=scale, bias_fn=bias_fn)
        else:
            bias = mask_bias(pos1d, pos1d, causal=causal, window=window,
                             prefix_len=prefix_len)
            out = attend(q, k, v, bias[None], scale=scale)

    out = logical(out, ("batch", "seq", "heads", "head_dim"))
    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"])
    return logical(y, ("batch", "seq", "embed")), new_cache


def cross_attention(params, x, kv_cache, *, acfg, norm_eps=1e-6):
    """Cross-attention against precomputed encoder K/V (full, unmasked)."""
    hd = acfg.head_dim
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    if acfg.qk_norm:
        q = common.head_rmsnorm(q, norm_eps)
    scale = acfg.softmax_scale or (1.0 / hd**0.5)
    t = kv_cache["k"].shape[1]
    bias = jnp.zeros((1, x.shape[1], t), jnp.float32)
    out = attend(q, kv_cache["k"], kv_cache["v"], bias, scale=scale)
    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"])
    return logical(y, ("batch", "seq", "embed"))


def encoder_kv(params, enc_out, *, acfg):
    """Precompute cross-attention K/V from encoder output (no RoPE)."""
    k = jnp.einsum("bsd,dhk->bshk", enc_out, params["wk"])
    v = jnp.einsum("bsd,dhk->bshk", enc_out, params["wv"])
    return {"k": k, "v": v}


def init_cache(batch, max_len, acfg, dtype):
    kvh, hd = acfg.num_kv_heads, acfg.head_dim
    return {
        "k": jnp.zeros((batch, max_len, kvh, hd), dtype),
        "v": jnp.zeros((batch, max_len, kvh, hd), dtype),
    }


def cache_spec(batch, max_len, acfg, dtype):
    kvh, hd = acfg.num_kv_heads, acfg.head_dim
    return {
        "k": jax.ShapeDtypeStruct((batch, max_len, kvh, hd), dtype),
        "v": jax.ShapeDtypeStruct((batch, max_len, kvh, hd), dtype),
    }
