"""Multi-head latent attention (DeepSeek-V3 / MiniCPM3).

Queries and keys/values are low-rank-compressed; only the compressed latent
c_kv (+ the shared rope key) is cached, which is MLA's serving advantage:
cache is [B, S, kv_lora + rope_dim] instead of [B, S, KV·hd·2].

Two decode paths:
  * naive  — reconstruct per-head K/V from the cached latents every step
             (faithful to the algebra; expensive: O(S·lora·H·hd)/token);
  * absorb — fold W_UK/W_UV into the query/output projections so attention
             runs directly in the latent space (O(S·lora)/token). This is the
             §Perf "matmul absorption" optimization (cfg.mla.absorb_decode).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import common
from repro.models.layers.attention import attend, attend_chunked, mask_bias, Q_BLOCK, CHUNK_THRESHOLD
from repro.sharding import logical


def init_mla(key, d_model, mcfg, dtype):
    ks = jax.random.split(key, 8)
    h = mcfg.num_heads
    qd = mcfg.nope_head_dim + mcfg.rope_head_dim
    return {
        "wq_a": common.dense_init(ks[0], (d_model, mcfg.q_lora_rank), dtype),
        "q_norm": common.init_rmsnorm(mcfg.q_lora_rank, dtype),
        "wq_b": common.dense_init(ks[1], (mcfg.q_lora_rank, h, qd), dtype),
        "wkv_a": common.dense_init(ks[2], (d_model, mcfg.kv_lora_rank + mcfg.rope_head_dim), dtype),
        "kv_norm": common.init_rmsnorm(mcfg.kv_lora_rank, dtype),
        "wk_b": common.dense_init(ks[3], (mcfg.kv_lora_rank, h, mcfg.nope_head_dim), dtype),
        "wv_b": common.dense_init(ks[4], (mcfg.kv_lora_rank, h, mcfg.v_head_dim), dtype),
        "wo_mla": common.dense_init(
            ks[5], (h, mcfg.v_head_dim, d_model), dtype, fan_in=h * mcfg.v_head_dim
        ),
    }


def _project_q(params, x, mcfg, positions, norm_eps):
    cq = jnp.einsum("bsd,dr->bsr", x, params["wq_a"])
    cq = common.rmsnorm(params["q_norm"], cq, norm_eps)
    q = jnp.einsum("bsr,rhk->bshk", cq, params["wq_b"])
    q_nope = q[..., : mcfg.nope_head_dim]
    q_rope = q[..., mcfg.nope_head_dim:]
    rp = jnp.broadcast_to(positions if positions.ndim > 1 else positions[None, :],
                          (x.shape[0], x.shape[1]))
    q_rope = common.rope(q_rope, rp, mcfg.rope_theta)
    return q_nope, q_rope


def _project_kv_latent(params, x, mcfg, positions, norm_eps):
    ckv_full = jnp.einsum("bsd,dr->bsr", x, params["wkv_a"])
    c_kv = common.rmsnorm(params["kv_norm"], ckv_full[..., : mcfg.kv_lora_rank], norm_eps)
    k_rope = ckv_full[..., mcfg.kv_lora_rank:][:, :, None, :]  # [B,S,1,rope]
    rp = jnp.broadcast_to(positions if positions.ndim > 1 else positions[None, :],
                          (x.shape[0], x.shape[1]))
    k_rope = common.rope(k_rope, rp, mcfg.rope_theta)[:, :, 0, :]
    c_kv = logical(c_kv, ("batch", "seq", "kv_lora"))
    return c_kv, k_rope


def mla_attention(params, x, *, mcfg, positions, causal=True, prefix_len=None,
                  cache=None, cache_pos=None, norm_eps=1e-6):
    """Returns (out, new_cache). Cache = {'c_kv': [B,S,lora], 'k_rope': [B,S,rope]}."""
    h = mcfg.num_heads
    scale = 1.0 / (mcfg.nope_head_dim + mcfg.rope_head_dim) ** 0.5
    q_nope, q_rope = _project_q(params, x, mcfg, positions, norm_eps)
    c_kv, k_rope = _project_kv_latent(params, x, mcfg, positions, norm_eps)

    if cache is not None and cache_pos is not None:
        ck = jax.lax.dynamic_update_slice_in_dim(
            cache["c_kv"], c_kv.astype(cache["c_kv"].dtype), cache_pos, axis=1)
        cr = jax.lax.dynamic_update_slice_in_dim(
            cache["k_rope"], k_rope.astype(cache["k_rope"].dtype), cache_pos, axis=1)
        ck = logical(ck, ("batch", "cache_seq", "kv_lora"))
        new_cache = {"c_kv": ck, "k_rope": cr}
        t = ck.shape[1]
        k_valid = jnp.arange(t)[None, :] <= cache_pos
        bias = mask_bias(positions, jnp.arange(t)[None, :], causal=causal, k_valid=k_valid)

        if mcfg.absorb_decode:
            # fold W_UK into q, W_UV into the output: attention in latent space
            q_eff = jnp.einsum("bshn,rhn->bshr", q_nope, params["wk_b"])
            s_nope = jnp.einsum("bshr,btr->bhst", q_eff, ck).astype(jnp.float32)
            s_rope = jnp.einsum("bshr,btr->bhst", q_rope, cr).astype(jnp.float32)
            scores = (s_nope + s_rope) * scale + bias[:, None, :, :]
            probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
            o_lat = jnp.einsum("bhst,btr->bshr", probs, ck)
            out = jnp.einsum("bshr,rhv->bshv", o_lat, params["wv_b"])
        else:
            # naive: reconstruct per-head K/V from the latent cache
            k_nope = jnp.einsum("btr,rhn->bthn", ck, params["wk_b"])
            v = jnp.einsum("btr,rhv->bthv", ck, params["wv_b"])
            s_nope = jnp.einsum("bshn,bthn->bhst", q_nope, k_nope).astype(jnp.float32)
            s_rope = jnp.einsum("bshr,btr->bhst", q_rope, cr).astype(jnp.float32)
            scores = (s_nope + s_rope) * scale + bias[:, None, :, :]
            probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
            out = jnp.einsum("bhst,bthv->bshv", probs, v)
        y = jnp.einsum("bshv,hvd->bsd", out, params["wo_mla"])
        return logical(y, ("batch", "seq", "embed")), new_cache

    # train / prefill: expand K/V per head, chunked over query blocks
    new_cache = None
    if cache is not None:
        ck = jax.lax.dynamic_update_slice_in_dim(
            cache["c_kv"], c_kv.astype(cache["c_kv"].dtype), 0, axis=1)
        cr = jax.lax.dynamic_update_slice_in_dim(
            cache["k_rope"], k_rope.astype(cache["k_rope"].dtype), 0, axis=1)
        ck = logical(ck, ("batch", "cache_seq", "kv_lora"))
        new_cache = {"c_kv": ck, "k_rope": cr}

    k_nope = jnp.einsum("btr,rhn->bthn", c_kv, params["wk_b"])
    v = jnp.einsum("btr,rhv->bthv", c_kv, params["wv_b"])
    k_nope = logical(k_nope, ("batch", "seq", "heads", "head_dim"))
    v = logical(v, ("batch", "seq", "heads", "head_dim"))
    # pack the shared rope key alongside per-head nope keys by concatenation
    k_rope_b = jnp.broadcast_to(k_rope[:, :, None, :],
                                k_rope.shape[:2] + (h, mcfg.rope_head_dim))
    k_full = jnp.concatenate([k_nope, k_rope_b], axis=-1)
    q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
    pos1d = positions if positions.ndim == 1 else positions[0]
    qlen = q_full.shape[1]
    if qlen > CHUNK_THRESHOLD and qlen % Q_BLOCK == 0:
        def bias_fn(start):
            qp = jax.lax.dynamic_slice_in_dim(pos1d, start, Q_BLOCK)
            return mask_bias(qp, pos1d, causal=causal, prefix_len=prefix_len)

        out = attend_chunked(q_full, k_full, v, scale=scale, bias_fn=bias_fn)
    else:
        bias = mask_bias(pos1d, pos1d, causal=causal, prefix_len=prefix_len)
        out = attend(q_full, k_full, v, bias[None], scale=scale)
    y = jnp.einsum("bshv,hvd->bsd", out, params["wo_mla"])
    return logical(y, ("batch", "seq", "embed")), new_cache


def init_mla_cache(batch, max_len, mcfg, dtype):
    return {
        "c_kv": jnp.zeros((batch, max_len, mcfg.kv_lora_rank), dtype),
        "k_rope": jnp.zeros((batch, max_len, mcfg.rope_head_dim), dtype),
    }


def mla_cache_spec(batch, max_len, mcfg, dtype):
    return {
        "c_kv": jax.ShapeDtypeStruct((batch, max_len, mcfg.kv_lora_rank), dtype),
        "k_rope": jax.ShapeDtypeStruct((batch, max_len, mcfg.rope_head_dim), dtype),
    }
