"""Mamba2 block — SSD (state-space duality) with chunked parallel scan.

Train/prefill uses the chunked SSD algorithm (quadratic attention-like within
chunks + associative state recurrence across chunks); decode is the O(1)
recurrent update on the [B, H, P, N] state (the reason the SSM archs run the
long_500k shape). A Pallas TPU kernel for the intra-chunk compute lives in
``repro.kernels.ssd_scan`` with this file's ``ssd_reference`` as its oracle.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import common
from repro.sharding import logical


# ---------------------------------------------------------------------------
# SSD core
# ---------------------------------------------------------------------------

def _segsum(la):
    """Lower-triangular pairwise decay sums. la: [..., cl] -> [..., cl, cl]
    with out[..., i, j] = Σ_{j < t <= i} la_t  (−inf above diagonal)."""
    cl = la.shape[-1]
    cs = jnp.cumsum(la, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]  # Σ_{j<t<=i}
    mask = jnp.tril(jnp.ones((cl, cl), bool), k=0)
    return jnp.where(mask, diff, -jnp.inf)


def ssd(x, dt, a_coef, b_in, c_in, *, chunk: int, initial_state=None):
    """Chunked SSD.

    x:  [B, L, H, P]   inputs (already multiplied by nothing; dt applied here)
    dt: [B, L, H]      positive step sizes
    a_coef: [H]        negative decay coefficients (A)
    b_in, c_in: [B, L, G, N]  input/output projections (G groups, H % G == 0)
    Returns (y [B,L,H,P], final_state [B,H,P,N]).
    """
    bsz, l, h, p = x.shape
    g, n = b_in.shape[2], b_in.shape[3]
    assert l % chunk == 0, f"seq {l} % chunk {chunk} != 0"
    nc = l // chunk
    rep = h // g

    # broadcast groups to heads
    bh = jnp.repeat(b_in, rep, axis=2)  # [B, L, H, N]
    ch = jnp.repeat(c_in, rep, axis=2)

    la = dt * a_coef[None, None, :]  # [B, L, H] (negative)
    xc = x.reshape(bsz, nc, chunk, h, p)
    dtc = dt.reshape(bsz, nc, chunk, h)
    lac = la.reshape(bsz, nc, chunk, h)
    bc = bh.reshape(bsz, nc, chunk, h, n)
    cc = ch.reshape(bsz, nc, chunk, h, n)

    # ---- intra-chunk (quadratic within chunk) -----------------------------
    lseg = _segsum(jnp.moveaxis(lac, -1, -2))  # [B, nc, H, cl, cl]
    decay = jnp.exp(lseg)
    scores = jnp.einsum("bzihn,bzjhn->bzhij", cc, bc)  # C_i · B_j
    y_diag = jnp.einsum(
        "bzhij,bzjh,bzjhp->bzihp", (scores * decay).astype(x.dtype), dtc, xc
    )

    # ---- chunk states ------------------------------------------------------
    cs = jnp.cumsum(lac, axis=2)  # [B, nc, cl, H]
    total = cs[:, :, -1, :]  # [B, nc, H]
    decay_to_end = jnp.exp(total[:, :, None, :] - cs)  # [B, nc, cl, H]
    states = jnp.einsum(
        "bzjh,bzjhn,bzjhp->bzhpn", (decay_to_end * dtc).astype(x.dtype), bc, xc
    )

    # ---- inter-chunk recurrence -------------------------------------------
    if initial_state is None:
        initial_state = jnp.zeros((bsz, h, p, n), x.dtype)

    def step(s_prev, inputs):
        st, tot = inputs  # [B,H,P,N], [B,H]
        s_new = s_prev * jnp.exp(tot)[:, :, None, None].astype(x.dtype) + st
        return s_new, s_prev

    states_t = jnp.moveaxis(states, 1, 0)  # [nc, B, H, P, N]
    total_t = jnp.moveaxis(total, 1, 0)  # [nc, B, H]
    final_state, prev_states = jax.lax.scan(step, initial_state, (states_t, total_t))
    prev_states = jnp.moveaxis(prev_states, 0, 1)  # [B, nc, H, P, N]

    # ---- inter-chunk output contribution ----------------------------------
    in_decay = jnp.exp(cs)  # decay from chunk start to position i
    y_off = jnp.einsum(
        "bzihn,bzih,bzhpn->bzihp", cc, in_decay.astype(x.dtype), prev_states
    )

    y = (y_diag + y_off).reshape(bsz, l, h, p)
    return y, final_state


def ssd_decode_step(state, x_t, dt_t, a_coef, b_t, c_t):
    """One recurrent step. state: [B,H,P,N]; x_t: [B,H,P]; dt_t: [B,H];
    b_t, c_t: [B,G,N]. Returns (y_t [B,H,P], new_state)."""
    h = x_t.shape[1]
    g = b_t.shape[1]
    rep = h // g
    bh = jnp.repeat(b_t, rep, axis=1)  # [B,H,N]
    ch = jnp.repeat(c_t, rep, axis=1)
    da = jnp.exp(dt_t * a_coef[None, :])  # [B,H]
    upd = jnp.einsum("bh,bhn,bhp->bhpn", dt_t, bh, x_t)
    new_state = state * da[:, :, None, None].astype(state.dtype) + upd.astype(state.dtype)
    y = jnp.einsum("bhpn,bhn->bhp", new_state, ch.astype(state.dtype))
    return y, new_state


# ---------------------------------------------------------------------------
# Mamba2 block
# ---------------------------------------------------------------------------

def _dims(d_model, scfg):
    d_inner = scfg.expand * d_model
    h = d_inner // scfg.head_dim
    conv_ch = d_inner + 2 * scfg.num_groups * scfg.state_dim
    return d_inner, h, conv_ch


def init_mamba(key, d_model, scfg, dtype):
    ks = jax.random.split(key, 6)
    d_inner, h, conv_ch = _dims(d_model, scfg)
    n, g = scfg.state_dim, scfg.num_groups
    proj_out = 2 * d_inner + 2 * g * n + h  # z, x, B, C, dt
    return {
        "in_proj": common.dense_init(ks[0], (d_model, proj_out), dtype),
        "conv_w": common.dense_init(ks[1], (scfg.conv_width, conv_ch), dtype, fan_in=scfg.conv_width),
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "a_log": jnp.zeros((h,), dtype),  # A = -exp(a_log) = -1 at init
        "ssm_d": jnp.ones((h,), dtype),
        "dt_bias": jnp.zeros((h,), dtype),
        "out_proj": common.dense_init(ks[2], (d_inner, d_model), dtype, fan_in=d_inner),
    }


def _split_proj(proj, d_inner, g, n, h):
    z = proj[..., :d_inner]
    xbc = proj[..., d_inner: 2 * d_inner + 2 * g * n]
    dt = proj[..., 2 * d_inner + 2 * g * n:]
    return z, xbc, dt


def _causal_conv(xbc, conv_w, conv_b, *, state=None):
    """Depthwise causal conv over time. xbc: [B, L, C]; state: [B, w-1, C]."""
    w = conv_w.shape[0]
    if state is None:
        pad = jnp.zeros((xbc.shape[0], w - 1, xbc.shape[2]), xbc.dtype)
    else:
        pad = state.astype(xbc.dtype)
    xp = jnp.concatenate([pad, xbc], axis=1)  # [B, L+w-1, C]
    out = sum(
        xp[:, i: i + xbc.shape[1], :] * conv_w[i][None, None, :] for i in range(w)
    ) + conv_b[None, None, :]
    new_state = xp[:, -(w - 1):, :] if w > 1 else None
    return jax.nn.silu(out), new_state


def mamba_apply(params, x, *, scfg, d_model, cache=None, decode=False):
    """x: [B, L, d]. cache = {'ssm': [B,H,P,N], 'conv': [B,w-1,C]} for decode.
    Returns (out, new_cache)."""
    d_inner, h, conv_ch = _dims(d_model, scfg)
    n, g, p = scfg.state_dim, scfg.num_groups, scfg.head_dim

    proj = jnp.einsum("bld,dk->blk", x, params["in_proj"])
    proj = logical(proj, ("batch", "seq", "ssm_inner"))
    z, xbc, dt = _split_proj(proj, d_inner, g, n, h)
    a_coef = -jnp.exp(params["a_log"].astype(jnp.float32))
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"].astype(jnp.float32))

    if decode:
        xbc_c, new_conv = _causal_conv(
            xbc, params["conv_w"], params["conv_b"], state=cache["conv"]
        )
        xs = xbc_c[..., :d_inner]
        b_in = xbc_c[..., d_inner: d_inner + g * n]
        c_in = xbc_c[..., d_inner + g * n:]
        x_t = xs[:, 0].reshape(-1, h, p)
        b_t = b_in[:, 0].reshape(-1, g, n)
        c_t = c_in[:, 0].reshape(-1, g, n)
        y_t, new_ssm = ssd_decode_step(
            cache["ssm"], x_t, dt[:, 0], a_coef, b_t, c_t
        )
        y = y_t[:, None].reshape(x.shape[0], 1, d_inner)
        y = y + xs * params["ssm_d"].repeat(p)[None, None, :].astype(y.dtype)
        new_cache = {"ssm": new_ssm, "conv": new_conv}
    else:
        xbc_c, last_conv = _causal_conv(xbc, params["conv_w"], params["conv_b"])
        xs = xbc_c[..., :d_inner]
        b_in = xbc_c[..., d_inner: d_inner + g * n]
        c_in = xbc_c[..., d_inner + g * n:]
        bsz, l = x.shape[0], x.shape[1]
        xh = xs.reshape(bsz, l, h, p)
        y, final_state = ssd(
            xh, dt, a_coef, b_in.reshape(bsz, l, g, n), c_in.reshape(bsz, l, g, n),
            chunk=min(scfg.chunk, l),
        )
        y = y.reshape(bsz, l, d_inner)
        y = y + xs * params["ssm_d"].repeat(p)[None, None, :].astype(y.dtype)
        new_cache = None
        if cache is not None:  # prefill: hand the state to the decoder
            new_cache = {"ssm": final_state, "conv": last_conv}

    y = (y * jax.nn.silu(z)).astype(x.dtype)
    y = logical(y, ("batch", "seq", "ssm_inner"))
    out = jnp.einsum("blk,kd->bld", y, params["out_proj"]).astype(x.dtype)
    return logical(out, ("batch", "seq", "embed")), new_cache


def init_ssm_cache(batch, d_model, scfg, dtype):
    d_inner, h, conv_ch = _dims(d_model, scfg)
    return {
        "ssm": jnp.zeros((batch, h, scfg.head_dim, scfg.state_dim), dtype),
        "conv": jnp.zeros((batch, scfg.conv_width - 1, conv_ch), dtype),
    }


def ssm_cache_spec(batch, d_model, scfg, dtype):
    d_inner, h, conv_ch = _dims(d_model, scfg)
    return {
        "ssm": jax.ShapeDtypeStruct((batch, h, scfg.head_dim, scfg.state_dim), dtype),
        "conv": jax.ShapeDtypeStruct((batch, scfg.conv_width - 1, conv_ch), dtype),
    }
