"""Shared layer primitives: initializers, RMSNorm, RoPE, embeddings, MLP."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.sharding import logical


def dense_init(key, shape, dtype, *, fan_in=None):
    fan_in = fan_in if fan_in is not None else shape[0]
    scale = (1.0 / max(1, fan_in)) ** 0.5
    return (scale * jax.random.normal(key, shape, jnp.float32)).astype(dtype)


def embed_init(key, shape, dtype):
    return (0.02 * jax.random.normal(key, shape, jnp.float32)).astype(dtype)


# ---------------------------------------------------------------------------


def init_rmsnorm(d, dtype):
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(params, x, eps=1e-6):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(dt)


def head_rmsnorm(x, eps=1e-6):
    """Per-head QK-norm (no learned scale; qwen3/gemma3 style simplification)."""
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps)).astype(x.dtype)


# ---------------------------------------------------------------------------


def rope(x, positions, theta):
    """Rotary embedding. x: [..., seq, heads, head_dim], positions: [..., seq]."""
    head_dim = x.shape[-1]
    half = head_dim // 2
    freq = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    angles = positions[..., :, None].astype(jnp.float32) * freq  # [..., seq, half]
    cos = jnp.cos(angles)[..., :, None, :]  # [..., seq, 1, half]
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    x32_1, x32_2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([x32_1 * cos - x32_2 * sin, x32_2 * cos + x32_1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------


def init_embedding(key, vocab, d, dtype):
    return {"embedding": embed_init(key, (vocab, d), dtype)}


def embed(params, tokens):
    out = jnp.take(params["embedding"], tokens, axis=0)
    return logical(out, ("batch", "seq", "embed"))


def unembed(params, x, *, lm_head=None):
    """Logits from hidden states; tied (embedding.T) or separate lm_head."""
    if lm_head is not None:
        logits = jnp.einsum("bsd,dv->bsv", x, lm_head)
    else:
        logits = jnp.einsum("bsd,vd->bsv", x, params["embedding"])
    return logical(logits, ("batch", "seq", "vocab"))


# ---------------------------------------------------------------------------


def init_mlp(key, d, d_ff, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(k1, (d, d_ff), dtype),
        "w_in": dense_init(k2, (d, d_ff), dtype),
        "w_out": dense_init(k3, (d_ff, d), dtype, fan_in=d_ff),
    }


def _act(name):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu, "relu": jax.nn.relu}[name]


def mlp(params, x, act="silu"):
    """Gated MLP (SwiGLU/GeGLU)."""
    g = jnp.einsum("bsd,df->bsf", x, params["w_gate"])
    h = jnp.einsum("bsd,df->bsf", x, params["w_in"])
    h = logical(_act(act)(g) * h, ("batch", "seq", "ff"))
    out = jnp.einsum("bsf,fd->bsd", h, params["w_out"])
    return logical(out, ("batch", "seq", "embed"))


def cross_entropy(logits, targets, *, ignore_id: int = -1):
    """Mean token cross-entropy, vocab-shard friendly (no host-side gather).

    logits: [B, S, V] (possibly vocab-sharded), targets: [B, S] int32.
    """
    logits32 = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits32, axis=-1)
    tgt = jnp.take_along_axis(
        logits32, jnp.maximum(targets, 0)[..., None], axis=-1
    )[..., 0]
    nll = lse - tgt
    mask = (targets != ignore_id).astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
