"""Lightweight run-metrics logging: JSONL event stream + rolling aggregates.

Used by the training/serving drivers; offline-friendly (plain files, no
external services).
"""
from __future__ import annotations

import json
import os
import time
from collections import deque
from typing import Optional


class MetricsLogger:
    def __init__(self, path: Optional[str] = None, *, window: int = 50):
        self.path = path
        self._fh = None
        if path:
            os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
            self._fh = open(path, "a")
        self._win = {}
        self._window = window
        self._t0 = time.time()

    def log(self, step: int, **values):
        rec = {"step": step, "t": round(time.time() - self._t0, 3)}
        for k, v in values.items():
            v = float(v)
            rec[k] = v
            self._win.setdefault(k, deque(maxlen=self._window)).append(v)
        if self._fh:
            self._fh.write(json.dumps(rec) + "\n")
            self._fh.flush()
        return rec

    def mean(self, key: str) -> float:
        buf = self._win.get(key)
        return sum(buf) / len(buf) if buf else float("nan")

    def close(self):
        if self._fh:
            self._fh.close()
            self._fh = None


def read_jsonl(path: str):
    with open(path) as f:
        return [json.loads(line) for line in f if line.strip()]
