"""Lightweight run-metrics logging: JSONL event stream + rolling aggregates.

Used by the training/serving drivers; offline-friendly (plain files, no
external services). ``MetricsLogger`` is a thin shim over the obs event
recorder (``repro.obs.events.EventRecorder``): every ``log()`` call is a
``metric`` event in the obs schema, so a training log and an executor event
log are the same JSONL dialect and ``python -m repro.obs report``
summarizes both. Context-managed — ``with MetricsLogger(path) as m: ...``
closes the file handle even when the training loop raises.
"""
from __future__ import annotations

import json

from repro.obs.events import EventRecorder


class MetricsLogger(EventRecorder):
    """Training-metric recorder: ``log(step, loss=...)`` appends one
    ``metric`` event (JSONL when a path is given) and feeds the rolling
    ``mean(key)`` windows. A plain ``EventRecorder`` restricted to the
    metric kind, kept as the drivers' stable entry point."""

    def log(self, step: int, **values) -> dict:
        return self.metric(step, **values)


def read_jsonl(path: str):
    with open(path) as f:
        return [json.loads(line) for line in f if line.strip()]
