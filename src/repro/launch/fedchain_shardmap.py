"""FedChain local phase via shard_map + grouped collectives.

.. note:: REBASED onto the distributed sweep subsystem (``repro.dist``).
   This module predates ``repro.dist`` and survives as the grouped-
   collective formulation for *model-training* meshes without a dedicated
   client axis; ``repro.dist.client_axis`` is the maintained client-axis
   layer (per-shard Pallas aggregation + one psum join) and
   ``repro.dist.grid`` is the production path for experiment grids. The
   ``shard_map`` calls go through ``repro.dist.compat`` (one home for the
   JAX version skew).

The pjit path (`launch.fedchain`) gives each client group its own parameter
replica along a mesh axis. This module is the alternative single-pod
formulation promised in DESIGN.md §2: clients are CONTIGUOUS SUBGROUPS of the
data axis, and the local phase's gradient all-reduce uses
``jax.lax.psum(..., axis_index_groups=...)`` so the reduction never leaves a
client group — the grouped-collective realization of FedAvg's inner loop on a
mesh without a dedicated client axis.

Works on any (data, model) mesh where ``data % clients == 0``. Parameters are
data-axis-replicated per standard DP; during the local phase each subgroup's
copy evolves independently (they diverge across subgroups and re-merge at the
round boundary), which shard_map expresses directly because parameters are
per-device values inside the mapped function.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import tree_math as tm
from repro.dist import compat


def client_groups(data_size: int, clients: int):
    """axis_index_groups: contiguous subgroups of the data axis."""
    assert data_size % clients == 0
    per = data_size // clients
    return [list(range(c * per, (c + 1) * per)) for c in range(clients)]


def make_grouped_local_steps(
    loss_fn: Callable,  # (params, batch) -> scalar loss
    *,
    mesh,
    clients: int,
    lr: float,
    steps: int,
):
    """Returns a shard_map-ed function
        (params, batches [steps, local_batch, ...]) -> (params, mean_loss)
    where gradient reductions use axis_index_groups over 'data' — a local
    step emits NO collective that crosses a client-group boundary.

    Inside the mapped function params are per-device; model-axis reductions
    (tensor parallelism) still span the full 'model' axis.
    """
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    groups = client_groups(sizes["data"], clients)

    def local_steps(params, batches):
        # per-device shards; batch sharded over data, params replicated
        def one_step(p, batch):
            loss, grads = jax.value_and_grad(loss_fn)(p, batch)
            # grouped data-parallel gradient mean: stays inside the client
            grads = jax.tree.map(
                lambda g: jax.lax.pmean(
                    g, axis_name="data", axis_index_groups=groups),
                grads)
            # model-axis reduction for any partial grads (TP) spans 'model'
            p = tm.tree_axpy(-lr, grads, p)
            loss = jax.lax.pmean(loss, axis_name="data",
                                 axis_index_groups=groups)
            return p, loss

        def body(p, batch):
            p, loss = one_step(p, batch)
            return p, loss

        params, losses = jax.lax.scan(body, params, batches)
        return params, jnp.mean(losses)

    return compat.shard_map(
        local_steps,
        mesh,
        in_specs=(P(), P(None, "data")),
        out_specs=(P(), P()),
    )


def make_grouped_sync(*, mesh, clients: int):
    """Round boundary: average the (diverged) per-group parameter copies —
    one all-reduce over the FULL data axis (the only cross-client collective)."""

    def sync(params):
        return jax.tree.map(
            lambda p: jax.lax.pmean(p, axis_name="data"), params)

    return compat.shard_map(sync, mesh, in_specs=(P(),), out_specs=P())


def run_grouped_fedavg_round(
    loss_fn, params, batches, *, mesh, clients: int, lr: float, steps: int,
    server_lr: float = 1.0,
):
    """One full FedAvg round: grouped local steps then the cross-group merge."""
    local = make_grouped_local_steps(
        loss_fn, mesh=mesh, clients=clients, lr=lr, steps=steps)
    sync = make_grouped_sync(mesh=mesh, clients=clients)
    new_params, loss = local(params, batches)
    merged = sync(new_params)
    if server_lr != 1.0:
        merged = jax.tree.map(
            lambda old, new: ((1 - server_lr) * old.astype(jnp.float32)
                              + server_lr * new.astype(jnp.float32)).astype(new.dtype),
            params, merged)
    return merged, loss
