"""Compiled-artifact analysis: collective-byte parsing from HLO text and
roofline term derivation (DESIGN.md §5, deliverable g).

``cost_analysis()`` gives per-device FLOPs/bytes of the SPMD-partitioned
module; collective bytes are NOT in cost_analysis, so we parse the HLO and
sum result-shape bytes of every collective op, bucketed by kind.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict

from repro.launch.mesh import hardware_constants

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

COLLECTIVE_OPS = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)


def cost_dict(compiled) -> dict:
    """``compiled.cost_analysis()`` normalized across JAX versions: older
    releases return a per-device LIST of dicts (all devices run the same
    SPMD program, so the first entry is the per-device cost), newer ones a
    single dict."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return dict(cost)

# matches e.g.  bf16[128,7168]{1,0}  inside an HLO instruction line
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
# one HLO instruction line: "%name = <shape(s)> opcode(" — opcode may have
# -start/-done suffixes for async collectives
_INSTR_RE = re.compile(
    r"=\s*(\(?[^=]*?\)?)\s+(all-reduce|all-gather|reduce-scatter|all-to-all|"
    r"collective-permute)(?:-start)?\(")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


_GROUPS_V2_RE = re.compile(
    r"replica_groups=\[(\d+),(\d+)\]<=\[([\d,]+)\](?:T\(([\d,]+)\))?")
_GROUPS_V1_RE = re.compile(r"replica_groups=\{(\{[\d,{}\s]*\})\}")


def _groups_cross_pod(line: str, pod_size: int):
    """True if the instruction's replica groups span a pod boundary
    (device ids < pod_size vs ≥ pod_size; mesh order is pod-major).
    None when no groups are present (e.g. single full-module group)."""
    import numpy as np

    m = _GROUPS_V2_RE.search(line)
    if m:
        ng, gs = int(m.group(1)), int(m.group(2))
        dims = [int(d) for d in m.group(3).split(",")]
        ids = np.arange(int(np.prod(dims))).reshape(dims)
        if m.group(4):
            perm = [int(p) for p in m.group(4).split(",")]
            ids = ids.transpose(perm)
        groups = ids.reshape(ng, gs)
        pods = groups // pod_size
        return bool((pods.min(axis=1) != pods.max(axis=1)).any())
    m = _GROUPS_V1_RE.search(line)
    if m:
        for grp in re.findall(r"\{([\d,\s]+)\}", m.group(0)):
            ids = [int(x) for x in grp.replace(" ", "").split(",") if x]
            pods = {i // pod_size for i in ids}
            if len(pods) > 1:
                return True
        return False
    return None


def parse_collectives(hlo_text: str, *, pod_size: int = 0) -> Dict[str, dict]:
    """Per-collective-kind {count, bytes[, cross_pod_bytes]} from HLO.

    pod_size > 0 additionally buckets bytes whose replica groups span a pod
    boundary (exact iota/v1 replica_groups decoding)."""
    out = {k: {"count": 0, "bytes": 0} for k in COLLECTIVE_OPS}
    if pod_size:
        for k in out:
            out[k]["cross_pod_bytes"] = 0
    for line in hlo_text.splitlines():
        m = _INSTR_RE.search(line)
        if not m:
            continue
        shapes, op = m.group(1), m.group(2)
        if "-done(" in line:  # avoid double counting async pairs
            continue
        b = _shape_bytes(shapes)
        out[op]["count"] += 1
        out[op]["bytes"] += b
        if pod_size:
            crosses = _groups_cross_pod(line, pod_size)
            if crosses or crosses is None:  # no groups => global => crosses
                out[op]["cross_pod_bytes"] += b
    return out


def collective_wire_bytes(colls: Dict[str, dict]) -> float:
    """Approximate per-device wire traffic: ring all-reduce moves ~2× the
    buffer; gather/scatter/all-to-all move ~1× the result."""
    b = 0.0
    for kind, rec in colls.items():
        factor = 2.0 if kind == "all-reduce" else 1.0
        b += factor * rec["bytes"]
    return b


@dataclasses.dataclass
class Roofline:
    flops_per_device: float
    hbm_bytes_per_device: float
    collective_bytes_per_device: float
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops: float
    useful_ratio: float
    collectives: Dict[str, dict]

    def to_dict(self):
        return dataclasses.asdict(self)


def roofline(cost: dict, colls: Dict[str, dict], *, n_chips: int,
             model_flops: float, links: int = 4) -> Roofline:
    """Derive the three roofline terms from the compiled per-device numbers.

    cost: compiled.cost_analysis() dict (per-device, post-partitioning).
    model_flops: 6·N·D (global); useful_ratio = model_flops / (flops·chips).
    """
    hw = hardware_constants()
    flops = float(cost.get("flops", 0.0))
    hbm_bytes = float(cost.get("bytes accessed", 0.0))
    wire = collective_wire_bytes(colls)
    compute_s = flops / hw["peak_flops_bf16"]
    memory_s = hbm_bytes / hw["hbm_bw"]
    collective_s = wire / (hw["ici_link_bw"] * links)
    dominant = max(
        (("compute", compute_s), ("memory", memory_s), ("collective", collective_s)),
        key=lambda kv: kv[1],
    )[0]
    total_hlo_flops = flops * n_chips
    useful = model_flops / total_hlo_flops if total_hlo_flops > 0 else 0.0
    return Roofline(
        flops_per_device=flops,
        hbm_bytes_per_device=hbm_bytes,
        collective_bytes_per_device=wire,
        compute_s=compute_s,
        memory_s=memory_s,
        collective_s=collective_s,
        dominant=dominant,
        model_flops=model_flops,
        useful_ratio=useful,
        collectives=colls,
    )


def memory_summary(mem) -> dict:
    return {
        "argument_bytes": mem.argument_size_in_bytes,
        "output_bytes": mem.output_size_in_bytes,
        "temp_bytes": mem.temp_size_in_bytes,
        "alias_bytes": mem.alias_size_in_bytes,
        "code_bytes": mem.generated_code_size_in_bytes,
    }
