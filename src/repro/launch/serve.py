"""Batched serving driver: prefill a prompt batch, then decode N tokens
autoregressively against the KV caches / SSM states.

CPU-runnable with ``--smoke``; identical code path targets the production
meshes. Greedy or temperature sampling.

Example:
  PYTHONPATH=src python -m repro.launch.serve --arch mamba2-1.3b --smoke \
      --batch 4 --prompt-len 64 --gen 32
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time

import jax
import jax.numpy as jnp

from repro.configs import registry
from repro.models import model_zoo, transformer


def parse_args(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2-1.3b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    return ap.parse_args(argv)


def serve(cfg, *, batch: int, prompt_len: int, gen: int, temperature: float = 0.0,
          seed: int = 0):
    key = jax.random.PRNGKey(seed)
    params = transformer.init_model(cfg, key)
    max_len = prompt_len + gen + (cfg.frontend.seq if cfg.frontend and
                                  cfg.frontend.kind == "vision" else 0)

    prompts = jax.random.randint(key, (batch, prompt_len), 0, cfg.vocab_size, jnp.int32)
    pre_batch = {"tokens": prompts}
    off = 0
    cross = None
    if cfg.frontend is not None and cfg.frontend.kind == "vision":
        pre_batch["image_embeds"] = jnp.zeros(
            (batch, cfg.frontend.seq, cfg.frontend.dim), cfg.param_dtype())
        off = cfg.frontend.seq
    if cfg.encoder is not None:
        frames = jnp.zeros((batch, cfg.frontend.seq, cfg.frontend.dim), cfg.param_dtype())
        enc_out = transformer._encode(params, cfg, frames)
        cross = transformer._cross_kv_from_encoder(params, cfg, enc_out)
        pre_batch["cross_kv"] = cross

    caches = transformer.init_caches(cfg, batch, max_len)
    prefill = jax.jit(model_zoo.make_prefill_step(cfg))
    serve_step = jax.jit(model_zoo.make_serve_step(cfg))

    t0 = time.time()
    logits, caches = prefill(params, pre_batch, caches)
    t_prefill = time.time() - t0

    def sample(k, lg):
        if temperature <= 0:
            return jnp.argmax(lg[:, -1, :], axis=-1).astype(jnp.int32)
        return jax.random.categorical(k, lg[:, -1, :] / temperature).astype(jnp.int32)

    tok = sample(key, logits)[:, None]
    out_tokens = [tok]
    t0 = time.time()
    for i in range(gen - 1):
        pos = off + prompt_len + i
        if cfg.encoder is not None:
            logits, caches = serve_step(params, caches, tok, pos, cross)
        else:
            logits, caches = serve_step(params, caches, tok, pos)
        key, sk = jax.random.split(key)
        tok = sample(sk, logits)[:, None]
        out_tokens.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.time() - t0
    tokens = jnp.concatenate(out_tokens, axis=1)
    return {
        "tokens": tokens,
        "prefill_s": t_prefill,
        "decode_tok_per_s": batch * (gen - 1) / max(t_decode, 1e-9),
    }


def main(argv=None):
    args = parse_args(argv)
    cfg = registry.get_config(args.arch, smoke=args.smoke)
    if args.smoke:
        cfg = dataclasses.replace(cfg, max_seq_len=max(2 * (args.prompt_len + args.gen), 256))
    res = serve(cfg, batch=args.batch, prompt_len=args.prompt_len, gen=args.gen,
                temperature=args.temperature, seed=args.seed)
    print(json.dumps({
        "arch": cfg.name,
        "generated_shape": list(res["tokens"].shape),
        "prefill_s": round(res["prefill_s"], 3),
        "decode_tok_per_s": round(res["decode_tok_per_s"], 1),
    }))
    return res


if __name__ == "__main__":
    main()
