"""FedChain as a first-class distributed-training feature.

Mapping (DESIGN.md §2): a *client* is a client-group of the mesh — the "pod"
axis on the multi-pod mesh, or a dedicated "client" axis on a single-pod FL
mesh. The paper's phases become collective schedules:

  * local phase  (A_local = FedAvg):  each client group holds its own replica
    of the parameters (leading [C] axis sharded over the client axis) and runs
    ``vmap``-ed train steps — data-parallel gradient reductions stay *inside*
    the group, so a local step emits ZERO cross-group collective bytes.
  * round boundary: one cross-group parameter average (all-reduce over the
    client axis) — optionally through the fused ``chain_aggregate`` kernel.
  * selection (Lemma H.2): per-client loss on a held-out probe batch for both
    candidates, one scalar all-reduce, argmin.
  * global phase (A_global = SGD/ASG): standard synchronous data-parallel
    steps over the full mesh every step.

The §Roofline collective-bytes comparison between these programs is the
paper's round-complexity saving expressed in TPU link traffic.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models import model_zoo, transformer
from repro.optim import Optimizer
from repro.sharding import RuleSet, param_specs


def make_fl_mesh(clients: int = 4, data: int = 4, model: int = 16):
    """Single-pod FL mesh: the 16-way data axis split into client × data."""
    from repro.dist import compat

    return compat.make_mesh(
        (clients, data, model), ("client", "data", "model"))


def client_axis_name(mesh) -> str:
    return "client" if "client" in mesh.axis_names else "pod"


def num_clients(mesh) -> int:
    return dict(zip(mesh.axis_names, mesh.devices.shape))[client_axis_name(mesh)]


@dataclasses.dataclass(frozen=True)
class FedChainConfig:
    local_rounds: int = 8  # rounds of A_local
    local_steps: int = 16  # K: local steps per round (between syncs)
    global_steps: int = 0  # remaining synchronous steps (0 => run until budget)
    server_lr: float = 1.0
    selection_enabled: bool = True


def _stack_specs(specs, client_axis):
    """Prepend the client axis to every leaf PartitionSpec."""
    return jax.tree.map(
        lambda s: P(client_axis, *s), specs, is_leaf=lambda s: isinstance(s, P))


def fedchain_shardings(cfg, mesh, ruleset: Optional[RuleSet] = None):
    """(stacked_param_shardings, per_client_batch_sharding builder)."""
    rs = ruleset or RuleSet(mesh)
    c_ax = client_axis_name(mesh)
    shapes = jax.eval_shape(
        lambda k: transformer.init_model(cfg, k), jax.ShapeDtypeStruct((2,), jnp.uint32))
    specs = param_specs(shapes, rs)
    stacked = _stack_specs(specs, c_ax)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), stacked,
                        is_leaf=lambda s: isinstance(s, P))


def broadcast_to_clients(params, n_clients: int):
    """Replicate server params into the [C, ...] stacked layout."""
    return jax.tree.map(lambda t: jnp.broadcast_to(t[None], (n_clients,) + t.shape), params)


def make_local_round(cfg, optimizer: Optimizer, fl: FedChainConfig, *,
                     n_clients: int, moe_groups: int = 1):
    """One A_local (FedAvg) round: ``local_steps`` per-client SGD steps with
    NO cross-client communication, then a cross-client parameter average.

    client_params/opt: [C, ...]; batches: [local_steps, C, b, ...].
    """
    step = model_zoo.make_train_step(cfg, optimizer, moe_groups=moe_groups)

    def per_client_steps(params, opt_state, batches):
        def body(carry, batch):
            p, o = carry
            p, o, m = step(p, o, batch)
            return (p, o), m["loss"]

        (params, opt_state), losses = jax.lax.scan(body, (params, opt_state), batches)
        return params, opt_state, jnp.mean(losses)

    def local_round(client_params, client_opt, batches):
        # vmap over the client axis: gradient reductions stay within a client
        new_p, new_o, losses = jax.vmap(per_client_steps, in_axes=(0, 0, 1))(
            client_params, client_opt, batches)
        # round boundary: FedAvg server step x <- (1-slr)x + slr*mean_c(y_c)
        mean_p = jax.tree.map(lambda t: jnp.mean(t, axis=0), new_p)
        if fl.server_lr != 1.0:
            old_mean = jax.tree.map(lambda t: jnp.mean(t, axis=0), client_params)
            mean_p = jax.tree.map(
                lambda o, n: ((1.0 - fl.server_lr) * o.astype(jnp.float32)
                              + fl.server_lr * n.astype(jnp.float32)).astype(n.dtype),
                old_mean, mean_p)
        new_client_p = jax.tree.map(
            lambda t: jnp.broadcast_to(t[None], (n_clients,) + t.shape), mean_p)
        return new_client_p, new_o, jnp.mean(losses)

    return local_round


def make_local_steps_only(cfg, optimizer: Optimizer, fl: FedChainConfig, *,
                          moe_groups: int = 1):
    """The inner local phase WITHOUT the sync (for dry-run collective
    accounting: this program must contain no cross-client collectives)."""
    step = model_zoo.make_train_step(cfg, optimizer, moe_groups=moe_groups)

    def local_steps(client_params, client_opt, batches):
        def per_client(params, opt_state, bs):
            def body(carry, batch):
                p, o = carry
                p, o, m = step(p, o, batch)
                return (p, o), m["loss"]

            (params, opt_state), losses = jax.lax.scan(body, (params, opt_state), bs)
            return params, opt_state, jnp.mean(losses)

        return jax.vmap(per_client, in_axes=(0, 0, 1))(client_params, client_opt, batches)

    return local_steps


def make_sync_step(n_clients: int, *, server_lr: float = 1.0, use_kernel: bool = False):
    """The round-boundary cross-client average (the only cross-group collective)."""

    def sync(client_params):
        if use_kernel:
            from repro.kernels.aggregate import ops as agg_ops

            mean_p = jax.tree.map(lambda t: agg_ops.mean_over_clients(t), client_params)
        else:
            mean_p = jax.tree.map(lambda t: jnp.mean(t, axis=0), client_params)
        return jax.tree.map(
            lambda t: jnp.broadcast_to(t[None], (n_clients,) + t.shape), mean_p)

    return sync


def make_selection_step(cfg, *, moe_groups: int = 1):
    """Lemma H.2 at scale: pick argmin of probe-batch loss between the
    pre-phase params and the local-phase output (both [C, ...])."""
    eval_loss = model_zoo.make_eval_loss(cfg, moe_groups=moe_groups)

    def select(cand_a, cand_b, probe_batches):
        la = jnp.mean(jax.vmap(eval_loss)(cand_a, probe_batches))
        lb = jnp.mean(jax.vmap(eval_loss)(cand_b, probe_batches))
        pick_a = la <= lb
        chosen = jax.tree.map(lambda a, b: jnp.where(pick_a, a, b), cand_a, cand_b)
        return chosen, pick_a, (la, lb)

    return select


def make_global_step(cfg, optimizer: Optimizer, *, moe_groups: int = 1):
    """A_global: plain synchronous data-parallel step over the full mesh."""
    return model_zoo.make_train_step(cfg, optimizer, moe_groups=moe_groups)
