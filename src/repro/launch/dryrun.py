import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run (deliverable e): lower + compile every
(architecture × input shape) on the production meshes and extract the
memory / cost / collective analysis that §Roofline reads.

The two lines above MUST stay first: jax locks the device count on first init.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch gemma3-4b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all --mesh both
  ... --fedchain            # additionally dry-run the FedChain local/sync steps
Results: experiments/dryrun/<arch>__<shape>__<mesh>.json
"""
import argparse  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs import INPUT_SHAPES, registry  # noqa: E402
from repro.launch import analysis  # noqa: E402
from repro.launch.mesh import data_shards, make_production_mesh  # noqa: E402
from repro.models import model_zoo, transformer  # noqa: E402
from repro.optim import sgd  # noqa: E402
from repro.sharding import RuleSet, param_specs, use_rules  # noqa: E402
from repro.sharding.rules import cache_specs_tree  # noqa: E402

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..", "experiments", "dryrun")


def _depth_units(cfg):
    """Depth units for FLOP/collective extrapolation.

    XLA's cost_analysis counts a scan (while-loop) body ONCE, ignoring the
    trip count, so the scanned compile undercounts FLOPs/collective bytes by
    ~num_layers×. Per-layer costs are additive in depth, so we compile tiny
    *unrolled* variants (every unit at 1, then each unit at 2) and solve
    total = a + Σ_u b_u·count_u exactly.
    Returns {unit_name: full_count}.
    """
    units = {}
    if cfg.arch_type == "hybrid":
        # one unit = `period` mamba layers + 1 shared-attn application;
        # the tail (num_layers % period) is approximated as a fraction.
        units["group"] = cfg.num_layers / cfg.hybrid.period
        return units
    if cfg.moe is not None and cfg.moe.first_dense_layers > 0:
        units["dense"] = cfg.moe.first_dense_layers
        units["moe"] = cfg.num_layers - cfg.moe.first_dense_layers
    elif cfg.moe is not None:
        units["moe"] = cfg.num_layers
    else:
        units["decoder"] = cfg.num_layers
    if cfg.encoder is not None:
        units["encoder"] = cfg.encoder.num_layers
    return units


def _variant_cfg(cfg, counts):
    """A depth-reduced unrolled clone: each unit at counts[unit] layers."""
    import dataclasses as dc

    kw = dict(scan_layers=False)
    if cfg.arch_type == "hybrid":
        kw["num_layers"] = counts["group"] * cfg.hybrid.period
    elif cfg.moe is not None and cfg.moe.first_dense_layers > 0:
        kw["num_layers"] = counts["dense"] + counts["moe"]
        kw["moe"] = dc.replace(cfg.moe, first_dense_layers=counts["dense"])
    elif cfg.moe is not None:
        kw["num_layers"] = counts["moe"]
    else:
        kw["num_layers"] = counts["decoder"]
    if cfg.encoder is not None:
        kw["encoder"] = dc.replace(cfg.encoder, num_layers=counts["encoder"])
    return dc.replace(cfg, **kw)


def _extrapolate(base, bumps, units):
    """total = a + Σ b_u·count_u given f(1,..,1) and f(..,2_u,..)."""
    b = {u: bumped - base for u, bumped in bumps.items()}
    a = base - sum(b.values())
    return a + sum(b[u] * units[u] for u in units)


def _cost_record(compiled):
    cost = analysis.cost_dict(compiled)
    colls = analysis.parse_collectives(compiled.as_text())
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "colls": colls,
    }


def _combine_colls(base, bumps, units):
    out = {}
    for kind in analysis.COLLECTIVE_OPS:
        rec = {}
        for field in ("count", "bytes"):
            rec[field] = max(0.0, _extrapolate(
                base["colls"][kind][field],
                {u: b["colls"][kind][field] for u, b in bumps.items()}, units))
        out[kind] = rec
    return out


def _skip_reason(cfg, shape) -> str:
    if shape.name == "long_500k" and not cfg.subquadratic:
        return "long_500k requires sub-quadratic attention (DESIGN.md §4 skip table)"
    return ""


def _batch_shardings(cfg, shape, rs: RuleSet):
    specs = model_zoo.batch_specs(cfg, shape)

    def spec_of(name, leaf):
        axes = ("batch",) + (None,) * (len(leaf.shape) - 1)
        return rs.spec_for(axes, leaf.shape)

    return {k: NamedSharding(rs.mesh, spec_of(k, v)) for k, v in specs.items()}


def _named(tree_specs, mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree_specs,
                        is_leaf=lambda s: isinstance(s, P))


def _compile_step(cfg, shape, mesh, rs: RuleSet, groups: int):
    """Build the right step fn for the shape kind, lower and compile it."""
    param_shapes = jax.eval_shape(
        lambda k: transformer.init_model(cfg, k), jax.ShapeDtypeStruct((2,), jnp.uint32))
    p_shardings = _named(param_specs(param_shapes, rs), mesh)

    t0 = time.time()
    with use_rules(rs):
        if shape.kind == "train":
            opt = sgd(1e-2)
            step = model_zoo.make_train_step(cfg, opt, moe_groups=groups)
            b_shardings = _batch_shardings(cfg, shape, rs)
            # repro: allow[R4] one-shot AOT lowering jit, never cached
            jitted = jax.jit(
                step,
                in_shardings=(p_shardings, (), b_shardings),
                out_shardings=(p_shardings, (), None),
                donate_argnums=(0,),
            )
            args = (param_shapes, (), model_zoo.batch_specs(cfg, shape))
        elif shape.kind == "prefill":
            step = model_zoo.make_prefill_step(cfg, moe_groups=groups)
            cache_shapes = transformer.cache_specs(cfg, shape.global_batch, shape.seq_len)
            c_shardings = _named(cache_specs_tree(cache_shapes, rs), mesh)
            b_shardings = _batch_shardings(cfg, shape, rs)
            # repro: allow[R4] one-shot AOT lowering jit, never cached
            jitted = jax.jit(
                step,
                in_shardings=(p_shardings, b_shardings, c_shardings),
                out_shardings=(None, c_shardings),
                donate_argnums=(2,),
            )
            args = (param_shapes, model_zoo.batch_specs(cfg, shape), cache_shapes)
        else:  # decode
            step = model_zoo.make_serve_step(cfg, moe_groups=groups)
            specs = model_zoo.decode_specs(cfg, shape)
            c_shardings = _named(cache_specs_tree(specs["caches"], rs), mesh)
            tok_sh = NamedSharding(mesh, rs.spec_for(("batch", None), specs["tokens"].shape))
            in_sh = [p_shardings, c_shardings, tok_sh, NamedSharding(mesh, P())]
            args = [param_shapes, specs["caches"], specs["tokens"], specs["pos"]]
            if cfg.encoder is not None:
                x_sh = _named(cache_specs_tree(specs["cross_kv"], rs), mesh)
                in_sh.append(x_sh)
                args.append(specs["cross_kv"])
            # repro: allow[R4] one-shot AOT lowering jit, never cached
            jitted = jax.jit(
                step,
                in_shardings=tuple(in_sh),
                out_shardings=(None, c_shardings),
                donate_argnums=(1,),
            )
            args = tuple(args)

        lowered = jitted.lower(*args)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0
    return compiled, round(t_lower, 2), round(t_compile, 2)


def lower_one(arch: str, shape_name: str, mesh, mesh_name: str, *,
              mla_absorb: bool = False, seq_shard: bool = False,
              attn_fallback: bool = False, fsdp: bool = False,
              measure_depth: bool = True):
    """Lower + compile one (arch × shape × mesh); returns the result record.

    The full (scanned) compile proves the config lowers and gives
    memory_analysis; tiny unrolled depth variants recover trip-count-exact
    FLOPs and collective bytes (see _depth_units).
    """
    import dataclasses

    cfg = registry.get_config(arch)
    if mla_absorb and cfg.mla is not None:
        cfg = dataclasses.replace(cfg, mla=dataclasses.replace(cfg.mla, absorb_decode=True))
    shape = INPUT_SHAPES[shape_name]
    reason = _skip_reason(cfg, shape)
    if reason:
        return {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                "status": "skipped", "reason": reason}

    rules = None
    if seq_shard:
        # §Perf: shard activations' sequence axis over the model axis so the
        # remat-saved scan carries shard 256-way (keeps weight sharding).
        rules = {"seq": "model"}
    rs = RuleSet(mesh, rules, attn_embed_fallback=attn_fallback, fsdp=fsdp)
    n_chips = mesh.devices.size
    groups = data_shards(mesh)

    compiled, t_lower, t_compile = _compile_step(cfg, shape, mesh, rs, groups)
    mem = compiled.memory_analysis()
    raw = _cost_record(compiled)

    rec = {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_name,
        "status": "ok",
        "n_chips": n_chips,
        "lower_s": t_lower,
        "compile_s": t_compile,
        "memory": analysis.memory_summary(mem),
        "cost_scanned_raw": {"flops": raw["flops"], "bytes": raw["bytes"]},
        "params": model_zoo.param_count(cfg),
        "active_params": model_zoo.active_param_count(cfg),
    }

    mf = model_zoo.model_flops(cfg, shape)
    if measure_depth:
        units = _depth_units(cfg)
        ones = {u: 1 for u in units}
        base_cfg = _variant_cfg(cfg, ones)
        c0, _, _ = _compile_step(base_cfg, shape, mesh, rs, groups)
        base = _cost_record(c0)
        bumps = {}
        for u in units:
            counts = dict(ones)
            counts[u] = 2
            cu, _, _ = _compile_step(_variant_cfg(cfg, counts), shape, mesh, rs, groups)
            bumps[u] = _cost_record(cu)
        flops = _extrapolate(base["flops"], {u: b["flops"] for u, b in bumps.items()}, units)
        hbytes = _extrapolate(base["bytes"], {u: b["bytes"] for u, b in bumps.items()}, units)
        colls = _combine_colls(base, bumps, units)
        rec["cost_extrapolated"] = {"flops": flops, "bytes": hbytes}
        roof = analysis.roofline({"flops": flops, "bytes accessed": hbytes}, colls,
                                 n_chips=n_chips, model_flops=mf)
    else:
        roof = analysis.roofline({"flops": raw["flops"], "bytes accessed": raw["bytes"]},
                                 raw["colls"], n_chips=n_chips, model_flops=mf)
        rec["note"] = "scanned-HLO cost (while-body counted once); see single-pod for exact"
    rec["roofline"] = roof.to_dict()
    return rec


def lower_fedchain(arch: str, mesh, mesh_name: str):
    """Dry-run the FedChain phases: local steps (must show zero cross-client
    collective growth), the sync step, and the global step, for §Perf."""
    import dataclasses as dc

    from repro.launch import fedchain as fc

    cfg = registry.get_config(arch)
    shape = dc.replace(INPUT_SHAPES["train_4k"])
    # FL layout: the client axis ("pod") holds per-client replicas, so the
    # activation "batch" axis must bind to "data" ONLY — otherwise the
    # logical() constraints inside the vmapped per-client step would force
    # resharding across clients (cross-pod traffic in the local phase).
    rs = RuleSet(mesh, {"batch": "data"})
    groups = data_shards(mesh)
    c_ax = fc.client_axis_name(mesh)
    n_clients = fc.num_clients(mesh)
    local_steps = 4

    param_shapes = jax.eval_shape(
        lambda k: transformer.init_model(cfg, k), jax.ShapeDtypeStruct((2,), jnp.uint32))
    stacked_shapes = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct((n_clients,) + s.shape, s.dtype), param_shapes)
    stacked_sh = _named(
        jax.tree.map(lambda s: P(c_ax, *s), param_specs(param_shapes, rs),
                     is_leaf=lambda s: isinstance(s, P)), mesh)

    bspecs = model_zoo.batch_specs(cfg, shape)
    per_client_b = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(
            (local_steps, n_clients, s.shape[0] // n_clients) + s.shape[1:], s.dtype),
        bspecs)
    b_sh = jax.tree.map(
        lambda s: NamedSharding(mesh, P(None, c_ax, "data", *([None] * (len(s.shape) - 3)))),
        per_client_b)

    opt = sgd(1e-2)
    fl = fc.FedChainConfig(local_steps=local_steps)
    local = fc.make_local_steps_only(cfg, opt, fl, moe_groups=groups // n_clients or 1)
    sync = fc.make_sync_step(n_clients)

    # pod size for cross-pod bucketing: devices are pod-major in the mesh
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    pod_size = mesh.devices.size // sizes.get(c_ax, 1)

    results = {}
    with use_rules(rs):
        # repro: allow[R4] one-shot AOT lowering jit, never cached
        j_local = jax.jit(local, in_shardings=(stacked_sh, (), b_sh),
                          out_shardings=(stacked_sh, (), None), donate_argnums=(0,))
        lo = j_local.lower(stacked_shapes, (), per_client_b)
        co = lo.compile()
        results["local_phase"] = {
            "collectives": analysis.parse_collectives(co.as_text(), pod_size=pod_size),
            "cost": {k: v for k, v in analysis.cost_dict(co).items()
                     if isinstance(v, (int, float))},
            "memory": analysis.memory_summary(co.memory_analysis()),
        }

        j_sync = jax.jit(sync, in_shardings=(stacked_sh,), out_shardings=stacked_sh)
        co2 = j_sync.lower(stacked_shapes).compile()
        results["sync_step"] = {
            "collectives": analysis.parse_collectives(co2.as_text(), pod_size=pod_size),
            "memory": analysis.memory_summary(co2.memory_analysis()),
        }

        # global phase: plain synchronous step (the A_global baseline) — uses
        # the standard layout (batch over pod+data) since no client axis exists
        rs_global = RuleSet(mesh)
        step = model_zoo.make_train_step(cfg, opt, moe_groups=groups)
        p_sh = _named(param_specs(param_shapes, rs_global), mesh)
        b2 = _batch_shardings(cfg, shape, rs_global)
        with use_rules(rs_global):
            # repro: allow[R4] one-shot AOT lowering jit, never cached
            j_glob = jax.jit(step, in_shardings=(p_sh, (), b2),
                             out_shardings=(p_sh, (), None), donate_argnums=(0,))
            co3 = j_glob.lower(param_shapes, (), model_zoo.batch_specs(cfg, shape)).compile()
        results["global_step"] = {
            "collectives": analysis.parse_collectives(co3.as_text(), pod_size=pod_size),
            "cost": {k: v for k, v in analysis.cost_dict(co3).items()
                     if isinstance(v, (int, float))},
            "memory": analysis.memory_summary(co3.memory_analysis()),
        }

    return {"arch": arch, "mesh": mesh_name, "mode": "fedchain",
            "status": "ok", "phases": results,
            "local_steps_per_round": local_steps, "n_clients": n_clients}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--fedchain", action="store_true")
    ap.add_argument("--mla-absorb", action="store_true")
    ap.add_argument("--seq-shard", action="store_true")
    ap.add_argument("--attn-fallback", action="store_true")
    ap.add_argument("--fsdp", action="store_true")
    ap.add_argument("--tag", default="", help="suffix for artifact filenames")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    out_dir = args.out or os.path.abspath(OUT_DIR)
    os.makedirs(out_dir, exist_ok=True)

    archs = list(registry.ARCH_IDS) if args.arch == "all" else args.arch.split(",")
    shapes = list(INPUT_SHAPES) if args.shape == "all" else args.shape.split(",")
    meshes = []
    if args.mesh in ("single", "both"):
        meshes.append(("single_pod_16x16", make_production_mesh(multi_pod=False)))
    if args.mesh in ("multi", "both"):
        meshes.append(("multi_pod_2x16x16", make_production_mesh(multi_pod=True)))

    failures = 0
    for mesh_name, mesh in meshes:
        for arch in archs:
            if args.fedchain:
                tag = f"fedchain__{arch}__{mesh_name}"
                try:
                    rec = lower_fedchain(arch, mesh, mesh_name)
                    print(f"[ok] {tag}")
                except Exception as e:  # noqa: BLE001
                    rec = {"arch": arch, "mesh": mesh_name, "mode": "fedchain",
                           "status": "error", "error": f"{type(e).__name__}: {e}",
                           "traceback": traceback.format_exc()}
                    failures += 1
                    print(f"[FAIL] {tag}: {e}")
                with open(os.path.join(out_dir, tag + ".json"), "w") as f:
                    json.dump(rec, f, indent=1)
                continue
            for shape_name in shapes:
                suffix = ""
                if args.mla_absorb:
                    suffix += "__absorb"
                if args.seq_shard:
                    suffix += "__seqshard"
                if args.attn_fallback:
                    suffix += "__attnfb"
                if args.fsdp:
                    suffix += "__fsdp"
                if args.tag:
                    suffix += f"__{args.tag}"
                tag = f"{arch}__{shape_name}__{mesh_name}{suffix}"
                path = os.path.join(out_dir, tag + ".json")
                try:
                    rec = lower_one(arch, shape_name, mesh, mesh_name,
                                    mla_absorb=args.mla_absorb,
                                    seq_shard=args.seq_shard,
                                    attn_fallback=args.attn_fallback,
                                    fsdp=args.fsdp,
                                    measure_depth=mesh_name.startswith("single"))
                    rec["variant"] = suffix.strip("_") or "baseline"
                    status = rec["status"]
                    extra = rec.get("reason", "")
                    if status == "ok":
                        r = rec["roofline"]
                        extra = (f"comp={r['compute_s']:.3e}s mem={r['memory_s']:.3e}s "
                                 f"coll={r['collective_s']:.3e}s dom={r['dominant']}")
                    print(f"[{status}] {tag} {extra}")
                except Exception as e:  # noqa: BLE001
                    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                           "status": "error", "error": f"{type(e).__name__}: {e}",
                           "traceback": traceback.format_exc()}
                    failures += 1
                    print(f"[FAIL] {tag}: {e}")
                with open(path, "w") as f:
                    json.dump(rec, f, indent=1)
    print(f"done; failures={failures}")
    return failures


if __name__ == "__main__":
    raise SystemExit(main())
