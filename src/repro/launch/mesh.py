"""Production mesh definitions (TPU v5e target).

Functions, not module-level constants, so importing this module never touches
jax device state (device count is locked at first jax init).

Mesh construction goes through ``repro.dist.compat`` (the distributed sweep
subsystem owns the JAX mesh/shard_map version skew); the sweep-grid meshes
themselves live in ``repro.dist.mesh`` — these are the model-parallel
(data × model) meshes of the serving/training scaffold.
"""
from __future__ import annotations

from repro.dist import compat


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 single-pod (256 chips) or 2×16×16 two-pod (512 chips) mesh."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes)


def make_debug_mesh(data: int = 2, model: int = 2, *, pod: int = 0):
    """Small mesh for CPU tests (requires xla_force_host_platform_device_count)."""
    if pod:
        return compat.make_mesh((pod, data, model), ("pod", "data", "model"))
    return compat.make_mesh((data, model), ("data", "model"))


def data_shards(mesh) -> int:
    """Number of data-parallel shards (= MoE routing groups, FL client slots)."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    return sizes.get("pod", 1) * sizes.get("data", 1)


def hardware_constants():
    """TPU v5e roofline constants (per chip)."""
    return {
        "peak_flops_bf16": 197e12,  # FLOP/s
        "hbm_bw": 819e9,  # B/s
        "ici_link_bw": 50e9,  # B/s per link
    }
