"""End-to-end training driver.

Modes:
  * plain synchronous training (``--fl-mode none``) — the A_global baseline;
  * FedChain (``--fl-mode fedchain``) — local-update phase with per-client
    replicas and zero cross-client collectives, Lemma H.2 selection, then the
    synchronous global phase (the paper's Algo 1 as a systems feature).

CPU-runnable end-to-end with ``--smoke`` (reduced configs, synthetic token
stream); the same code path drives the production meshes on TPU.

Example:
  PYTHONPATH=src python -m repro.launch.train --arch qwen3-14b --smoke \
      --steps 60 --fl-mode fedchain --clients 4 --local-steps 4 --local-rounds 4
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import save_checkpoint
from repro.configs import registry
from repro.data.tokens import SyntheticTokenStream, TokenStreamConfig
from repro.launch import fedchain as fc
from repro.models import model_zoo, transformer
from repro.optim import get_optimizer


def parse_args(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-14b")
    ap.add_argument("--smoke", action="store_true", help="reduced config (CPU)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=0.1)
    ap.add_argument("--optimizer", default="sgd", choices=["sgd", "momentum", "adamw"])
    ap.add_argument("--fl-mode", default="none", choices=["none", "fedchain"])
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--local-steps", type=int, default=4, help="K between syncs")
    ap.add_argument("--local-rounds", type=int, default=4)
    ap.add_argument("--heterogeneity", type=float, default=1.0)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--metrics-path", default=None, help="JSONL metrics file")
    ap.add_argument("--microbatches", type=int, default=1,
                    help=">1 enables gradient accumulation (memory lever)")
    ap.add_argument("--seed", type=int, default=0)
    return ap.parse_args(argv)


def run_plain(cfg, args):
    from repro.launch.metrics import MetricsLogger

    key = jax.random.PRNGKey(args.seed)
    params = transformer.init_model(cfg, key)
    opt = get_optimizer(args.optimizer, args.lr)
    opt_state = opt.init(params)
    if args.microbatches > 1:
        from repro.optim.accumulate import make_accumulating_train_step

        def loss_fn(p, b):
            return transformer.lm_loss(p, cfg, b)

        step_fn = jax.jit(make_accumulating_train_step(
            loss_fn, opt, microbatches=args.microbatches))
    else:
        step_fn = jax.jit(model_zoo.make_train_step(cfg, opt))

    stream = SyntheticTokenStream(TokenStreamConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq, batch_size=args.batch,
        num_clients=1, seed=args.seed))

    losses = []
    t0 = time.time()
    with MetricsLogger(args.metrics_path) as logger:
        for step in range(args.steps):
            batch = _full_batch(cfg, stream.batch(0, step), args)
            params, opt_state, metrics = step_fn(params, opt_state, batch)
            losses.append(float(metrics["loss"]))
            logger.log(step, loss=losses[-1])
            if step % args.log_every == 0:
                print(f"step {step:5d} loss {losses[-1]:.4f} "
                      f"({(time.time()-t0)/(step+1):.2f}s/step)")
            if (args.ckpt_every and args.ckpt_dir
                    and (step + 1) % args.ckpt_every == 0):
                save_checkpoint(args.ckpt_dir, step + 1, params)
    return params, losses


def _full_batch(cfg, batch, args):
    """Attach stub frontend inputs for VLM/audio archs."""
    out = dict(batch)
    b = batch["tokens"].shape[0]
    if cfg.frontend is not None and cfg.frontend.kind == "vision":
        out["image_embeds"] = jnp.zeros((b, cfg.frontend.seq, cfg.frontend.dim),
                                        cfg.param_dtype())
    if cfg.encoder is not None:
        out["frames"] = jnp.zeros((b, cfg.frontend.seq, cfg.frontend.dim),
                                  cfg.param_dtype())
    return out


def run_fedchain(cfg, args):
    """FedChain (Algo 1) over simulated client groups:
    local rounds (K steps each, per-client replicas) → selection → global."""
    from repro.launch.metrics import MetricsLogger

    key = jax.random.PRNGKey(args.seed)
    c = args.clients
    params0 = transformer.init_model(cfg, key)
    opt = get_optimizer(args.optimizer, args.lr)
    fl = fc.FedChainConfig(local_rounds=args.local_rounds,
                           local_steps=args.local_steps)

    stream = SyntheticTokenStream(TokenStreamConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq, batch_size=args.batch,
        num_clients=c, heterogeneity=args.heterogeneity, seed=args.seed))

    local_round = jax.jit(fc.make_local_round(cfg, opt, fl, n_clients=c))
    select = jax.jit(fc.make_selection_step(cfg))
    global_step = jax.jit(fc.make_global_step(cfg, opt))

    def client_batches(step0, steps):
        def stack(fn):
            return jnp.stack([jnp.stack([fn(ci, step0 + s) for ci in range(c)])
                              for s in range(steps)])

        toks = stack(lambda ci, s: _full_batch(cfg, stream.batch(ci, s), args)["tokens"])
        out = {"tokens": toks}
        b = toks.shape[-2]
        if cfg.frontend is not None and cfg.frontend.kind == "vision":
            out["image_embeds"] = jnp.zeros(
                (steps, c, b, cfg.frontend.seq, cfg.frontend.dim), cfg.param_dtype())
        if cfg.encoder is not None:
            out["frames"] = jnp.zeros(
                (steps, c, b, cfg.frontend.seq, cfg.frontend.dim), cfg.param_dtype())
        return out

    # ---- phase 1: A_local (FedAvg) ----------------------------------------
    client_p = fc.broadcast_to_clients(params0, c)
    client_o = jax.vmap(opt.init)(client_p)
    losses = []
    step0 = 0
    with MetricsLogger(args.metrics_path) as logger:
        for r in range(fl.local_rounds):
            batches = client_batches(step0, fl.local_steps)
            client_p, client_o, loss = local_round(client_p, client_o,
                                                   batches)
            step0 += fl.local_steps
            losses.append(float(loss))
            logger.log(step0, loss=losses[-1], phase=0.0, local_round=r)
            print(f"[local round {r}] loss {loss:.4f}")

        # ---- selection (Lemma H.2) ----------------------------------------
        probe = client_batches(step0, 1)
        probe = jax.tree.map(lambda t: t[0], probe)  # [C, b, ...]
        cand_a = fc.broadcast_to_clients(params0, c)
        chosen, picked_init, (la, lb) = select(cand_a, client_p, probe)
        print(f"[selection] F(x0)={float(la):.4f} F(x_half)={float(lb):.4f} "
              f"kept {'x0' if bool(picked_init) else 'x_half'}")

        # ---- phase 2: A_global (synchronous SGD) --------------------------
        params = jax.tree.map(lambda t: t[0], chosen)
        opt_state = opt.init(params)
        remaining = max(0, args.steps - fl.local_rounds * fl.local_steps)
        for step in range(remaining):
            batch = _full_batch(cfg, stream.batch(step % c, step0 + step),
                                args)
            params, opt_state, metrics = global_step(params, opt_state,
                                                     batch)
            losses.append(float(metrics["loss"]))
            logger.log(step0 + step, loss=losses[-1], phase=1.0)
            if step % args.log_every == 0:
                print(f"[global step {step}] loss {losses[-1]:.4f}")
    return params, losses


def main(argv=None):
    args = parse_args(argv)
    cfg = registry.get_config(args.arch, smoke=args.smoke)
    if args.smoke:
        cfg = dataclasses.replace(cfg, max_seq_len=max(args.seq * 2, 256))
    print(f"arch={cfg.name} params≈{model_zoo.param_count(cfg):,} "
          f"fl_mode={args.fl_mode}")
    if args.fl_mode == "fedchain":
        params, losses = run_fedchain(cfg, args)
    else:
        params, losses = run_plain(cfg, args)
    result = {"arch": cfg.name, "fl_mode": args.fl_mode,
              "first_loss": losses[0], "final_loss": losses[-1]}
    print(json.dumps(result))
    return result


if __name__ == "__main__":
    main()
