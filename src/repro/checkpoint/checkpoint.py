"""Numpy-based pytree checkpointing (no external deps).

Layout: <dir>/step_<N>/arrays.npz + tree.json (structure + dtypes).
Atomic via write-to-tmp + rename; ``keep`` rotates old checkpoints out.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile

import jax
import jax.numpy as jnp
import numpy as np


def _flatten_with_names(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    names, leaves = [], []
    for path, leaf in flat:
        parts = []
        for p in path:
            parts.append(str(getattr(p, "key", getattr(p, "idx", p))))
        names.append("/".join(parts))
        leaves.append(leaf)
    return names, leaves, treedef


def save_checkpoint(directory: str, step: int, tree, *, keep: int = 3) -> str:
    os.makedirs(directory, exist_ok=True)
    names, leaves, _ = _flatten_with_names(tree)

    def to_numpy(leaf):
        arr = np.asarray(jax.device_get(leaf))
        if arr.dtype.kind not in "fiub":  # bf16 (void kind) etc: store as f32
            arr = arr.astype(np.float32)
        return arr

    arrays = {f"a{i}": to_numpy(leaf) for i, leaf in enumerate(leaves)}
    meta = {
        "step": step,
        "names": names,
        "dtypes": [str(np.asarray(jax.device_get(l)).dtype) for l in leaves],
    }
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = tempfile.mkdtemp(dir=directory)
    try:
        np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
        with open(os.path.join(tmp, "tree.json"), "w") as f:
            json.dump(meta, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
    except Exception:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    # rotation
    steps = sorted(latest_steps(directory))
    for old in steps[:-keep]:
        shutil.rmtree(os.path.join(directory, f"step_{old:08d}"), ignore_errors=True)
    return final


def latest_steps(directory: str):
    if not os.path.isdir(directory):
        return []
    out = []
    for name in os.listdir(directory):
        if name.startswith("step_"):
            try:
                out.append(int(name[5:]))
            except ValueError:
                pass
    return sorted(out)


def latest_step(directory: str):
    steps = latest_steps(directory)
    return steps[-1] if steps else None


def load_checkpoint(directory: str, step: int):
    """Returns (names, arrays) — raw contents."""
    d = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(d, "tree.json")) as f:
        meta = json.load(f)
    data = np.load(os.path.join(d, "arrays.npz"))
    arrays = [data[f"a{i}"] for i in range(len(meta["names"]))]
    return meta, arrays


def restore(directory: str, step: int, template):
    """Restore into the structure of ``template`` (shapes must match)."""
    meta, arrays = load_checkpoint(directory, step)
    names, leaves, treedef = _flatten_with_names(template)
    by_name = dict(zip(meta["names"], arrays))
    new_leaves = []
    for name, leaf in zip(names, leaves):
        if name not in by_name:
            raise KeyError(f"checkpoint missing leaf {name!r}")
        arr = by_name[name]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"shape mismatch for {name}: {arr.shape} vs {leaf.shape}")
        new_leaves.append(jnp.asarray(arr).astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, new_leaves)
