from repro.checkpoint.checkpoint import (
    latest_step, latest_steps, load_checkpoint, restore, save_checkpoint,
)

__all__ = ["latest_step", "latest_steps", "load_checkpoint", "restore",
           "save_checkpoint"]
