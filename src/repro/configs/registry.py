"""Architecture registry: ``--arch <id>`` resolution for launchers/tests."""
from __future__ import annotations

import importlib

_MODULES = {
    "zamba2-1.2b": "repro.configs.zamba2_1p2b",
    "seamless-m4t-medium": "repro.configs.seamless_m4t_medium",
    "deepseek-v3-671b": "repro.configs.deepseek_v3_671b",
    "mamba2-1.3b": "repro.configs.mamba2_1p3b",
    "paligemma-3b": "repro.configs.paligemma_3b",
    "gemma3-4b": "repro.configs.gemma3_4b",
    "qwen3-14b": "repro.configs.qwen3_14b",
    "yi-34b": "repro.configs.yi_34b",
    "arctic-480b": "repro.configs.arctic_480b",
    "minicpm3-4b": "repro.configs.minicpm3_4b",
}

ARCH_IDS = tuple(_MODULES)


def get_config(arch_id: str, *, smoke: bool = False):
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(_MODULES)}")
    mod = importlib.import_module(_MODULES[arch_id])
    return mod.smoke_config() if smoke else mod.config()


def all_configs(*, smoke: bool = False):
    return {a: get_config(a, smoke=smoke) for a in ARCH_IDS}
