"""zamba2-1.2b — hybrid Mamba2 backbone + shared attention blocks.

[arXiv:2411.15242] 38 layers, d_model=2048, shared attn 32H (GQA kv=32,
head_dim 64) + d_ff=8192 MLP, vocab=32000, ssm_state=64. The single shared
transformer block is re-applied every ``period`` Mamba2 layers with the SAME
weights (Zamba's parameter-sharing trick).
"""
from repro.configs.base import (
    AttentionConfig, HybridConfig, ModelConfig, SSMConfig, reduced,
)

ARCH_ID = "zamba2-1.2b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        arch_type="hybrid",
        num_layers=38,
        d_model=2048,
        d_ff=8192,
        vocab_size=32000,
        ssm=SSMConfig(state_dim=64, head_dim=64, expand=2, chunk=128),
        hybrid=HybridConfig(
            period=6,
            shared_attn=AttentionConfig(num_heads=32, num_kv_heads=32, head_dim=64),
            shared_d_ff=8192,
        ),
        subquadratic=True,  # SSM backbone; shared-attn decode is O(1)/token compute
        source="arXiv:2411.15242",
    )


def smoke_config() -> ModelConfig:
    return reduced(config())
