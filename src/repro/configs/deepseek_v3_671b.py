"""deepseek-v3-671b — MoE with multi-head latent attention (MLA) and MTP.

[arXiv:2412.19437] 61 layers, d_model=7168, 128 heads (MLA), per-expert
d_ff=2048, vocab=129280; MoE = 1 shared + 256 routed experts, top-8; the first
3 layers are dense (d_ff=18432 per the model card); multi-token-prediction
(MTP) head. MLA dims per the model card: q_lora=1536, kv_lora=512,
nope_head=128, rope_head=64, v_head=128.
"""
from repro.configs.base import MLAConfig, MoEConfig, ModelConfig, reduced

ARCH_ID = "deepseek-v3-671b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        arch_type="moe",
        num_layers=61,
        d_model=7168,
        d_ff=2048,  # per-expert FF dim (assignment spec)
        dense_d_ff=18432,  # the 3 dense layers (model card)
        vocab_size=129280,
        mla=MLAConfig(
            num_heads=128,
            q_lora_rank=1536,
            kv_lora_rank=512,
            nope_head_dim=128,
            rope_head_dim=64,
            v_head_dim=128,
        ),
        moe=MoEConfig(
            num_experts=256,
            top_k=8,
            d_expert=2048,
            num_shared_experts=1,
            first_dense_layers=3,
            capacity_factor=1.0,
        ),
        mtp=True,
        tie_embeddings=False,
        source="arXiv:2412.19437",
    )


def smoke_config() -> ModelConfig:
    return reduced(config())
