"""seamless-m4t-medium — audio encoder-decoder transformer backbone.

[arXiv:2308.11596] 12 layers (encoder + decoder), d_model=1024, 16H (GQA
kv=16, head_dim 64), d_ff=4096, vocab=256206. The mel-spectrogram + conformer
feature frontend is a STUB per the assignment: ``input_specs`` provides
precomputed frame embeddings [B, frames, 1024].
"""
from repro.configs.base import (
    AttentionConfig, EncoderConfig, FrontendConfig, ModelConfig, reduced,
)

ARCH_ID = "seamless-m4t-medium"


def config() -> ModelConfig:
    attn = AttentionConfig(num_heads=16, num_kv_heads=16, head_dim=64)
    return ModelConfig(
        name=ARCH_ID,
        arch_type="audio",
        num_layers=12,  # decoder layers (self + cross attention)
        d_model=1024,
        d_ff=4096,
        vocab_size=256206,
        attention=attn,
        encoder=EncoderConfig(num_layers=12, attention=attn, d_ff=4096),
        frontend=FrontendConfig(kind="audio", seq=1024, dim=1024),
        tie_embeddings=True,
        source="arXiv:2308.11596",
    )


def smoke_config() -> ModelConfig:
    return reduced(config())
