"""minicpm3-4b — dense LM with multi-head latent attention (MLA).

[hf:openbmb/MiniCPM3-4B] 62 layers, d_model=2560, 40 heads (MLA), d_ff=6400,
vocab=73448. MLA dims per the model card: q_lora=768, kv_lora=256,
nope_head=64, rope_head=32, v_head=64.
"""
from repro.configs.base import MLAConfig, ModelConfig, reduced

ARCH_ID = "minicpm3-4b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        arch_type="dense",
        num_layers=62,
        d_model=2560,
        d_ff=6400,
        vocab_size=73448,
        mla=MLAConfig(
            num_heads=40,
            q_lora_rank=768,
            kv_lora_rank=256,
            nope_head_dim=64,
            rope_head_dim=32,
            v_head_dim=64,
        ),
        source="hf:openbmb/MiniCPM3-4B",
    )


def smoke_config() -> ModelConfig:
    return reduced(config())
