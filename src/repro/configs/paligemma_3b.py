"""paligemma-3b — VLM: SigLIP vision encoder (STUB) + Gemma-2B language model.

[arXiv:2407.07726] LM backbone: 18 layers, d_model=2048, 8 heads (MQA,
kv=1, head_dim 256), d_ff=16384 (GeGLU), vocab=257216. The SigLIP encoder +
projector is a STUB per the assignment: ``input_specs`` provides precomputed
patch embeddings [B, 256, 1152]; the image prefix attends bidirectionally
(prefix-LM mask), text is causal.
"""
from repro.configs.base import AttentionConfig, FrontendConfig, ModelConfig, reduced

ARCH_ID = "paligemma-3b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        arch_type="vlm",
        num_layers=18,
        d_model=2048,
        d_ff=16384,
        vocab_size=257216,
        attention=AttentionConfig(num_heads=8, num_kv_heads=1, head_dim=256),
        frontend=FrontendConfig(kind="vision", seq=256, dim=1152, prefix_bidirectional=True),
        act="gelu",
        source="arXiv:2407.07726",
    )


def smoke_config() -> ModelConfig:
    return reduced(config())
