"""Model/config schema for the architecture zoo.

One ``ModelConfig`` describes any of the 10 assigned architectures; layers are
grouped into structurally-homogeneous *segments* that the model code scans
over (compile-time stays O(1) in depth). Per-layer differences that are
metadata-only (sliding-window vs global attention, rope theta) ride along the
scan as stacked per-layer arrays.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class AttentionConfig:
    num_heads: int
    num_kv_heads: int
    head_dim: int
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    rope_theta_local: float = 10_000.0  # gemma3 uses a different theta for local layers
    sliding_window: int = 0  # 0 => always global
    local_global_period: int = 0  # gemma3: 6 => 5 local + 1 global per period
    softmax_scale: Optional[float] = None


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    """Multi-head latent attention (DeepSeek-V3 / MiniCPM3)."""

    num_heads: int
    q_lora_rank: int
    kv_lora_rank: int
    nope_head_dim: int
    rope_head_dim: int
    v_head_dim: int
    rope_theta: float = 10_000.0
    absorb_decode: bool = False  # matmul-absorbed decode (perf variant, §Perf)


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_expert: int
    num_shared_experts: int = 0  # deepseek: 1 shared expert
    dense_residual_d_ff: int = 0  # arctic: parallel dense MLP
    first_dense_layers: int = 0  # deepseek: first 3 layers are dense
    capacity_factor: float = 1.0
    aux_loss_weight: float = 0.001
    router_dtype: str = "float32"


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    """Mamba2 / SSD."""

    state_dim: int  # N
    head_dim: int = 64  # P
    expand: int = 2
    conv_width: int = 4
    chunk: int = 64
    num_groups: int = 1  # B/C groups

    def num_heads(self, d_model: int) -> int:
        return self.expand * d_model // self.head_dim


@dataclasses.dataclass(frozen=True)
class HybridConfig:
    """Zamba2: Mamba2 backbone with a single SHARED attention block applied
    every ``period`` layers (weights reused at every application)."""

    period: int = 6
    shared_attn: Optional[AttentionConfig] = None
    shared_d_ff: int = 0


@dataclasses.dataclass(frozen=True)
class EncoderConfig:
    """Seamless-style encoder for enc-dec models (consumes frontend embeds)."""

    num_layers: int
    attention: AttentionConfig = None
    d_ff: int = 0


@dataclasses.dataclass(frozen=True)
class FrontendConfig:
    """Modality frontend STUB: input_specs provide precomputed embeddings of
    shape [B, seq, dim] (per the assignment's carve-out for audio/vision)."""

    kind: str  # "audio" | "vision"
    seq: int
    dim: int
    prefix_bidirectional: bool = False  # paligemma prefix-LM mask over image tokens


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str  # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    d_ff: int
    vocab_size: int
    attention: Optional[AttentionConfig] = None
    mla: Optional[MLAConfig] = None
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    hybrid: Optional[HybridConfig] = None
    encoder: Optional[EncoderConfig] = None
    frontend: Optional[FrontendConfig] = None
    tie_embeddings: bool = True
    act: str = "silu"
    norm_eps: float = 1e-6
    dense_d_ff: int = 0  # d_ff of the first_dense_layers (deepseek)
    mtp: bool = False  # deepseek multi-token-prediction head
    dtype: str = "bfloat16"
    remat: bool = True
    scan_layers: bool = True
    max_seq_len: int = 131_072
    subquadratic: bool = False  # eligible for long_500k decode
    source: str = ""  # citation

    # ------------------------------------------------------------------
    def block_kinds(self) -> Tuple[Tuple[str, int], ...]:
        """Consecutive (kind, count) segments of structurally-identical layers."""
        if self.arch_type in ("ssm",):
            return (("mamba", self.num_layers),)
        if self.arch_type == "hybrid":
            return (("mamba_hybrid", self.num_layers),)
        if self.moe is not None and self.moe.first_dense_layers > 0:
            return (
                ("attn_dense", self.moe.first_dense_layers),
                ("attn_moe", self.num_layers - self.moe.first_dense_layers),
            )
        if self.moe is not None:
            return (("attn_moe", self.num_layers),)
        return (("attn_dense", self.num_layers),)

    def param_dtype(self):
        import jax.numpy as jnp

        return {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[self.dtype]


# ---------------------------------------------------------------------------
# Input shapes assigned to this paper
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


def reduced(cfg: ModelConfig, **overrides) -> ModelConfig:
    """A smoke-test variant of the same family: ≤2 layers, d_model ≤ 512,
    ≤4 experts, small vocab — runs a forward/train step on CPU."""
    small = dict(
        num_layers=2,
        d_model=min(cfg.d_model, 128),
        d_ff=min(cfg.d_ff, 256),
        vocab_size=min(cfg.vocab_size, 512),
        dtype="float32",
        remat=False,
        max_seq_len=512,
    )
    if cfg.attention is not None:
        small["attention"] = dataclasses.replace(
            cfg.attention,
            num_heads=min(cfg.attention.num_heads, 4),
            num_kv_heads=min(cfg.attention.num_kv_heads, min(cfg.attention.num_heads, 4)),
            head_dim=min(cfg.attention.head_dim, 32),
            sliding_window=min(cfg.attention.sliding_window, 64) if cfg.attention.sliding_window else 0,
            local_global_period=min(cfg.attention.local_global_period, 2) if cfg.attention.local_global_period else 0,
        )
    if cfg.mla is not None:
        small["mla"] = dataclasses.replace(
            cfg.mla, num_heads=4, q_lora_rank=32, kv_lora_rank=32,
            nope_head_dim=16, rope_head_dim=8, v_head_dim=16,
        )
    if cfg.moe is not None:
        small["moe"] = dataclasses.replace(
            cfg.moe,
            num_experts=min(cfg.moe.num_experts, 4),
            top_k=min(cfg.moe.top_k, 2),
            d_expert=min(cfg.moe.d_expert, 128),
            first_dense_layers=min(cfg.moe.first_dense_layers, 1),
            dense_residual_d_ff=min(cfg.moe.dense_residual_d_ff, 128) if cfg.moe.dense_residual_d_ff else 0,
        )
    if cfg.ssm is not None:
        small["ssm"] = dataclasses.replace(cfg.ssm, state_dim=min(cfg.ssm.state_dim, 16), head_dim=32, chunk=16)
    if cfg.hybrid is not None:
        sa = cfg.hybrid.shared_attn
        small["hybrid"] = dataclasses.replace(
            cfg.hybrid, period=2,
            shared_attn=dataclasses.replace(sa, num_heads=4, num_kv_heads=4, head_dim=32) if sa else None,
            shared_d_ff=min(cfg.hybrid.shared_d_ff, 128) if cfg.hybrid.shared_d_ff else 0,
        )
    if cfg.encoder is not None:
        small["encoder"] = dataclasses.replace(
            cfg.encoder, num_layers=2,
            attention=dataclasses.replace(
                cfg.encoder.attention, num_heads=4, num_kv_heads=4, head_dim=32
            ),
            d_ff=min(cfg.encoder.d_ff, 256),
        )
    if cfg.frontend is not None:
        small["frontend"] = dataclasses.replace(cfg.frontend, seq=min(cfg.frontend.seq, 16), dim=64)
    small.update(overrides)
    return dataclasses.replace(cfg, **small)
