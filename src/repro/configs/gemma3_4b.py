"""gemma3-4b — dense LM with 5:1 local(sliding-window):global attention, 128k.

[hf:google/gemma-3-1b-pt family] 34 layers, d_model=2560, 8 heads (GQA kv=4,
head_dim 256), d_ff=10240, vocab=262144. Every 6th layer is global
(rope theta 1M); local layers use a 1024-token sliding window (theta 10k).
QK-norm per the Gemma-3 card. The sliding-window variant makes this dense
arch eligible for the long_500k decode shape.
"""
from repro.configs.base import AttentionConfig, ModelConfig, reduced

ARCH_ID = "gemma3-4b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        arch_type="dense",
        num_layers=34,
        d_model=2560,
        d_ff=10240,
        vocab_size=262144,
        attention=AttentionConfig(
            num_heads=8,
            num_kv_heads=4,
            head_dim=256,
            qk_norm=True,
            sliding_window=1024,
            local_global_period=6,  # 5 local : 1 global
            rope_theta=1_000_000.0,
            rope_theta_local=10_000.0,
        ),
        act="gelu",
        subquadratic=True,  # sliding-window local layers (global layers decode O(S) reads)
        source="hf:google/gemma-3-1b-pt",
    )


def smoke_config() -> ModelConfig:
    return reduced(config())
