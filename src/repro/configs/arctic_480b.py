"""arctic-480b — dense-MoE hybrid: 128-expert top-2 MoE + parallel dense
residual MLP.

[hf:Snowflake/snowflake-arctic-base] 35 layers, d_model=7168, 56 heads (GQA
kv=8, head_dim 128), d_ff=4864, vocab=32000; MoE 128e top-2 with a dense
residual MLP in parallel on every layer.
"""
from repro.configs.base import AttentionConfig, MoEConfig, ModelConfig, reduced

ARCH_ID = "arctic-480b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        arch_type="moe",
        num_layers=35,
        d_model=7168,
        d_ff=4864,
        vocab_size=32000,
        attention=AttentionConfig(num_heads=56, num_kv_heads=8, head_dim=128),
        moe=MoEConfig(
            num_experts=128,
            top_k=2,
            d_expert=4864,
            dense_residual_d_ff=4864,
            capacity_factor=1.25,
        ),
        tie_embeddings=False,
        source="hf:Snowflake/snowflake-arctic-base",
    )


def smoke_config() -> ModelConfig:
    return reduced(config())
