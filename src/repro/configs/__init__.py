from repro.configs.base import (
    AttentionConfig, EncoderConfig, FrontendConfig, HybridConfig, INPUT_SHAPES,
    InputShape, MLAConfig, MoEConfig, ModelConfig, SSMConfig, reduced,
)
from repro.configs.registry import ARCH_IDS, all_configs, get_config

__all__ = [
    "AttentionConfig", "EncoderConfig", "FrontendConfig", "HybridConfig",
    "INPUT_SHAPES", "InputShape", "MLAConfig", "MoEConfig", "ModelConfig",
    "SSMConfig", "reduced", "ARCH_IDS", "all_configs", "get_config",
]
