"""mamba2-1.3b — pure SSM (attention-free), SSD state-space duality.

[arXiv:2405.21060] 48 layers, d_model=2048, no attention (d_ff=0 — Mamba2
blocks contain their own gated expansion), vocab=50280, ssm_state=128.
"""
from repro.configs.base import ModelConfig, SSMConfig, reduced

ARCH_ID = "mamba2-1.3b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        arch_type="ssm",
        num_layers=48,
        d_model=2048,
        d_ff=0,
        vocab_size=50280,
        ssm=SSMConfig(state_dim=128, head_dim=64, expand=2, chunk=128),
        subquadratic=True,
        source="arXiv:2405.21060",
    )


def smoke_config() -> ModelConfig:
    return reduced(config())
