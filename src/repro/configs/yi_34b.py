"""yi-34b — llama-architecture dense GQA.

[arXiv:2403.04652] 60 layers, d_model=7168, 56 heads (GQA kv=8, head_dim 128),
d_ff=20480, vocab=64000.
"""
from repro.configs.base import AttentionConfig, ModelConfig, reduced

ARCH_ID = "yi-34b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        arch_type="dense",
        num_layers=60,
        d_model=7168,
        d_ff=20480,
        vocab_size=64000,
        attention=AttentionConfig(num_heads=56, num_kv_heads=8, head_dim=128),
        tie_embeddings=False,
        source="arXiv:2403.04652",
    )


def smoke_config() -> ModelConfig:
    return reduced(config())
