"""qwen3-14b — dense GQA with QK-norm.

[hf:Qwen/Qwen3-8B family] 40 layers, d_model=5120, 40 heads (GQA kv=8,
head_dim 128), d_ff=17408, vocab=151936, qk_norm.
"""
from repro.configs.base import AttentionConfig, ModelConfig, reduced

ARCH_ID = "qwen3-14b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        arch_type="dense",
        num_layers=40,
        d_model=5120,
        d_ff=17408,
        vocab_size=151936,
        attention=AttentionConfig(
            num_heads=40, num_kv_heads=8, head_dim=128, qk_norm=True,
            rope_theta=1_000_000.0,
        ),
        tie_embeddings=False,
        source="hf:Qwen/Qwen3-8B",
    )


def smoke_config() -> ModelConfig:
    return reduced(config())
