"""Layer 2: the executor event log — a structured host-side JSONL recorder.

Everything here runs OUTSIDE the trace. The runner's executor cache and the
``_audit_wrap`` call layer forward events to the module-level ``RECORDER``
(installed via ``recording()`` / ``install``): one ``compile`` event per
top-level executor call that moved ``runner.TRACE_COUNTS`` (executor
family, trace tags, wall seconds, donation tuple, optionally jaxpr const
bytes), one ``cache`` event per executor-cache hit / miss / put / eviction,
one ``phase`` event per benchmark phase (``repro.obs.profile.phase``), and
``metric`` events carrying training-loop scalars (the
``launch.metrics.MetricsLogger`` schema, which is now a shim over this
recorder). ``python -m repro.obs report`` summarizes a log.

The ONE trace-time artifact in this module is ``TRACE_EVENTS``: a Counter
the executor bodies bump beside ``runner.TRACE_COUNTS`` when they (re)trace.
It is the registered obs event sink for traced code — the trace-discipline
analyzer (R2) whitelists bumps into it exactly like TRACE_COUNTS bumps, and
``observed_call`` turns its movement into host-side ``compile`` events after
the fact. No recorder I/O ever happens at trace time.
"""
from __future__ import annotations

import collections
import contextlib
import json
import os
import time
from collections import deque
from typing import Optional

# Trace-time event sink: executor bodies bump this beside TRACE_COUNTS when
# (re)traced. R2-whitelisted (see repro.analysis.lint.base.TRACE_WHITELIST).
TRACE_EVENTS: collections.Counter = collections.Counter()

# default event-log path (repo-root relative; uncommitted, see .gitignore)
DEFAULT_PATH = "obs_events.jsonl"


class EventRecorder:
    """JSONL event stream + rolling metric aggregates, context-managed.

    ``path=None`` keeps events in ``self.records`` only (tests); a path
    appends JSONL. ``const_bytes=True`` additionally re-traces each compiled
    executor on its recorded operands to log jaxpr const bytes (host
    backends only — donation must be a no-op for the operands to survive).
    """

    def __init__(self, path: Optional[str] = None, *, window: int = 50,
                 const_bytes: bool = False):
        self.path = path
        self.const_bytes = const_bytes
        self.records = []
        self._fh = None
        if path:
            os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
            self._fh = open(path, "a")
        self._win = {}
        self._window = window
        self._t0 = time.time()

    def event(self, kind: str, **fields) -> dict:
        rec = {"kind": kind, "t": round(time.time() - self._t0, 3), **fields}
        self.records.append(rec)
        if self._fh:
            self._fh.write(json.dumps(rec) + "\n")
            self._fh.flush()
        return rec

    def metric(self, step: int, **values) -> dict:
        """A training-loop metric event (the MetricsLogger schema plus a
        ``kind`` discriminator); floats also feed the rolling means."""
        floats = {}
        for k, v in values.items():
            v = float(v)
            floats[k] = v
            self._win.setdefault(k, deque(maxlen=self._window)).append(v)
        return self.event("metric", step=step, **floats)

    def mean(self, key: str) -> float:
        buf = self._win.get(key)
        return sum(buf) / len(buf) if buf else float("nan")

    def close(self):
        if self._fh:
            self._fh.close()
            self._fh = None

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False


# the installed recorder; ``emit`` is a no-op while this is None, so the
# executor hooks cost one None-check when nothing is recording
RECORDER: Optional[EventRecorder] = None


def install(recorder: EventRecorder) -> EventRecorder:
    global RECORDER
    RECORDER = recorder
    return recorder


def uninstall() -> None:
    global RECORDER
    RECORDER = None


@contextlib.contextmanager
def recording(path: Optional[str] = None, **kwargs):
    """Install a fresh ``EventRecorder`` for the block and close it after."""
    rec = EventRecorder(path, **kwargs)
    install(rec)
    try:
        yield rec
    finally:
        uninstall()
        rec.close()


def emit(kind: str, **fields) -> None:
    """Forward one event to the installed recorder (no-op when none is)."""
    if RECORDER is not None:
        RECORDER.event(kind, **fields)


def _key_repr(key, limit: int = 200) -> str:
    s = repr(key)
    return s if len(s) <= limit else s[:limit] + "..."


def _donate_of(key):
    """The named donate tuple threaded through an executor cache key (R4):
    the last all-int tuple element, or None for undonated executors."""
    if isinstance(key, tuple):
        for el in reversed(key):
            if (isinstance(el, tuple) and el
                    and all(isinstance(i, int) for i in el)):
                return list(el)
    return None


def observed_call(key, fn, args, kwargs):
    """Run one concrete top-level executor call under the recorder.

    Snapshots ``runner.TRACE_COUNTS`` and ``TRACE_EVENTS`` around the call;
    when either moved, the call paid a (re)trace and a ``compile`` event is
    emitted with the family, trace tags, wall seconds, and donation tuple
    (plus jaxpr const bytes when the recorder opted in).
    """
    from repro.core import runner

    before = dict(runner.TRACE_COUNTS)
    ev_before = dict(TRACE_EVENTS)
    t0 = time.perf_counter()
    out = fn(*args, **kwargs)
    wall = time.perf_counter() - t0
    deltas = runner.trace_deltas(before)
    ev_deltas = {k: v - ev_before.get(k, 0) for k, v in TRACE_EVENTS.items()
                 if v != ev_before.get(k, 0)}
    if deltas or ev_deltas:
        family = key[0] if isinstance(key, tuple) and key else str(key)
        fields = dict(
            family=family,
            cache_key=_key_repr(key),
            traces=sum(deltas.values()) or sum(ev_deltas.values()),
            trace_tags=sorted(set(deltas) | set(ev_deltas)),
            compile_s=round(wall, 6),
            donate=_donate_of(key),
        )
        if RECORDER is not None and RECORDER.const_bytes:
            try:
                from repro.analysis import jaxpr_audit

                fields["const_bytes"] = jaxpr_audit.audit_record(
                    fn, args, kwargs)["const_bytes"]
            except Exception as e:  # noqa: BLE001 — best-effort enrichment
                fields["const_bytes_error"] = f"{type(e).__name__}: {e}"
        emit("compile", **fields)
    return out
