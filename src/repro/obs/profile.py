"""Layer 3: profiled benchmarks — run manifests, ``jax.profiler`` trace
annotations, and a uniform compile-vs-warm phase capture.

``benchmarks/run.py --profile`` composes these: every harness runs inside
``annotate`` scopes (visible in a profiler trace when one is being
captured), each phase's wall seconds and ``TRACE_COUNTS`` movement land in
the obs event log as ``phase`` events, and ``write_manifest`` records the
run environment (backend, devices, XLA flags, config hash) next to every
``BENCH_*.json`` so benchmark numbers are attributable to a machine state.
All host-side; nothing here runs in a trace.
"""
from __future__ import annotations

import contextlib
import hashlib
import json
import os
import time

MANIFEST_PATH = "BENCH_manifest.json"


def run_manifest(extra: dict = None) -> dict:
    """The run environment a benchmark number depends on, as a flat dict
    with a stable ``config_hash`` over the sorted contents."""
    import jax

    manifest = {
        "backend": jax.default_backend(),
        "device_count": jax.device_count(),
        "devices": [str(d) for d in jax.devices()],
        "jax_version": jax.__version__,
        "xla_flags": os.environ.get("XLA_FLAGS", ""),
        "force_pallas": os.environ.get("REPRO_FORCE_PALLAS", ""),
    }
    if extra:
        manifest.update(extra)
    digest = hashlib.sha256(
        json.dumps(manifest, sort_keys=True).encode()).hexdigest()
    manifest["config_hash"] = digest[:16]
    return manifest


def write_manifest(path: str = MANIFEST_PATH, extra: dict = None) -> dict:
    manifest = run_manifest(extra)
    with open(path, "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
        f.write("\n")
    return manifest


@contextlib.contextmanager
def annotate(name: str):
    """A named ``jax.profiler`` trace annotation (no-op without profiler
    support) — harness phases show up as labeled spans in captured traces."""
    try:
        from jax.profiler import TraceAnnotation
    except ImportError:  # profiler not available on this build
        yield
        return
    with TraceAnnotation(name):
        yield


@contextlib.contextmanager
def phase(name: str):
    """Measure one benchmark phase: wall seconds + TRACE_COUNTS movement.

    Yields a dict filled at exit with ``seconds``, ``traces`` (total trace
    count the phase paid) and ``trace_tags``; the same summary is emitted as
    a ``phase`` event to the installed obs recorder. Wrapping a harness call
    twice — cold then warm — is the uniform compile-vs-warm breakdown
    ``benchmarks/run.py --profile`` reports: the cold phase carries the
    compiles, the warm phase must carry none.
    """
    from repro.core import runner
    from repro.obs import events

    info = {"name": name}
    before = dict(runner.TRACE_COUNTS)
    t0 = time.perf_counter()
    with annotate(name):
        yield info
    info["seconds"] = round(time.perf_counter() - t0, 6)
    deltas = runner.trace_deltas(before)
    info["traces"] = sum(deltas.values())
    info["trace_tags"] = sorted(deltas)
    events.emit("phase", **info)
