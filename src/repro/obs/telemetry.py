"""Layer 1: in-scan round taps — the ``Telemetry`` spec and tap computation.

A ``Telemetry`` instance is a STRUCTURAL executor-cache-key dimension,
exactly like the named donate tuples (rule R4): every executor body appends
it to its cache key, so runs with different tap sets compile distinct
executors, and ``telemetry=None`` (the default everywhere) leaves today's
cache keys, jaxprs, and outputs bitwise identical — the tap code is never
traced on the None path.

All taps are pure in-trace functions of values the round body already holds
(no host callbacks, no side effects — R1/R2-clean by construction) built on
the batch-invariant ``tree_math`` reductions, so the vmapped and sharded
engines emit bitwise-identical diagnostics. Each round contributes one
scalar per enabled tap; ``lax.scan`` stacks them into ``[R]`` leaves of the
``diagnostics`` dict riding beside the usual outputs (grid sweeps add the
cell axes in front, like ``history``).
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from repro.core import tree_math as tm


@dataclasses.dataclass(frozen=True)
class Telemetry:
    """Which per-round diagnostics the executors emit as extra scan outputs.

    Only ``grad_norm`` adds real work (one extra full gradient per round);
    every other tap is a cheap reduction of values the round already
    computed, which is what keeps the taps-on warm path inside the
    ``BENCH_obs.json`` overhead gate. A tap only appears in the diagnostics
    dict when the executor family actually has its input (e.g. no
    ``participation`` on the plain, comm-free runner), so the tap pytree
    structure is a pure function of (telemetry, executor family).
    """

    update_norm: bool = True     # ‖x_r − x_{r−1}‖ of the server iterate
    grad_norm: bool = False      # ‖∇F(x_eval)‖ — one extra global gradient
    residual_norms: bool = True  # EF residual norms on all three CommPlan legs
    participation: bool = True   # Σ mask — clients participating this round
    leg_bits: bool = True        # per-round uplink/downlink bits in the taps
    policy_summary: bool = True  # PolicyState summaries (selection executors)
    stage_index: bool = True     # active chain stage id (chain executors)


def round_taps(tel: Telemetry, *, problem=None, prev_x=None, new_x=None,
               x_eval=None, comm=None, mask=None, pstate=None,
               stage=None, bits_up=None, bits_down=None) -> dict:
    """One round's diagnostics dict (scalar leaves, in-trace only).

    Callers pass whatever their round body holds; disabled or unavailable
    taps are simply absent. The uplink and momentum CommPlan legs share the
    per-client residual tables (``CommState.residual`` — the momentum leg
    runs the same EF kernels on the same tables), so their norms coincide;
    both are emitted so the three legs are always individually named. With
    error feedback off the residual tables are ``[N, 0]`` and the norms are
    exactly 0.0 — no trace-time branching.
    """
    taps = {}
    if tel.update_norm and prev_x is not None:
        taps["update_norm"] = tm.tree_norm(tm.tree_sub(new_x, prev_x))
    if tel.grad_norm and problem is not None and x_eval is not None:
        taps["grad_norm"] = tm.tree_norm(problem.global_grad(x_eval))
    if tel.residual_norms and comm is not None:
        up_norm = tm.tree_norm(comm.residual)
        taps["residual_up_norm"] = up_norm
        taps["residual_mom_norm"] = up_norm
        taps["residual_down_norm"] = tm.tree_norm(comm.down_residual)
    if tel.participation and mask is not None:
        taps["participation"] = jnp.sum(mask)
    if tel.leg_bits and bits_up is not None:
        taps["bits_up"] = bits_up
        taps["bits_down"] = bits_down
    if tel.policy_summary and pstate is not None:
        taps["policy_t"] = pstate.t
        taps["policy_count_max"] = jnp.max(pstate.counts)
        taps["policy_value_mean"] = jnp.mean(pstate.values)
    if tel.stage_index and stage is not None:
        taps["stage"] = jnp.asarray(stage, jnp.int32)
    return taps
