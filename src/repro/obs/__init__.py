"""Run-telemetry subsystem: in-scan round taps, executor event log, and
profiled benchmarks.

The telemetry model, in one page
================================

**What is traced.** Layer 1 (``repro.obs.telemetry``) lives INSIDE the
compiled executors: a frozen ``Telemetry`` spec makes the
runner/chain/sweep/dist scan bodies emit a per-round diagnostics dict as
extra ``lax.scan`` outputs — update/gradient norms, the error-feedback
residual norms of all three ``CommPlan`` legs (uplink and momentum share
the per-client tables; downlink is the server-side residual), participation
counts, per-leg bits, policy-state summaries, and the active chain stage.
Every tap is a pure in-trace reduction of values the round body already
holds (batch-invariant ``tree_math`` ops — the vmapped and sharded engines
agree bitwise); there are no host callbacks and no trace-time side effects
beyond the whitelisted ``TRACE_EVENTS`` counter bump, so the taps are
R1/R2-clean by construction.

**Cache-key semantics.** ``Telemetry`` is a STRUCTURAL cache-key dimension,
like the named donate tuples: executor bodies append it to their cache key,
so a taps-on run compiles its own executor (exactly one extra compile per
family) and ``telemetry=None`` — the default on every entry point — reuses
today's keys and traces today's jaxprs, making the None path bitwise
identical to a build without this package. The taps-on warm path is gated
by ``BENCH_obs.json`` (≤1.15× the taps-off warm time, zero warm retraces)
in ``benchmarks/check_regression.py``.

**What is host-side.** Layer 2 (``repro.obs.events``) is a JSONL event
recorder hooked beside ``runner.AUDIT_SINK`` and the executor cache:
``compile`` events (family, trace tags, wall seconds, donation tuple,
optional jaxpr const bytes), ``cache`` hit/miss/put/evict events, benchmark
``phase`` events, and training ``metric`` events (the
``launch.metrics.MetricsLogger`` schema — that logger is now a shim over
this recorder). ``python -m repro.obs report`` summarizes a log. Layer 3
(``repro.obs.profile``) adds run manifests and ``jax.profiler`` annotations
for ``benchmarks/run.py --profile``. Both layers observe from the host and
never execute at trace time — a recorder can be installed or removed
without invalidating a single cached executor.
"""
from repro.obs.events import (
    EventRecorder, TRACE_EVENTS, emit, install, recording, uninstall,
)
from repro.obs.profile import annotate, phase, run_manifest, write_manifest
from repro.obs.telemetry import Telemetry, round_taps

__all__ = [
    "EventRecorder", "TRACE_EVENTS", "Telemetry", "annotate", "emit",
    "install", "phase", "recording", "round_taps", "run_manifest",
    "uninstall", "write_manifest",
]
