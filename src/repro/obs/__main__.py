"""``python -m repro.obs report [PATH]`` — summarize an event-log JSONL.

Prints, for one run's ``obs_events.jsonl``: event counts by kind, compiles
per executor family with total compile seconds, executor-cache
hit/miss/put/evict tallies, the benchmark phases with their trace counts,
and rolling means of the logged training metrics.
"""
from __future__ import annotations

import argparse
import collections
import json
import sys

from repro.obs import events as events_lib


def _load(path: str):
    with open(path) as f:
        return [json.loads(line) for line in f if line.strip()]


def report(records, out=sys.stdout) -> None:
    kinds = collections.Counter(r.get("kind", "?") for r in records)
    print(f"events: {sum(kinds.values())} "
          f"({', '.join(f'{k}={v}' for k, v in sorted(kinds.items()))})",
          file=out)

    compiles = [r for r in records if r.get("kind") == "compile"]
    if compiles:
        per_family = collections.defaultdict(lambda: [0, 0.0])
        for r in compiles:
            fam = per_family[r.get("family", "?")]
            fam[0] += int(r.get("traces", 1))
            fam[1] += float(r.get("compile_s", 0.0))
        print("compiles:", file=out)
        for name, (n, secs) in sorted(per_family.items()):
            print(f"  {name}: {n} trace(s), {secs:.3f}s", file=out)
        total = sum(f[1] for f in per_family.values())
        print(f"  total: {sum(f[0] for f in per_family.values())} trace(s), "
              f"{total:.3f}s", file=out)

    cache_ops = collections.Counter(
        r.get("op", "?") for r in records if r.get("kind") == "cache")
    if cache_ops:
        print("cache: " + ", ".join(
            f"{k}={v}" for k, v in sorted(cache_ops.items())), file=out)

    phases = [r for r in records if r.get("kind") == "phase"]
    if phases:
        print("phases:", file=out)
        for r in phases:
            print(f"  {r.get('name', '?')}: {r.get('seconds', 0.0):.3f}s, "
                  f"{r.get('traces', 0)} trace(s)", file=out)

    metrics = [r for r in records if r.get("kind") == "metric"]
    if metrics:
        sums = collections.defaultdict(lambda: [0, 0.0])
        for r in metrics:
            for k, v in r.items():
                if k in ("kind", "t", "step"):
                    continue
                if isinstance(v, (int, float)):
                    sums[k][0] += 1
                    sums[k][1] += float(v)
        print(f"metrics: {len(metrics)} record(s)", file=out)
        for k, (n, s) in sorted(sums.items()):
            print(f"  {k}: mean {s / n:.6g} over {n}", file=out)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.obs")
    sub = ap.add_subparsers(dest="cmd", required=True)
    rep = sub.add_parser("report", help="summarize an event-log JSONL")
    rep.add_argument("path", nargs="?", default=events_lib.DEFAULT_PATH)
    args = ap.parse_args(argv)
    try:
        records = _load(args.path)
    except OSError as e:
        print(f"cannot read {args.path}: {e}", file=sys.stderr)
        return 2
    report(records)
    return 0


if __name__ == "__main__":
    sys.exit(main())
