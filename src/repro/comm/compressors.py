"""Uplink compressors as one switchable, jit-stable operator.

``compress_rows`` applies the compressor selected by ``params.comp_id`` to a
batch of per-client vectors [S, D]. All four branches are traced into every
comm-enabled executor and selected at RUNTIME by a ``lax.switch``, so the
compressor choice (and its bit-width / sparsity knobs) is operand data — the
hook that keeps ``runner.TRACE_COUNTS`` flat across comm configs.

Branch semantics (all return the server-side dequantized reconstruction):

* identity — the input, bitwise (the branch body is ``lambda v: v``; this is
  what makes identity-compressor runs reproduce uncompressed trajectories
  bit-exactly).
* qsgd — unbiased stochastic quantization to L = 2^b − 1 levels per row
  (Alistarh et al. 2017), via the Pallas quantize/dequantize kernel.
* topk — keep the k largest-|v| coordinates per row (biased; pair with
  error feedback).
* randk — keep k uniformly random coordinates per row, scaled by d/k
  (unbiased).

k and b are traced scalars: top-k/rand-k use rank masks (``ranks < k``)
rather than dynamic slicing, so a sparsity grid reuses one compile.

``compress_tree`` is the pytree entry point: each leaf [S, ...] is flattened
to [S, d_leaf] rows at the kernel boundary, compressed independently (QSGD
norms, top-k ranks and rand-k subsets are PER LEAF), and unflattened. A
single-leaf pytree — the flat [D] theory problems — uses the caller's key
unsplit, so flat-path trajectories are bitwise identical to the pre-pytree
implementation; multi-leaf pytrees derive one independent key per leaf.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

COMP_IDENTITY = 0
COMP_QSGD = 1
COMP_TOPK = 2
COMP_RANDK = 3

COMP_IDS = {
    "identity": COMP_IDENTITY,
    "qsgd": COMP_QSGD,
    "topk": COMP_TOPK,
    "randk": COMP_RANDK,
}


class CommParams(NamedTuple):
    """Runtime compressor knobs — jnp scalars, never trace triggers."""

    comp_id: jnp.ndarray  # int32 ∈ COMP_IDS.values()
    qsgd_bits: jnp.ndarray  # float32, QSGD bit-width b (L = 2^b − 1)
    spars_k: jnp.ndarray  # int32, retained coords for top-k/rand-k


def _row_ranks(x):
    """Per-row ranks along axis 1: rank 0 = smallest (argsort of argsort)."""
    order = jnp.argsort(x, axis=1)
    return jnp.argsort(order, axis=1)


def compress_rows(vec, key, params: CommParams):
    """Quantize→dequantize each row of ``vec`` [S, D].

    ``key`` drives the stochastic branches (QSGD rounding / rand-k subset);
    the uniforms are drawn INSIDE those branches, so deterministic
    compressors (identity, top-k) never pay for the [S, D] sample.
    """
    d = vec.shape[1]

    def _identity(v, _):
        return v

    def _qsgd(v, k):
        from repro.kernels.compress import ops as compress_ops

        u = jax.random.uniform(k, v.shape, jnp.float32)
        norms = jnp.linalg.norm(v.astype(jnp.float32), axis=1)
        levels = jnp.maximum(2.0 ** params.qsgd_bits - 1.0, 1.0)
        return compress_ops.qsgd_dequantize(v, u, norms, levels)

    def _topk(v, _):
        ranks = _row_ranks(-jnp.abs(v))
        return v * (ranks < params.spars_k).astype(v.dtype)

    def _randk(v, k):
        u = jax.random.uniform(k, v.shape, jnp.float32)
        ranks = _row_ranks(u)
        keep = (ranks < params.spars_k).astype(v.dtype)
        scale = jnp.float32(d) / jnp.maximum(params.spars_k.astype(jnp.float32), 1.0)
        return v * keep * scale.astype(v.dtype)

    return jax.lax.switch(
        params.comp_id, [_identity, _qsgd, _topk, _randk], vec, key)


def compress_tree(tree, key, params: CommParams):
    """Leaf-wise quantize→dequantize of a pytree of per-client rows.

    Every leaf is [S, ...] (row i = one client's slice); each is raveled to
    [S, d_leaf] at the kernel boundary (``tree_math.tree_ravel_rows``),
    pushed through ``compress_rows`` and unraveled back. Keys: the caller's
    key verbatim for a single leaf (flat-path bit-exactness),
    ``split(key, n_leaves)`` otherwise.
    """
    from repro.core import tree_math as tm

    rows, treedef = jax.tree.flatten(tm.tree_ravel_rows(tree))
    keys = [key] if len(rows) == 1 else list(
        jax.random.split(key, len(rows)))
    comp = jax.tree.unflatten(
        treedef, [compress_rows(x, k, params) for x, k in zip(rows, keys)])
    return tm.tree_unravel_rows(comp, tree)
