"""Communication subsystem: compressed uplinks AND downlinks, partial
participation, and exact bits accounting for the FedChain experiment
harnesses.

The paper's objective is *communication* cost, but rounds R are only a proxy
for it — this package makes cost first-class, so every sweep can report
suboptimality-vs-bits instead of suboptimality-vs-rounds.

Design: comm config is DATA, not a trace trigger
------------------------------------------------
All comm behavior threads through the single-compile executors
(``core.runner``/``core.chain``) as runtime operands:

* the compressor choice PER LEG (uplink / downlink / momentum uplink — a
  ``CommPlan`` is one ``Leg`` per wire direction) is an integer ``comp_id``
  selecting a branch of one ``lax.switch`` (every branch is traced once;
  only the selected one runs),
* QSGD bit-width and top-k/rand-k sparsity ``k`` are traced scalars,
* partial participation is a precomputed per-round client-mask schedule
  ``[R, N]`` fed to the ``lax.scan`` alongside the PRNG keys,
* the downlink error-feedback state (``down_ref``/``down_residual``, one
  params-sized pytree each) is carried unconditionally,

so changing participation fraction, any leg's compressor, or bit-width
never recompiles an executor (``runner.TRACE_COUNTS`` stays flat). The only
trace-time comm choice is *enabling* uplink/momentum error feedback, which
changes the state structure (the per-client residual table goes from
``[N, 0]`` to ``[N, D]``).

Compression is simulated as a quantize→dequantize round trip: algorithms see
the server-side reconstruction of each client's uplink, while the bits that
WOULD have crossed the wire are accounted in closed form.

Parameters are arbitrary pytrees, handled LEAF-WISE: every operator ravels
each leaf [S, ...] to [S, d_leaf] rows at the kernel boundary (compress
switch, error-feedback residual tables, masked Pallas aggregation) and
unravels after, so the flat [D] theory problems — the single-leaf case —
stay bitwise identical to the pre-pytree implementation while vision MLPs
(``data.vision_problem``) ride the same compiled executors.

Bits-accounting model (leaf-wise)
---------------------------------
Let d₁…d_L be the per-leaf parameter dims (one entry, d, for flat vectors),
S_r = Σ mask_r the number of participating clients in round r, and
⌈log₂d_l⌉ the per-leaf index width. Per participating client and uplinked
parameter pytree, bits are the SUM over leaves of the per-leaf closed form:

* identity:  ``Σ_l 32·d_l``                  (full-precision float32)
* QSGD(b):   ``Σ_l 32 + d_l·(b+1)``          (one ℓ₂ norm per LEAF + sign and
                                              b-bit level per coordinate —
                                              quantization is leaf-wise)
* top-k/rand-k: ``Σ_l k·(32 + ⌈log₂ d_l⌉)``  (k coordinates retained per
                                              LEAF, float32 value + index
                                              each)

Downlinks bill the SAME per-leaf closed forms, evaluated at the DOWNLINK
leg's params (the wire format is direction-symmetric): an identity downlink
leg reduces exactly to the full-precision ``32·Σ_l d_l`` per broadcast
pytree per participant (SCAFFOLD broadcasts x and the server variate: 2
pytrees; SSNM broadcasts x and the snapshot point). Compressed-momentum
uplinks (ASG's lookahead gradients, SSNM's sampled-negative-momentum and
snapshot gradients) bill the uplink closed forms at the MOMENTUM leg's
params — e.g. a QSGD(b) momentum leg ships ``Σ_l 32 + d_l·(b+1)`` bits per
accelerated gradient instead of ``Σ_l 32·d_l``. A Lemma H.2 selection round
stays full-precision: ``2·32·Σ_l d_l`` down and ``2·32`` up per sampled
client (both candidates broadcast; one scalar empirical value returned
each).
``CommState.bits_up``/``bits_down`` meter ONE round at a time (executors
zero them each scan step and emit them as the per-round [R] meters);
cumulative totals are summed in float64 outside the scan
(``SweepResult.cumulative_bits``), so the accounting stays exact instead of
saturating a float32 running sum.
"""
from repro.comm.compressors import (
    COMP_IDENTITY,
    COMP_QSGD,
    COMP_RANDK,
    COMP_TOPK,
    CommParams,
    compress_rows,
    compress_tree,
)
from repro.comm.config import (
    CommConfig,
    CommPlan,
    CommState,
    Leg,
    account_round,
    comm_key,
    downlink,
    downlink_bits_per_client,
    downlink_key,
    downlink_second,
    ef_enabled,
    leaf_dims,
    masked_keep,
    momentum_uplink_key,
    participation_scale,
    second_downlink_key,
    second_uplink_key,
    selection_round_bits,
    total_dim,
    uplink,
    uplink_bits_per_client,
    uplink_bits_per_client_tree,
    uplink_fused_apply,
)

__all__ = [
    "COMP_IDENTITY", "COMP_QSGD", "COMP_TOPK", "COMP_RANDK",
    "CommParams", "CommConfig", "CommPlan", "Leg", "CommState",
    "compress_rows", "compress_tree", "uplink", "uplink_fused_apply",
    "downlink", "downlink_second",
    "account_round", "comm_key", "second_uplink_key",
    "downlink_key", "second_downlink_key", "momentum_uplink_key",
    "participation_scale", "masked_keep", "ef_enabled",
    "leaf_dims", "total_dim",
    "uplink_bits_per_client", "uplink_bits_per_client_tree",
    "downlink_bits_per_client", "selection_round_bits",
]
