"""Comm configuration, per-run state, and the comm operators for BOTH wire
directions.

``CommPlan`` is the user-facing static description: one ``Leg`` per wire
direction (uplink, downlink, and the momentum uplink ASG/SSNM ship their
accelerated gradients on). Everything a plan produces for the executors —
``CommParams`` scalars per leg, the per-round participation mask schedule,
the ``CommState`` carried in algorithm state — is runtime data, so swapping
any compressor on any leg at fixed shapes re-uses the compiled executor.
``CommConfig`` survives as a deprecation shim constructing an uplink-only
plan, bitwise identical to the pre-plan behaviour. See the package docstring
for the bits model.
"""
from __future__ import annotations

import dataclasses
import math
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.comm import compressors
from repro.comm.compressors import COMP_IDENTITY, COMP_QSGD, CommParams
from repro.core import tree_math as tm

# fold_in tag deriving the comm PRNG stream from a round key WITHOUT
# disturbing the key splits the algorithms already perform (bit-exactness of
# identity-compressor runs depends on this).
_COMM_KEY_TAG = 0x636D
# second-uplink stream tag (see second_uplink_key); registered in
# repro.analysis.REGISTERED_KEY_TAGS
_SECOND_UPLINK_TAG = 1
# downlink-EF broadcast stream tag (see downlink_key); registered in
# repro.analysis.REGISTERED_KEY_TAGS
_DOWNLINK_KEY_TAG = 2
# compressed-momentum uplink stream tag (see momentum_uplink_key);
# registered in repro.analysis.REGISTERED_KEY_TAGS
_MOMENTUM_UPLINK_TAG = 3


class CommState(NamedTuple):
    """The optional ``comm`` leaf of the uniform state protocol.

    All fields are arrays or pytrees of arrays (operand data). ``mask`` is
    the CURRENT round's participation mask — the executor overwrites it each
    scan step from the precomputed schedule. ``residual`` is the per-client
    error-feedback table, mirroring the PARAMETER pytree leaf-for-leaf with a
    leading client axis: a ``[N, D]`` array for flat params, a pytree of
    ``[N, *leaf.shape]`` tables for pytree params (vision MLPs), and a single
    empty ``[N, 0]`` array when EF is off (residual element count is the
    trace-time EF flag — see ``ef_enabled``).

    ``params``/``down``/``mom`` are the three legs' compressor scalars —
    pure operand data, so a full compressor swap on any leg re-traces
    nothing. ``down_ref`` is the last broadcast reconstruction (what every
    client currently holds) and ``down_residual`` the SERVER-side
    bidirectional error-feedback residual; both mirror the parameter pytree
    (one copy, not per-client — the broadcast is common) and are carried
    unconditionally so enabling downlink compression is an operand change,
    not a shape change. Under an identity downlink leg both are exact:
    ``down_ref`` equals the last payload bitwise and ``down_residual`` is
    exactly zero.

    ``bits_up``/``bits_down`` meter the CURRENT round only: executors zero
    them at round start, ``account_round`` (and the chain's selection
    billing) add within the round, and the executor emits the totals as the
    per-round [R] meters. Keeping the in-scan meters per-round (a few 1e8
    bits at most, exact in float32 for the 32-bit-granular counts) instead
    of cumulative is what keeps the accounting exact — cumulative sums are
    taken in float64 OUTSIDE the scan (``SweepResult.cumulative_bits``).
    """

    params: CommParams  # uplink leg compressor scalars
    mask: jnp.ndarray  # [N] float32 ∈ {0, 1}
    residual: object  # params-shaped pytree of [N, ...] tables, or [N, 0]
    bits_up: jnp.ndarray  # float32 scalar, THIS round's uplink bits
    bits_down: jnp.ndarray  # float32 scalar, THIS round's downlink bits
    down: CommParams  # downlink leg compressor scalars
    mom: CommParams  # momentum-uplink leg compressor scalars
    down_ref: object  # params-shaped pytree: last broadcast reconstruction
    down_residual: object  # params-shaped pytree: server-side EF residual


def zero_round_bits(comm: CommState) -> CommState:
    """Reset the per-round meters (executors call this at round start)."""
    return comm._replace(bits_up=jnp.zeros_like(comm.bits_up),
                         bits_down=jnp.zeros_like(comm.bits_down))


def ef_enabled(comm: CommState) -> bool:
    """Trace-time error-feedback flag for the UPLINK residual tables,
    encoded in their shapes (an EF-off state carries one empty [N, 0]
    table; shapes are static). The downlink residual is always carried."""
    return tm.tree_size(comm.residual) > 0


def leaf_dims(x) -> tuple:
    """Per-leaf element counts of a parameter pytree — the shape signature
    bits accounting sums closed forms over. Accepts an int (a flat dimension
    ``d``), a tuple of per-leaf dims, or any params pytree."""
    if isinstance(x, int):
        return (x,)
    if isinstance(x, (tuple, list)) and all(isinstance(d, int) for d in x):
        return tuple(x)
    return tm.tree_leaf_dims(x)


def total_dim(x) -> int:
    """Total parameter count of ``x`` (sum over leaves; static)."""
    return sum(leaf_dims(x))


def comm_key(key):
    """The comm PRNG stream for a round key (quantization randomness)."""
    return jax.random.fold_in(key, _COMM_KEY_TAG)


def second_uplink_key(key):
    """The comm stream for a round's SECOND compressed uplink (SAGA's fresh
    gradients, SCAFFOLD's control deltas). The tag value predates the
    registry and stays 1 so existing trajectories remain bitwise intact."""
    return jax.random.fold_in(comm_key(key), _SECOND_UPLINK_TAG)


def downlink_key(key):
    """The comm stream for the round's compressed broadcast (downlink EF).
    Derived UNDER the comm stream so enabling downlink compression never
    disturbs the uplink randomness (identity-downlink bit-exactness)."""
    return jax.random.fold_in(comm_key(key), _DOWNLINK_KEY_TAG)


def second_downlink_key(key):
    """The stream for a round's SECOND broadcast (SCAFFOLD's server
    variate, SSNM's snapshot point) — stateless, no EF chain."""
    return jax.random.fold_in(downlink_key(key), _SECOND_UPLINK_TAG)


def momentum_uplink_key(key):
    """The comm stream for a compressed MOMENTUM uplink (ASG's lookahead
    gradients, SSNM's sampled-negative-momentum gradients), independent of
    the plain uplink stream so momentum compression composes with it."""
    return jax.random.fold_in(comm_key(key), _MOMENTUM_UPLINK_TAG)


def participation_scale(mask, cids):
    """Per-row aggregation weights turning a plain client mean into the
    participant mean: scaleᵢ = m_i · S_rows / Σm, so
    meanᵢ(scaleᵢ·vᵢ) = Σ m_i·v_i / Σm. Under full participation every scale
    is exactly 1.0 — multiplying by it is a bitwise no-op."""
    m = mask[cids].astype(jnp.float32)
    total = jnp.maximum(jnp.sum(m), 1.0)
    return m * (jnp.float32(m.shape[0]) / total)


def uplink_bits_per_client(params: CommParams, d: int):
    """Closed-form uplink bits for ONE compressed [d] LEAF (traced scalar).

    QSGD bills one ℓ₂-norm float per leaf (compression is leaf-wise);
    top-k/rand-k retain k coordinates per leaf, each addressed by a
    ⌈log₂ d_leaf⌉-bit index.
    """
    idx_bits = float(max(1, math.ceil(math.log2(d)))) if d > 1 else 1.0
    k = params.spars_k.astype(jnp.float32)
    return jnp.select(
        [params.comp_id == COMP_IDENTITY, params.comp_id == COMP_QSGD],
        [jnp.float32(32.0 * d), 32.0 + d * (params.qsgd_bits + 1.0)],
        default=k * (32.0 + idx_bits),
    )


def uplink_bits_per_client_tree(params: CommParams, dims):
    """Uplink bits of one compressed parameter PYTREE per client: the sum of
    per-leaf closed forms. ``dims`` is an int, a tuple of leaf dims, or a
    params pytree (see ``leaf_dims``); a flat [D] vector reduces to the
    single-leaf closed form exactly."""
    return sum(uplink_bits_per_client(params, d) for d in leaf_dims(dims))


def downlink_bits_per_client(params: CommParams, dims):
    """Closed-form downlink bits of ONE broadcast pytree per client: the
    wire format is direction-symmetric, so the per-leaf closed forms are the
    uplink's, evaluated at the DOWNLINK leg's params. An identity leg
    reduces to the full-precision 32·Σ_l d_l broadcast exactly (the
    pre-plan hardcoded form)."""
    return sum(uplink_bits_per_client(params, d) for d in leaf_dims(dims))


def selection_round_bits(dims, s_sel: int):
    """(uplink, downlink) bits of one Lemma H.2 two-candidate selection.
    Selection broadcasts stay full-precision: candidates must be evaluated
    at the exact points the chain compares."""
    return 2.0 * 32.0 * s_sel, 2.0 * 32.0 * total_dim(dims) * s_sel


def account_round(comm: CommState, dims, *, up_vectors: int = 0,
                  down_vectors: int = 0, mom_vectors: int = 0) -> CommState:
    """Accumulate one round's bits: S_r participants, each transmitting
    ``up_vectors`` pytrees on the uplink leg and ``mom_vectors`` on the
    momentum leg, and receiving ``down_vectors`` broadcast pytrees billed at
    the downlink leg's closed form. ``dims`` is the parameter pytree itself
    (or its int/tuple dims)."""
    s_r = jnp.sum(comm.mask.astype(jnp.float32))
    up = s_r * up_vectors * uplink_bits_per_client_tree(comm.params, dims)
    if mom_vectors:
        up = up + (s_r * mom_vectors
                   * uplink_bits_per_client_tree(comm.mom, dims))
    down = s_r * down_vectors * downlink_bits_per_client(comm.down, dims)
    return comm._replace(bits_up=comm.bits_up + up,
                         bits_down=comm.bits_down + down)


def uplink(comm: CommState, payload, cids, key, *, ref=None,
           use_ef: bool = True, leg: str = "up"):
    """Compress one batch of per-client uplink pytrees.

    ``payload`` is a pytree whose leaves are [S, ...] (row i = client
    ``cids[i]``'s transmission); a flat [S, D] array is the single-leaf case
    and reproduces the pre-pytree implementation bitwise. ``ref`` is an
    optional reference pytree (the broadcast iterate) — when given, the
    *delta* payload − ref is compressed and the reconstruction ref + C(Δ)
    returned, which is the standard wire format for local-update methods.
    Identity compression short-circuits to the payload itself (bitwise),
    whatever the reference. Error feedback adds the client's residual (a
    params-shaped table pytree) before compression and stores the
    quantization error after — participants only (masked-out clients neither
    transmit nor consume residual). ``leg`` selects the compressor params:
    ``"up"`` (the plain uplink leg) or ``"mom"`` (the momentum leg ASG/SSNM
    ship accelerated gradients on — same residual tables, same kernels,
    independently swappable params). Returns ``(reconstruction, CommState)``.
    """
    if leg not in ("up", "mom"):
        raise ValueError(f"unknown uplink leg {leg!r}; expected 'up'/'mom'")
    params = comm.params if leg == "up" else comm.mom
    delta = tm.tree_sub(payload, ref) if ref is not None else payload

    ef = ef_enabled(comm) and use_ef
    if ef:
        res = jax.tree.map(lambda t: t[cids], comm.residual)
        delta_in = tm.tree_add(delta, res)
    else:
        delta_in = delta

    comp = compressors.compress_tree(delta_in, key, params)

    if ef:
        m = comm.mask[cids].astype(jnp.float32)
        mb = tm.tree_bcast_rows(m, delta_in)  # [S, 1, …, 1] per leaf
        new_res = jax.tree.map(
            lambda mm, di, co, rs: mm * (di - co) + (1.0 - mm) * rs,
            mb, delta_in, comp, res)
        comm = comm._replace(residual=jax.tree.map(
            lambda t, v: t.at[cids].set(v), comm.residual, new_res))

    recon = tm.tree_add(ref, comp) if ref is not None else comp
    # identity returns the payload itself: ref + (payload − ref) round-trips
    # through float addition, but the wire carried the exact payload.
    out = jax.tree.map(
        lambda pl, rc: jnp.where(params.comp_id == COMP_IDENTITY, pl, rc),
        payload, recon)
    return out, comm


def downlink(comm: CommState, payload, key):
    """Compress the round's server→client broadcast with bidirectional
    error feedback.

    ``payload`` is the parameter pytree the server wants every client to
    hold (the iterate, or ASG's lookahead point). The wire carries
    C(payload − down_ref + down_residual) through the SAME leaf-wise
    [S, d_leaf] ravel boundary and compressor kernels as the uplink (S = 1:
    the broadcast is common to all clients), the reconstruction
    down_ref + C(Δ) is what clients compute at this round, and the server
    keeps the quantization error in ``down_residual`` for the next
    broadcast. An identity downlink leg short-circuits bitwise to the
    payload with an exactly-zero residual, so uplink-only plans reproduce
    the uncompressed trajectories bit-for-bit. Returns
    ``(reconstruction, CommState)``.
    """
    params = comm.down
    delta = tm.tree_sub(payload, comm.down_ref)
    delta_in = tm.tree_add(delta, comm.down_residual)
    rows = jax.tree.map(lambda l: l[None], delta_in)
    comp = jax.tree.map(lambda l: jnp.squeeze(l, 0),
                        compressors.compress_tree(rows, key, params))
    is_id = params.comp_id == COMP_IDENTITY
    recon = jax.tree.map(
        lambda pl, rf, co: jnp.where(is_id, pl, rf + co),
        payload, comm.down_ref, comp)
    new_res = jax.tree.map(
        lambda di, co: jnp.where(is_id, jnp.zeros_like(di), di - co),
        delta_in, comp)
    return recon, comm._replace(down_ref=recon, down_residual=new_res)


def downlink_second(comm: CommState, payload, key):
    """Compress a round's SECOND broadcast (SCAFFOLD's server variate c,
    SSNM's snapshot point) on the downlink leg — stateless: no reference,
    no error-feedback chain (the payload is not the iterate the ``down_ref``
    chain tracks). Identity short-circuits to the payload bitwise. Returns
    the reconstruction only; bill it via ``down_vectors``."""
    params = comm.down
    rows = jax.tree.map(lambda l: l[None], payload)
    comp = jax.tree.map(lambda l: jnp.squeeze(l, 0),
                        compressors.compress_tree(rows, key, params))
    return jax.tree.map(
        lambda pl, co: jnp.where(params.comp_id == COMP_IDENTITY, pl, co),
        payload, comp)


def uplink_fused_apply(comm: CommState, payload, cids, key, x, eta, *,
                       ref=None, force_pallas: bool = False):
    """One fused uplink + error-feedback + server-apply round.

    The launch-minimal sibling of ``uplink`` + aggregate + step for
    error-feedback rounds: compression still runs leaf-wise (identical
    randomness and results to ``uplink``), but the masked residual update
    AND the weighted server step then execute as ONE fused kernel pass per
    leaf over the raveled [S, d_leaf] rows
    (``kernels.aggregate.ops.aggregate_apply``) instead of separate
    gather/scatter/mean/axpy launches.

    ``payload`` rows are client transmissions (leaves [S, ...] mirroring the
    ``x`` pytree); ``ref`` selects the wire format exactly as in ``uplink``
    (``None``: the payload itself is the wire delta — global-update methods;
    the broadcast iterate: payload − ref is compressed — local-update
    methods). ``eta`` is the server stepsize folded into the aggregation
    weights as ``scale·(η/S)`` — the exact fold ``base.fused_server_step``
    performs, so the SGD comm round is bitwise identical fused vs unfused on
    kernel backends; pass ``−server_lr`` for iterate-averaging methods
    (x + lr·mean ≡ x − (−lr)·mean, equal to float tolerance).

    EF only (the residual tables are what the fusion saves traffic on);
    callers gate on ``ef_enabled`` and ``ops.use_fused_aggregate``. Returns
    ``(x_new, CommState)`` — bits accounting stays with ``account_round``.
    """
    from repro.kernels.aggregate import ops as agg_ops

    if not ef_enabled(comm):
        raise ValueError(
            "uplink_fused_apply is the error-feedback round path; with EF "
            "off there is no residual table to fuse over — use uplink()")
    params = comm.params
    delta = tm.tree_sub(payload, ref) if ref is not None else payload
    res = jax.tree.map(lambda t: t[cids], comm.residual)
    delta_in = tm.tree_add(delta, res)
    comp = compressors.compress_tree(delta_in, key, params)
    # wire rows entering the server sum: identity short-circuits to the
    # exact delta (matching uplink's bitwise identity contract), every
    # other compressor transmits C(Δ_in)
    agg = jax.tree.map(
        lambda dl, co: jnp.where(params.comp_id == COMP_IDENTITY, dl, co),
        delta, comp)
    m = comm.mask[cids].astype(jnp.float32)
    s = m.shape[0]
    w = participation_scale(comm.mask, cids) * (eta / s)

    treedef = jax.tree.structure(x)
    x_new, res_new = [], []
    for xl, al, dl, cl, rl in zip(
            jax.tree.leaves(x), jax.tree.leaves(agg),
            jax.tree.leaves(delta_in), jax.tree.leaves(comp),
            jax.tree.leaves(res)):
        xn, rn = agg_ops.aggregate_apply(
            xl.reshape(-1), al.reshape(s, -1), cl.reshape(s, -1),
            dl.reshape(s, -1), rl.reshape(s, -1), m, w,
            force_pallas=force_pallas)
        x_new.append(xn.reshape(xl.shape))
        res_new.append(rn.reshape(rl.shape))
    comm = comm._replace(residual=jax.tree.map(
        lambda t, v: t.at[cids].set(v), comm.residual,
        jax.tree.unflatten(treedef, res_new)))
    return jax.tree.unflatten(treedef, x_new), comm


@dataclasses.dataclass(frozen=True)
class Leg:
    """One wire direction of a ``CommPlan``: a compressor + its params.

    ``error_feedback`` sizes the per-client residual tables for the uplink
    and momentum legs (trace-time flag, as before). On the DOWNLINK leg the
    flag is ignored: the server-side residual is one params-sized pytree
    (cheap), so bidirectional EF is always active for lossy downlink
    compressors and exactly zero under identity.
    """

    compressor: str = "identity"  # identity | qsgd | topk | randk
    qsgd_bits: int = 4
    spars_k: int = 4
    error_feedback: bool = False

    def __post_init__(self):
        if self.compressor not in compressors.COMP_IDS:
            raise ValueError(
                f"unknown compressor {self.compressor!r}; "
                f"expected one of {sorted(compressors.COMP_IDS)}")
        if self.qsgd_bits < 1:
            raise ValueError("qsgd_bits must be ≥ 1 (one sign+level bit)")
        if self.spars_k < 1:
            raise ValueError("spars_k must be ≥ 1 (top-k/rand-k keep ≥ 1 "
                             "coordinate)")

    @property
    def name(self) -> str:
        tag = {"identity": "full32",
               "qsgd": f"qsgd{self.qsgd_bits}",
               "topk": f"topk{self.spars_k}",
               "randk": f"randk{self.spars_k}"}[self.compressor]
        if self.error_feedback:
            tag += "+ef"
        return tag

    def params(self) -> CommParams:
        return CommParams(
            comp_id=jnp.asarray(compressors.COMP_IDS[self.compressor],
                                jnp.int32),
            qsgd_bits=jnp.asarray(self.qsgd_bits, jnp.float32),
            spars_k=jnp.asarray(self.spars_k, jnp.int32),
        )

    def _check_dims(self, dims, role: str):
        if self.compressor in ("topk", "randk") and self.spars_k > min(dims):
            raise ValueError(
                f"spars_k={self.spars_k} exceeds the parameter dimension "
                f"{min(dims)} (smallest leaf of {dims}): the sparsifier "
                f"would keep everything while billing MORE than the identity "
                f"compressor — use identity (or a smaller k) instead "
                f"[{role} leg]")


@dataclasses.dataclass(frozen=True)
class CommPlan:
    """Static description of a direction-symmetric communication regime.

    One ``Leg`` per wire direction: ``uplink`` (client→server deltas),
    ``downlink`` (server→client broadcasts, bidirectional EF), and
    ``momentum`` (the uplink leg accelerated methods — ASG/SSNM — ship
    momentum/variance-reduction gradients on; ``None`` reuses the uplink
    leg). All three compress through the same ``lax.switch`` compressor
    table in one compile — every leg's params are executor operands.

    ``participation`` is the per-round client fraction (exactly
    ``max(1, round(frac·N))`` clients are drawn uniformly without
    replacement each round); ``mask_seed`` seeds the mask schedule —
    independent of the run key, so comm schedules are reproducible across
    algorithms.
    """

    uplink: Leg = Leg()
    downlink: Leg = Leg()
    momentum: Optional[Leg] = None
    participation: float = 1.0
    mask_seed: int = 0

    def __post_init__(self):
        if not (0.0 < self.participation <= 1.0):
            raise ValueError("participation must be in (0, 1]")

    @property
    def momentum_leg(self) -> Leg:
        """The effective momentum leg (``momentum`` or the uplink leg)."""
        return self.momentum if self.momentum is not None else self.uplink

    @property
    def name(self) -> str:
        tag = f"up:{self.uplink.name}|down:{self.downlink.name}"
        if self.momentum is not None:
            tag += f"|mom:{self.momentum.name}"
        if self.participation < 1.0:
            tag += f"+part{self.participation:g}"
        return tag

    def clients_per_round(self, num_clients: int) -> int:
        return max(1, int(round(self.participation * num_clients)))

    def round_masks(self, rounds: int, num_clients: int, *, fold: int = 0):
        """[R, N] float32 schedule: exactly ``clients_per_round`` ones per
        row, drawn uniformly without replacement. ``fold`` derives
        independent schedules (e.g. one per sweep seed) from one mask_seed.
        Full participation returns all-ones (no randomness consumed)."""
        if self.participation >= 1.0:
            return jnp.ones((rounds, num_clients), jnp.float32)
        s = self.clients_per_round(num_clients)
        key = jax.random.fold_in(jax.random.PRNGKey(self.mask_seed), fold)

        def one_round(k):
            u = jax.random.uniform(k, (num_clients,))
            ranks = jnp.argsort(jnp.argsort(u))
            return (ranks < s).astype(jnp.float32)

        return jax.vmap(one_round)(jax.random.split(key, rounds))

    def init_state(self, num_clients: int, params_or_dim) -> CommState:
        """Initial ``CommState`` for ``num_clients`` clients over the given
        parameter layout: an int (flat dimension d — the legacy signature)
        or the parameter pytree itself, whose leaf shapes size the
        per-client error-feedback residual tables and the server-side
        downlink reference/residual."""
        template = (jnp.zeros((params_or_dim,), jnp.float32)
                    if isinstance(params_or_dim, int) else params_or_dim)
        dims = leaf_dims(template)
        self.uplink._check_dims(dims, "uplink")
        self.downlink._check_dims(dims, "downlink")
        self.momentum_leg._check_dims(dims, "momentum")
        ef = self.uplink.error_feedback or (
            self.momentum is not None and self.momentum.error_feedback)
        if ef:
            residual = jax.tree.map(
                lambda l: jnp.zeros((num_clients,) + jnp.shape(l),
                                    jnp.float32), template)
        else:
            residual = jnp.zeros((num_clients, 0), jnp.float32)
        return CommState(
            params=self.uplink.params(),
            mask=jnp.ones((num_clients,), jnp.float32),
            residual=residual,
            bits_up=jnp.asarray(0.0, jnp.float32),
            bits_down=jnp.asarray(0.0, jnp.float32),
            down=self.downlink.params(),
            mom=self.momentum_leg.params(),
            down_ref=tm.tree_zeros_like(template),
            down_residual=tm.tree_zeros_like(template),
        )

    def uplink_bits(self, dims) -> float:
        """Bits per client per uplinked pytree (int dim, tuple of leaf
        dims, or params pytree) — evaluates the SAME closed form the
        executors bill (``uplink_bits_per_client_tree``), so reports can
        never desynchronize from the in-scan accounting."""
        return float(uplink_bits_per_client_tree(self.uplink.params(), dims))

    def downlink_bits(self, dims) -> float:
        """Bits per client per broadcast pytree at the downlink leg."""
        return float(downlink_bits_per_client(self.downlink.params(), dims))

    def momentum_bits(self, dims) -> float:
        """Bits per client per momentum-leg uplinked pytree."""
        return float(uplink_bits_per_client_tree(
            self.momentum_leg.params(), dims))


@dataclasses.dataclass(frozen=True)
class CommConfig:
    """Deprecated uplink-only shim over ``CommPlan``.

    Kept for existing configs and reports: it describes ONE compressed
    direction and constructs ``plan()`` — an uplink-only ``CommPlan`` with
    an identity downlink leg — bitwise identical to the pre-plan behaviour
    (the spec.py ``FederatedProblem`` shim is the template). Every executor
    entry point accepts either; new code should build ``CommPlan`` directly.
    """

    compressor: str = "identity"  # identity | qsgd | topk | randk
    qsgd_bits: int = 4
    spars_k: int = 4
    participation: float = 1.0
    error_feedback: bool = False
    mask_seed: int = 0

    def __post_init__(self):
        self.plan()  # Leg/CommPlan validation, same messages as before

    def plan(self) -> CommPlan:
        """The uplink-only ``CommPlan`` this shim describes."""
        return CommPlan(
            uplink=Leg(compressor=self.compressor, qsgd_bits=self.qsgd_bits,
                       spars_k=self.spars_k,
                       error_feedback=self.error_feedback),
            participation=self.participation,
            mask_seed=self.mask_seed,
        )

    @property
    def name(self) -> str:
        tag = self.plan().uplink.name
        if self.participation < 1.0:
            tag += f"+part{self.participation:g}"
        return tag

    def params(self) -> CommParams:
        return self.plan().uplink.params()

    def clients_per_round(self, num_clients: int) -> int:
        return self.plan().clients_per_round(num_clients)

    def round_masks(self, rounds: int, num_clients: int, *, fold: int = 0):
        return self.plan().round_masks(rounds, num_clients, fold=fold)

    def init_state(self, num_clients: int, params_or_dim) -> CommState:
        return self.plan().init_state(num_clients, params_or_dim)

    def uplink_bits(self, dims) -> float:
        return self.plan().uplink_bits(dims)


def masked_keep(mask_rows, new, old):
    """Participants take the new value; masked-out clients keep the old —
    the table-update convention every comm-aware algorithm shares (a bitwise
    no-op selecting ``new`` under full participation). ``new``/``old`` are
    pytrees with [S, ...] leaves; the [S] mask broadcasts leaf-wise."""
    return jax.tree.map(
        lambda n, o: jnp.where(
            mask_rows.reshape(mask_rows.shape + (1,) * (n.ndim - 1)) > 0,
            n, o),
        new, old)


def reject_algo_participation(algo_s: int, algo_name: str):
    """Comm-enabled rounds own participation through the mask schedule; an
    algorithm's own ``s`` would be silently ignored — refuse instead."""
    if algo_s and algo_s > 0:
        raise ValueError(
            f"algorithm {algo_name!r} sets s={algo_s} (its own client "
            f"sampling) but the comm layer is enabled — participation is "
            f"owned by CommConfig.participation (the per-round mask "
            f"schedule); set s=0 on the algorithm and put the fraction in "
            f"the comm config")


def require_comm_leaf(state, algo_name: str):
    """Pre-run check that an algorithm's state CAN carry a comm leaf (the
    friendly error before ``_replace(comm=...)`` would crash on a NamedTuple
    without the field — e.g. ACSA's state)."""
    if not hasattr(state, "comm"):
        raise TypeError(
            f"algorithm {algo_name!r} is not comm-aware: its state has no "
            f"comm leaf (see algorithms.base — comm-aware states declare "
            f"`comm: Optional[object] = None`)")
    return state


def comm_state_or_error(state, algo_name: str) -> Optional[CommState]:
    """Executor-side check that an algorithm honored the comm leaf."""
    comm = getattr(state, "comm", None)
    if comm is None:
        raise TypeError(
            f"algorithm {algo_name!r} is not comm-aware: its round() dropped "
            f"the comm leaf (comm-aware rounds must thread state.comm "
            f"through and account their uplinks)")
    return comm
