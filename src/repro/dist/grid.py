"""The grid axis: sweeps sharded over devices with ``shard_map``.

``run_sweep_sharded`` is the device-mesh twin of ``core.sweep.run_sweep``
(and the target of its ``mesh=`` argument): the flattened problems × seeds
cells are partitioned across the ``grid`` mesh axis, each shard runs ITS
cells through the SAME cell functions the vmapped engine uses
(``core.sweep.make_*_cell`` — one source of truth for per-cell math), and
results come back bitwise identical to the single-device call.

Anatomy of a sharded sweep
--------------------------
1. ``dist.partition`` flattens cell (p, s) to ``p·S + s``, pads the flat
   axis to a multiple of the grid size by repeating real cells, and keeps
   the identity prefix for unpadding (a property-tested bijection).
2. Every per-cell operand is gathered to a ``[C_pad, ...]`` stack: the
   stacked ``ProblemSpec`` leaves, per-cell x0, per-cell raw PRNG keys
   (``PRNGKey(seeds[s])``, exactly the single-device values), and — under
   ``comm=`` — the per-cell ``[R, N]`` mask schedule derived with the same
   fold ``p·S + s``. The stepsize axis stays dense inside every cell.
3. ``sharding.rules.leading_axis_specs`` (the ``cells`` logical axis) maps
   each stack's leading axis to the ``grid`` mesh axis; replicated operands
   (η grid, chain decay rows, initial ``CommState``) get empty specs.
4. The executor is ``jit(shard_map(vmap(vmap(cell))))``: each shard vmaps
   its local cells × stepsizes, the same nesting as the vmapped engine. No
   collective crosses cells — the grid axis is pure map parallelism, so
   per-cell results (and the in-cell bits accounting) cannot depend on
   placement.

Executors are cached per (algorithm-or-chain, problem STRUCTURE, rounds,
mesh signature) in the same LRU the single-device engine uses, and the
shard_map body is traced ONCE per structure (``runner.TRACE_COUNTS`` moves
by exactly 1 — asserted in the dist tests and the ``dist_scaling``
benchmark).

The fraction sweep (``run_fraction_sweep_sharded``) shards the seeds ×
fractions cells the same way, with the per-fraction schedule rows riding
each cell's shard as operands.
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import chain as chain_lib
from repro.core import runner as runner_lib
from repro.core import sweep as sweep_lib
from repro.core import tree_math as tm
from repro.dist import compat, mesh as mesh_lib, partition
from repro.sharding import rules as rules_lib


def _require_spec(problem):
    spec = runner_lib.as_spec(problem)
    if spec is None:
        raise TypeError(
            "the sharded sweep needs spec-backed problems (ProblemSpec or a "
            "spec-backed shim): legacy hand-closure problems keep their data "
            "in Python closures, which cannot be placed on a device shard")
    return spec


def _cell_specs(tree, ruleset):
    """PartitionSpecs sharding every leaf's leading cells axis over 'grid'
    (the ``cells`` logical rule of ``sharding.rules``)."""
    return rules_lib.leading_axis_specs(tree, ruleset, "cells")


def _replicated(tree):
    return jax.tree.map(lambda _: P(), tree)


def _gather_cells(tree, idx):
    """Stack per-cell operands: gather ``idx`` along every leaf's leading
    axis (idx indexes the unpadded cell order; repeats implement padding)."""
    return jax.tree.map(lambda l: jnp.take(l, idx, axis=0), tree)


def _indexed_cell_plan(cell, cell_in_axes, replicated_args):
    """Adapt a cell's sharding plan to the indexed O(P) operand layout:
    the leading ``(spec, x0)`` become replicated stacks and a per-cell
    problem index is inserted (sharded with the cells, off the dense
    stepsize axis) — ``core.sweep.make_indexed_cell`` does the in-cell
    gather."""
    icell = sweep_lib.make_indexed_cell(cell)
    in_axes = (None if cell_in_axes is None
               else (None, None, None) + tuple(cell_in_axes[2:]))
    rep_args = (True, True, False) + tuple(replicated_args[2:])
    return icell, in_axes, rep_args


def _sharded_grid_fn(cache_key, mesh, cell, cell_in_axes, replicated_args,
                     donate_argnums=()):
    """Build (or fetch) the sharded grid executor around one sweep cell.

    ``replicated_args`` flags which cell arguments ride replicated
    (everything else is a [C_pad, ...] per-cell stack whose leading axis is
    sharded over ``grid``); the shard body vmaps local cells over the
    non-replicated axis-0s, with an optional inner dense vmap
    (``cell_in_axes``, the stepsize axis — None for flat cell grids).
    ``in_specs`` follow each argument's pytree STRUCTURE, so one cached
    entry lazily assembles a shard_map per operand structure (e.g. comm
    states with/without error-feedback residuals); jit handles shapes.
    ``donate_argnums`` positions are donated to the jit (call-private
    stacks only — never the caller-owned spec/x0) and are part of the
    cache key.
    """
    key = ("dist-grid", cache_key, mesh_lib.mesh_signature(mesh),
           tuple(donate_argnums))
    fn = runner_lib._cache_get(key)
    if fn is not None:
        return fn

    ruleset = rules_lib.RuleSet(mesh)
    outer_axes = tuple(None if rep else 0 for rep in replicated_args)

    def shard_body(*args):
        inner = (cell if cell_in_axes is None
                 else jax.vmap(cell, in_axes=cell_in_axes))
        return jax.vmap(inner, in_axes=outer_axes)(*args)

    compiled: dict = {}

    def call(*args):
        struct = jax.tree_util.tree_structure(args)
        jitted = compiled.get(struct)
        if jitted is None:
            in_specs = tuple(
                _replicated(a) if rep else _cell_specs(a, ruleset)
                for a, rep in zip(args, replicated_args))
            jitted = jax.jit(
                compat.shard_map(shard_body, mesh, in_specs=in_specs,
                                 out_specs=P("grid")),
                donate_argnums=tuple(donate_argnums))
            compiled[struct] = jitted
        return jitted(*args)

    return runner_lib._cache_put(key, call)


def _unpad_cells(outs, n_cells, lead_shape):
    """Drop padding and restore the grid's leading axes ([P, S] or [S])."""

    def fix(l):
        l = partition.unpad(l, n_cells)
        return l.reshape(tuple(lead_shape) + l.shape[1:])

    return jax.tree.map(fix, outs)


def run_sweep_sharded(algo_or_chain, problem, x0, rounds: int, *,
                      seeds: Sequence[int], etas: Sequence[float], mesh,
                      eta_mode: Optional[str] = None,
                      eval_output: bool = True,
                      decay: Optional[dict] = None, comm=None,
                      problems=None,
                      operand_layout: str = "indexed",
                      telemetry=None) -> "sweep_lib.SweepResult":
    """``core.sweep.run_sweep`` on a ``('grid',)`` device mesh.

    Same arguments, same semantics, same ``SweepResult`` shapes; results,
    per-cell RNG streams and ``bits_up``/``bits_down`` are BITWISE identical
    to the single-device call (tested on a CPU debug mesh). See the module
    docstring for the sharding anatomy. Under the default
    ``operand_layout="indexed"`` the O(P) stacked spec/x0 ride REPLICATED
    across shards and only the int32 per-cell problem index is sharded
    with the cells; ``operand_layout="stacked"`` keeps the historical
    per-cell gathered copies. The two layouts are bitwise identical
    (``core.sweep``'s memory model).
    """
    is_chain = isinstance(algo_or_chain, chain_lib.Chain)
    eta_mode = sweep_lib._resolve_eta_mode(algo_or_chain, eta_mode)
    sweep_lib.check_operand_layout(operand_layout)
    seeds = tuple(int(s) for s in seeds)
    etas = tuple(float(e) for e in etas)
    if not seeds:
        raise ValueError("run_sweep needs at least one seed")
    if decay is not None and not is_chain:
        raise NotImplementedError(
            "decay sweeps: wrap the algorithm in a Chain")
    n_shards = mesh_lib.grid_size(mesh)
    keys = jnp.stack([jax.random.PRNGKey(s) for s in seeds])
    etas_arr = jnp.asarray(etas, jnp.float32)
    n_seeds = len(seeds)

    per_cell = problems is not None  # spec/x0 stacked per cell, or replicated
    if per_cell:
        stacked, prob_names = sweep_lib._as_stacked_specs(problems)
        n_probs = len(prob_names)
        x0_stack = sweep_lib._normalize_x0_stack(x0, stacked, n_probs)
        lead_shape = (n_probs, n_seeds)
    else:
        stacked = _require_spec(problem)
        prob_names = None
        n_probs = 1
        lead_shape = (n_seeds,)

    n_cells = n_probs * n_seeds
    src_idx, _ = partition.pad_cells(n_cells, n_shards)
    idx = jnp.asarray(src_idx)
    p_idx, s_idx = partition.cell_coords(n_probs, n_seeds)
    keys_c = keys[jnp.asarray(s_idx)][idx]  # [C_pad, 2]

    indexed = per_cell and operand_layout == "indexed"
    if indexed:
        # O(P) layout: the stacks ride replicated, the in-cell gather is
        # driven by the sharded per-cell problem index
        spec_c, x0_c = stacked, x0_stack
        pidx_c = jnp.asarray(p_idx, jnp.int32)[idx]
    elif per_cell:
        spec_c = _gather_cells(stacked, jnp.asarray(p_idx)[idx])
        x0_c = _gather_cells(x0_stack, jnp.asarray(p_idx)[idx])
    else:
        spec_c, x0_c = stacked, x0  # replicated: the single-device layout

    if comm is not None:
        n_clients = stacked.num_clients
        n_sched = (algo_or_chain.schedule_len(rounds) if is_chain else rounds)
        # per-cell [R, N] schedules; fold p·S + s == s when there is no
        # problems axis — exactly the single-device folds, cell for cell
        masks_flat = jnp.stack([
            comm.round_masks(n_sched, n_clients,
                             fold=partition.flatten_cell(p, s, n_seeds))
            for p in range(n_probs) for s in range(n_seeds)])
        masks_c = masks_flat[idx]
        comm0 = comm.init_state(
            n_clients, tm.tree_index(x0_stack, 0) if per_cell else x0)

    rep = not per_cell  # spec/x0 replication flag (stacked layout)
    name_tag = "dist-comm" if comm is not None else "dist"
    if per_cell:
        name_tag += "-probs"
    pkey = runner_lib.problem_key(stacked)

    def plan(cell, cell_in_axes, replicated_args):
        """(cell, axes, replication, operand prefix, donated argnums) for
        the chosen layout — donation covers every call-private stack
        (keys/masks/η rows/pidx/comm0), never the caller-owned spec/x0."""
        if indexed:
            cell, cell_in_axes, replicated_args = _indexed_cell_plan(
                cell, cell_in_axes, replicated_args)
            lead = (spec_c, x0_c, pidx_c)
        else:
            lead = (spec_c, x0_c)
        donate = tuple(range(2, len(replicated_args)))
        return cell, cell_in_axes, replicated_args, lead, donate

    layout_key = operand_layout if per_cell else None
    if is_chain:
        chain = algo_or_chain
        eta_sched = chain.eta_schedule(rounds, decay)
        if comm is not None:
            cell, axes, reps, lead, donate = plan(
                sweep_lib.make_chain_comm_cell(chain, stacked, rounds,
                                               name_tag, telemetry),
                (None, None, None, 0, None, None, None),
                (rep, rep, False, True, True, False, True))
            fn = _sharded_grid_fn(
                ("dist-chain-comm", chain._key(), pkey, rounds, per_cell,
                 layout_key, telemetry),
                mesh, cell, cell_in_axes=axes, replicated_args=reps,
                donate_argnums=donate)
            outs, taps = sweep_lib._split_taps(_unpad_cells(
                fn(*lead, keys_c, etas_arr, eta_sched, masks_c, comm0),
                n_cells, lead_shape), telemetry)
            x_hat, history, final, kept, bits_up, bits_down = outs
            return sweep_lib.SweepResult(
                history=history, final_sub=final, x_hat=x_hat, seeds=seeds,
                etas=etas, selected_initial=kept, bits_up=bits_up,
                bits_down=bits_down, problems=prob_names, diagnostics=taps)
        cell, axes, reps, lead, donate = plan(
            sweep_lib.make_chain_cell(chain, stacked, rounds, name_tag,
                                      telemetry),
            (None, None, None, 0, None),
            (rep, rep, False, True, True))
        fn = _sharded_grid_fn(
            ("dist-chain", chain._key(), pkey, rounds, per_cell, layout_key,
             telemetry),
            mesh, cell, cell_in_axes=axes, replicated_args=reps,
            donate_argnums=donate)
        outs, taps = sweep_lib._split_taps(_unpad_cells(
            fn(*lead, keys_c, etas_arr, eta_sched), n_cells, lead_shape),
            telemetry)
        x_hat, history, final, kept = outs
        return sweep_lib.SweepResult(
            history=history, final_sub=final, x_hat=x_hat, seeds=seeds,
            etas=etas, selected_initial=kept, problems=prob_names,
            diagnostics=taps)

    algo = algo_or_chain
    if comm is not None:
        cell, axes, reps, lead, donate = plan(
            sweep_lib.make_algo_comm_cell(
                algo, stacked, rounds, eval_output, eta_mode, name_tag,
                telemetry),
            (None, None, None, 0, None, None),
            (rep, rep, False, True, False, True))
        fn = _sharded_grid_fn(
            ("dist-algo-comm", algo, pkey, rounds, eval_output, eta_mode,
             per_cell, layout_key, telemetry),
            mesh, cell, cell_in_axes=axes, replicated_args=reps,
            donate_argnums=donate)
        outs, taps = sweep_lib._split_taps(_unpad_cells(
            fn(*lead, keys_c, etas_arr, masks_c, comm0), n_cells,
            lead_shape), telemetry)
        x_hat, history, final, bits_up, bits_down = outs
        return sweep_lib.SweepResult(
            history=history, final_sub=final, x_hat=x_hat, seeds=seeds,
            etas=etas, bits_up=bits_up, bits_down=bits_down,
            problems=prob_names, diagnostics=taps)
    cell, axes, reps, lead, donate = plan(
        sweep_lib.make_algo_cell(
            algo, stacked, rounds, eval_output, eta_mode, name_tag,
            telemetry),
        (None, None, None, 0),
        (rep, rep, False, True))
    fn = _sharded_grid_fn(
        ("dist-algo", algo, pkey, rounds, eval_output, eta_mode, per_cell,
         layout_key, telemetry),
        mesh, cell, cell_in_axes=axes, replicated_args=reps,
        donate_argnums=donate)
    outs, taps = sweep_lib._split_taps(_unpad_cells(
        fn(*lead, keys_c, etas_arr), n_cells, lead_shape), telemetry)
    x_hat, history, final = outs
    return sweep_lib.SweepResult(history=history, final_sub=final,
                                 x_hat=x_hat, seeds=seeds, etas=etas,
                                 problems=prob_names, diagnostics=taps)


def run_selection_sweep_sharded(algo_or_chain, problem, x0, rounds: int, *,
                                policies, seeds: Sequence[int], mesh,
                                etas: Sequence[float] = (1.0,),
                                eta_mode: Optional[str] = None, comm=None,
                                problems=None, eval_output: bool = True,
                                telemetry=None):
    """``selection.sweep.run_selection_sweep`` with the flattened policies ×
    problems × seeds cells sharded over the ``grid`` mesh axis.

    Both engines consume the SAME host-derived operands
    (``selection.sweep.selection_grid_operands``): here the per-cell index
    vectors (qidx/pidx), raw key rows, and [R, 2] selection-key rows are
    gathered onto their shard while the O(Q) policy stacks and the O(P)
    spec/x0 stacks ride replicated — so per-cell results, masks and the
    bits ledgers are BITWISE identical to the vmapped call.
    """
    from repro.selection import sweep as sel_sweep

    ops = sel_sweep.selection_grid_operands(
        algo_or_chain, problem, x0, rounds, policies=policies, seeds=seeds,
        etas=etas, eta_mode=eta_mode, comm=comm, problems=problems,
        eval_output=eval_output)

    n_cells = ops.n_pols * ops.n_probs * ops.n_seeds
    lead_shape = (ops.n_pols, ops.n_probs, ops.n_seeds)
    src_idx, _ = partition.pad_cells(n_cells, mesh_lib.grid_size(mesh))
    idx = jnp.asarray(src_idx)
    pidx_c = ops.pidx[idx]
    qidx_c = ops.qidx[idx]
    keys_c = ops.keys_c[idx]
    sel_keys_c = ops.sel_keys_c[idx]
    pkey = runner_lib.problem_key(ops.stacked)
    lead = (ops.stacked, ops.x0_stack, ops.pol_stack, ops.pst_stack)

    if ops.is_chain:
        chain = algo_or_chain
        cell = sweep_lib.make_policy_cell(
            sweep_lib.make_selection_chain_cell(chain, ops.stacked, rounds,
                                                "dist-sel", telemetry))
        fn = _sharded_grid_fn(
            ("dist-sel-chain", chain._key(), pkey, rounds, telemetry),
            mesh, cell,
            cell_in_axes=(None, None, None, None, None, None, None, 0,
                          None, None, None),
            replicated_args=(True, True, True, True, False, False, False,
                             True, True, False, True),
            donate_argnums=tuple(range(2, 11)))
        outs = fn(*lead, pidx_c, qidx_c, keys_c, ops.etas_arr,
                  ops.eta_sched, sel_keys_c, ops.comm0)
        outs, taps = sweep_lib._split_taps(
            _unpad_cells(outs, n_cells, lead_shape), telemetry)
        (x_hat, history, final, kept, bits_up, bits_down, masks,
         pstate) = outs
        return sel_sweep.SelectionSweepResult(
            history=history, final_sub=final, x_hat=x_hat, bits_up=bits_up,
            bits_down=bits_down, masks=masks, policy_state=pstate,
            policies=ops.pol_names, problems=ops.prob_names,
            seeds=ops.seeds, etas=ops.etas, selected_initial=kept,
            diagnostics=taps)

    algo = algo_or_chain
    cell = sweep_lib.make_policy_cell(
        sweep_lib.make_selection_algo_cell(algo, ops.stacked, rounds,
                                           eval_output, ops.eta_mode,
                                           "dist-sel", telemetry))
    fn = _sharded_grid_fn(
        ("dist-sel-algo", algo, pkey, rounds, eval_output, ops.eta_mode,
         telemetry),
        mesh, cell,
        cell_in_axes=(None, None, None, None, None, None, None, 0, None,
                      None),
        replicated_args=(True, True, True, True, False, False, False, True,
                         False, True),
        donate_argnums=tuple(range(2, 10)))
    outs = fn(*lead, pidx_c, qidx_c, keys_c, ops.etas_arr, sel_keys_c,
              ops.comm0)
    outs, taps = sweep_lib._split_taps(
        _unpad_cells(outs, n_cells, lead_shape), telemetry)
    x_hat, history, final, bits_up, bits_down, masks, pstate = outs
    return sel_sweep.SelectionSweepResult(
        history=history, final_sub=final, x_hat=x_hat, bits_up=bits_up,
        bits_down=bits_down, masks=masks, policy_state=pstate,
        policies=ops.pol_names, problems=ops.prob_names, seeds=ops.seeds,
        etas=ops.etas, diagnostics=taps)


def run_fraction_sweep_sharded(chain, problem, x0, rounds: int, *,
                               seeds: Sequence[int],
                               fractions: Sequence[float], mesh,
                               decay: Optional[dict] = None
                               ) -> "sweep_lib.SweepResult":
    """``core.sweep.run_fraction_sweep`` with the seeds × fractions cells
    sharded over the ``grid`` mesh axis (cell (s, f) flattens to
    ``s·F + f``; per-cell key streams and schedule rows ride their shard)."""
    if not isinstance(chain, chain_lib.Chain):
        raise TypeError("run_fraction_sweep takes a Chain")
    seeds = tuple(int(s) for s in seeds)
    fractions = tuple(float(f) for f in fractions)
    if not seeds or not fractions:
        raise ValueError("run_fraction_sweep needs ≥1 seed and ≥1 fraction")
    spec = _require_spec(problem)
    if x0 is None:
        x0 = spec.x0

    (_, keys_r, keys_s, stage_id, kind, hmode, eta_rows,
     sel_indices) = sweep_lib.fraction_schedule_operands(
         chain, rounds, fractions, seeds, decay)

    n_seeds, n_fracs = len(seeds), len(fractions)
    n_cells = n_seeds * n_fracs
    src_idx, _ = partition.pad_cells(n_cells, mesh_lib.grid_size(mesh))
    idx = jnp.asarray(src_idx)
    _, f_idx = partition.cell_coords(n_seeds, n_fracs)
    f_c = jnp.asarray(f_idx)[idx]

    keys_r_c = keys_r.reshape((n_cells,) + keys_r.shape[2:])[idx]
    keys_s_c = keys_s.reshape((n_cells,) + keys_s.shape[2:])[idx]
    stage_c, kind_c, hmode_c, eta_c = (
        arr[f_c] for arr in (stage_id, kind, hmode, eta_rows))

    cell = sweep_lib.make_chain_fraction_cell(chain, spec, rounds,
                                              "dist-frac")
    fn = _sharded_grid_fn(
        ("dist-chain-frac", chain._fraction_free_key(),
         runner_lib.problem_key(spec), rounds),
        mesh, cell,
        cell_in_axes=None,  # flat cells axis, no dense inner axis
        replicated_args=(True, True, False, False, False, False, False,
                         False),
        donate_argnums=(2, 3, 4, 5, 6, 7))  # per-cell key/schedule rows
    outs = fn(spec, x0, keys_r_c, keys_s_c, stage_c, kind_c, hmode_c, eta_c)
    x_hat, history, final, kept = _unpad_cells(
        outs, n_cells, (n_seeds, n_fracs))
    return sweep_lib.SweepResult(
        history=history, final_sub=final, x_hat=x_hat, seeds=seeds,
        etas=fractions,
        selected_initial=sweep_lib.gather_selection_flags(kept, sel_indices))
