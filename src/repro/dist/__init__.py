"""Distributed sweep subsystem: the experiment grid on a device mesh.

FedChain's Tables 1-4 grids are embarrassingly parallel twice over: across
the problems x seeds x stepsizes cells of a sweep (independent runs joined by
nothing), and across the N clients inside a round (independent local
computations joined only by the server aggregation). This package maps both
onto a JAX device mesh with ``shard_map``, on top of the single-compile
executors of ``core.runner``/``core.chain``/``core.sweep``:

Mesh layout
-----------
``dist.mesh`` builds 1-D ``('grid',)`` meshes (and 2-D ``('grid', 'client')``
ones). The two axes carry the two parallelisms:

* **grid axis** (``dist.grid``) -- the flattened problems x seeds cells of a
  sweep are partitioned across the ``grid`` mesh axis. ``dist.partition``
  flattens cell (p, s) to index ``p * n_seeds + s``, pads the flat axis up to
  a multiple of the axis size by REPEATING real cells, and keeps the inverse
  map; padding cells compute and are dropped on the way out, so the
  unpadded results are a bijection onto the vmapped grid (property-tested).
  Every per-cell operand -- stacked ``ProblemSpec`` leaves, per-cell x0,
  per-cell PRNG keys (the same ``PRNGKey(seed)`` / mask-schedule fold
  ``p * n_seeds + s`` the single-device sweep uses), comm mask schedules --
  is placed on its shard through the ``cells`` logical axis of
  ``sharding.rules``; the stepsize axis stays dense inside each cell.
  Inside each shard the SAME cell functions as ``core.sweep`` run under the
  same vmap nesting, so the sharded grid is **bitwise identical** to the
  single-device ``run_sweep`` (tested on a CPU debug mesh built with
  ``--xla_force_host_platform_device_count``).

* **client axis** (``dist.client_axis``) -- inside one cell, the ``[N, ...]``
  client dimension is sharded: each device runs its clients' local
  computations and the Pallas ``chain_aggregate`` /
  ``weighted_mean_over_clients`` kernels on its LOCAL rows, and one
  cross-device ``jax.lax.psum`` over the ``client`` axis completes the
  client mean -- the grouped-collective structure of the paper's local
  phase (a per-client computation joined only by aggregation). Summing
  per-shard partial aggregates reorders the float reduction, so this axis
  is equivalent-to-tolerance rather than bitwise; the grid axis is the
  bitwise (and production) path.

Why bits accounting is placement-invariant
------------------------------------------
``bits_up``/``bits_down`` are computed INSIDE each cell's scan from the
round's participation mask and the closed-form per-client costs
(``repro.comm``) -- they are functions of schedule data that rides the cell's
shard, never of device placement. Sharding the grid axis moves whole cells
(each carries its own mask schedule, derived from the same per-cell fold as
the single-device path); sharding the client axis moves rows of a mean whose
billed size is a static shape. Either way the accounted wire cost is
identical to the single-device run -- asserted bit-for-bit in the dist tests.

Single-compile discipline survives sharding: the shard_map body is traced
once per executor structure (``runner.TRACE_COUNTS`` moves by exactly one),
problems / comm knobs / schedules stay operands, and re-running any
same-shaped grid on the same mesh reuses the compile.

Entry points: ``core.sweep.run_sweep(..., mesh=...)`` and
``core.sweep.run_fraction_sweep(..., mesh=...)`` delegate here;
``dist.grid.run_sweep_sharded`` / ``run_fraction_sweep_sharded`` are the
direct API. ``dist.compat`` pins the ``shard_map``/mesh API across the JAX
versions this repo supports (the old ``launch/`` mesh scaffold is rebased on
it).
"""
from repro.dist import compat, mesh, partition  # noqa: F401
from repro.dist.mesh import (  # noqa: F401
    auto_grid_mesh,
    client_size,
    grid_size,
    make_grid_client_mesh,
    make_grid_mesh,
    mesh_signature,
)
