"""JAX API compatibility shims for the dist subsystem.

The repo supports a range of JAX versions: older ones expose
``jax.experimental.shard_map.shard_map(..., check_rep=...)`` and a
``jax.make_mesh`` without ``axis_types``; newer ones promote ``shard_map`` to
``jax.shard_map(..., check_vma=...)`` and add ``jax.sharding.AxisType``.
Everything mesh- or shard_map-shaped in this repo (``dist``, and the
``launch/`` scaffold rebased onto it) goes through these two functions so the
version skew lives in exactly one place.
"""
from __future__ import annotations

import jax


def shard_map(f, mesh, in_specs, out_specs):
    """``shard_map`` without replication checking, on any supported JAX.

    Replication checking is disabled (``check_rep``/``check_vma`` False):
    the dist executors vmap cell bodies whose outputs are device-varying by
    construction, which the static replication checker cannot always prove.
    """
    sm = getattr(jax, "shard_map", None)
    if sm is not None:  # jax >= 0.6: top-level API, check_vma keyword
        try:
            return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_vma=False)
        except TypeError:  # transitional versions kept check_rep
            return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=False)
    from jax.experimental.shard_map import shard_map as exp_sm

    return exp_sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_rep=False)


def make_mesh(axis_shapes, axis_names):
    """``jax.make_mesh`` that tolerates the ``AxisType`` API generations.

    Newer JAX wants explicit ``axis_types`` (all ``Auto`` here — the dist
    executors place every operand explicitly through ``shard_map`` /
    ``NamedSharding``); older JAX has no ``AxisType`` at all.
    """
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(axis_shapes, axis_names,
                             axis_types=(axis_type.Auto,) * len(axis_names))
    return jax.make_mesh(axis_shapes, axis_names)


def abstract_mesh(axis_shapes, axis_names):
    """``jax.sharding.AbstractMesh`` across its signature generations:
    older JAX takes one ``((name, size), ...)`` tuple, newer JAX mirrors
    ``make_mesh``'s ``(shapes, names)`` pair."""
    from jax.sharding import AbstractMesh

    try:
        return AbstractMesh(tuple(axis_shapes), tuple(axis_names))
    except TypeError:
        return AbstractMesh(tuple(zip(axis_names, axis_shapes)))
