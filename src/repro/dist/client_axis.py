"""The client axis: intra-cell [N, ...] aggregation sharded with psum.

FedChain's round body is the paper's local-phase structure: N independent
per-client computations joined ONLY by a server aggregation. On a
``('client',)`` (or ``('grid', 'client')``) mesh this maps to shard_map over
the client rows — each device computes ITS clients and runs the Pallas
aggregation kernels (``chain_aggregate`` / ``weighted_mean_over_clients``,
``repro.kernels``) on its LOCAL rows; one ``jax.lax.psum`` over the
``client`` mesh axis completes the mean. That psum is the grouped-collective
formulation the old ``launch/fedchain_shardmap.py`` scaffold sketched with
``axis_index_groups`` (now rebased here): no collective crosses the client
axis except the aggregation itself.

Numerics: summing per-shard partial aggregates reorders the float reduction
over clients, so the client axis is equivalent to the single-device mean to
float tolerance, not bitwise — use the grid axis (``dist.grid``) when
bit-reproducibility matters. Bits accounting is unaffected either way: the
wire cost of a round is a closed form over the mask and parameter shapes
(``repro.comm``), independent of how the server-side mean is computed.

Scope: these are the aggregation-layer primitives plus a full-participation
client-sharded round (``sgd_round_client_sharded``) demonstrating the
local-compute → psum-join structure end to end. The sweep engines do not
route through this axis by default — grid cells are embarrassingly parallel
and pay zero collectives, so the grid axis is the production path; the
client axis is for the regime where ONE cell's clients outgrow a device.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import tree_math as tm
from repro.dist import compat
from repro.dist import mesh as mesh_lib


def _client_axis_size(mesh):
    n = mesh_lib.client_size(mesh)
    if n <= 1 and "client" not in mesh.axis_names:
        raise ValueError(
            f"mesh {mesh.axis_names} has no 'client' axis — build one with "
            f"dist.make_grid_client_mesh (or a 1-D ('client',) mesh)")
    return max(n, 1)


def sharded_client_mean(mesh, stacked, weights=None):
    """meanᵢ(wᵢ·tᵢ) over a [N, ...] client pytree, rows sharded over the
    ``client`` mesh axis.

    Each shard ravels its local rows leaf-wise to the kernel boundary and
    runs the Pallas ``weighted_mean_over_clients`` on them (exactly like the
    single-device ``algorithms.base.weighted_client_mean``); the partial
    means are completed by one psum: with K shards of N/K rows each, the
    mean over N is (1/K)·psum(local mean). ``weights`` defaults to all-ones
    (the plain client mean). N must divide by the client-axis size.
    """
    from repro.kernels.compress import ops as compress_ops

    k_shards = _client_axis_size(mesh)
    n = jax.tree.leaves(stacked)[0].shape[0]
    if n % k_shards:
        raise ValueError(f"client rows {n} must divide the client axis "
                         f"({k_shards} shards)")
    if weights is None:
        weights = jnp.ones((n,), jnp.float32)

    def body(rows, w):
        local = jax.tree.map(
            lambda r: compress_ops.weighted_mean_over_clients(r, w),
            tm.tree_ravel_rows(rows))
        total = jax.tree.map(
            lambda m: jax.lax.psum(m, "client") / k_shards, local)
        return jax.tree.map(
            lambda m, r: m.reshape(r.shape[1:]), total, rows)

    fn = compat.shard_map(
        body, mesh,
        in_specs=(jax.tree.map(lambda _: P("client"), stacked),
                  P("client")),
        out_specs=jax.tree.map(lambda _: P(), stacked))
    return fn(stacked, weights)


def sharded_chain_aggregate(mesh, x, g, c_i, c, *, lr: float, weights=None):
    """The fused FedChain server update with client rows sharded:

        out = x − lr·(Σᵢ wᵢ·(gᵢ − cᵢ) + c)

    Each shard runs the Pallas ``chain_aggregate`` kernel on its local rows
    (server variate 0, so the shard output is x − lr·Σ_local); the partial
    updates are joined by one psum over the ``client`` axis and the server
    variate ``c`` is applied once. ``weights`` defaults to uniform 1/S over
    the GLOBAL rows, matching the single-device kernel's default.
    """
    from repro.kernels.aggregate import ops as agg_ops

    k_shards = _client_axis_size(mesh)
    s = g.shape[0]
    if s % k_shards:
        raise ValueError(f"client rows {s} must divide the client axis "
                         f"({k_shards} shards)")
    if weights is None:
        weights = jnp.full((s,), 1.0 / s, jnp.float32)

    def body(g_loc, ci_loc, w_loc):
        partial = agg_ops.chain_aggregate(
            x, g_loc, ci_loc, jnp.zeros_like(x), weights=w_loc, lr=lr)
        delta = jax.lax.psum(partial - x, "client")  # −lr·Σ wᵢ(gᵢ−cᵢ)
        return x + delta - lr * c.astype(x.dtype)

    fn = compat.shard_map(
        body, mesh,
        in_specs=(P("client"), P("client"), P("client")),
        out_specs=P())
    return fn(g, c_i, weights)


def sgd_round_client_sharded(mesh, problem, x, eta, key, *, k: int):
    """One full-participation Algo-2 round with the client dimension
    sharded: per-shard ``grad_k`` local phases, per-shard Pallas partial
    aggregation, one psum join — the paper's local-computation/aggregation
    split as mesh collectives. Returns the updated server iterate
    (equivalent to the single-device round's ``state.x`` to float
    tolerance; the client permutation and oracle keys are identical).
    """
    from repro.core.algorithms import base

    spec = problem if getattr(problem, "is_problem_spec", False) else None
    if spec is None:
        raise TypeError("sgd_round_client_sharded needs a ProblemSpec")
    n = spec.num_clients
    k_shards = _client_axis_size(mesh)
    if n % k_shards:
        raise ValueError(f"num_clients {n} must divide the client axis "
                         f"({k_shards} shards)")
    k_sample, k_grad = jax.random.split(key)
    cids = base.sample_clients(k_sample, n, n)
    keys = jax.random.split(k_grad, n * k).reshape(n, k, -1)
    weights = jnp.full((n,), eta / n, jnp.float32)

    def body(cids_loc, keys_loc, w_loc):
        # the local phase: this shard's clients compute their K-sample
        # gradients with the SAME per-row keys the single-device round uses
        g_loc = base.grad_k(spec, x, cids_loc, None, k, keys=keys_loc)
        partial = _partial_aggregate(x, g_loc, w_loc)
        return x + jax.lax.psum(partial - x, "client")

    def _partial_aggregate(x_, g_loc, w_loc):
        from repro.kernels.aggregate import ops as agg_ops

        return agg_ops.chain_aggregate(
            x_, g_loc, jnp.zeros_like(g_loc), jnp.zeros_like(x_),
            weights=w_loc, lr=1.0)

    fn = compat.shard_map(
        body, mesh,
        in_specs=(P("client"), P("client"), P("client")),
        out_specs=P())
    return fn(cids, keys, weights)
