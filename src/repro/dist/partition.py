"""Grid partitioning: flatten problems x seeds cells, pad, and invert.

Host-side (numpy) logic only — the arrays it produces are gather indices and
validity masks that ``dist.grid`` applies to device operands. Keeping it
free of JAX makes the bijection contract property-testable in microseconds
for arbitrary grid sizes x device counts.

Contract (property-tested in ``tests/test_dist_sweep.py``):

* cell (p, s) of a P x S grid flattens to index ``p * S + s`` — the SAME
  order as the single-device sweep's nested problem/seed vmaps (and the same
  fold the comm mask schedules use), so the sharded grid reproduces every
  cell's RNG and mask stream exactly;
* ``pad_cells(n_cells, n_shards)`` returns gather indices whose first
  ``n_cells`` entries are the identity and whose padding tail repeats real
  cells (cyclically) up to the next multiple of ``n_shards`` — padding cells
  run real (duplicate) work and are DROPPED, never masked into results;
* because real cells occupy the prefix in order, ``unpad`` is a plain
  prefix slice: composed with the gather it is a bijection onto the
  unpadded cells.
"""
from __future__ import annotations

import numpy as np


def padded_count(n_cells: int, n_shards: int) -> int:
    """Smallest multiple of ``n_shards`` that holds ``n_cells`` cells."""
    if n_cells < 1 or n_shards < 1:
        raise ValueError(f"need n_cells >= 1 and n_shards >= 1, got "
                         f"{n_cells}, {n_shards}")
    return ((n_cells + n_shards - 1) // n_shards) * n_shards


def pad_cells(n_cells: int, n_shards: int):
    """(src_idx [C_pad] int64, valid [C_pad] bool): gather map from padded
    cell slots to real cells, identity on the valid prefix."""
    c_pad = padded_count(n_cells, n_shards)
    src_idx = np.arange(c_pad, dtype=np.int64) % n_cells
    valid = np.arange(c_pad) < n_cells
    return src_idx, valid


def flatten_cell(p: int, s: int, n_seeds: int) -> int:
    """Flat index of grid cell (problem p, seed s)."""
    return p * n_seeds + s


def cell_coords(n_problems: int, n_seeds: int):
    """(p_idx [C], s_idx [C]) coordinate vectors of the flattened grid, in
    flat-index order (c = p * n_seeds + s)."""
    flat = np.arange(n_problems * n_seeds)
    return flat // n_seeds, flat % n_seeds


def unpad(x, n_cells: int):
    """Drop padding slots from a leading padded-cells axis (prefix slice —
    see the module contract)."""
    return x[:n_cells]
