"""Sweep meshes: the ``grid`` (and optional ``client``) axes.

Functions, not module constants — importing this module never touches JAX
device state. On CPU, multiple host devices come from
``XLA_FLAGS=--xla_force_host_platform_device_count=N`` set BEFORE the first
JAX import (``benchmarks/run.py --devices N`` does this; the dist tests use
subprocess isolation).
"""
from __future__ import annotations

import os
from typing import Optional

import jax
import numpy as np
from jax.sharding import Mesh

# benchmarks/run.py --devices N exports this so harnesses can tell "the
# operator asked for a debug mesh" apart from "we happen to see N devices"
DEVICES_ENV = "REPRO_DIST_DEVICES"


def make_grid_mesh(n_devices: Optional[int] = None) -> Mesh:
    """1-D ``('grid',)`` mesh over the first ``n_devices`` devices (all by
    default) — the cells axis of a sharded sweep."""
    devices = jax.devices()
    n = len(devices) if n_devices is None else int(n_devices)
    if not 1 <= n <= len(devices):
        raise ValueError(f"need 1..{len(devices)} devices, got {n}")
    return Mesh(np.asarray(devices[:n]), ("grid",))


def make_grid_client_mesh(grid: int, client: int) -> Mesh:
    """2-D ``('grid', 'client')`` mesh: cells x intra-cell client shards."""
    devices = jax.devices()
    if grid * client > len(devices):
        raise ValueError(
            f"grid={grid} x client={client} needs {grid * client} devices, "
            f"have {len(devices)}")
    return Mesh(
        np.asarray(devices[: grid * client]).reshape(grid, client),
        ("grid", "client"))


def auto_grid_mesh(min_devices: int = 2) -> Optional[Mesh]:
    """The grid mesh a harness should use, or None for the vmapped path.

    Returns a mesh over every visible device when there are at least
    ``min_devices`` (i.e. when ``--devices``/XLA_FLAGS forced a multi-device
    host, or real accelerators are attached); single-device hosts stay on
    the plain vmapped engine — same results either way (bit-exact, tested).
    """
    n = len(jax.devices())
    want = os.environ.get(DEVICES_ENV)
    if want is not None and int(want) != n:
        raise RuntimeError(
            f"{DEVICES_ENV}={want} but JAX sees {n} devices — the XLA flag "
            f"must be set before the first JAX import "
            f"(use benchmarks/run.py --devices, which orders this correctly)")
    return make_grid_mesh(n) if n >= min_devices else None


def grid_size(mesh: Mesh) -> int:
    """Number of shards along the ``grid`` axis (1 if the mesh lacks it)."""
    return dict(zip(mesh.axis_names, mesh.devices.shape)).get("grid", 1)


def client_size(mesh: Mesh) -> int:
    """Number of shards along the ``client`` axis (1 if absent)."""
    return dict(zip(mesh.axis_names, mesh.devices.shape)).get("client", 1)


def mesh_signature(mesh: Mesh) -> tuple:
    """The mesh's contribution to an executor cache key: axis layout plus
    concrete device identity (an executor compiled for one device set must
    not be served for another)."""
    return (tuple(mesh.axis_names), tuple(mesh.devices.shape),
            tuple(d.id for d in mesh.devices.flat))
