"""Gradient accumulation (microbatching) — the memory lever identified in
EXPERIMENTS.md §Perf(a): splits a step's batch into N microbatches, averaging
gradients in fp32, so activation residency shrinks ~N× at the cost of N
sequential forward/backward passes (FLOPs unchanged, collective per-step
unchanged: one gradient sync after accumulation).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.optim.optimizers import Optimizer


def make_accumulating_train_step(loss_fn, optimizer: Optimizer, *,
                                 microbatches: int):
    """loss_fn: (params, batch) -> (loss, metrics_dict).

    Returns step(params, opt_state, batch) with batch leaves [B, ...] where
    B % microbatches == 0; microbatch axis is processed with lax.scan.
    """

    def grad_of(params, mb):
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, mb)
        return loss, metrics, grads

    def step(params, opt_state, batch):
        def split(x):
            b = x.shape[0]
            assert b % microbatches == 0, (b, microbatches)
            return x.reshape((microbatches, b // microbatches) + x.shape[1:])

        mbs = jax.tree.map(split, batch)

        def body(acc, mb):
            loss_sum, grad_acc = acc
            loss, _, grads = grad_of(params, mb)
            grad_acc = jax.tree.map(
                lambda a, g: a + g.astype(jnp.float32) / microbatches,
                grad_acc, grads)
            return (loss_sum + loss / microbatches, grad_acc), None

        zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (loss, grads), _ = jax.lax.scan(body, (jnp.zeros((), jnp.float32), zero), mbs)
        params, opt_state = optimizer.update(grads, opt_state, params)
        return params, opt_state, {"loss": loss}

    return step
