"""Minimal optimizer library (optax-like API, no external deps).

FedChain's algorithms are SGD-based, so the distributed training path defaults
to SGD(+momentum); AdamW is provided for the nonconvex baseline experiments.
Giant-arch dry-runs use plain SGD to stay inside HBM (see DESIGN.md §6).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable  # params -> state
    update: Callable  # (grads, state, params) -> (new_params, new_state)


def _tree_update(params, grads, fn):
    return jax.tree.map(fn, params, grads)


def sgd(lr: float, *, weight_decay: float = 0.0):
    def init(params):
        return ()

    def update(grads, state, params):
        def upd(p, g):
            g = g + weight_decay * p if weight_decay else g
            return (p - lr * g.astype(p.dtype)).astype(p.dtype)

        return _tree_update(params, grads, upd), state

    return Optimizer(init, update)


def momentum(lr: float, *, beta: float = 0.9, nesterov: bool = False,
             weight_decay: float = 0.0, momentum_dtype=jnp.float32):
    def init(params):
        return {"m": jax.tree.map(lambda p: jnp.zeros(p.shape, momentum_dtype), params)}

    def update(grads, state, params):
        def upd_m(m, g):
            return beta * m + g.astype(momentum_dtype)

        m = jax.tree.map(upd_m, state["m"], grads)

        def upd_p(p, g, mm):
            g32 = g.astype(momentum_dtype) + weight_decay * p.astype(momentum_dtype)
            step = beta * mm + g32 if nesterov else mm
            return (p.astype(momentum_dtype) - lr * step).astype(p.dtype)

        new_params = jax.tree.map(upd_p, params, grads, m)
        return new_params, {"m": m}

    return Optimizer(init, update)


def adamw(lr: float, *, b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.0):
    def init(params):
        z = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {
            "m": jax.tree.map(z, params),
            "v": jax.tree.map(z, params),
            "t": jnp.zeros((), jnp.int32),
        }

    def update(grads, state, params):
        t = state["t"] + 1
        m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g.astype(jnp.float32),
                         state["m"], grads)
        v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2) * jnp.square(g.astype(jnp.float32)),
                         state["v"], grads)
        bc1 = 1 - b1 ** t.astype(jnp.float32)
        bc2 = 1 - b2 ** t.astype(jnp.float32)

        def upd(p, m_, v_):
            step = (m_ / bc1) / (jnp.sqrt(v_ / bc2) + eps) + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * step).astype(p.dtype)

        return jax.tree.map(upd, params, m, v), {"m": m, "v": v, "t": t}

    return Optimizer(init, update)


def get_optimizer(name: str, lr: float, **kw) -> Optimizer:
    return {"sgd": sgd, "momentum": momentum, "adamw": adamw}[name](lr, **kw)


# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Schedule:
    """Stepwise-decay LR schedule (the paper's M- variants) + warmup."""

    base_lr: float
    warmup_steps: int = 0
    decay_every: Optional[int] = None
    decay_factor: float = 0.5

    def __call__(self, step):
        lr = jnp.asarray(self.base_lr, jnp.float32)
        if self.warmup_steps > 0:
            lr = lr * jnp.minimum(1.0, (step + 1) / self.warmup_steps)
        if self.decay_every:
            lr = lr * self.decay_factor ** (step // self.decay_every)
        return lr
