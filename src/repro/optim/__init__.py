from repro.optim.optimizers import Optimizer, Schedule, adamw, get_optimizer, momentum, sgd

__all__ = ["Optimizer", "Schedule", "adamw", "get_optimizer", "momentum", "sgd"]
