"""Round-loop runners: jit/scan execution of federated algorithms with
suboptimality trajectories, plus a stepsize-decay (multistage "M-") wrapper.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass
class RunResult:
    state: object  # final algorithm state
    x_hat: object  # algorithm's returned iterate
    history: jnp.ndarray  # [R] F(x̂_r) − F* after each round (of x̂, not x)
    grad_norms: Optional[jnp.ndarray] = None


def run(algo, problem, x0, rounds: int, key, *, eval_output: bool = True, jit: bool = True):
    """Run ``rounds`` communication rounds; record suboptimality each round."""
    f_star = problem.f_star if problem.f_star is not None else 0.0

    def one_round(state, k):
        state = algo.round(problem, state, k)
        x_eval = algo.output(state) if eval_output else state.x
        sub = problem.global_loss(x_eval) - f_star
        return state, sub

    def scan_all(state0, keys):
        return jax.lax.scan(one_round, state0, keys)

    state0 = algo.init(problem, x0)
    keys = jax.random.split(key, rounds)
    fn = jax.jit(scan_all) if jit else scan_all
    state, history = fn(state0, keys)
    return RunResult(state=state, x_hat=algo.output(state), history=history)


def run_with_decay(
    algo, problem, x0, rounds: int, key, *,
    decay_first: float = 0.3, decay_factor: float = 0.5, jit: bool = True,
):
    """The paper's "M-" stepsize-decay variants (App. I.1): halve η at
    R_decay = decay_first·R and again at every doubling of R_decay."""
    # decay boundaries: ceil(decay_first*R), 2x, 4x, ... up to R
    boundaries = []
    b = max(1, int(round(decay_first * rounds)))
    while b < rounds:
        boundaries.append(b)
        b *= 2
    segments = []
    prev = 0
    for b in boundaries:
        segments.append(b - prev)
        prev = b
    segments.append(rounds - prev)

    state = algo.init(problem, x0)
    f_star = problem.f_star if problem.f_star is not None else 0.0
    hist = []
    keys = jax.random.split(key, len(segments))

    def seg_fn(state0, ks):
        def one_round(st, k):
            st = algo.round(problem, st, k)
            sub = problem.global_loss(algo.output(st)) - f_star
            return st, sub

        return jax.lax.scan(one_round, state0, ks)

    seg_jit = jax.jit(seg_fn) if jit else seg_fn
    for i, seg in enumerate(segments):
        if seg <= 0:
            continue
        ks = jax.random.split(keys[i], seg)
        state, h = seg_jit(state, ks)
        hist.append(h)
        state = state._replace(eta=state.eta * decay_factor)
    history = jnp.concatenate(hist) if hist else jnp.zeros((0,))
    return RunResult(state=state, x_hat=algo.output(state), history=history)
