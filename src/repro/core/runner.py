"""Single-compile round executors for federated algorithms.

The round loop is one ``jax.lax.scan`` over a per-round *schedule*: PRNG keys
plus a stepsize multiplier ``eta_scale[r]`` applied to the state's base η each
round. Stepsize decay (the paper's "M-" variants, App. I.1) is therefore pure
data — the same compiled executor runs constant-η and decayed-η schedules.

Executors are cached at module level, keyed by ``(algo, problem, eval mode)``:
repeated ``run`` calls with the same algorithm on the same problem never
re-trace (the seed implementation re-jitted a fresh closure per call). The
cache also exposes the *unjitted* executor body so ``repro.core.sweep`` can
``vmap`` it over a seeds × stepsizes grid inside one compiled call.

State protocol (audited in ``algorithms.base``): every algorithm state is a
NamedTuple carrying ``.x`` (server iterate), ``.eta`` (base stepsize — the
executor owns annealing and restores the base after every round) and ``.r``
(round counter). ``round`` must pass ``eta`` through unchanged.

``TRACE_COUNTS`` increments once per executor *trace* (a Python side effect
inside the traced body) — tests assert single-compile behaviour with it.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

# Trace counter: the executor bodies bump this when (re)traced. A cached,
# single-compile executor leaves the count unchanged on repeated calls.
TRACE_COUNTS: collections.Counter = collections.Counter()

# (cache key) -> (problem, executor fn). The problem participates in the key
# by id() — FederatedProblem closes over arrays and is not hashable — and is
# held strongly in the entry so a hit can verify identity (guarding against
# id reuse). The cache is a bounded LRU: executors close over their problem's
# data, so unbounded growth would pin every problem ever run.
_EXECUTOR_CACHE: "collections.OrderedDict" = collections.OrderedDict()
_EXECUTOR_CACHE_MAX = 128


@dataclasses.dataclass
class RunResult:
    state: object  # final algorithm state
    x_hat: object  # algorithm's returned iterate
    history: jnp.ndarray  # [R] F(x̂_r) − F* after each round (of x̂, not x)
    grad_norms: Optional[jnp.ndarray] = None
    bits_up: Optional[jnp.ndarray] = None  # [R] per-round uplink bits (comm)
    bits_down: Optional[jnp.ndarray] = None  # [R] per-round downlink bits


def _env_key():
    """Trace-time environment baked into compiled executors: a cached
    executor traced under one kernel-dispatch mode must not be served under
    another (``REPRO_FORCE_PALLAS`` is read when the round body traces)."""
    from repro.kernels.aggregate import ops as agg_ops

    return agg_ops._force_pallas_env()


def _cache_get(key, problem):
    hit = _EXECUTOR_CACHE.get((key, _env_key()))
    if hit is not None:
        cached_problem, fn = hit
        if cached_problem is problem:
            _EXECUTOR_CACHE.move_to_end((key, _env_key()))
            return fn
    return None


def _cache_put(key, problem, fn):
    full = (key, _env_key())
    _EXECUTOR_CACHE[full] = (problem, fn)
    _EXECUTOR_CACHE.move_to_end(full)
    while len(_EXECUTOR_CACHE) > _EXECUTOR_CACHE_MAX:
        _EXECUTOR_CACHE.popitem(last=False)
    return fn


def clear_executor_cache():
    """Drop all cached executors (mainly for tests)."""
    _EXECUTOR_CACHE.clear()


def executor_body(algo, problem, eval_output: bool = True):
    """The unjitted single-compile executor.

    Returns ``fn(state0, keys, eta_scale) -> (state, history)`` scanning all
    rounds at once; ``keys`` is [R, 2] raw PRNG keys, ``eta_scale`` is [R]
    multipliers on the *base* stepsize carried in ``state0.eta``.
    """
    key = ("body", algo, id(problem), eval_output)
    fn = _cache_get(key, problem)
    if fn is not None:
        return fn

    f_star = problem.f_star if problem.f_star is not None else 0.0

    def executor(state0, keys, eta_scale):
        from repro.core.algorithms import base as algo_base

        algo_base.audit_state(state0)  # protocol check, once per trace
        TRACE_COUNTS[f"runner/{algo.name}"] += 1  # trace-time side effect
        base_eta = state0.eta

        def one_round(state, xs):
            k, scale = xs
            st = algo.round(problem, state._replace(eta=base_eta * scale), k)
            st = st._replace(eta=base_eta)  # executor owns annealing
            x_eval = algo.output(st) if eval_output else st.x
            sub = problem.global_loss(x_eval) - f_star
            return st, sub

        return jax.lax.scan(one_round, state0, (keys, eta_scale))

    return _cache_put(key, problem, executor)


def executor(algo, problem, eval_output: bool = True):
    """The jitted, module-cached executor (same signature as the body)."""
    key = ("jit", algo, id(problem), eval_output)
    fn = _cache_get(key, problem)
    if fn is not None:
        return fn
    return _cache_put(key, problem, jax.jit(executor_body(algo, problem, eval_output)))


def comm_executor_body(algo, problem, eval_output: bool = True):
    """The comm-enabled single-compile executor.

    Returns ``fn(state0, keys, eta_scale, masks) -> (state, (history,
    bits_up, bits_down))``. ``state0`` must carry a ``CommState`` in its
    ``comm`` leaf; ``masks`` is the [R, N] participation schedule — pure scan
    data, like the keys and η multipliers, so comm config (participation
    fraction, compressor, bit-width) never re-traces this executor.
    """
    key = ("comm-body", algo, id(problem), eval_output)
    fn = _cache_get(key, problem)
    if fn is not None:
        return fn

    f_star = problem.f_star if problem.f_star is not None else 0.0

    def executor(state0, keys, eta_scale, masks):
        from repro.comm import config as comm_cfg
        from repro.core.algorithms import base as algo_base

        algo_base.audit_state(state0)
        comm_cfg.comm_state_or_error(state0, algo.name)
        TRACE_COUNTS[f"runner-comm/{algo.name}"] += 1
        base_eta = state0.eta

        def one_round(state, xs):
            k, scale, mask = xs
            comm_in = comm_cfg.zero_round_bits(
                state.comm._replace(mask=mask))
            st = algo.round(
                problem, state._replace(eta=base_eta * scale, comm=comm_in), k)
            comm = comm_cfg.comm_state_or_error(st, algo.name)
            st = st._replace(eta=base_eta)
            x_eval = algo.output(st) if eval_output else st.x
            sub = problem.global_loss(x_eval) - f_star
            return st, (sub, comm.bits_up, comm.bits_down)

        return jax.lax.scan(one_round, state0, (keys, eta_scale, masks))

    return _cache_put(key, problem, executor)


def comm_executor(algo, problem, eval_output: bool = True):
    """The jitted, module-cached comm executor."""
    key = ("comm-jit", algo, id(problem), eval_output)
    fn = _cache_get(key, problem)
    if fn is not None:
        return fn
    return _cache_put(
        key, problem, jax.jit(comm_executor_body(algo, problem, eval_output)))


def run(algo, problem, x0, rounds: int, key, *, eval_output: bool = True,
        jit: bool = True, eta=None, comm=None, comm_masks=None):
    """Run ``rounds`` communication rounds; record suboptimality each round.

    ``eta`` overrides the state's base stepsize (used by the sweep engine's
    per-run comparator); ``None`` keeps the algorithm's own initialization.
    ``comm`` (a ``repro.comm.CommConfig``) enables the communication layer:
    compressed uplinks, the per-round participation schedule (``comm_masks``
    overrides the config-derived [R, N] masks) and exact bits accounting in
    the result's ``bits_up``/``bits_down``.
    """
    state0 = algo.init_with_eta(problem, x0, eta)
    keys = jax.random.split(key, rounds)
    eta_scale = jnp.ones((rounds,), jnp.float32)
    if comm is not None:
        from repro.comm import config as comm_cfg

        comm_cfg.require_flat(x0)
        comm_cfg.require_comm_leaf(state0, algo.name)
        n = problem.num_clients
        masks = (comm.round_masks(rounds, n) if comm_masks is None
                 else jnp.asarray(comm_masks, jnp.float32))
        state0 = state0._replace(comm=comm.init_state(n, x0.shape[0]))
        fn = (comm_executor if jit else comm_executor_body)(
            algo, problem, eval_output)
        state, (history, bits_up, bits_down) = fn(
            state0, keys, eta_scale, masks)
        return RunResult(state=state, x_hat=algo.output(state),
                         history=history, bits_up=bits_up,
                         bits_down=bits_down)
    fn = (executor if jit else executor_body)(algo, problem, eval_output)
    state, history = fn(state0, keys, eta_scale)
    return RunResult(state=state, x_hat=algo.output(state), history=history)


def decay_segments(rounds: int, decay_first: float = 0.3):
    """Segment lengths of the App. I.1 decay schedule (sum == rounds).

    Boundaries at ceil(decay_first·R) and every doubling thereof.
    """
    boundaries = []
    b = max(1, int(round(decay_first * rounds)))
    while b < rounds:
        boundaries.append(b)
        b *= 2
    segments = []
    prev = 0
    for b in boundaries:
        segments.append(b - prev)
        prev = b
    segments.append(rounds - prev)
    return segments


def decay_eta_scale(rounds: int, decay_first: float = 0.3,
                    decay_factor: float = 0.5) -> jnp.ndarray:
    """Per-round η multipliers implementing the "M-" stepsize decay."""
    segments = decay_segments(rounds, decay_first)
    scales = []
    for i, seg in enumerate(segments):
        if seg > 0:
            scales.append(jnp.full((seg,), decay_factor**i, jnp.float32))
    return jnp.concatenate(scales) if scales else jnp.zeros((0,), jnp.float32)


def run_with_decay(
    algo, problem, x0, rounds: int, key, *,
    decay_first: float = 0.3, decay_factor: float = 0.5, jit: bool = True,
    eta=None,
):
    """The paper's "M-" stepsize-decay variants (App. I.1): halve η at
    R_decay = decay_first·R and again at every doubling of R_decay.

    Runs through the SAME compiled executor as ``run`` — decay is schedule
    data (``eta_scale``), not a re-traced per-segment loop.
    """
    segments = decay_segments(rounds, decay_first)
    seg_keys = jax.random.split(key, len(segments))
    keys = jnp.concatenate([
        jax.random.split(seg_keys[i], seg)
        for i, seg in enumerate(segments) if seg > 0
    ]) if rounds > 0 else jnp.zeros((0, 2), jnp.uint32)
    eta_scale = decay_eta_scale(rounds, decay_first, decay_factor)

    state0 = algo.init_with_eta(problem, x0, eta)
    fn = (executor if jit else executor_body)(algo, problem, True)
    state, history = fn(state0, keys, eta_scale)
    # final state carries the fully-annealed stepsize, as the segment loop did
    n_applied = sum(1 for seg in segments if seg > 0)
    state = state._replace(eta=state0.eta * decay_factor**n_applied)
    return RunResult(state=state, x_hat=algo.output(state), history=history)
