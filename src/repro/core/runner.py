"""Single-compile round executors for federated algorithms.

The round loop is one ``jax.lax.scan`` over a per-round *schedule*: PRNG keys
plus a stepsize multiplier ``eta_scale[r]`` applied to the state's base η each
round. Stepsize decay (the paper's "M-" variants, App. I.1) is therefore pure
data — the same compiled executor runs constant-η and decayed-η schedules.

Problems are executor OPERANDS. Every executor takes a leading ``spec``
argument (a ``repro.data.spec.ProblemSpec`` pytree — arrays only, family
dispatch is static metadata): the cache key is the spec's *structural*
identity (family tag + static fields + leaf shapes/dtypes), never the
instance, so re-running any same-shaped problem — a whole ζ/σ grid of them —
reuses ONE compile. Legacy hand-closure problems (``FederatedProblem`` with
``spec=None``) still run: their executors close over the problem and are
keyed by an id-reuse-safe weak token; callers pass ``spec=None``.

The executor cache holds ``(key, fn)`` ONLY. Spec-path entries capture no
problem data at all (the spec rides in as an argument), so evicting or
caching an executor never pins client data shards; tokens for legacy
problems are weak references.

State protocol (audited in ``algorithms.base``): every algorithm state is a
NamedTuple carrying ``.x`` (server iterate), ``.eta`` (base stepsize — the
executor owns annealing and restores the base after every round) and ``.r``
(round counter). ``round`` must pass ``eta`` through unchanged.

``method_executor_body`` stacks SEVERAL method instances with matching state
structure (e.g. SGD at three ``mu_avg`` values, FedAvg at two local-step
counts) into one executor: the per-round dispatch is a ``lax.switch`` over
the method index — an operand — so the sweep engine vmaps methods × seeds ×
stepsizes through one compile (``core.sweep.run_method_sweep``).

``TRACE_COUNTS`` increments once per executor *trace* (a Python side effect
inside the traced body) — tests assert single-compile behaviour with it.
"""
from __future__ import annotations

import collections
import contextlib
import dataclasses
import itertools
import warnings
import weakref
from typing import Optional

import jax
import jax.numpy as jnp

# The jitted executors donate their call-private operands (scan-carry
# state0, per-cell key/mask stacks — see core.sweep's memory model). CPU
# has no donation support, so JAX warns once per call that the donated
# buffers went unused; that is expected on CPU hosts and pure noise here.
warnings.filterwarnings(
    "ignore", message="Some donated buffers were not usable")


def dealias_donated(donated, *others):
    """Copy any leaf of ``donated`` whose buffer also backs a protected
    array in ``others`` — the call's other operands (XLA refuses to execute
    when a donated buffer appears twice among the arguments) and any
    caller-owned arrays like ``x0`` that the fresh state stores by
    reference (donation would delete them out from under the caller).
    Aliases only arise from object reuse at init time, so object identity
    is the right test; fresh arrays pass through untouched and nothing is
    copied on the common path."""
    seen = set()
    for t in others:
        for leaf in jax.tree.leaves(t):
            if isinstance(leaf, jax.Array):
                seen.add(id(leaf))

    def dealias(leaf):
        if isinstance(leaf, jax.Array):
            if id(leaf) in seen:
                return jnp.array(leaf, copy=True)
            seen.add(id(leaf))
        return leaf

    return jax.tree.map(dealias, donated)

# Trace counter: the executor bodies bump this when (re)traced. A cached,
# single-compile executor leaves the count unchanged on repeated calls.
TRACE_COUNTS: collections.Counter = collections.Counter()


def snapshot_traces() -> dict:
    """A point-in-time copy of ``TRACE_COUNTS`` for later ``trace_deltas``."""
    return dict(TRACE_COUNTS)


def trace_deltas(before: dict) -> dict:
    """TRACE_COUNTS movement since the ``before`` snapshot (nonzero only)."""
    return {k: v - before.get(k, 0) for k, v in TRACE_COUNTS.items()
            if v != before.get(k, 0)}


class _TraceProbe:
    """Exposes ``.deltas`` (the TRACE_COUNTS movement) after the
    ``assert_no_retrace`` block exits."""

    deltas: dict = {}


@contextlib.contextmanager
def assert_no_retrace(traced=(), *, what: str = "with-block"):
    """Assert executor-trace discipline across the block.

    Each counter named in ``traced`` must move by EXACTLY one (the block
    pays that executor's single compile) and every other ``TRACE_COUNTS``
    entry must not move at all. ``traced=()`` is the warm contract: zero
    movement anywhere — re-running an already-compiled grid, swapping
    operands (problems, comm configs, policies) at a fixed structure, or a
    repeat call of any cached executor must all pass it.

    Yields a probe whose ``.deltas`` holds the observed movement at exit,
    for tests that want to report or further inspect the counters.
    """
    probe = _TraceProbe()
    before = dict(TRACE_COUNTS)
    yield probe
    probe.deltas = deltas = trace_deltas(before)
    traced = tuple(traced)
    problems = [f"{name!r} traced {deltas.get(name, 0)} times "
                f"(expected exactly 1)"
                for name in traced if deltas.get(name, 0) != 1]
    extra = {k: v for k, v in deltas.items() if k not in traced}
    if extra:
        problems.append(f"unexpected re-traces: {extra}")
    if problems:
        raise AssertionError(
            f"trace discipline violated across {what}: "
            + "; ".join(problems))


# jaxpr-audit hook (``repro.analysis.jaxpr_audit``): while ``AUDIT_SINK`` is
# a list, every top-level call of a cached executor records
# ``(cache_key, fn, args, kwargs)`` so the audit can re-trace the EXACT
# executor object on its real operands and walk the ClosedJaxpr consts.
# Calls made during tracing (the unjitted bodies run inside jit/vmap with
# Tracer arguments) are skipped — recording them would leak tracers.
AUDIT_SINK: Optional[list] = None

# cache key -> executor fn. A bounded LRU; entries hold NO problem objects
# (spec-path executors take the problem as an operand; legacy closure
# executors capture their problem themselves, which is exactly the lifetime
# the closure path implies).
_EXECUTOR_CACHE: "collections.OrderedDict" = collections.OrderedDict()
_EXECUTOR_CACHE_MAX = 128

# Legacy problems are keyed by a token that is unique per live object and
# never reused while the object is alive: ids are validated through a weak
# reference and the table entry dies with the problem (no strong refs).
_TOKEN_COUNTER = itertools.count()
_PROBLEM_TOKENS: dict = {}


@dataclasses.dataclass
class RunResult:
    state: object  # final algorithm state
    x_hat: object  # algorithm's returned iterate
    history: jnp.ndarray  # [R] F(x̂_r) − F* after each round (of x̂, not x)
    grad_norms: Optional[jnp.ndarray] = None
    bits_up: Optional[jnp.ndarray] = None  # [R] per-round uplink bits (comm)
    bits_down: Optional[jnp.ndarray] = None  # [R] per-round downlink bits
    diagnostics: Optional[dict] = None  # per-round taps ([R] leaves), obs


def _env_key():
    """Trace-time environment baked into compiled executors: a cached
    executor traced under one kernel-dispatch mode must not be served under
    another (``REPRO_FORCE_PALLAS`` is read when the round body traces)."""
    from repro.kernels.aggregate import ops as agg_ops

    return agg_ops._force_pallas_env()


def as_spec(problem):
    """The operand form of a problem: the ProblemSpec itself, a shim's
    ``.spec``, or None for legacy hand-closure problems."""
    if getattr(problem, "is_problem_spec", False):
        return problem
    return getattr(problem, "spec", None)


def _problem_token(problem) -> int:
    pid = id(problem)
    entry = _PROBLEM_TOKENS.get(pid)
    if entry is not None:
        ref, token = entry
        if ref() is problem:
            return token
    token = next(_TOKEN_COUNTER)
    ref = weakref.ref(problem,
                      lambda _, pid=pid: _PROBLEM_TOKENS.pop(pid, None))
    _PROBLEM_TOKENS[pid] = (ref, token)
    return token


def problem_key(problem):
    """The problem's contribution to an executor cache key: structural for
    specs (shapes, never identity), a weak identity token for legacy
    closures."""
    spec = as_spec(problem)
    if spec is not None:
        return ("spec", spec.cache_key())
    return ("closure", _problem_token(problem))


def f_star_operand(problem):
    """The F* the executors subtract. For specs this is the ``f_star``
    CONSTANT LEAF (an operand — 0.0 when unknown, making histories raw
    objective values; the explicit-fallback warning lives in
    ``suboptimality``). For legacy problems it is the baked float."""
    spec = as_spec(problem)
    if spec is not None:
        return spec.f_star_leaf
    return problem.f_star if problem.f_star is not None else 0.0


def _obs_emit(kind, **fields):
    """Forward one cache/compile event to the obs event log — a None-check
    no-op unless ``repro.obs.events`` has a recorder installed."""
    from repro.obs import events as obs_events

    obs_events.emit(kind, **fields)


def _cache_get(key):
    full = (key, _env_key())
    fn = _EXECUTOR_CACHE.get(full)
    if fn is not None:
        _EXECUTOR_CACHE.move_to_end(full)
        _obs_emit("cache", op="hit", family=key[0])
    else:
        _obs_emit("cache", op="miss", family=key[0])
    return fn


def _audit_wrap(key, fn):
    def wrapped(*args, **kwargs):
        concrete = not any(
            isinstance(leaf, jax.core.Tracer)
            for leaf in jax.tree.leaves((args, kwargs)))
        if AUDIT_SINK is not None and concrete:
            AUDIT_SINK.append((key, fn, args, kwargs))
        if concrete:
            from repro.obs import events as obs_events

            if obs_events.RECORDER is not None:
                return obs_events.observed_call(key, fn, args, kwargs)
        return fn(*args, **kwargs)

    return wrapped


def _cache_put(key, fn):
    full = (key, _env_key())
    fn = _audit_wrap(key, fn)
    _EXECUTOR_CACHE[full] = fn
    _EXECUTOR_CACHE.move_to_end(full)
    _obs_emit("cache", op="put", family=key[0])
    while len(_EXECUTOR_CACHE) > _EXECUTOR_CACHE_MAX:
        evicted, _ = _EXECUTOR_CACHE.popitem(last=False)
        _obs_emit("cache", op="evict", family=evicted[0][0])
    return fn


def clear_executor_cache():
    """Drop all cached executors (mainly for tests)."""
    _EXECUTOR_CACHE.clear()


def _bind(problem):
    """(spec, resolve) where ``resolve(spec_op)`` yields the problem an
    executor body should query: the traced spec operand on the spec path, or
    the captured legacy problem (spec_op is then None) on the closure path."""
    spec = as_spec(problem)
    if spec is not None:
        return spec, (lambda spec_op: spec_op)
    return None, (lambda spec_op: problem)


def executor_body(algo, problem, eval_output: bool = True, telemetry=None):
    """The unjitted single-compile executor.

    Returns ``fn(spec, state0, keys, eta_scale) -> (state, history)``
    scanning all rounds at once; ``spec`` is the problem operand (None for
    legacy closure problems), ``keys`` is [R, 2] raw PRNG keys, ``eta_scale``
    is [R] multipliers on the *base* stepsize carried in ``state0.eta``.

    ``telemetry`` (a ``repro.obs.Telemetry``, part of the cache key like
    ``eval_output``) switches the scan output to ``(history, taps)`` where
    ``taps`` is the per-round diagnostics dict; ``None`` traces exactly the
    pre-telemetry jaxpr.
    """
    key = ("body", algo, problem_key(problem), eval_output, telemetry)
    fn = _cache_get(key)
    if fn is not None:
        return fn

    _, resolve = _bind(problem)

    def executor(spec, state0, keys, eta_scale):
        from repro.core.algorithms import base as algo_base
        from repro.obs import events as obs_events
        from repro.obs import telemetry as obs_tel

        p = resolve(spec)
        algo_base.audit_state(state0)  # protocol check, once per trace
        TRACE_COUNTS[f"runner/{algo.name}"] += 1  # trace-time side effect
        obs_events.TRACE_EVENTS[f"runner/{algo.name}"] += 1
        f_star = f_star_operand(p)
        base_eta = state0.eta

        def one_round(state, xs):
            k, scale = xs
            st = algo.round(p, state._replace(eta=base_eta * scale), k)
            st = st._replace(eta=base_eta)  # executor owns annealing
            x_eval = algo.output(st) if eval_output else st.x
            sub = p.global_loss(x_eval) - f_star
            if telemetry is None:
                return st, sub
            taps = obs_tel.round_taps(
                telemetry, problem=p, prev_x=state.x, new_x=st.x,
                x_eval=x_eval)
            return st, (sub, taps)

        return jax.lax.scan(one_round, state0, (keys, eta_scale))

    return _cache_put(key, executor)


def executor(algo, problem, eval_output: bool = True, telemetry=None):
    """The jitted, module-cached executor (same signature as the body).

    ``state0`` (argnum 1) is DONATED: it is the scan carry, dead the moment
    the scan starts, so donation-capable backends reuse its buffers for the
    output state instead of copying. Callers must build it fresh per call
    (``run``/``run_with_decay`` do). The donated argnums are part of the
    cache key.
    """
    donate = (1,)
    key = ("jit", algo, problem_key(problem), eval_output, telemetry, donate)
    fn = _cache_get(key)
    if fn is not None:
        return fn
    return _cache_put(key, jax.jit(
        executor_body(algo, problem, eval_output, telemetry),
        donate_argnums=donate))


def comm_executor_body(algo, problem, eval_output: bool = True,
                       telemetry=None):
    """The comm-enabled single-compile executor.

    Returns ``fn(spec, state0, keys, eta_scale, masks) -> (state, (history,
    bits_up, bits_down))``. ``state0`` must carry a ``CommState`` in its
    ``comm`` leaf; ``masks`` is the [R, N] participation schedule — pure scan
    data, like the keys and η multipliers, so comm config (participation
    fraction, compressor, bit-width) never re-traces this executor.

    With ``telemetry`` set the scan emits ``(history, bits_up, bits_down,
    taps)`` — the taps include the EF residual norms of all three CommPlan
    legs and the per-round participation count.
    """
    key = ("comm-body", algo, problem_key(problem), eval_output, telemetry)
    fn = _cache_get(key)
    if fn is not None:
        return fn

    _, resolve = _bind(problem)

    def executor(spec, state0, keys, eta_scale, masks):
        from repro.comm import config as comm_cfg
        from repro.core.algorithms import base as algo_base
        from repro.obs import events as obs_events
        from repro.obs import telemetry as obs_tel

        p = resolve(spec)
        algo_base.audit_state(state0)
        comm_cfg.comm_state_or_error(state0, algo.name)
        TRACE_COUNTS[f"runner-comm/{algo.name}"] += 1
        obs_events.TRACE_EVENTS[f"runner-comm/{algo.name}"] += 1
        f_star = f_star_operand(p)
        base_eta = state0.eta

        def one_round(state, xs):
            k, scale, mask = xs
            comm_in = comm_cfg.zero_round_bits(
                state.comm._replace(mask=mask))
            st = algo.round(
                p, state._replace(eta=base_eta * scale, comm=comm_in), k)
            comm = comm_cfg.comm_state_or_error(st, algo.name)
            st = st._replace(eta=base_eta)
            x_eval = algo.output(st) if eval_output else st.x
            sub = p.global_loss(x_eval) - f_star
            if telemetry is None:
                return st, (sub, comm.bits_up, comm.bits_down)
            taps = obs_tel.round_taps(
                telemetry, problem=p, prev_x=state.x, new_x=st.x,
                x_eval=x_eval, comm=comm, mask=mask, bits_up=comm.bits_up,
                bits_down=comm.bits_down)
            return st, (sub, comm.bits_up, comm.bits_down, taps)

        return jax.lax.scan(one_round, state0, (keys, eta_scale, masks))

    return _cache_put(key, executor)


def comm_executor(algo, problem, eval_output: bool = True, telemetry=None):
    """The jitted, module-cached comm executor. ``state0`` is donated like
    the plain executor's (the [R, N] masks are NOT — ``run`` forwards
    user-supplied ``comm_masks`` arrays there)."""
    donate = (1,)
    key = ("comm-jit", algo, problem_key(problem), eval_output, telemetry,
           donate)
    fn = _cache_get(key)
    if fn is not None:
        return fn
    return _cache_put(key, jax.jit(
        comm_executor_body(algo, problem, eval_output, telemetry),
        donate_argnums=donate))


def selection_executor_body(algo, problem, eval_output: bool = True,
                            telemetry=None):
    """The policy-selection single-compile executor.

    Returns ``fn(spec, state0, keys, eta_scale, sel_keys, pparams, pstate0)
    -> ((state, pstate), (history, bits_up, bits_down, masks))``.  Instead
    of a precomputed [R, N] mask schedule, each round's participation mask
    is produced in-scan by ``selection.policies.round_select`` from the
    policy operand ``pparams`` (a ``PolicyParams`` of jnp scalars — the
    policy choice is a ``lax.switch`` index, so swapping policies or their
    hyperparameters never re-traces) and the policy state ``pstate0``
    (``PolicyState`` pytree leaves carried through the scan).  The mask
    feeds the comm ledger unchanged; probing policies additionally bill
    their value-probe uplink via ``policies.probe_bits``.

    With ``telemetry`` set the scan additionally emits the per-round taps
    dict (policy-state summaries included) as a trailing output.
    """
    key = ("sel-body", algo, problem_key(problem), eval_output, telemetry)
    fn = _cache_get(key)
    if fn is not None:
        return fn

    _, resolve = _bind(problem)

    def executor(spec, state0, keys, eta_scale, sel_keys, pparams, pstate0):
        from repro.comm import config as comm_cfg
        from repro.core.algorithms import base as algo_base
        from repro.obs import events as obs_events
        from repro.obs import telemetry as obs_tel
        from repro.selection import policies as pol

        p = resolve(spec)
        algo_base.audit_state(state0)
        comm_cfg.comm_state_or_error(state0, algo.name)
        TRACE_COUNTS[f"runner-sel/{algo.name}"] += 1
        obs_events.TRACE_EVENTS[f"runner-sel/{algo.name}"] += 1
        f_star = f_star_operand(p)
        base_eta = state0.eta
        extra_up = pol.probe_bits(pparams, p.num_clients)

        def one_round(carry, xs):
            state, pstate = carry
            k, scale, k_sel = xs
            mask, pstate = pol.round_select(p, state.x, pstate, pparams,
                                            k_sel)
            comm_in = comm_cfg.zero_round_bits(
                state.comm._replace(mask=mask))
            st = algo.round(
                p, state._replace(eta=base_eta * scale, comm=comm_in), k)
            comm = comm_cfg.comm_state_or_error(st, algo.name)
            comm = comm._replace(bits_up=comm.bits_up + extra_up)
            st = st._replace(eta=base_eta, comm=comm)
            x_eval = algo.output(st) if eval_output else st.x
            sub = p.global_loss(x_eval) - f_star
            if telemetry is None:
                return (st, pstate), (sub, comm.bits_up, comm.bits_down,
                                      mask)
            taps = obs_tel.round_taps(
                telemetry, problem=p, prev_x=state.x, new_x=st.x,
                x_eval=x_eval, comm=comm, mask=mask, pstate=pstate,
                bits_up=comm.bits_up, bits_down=comm.bits_down)
            return (st, pstate), (sub, comm.bits_up, comm.bits_down, mask,
                                  taps)

        return jax.lax.scan(one_round, (state0, pstate0),
                            (keys, eta_scale, sel_keys))

    return _cache_put(key, executor)


def method_executor_body(methods, problem, eval_output: bool = True):
    """The multi-method stacked executor (one compile for several methods).

    ``methods`` is a tuple of algorithm instances whose states share one
    pytree structure (e.g. one class at different hyperparameters — SGD at
    several ``mu_avg``, FedAvg at several local-step counts). Returns
    ``fn(spec, state0, keys, eta_scale, midx) -> (state, history)`` where
    ``midx`` selects the method via ``lax.switch`` each round — an operand,
    so the sweep engine vmaps it alongside seeds and stepsizes.
    """
    methods = tuple(methods)
    tag = "+".join(m.name for m in methods)
    key = ("methods-body", methods, problem_key(problem), eval_output)
    fn = _cache_get(key)
    if fn is not None:
        return fn

    _, resolve = _bind(problem)

    def executor(spec, state0, keys, eta_scale, midx):
        from repro.core.algorithms import base as algo_base
        from repro.obs import events as obs_events

        p = resolve(spec)
        algo_base.audit_state(state0)
        TRACE_COUNTS[f"runner-methods/{tag}"] += 1
        obs_events.TRACE_EVENTS[f"runner-methods/{tag}"] += 1
        f_star = f_star_operand(p)
        base_eta = state0.eta

        def _output(st):
            if not eval_output:
                return st.x
            return jax.lax.switch(
                midx, [lambda s, m=m: m.output(s) for m in methods], st)

        def one_round(state, xs):
            k, scale = xs
            st_in = state._replace(eta=base_eta * scale)
            st = jax.lax.switch(
                midx,
                [lambda args, m=m: m.round(p, args[0], args[1])
                 for m in methods],
                (st_in, k))
            st = st._replace(eta=base_eta)
            sub = p.global_loss(_output(st)) - f_star
            return st, sub

        return jax.lax.scan(one_round, state0, (keys, eta_scale))

    return _cache_put(key, executor)


def run(algo, problem, x0, rounds: int, key, *, eval_output: bool = True,
        jit: bool = True, eta=None, comm=None, comm_masks=None,
        telemetry=None):
    """Run ``rounds`` communication rounds; record suboptimality each round.

    ``eta`` overrides the state's base stepsize (used by the sweep engine's
    per-run comparator); ``None`` keeps the algorithm's own initialization.
    ``comm`` (a ``repro.comm.CommConfig``) enables the communication layer:
    compressed uplinks, the per-round participation schedule (``comm_masks``
    overrides the config-derived [R, N] masks) and exact bits accounting in
    the result's ``bits_up``/``bits_down``. ``telemetry`` (a
    ``repro.obs.Telemetry``) additionally returns the per-round taps in the
    result's ``diagnostics`` ([R]-shaped leaves); ``None`` is bitwise
    identical to a run without the telemetry layer.
    """
    spec = as_spec(problem)
    state0 = algo.init_with_eta(problem, x0, eta)
    keys = jax.random.split(key, rounds)
    eta_scale = jnp.ones((rounds,), jnp.float32)
    if comm is not None:
        from repro.comm import config as comm_cfg

        comm_cfg.require_comm_leaf(state0, algo.name)
        n = problem.num_clients
        masks = (comm.round_masks(rounds, n) if comm_masks is None
                 else jnp.asarray(comm_masks, jnp.float32))
        state0 = state0._replace(comm=comm.init_state(n, x0))
        # x0/eta are caller-owned and typically stored BY REFERENCE in the
        # fresh state — they must survive the donation
        state0 = dealias_donated(state0, spec, keys, eta_scale, masks,
                                 x0, eta)
        fn = (comm_executor if jit else comm_executor_body)(
            algo, problem, eval_output, telemetry)
        if telemetry is None:
            state, (history, bits_up, bits_down) = fn(
                spec, state0, keys, eta_scale, masks)
            taps = None
        else:
            state, (history, bits_up, bits_down, taps) = fn(
                spec, state0, keys, eta_scale, masks)
        return RunResult(state=state, x_hat=algo.output(state),
                         history=history, bits_up=bits_up,
                         bits_down=bits_down, diagnostics=taps)
    fn = (executor if jit else executor_body)(algo, problem, eval_output,
                                              telemetry)
    state0 = dealias_donated(state0, spec, keys, eta_scale, x0, eta)
    if telemetry is None:
        state, history = fn(spec, state0, keys, eta_scale)
        taps = None
    else:
        state, (history, taps) = fn(spec, state0, keys, eta_scale)
    return RunResult(state=state, x_hat=algo.output(state), history=history,
                     diagnostics=taps)


def decay_segments(rounds: int, decay_first: float = 0.3):
    """Segment lengths of the App. I.1 decay schedule (sum == rounds).

    Boundaries at ceil(decay_first·R) and every doubling thereof.
    """
    boundaries = []
    b = max(1, int(round(decay_first * rounds)))
    while b < rounds:
        boundaries.append(b)
        b *= 2
    segments = []
    prev = 0
    for b in boundaries:
        segments.append(b - prev)
        prev = b
    segments.append(rounds - prev)
    return segments


def decay_eta_scale(rounds: int, decay_first: float = 0.3,
                    decay_factor: float = 0.5) -> jnp.ndarray:
    """Per-round η multipliers implementing the "M-" stepsize decay."""
    segments = decay_segments(rounds, decay_first)
    scales = []
    for i, seg in enumerate(segments):
        if seg > 0:
            scales.append(jnp.full((seg,), decay_factor**i, jnp.float32))
    return jnp.concatenate(scales) if scales else jnp.zeros((0,), jnp.float32)


def run_with_decay(
    algo, problem, x0, rounds: int, key, *,
    decay_first: float = 0.3, decay_factor: float = 0.5, jit: bool = True,
    eta=None,
):
    """The paper's "M-" stepsize-decay variants (App. I.1): halve η at
    R_decay = decay_first·R and again at every doubling of R_decay.

    Runs through the SAME compiled executor as ``run`` — decay is schedule
    data (``eta_scale``), not a re-traced per-segment loop.
    """
    segments = decay_segments(rounds, decay_first)
    seg_keys = jax.random.split(key, len(segments))
    keys = jnp.concatenate([
        jax.random.split(seg_keys[i], seg)
        for i, seg in enumerate(segments) if seg > 0
    ]) if rounds > 0 else jnp.zeros((0, 2), jnp.uint32)
    eta_scale = decay_eta_scale(rounds, decay_first, decay_factor)

    state0 = algo.init_with_eta(problem, x0, eta)
    # the annealed final stepsize is derived BEFORE the executor call:
    # state0 is donated to the jit, so its buffers must not be read after
    n_applied = sum(1 for seg in segments if seg > 0)
    eta_final = state0.eta * decay_factor**n_applied
    fn = (executor if jit else executor_body)(algo, problem, True)
    spec = as_spec(problem)
    state0 = dealias_donated(state0, spec, keys, eta_scale, x0, eta)
    state, history = fn(spec, state0, keys, eta_scale)
    # final state carries the fully-annealed stepsize, as the segment loop did
    state = state._replace(eta=eta_final)
    return RunResult(state=state, x_hat=algo.output(state), history=history)
