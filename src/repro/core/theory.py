"""Executable convergence-rate bounds — the paper's Tables 1, 2 and 4 plus the
Thm. 5.4 / Cor. 5.5 lower bounds, as plain functions of the problem constants.

These are *order* bounds (Õ hides polylog factors and absolute constants); the
benchmarks and tests use them for ordering/regime checks, not exact values.
Every formula cites its table row.
"""
from __future__ import annotations

import dataclasses
import math


@dataclasses.dataclass(frozen=True)
class Constants:
    delta: float  # Δ, initial suboptimality (B.9)
    d: float  # D, initial distance (B.10)
    mu: float
    beta: float
    zeta: float
    sigma: float = 0.0
    n: int = 8  # N clients
    s: int = 8  # S sampled per round
    k: int = 16  # oracle calls per client per round

    @property
    def kappa(self):
        return self.beta / self.mu if self.mu > 0 else math.inf

    @property
    def part_frac(self):
        """(1 − S/N) sampling-heterogeneity factor."""
        return max(0.0, 1.0 - self.s / self.n)


def _sampling_term_sc(c: Constants, r: int) -> float:
    """(1 − S/N)·ζ²/(μSR) — strongly convex sampling error."""
    if c.mu <= 0:
        return math.inf
    return c.part_frac * c.zeta**2 / (c.mu * c.s * r)


def _variance_term_sc(c: Constants, r: int) -> float:
    if c.mu <= 0:
        return math.inf
    return c.sigma**2 / (c.mu * c.s * c.k * r)


# --------------------------- Table 1: strongly convex ----------------------

def sgd_strongly_convex(c: Constants, r: int) -> float:
    """Δ·exp(−R/κ) + σ²/(μSKR) + (1−S/N)·ζ²/(μSR)   (Thm. D.1)."""
    return c.delta * math.exp(-r / c.kappa) + _variance_term_sc(c, r) + _sampling_term_sc(c, r)


def asg_strongly_convex(c: Constants, r: int) -> float:
    """Δ·exp(−R/√κ) + σ²/(μSKR) + (1−S/N)·ζ²/(μSR)  (Thm. D.3)."""
    return c.delta * math.exp(-r / c.kappa**0.5) + _variance_term_sc(c, r) + _sampling_term_sc(c, r)


def fedavg_strongly_convex(c: Constants, r: int) -> float:
    """κ·(ζ²/μ)·R⁻² (Woodworth et al. 2020a row of Table 1; σ-term omitted
    per the paper's footnote 2 — made negligible by large K)."""
    return c.kappa * (c.zeta**2 / c.mu) / r**2 + c.sigma**2 / (c.mu * c.k**0.5)


def fedavg_sgd_strongly_convex(c: Constants, r: int) -> float:
    """FedChain FedAvg→SGD (Thm. 4.1): min{Δ, ζ²/μ}·exp(−R/κ) + (1−S/N)ζ²/(μSR)."""
    head = min(c.delta, c.zeta**2 / c.mu) * math.exp(-r / c.kappa)
    return head + _variance_term_sc(c, r) + _sampling_term_sc(c, r)


def fedavg_asg_strongly_convex(c: Constants, r: int) -> float:
    """FedChain FedAvg→ASG (Thm. 4.2): min{Δ, ζ²/μ}·exp(−R/√κ) + (1−S/N)ζ²/(μSR)."""
    head = min(c.delta, c.zeta**2 / c.mu) * math.exp(-r / c.kappa**0.5)
    return head + _variance_term_sc(c, r) + _sampling_term_sc(c, r)


def fedavg_saga_strongly_convex(c: Constants, r: int) -> float:
    """FedChain FedAvg→SAGA (Thm. 4.3), requires R ≥ N/S:
    min{Δ, ζ²/μ}·exp(−min{1/κ, S/N}·R)  — no sampling term."""
    rate = min(1.0 / c.kappa, c.s / c.n)
    return min(c.delta, c.zeta**2 / c.mu) * math.exp(-rate * r) + _variance_term_sc(c, r)


def fedavg_ssnm_strongly_convex(c: Constants, r: int) -> float:
    """FedChain FedAvg→SSNM (Thm. 4.4): κ·min{Δ,ζ²/μ}·exp(−min{S/N, √(S/(Nκ))}·R)."""
    rate = min(c.s / c.n, (c.s / (c.n * c.kappa)) ** 0.5)
    return c.kappa * min(c.delta, c.zeta**2 / c.mu) * math.exp(-rate * r)


def lower_bound_strongly_convex(c: Constants, r: int, *, algo_c: float = 1.0) -> float:
    """Thm. 5.4: Ω(min{Δ, (1/(cκ^{3/2}))·ζ²/β}·exp(−R/√κ)).

    (App. G Eq. 332 has constant 18 in the exponent; we keep the clean −R/√κ
    form of the theorem statement and treat constants as 1.)
    """
    head = min(c.delta, c.zeta**2 / (algo_c * c.kappa**1.5 * c.beta))
    return head * math.exp(-r / c.kappa**0.5)


# --------------------------- Table 2: general convex -----------------------

def sgd_convex(c: Constants, r: int) -> float:
    return c.beta * c.d**2 / r + c.part_frac**0.5 * c.zeta * c.d / (c.s * r) ** 0.5


def asg_convex(c: Constants, r: int) -> float:
    return c.beta * c.d**2 / r**2 + c.part_frac**0.5 * c.zeta * c.d / (c.s * r) ** 0.5


def fedavg_convex(c: Constants, r: int) -> float:
    """Woodworth et al. 2020a row: (β ζ² D⁴ / R²)^{1/3}."""
    return (c.beta * c.zeta**2 * c.d**4 / r**2) ** (1.0 / 3.0)


def fedavg_sgd_convex(c: Constants, r: int) -> float:
    """Thm. 4.1 general convex."""
    head = min(c.beta * c.d**2 / r, (c.beta * c.zeta * c.d**3) ** 0.5 / r**0.5)
    tail = c.part_frac**0.25 * (c.beta * c.zeta * c.d**3) ** 0.5 / (c.s * r) ** 0.25
    return head + tail


def fedavg_asg_convex(c: Constants, r: int) -> float:
    """Thm. 4.2 general convex."""
    head = min(c.beta * c.d**2 / r**2, (c.beta * c.zeta * c.d**3) ** 0.5 / r)
    tail = (
        c.part_frac**0.5 * c.zeta * c.d / (c.s * r) ** 0.5
        + c.part_frac**0.25 * (c.beta * c.zeta * c.d**3) ** 0.5 / (c.s * r) ** 0.25
    )
    return head + tail


def lower_bound_convex(c: Constants, r: int, *, algo_c: float = 1.0) -> float:
    """Thm. 5.4 (μ=0): Ω(min{βD²/R², ζD/(√c·R^{5/2})})."""
    return min(c.beta * c.d**2 / r**2, c.zeta * c.d / (algo_c**0.5 * r**2.5))


# --------------------------- Table 4: PL -----------------------------------

def sgd_pl(c: Constants, r: int) -> float:
    return (
        c.delta * math.exp(-r / c.kappa)
        + c.kappa * c.sigma**2 / (c.mu * c.s * c.k * r)
        + c.part_frac * c.kappa * c.zeta**2 / (c.mu * c.s * r)
    )


def fedavg_pl(c: Constants, r: int) -> float:
    """Karimireddy et al. 2020a row: κΔ·exp(−R/κ) + κ²ζ²/(μR²)."""
    return c.kappa * c.delta * math.exp(-r / c.kappa) + c.kappa**2 * c.zeta**2 / (c.mu * r**2)


def fedavg_sgd_pl(c: Constants, r: int) -> float:
    """Thm. 4.1 PL: min{Δ, ζ²/μ}·exp(−R/κ) + (1−S/N)κζ²/(μSR)."""
    head = min(c.delta, c.zeta**2 / c.mu) * math.exp(-r / c.kappa)
    return head + c.part_frac * c.kappa * c.zeta**2 / (c.mu * c.s * r)


def fedavg_saga_pl(c: Constants, r: int) -> float:
    """Thm. 4.3 PL: min{Δ, ζ²/μ}·exp(−R/(κ(N/S)^{2/3}))."""
    return min(c.delta, c.zeta**2 / c.mu) * math.exp(-r / (c.kappa * (c.n / c.s) ** (2.0 / 3.0)))


def lower_bound_pl(c: Constants, r: int, *, algo_c: float = 1.0) -> float:
    """Cor. 5.5 — same as the strongly convex lower bound."""
    return lower_bound_strongly_convex(c, r, algo_c=algo_c)


TABLE1 = {
    "sgd": sgd_strongly_convex,
    "asg": asg_strongly_convex,
    "fedavg": fedavg_strongly_convex,
    "fedavg->sgd": fedavg_sgd_strongly_convex,
    "fedavg->asg": fedavg_asg_strongly_convex,
    "fedavg->saga": fedavg_saga_strongly_convex,
    "fedavg->ssnm": fedavg_ssnm_strongly_convex,
    "lower_bound": lower_bound_strongly_convex,
}

TABLE2 = {
    "sgd": sgd_convex,
    "asg": asg_convex,
    "fedavg": fedavg_convex,
    "fedavg->sgd": fedavg_sgd_convex,
    "fedavg->asg": fedavg_asg_convex,
    "lower_bound": lower_bound_convex,
}

TABLE4 = {
    "sgd": sgd_pl,
    "fedavg": fedavg_pl,
    "fedavg->sgd": fedavg_sgd_pl,
    "fedavg->saga": fedavg_saga_pl,
    "lower_bound": lower_bound_pl,
}
