"""FedChain core: the paper's contribution as a composable JAX module."""
from repro.core import algorithms, chain, heterogeneity, lower_bound, runner, selection, sweep, theory, tree_math
from repro.core.chain import Chain, fedchain
from repro.core.sweep import (
    SweepResult, run_fraction_sweep, run_method_sweep, run_sweep,
)

__all__ = [
    "algorithms", "chain", "heterogeneity", "lower_bound", "runner",
    "selection", "sweep", "theory", "tree_math", "Chain", "fedchain",
    "SweepResult", "run_fraction_sweep", "run_method_sweep", "run_sweep",
]
