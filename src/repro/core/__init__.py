"""FedChain core: the paper's contribution as a composable JAX module."""
from repro.core import algorithms, chain, heterogeneity, lower_bound, runner, selection, theory, tree_math
from repro.core.chain import Chain, fedchain

__all__ = [
    "algorithms", "chain", "heterogeneity", "lower_bound", "runner",
    "selection", "theory", "tree_math", "Chain", "fedchain",
]
