"""The App. G lower-bound construction (Thm. 5.4) as an executable problem.

Two quadratic clients on R^d (d even):

  F₁(x) = −ℓ₂·ζ̂·x₁ + (C·ℓ₂/2)·x_d² + (ℓ₂/2)·Σ_{i=1}^{d/2−1}(x_{2i+1} − x_{2i})²
          + (μ/2)·||x||²
  F₂(x) = (ℓ₂/2)·Σ_{i=1}^{d/2}(x_{2i} − x_{2i−1})² + (μ/2)·||x||²
  F = (F₁ + F₂)/2

with α = √(1 + 2ℓ₂/μ), q = (α−1)/(α+1), C = 1 − q. Key properties (App. G):

  * F, F₁, F₂ are μ-strongly convex and β-smooth for ℓ₂ ≤ (β−μ)/4;
  * the zero-chain property (Eqs. 276–277): from span{e₁..e_{2i}} only ∇F₁
    unlocks coordinate 2i+1, and from span{e₁..e_{2i−1}} only ∇F₂ unlocks 2i
    ⇒ any distributed zero-respecting algorithm gains ≤ 1 coordinate per
    communication round (Lemma G.4);
  * x*_j = (ζ̂/(1−q))·q^j  and  F(x̂) − F* ≥ (μ ζ̂² q²/(16(1−q)²(1−q²)))·q^{2R}.

Indices above are the paper's 1-based maths; code is 0-based.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class LowerBoundInstance:
    dim: int
    ell2: float
    mu: float
    zeta_hat: float

    @property
    def alpha(self):
        return (1.0 + 2.0 * self.ell2 / self.mu) ** 0.5

    @property
    def q(self):
        a = self.alpha
        return (a - 1.0) / (a + 1.0)

    @property
    def c_coef(self):
        return 1.0 - self.q

    # ---- objectives -------------------------------------------------------
    def f1(self, x):
        l2, mu, zh, c = self.ell2, self.mu, self.zeta_hat, self.c_coef
        d = self.dim
        # pairs (x_{2i+1} - x_{2i}) for i = 1..d/2-1  -> 0-based (x[2i] - x[2i-1]),
        # i.e. odd->even couplings: x[2], x[1]; x[4], x[3]; ...
        odd_even = x[2::2] - x[1:-1:2]  # length d/2 - 1
        return (
            -l2 * zh * x[0]
            + 0.5 * c * l2 * x[d - 1] ** 2
            + 0.5 * l2 * jnp.sum(odd_even**2)
            + 0.5 * mu * jnp.sum(x**2)
        )

    def f2(self, x):
        l2, mu = self.ell2, self.mu
        # pairs (x_{2i} - x_{2i-1}) for i = 1..d/2 -> 0-based (x[2i-1] - x[2i-2])
        even_odd = x[1::2] - x[0::2][: self.dim // 2]
        return 0.5 * l2 * jnp.sum(even_odd**2) + 0.5 * mu * jnp.sum(x**2)

    def f(self, x):
        return 0.5 * (self.f1(x) + self.f2(x))

    # ---- known solution ----------------------------------------------------
    def x_star(self):
        """x*_j = (ζ̂/(1−q))·q^j (1-based j), from App. G.2 / Woodworth'21."""
        j = jnp.arange(1, self.dim + 1, dtype=jnp.float32)
        return (self.zeta_hat / (1.0 - self.q)) * self.q**j

    def f_star(self):
        # the closed form above is asymptotic in d; evaluate F at a numerically
        # exact solution instead (solve the quadratic's normal equations).
        h = jax.hessian(self.f)(jnp.zeros(self.dim))
        g0 = jax.grad(self.f)(jnp.zeros(self.dim))
        xs = jnp.linalg.solve(h, -g0)
        return self.f(xs), xs

    def suboptimality_lb(self, rounds: int):
        """F(x̂) − F* ≥ (μ ζ̂² q² / (16(1−q)²(1−q²)))·q^{2R}  (App. G.4)."""
        q = self.q
        return (self.mu * self.zeta_hat**2 * q**2 / (16 * (1 - q) ** 2 * (1 - q**2))) * q ** (
            2 * rounds
        )

    def initial_gap_ub(self):
        """F(0) − F* ≤ q·ℓ₂·ζ̂²/(4(1−q))  (App. G.3)."""
        return self.q * self.ell2 * self.zeta_hat**2 / (4 * (1 - self.q))


def make_lower_bound_problem(
    *, dim: int = 64, beta: float = 1.0, mu: float = 0.01, zeta_hat: float = 1.0,
    num_clients: int = 2, sigma: float = 0.0,
):
    """Wrap the two-client instance as a FederatedProblem (noiseless oracles by
    default — the lower bound assumes deterministic gradients)."""
    from repro.data.problems import FederatedProblem  # local import: avoids cycle

    assert dim % 2 == 0
    ell2 = (beta - mu) / 4.0
    inst = LowerBoundInstance(dim=dim, ell2=ell2, mu=mu, zeta_hat=zeta_hat)
    f_star, x_star = inst.f_star()

    losses = [inst.f1, inst.f2]

    def client_loss(x, i):
        return jax.lax.switch(i % 2, losses, x)

    def global_loss(x):
        return inst.f(x)

    def grad_oracle(x, i, rng):
        g = jax.grad(client_loss)(x, i)
        if sigma > 0:
            g = g + (sigma / jnp.sqrt(dim)) * jax.random.normal(rng, (dim,))
        return g

    def value_oracle(x, i, rng):
        del rng
        return client_loss(x, i)

    def init_params(rng):
        del rng
        return jnp.zeros((dim,))

    problem = FederatedProblem(
        num_clients=num_clients,
        grad_oracle=grad_oracle,
        value_oracle=value_oracle,
        client_loss=client_loss,
        global_loss=global_loss,
        init_params=init_params,
        mu=mu,
        beta=beta,
        zeta=0.0,  # the construction's ζ is position-dependent; see Def. 5.3
        sigma=sigma,
        f_star=float(f_star),
        x_star=x_star,
        name=f"lower_bound(d={dim},beta={beta},mu={mu})",
    )
    return problem, inst


def support(v, tol: float = 1e-12):
    """supp(v) as a boolean mask."""
    return jnp.abs(v) > tol


def max_unlocked_coordinate(x, tol: float = 1e-12) -> int:
    """Highest nonzero coordinate index + 1 (= |E_i| of Lemma G.4)."""
    mask = support(x, tol)
    idx = jnp.where(mask, jnp.arange(x.shape[0]) + 1, 0)
    return int(jnp.max(idx))
