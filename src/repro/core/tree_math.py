"""Pytree arithmetic helpers.

Every federated algorithm in this package operates on arbitrary parameter
pytrees (vectors for the theory problems, nested dicts for neural nets), so
all linear-algebra-on-parameters goes through these helpers.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def tree_add(a, b):
    return jax.tree.map(jnp.add, a, b)


def tree_sub(a, b):
    return jax.tree.map(jnp.subtract, a, b)


def tree_scale(s, a):
    return jax.tree.map(lambda x: s * x, a)


def tree_axpy(s, a, b):
    """b + s * a  (elementwise)."""
    return jax.tree.map(lambda x, y: y + s * x, a, b)


def tree_lerp(t, a, b):
    """(1 - t) * a + t * b."""
    return jax.tree.map(lambda x, y: (1.0 - t) * x + t * y, a, b)


def tree_zeros_like(a):
    return jax.tree.map(jnp.zeros_like, a)


def tree_dot(a, b):
    # sum(x*y), not vdot: XLA:CPU lowers batched dots with a batch-size-
    # dependent reduction blocking; multiply-then-sum is batch-invariant,
    # which the sharded sweep engine (repro.dist) relies on for bitwise
    # equality with the vmapped engine.
    leaves = jax.tree.leaves(
        jax.tree.map(lambda x, y: jnp.sum(x * y), a, b))
    return sum(leaves) if leaves else jnp.asarray(0.0)


def tree_sq_norm(a):
    return tree_dot(a, a)


def tree_norm(a):
    return jnp.sqrt(tree_sq_norm(a))


def tree_mean_leading(a):
    """Mean over a leading (stacked-clients) axis of every leaf."""
    return jax.tree.map(lambda x: jnp.mean(x, axis=0), a)


def tree_index(a, i):
    """Select index ``i`` along the leading axis of every leaf."""
    return jax.tree.map(lambda x: x[i], a)


def tree_dynamic_index(a, i):
    return jax.tree.map(lambda x: jax.lax.dynamic_index_in_dim(x, i, keepdims=False), a)


def tree_stack(trees):
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def tree_broadcast_leading(a, n):
    """Tile a pytree along a new leading axis of size ``n``."""
    return jax.tree.map(lambda x: jnp.broadcast_to(x[None], (n,) + x.shape), a)


def tree_where(pred, a, b):
    return jax.tree.map(lambda x, y: jnp.where(pred, x, y), a, b)


def tree_scatter_set(table, idx, values):
    """table.at[idx].set(values) leafwise; idx is a vector of leading indices."""
    return jax.tree.map(lambda t, v: t.at[idx].set(v), table, values)


def tree_random_like(key, a, scale=1.0):
    """Gaussian noise pytree with the structure/shape of ``a``."""
    leaves, treedef = jax.tree.flatten(a)
    keys = jax.random.split(key, len(leaves))
    noisy = [
        scale * jax.random.normal(k, x.shape, jnp.result_type(x, jnp.float32))
        for k, x in zip(keys, leaves)
    ]
    return jax.tree.unflatten(treedef, noisy)


def tree_size(a):
    return sum(x.size for x in jax.tree.leaves(a))


def tree_leaf_dims(a):
    """Per-leaf element counts (static): the shape signature the leaf-wise
    comm subsystem bills bits over — ``(D,)`` for a flat vector."""
    return tuple(int(x.size) for x in jax.tree.leaves(a))


def tree_ravel_rows(a):
    """Flatten each leaf [S, ...] to [S, d_leaf] (kernel-boundary layout).

    A no-op reshape on already-2D leaves, so flat-[D] comm paths stay
    bitwise identical to the pre-pytree implementation.
    """
    return jax.tree.map(lambda x: x.reshape(x.shape[0], -1), a)


def tree_unravel_rows(a2d, template):
    """Inverse of ``tree_ravel_rows``: reshape [S, d_leaf] leaves back to the
    template's [S, ...] leaf shapes."""
    return jax.tree.map(lambda x, t: x.reshape(t.shape), a2d, template)


def tree_bcast_rows(rows, a):
    """Broadcast a per-row vector [S] against every leaf [S, ...] of ``a`` —
    returns a pytree of [S, 1, …, 1]-shaped views aligned leaf-by-leaf."""
    return jax.tree.map(
        lambda x: rows.reshape(rows.shape + (1,) * (x.ndim - 1)), a)


def tree_cast(a, dtype):
    return jax.tree.map(lambda x: x.astype(dtype), a)


def ravel(a):
    """Flatten a pytree to a single vector (for diagnostics / checkpoints)."""
    return jnp.concatenate([jnp.ravel(x) for x in jax.tree.leaves(a)]) if jax.tree.leaves(a) else jnp.zeros((0,))
