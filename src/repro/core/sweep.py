"""Vmapped sweep engine: one compiled call for seeds × stepsizes × problems.

FedChain's experiment grids (Tables 1–4, Fig. 2) repeat the same algorithm
over seeds, stepsizes, heterogeneity levels ζ, noise levels σ and problem
instances. ``run_sweep`` vmaps the single-compile executors from
``runner``/``chain`` over all of these axes and jits the whole grid, so a
P × S × E sweep costs ONE trace + one device dispatch instead of P·S·E
re-traced round loops.

Problems are OPERANDS (``repro.data.spec.ProblemSpec``): sweep functions are
cached per ``(algo-or-chain, problem STRUCTURE, rounds)`` — family tag +
shapes, never instance identity — so repeated sweeps across ζ values, σ
values or fresh instances never re-trace, and the ``problems=`` axis batches
a stacked spec (``spec.stack_specs``) through the same compiled cell.

Stepsize semantics
------------------
* Plain algorithms, ``eta_mode="absolute"`` (default): each grid value is the
  stepsize itself (``state.eta = η``), matching ``runner.run(..., eta=η)``.
* Plain algorithms, ``eta_mode="scale"``: grid values multiply the state's
  own initialized stepsize — use this for algorithms that derive η from
  problem constants (e.g. SSNM's Thm. D.5 stepsize).
* Chains: grid values are always *multipliers* applied to every stage's base
  stepsize (a chain has one η per stage, so an absolute grid is ambiguous),
  matching ``Chain.run(..., eta_scale=m)``.

Because η lives in algorithm state (the uniform state protocol of
``algorithms.base``), batching stepsizes is just a batched ``state.eta`` leaf
— no algorithm code is sweep-aware.

Multi-method stacking
---------------------
``run_method_sweep`` batches SEVERAL method instances whose states share one
pytree structure (SGD at several ``mu_avg``, FedAvg at several local-step
counts, mixed output modes, …) into one compiled call: the method index is
an operand dispatched by ``lax.switch`` inside the executor
(``runner.method_executor_body``), riding the same uniform-state protocol
that batches η — the methods axis is just a stacked state plus an index.
Cost model: stacking trades COMPILES for FLOPs. Because the switch index is
batched, vmap evaluates every branch and selects, so each grid row runs all
M methods' rounds (M× device work vs a per-method loop, which — thanks to
structural executor caching — pays at most M compiles). Stack when traces
dominate (many short cold-path configs); loop per method for long warm
grids.

Communication sweeps
--------------------
``run_sweep(..., comm=CommConfig(...))`` threads the communication subsystem
(``repro.comm``) through every grid cell: uplinks are compressed, per-round
participation masks (one independent [R, N] schedule per seed) ride the scan
as data, and ``SweepResult.bits_up``/``bits_down`` record the exact per-round
wire cost — the suboptimality-vs-bits frontier. All comm knobs are operands:
switching compressor, bit-width or participation fraction reuses the same
compiled grid (``runner.TRACE_COUNTS`` stays flat). Comm composes with the
``problems=`` axis — mask schedules batch per (problem, seed) cell (fold
p·S + s of the config's mask seed) and the ``CommState`` rides the vmapped
state like any other leaf, so a bits-accounted ζ×σ frontier over a whole
problem grid is still ONE compile. Parameters may be arbitrary pytrees
(vision MLPs): the comm layer operates leaf-wise (``repro.comm``).

Decay sweeps: stepsize-decay multipliers are an executor *operand* (PR-2),
so ``run_decay_sweep`` batches a ``decay_factor`` grid through one compile
of the same chain executor ``run_sweep`` uses. Local-fraction sweeps
(``run_fraction_sweep``) go further: the chain's whole per-round schedule —
stage assignment, selection placement, key streams — is an operand
(``Chain.fraction_executor_body``), so the App. I.2 tuning grid rides one
compile too.

Device sharding
---------------
Grid cells are built by the ``make_*_cell`` factories below and batched two
ways from the same cells: the vmapped engine here (a flattened problems ×
seeds cells axis × a dense stepsize axis), or sharded over a ``('grid',)``
device mesh via ``run_sweep(..., mesh=...)`` / ``run_fraction_sweep(...,
mesh=...)`` (``repro.dist.grid``), which partitions the identical cell
stacks across devices with ``shard_map`` — bitwise the same results, one
compile either way.

Memory model
------------
Operand layouts. A ``problems=`` sweep flattens problems × seeds into one
cells axis (c = p·S + s, the ``repro.dist.partition`` contract). Two
operand layouts feed it:

* ``operand_layout="indexed"`` (default) — ONE O(P) stacked spec (and one
  [P, …] x0 stack) rides the call unbatched, plus a per-cell int32 problem
  index ``pidx[c] = c // S``; each cell gathers its own spec leaves
  (``make_indexed_cell``). Spec-operand memory is O(P) regardless of the
  seed count.
* ``operand_layout="stacked"`` — the historical layout: every spec data
  leaf materialized once per cell (``jnp.repeat`` along the cells axis),
  O(P·S) operand memory. Kept as the reference the indexed path is tested
  bitwise against (``benchmarks/memory_bench.py`` measures both).

The in-cell gather is exact (a gather of identical rows), and every
per-cell op is batch-invariant, so the two layouts are BITWISE identical —
on the vmapped engine here and on the sharded one (where the indexed
layout replicates the O(P) stack across shards and shards only ``pidx``).

Donation contract. Every jitted executor donates its call-private operands
(``jax.jit(..., donate_argnums=...)``): the scan-carry state0/states0 in
``runner``/``chain`` executors, and the per-cell key/mask/index/stepsize
stacks here — never ``spec``/``x0`` (caller-owned; donating them would
invalidate the user's arrays on donation-capable backends). Callers of the
cached executors must therefore pass freshly built arrays for the donated
positions — everything ``run_sweep`` constructs per call. On CPU donation
is a no-op (JAX's "donated buffers were not usable" warning is filtered in
``runner``).

Executor cache keys. The executor LRU (``runner._EXECUTOR_CACHE``) keys
every jitted grid on (algo/chain identity, problem STRUCTURE, rounds,
flags, operand layout, donated argnums) plus the Pallas-dispatch env — so
switching layout or donation never silently reuses a stale compile, and
numeric knobs (ζ, σ, compressor, …) never force a new one.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import chain as chain_lib
from repro.core import runner as runner_lib
from repro.core import tree_math as tm


@dataclasses.dataclass
class SweepResult:
    """Results over the grid.

    Leading axes are ``[n_seeds, n_etas]``; a ``problems=`` sweep prepends a
    problem axis (``[n_problems, n_seeds, n_etas]``) and a
    ``run_method_sweep`` prepends a method axis (``[n_methods, …]``) —
    ``problems``/``methods`` are set accordingly.
    """

    history: jnp.ndarray  # [..., S, E, R] per-round suboptimality
    final_sub: jnp.ndarray  # [..., S, E] F(x̂) − F* at the end
    x_hat: object  # pytree, leaves [..., S, E, ...]
    seeds: tuple
    etas: tuple
    selected_initial: Optional[jnp.ndarray] = None  # [..., S, E, n_sel]
    bits_up: Optional[jnp.ndarray] = None  # [S, E, R] per-round uplink bits
    bits_down: Optional[jnp.ndarray] = None  # [S, E, R] downlink bits
    problems: Optional[tuple] = None  # problem names along the leading axis
    methods: Optional[tuple] = None  # method names along the leading axis
    diagnostics: Optional[dict] = None  # per-round obs taps, leaves [..., R]

    def cumulative_bits(self):
        """[S, E, R] total (up + down) bits through each round, float64 —
        the x-axis of a cost-vs-accuracy frontier."""
        if self.bits_up is None:
            raise ValueError("not a comm sweep: no bits were accounted")
        per_round = (np.asarray(self.bits_up, np.float64)
                     + np.asarray(self.bits_down, np.float64))
        return np.cumsum(per_round, axis=-1)


def make_algo_cell(algo, problem, rounds: int, eval_output: bool,
                   eta_mode: str, tag: str, telemetry=None):
    """ONE grid cell of a plain-algorithm sweep: ``cell(spec, x0, key, eta)``.

    The vmapped engine below and the sharded engine (``repro.dist.grid``)
    both build their grids from these cell factories, so a sharded sweep
    runs bit-for-bit the same per-cell computation as the single-device one
    — only the batching around the cell differs. ``tag`` names the
    ``TRACE_COUNTS`` entry the cell bumps when traced. A non-None
    ``telemetry`` (``repro.obs.Telemetry``) appends the per-round taps dict
    as a trailing output.
    """
    from repro.obs import events as obs_events

    body = runner_lib.executor_body(algo, problem, eval_output, telemetry)
    _, resolve = runner_lib._bind(problem)
    eta_scale = jnp.ones((rounds,), jnp.float32)

    def cell(spec, x0, key, eta):
        p = resolve(spec)
        runner_lib.TRACE_COUNTS[f"{tag}/{algo.name}"] += 1
        obs_events.TRACE_EVENTS[f"{tag}/{algo.name}"] += 1
        state0 = algo.init(p, x0)
        new_eta = (state0.eta * eta if eta_mode == "scale"
                   else jnp.asarray(eta, jnp.result_type(state0.eta)))
        state0 = state0._replace(eta=new_eta)
        keys = jax.random.split(key, rounds)
        if telemetry is None:
            state, history = body(spec, state0, keys, eta_scale)
        else:
            state, (history, taps) = body(spec, state0, keys, eta_scale)
        x_hat = algo.output(state)
        sub = p.global_loss(x_hat) - runner_lib.f_star_operand(p)
        if telemetry is None:
            return x_hat, history, sub
        return x_hat, history, sub, taps

    return cell


def make_algo_comm_cell(algo, problem, rounds: int, eval_output: bool,
                        eta_mode: str, tag: str, telemetry=None):
    """Comm-enabled cell: ``cell(spec, x0, key, eta, masks, comm0)``."""
    from repro.obs import events as obs_events

    body = runner_lib.comm_executor_body(algo, problem, eval_output,
                                         telemetry)
    _, resolve = runner_lib._bind(problem)
    eta_scale = jnp.ones((rounds,), jnp.float32)

    def cell(spec, x0, key, eta, masks, comm0):
        p = resolve(spec)
        runner_lib.TRACE_COUNTS[f"{tag}/{algo.name}"] += 1
        obs_events.TRACE_EVENTS[f"{tag}/{algo.name}"] += 1
        state0 = algo.init(p, x0)
        new_eta = (state0.eta * eta if eta_mode == "scale"
                   else jnp.asarray(eta, jnp.result_type(state0.eta)))
        state0 = state0._replace(eta=new_eta, comm=comm0)
        keys = jax.random.split(key, rounds)
        if telemetry is None:
            state, (history, bits_up, bits_down) = body(
                spec, state0, keys, eta_scale, masks)
        else:
            state, (history, bits_up, bits_down, taps) = body(
                spec, state0, keys, eta_scale, masks)
        x_hat = algo.output(state)
        sub = p.global_loss(x_hat) - runner_lib.f_star_operand(p)
        if telemetry is None:
            return x_hat, history, sub, bits_up, bits_down
        return x_hat, history, sub, bits_up, bits_down, taps

    return cell


def make_chain_cell(chain, problem, rounds: int, tag: str, telemetry=None):
    """Chain cell: ``cell(spec, x0, key, mult, eta_scale)``."""
    from repro.obs import events as obs_events

    body = chain.executor_body(problem, rounds, telemetry=telemetry)
    _, resolve = runner_lib._bind(problem)
    sel_idx = jnp.asarray(chain._schedule(rounds).sel_indices, jnp.int32)

    def cell(spec, x0, key, mult, eta_scale):
        p = resolve(spec)
        runner_lib.TRACE_COUNTS[f"{tag}/{chain.name}"] += 1
        obs_events.TRACE_EVENTS[f"{tag}/{chain.name}"] += 1
        states0 = chain.init_states(p, x0, eta_scale=mult)
        if telemetry is None:
            x_hat, history, kept = body(spec, x0, states0, key, eta_scale)
        else:
            x_hat, history, kept, taps = body(spec, x0, states0, key,
                                              eta_scale)
        sub = p.global_loss(x_hat) - runner_lib.f_star_operand(p)
        if telemetry is None:
            return x_hat, history, sub, kept[sel_idx]
        return x_hat, history, sub, kept[sel_idx], taps

    return cell


def make_chain_comm_cell(chain, problem, rounds: int, tag: str,
                         telemetry=None):
    """Comm-enabled chain cell:
    ``cell(spec, x0, key, mult, eta_scale, masks, comm0)``."""
    from repro.obs import events as obs_events

    body = chain.executor_body(problem, rounds, comm=True,
                               telemetry=telemetry)
    _, resolve = runner_lib._bind(problem)
    sel_idx = jnp.asarray(chain._schedule(rounds).sel_indices, jnp.int32)

    def cell(spec, x0, key, mult, eta_scale, masks, comm0):
        p = resolve(spec)
        runner_lib.TRACE_COUNTS[f"{tag}/{chain.name}"] += 1
        obs_events.TRACE_EVENTS[f"{tag}/{chain.name}"] += 1
        states0 = chain.init_states(p, x0, eta_scale=mult)
        if telemetry is None:
            x_hat, history, kept, bits_up, bits_down = body(
                spec, x0, states0, key, eta_scale, masks, comm0)
        else:
            x_hat, history, kept, bits_up, bits_down, taps = body(
                spec, x0, states0, key, eta_scale, masks, comm0)
        sub = p.global_loss(x_hat) - runner_lib.f_star_operand(p)
        if telemetry is None:
            return x_hat, history, sub, kept[sel_idx], bits_up, bits_down
        return (x_hat, history, sub, kept[sel_idx], bits_up, bits_down,
                taps)

    return cell


def make_chain_fraction_cell(chain, problem, rounds: int, tag: str):
    """Local-fraction-sweep cell over operand schedules:
    ``cell(spec, x0, keys_r, keys_s, stage_id, kind, hmode, eta_scale)``.
    Returns the FULL [R] kept-flags row (selection positions differ per
    fraction, so callers gather them per schedule)."""
    from repro.obs import events as obs_events

    body = chain.fraction_executor_body(problem, rounds)
    _, resolve = runner_lib._bind(problem)

    def cell(spec, x0, keys_r, keys_s, stage_id, kind, hmode, eta_scale):
        p = resolve(spec)
        runner_lib.TRACE_COUNTS[f"{tag}/{chain.name}"] += 1
        obs_events.TRACE_EVENTS[f"{tag}/{chain.name}"] += 1
        states0 = chain.init_states(p, x0)
        x_hat, history, kept = body(spec, x0, states0, keys_r, keys_s,
                                    stage_id, kind, hmode, eta_scale)
        sub = p.global_loss(x_hat) - runner_lib.f_star_operand(p)
        return x_hat, history, sub, kept

    return cell


def make_selection_algo_cell(algo, problem, rounds: int, eval_output: bool,
                             eta_mode: str, tag: str, telemetry=None):
    """Policy-selection cell:
    ``cell(spec, x0, pparams, pstate0, key, eta, sel_keys, comm0)``.

    The policy (``PolicyParams``) and its initial state (``PolicyState``)
    are leading operands so the policy-index adapter
    (``make_policy_cell``) can gather them per cell exactly like the
    problem stacks."""
    from repro.obs import events as obs_events

    body = runner_lib.selection_executor_body(algo, problem, eval_output,
                                              telemetry)
    _, resolve = runner_lib._bind(problem)
    eta_scale = jnp.ones((rounds,), jnp.float32)

    def cell(spec, x0, pparams, pstate0, key, eta, sel_keys, comm0):
        p = resolve(spec)
        runner_lib.TRACE_COUNTS[f"{tag}/{algo.name}"] += 1
        obs_events.TRACE_EVENTS[f"{tag}/{algo.name}"] += 1
        state0 = algo.init(p, x0)
        new_eta = (state0.eta * eta if eta_mode == "scale"
                   else jnp.asarray(eta, jnp.result_type(state0.eta)))
        state0 = state0._replace(eta=new_eta, comm=comm0)
        keys = jax.random.split(key, rounds)
        if telemetry is None:
            (state, pstate), (history, bits_up, bits_down, masks) = body(
                spec, state0, keys, eta_scale, sel_keys, pparams, pstate0)
        else:
            (state, pstate), (history, bits_up, bits_down, masks,
                              taps) = body(
                spec, state0, keys, eta_scale, sel_keys, pparams, pstate0)
        x_hat = algo.output(state)
        sub = p.global_loss(x_hat) - runner_lib.f_star_operand(p)
        if telemetry is None:
            return x_hat, history, sub, bits_up, bits_down, masks, pstate
        return (x_hat, history, sub, bits_up, bits_down, masks, pstate,
                taps)

    return cell


def make_selection_chain_cell(chain, problem, rounds: int, tag: str,
                              telemetry=None):
    """Policy-selection chain cell:
    ``cell(spec, x0, pparams, pstate0, key, mult, eta_sched, sel_keys,
    comm0)``."""
    from repro.obs import events as obs_events

    body = chain.selection_executor_body(problem, rounds,
                                         telemetry=telemetry)
    _, resolve = runner_lib._bind(problem)
    sel_idx = jnp.asarray(chain._schedule(rounds).sel_indices, jnp.int32)

    def cell(spec, x0, pparams, pstate0, key, mult, eta_sched, sel_keys,
             comm0):
        p = resolve(spec)
        runner_lib.TRACE_COUNTS[f"{tag}/{chain.name}"] += 1
        obs_events.TRACE_EVENTS[f"{tag}/{chain.name}"] += 1
        states0 = chain.init_states(p, x0, eta_scale=mult)
        if telemetry is None:
            x_hat, history, kept, bits_up, bits_down, masks, pstate = body(
                spec, x0, states0, key, eta_sched, sel_keys, pparams,
                pstate0, comm0)
        else:
            (x_hat, history, kept, bits_up, bits_down, masks, pstate,
             taps) = body(
                spec, x0, states0, key, eta_sched, sel_keys, pparams,
                pstate0, comm0)
        sub = p.global_loss(x_hat) - runner_lib.f_star_operand(p)
        if telemetry is None:
            return (x_hat, history, sub, kept[sel_idx], bits_up, bits_down,
                    masks, pstate)
        return (x_hat, history, sub, kept[sel_idx], bits_up, bits_down,
                masks, pstate, taps)

    return cell


_OPERAND_LAYOUTS = ("indexed", "stacked")


def check_operand_layout(layout: str) -> str:
    """Validate an ``operand_layout`` value (shared with the sharded
    engine)."""
    if layout not in _OPERAND_LAYOUTS:
        raise ValueError(f"operand_layout must be one of "
                         f"{_OPERAND_LAYOUTS}, got {layout!r}")
    return layout


def make_indexed_cell(cell):
    """O(P) operand adapter around a ``make_*_cell`` cell: the cell's
    leading ``(spec, x0, …)`` operands become ``(spec_stack, x0_stack,
    pidx, …)`` with an in-cell gather of the problem's own leaves.

    Under the engines' batching only ``pidx`` is per-cell (batched /
    shard-sharded) while the stacks ride unbatched (replicated), so spec
    operand memory is O(P) instead of O(P·S). The gather pulls identical
    rows to what the stacked layout materializes per cell, and every
    per-cell op is batch-invariant, so results are bitwise identical.
    """
    def indexed_cell(spec_stack, x0_stack, pidx, *rest):
        spec = jax.tree.map(lambda l: l[pidx], spec_stack)
        x0 = jax.tree.map(lambda l: l[pidx], x0_stack)
        return cell(spec, x0, *rest)

    return indexed_cell


def problem_index_operand(n_probs: int, n_seeds: int) -> jnp.ndarray:
    """The per-cell problem index of the flattened cells axis:
    ``pidx[c] = c // S`` for c = p·S + s (``repro.dist.partition``)."""
    return jnp.arange(n_probs * n_seeds, dtype=jnp.int32) // n_seeds


def build_problem_operands(stacked, x0_stack, keys, n_probs: int,
                           n_seeds: int, layout: str = "indexed"):
    """Materialize the flattened problems × seeds cell operands for the
    vmapped engine (shared with ``benchmarks/memory_bench.py``).

    Returns ``(spec_op, x0_op, pidx, keys_c)``: the indexed layout keeps
    the O(P) stacks and adds an int32 [P·S] problem index; the stacked
    layout repeats every spec/x0 leaf once per seed (O(P·S)) and returns
    ``pidx=None``. ``keys_c`` tiles the per-seed keys per problem either
    way.
    """
    check_operand_layout(layout)
    keys_c = jnp.tile(keys, (n_probs, 1))
    if layout == "stacked":
        spec_op = jax.tree.map(
            lambda l: jnp.repeat(l, n_seeds, axis=0), stacked)
        x0_op = jax.tree.map(
            lambda l: jnp.repeat(l, n_seeds, axis=0), x0_stack)
        return spec_op, x0_op, None, keys_c
    return stacked, x0_stack, problem_index_operand(n_probs, n_seeds), keys_c


def make_policy_cell(cell):
    """O(Q)+O(P) operand adapter around a ``make_selection_*_cell`` cell:
    the cell's leading ``(spec, x0, pparams, pstate0, …)`` operands become
    ``(spec_stack, x0_stack, pol_stack, pst_stack, pidx, qidx, …)`` with
    in-cell gathers — the policies × problems × seeds grid carries ONE
    stacked spec, ONE stacked ``PolicyParams``/``PolicyState`` and two
    int32 per-cell indices (the selection-sweep analogue of
    ``make_indexed_cell``). Both engines batch over this same adapter, so
    sharding stays bitwise."""
    def policy_cell(spec_stack, x0_stack, pol_stack, pst_stack, pidx, qidx,
                    *rest):
        spec = jax.tree.map(lambda l: l[pidx], spec_stack)
        x0 = jax.tree.map(lambda l: l[pidx], x0_stack)
        pparams = jax.tree.map(lambda l: l[qidx], pol_stack)
        pstate0 = jax.tree.map(lambda l: l[qidx], pst_stack)
        return cell(spec, x0, pparams, pstate0, *rest)

    return policy_cell


def policy_index_operands(n_pols: int, n_probs: int, n_seeds: int):
    """Per-cell (qidx, pidx) of the flattened policies × problems × seeds
    cells axis ``c = (q·P + p)·S + s``: ``qidx[c] = c // (P·S)``,
    ``pidx[c] = (c // S) % P``."""
    c = jnp.arange(n_pols * n_probs * n_seeds, dtype=jnp.int32)
    return c // (n_probs * n_seeds), (c // n_seeds) % n_probs


def _sweep_fn_selection_algo(algo, problem, rounds: int, eval_output: bool,
                             eta_mode: str, telemetry=None):
    # donate everything but the problem stacks: the policy stacks, index
    # vectors, keys and comm state are all built fresh per call
    donate = (2, 3, 4, 5, 6, 7, 8, 9)
    key = ("sweep-sel-algo", algo, runner_lib.problem_key(problem), rounds,
           eval_output, eta_mode, telemetry, donate)
    fn = runner_lib._cache_get(key)
    if fn is not None:
        return fn

    cell = make_selection_algo_cell(algo, problem, rounds, eval_output,
                                    eta_mode, "sweep-sel", telemetry)
    pcell = make_policy_cell(cell)
    # (spec, x0, pol, pst, pidx, qidx, key, eta, sel_keys, comm0):
    # inner vmap is the dense η axis, outer the flattened cells axis
    inner = jax.vmap(pcell, in_axes=(None, None, None, None, None, None,
                                     None, 0, None, None))
    grid = jax.vmap(inner, in_axes=(None, None, None, None, 0, 0, 0, None,
                                    0, None))
    return runner_lib._cache_put(key, jax.jit(grid, donate_argnums=donate))


def _sweep_fn_selection_chain(chain, problem, rounds: int, telemetry=None):
    donate = (2, 3, 4, 5, 6, 7, 8, 9, 10)
    key = ("sweep-sel-chain", chain._key(), runner_lib.problem_key(problem),
           rounds, telemetry, donate)
    fn = runner_lib._cache_get(key)
    if fn is not None:
        return fn

    cell = make_selection_chain_cell(chain, problem, rounds, "sweep-sel",
                                     telemetry)
    pcell = make_policy_cell(cell)
    # (spec, x0, pol, pst, pidx, qidx, key, mult, eta_sched, sel_keys, comm0)
    inner = jax.vmap(pcell, in_axes=(None, None, None, None, None, None,
                                     None, 0, None, None, None))
    grid = jax.vmap(inner, in_axes=(None, None, None, None, 0, 0, 0, None,
                                    None, 0, None))
    return runner_lib._cache_put(key, jax.jit(grid, donate_argnums=donate))


def _sweep_fn_algo(algo, problem, rounds: int, eval_output: bool,
                   eta_mode: str, problem_axis: bool = False,
                   layout: str = "indexed", telemetry=None):
    """The seeds × etas grid cell; ``problem_axis`` wraps one more vmap over
    the problem operands — one compiled call for the whole problems × seeds
    × stepsizes grid (O(P) spec memory under the indexed layout)."""
    if problem_axis and layout == "indexed":
        donate = (2, 3, 4)  # pidx, keys, etas — never spec/x0
    else:
        donate = (2, 3)  # keys, etas
    key = ("sweep-algo", algo, runner_lib.problem_key(problem), rounds,
           eval_output, eta_mode, problem_axis,
           layout if problem_axis else None, telemetry, donate)
    fn = runner_lib._cache_get(key)
    if fn is not None:
        return fn

    tag = "sweep-probs" if problem_axis else "sweep"
    cell = make_algo_cell(algo, problem, rounds, eval_output, eta_mode, tag,
                          telemetry)
    # problems × seeds ride ONE flattened cells axis (c = p·S + s) — the
    # same batching structure the sharded engine (repro.dist.grid) runs per
    # shard, so sharding is bitwise. Indexed layout: the O(P) spec/x0
    # stacks ride unbatched and only pidx is per-cell.
    if problem_axis and layout == "indexed":
        icell = make_indexed_cell(cell)
        inner = jax.vmap(icell, in_axes=(None, None, None, None, 0))
        grid = jax.vmap(inner, in_axes=(None, None, 0, 0, None))
    else:
        inner = jax.vmap(cell, in_axes=(None, None, None, 0))
        grid = jax.vmap(inner, in_axes=((0, 0, 0, None) if problem_axis
                                        else (None, None, 0, None)))
    return runner_lib._cache_put(key, jax.jit(grid, donate_argnums=donate))


def _sweep_fn_algo_comm(algo, problem, rounds: int, eval_output: bool,
                        eta_mode: str, problem_axis: bool = False,
                        layout: str = "indexed", telemetry=None):
    if problem_axis and layout == "indexed":
        donate = (2, 3, 4, 5, 6)  # pidx, keys, etas, masks, comm0
    else:
        donate = (2, 3, 4, 5)  # keys, etas, masks, comm0
    key = ("sweep-algo-comm", algo, runner_lib.problem_key(problem), rounds,
           eval_output, eta_mode, problem_axis,
           layout if problem_axis else None, telemetry, donate)
    fn = runner_lib._cache_get(key)
    if fn is not None:
        return fn

    tag = "sweep-comm-probs" if problem_axis else "sweep-comm"
    cell = make_algo_comm_cell(algo, problem, rounds, eval_output, eta_mode,
                               tag, telemetry)
    # masks batch with the cells axis (one independent [R, N] schedule per
    # (problem, seed) cell); the initial CommState is identical across the
    # grid (zeros) so it broadcasts
    if problem_axis and layout == "indexed":
        icell = make_indexed_cell(cell)
        inner = jax.vmap(icell,
                         in_axes=(None, None, None, None, 0, None, None))
        grid = jax.vmap(inner, in_axes=(None, None, 0, 0, None, 0, None))
    else:
        inner = jax.vmap(cell, in_axes=(None, None, None, 0, None, None))
        grid = jax.vmap(inner, in_axes=(
            (0, 0, 0, None, 0, None) if problem_axis
            else (None, None, 0, None, 0, None)))
    return runner_lib._cache_put(key, jax.jit(grid, donate_argnums=donate))


def _sweep_fn_chain(chain, problem, rounds: int, problem_axis: bool = False,
                    layout: str = "indexed", telemetry=None):
    if problem_axis and layout == "indexed":
        donate = (2, 3, 4, 5)  # pidx, keys, mults, eta_sched
    else:
        donate = (2, 3, 4)  # keys, mults, eta_sched
    key = ("sweep-chain", chain._key(), runner_lib.problem_key(problem),
           rounds, problem_axis, layout if problem_axis else None,
           telemetry, donate)
    fn = runner_lib._cache_get(key)
    if fn is not None:
        return fn

    tag = "sweep-probs" if problem_axis else "sweep"
    cell = make_chain_cell(chain, problem, rounds, tag, telemetry)
    if problem_axis and layout == "indexed":
        icell = make_indexed_cell(cell)
        inner = jax.vmap(icell,
                         in_axes=(None, None, None, None, 0, None))
        grid = jax.vmap(inner, in_axes=(None, None, 0, 0, None, None))
    else:
        inner = jax.vmap(cell, in_axes=(None, None, None, 0, None))
        grid = jax.vmap(inner, in_axes=((0, 0, 0, None, None) if problem_axis
                                        else (None, None, 0, None, None)))
    return runner_lib._cache_put(key, jax.jit(grid, donate_argnums=donate))


def _sweep_fn_chain_comm(chain, problem, rounds: int,
                         problem_axis: bool = False,
                         layout: str = "indexed", telemetry=None):
    if problem_axis and layout == "indexed":
        donate = (2, 3, 4, 5, 6, 7)  # pidx, keys, mults, η-sched, masks, comm0
    else:
        donate = (2, 3, 4, 5, 6)
    key = ("sweep-chain-comm", chain._key(), runner_lib.problem_key(problem),
           rounds, problem_axis, layout if problem_axis else None,
           telemetry, donate)
    fn = runner_lib._cache_get(key)
    if fn is not None:
        return fn

    tag = "sweep-comm-probs" if problem_axis else "sweep-comm"
    cell = make_chain_comm_cell(chain, problem, rounds, tag, telemetry)
    if problem_axis and layout == "indexed":
        icell = make_indexed_cell(cell)
        inner = jax.vmap(
            icell, in_axes=(None, None, None, None, 0, None, None, None))
        grid = jax.vmap(inner,
                        in_axes=(None, None, 0, 0, None, None, 0, None))
    else:
        inner = jax.vmap(cell,
                         in_axes=(None, None, None, 0, None, None, None))
        grid = jax.vmap(inner, in_axes=(
            (0, 0, 0, None, None, 0, None) if problem_axis
            else (None, None, 0, None, None, 0, None)))
    return runner_lib._cache_put(key, jax.jit(grid, donate_argnums=donate))


def _sweep_fn_chain_fraction(chain, problem, rounds: int):
    donate = (2, 3, 4, 5, 6, 7)  # every operand row but spec/x0
    key = ("sweep-chain-frac", chain._fraction_free_key(),
           runner_lib.problem_key(problem), rounds, donate)
    fn = runner_lib._cache_get(key)
    if fn is not None:
        return fn

    cell = make_chain_fraction_cell(chain, problem, rounds, "sweep-frac")
    # axes: seeds (outer) × fractions (inner); key streams vary on both,
    # schedule rows on the fraction axis only
    grid = jax.vmap(jax.vmap(cell, in_axes=(None, None, 0, 0, 0, 0, 0, 0)),
                    in_axes=(None, None, 0, 0, None, None, None, None))
    return runner_lib._cache_put(key, jax.jit(grid, donate_argnums=donate))


def _sweep_fn_chain_decay(chain, problem, rounds: int):
    donate = (2, 3)  # keys, eta_scale rows
    key = ("sweep-chain-decay", chain._key(), runner_lib.problem_key(problem),
           rounds, donate)
    fn = runner_lib._cache_get(key)
    if fn is not None:
        return fn

    from repro.obs import events as obs_events

    body = chain.executor_body(problem, rounds)  # SAME executor as run_sweep
    _, resolve = runner_lib._bind(problem)

    def cell(spec, x0, key, eta_scale):
        p = resolve(spec)
        runner_lib.TRACE_COUNTS[f"sweep-decay/{chain.name}"] += 1
        obs_events.TRACE_EVENTS[f"sweep-decay/{chain.name}"] += 1
        states0 = chain.init_states(p, x0)
        x_hat, history, _ = body(spec, x0, states0, key, eta_scale)
        sub = p.global_loss(x_hat) - runner_lib.f_star_operand(p)
        return x_hat, history, sub

    # axes: seeds × decay grids (eta_scale rows)
    grid = jax.vmap(jax.vmap(cell, in_axes=(None, None, None, 0)),
                    in_axes=(None, None, 0, None))
    return runner_lib._cache_put(key, jax.jit(grid, donate_argnums=donate))


def _sweep_fn_methods(methods, problem, rounds: int, eval_output: bool):
    tag = "+".join(m.name for m in methods)
    donate = (2, 3, 4, 5)  # stacked state0, keys, etas, method index
    key = ("sweep-methods", methods, runner_lib.problem_key(problem), rounds,
           eval_output, donate)
    fn = runner_lib._cache_get(key)
    if fn is not None:
        return fn

    from repro.obs import events as obs_events

    body = runner_lib.method_executor_body(methods, problem, eval_output)
    _, resolve = runner_lib._bind(problem)
    eta_scale = jnp.ones((rounds,), jnp.float32)

    def cell(spec, x0, state0, key, eta, midx):
        p = resolve(spec)
        runner_lib.TRACE_COUNTS[f"sweep-methods/{tag}"] += 1
        obs_events.TRACE_EVENTS[f"sweep-methods/{tag}"] += 1
        state0 = state0._replace(eta=state0.eta * eta)  # scale semantics
        keys = jax.random.split(key, rounds)
        state, history = body(spec, state0, keys, eta_scale, midx)
        x_hat = jax.lax.switch(
            midx, [lambda s, m=m: m.output(s) for m in methods], state)
        sub = p.global_loss(x_hat) - runner_lib.f_star_operand(p)
        return x_hat, history, sub

    grid = jax.vmap(jax.vmap(cell, in_axes=(None, None, None, None, 0, None)),
                    in_axes=(None, None, None, 0, None, None))
    grid = jax.vmap(grid, in_axes=(None, None, 0, None, None, 0))  # methods
    return runner_lib._cache_put(key, jax.jit(grid, donate_argnums=donate))


def _normalize_x0_stack(x0, stacked, n_probs: int):
    """The ``problems=`` x0 semantics, shared with the sharded engine:
    None -> each spec's own x0; array-likes keep the historical behaviour (a
    [D] point is shared, a [P, ...] stack is per-problem); a params PYTREE
    (vision MLPs) is a shared unbatched point broadcast along the axis."""
    if x0 is None:
        return stacked.x0
    try:
        x0_stack = jnp.asarray(x0)
    except (TypeError, ValueError):
        return tm.tree_broadcast_leading(x0, n_probs)
    if x0_stack.ndim == 1:
        return jnp.broadcast_to(x0_stack, (n_probs,) + x0_stack.shape)
    if x0_stack.shape[0] != n_probs:
        raise ValueError(
            f"x0 leading axis {x0_stack.shape[0]} != number of "
            f"problems {n_probs}")
    return x0_stack


def _resolve_eta_mode(algo_or_chain, eta_mode):
    """Default + validate ``eta_mode`` (shared with the sharded engine)."""
    is_chain = isinstance(algo_or_chain, chain_lib.Chain)
    if eta_mode is None:
        eta_mode = "scale" if is_chain else "absolute"
    if eta_mode not in ("absolute", "scale"):
        raise ValueError(
            f"eta_mode must be 'absolute' or 'scale', got {eta_mode!r}")
    if is_chain and eta_mode != "scale":
        raise ValueError(
            "chains sweep stepsize *multipliers* (one η per stage makes an "
            "absolute grid ambiguous); pass eta_mode='scale' or omit it")
    return eta_mode


def _as_stacked_specs(problems):
    """Normalize the ``problems=`` argument into (stacked spec, names)."""
    from repro.data import spec as spec_lib

    if spec_lib.is_spec(problems):
        return problems, tuple(
            [problems.name] * spec_lib.spec_count(problems))
    specs = []
    for p in problems:
        s = runner_lib.as_spec(p)
        if s is None:
            raise TypeError(
                "problems= entries must be ProblemSpecs (or spec-backed "
                "problems); legacy hand-closure problems cannot batch — "
                "their data lives in Python closures, not operands")
        specs.append(s)
    names = tuple(s.name for s in specs)
    return spec_lib.stack_specs(specs), names


def _split_taps(outs, telemetry):
    """Split the trailing taps element off a grid output tuple when
    telemetry was enabled — ``(outs, taps-or-None)``."""
    if telemetry is None:
        return outs, None
    return outs[:-1], outs[-1]


def _run_grid_sweep(algo_or_chain, problem, x0, rounds: int, *,
                    seeds: Sequence[int], etas: Sequence[float],
                    eta_mode: Optional[str] = None, eval_output: bool = True,
                    decay: Optional[dict] = None, comm=None,
                    problems=None, mesh=None,
                    operand_layout: str = "indexed",
                    telemetry=None) -> SweepResult:
    """The (seed, η) / (problem, seed, η) grid family — see ``run()``."""
    if mesh is not None:
        from repro.dist import grid as dist_grid

        return dist_grid.run_sweep_sharded(
            algo_or_chain, problem, x0, rounds, seeds=seeds, etas=etas,
            eta_mode=eta_mode, eval_output=eval_output, decay=decay,
            comm=comm, problems=problems, mesh=mesh,
            operand_layout=operand_layout, telemetry=telemetry)
    is_chain = isinstance(algo_or_chain, chain_lib.Chain)
    eta_mode = _resolve_eta_mode(algo_or_chain, eta_mode)
    check_operand_layout(operand_layout)
    seeds = tuple(int(s) for s in seeds)
    etas = tuple(float(e) for e in etas)
    if not seeds:
        raise ValueError("run_sweep needs at least one seed")
    keys = jnp.stack([jax.random.PRNGKey(s) for s in seeds])
    etas_arr = jnp.asarray(etas, jnp.float32)

    if problems is not None:
        if decay is not None and not is_chain:
            raise NotImplementedError(
                "decay sweeps: wrap the algorithm in a Chain")
        stacked, prob_names = _as_stacked_specs(problems)
        n_probs = len(prob_names)
        n_seeds = len(seeds)
        x0_stack = _normalize_x0_stack(x0, stacked, n_probs)
        # problems × seeds flatten to ONE cells axis, c = p·S + s (the
        # contract of repro.dist.partition): keys tile per problem and the
        # spec rides either as ONE O(P) stack + per-cell problem index
        # (indexed layout, the default) or with every leaf repeated per
        # seed (stacked layout, O(P·S)) — the exact per-cell values the
        # sharded engine partitions over devices, so run_sweep(...,
        # mesh=...) is bitwise identical to this path, and so are the two
        # layouts to each other (module docstring: memory model).
        spec_c, x0_c, pidx, keys_c = build_problem_operands(
            stacked, x0_stack, keys, n_probs, n_seeds, operand_layout)
        lead = ((spec_c, x0_c, pidx) if pidx is not None
                else (spec_c, x0_c))

        def grid_shape(outs):
            return jax.tree.map(
                lambda l: l.reshape((n_probs, n_seeds) + l.shape[1:]), outs)

        if comm is not None:
            n_clients = stacked.num_clients
            n_sched = (algo_or_chain.schedule_len(rounds) if is_chain
                       else rounds)
            # one independent [R, N] schedule per (problem, seed) cell:
            # cell (p, s) uses the config's fold p·len(seeds) + s, so
            # runner.run(..., comm_masks=round_masks(R, N, fold=p*S+s))
            # reproduces it
            masks = jnp.stack([
                comm.round_masks(n_sched, n_clients, fold=p * n_seeds + s)
                for p in range(n_probs) for s in range(n_seeds)])
            comm0 = comm.init_state(n_clients, tm.tree_index(x0_stack, 0))
        if is_chain:
            chain = algo_or_chain
            eta_sched = chain.eta_schedule(rounds, decay)
            if comm is not None:
                fn = _sweep_fn_chain_comm(chain, stacked, rounds,
                                          problem_axis=True,
                                          layout=operand_layout,
                                          telemetry=telemetry)
                outs, taps = _split_taps(grid_shape(
                    fn(*lead, keys_c, etas_arr, eta_sched, masks, comm0)),
                    telemetry)
                x_hat, history, final, kept, bits_up, bits_down = outs
                return SweepResult(history=history, final_sub=final,
                                   x_hat=x_hat, seeds=seeds, etas=etas,
                                   selected_initial=kept, bits_up=bits_up,
                                   bits_down=bits_down, problems=prob_names,
                                   diagnostics=taps)
            fn = _sweep_fn_chain(chain, stacked, rounds, problem_axis=True,
                                 layout=operand_layout, telemetry=telemetry)
            outs, taps = _split_taps(grid_shape(
                fn(*lead, keys_c, etas_arr, eta_sched)), telemetry)
            x_hat, history, final, kept = outs
            return SweepResult(history=history, final_sub=final, x_hat=x_hat,
                               seeds=seeds, etas=etas, selected_initial=kept,
                               problems=prob_names, diagnostics=taps)
        if comm is not None:
            fn = _sweep_fn_algo_comm(algo_or_chain, stacked, rounds,
                                     eval_output, eta_mode,
                                     problem_axis=True,
                                     layout=operand_layout,
                                     telemetry=telemetry)
            outs, taps = _split_taps(grid_shape(
                fn(*lead, keys_c, etas_arr, masks, comm0)), telemetry)
            x_hat, history, final, bits_up, bits_down = outs
            return SweepResult(history=history, final_sub=final, x_hat=x_hat,
                               seeds=seeds, etas=etas, bits_up=bits_up,
                               bits_down=bits_down, problems=prob_names,
                               diagnostics=taps)
        fn = _sweep_fn_algo(algo_or_chain, stacked, rounds, eval_output,
                            eta_mode, problem_axis=True,
                            layout=operand_layout, telemetry=telemetry)
        outs, taps = _split_taps(grid_shape(
            fn(*lead, keys_c, etas_arr)), telemetry)
        x_hat, history, final = outs
        return SweepResult(history=history, final_sub=final, x_hat=x_hat,
                           seeds=seeds, etas=etas, problems=prob_names,
                           diagnostics=taps)

    spec = runner_lib.as_spec(problem)

    if comm is not None:
        n_clients = problem.num_clients
        comm0 = comm.init_state(n_clients, x0)

    if is_chain:
        chain = algo_or_chain
        eta_sched = chain.eta_schedule(rounds, decay)
        if comm is not None:
            n_sched = chain.schedule_len(rounds)
            masks = jnp.stack([
                comm.round_masks(n_sched, n_clients, fold=s)
                for s in range(len(seeds))])
            fn = _sweep_fn_chain_comm(chain, problem, rounds,
                                      telemetry=telemetry)
            outs, taps = _split_taps(
                fn(spec, x0, keys, etas_arr, eta_sched, masks, comm0),
                telemetry)
            x_hat, history, final, kept, bits_up, bits_down = outs
            return SweepResult(history=history, final_sub=final, x_hat=x_hat,
                               seeds=seeds, etas=etas, selected_initial=kept,
                               bits_up=bits_up, bits_down=bits_down,
                               diagnostics=taps)
        fn = _sweep_fn_chain(chain, problem, rounds, telemetry=telemetry)
        outs, taps = _split_taps(
            fn(spec, x0, keys, etas_arr, eta_sched), telemetry)
        x_hat, history, final, kept = outs
        return SweepResult(history=history, final_sub=final, x_hat=x_hat,
                           seeds=seeds, etas=etas, selected_initial=kept,
                           diagnostics=taps)

    if decay is not None:
        raise NotImplementedError("decay sweeps: wrap the algorithm in a Chain")
    if comm is not None:
        masks = jnp.stack([
            comm.round_masks(rounds, n_clients, fold=s)
            for s in range(len(seeds))])
        fn = _sweep_fn_algo_comm(algo_or_chain, problem, rounds, eval_output,
                                 eta_mode, telemetry=telemetry)
        outs, taps = _split_taps(
            fn(spec, x0, keys, etas_arr, masks, comm0), telemetry)
        x_hat, history, final, bits_up, bits_down = outs
        return SweepResult(history=history, final_sub=final, x_hat=x_hat,
                           seeds=seeds, etas=etas,
                           bits_up=bits_up, bits_down=bits_down,
                           diagnostics=taps)
    fn = _sweep_fn_algo(algo_or_chain, problem, rounds, eval_output, eta_mode,
                        telemetry=telemetry)
    outs, taps = _split_taps(fn(spec, x0, keys, etas_arr), telemetry)
    x_hat, history, final = outs
    return SweepResult(history=history, final_sub=final, x_hat=x_hat,
                       seeds=seeds, etas=etas, diagnostics=taps)


def run_method_sweep(methods, problem, x0, rounds: int, *,
                     seeds: Sequence[int], etas: Sequence[float] = (1.0,),
                     eval_output: bool = True) -> SweepResult:
    """Batch SEVERAL methods through one compiled methods × seeds × η call.

    ``methods`` must be plain algorithms (not chains) whose states share one
    pytree structure and leaf shapes on this problem — one class at
    different hyperparameters is the canonical case (SGD at several
    ``mu_avg``, FedAvg at several ``local_steps``). ``etas`` are
    MULTIPLIERS on each method's own base stepsize ("scale" semantics: an
    absolute grid is ambiguous across methods). Results carry the method
    axis first (``history[m, s, e]`` matches ``runner.run(methods[m], …)``
    cell-for-cell) and ``SweepResult.methods`` names it.

    Note the cost model (module docstring): the batched ``lax.switch``
    evaluates every method's round per grid row — ONE compile but M× the
    warm FLOPs of a per-method sweep loop. Prefer stacking when compile
    time dominates; prefer looping ``run_sweep`` per method for long warm
    grids.
    """
    methods = tuple(methods)
    if not methods:
        raise ValueError("run_method_sweep needs at least one method")
    for m in methods:
        if isinstance(m, chain_lib.Chain):
            raise TypeError("run_method_sweep stacks plain algorithms; "
                            "chains batch through run_sweep directly")
    seeds = tuple(int(s) for s in seeds)
    etas = tuple(float(e) for e in etas)
    if not seeds:
        raise ValueError("run_method_sweep needs at least one seed")

    states = [m.init(problem, x0) for m in methods]
    td0 = jax.tree_util.tree_structure(states[0])
    shapes0 = [jnp.shape(l) for l in jax.tree_util.tree_leaves(states[0])]
    for m, st in zip(methods[1:], states[1:]):
        td = jax.tree_util.tree_structure(st)
        shapes = [jnp.shape(l) for l in jax.tree_util.tree_leaves(st)]
        if td != td0 or shapes != shapes0:
            raise TypeError(
                f"method {m.name!r} has a state structure incompatible with "
                f"{methods[0].name!r}: multi-method stacking needs one state "
                f"pytree structure and leaf shapes across all methods "
                f"(same algorithm class at different hyperparameters)")
    state0 = jax.tree.map(lambda *xs: jnp.stack(xs), *states)

    keys = jnp.stack([jax.random.PRNGKey(s) for s in seeds])
    etas_arr = jnp.asarray(etas, jnp.float32)
    midx = jnp.arange(len(methods), dtype=jnp.int32)
    spec = runner_lib.as_spec(problem)

    fn = _sweep_fn_methods(methods, problem, rounds, eval_output)
    x_hat, history, final = fn(spec, x0, state0, keys, etas_arr, midx)
    return SweepResult(history=history, final_sub=final, x_hat=x_hat,
                       seeds=seeds, etas=etas,
                       methods=tuple(m.name for m in methods))


def _run_decay_sweep(chain, problem, x0, rounds: int, *,
                     seeds: Sequence[int], decay_factors: Sequence[float],
                     decay_first: float = 0.3) -> SweepResult:
    """The "M-" ``decay_factor`` grid family — see ``run()``."""
    if not isinstance(chain, chain_lib.Chain):
        raise TypeError("run_decay_sweep takes a Chain (wrap plain "
                        "algorithms in a single-stage Chain)")
    seeds = tuple(int(s) for s in seeds)
    factors = tuple(float(f) for f in decay_factors)
    if not seeds or not factors:
        raise ValueError("run_decay_sweep needs ≥1 seed and ≥1 decay factor")
    keys = jnp.stack([jax.random.PRNGKey(s) for s in seeds])
    eta_rows = jnp.stack([
        chain.eta_schedule(rounds, {"decay_first": decay_first,
                                    "decay_factor": f})
        for f in factors])
    fn = _sweep_fn_chain_decay(chain, problem, rounds)
    x_hat, history, final = fn(runner_lib.as_spec(problem), x0, keys,
                               eta_rows)
    return SweepResult(history=history, final_sub=final, x_hat=x_hat,
                       seeds=seeds, etas=factors)


def fraction_schedule_operands(chain, rounds: int, fractions,
                               seeds, decay: Optional[dict] = None):
    """The operand rows a local-fraction sweep feeds the fraction executor.

    Returns ``(chains, keys_r [S,F,R,2], keys_s [S,F,R,2], stage_id [F,R],
    kind [F,R], hmode [F,R], eta_rows [F,R], sel_indices [F][n_sel])`` —
    per-fraction schedules stacked into operands (every fraction of a fixed
    stage tuple has the same schedule length), with the key streams
    precomputed host-side by the SAME derivation the fixed-schedule executor
    performs in-trace — each row therefore replays ``Chain.run``'s exact
    RNG streams on the corresponding per-fraction chain. Shared by the
    vmapped and sharded fraction sweeps.

    Fractions must leave BOTH stages at least one round inside the fixed
    budget: ``Chain.budgets`` clamps a starved last stage back up to one
    round, which would CHANGE the schedule length and break the stacked
    operand layout — such fractions are rejected up front with the valid
    range for this round budget.
    """
    chains = [chain.with_local_fraction(float(f)) for f in fractions]
    # a costed between-stage selection occupies one scanned round of the
    # total budget; the first stage may take at most rounds − n_sel − 1
    n_sel = ((len(chain.stages) - 1)
             if (chain.select_between_stages and chain.selection_costs_round)
             else 0)
    max_b0 = rounds - n_sel - 1
    for ch in chains:
        b0 = max(1, int(round(ch.fractions[0] * rounds)))
        if b0 > max_b0:
            lo = 0.5 / rounds  # anything rounding to ≥ 1 is fine below
            hi = (max_b0 + 0.49) / rounds
            raise ValueError(
                f"local_fraction {ch.fractions[0]:g} gives the first stage "
                f"{b0} of {rounds} rounds, leaving none for the second "
                f"stage (selection costs {n_sel}); with rounds={rounds} "
                f"sweepable fractions lie in about ({lo:g}, {hi:g}]")
    scheds = [ch._schedule(rounds) for ch in chains]
    n_sched = len(scheds[0].stage_id)
    # backstop only — the budget check above is the real gate
    for ch, sc in zip(chains, scheds):
        if len(sc.stage_id) != n_sched:
            raise AssertionError(
                f"fraction {ch.fractions[0]} produced schedule length "
                f"{len(sc.stage_id)} != {n_sched}")
    stage_id = jnp.asarray(np.stack([s.stage_id for s in scheds]))
    kind = jnp.asarray(np.stack([s.kind for s in scheds]))
    hmode = jnp.asarray(np.stack([s.hmode for s in scheds]))
    eta_rows = jnp.stack([ch.eta_schedule(rounds, decay) for ch in chains])
    per_seed = []
    for s in seeds:
        key = jax.random.PRNGKey(s)
        per_seed.append([ch._derive_keys(sc, key)
                         for ch, sc in zip(chains, scheds)])
    keys_r = jnp.stack([jnp.stack([kr for kr, _ in row]) for row in per_seed])
    keys_s = jnp.stack([jnp.stack([ks for _, ks in row]) for row in per_seed])
    sel_indices = [list(s.sel_indices) for s in scheds]
    return chains, keys_r, keys_s, stage_id, kind, hmode, eta_rows, sel_indices


def gather_selection_flags(kept, sel_indices):
    """[S, F, R] full kept-flags rows → the [S, F, n_sel] selection
    decisions: selection rounds sit at fraction-dependent positions, so
    each fraction's flags are gathered from its own schedule's indices.
    Shared by the vmapped and sharded fraction sweeps."""
    kept_np = np.asarray(kept)
    return jnp.asarray(np.stack(
        [kept_np[:, fi, idx] for fi, idx in enumerate(sel_indices)], axis=1))


def _run_fraction_sweep(chain, problem, x0, rounds: int, *,
                        seeds: Sequence[int], fractions: Sequence[float],
                        decay: Optional[dict] = None,
                        mesh=None) -> SweepResult:
    """The two-stage ``local_fraction`` grid family — see ``run()``."""
    if not isinstance(chain, chain_lib.Chain):
        raise TypeError("run_fraction_sweep takes a Chain")
    seeds = tuple(int(s) for s in seeds)
    fractions = tuple(float(f) for f in fractions)
    if not seeds or not fractions:
        raise ValueError("run_fraction_sweep needs ≥1 seed and ≥1 fraction")
    if mesh is not None:
        from repro.dist import grid as dist_grid

        return dist_grid.run_fraction_sweep_sharded(
            chain, problem, x0, rounds, seeds=seeds, fractions=fractions,
            decay=decay, mesh=mesh)
    if x0 is None:
        spec = runner_lib.as_spec(problem)
        if spec is None:
            raise TypeError("x0=None needs a spec-backed problem "
                            "(uses the spec's own x0)")
        x0 = spec.x0

    (_, keys_r, keys_s, stage_id, kind, hmode, eta_rows,
     sel_indices) = fraction_schedule_operands(
         chain, rounds, fractions, seeds, decay)

    fn = _sweep_fn_chain_fraction(chain, problem, rounds)
    x_hat, history, final, kept = fn(
        runner_lib.as_spec(problem), x0, keys_r, keys_s, stage_id, kind,
        hmode, eta_rows)
    return SweepResult(
        history=history, final_sub=final, x_hat=x_hat, seeds=seeds,
        etas=fractions,
        selected_initial=gather_selection_flags(kept, sel_indices))


@dataclasses.dataclass(frozen=True)
class SweepRequest:
    """One description for every sweep family the engine runs.

    Exactly one grid FAMILY is selected by which axis field is set —
    ``run()`` dispatches on it:

    * none of the below → the (seed, η) grid over ``etas`` (optionally ×
      ``problems``), vmapped through one compiled executor per structure;
    * ``decay_factors`` → the "M-" decay grid (η-scale rows as operands;
      ``decay_first`` sets the undecayed prefix fraction);
    * ``fractions`` → the two-stage chain ``local_fraction`` grid (App. I.2;
      the whole per-round schedule is an operand);
    * ``policies`` → the client-selection grid (policies × problems ×
      seeds × etas through the ``lax.switch`` policy operand).

    Shared operand axes and options, identical across families:

    * ``seeds``: PRNG seeds — cell s uses ``jax.random.PRNGKey(seeds[s])``,
      so any cell is reproducible by the corresponding per-call runner
      (``runner.run`` / ``Chain.run``) with that key.
    * ``etas``: stepsize grid. ``eta_mode`` defaults to "absolute" for
      plain algorithms; chains only accept "scale" (per-stage multipliers)
      — passing "absolute" with a chain is an error, not a silent
      reinterpretation. Decay/fraction families carry their own grid in the
      result's ``etas`` slot instead.
    * ``problems``: a sequence of same-family, same-shaped ``ProblemSpec``s
      (or one pre-stacked spec from ``spec.stack_specs``). Problems × seeds
      flatten to ONE cells axis c = p·S + s; under the default
      ``operand_layout="indexed"`` the call carries ONE O(P) stacked spec
      plus an int32 per-cell index ("stacked" keeps the O(P·S)
      repeated-leaf reference layout, bitwise identical). ``x0`` may be
      None (each problem starts from its spec's own x0), a single shared
      point, or a [P, …] stack.
    * ``comm``: a ``repro.comm.CommPlan`` (or legacy ``CommConfig`` shim)
      enabling compressed uplinks/downlinks, partial participation, and
      the bits ledgers. Cell (p, s) uses the plan's mask schedule with
      ``fold=p·len(seeds)+s`` (``fold=s`` without a problem axis), so
      ``runner.run(..., comm_masks=...)`` reproduces any cell.
    * ``mesh``: a 1-D ``('grid',)`` device mesh (``dist.make_grid_mesh``)
      shard_maps the flattened cells axis — same semantics, same bits,
      bitwise identical results including the ledgers.
    * ``telemetry``: a ``repro.obs.Telemetry`` spec enabling in-scan round
      taps — ``SweepResult.diagnostics`` carries the per-round diagnostics
      dict with the grid's leading axes. A structural cache-key dimension:
      ``telemetry=None`` (the default) reuses today's executors bitwise.
      Supported by the (seed, η) and ``policies`` families; the
      decay/fraction families reject it.

    The legacy entry points (``run_sweep``, ``run_decay_sweep``,
    ``run_fraction_sweep``, ``selection.run_selection_sweep``) are thin
    keyword shims constructing a ``SweepRequest`` and calling ``run()`` —
    same code path, bitwise identical.
    """

    algo_or_chain: object
    problem: object
    x0: object
    rounds: int
    seeds: Sequence[int]
    etas: Sequence[float] = (1.0,)
    # family-selecting axes (at most one)
    decay_factors: Optional[Sequence[float]] = None
    fractions: Optional[Sequence[float]] = None
    policies: Optional[Sequence] = None
    # shared options
    eta_mode: Optional[str] = None
    eval_output: bool = True
    decay: Optional[dict] = None
    decay_first: float = 0.3
    comm: object = None
    problems: object = None
    mesh: object = None
    operand_layout: str = "indexed"
    telemetry: object = None


def run(req: SweepRequest) -> SweepResult:
    """Run the sweep family ``req`` describes — see ``SweepRequest`` for
    the operand axes. Returns a ``SweepResult`` (``SelectionSweepResult``
    for the policy family)."""
    families = [name for name, axis in (
        ("decay_factors", req.decay_factors),
        ("fractions", req.fractions),
        ("policies", req.policies)) if axis is not None]
    if len(families) > 1:
        raise ValueError(
            f"SweepRequest selects at most one sweep family; got "
            f"{families} together")
    if req.telemetry is not None and families not in ([], ["policies"]):
        raise ValueError(
            f"telemetry round taps are supported by the (seed, η) and "
            f"policies sweep families, not {families[0]!r}")
    if req.policies is not None:
        from repro.selection import sweep as sel_sweep

        return sel_sweep._run_selection_sweep(
            req.algo_or_chain, req.problem, req.x0, req.rounds,
            policies=req.policies, seeds=req.seeds, etas=req.etas,
            eta_mode=req.eta_mode, comm=req.comm, problems=req.problems,
            eval_output=req.eval_output, mesh=req.mesh,
            telemetry=req.telemetry)
    if req.fractions is not None:
        return _run_fraction_sweep(
            req.algo_or_chain, req.problem, req.x0, req.rounds,
            seeds=req.seeds, fractions=req.fractions, decay=req.decay,
            mesh=req.mesh)
    if req.decay_factors is not None:
        return _run_decay_sweep(
            req.algo_or_chain, req.problem, req.x0, req.rounds,
            seeds=req.seeds, decay_factors=req.decay_factors,
            decay_first=req.decay_first)
    return _run_grid_sweep(
        req.algo_or_chain, req.problem, req.x0, req.rounds,
        seeds=req.seeds, etas=req.etas, eta_mode=req.eta_mode,
        eval_output=req.eval_output, decay=req.decay, comm=req.comm,
        problems=req.problems, mesh=req.mesh,
        operand_layout=req.operand_layout, telemetry=req.telemetry)


def run_sweep(algo_or_chain, problem, x0, rounds: int, *,
              seeds: Sequence[int], etas: Sequence[float],
              eta_mode: Optional[str] = None, eval_output: bool = True,
              decay: Optional[dict] = None, comm=None,
              problems=None, mesh=None,
              operand_layout: str = "indexed",
              telemetry=None) -> SweepResult:
    """Thin keyword shim over ``run()`` for the (seed, η) grid family —
    ``SweepRequest`` documents the operand axes."""
    return run(SweepRequest(
        algo_or_chain=algo_or_chain, problem=problem, x0=x0, rounds=rounds,
        seeds=seeds, etas=etas, eta_mode=eta_mode, eval_output=eval_output,
        decay=decay, comm=comm, problems=problems, mesh=mesh,
        operand_layout=operand_layout, telemetry=telemetry))


def run_decay_sweep(chain, problem, x0, rounds: int, *,
                    seeds: Sequence[int], decay_factors: Sequence[float],
                    decay_first: float = 0.3) -> SweepResult:
    """Thin keyword shim over ``run()`` for the decay-factor grid family —
    ``SweepRequest`` documents the operand axes."""
    return run(SweepRequest(
        algo_or_chain=chain, problem=problem, x0=x0, rounds=rounds,
        seeds=seeds, decay_factors=decay_factors, decay_first=decay_first))


def run_fraction_sweep(chain, problem, x0, rounds: int, *,
                       seeds: Sequence[int], fractions: Sequence[float],
                       decay: Optional[dict] = None,
                       mesh=None) -> SweepResult:
    """Thin keyword shim over ``run()`` for the local-fraction grid family —
    ``SweepRequest`` documents the operand axes."""
    return run(SweepRequest(
        algo_or_chain=chain, problem=problem, x0=x0, rounds=rounds,
        seeds=seeds, fractions=fractions, decay=decay, mesh=mesh))


def best_cell(result: SweepResult):
    """Grid index of the lowest finite final suboptimality —
    ``(seed_idx, eta_idx)``, with a leading problem/method index when the
    sweep had one.

    Raises if every cell diverged — callers must not mistake a nan/inf run
    for a tuned result.
    """
    final = np.asarray(result.final_sub)
    masked = np.where(np.isfinite(final), final, np.inf)
    if not np.isfinite(masked).any():
        raise ValueError(
            f"every sweep cell diverged (no finite final suboptimality) "
            f"over seeds={result.seeds} etas={result.etas}")
    flat = int(np.argmin(masked))
    return np.unravel_index(flat, final.shape)
