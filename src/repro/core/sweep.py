"""Vmapped sweep engine: one compiled call for a seeds × stepsizes grid.

FedChain's experiment grids (Tables 1–4, Fig. 2) repeat the same algorithm
over seeds and stepsizes. ``run_sweep`` vmaps the single-compile executors
from ``runner``/``chain`` over both axes and jits the whole grid, so an
S × E sweep costs ONE trace + one device dispatch instead of S·E re-traced
round loops. Sweep functions are cached per ``(algo-or-chain, problem,
rounds)`` — repeated sweeps (e.g. across ζ values on the same problem
instance) never re-trace.

Stepsize semantics
------------------
* Plain algorithms, ``eta_mode="absolute"`` (default): each grid value is the
  stepsize itself (``state.eta = η``), matching ``runner.run(..., eta=η)``.
* Plain algorithms, ``eta_mode="scale"``: grid values multiply the state's
  own initialized stepsize — use this for algorithms that derive η from
  problem constants (e.g. SSNM's Thm. D.5 stepsize).
* Chains: grid values are always *multipliers* applied to every stage's base
  stepsize (a chain has one η per stage, so an absolute grid is ambiguous),
  matching ``Chain.run(..., eta_scale=m)``.

Because η lives in algorithm state (the uniform state protocol of
``algorithms.base``), batching stepsizes is just a batched ``state.eta`` leaf
— no algorithm code is sweep-aware.

Communication sweeps
--------------------
``run_sweep(..., comm=CommConfig(...))`` threads the communication subsystem
(``repro.comm``) through every grid cell: uplinks are compressed, per-round
participation masks (one independent [R, N] schedule per seed) ride the scan
as data, and ``SweepResult.bits_up``/``bits_down`` record the exact per-round
wire cost — the suboptimality-vs-bits frontier. All comm knobs are operands:
switching compressor, bit-width or participation fraction reuses the same
compiled grid (``runner.TRACE_COUNTS`` stays flat).

Decay sweeps: stepsize-decay multipliers are an executor *operand* (PR-2),
so ``run_decay_sweep`` batches a ``decay_factor`` grid through one compile
of the same chain executor ``run_sweep`` uses.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import jax
import jax.numpy as jnp

from repro.core import chain as chain_lib
from repro.core import runner as runner_lib


@dataclasses.dataclass
class SweepResult:
    """Results over the grid; leading axes are [n_seeds, n_etas]."""

    history: jnp.ndarray  # [S, E, R] per-round suboptimality
    final_sub: jnp.ndarray  # [S, E] F(x̂) − F* at the end
    x_hat: object  # pytree, leaves [S, E, ...]
    seeds: tuple
    etas: tuple
    selected_initial: Optional[jnp.ndarray] = None  # [S, E, n_sel] (chains)
    bits_up: Optional[jnp.ndarray] = None  # [S, E, R] per-round uplink bits
    bits_down: Optional[jnp.ndarray] = None  # [S, E, R] downlink bits

    def cumulative_bits(self):
        """[S, E, R] total (up + down) bits through each round, float64 —
        the x-axis of a cost-vs-accuracy frontier."""
        import numpy as np

        if self.bits_up is None:
            raise ValueError("not a comm sweep: no bits were accounted")
        per_round = (np.asarray(self.bits_up, np.float64)
                     + np.asarray(self.bits_down, np.float64))
        return np.cumsum(per_round, axis=-1)


def _sweep_fn_algo(algo, problem, rounds: int, eval_output: bool, eta_mode: str):
    key = ("sweep-algo", algo, id(problem), rounds, eval_output, eta_mode)
    fn = runner_lib._cache_get(key, problem)
    if fn is not None:
        return fn

    body = runner_lib.executor_body(algo, problem, eval_output)
    f_star = problem.f_star if problem.f_star is not None else 0.0
    eta_scale = jnp.ones((rounds,), jnp.float32)

    def cell(x0, key, eta):
        runner_lib.TRACE_COUNTS[f"sweep/{algo.name}"] += 1
        state0 = algo.init(problem, x0)
        new_eta = (state0.eta * eta if eta_mode == "scale"
                   else jnp.asarray(eta, jnp.result_type(state0.eta)))
        state0 = state0._replace(eta=new_eta)
        keys = jax.random.split(key, rounds)
        state, history = body(state0, keys, eta_scale)
        x_hat = algo.output(state)
        return x_hat, history, problem.global_loss(x_hat) - f_star

    grid = jax.vmap(jax.vmap(cell, in_axes=(None, None, 0)),
                    in_axes=(None, 0, None))
    return runner_lib._cache_put(key, problem, jax.jit(grid))


def _sweep_fn_algo_comm(algo, problem, rounds: int, eval_output: bool,
                        eta_mode: str):
    key = ("sweep-algo-comm", algo, id(problem), rounds, eval_output, eta_mode)
    fn = runner_lib._cache_get(key, problem)
    if fn is not None:
        return fn

    body = runner_lib.comm_executor_body(algo, problem, eval_output)
    f_star = problem.f_star if problem.f_star is not None else 0.0
    eta_scale = jnp.ones((rounds,), jnp.float32)

    def cell(x0, key, eta, masks, comm0):
        runner_lib.TRACE_COUNTS[f"sweep-comm/{algo.name}"] += 1
        state0 = algo.init(problem, x0)
        new_eta = (state0.eta * eta if eta_mode == "scale"
                   else jnp.asarray(eta, jnp.result_type(state0.eta)))
        state0 = state0._replace(eta=new_eta, comm=comm0)
        keys = jax.random.split(key, rounds)
        state, (history, bits_up, bits_down) = body(
            state0, keys, eta_scale, masks)
        x_hat = algo.output(state)
        return (x_hat, history, problem.global_loss(x_hat) - f_star,
                bits_up, bits_down)

    # masks batch with the seed axis (one independent schedule per seed)
    grid = jax.vmap(jax.vmap(cell, in_axes=(None, None, 0, None, None)),
                    in_axes=(None, 0, None, 0, None))
    return runner_lib._cache_put(key, problem, jax.jit(grid))


def _sweep_fn_chain(chain, problem, rounds: int):
    key = ("sweep-chain", chain._key(), id(problem), rounds)
    fn = runner_lib._cache_get(key, problem)
    if fn is not None:
        return fn

    body = chain.executor_body(problem, rounds)
    sched = chain._schedule(rounds)
    sel_idx = jnp.asarray(sched.sel_indices, jnp.int32)
    f_star = problem.f_star if problem.f_star is not None else 0.0

    def cell(x0, key, mult, eta_scale):
        runner_lib.TRACE_COUNTS[f"sweep/{chain.name}"] += 1
        states0 = chain.init_states(problem, x0, eta_scale=mult)
        x_hat, history, kept = body(x0, states0, key, eta_scale)
        return x_hat, history, problem.global_loss(x_hat) - f_star, kept[sel_idx]

    grid = jax.vmap(jax.vmap(cell, in_axes=(None, None, 0, None)),
                    in_axes=(None, 0, None, None))
    return runner_lib._cache_put(key, problem, jax.jit(grid))


def _sweep_fn_chain_comm(chain, problem, rounds: int):
    key = ("sweep-chain-comm", chain._key(), id(problem), rounds)
    fn = runner_lib._cache_get(key, problem)
    if fn is not None:
        return fn

    body = chain.executor_body(problem, rounds, comm=True)
    sched = chain._schedule(rounds)
    sel_idx = jnp.asarray(sched.sel_indices, jnp.int32)
    f_star = problem.f_star if problem.f_star is not None else 0.0

    def cell(x0, key, mult, eta_scale, masks, comm0):
        runner_lib.TRACE_COUNTS[f"sweep-comm/{chain.name}"] += 1
        states0 = chain.init_states(problem, x0, eta_scale=mult)
        x_hat, history, kept, bits_up, bits_down = body(
            x0, states0, key, eta_scale, masks, comm0)
        return (x_hat, history, problem.global_loss(x_hat) - f_star,
                kept[sel_idx], bits_up, bits_down)

    grid = jax.vmap(jax.vmap(cell, in_axes=(None, None, 0, None, None, None)),
                    in_axes=(None, 0, None, None, 0, None))
    return runner_lib._cache_put(key, problem, jax.jit(grid))


def _sweep_fn_chain_decay(chain, problem, rounds: int):
    key = ("sweep-chain-decay", chain._key(), id(problem), rounds)
    fn = runner_lib._cache_get(key, problem)
    if fn is not None:
        return fn

    body = chain.executor_body(problem, rounds)  # SAME executor as run_sweep
    f_star = problem.f_star if problem.f_star is not None else 0.0

    def cell(x0, key, eta_scale):
        runner_lib.TRACE_COUNTS[f"sweep-decay/{chain.name}"] += 1
        states0 = chain.init_states(problem, x0)
        x_hat, history, _ = body(x0, states0, key, eta_scale)
        return x_hat, history, problem.global_loss(x_hat) - f_star

    # axes: seeds × decay grids (eta_scale rows)
    grid = jax.vmap(jax.vmap(cell, in_axes=(None, None, 0)),
                    in_axes=(None, 0, None))
    return runner_lib._cache_put(key, problem, jax.jit(grid))


def run_sweep(algo_or_chain, problem, x0, rounds: int, *,
              seeds: Sequence[int], etas: Sequence[float],
              eta_mode: Optional[str] = None, eval_output: bool = True,
              decay: Optional[dict] = None, comm=None) -> SweepResult:
    """Run every (seed, η) grid cell in one compiled, vmapped call.

    ``seeds`` are PRNG seeds (cell s uses ``jax.random.PRNGKey(seeds[s])``,
    so results match per-call ``runner.run``/``Chain.run`` with those keys);
    ``etas`` follow the stepsize semantics in the module docstring.
    ``eta_mode`` defaults to "absolute" for plain algorithms; chains only
    accept "scale" (their grid values are per-stage multipliers), so passing
    "absolute" with a chain is an error rather than a silent reinterpretation.
    ``comm`` (a ``repro.comm.CommConfig``) enables compressed uplinks /
    partial participation / bits accounting; seed s uses the config's mask
    schedule derived with ``fold=s`` (``runner.run(..., comm_masks=...)``
    reproduces any single cell).
    """
    is_chain = isinstance(algo_or_chain, chain_lib.Chain)
    if eta_mode is None:
        eta_mode = "scale" if is_chain else "absolute"
    if eta_mode not in ("absolute", "scale"):
        raise ValueError(f"eta_mode must be 'absolute' or 'scale', got {eta_mode!r}")
    if is_chain and eta_mode != "scale":
        raise ValueError(
            "chains sweep stepsize *multipliers* (one η per stage makes an "
            "absolute grid ambiguous); pass eta_mode='scale' or omit it")
    seeds = tuple(int(s) for s in seeds)
    etas = tuple(float(e) for e in etas)
    if not seeds:
        raise ValueError("run_sweep needs at least one seed")
    keys = jnp.stack([jax.random.PRNGKey(s) for s in seeds])
    etas_arr = jnp.asarray(etas, jnp.float32)

    if comm is not None:
        from repro.comm import config as comm_cfg

        comm_cfg.require_flat(x0)
        n_clients = problem.num_clients
        comm0 = comm.init_state(n_clients, x0.shape[0])

    if is_chain:
        chain = algo_or_chain
        eta_sched = chain.eta_schedule(rounds, decay)
        if comm is not None:
            n_sched = len(chain._schedule(rounds).stage_id)
            masks = jnp.stack([
                comm.round_masks(n_sched, n_clients, fold=s)
                for s in range(len(seeds))])
            fn = _sweep_fn_chain_comm(chain, problem, rounds)
            x_hat, history, final, kept, bits_up, bits_down = fn(
                x0, keys, etas_arr, eta_sched, masks, comm0)
            return SweepResult(history=history, final_sub=final, x_hat=x_hat,
                               seeds=seeds, etas=etas, selected_initial=kept,
                               bits_up=bits_up, bits_down=bits_down)
        fn = _sweep_fn_chain(chain, problem, rounds)
        x_hat, history, final, kept = fn(x0, keys, etas_arr, eta_sched)
        return SweepResult(history=history, final_sub=final, x_hat=x_hat,
                           seeds=seeds, etas=etas, selected_initial=kept)

    if decay is not None:
        raise NotImplementedError("decay sweeps: wrap the algorithm in a Chain")
    if comm is not None:
        masks = jnp.stack([
            comm.round_masks(rounds, n_clients, fold=s)
            for s in range(len(seeds))])
        fn = _sweep_fn_algo_comm(algo_or_chain, problem, rounds, eval_output,
                                 eta_mode)
        x_hat, history, final, bits_up, bits_down = fn(
            x0, keys, etas_arr, masks, comm0)
        return SweepResult(history=history, final_sub=final, x_hat=x_hat,
                           seeds=seeds, etas=etas,
                           bits_up=bits_up, bits_down=bits_down)
    fn = _sweep_fn_algo(algo_or_chain, problem, rounds, eval_output, eta_mode)
    x_hat, history, final = fn(x0, keys, etas_arr)
    return SweepResult(history=history, final_sub=final, x_hat=x_hat,
                       seeds=seeds, etas=etas)


def run_decay_sweep(chain, problem, x0, rounds: int, *,
                    seeds: Sequence[int], decay_factors: Sequence[float],
                    decay_first: float = 0.3) -> SweepResult:
    """Sweep the "M-" ``decay_factor`` grid in one compiled, vmapped call.

    Decay multipliers are executor operands ([R] η-scale rows, one per
    factor), so the whole grid — and any later ``run_sweep``/``Chain.run`` on
    the same chain — shares ONE compiled executor. Returns a ``SweepResult``
    whose ``etas`` axis carries the decay factors.
    """
    if not isinstance(chain, chain_lib.Chain):
        raise TypeError("run_decay_sweep takes a Chain (wrap plain "
                        "algorithms in a single-stage Chain)")
    seeds = tuple(int(s) for s in seeds)
    factors = tuple(float(f) for f in decay_factors)
    if not seeds or not factors:
        raise ValueError("run_decay_sweep needs ≥1 seed and ≥1 decay factor")
    keys = jnp.stack([jax.random.PRNGKey(s) for s in seeds])
    eta_rows = jnp.stack([
        chain.eta_schedule(rounds, {"decay_first": decay_first,
                                    "decay_factor": f})
        for f in factors])
    fn = _sweep_fn_chain_decay(chain, problem, rounds)
    x_hat, history, final = fn(x0, keys, eta_rows)
    return SweepResult(history=history, final_sub=final, x_hat=x_hat,
                       seeds=seeds, etas=factors)


def best_cell(result: SweepResult):
    """(seed_idx, eta_idx) of the lowest finite final suboptimality.

    Raises if every cell diverged — callers must not mistake a nan/inf run
    for a tuned result.
    """
    import numpy as np

    final = np.asarray(result.final_sub)
    masked = np.where(np.isfinite(final), final, np.inf)
    if not np.isfinite(masked).any():
        raise ValueError(
            f"every sweep cell diverged (no finite final suboptimality) "
            f"over seeds={result.seeds} etas={result.etas}")
    flat = int(np.argmin(masked))
    return np.unravel_index(flat, final.shape)
