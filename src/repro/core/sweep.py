"""Vmapped sweep engine: one compiled call for a seeds × stepsizes grid.

FedChain's experiment grids (Tables 1–4, Fig. 2) repeat the same algorithm
over seeds and stepsizes. ``run_sweep`` vmaps the single-compile executors
from ``runner``/``chain`` over both axes and jits the whole grid, so an
S × E sweep costs ONE trace + one device dispatch instead of S·E re-traced
round loops. Sweep functions are cached per ``(algo-or-chain, problem,
rounds)`` — repeated sweeps (e.g. across ζ values on the same problem
instance) never re-trace.

Stepsize semantics
------------------
* Plain algorithms, ``eta_mode="absolute"`` (default): each grid value is the
  stepsize itself (``state.eta = η``), matching ``runner.run(..., eta=η)``.
* Plain algorithms, ``eta_mode="scale"``: grid values multiply the state's
  own initialized stepsize — use this for algorithms that derive η from
  problem constants (e.g. SSNM's Thm. D.5 stepsize).
* Chains: grid values are always *multipliers* applied to every stage's base
  stepsize (a chain has one η per stage, so an absolute grid is ambiguous),
  matching ``Chain.run(..., eta_scale=m)``.

Because η lives in algorithm state (the uniform state protocol of
``algorithms.base``), batching stepsizes is just a batched ``state.eta`` leaf
— no algorithm code is sweep-aware.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import jax
import jax.numpy as jnp

from repro.core import chain as chain_lib
from repro.core import runner as runner_lib


@dataclasses.dataclass
class SweepResult:
    """Results over the grid; leading axes are [n_seeds, n_etas]."""

    history: jnp.ndarray  # [S, E, R] per-round suboptimality
    final_sub: jnp.ndarray  # [S, E] F(x̂) − F* at the end
    x_hat: object  # pytree, leaves [S, E, ...]
    seeds: tuple
    etas: tuple
    selected_initial: Optional[jnp.ndarray] = None  # [S, E, n_sel] (chains)


def _sweep_fn_algo(algo, problem, rounds: int, eval_output: bool, eta_mode: str):
    key = ("sweep-algo", algo, id(problem), rounds, eval_output, eta_mode)
    fn = runner_lib._cache_get(key, problem)
    if fn is not None:
        return fn

    body = runner_lib.executor_body(algo, problem, eval_output)
    f_star = problem.f_star if problem.f_star is not None else 0.0
    eta_scale = jnp.ones((rounds,), jnp.float32)

    def cell(x0, key, eta):
        runner_lib.TRACE_COUNTS[f"sweep/{algo.name}"] += 1
        state0 = algo.init(problem, x0)
        new_eta = (state0.eta * eta if eta_mode == "scale"
                   else jnp.asarray(eta, jnp.result_type(state0.eta)))
        state0 = state0._replace(eta=new_eta)
        keys = jax.random.split(key, rounds)
        state, history = body(state0, keys, eta_scale)
        x_hat = algo.output(state)
        return x_hat, history, problem.global_loss(x_hat) - f_star

    grid = jax.vmap(jax.vmap(cell, in_axes=(None, None, 0)),
                    in_axes=(None, 0, None))
    return runner_lib._cache_put(key, problem, jax.jit(grid))


def _sweep_fn_chain(chain, problem, rounds: int, decay):
    decay_key = tuple(sorted(decay.items())) if decay is not None else None
    key = ("sweep-chain", chain._key(), id(problem), rounds, decay_key)
    fn = runner_lib._cache_get(key, problem)
    if fn is not None:
        return fn

    body = chain.executor_body(problem, rounds, decay)
    sched = chain._schedule(rounds, decay)
    sel_idx = jnp.asarray(sched.sel_indices, jnp.int32)
    f_star = problem.f_star if problem.f_star is not None else 0.0

    def cell(x0, key, mult):
        runner_lib.TRACE_COUNTS[f"sweep/{chain.name}"] += 1
        states0 = chain.init_states(problem, x0, eta_scale=mult)
        x_hat, history, kept = body(x0, states0, key)
        return x_hat, history, problem.global_loss(x_hat) - f_star, kept[sel_idx]

    grid = jax.vmap(jax.vmap(cell, in_axes=(None, None, 0)),
                    in_axes=(None, 0, None))
    return runner_lib._cache_put(key, problem, jax.jit(grid))


def run_sweep(algo_or_chain, problem, x0, rounds: int, *,
              seeds: Sequence[int], etas: Sequence[float],
              eta_mode: Optional[str] = None, eval_output: bool = True,
              decay: Optional[dict] = None) -> SweepResult:
    """Run every (seed, η) grid cell in one compiled, vmapped call.

    ``seeds`` are PRNG seeds (cell s uses ``jax.random.PRNGKey(seeds[s])``,
    so results match per-call ``runner.run``/``Chain.run`` with those keys);
    ``etas`` follow the stepsize semantics in the module docstring.
    ``eta_mode`` defaults to "absolute" for plain algorithms; chains only
    accept "scale" (their grid values are per-stage multipliers), so passing
    "absolute" with a chain is an error rather than a silent reinterpretation.
    """
    is_chain = isinstance(algo_or_chain, chain_lib.Chain)
    if eta_mode is None:
        eta_mode = "scale" if is_chain else "absolute"
    if eta_mode not in ("absolute", "scale"):
        raise ValueError(f"eta_mode must be 'absolute' or 'scale', got {eta_mode!r}")
    if is_chain and eta_mode != "scale":
        raise ValueError(
            "chains sweep stepsize *multipliers* (one η per stage makes an "
            "absolute grid ambiguous); pass eta_mode='scale' or omit it")
    seeds = tuple(int(s) for s in seeds)
    etas = tuple(float(e) for e in etas)
    if not seeds:
        raise ValueError("run_sweep needs at least one seed")
    keys = jnp.stack([jax.random.PRNGKey(s) for s in seeds])
    etas_arr = jnp.asarray(etas, jnp.float32)

    if is_chain:
        fn = _sweep_fn_chain(algo_or_chain, problem, rounds, decay)
        x_hat, history, final, kept = fn(x0, keys, etas_arr)
        return SweepResult(history=history, final_sub=final, x_hat=x_hat,
                           seeds=seeds, etas=etas, selected_initial=kept)

    if decay is not None:
        raise NotImplementedError("decay sweeps: wrap the algorithm in a Chain")
    fn = _sweep_fn_algo(algo_or_chain, problem, rounds, eval_output, eta_mode)
    x_hat, history, final = fn(x0, keys, etas_arr)
    return SweepResult(history=history, final_sub=final, x_hat=x_hat,
                       seeds=seeds, etas=etas)


def best_cell(result: SweepResult):
    """(seed_idx, eta_idx) of the lowest finite final suboptimality.

    Raises if every cell diverged — callers must not mistake a nan/inf run
    for a tuned result.
    """
    import numpy as np

    final = np.asarray(result.final_sub)
    masked = np.where(np.isfinite(final), final, np.inf)
    if not np.isfinite(masked).any():
        raise ValueError(
            f"every sweep cell diverged (no finite final suboptimality) "
            f"over seeds={result.seeds} etas={result.etas}")
    flat = int(np.argmin(masked))
    return np.unravel_index(flat, final.shape)
