"""SGD — the paper's Algorithm 2 (global-update method).

Each round: sample S clients, every sampled client returns the average of K
stochastic gradients at the server iterate (Algo 7), the server averages and
takes one step. The returned iterate follows Thm. D.1:

  * strongly convex: weighted average with w_r = (1 − ημ)^{−(r+1)}
  * general convex:  uniform average
  * PL:              last iterate

On flat [D] parameter vectors (the quadratic/theory problems) the server step
runs through the fused Pallas aggregation kernel (``kernels.aggregate.ops``):
η is folded into the client weights (η/S each) so the traced stepsize reaches
the kernel as data while ``lr`` stays static.

Comm-aware: with a ``comm`` leaf injected (``repro.comm``), every client's
K-sample gradient is computed, the uplink is compressed (g is the wire
payload), and the server step averages over the round's participation mask.
With the identity compressor and full participation this path is bit-exact
with the plain one.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional

import jax.numpy as jnp

from repro.core import tree_math as tm
from repro.core.algorithms import base


class SGDState(NamedTuple):
    x: object
    tracker: base.AvgTracker
    eta: jnp.ndarray
    r: jnp.ndarray
    comm: Optional[object] = None


@dataclasses.dataclass(frozen=True)
class SGD(base.FederatedAlgorithm):
    mu_avg: float = 0.0  # μ used for the Thm. D.1 averaging weights
    output_mode: str = "weighted_avg"  # weighted_avg | uniform_avg | last
    name: str = "sgd"

    def init(self, problem, x0):
        return SGDState(
            x=x0,
            tracker=base.AvgTracker.init(x0),
            eta=jnp.asarray(self.eta),
            r=jnp.asarray(0),
        )

    def round(self, problem, state, key):
        import jax

        k_sample, k_grad = jax.random.split(key)
        comm = state.comm
        if comm is not None:
            from repro import comm as comm_lib
            from repro.comm import config as comm_cfg
            from repro.kernels.aggregate import ops as agg_ops

            # all N clients compute (static shape); the round's mask decides
            # who transmits — an algorithm-level s would be silently ignored
            comm_cfg.reject_algo_participation(self.s, self.name)
            n = problem.num_clients
            cids = base.sample_clients(k_sample, n, n)
            # broadcast the iterate through the downlink leg: clients
            # compute at the reconstruction (bitwise = state.x under an
            # identity downlink); the server step stays at the exact iterate
            x_b, comm = comm_lib.downlink(
                comm, state.x, comm_lib.downlink_key(key))
            g_per = base.grad_k(problem, x_b, cids, k_grad, self.k)
            if comm_cfg.ef_enabled(comm) and agg_ops.use_fused_aggregate():
                # one fused kernel pass: masked weighted mean + EF residual
                # update + server step — bitwise identical to the unfused
                # sequence below on kernel backends (same einsum order,
                # η folded into the weights the same way)
                x, comm = comm_lib.uplink_fused_apply(
                    comm, g_per, cids, comm_lib.comm_key(key), state.x,
                    state.eta)
            else:
                g_hat, comm = comm_lib.uplink(
                    comm, g_per, cids, comm_lib.comm_key(key))
                scale = comm_lib.participation_scale(comm.mask, cids)
                x = base.fused_server_step(state.x, g_hat, state.eta,
                                           weight_scale=scale)
            comm = comm_lib.account_round(
                comm, state.x, up_vectors=1, down_vectors=1)
        else:
            s = self.participation(problem)
            cids = base.sample_clients(k_sample, problem.num_clients, s)
            g_per = base.grad_k(problem, state.x, cids, k_grad, self.k)
            x = base.fused_server_step(state.x, g_per, state.eta)
        decay = jnp.asarray(1.0 - state.eta * self.mu_avg)
        tracker = state.tracker.update(x, jnp.clip(decay, 0.0, 1.0))
        return SGDState(x=x, tracker=tracker, eta=state.eta, r=state.r + 1,
                        comm=comm)

    def output(self, state):
        if self.output_mode == "last":
            return state.x
        return state.tracker.avg
