"""FedAvg — the paper's Algorithm 4 (local-update method).

The paper's parametrization splits the per-round oracle budget K into √K
outer local steps, each using a √K-sample-averaged stochastic gradient. We
expose (local_steps, inner_batch) directly and provide ``from_k`` for the
paper's √K×√K convention.

Server update: x^{r+1} = x^r − server_lr · mean_i Σ_k η·g_{i,k}
             = (1 − server_lr)·x^r + server_lr · mean_i x_{i,final}
(the paper uses server_lr = 1, i.e. plain iterate averaging).

Comm-aware: the uplink payload is the local iterate delta y_i − x (the wire
format of local-update methods); the server reconstructs x + C(y_i − x) and
averages over the round's participation mask.
"""
from __future__ import annotations

import dataclasses
import math
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import tree_math as tm
from repro.core.algorithms import base


class FedAvgState(NamedTuple):
    x: object
    eta: jnp.ndarray
    r: jnp.ndarray
    comm: Optional[object] = None


@dataclasses.dataclass(frozen=True)
class FedAvg(base.FederatedAlgorithm):
    local_steps: int = 4  # √K in the paper
    inner_batch: int = 4  # gradient samples averaged per local step (√K)
    server_lr: float = 1.0
    name: str = "fedavg"

    @classmethod
    def from_k(cls, k: int, **kw):
        root = max(1, int(round(math.sqrt(k))))
        return cls(k=k, local_steps=root, inner_batch=root, **kw)

    def _local(self, problem, x0, cid, key, eta):
        """Local SGD steps on client ``cid``; returns the final local iterate."""

        def step(carry, k_step):
            y = carry
            ks = jax.random.split(k_step, self.inner_batch)
            gs = jax.vmap(lambda kk: problem.grad_oracle(y, cid, kk))(ks)
            g = tm.tree_mean_leading(gs)
            return tm.tree_axpy(-eta, g, y), None

        keys = jax.random.split(key, self.local_steps)
        y, _ = jax.lax.scan(step, x0, keys)
        return y

    def round(self, problem, state, key):
        k_sample, k_local = jax.random.split(key)
        comm = state.comm
        if comm is not None:
            from repro.comm import config as comm_cfg

            comm_cfg.reject_algo_participation(self.s, self.name)
        s = (problem.num_clients if comm is not None
             else self.participation(problem))
        cids = base.sample_clients(k_sample, problem.num_clients, s)
        keys = jax.random.split(k_local, s)
        x_start = state.x
        if comm is not None:
            from repro import comm as comm_lib

            # clients local-step from the downlink reconstruction (bitwise
            # = state.x under an identity downlink leg) and the same point
            # is the delta wire reference
            x_start, comm = comm_lib.downlink(
                comm, state.x, comm_lib.downlink_key(key))
        y_final = jax.vmap(
            lambda cid, kk: self._local(problem, x_start, cid, kk, state.eta)
        )(cids, keys)
        if comm is not None:
            from repro.kernels.aggregate import ops as agg_ops

            if comm_cfg.ef_enabled(comm) and agg_ops.use_fused_aggregate():
                # fused EF round: the wire deltas C(y_i − x) aggregate and
                # apply in one kernel pass — x + lr·meanᵢwᵢĉᵢ expressed as
                # x − (−lr)·Σᵢ(wᵢ/S)·ĉᵢ (meanᵢwᵢ = 1 by construction of the
                # participation scale, so this equals the unfused
                # reconstruct-then-lerp to float tolerance)
                x, comm = comm_lib.uplink_fused_apply(
                    comm, y_final, cids, comm_lib.comm_key(key), state.x,
                    -self.server_lr, ref=x_start)
            else:
                y_hat, comm = comm_lib.uplink(
                    comm, y_final, cids, comm_lib.comm_key(key), ref=x_start)
                scale = comm_lib.participation_scale(comm.mask, cids)
                y_mean = base.client_mean(state.x, y_hat, weight_scale=scale)
                x = tm.tree_lerp(self.server_lr, state.x, y_mean)
            comm = comm_lib.account_round(
                comm, state.x, up_vectors=1, down_vectors=1)
        else:
            y_mean = base.client_mean(state.x, y_final)
            x = tm.tree_lerp(self.server_lr, state.x, y_mean)
        return FedAvgState(x=x, eta=state.eta, r=state.r + 1, comm=comm)

    def init(self, problem, x0):
        return FedAvgState(x=x0, eta=jnp.asarray(self.eta), r=jnp.asarray(0))

    def output(self, state):
        return state.x
