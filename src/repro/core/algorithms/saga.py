"""SAGA — the paper's Algorithm 5 (variance-reduced global-update method).

State keeps per-client control variates c_i (warm-started with gradients at
x^{(0)}, as the Thm. D.4 proof's warm-start strategy) and their running mean.

Round:
  g = mean_{i∈S}(g_i(x) − c_i) + c̄ ;  x ← x − η·g
  Option I : c_i ← g_i(x) for i ∈ S (reuses the same gradients)
  Option II: fresh independent sample S′ and fresh gradients for the update.

The strongly-convex returned iterate is the Thm. D.4 weighted average.

On flat [D] parameters the variance-reduced server step
``x − η·(mean(g_i − c_i) + c̄)`` is exactly the fused Pallas aggregation
kernel's contract; η is folded into the weights/server-variate operands so
the traced stepsize passes as data.

Comm-aware: compressed variance reduction in the style of Zhao et al.
("Faster Rates for Compressed Federated Learning with Client-Variance
Reduction") — gradients are compressed on the uplink and the server-side
control-variate table stores the TRANSMITTED (dequantized) values, so server
state never references information that did not cross the wire. Masked-out
clients neither update the table nor contribute to the step.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import tree_math as tm
from repro.core.algorithms import base


class SAGAState(NamedTuple):
    x: object
    c_table: object  # [N, ...]
    c_mean: object
    tracker: base.AvgTracker
    eta: jnp.ndarray
    r: jnp.ndarray
    comm: Optional[object] = None


@dataclasses.dataclass(frozen=True)
class SAGA(base.FederatedAlgorithm):
    option: str = "I"  # "I" or "II"
    mu_avg: float = 0.0
    output_mode: str = "weighted_avg"  # weighted_avg | last | uniform_avg
    name: str = "saga"

    def init(self, problem, x0):
        # Warm start: c_i^{(0)} = Grad at x^{(0)} for every client (noiseless
        # expectation is approximated with the K-sample average below at r=0;
        # we initialize with exact client gradients which is the σ→0 limit).
        n = problem.num_clients
        grads = jax.vmap(lambda i: jax.grad(problem.client_loss)(x0, i))(jnp.arange(n))
        return SAGAState(
            x=x0,
            c_table=grads,
            c_mean=tm.tree_mean_leading(grads),
            tracker=base.AvgTracker.init(x0),
            eta=jnp.asarray(self.eta),
            r=jnp.asarray(0),
        )

    def _update_table(self, state, cids, new_grads):
        n = state.c_table  # noqa: placeholder for clarity
        old = jax.tree.map(lambda t: t[cids], state.c_table)
        c_table = tm.tree_scatter_set(state.c_table, cids, new_grads)
        num = jnp.asarray(float(jax.tree.leaves(state.c_table)[0].shape[0]))
        delta = tm.tree_mean_leading(jax.tree.map(jnp.subtract, new_grads, old))
        s = cids.shape[0]
        c_mean = tm.tree_axpy(s / num, delta, state.c_mean)
        return c_table, c_mean

    def round(self, problem, state, key):
        k_sample, k_grad, k_sample2, k_grad2 = jax.random.split(key, 4)
        comm = state.comm
        x_b = state.x
        if comm is not None:
            from repro import comm as comm_lib
            from repro.comm import config as comm_cfg

            comm_cfg.reject_algo_participation(self.s, self.name)
            # clients evaluate gradients at the downlink reconstruction
            # (bitwise = state.x under an identity downlink leg)
            x_b, comm = comm_lib.downlink(
                comm, state.x, comm_lib.downlink_key(key))
        s = (problem.num_clients if comm is not None
             else self.participation(problem))
        cids = base.sample_clients(k_sample, problem.num_clients, s)
        g_per = base.grad_k(problem, x_b, cids, k_grad, self.k)
        c_i = jax.tree.map(lambda t: t[cids], state.c_table)
        if comm is not None:
            from repro import comm as comm_lib

            g_per, comm = comm_lib.uplink(
                comm, g_per, cids, comm_lib.comm_key(key))
            scale = comm_lib.participation_scale(comm.mask, cids)
            x = base.fused_server_step(state.x, g_per, state.eta,
                                       c_i=c_i, c_mean=state.c_mean,
                                       weight_scale=scale)
        else:
            x = base.fused_server_step(state.x, g_per, state.eta,
                                       c_i=c_i, c_mean=state.c_mean)

        def masked(new, old, m):
            """Participants' values, masked-out rows keep the old table
            entry (``comm_lib.masked_keep``; identity when no mask)."""
            if m is None:
                return new
            from repro.comm import config as comm_cfg

            return comm_cfg.masked_keep(m, new, old)

        if self.option == "I":
            m = comm.mask[cids] if comm is not None else None
            c_table, c_mean = self._update_table(
                state, cids, masked(g_per, c_i, m))
        else:  # Option II: independent sample + fresh gradients at x^{(r)}
            cids2 = base.sample_clients(k_sample2, problem.num_clients, s)
            g2 = base.grad_k(problem, x_b, cids2, k_grad2, self.k)
            m2 = None
            if comm is not None:
                from repro import comm as comm_lib

                # fresh gradients are a second compressed uplink (no EF:
                # the residual stream belongs to the step gradients)
                g2, comm = comm_lib.uplink(
                    comm, g2, cids2, comm_lib.second_uplink_key(key),
                    use_ef=False)
                m2 = comm.mask[cids2]
            old2 = jax.tree.map(lambda t: t[cids2], state.c_table)
            c_table, c_mean = self._update_table(
                state, cids2, masked(g2, old2, m2))
        if comm is not None:
            from repro import comm as comm_lib

            comm = comm_lib.account_round(
                comm, state.x,
                up_vectors=1 if self.option == "I" else 2, down_vectors=1)

        decay = jnp.clip(jnp.asarray(1.0 - state.eta * self.mu_avg), 0.0, 1.0)
        tracker = state.tracker.update(x, decay)
        return SAGAState(
            x=x, c_table=c_table, c_mean=c_mean, tracker=tracker,
            eta=state.eta, r=state.r + 1, comm=comm,
        )

    def output(self, state):
        if self.output_mode == "last":
            return state.x
        return state.tracker.avg
