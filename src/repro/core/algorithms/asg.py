"""Accelerated SGD — the paper's Algorithm 3 (AC-SA, Ghadimi & Lan) plus the
practical Nesterov variant the paper actually runs in experiments (App. I.1,
"the more easily implementable version in Aybat et al. (2019)").

AC-SA round r (1-indexed), with α_r = 2/(r+1), γ_r = 4φ/(r(r+1)):

  x_md = [(1−α)(μ+γ)·x_ag + α((1−α)μ+γ)·x] / (γ + (1−α²)μ)
  g    = mean_i Grad(x_md)
  x    = [αμ·x_md + ((1−α)μ+γ)·x_prev − α·g] / (μ + γ)
  x_ag = α·x + (1−α)·x_ag

The closed-form x-update solves Algo 3's argmin exactly.

``MultistageACSA`` implements the Thm. D.3 stage schedule (R_s doubling,
φ_s shrinking) used for the theory-facing experiments.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import tree_math as tm
from repro.core.algorithms import base


class ACSAState(NamedTuple):
    x: object
    x_ag: object
    eta: jnp.ndarray  # unused by AC-SA updates; kept for wrapper compat
    phi: jnp.ndarray
    r: jnp.ndarray


@dataclasses.dataclass(frozen=True)
class ACSA(base.FederatedAlgorithm):
    """Single-stage AC-SA (Algo 3)."""

    mu: float = 0.0
    beta: float = 1.0
    phi: float = 0.0  # 0 => use 2*beta (Thm. D.3 low-variance regime)
    name: str = "acsa"

    def init(self, problem, x0):
        phi = self.phi if self.phi > 0 else 2.0 * self.beta
        return ACSAState(
            x=x0, x_ag=x0, eta=jnp.asarray(self.eta),
            phi=jnp.asarray(phi), r=jnp.asarray(1),
        )

    def round(self, problem, state, key):
        k_sample, k_grad = jax.random.split(key)
        s = self.participation(problem)
        cids = base.sample_clients(k_sample, problem.num_clients, s)

        r = state.r.astype(jnp.float32)
        alpha = 2.0 / (r + 1.0)
        gamma = 4.0 * state.phi / (r * (r + 1.0))
        mu = self.mu

        denom_md = gamma + (1.0 - alpha**2) * mu
        ca = (1.0 - alpha) * (mu + gamma) / denom_md
        cb = alpha * ((1.0 - alpha) * mu + gamma) / denom_md
        x_md = jax.tree.map(lambda a, b: ca * a + cb * b, state.x_ag, state.x)

        g = base.client_mean(state.x, base.grad_k(problem, x_md, cids, k_grad, self.k))

        denom_x = mu + gamma
        x = jax.tree.map(
            lambda xm, xp, gg: (alpha * mu * xm + ((1 - alpha) * mu + gamma) * xp - alpha * gg) / denom_x,
            x_md, state.x, g,
        )
        x_ag = tm.tree_lerp(alpha, state.x_ag, x)
        return ACSAState(x=x, x_ag=x_ag, eta=state.eta, phi=state.phi, r=state.r + 1)

    def output(self, state):
        return state.x_ag


class NesterovState(NamedTuple):
    x: object
    v: object  # momentum buffer
    eta: jnp.ndarray
    r: jnp.ndarray
    comm: Optional[object] = None


@dataclasses.dataclass(frozen=True)
class NesterovSGD(base.FederatedAlgorithm):
    """Practical accelerated SGD: Nesterov momentum on the global gradient.

    This is what the paper's experiments use for "ASG"; momentum defaults to
    the strongly-convex optimal (√κ−1)/(√κ+1) when μ>0.

    Comm-aware: the server broadcasts the LOOKAHEAD point x + m·v through
    the downlink leg (the only point clients query) and the accelerated
    gradients ride the MOMENTUM uplink leg through the compressed +
    error-feedback path — the momentum buffer itself is server state and
    never crosses the wire. Identity legs and full participation are
    bit-exact with the plain path.
    """

    mu: float = 0.0
    beta: float = 1.0
    momentum: float = -1.0  # <0 => derive from kappa
    name: str = "asg"

    def _momentum(self):
        if self.momentum >= 0:
            return self.momentum
        if self.mu > 0:
            sk = (self.beta / self.mu) ** 0.5
            return (sk - 1.0) / (sk + 1.0)
        return 0.9

    def init(self, problem, x0):
        return NesterovState(
            x=x0, v=tm.tree_zeros_like(x0), eta=jnp.asarray(self.eta), r=jnp.asarray(0),
        )

    def round(self, problem, state, key):
        k_sample, k_grad = jax.random.split(key)
        comm = state.comm
        m = self._momentum()
        # lookahead point
        x_look = tm.tree_axpy(m, state.v, state.x)
        if comm is not None:
            from repro import comm as comm_lib
            from repro.comm import config as comm_cfg

            comm_cfg.reject_algo_participation(self.s, self.name)
            n = problem.num_clients
            cids = base.sample_clients(k_sample, n, n)
            # broadcast the lookahead point through the downlink-EF chain
            # (bitwise = x_look under an identity downlink leg)
            x_look_b, comm = comm_lib.downlink(
                comm, x_look, comm_lib.downlink_key(key))
            g_per = base.grad_k(problem, x_look_b, cids, k_grad, self.k)
            g_hat, comm = comm_lib.uplink(
                comm, g_per, cids, comm_lib.momentum_uplink_key(key),
                leg="mom")
            scale = comm_lib.participation_scale(comm.mask, cids)
            g = base.client_mean(state.x, g_hat, weight_scale=scale)
            comm = comm_lib.account_round(
                comm, state.x, mom_vectors=1, down_vectors=1)
        else:
            s = self.participation(problem)
            cids = base.sample_clients(k_sample, problem.num_clients, s)
            g = base.client_mean(
                state.x, base.grad_k(problem, x_look, cids, k_grad, self.k))
        v = jax.tree.map(lambda vv, gg: m * vv - state.eta * gg, state.v, g)
        x = tm.tree_add(state.x, v)
        return NesterovState(x=x, v=v, eta=state.eta, r=state.r + 1,
                             comm=comm)

    def output(self, state):
        return state.x


def multistage_acsa_schedule(*, mu, beta, delta, c_var, total_rounds):
    """Thm. D.3 stage schedule: returns a list of (num_rounds, phi) stages.

    R_s = max{4√(4β/μ), 128 c /(3 μ Δ 2^{−(s+1)})},
    φ_s = max{2β, √( μ c / (3 Δ 2^{−(s−1)} R_s (R_s+1)(R_s+2)) )}.
    Stages are emitted until the round budget is spent.
    """
    stages = []
    used = 0
    s = 1
    while used < total_rounds and s < 64:
        r_s = int(max(4 * (4 * beta / max(mu, 1e-12)) ** 0.5,
                      128.0 * c_var / max(3 * mu * delta * 2.0 ** (-(s + 1)), 1e-12)))
        r_s = max(1, min(r_s, total_rounds - used))
        denom = 3 * delta * 2.0 ** (-(s - 1)) * r_s * (r_s + 1) * (r_s + 2)
        phi_s = max(2 * beta, (mu * c_var / max(denom, 1e-12)) ** 0.5)
        stages.append((r_s, phi_s))
        used += r_s
        s += 1
    return stages
