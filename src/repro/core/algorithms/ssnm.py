"""SSNM (Zhou et al. 2019) — the paper's Algorithm 6: Nesterov-accelerated
SAGA via sampled negative momentum.

The client losses are viewed as F_i(x) = F̃_i(x) + h(x), with h(x) = μ_h/2·||x||²
the strongly-convex part (paper App. D.4: the usual strong-convexity assumption
converts to this form). The oracle returns ∇F_i, so ∇F̃_i(y) = ∇F_i(y) − μ_h·y.

Round r (with per-client snapshots φ_i and control variates c_i = ∇F̃_i(φ_i)):
  sample S:        y_i = τ·x + (1−τ)·φ_i,  i ∈ S
  g = mean_i(∇F̃_i(y_i) − c_i) + c̄
  x⁺ = argmin_x h(x) + ⟨g, x⟩ + 1/(2η)||x − x_r||²  =  (x_r − η·g)/(1 + η·μ_h)
  fresh sample S′: φ_I ← τ·x⁺ + (1−τ)·φ_I,  c_I ← ∇F̃_I(φ_I⁺)

Parameter choices follow Thm. D.5's two cases on (N/S)/κ.

Comm-aware: compressed variance reduction in the style of Zhao et al.
("Faster Rates for Compressed Federated Learning with Client-Variance
Reduction") — the iterate broadcasts through the downlink-EF chain and the
new snapshot point x⁺ through the stateless second downlink; both gradient
uplinks (the sampled-negative-momentum gradients and the fresh snapshot
gradients) ride the MOMENTUM leg, the first through the error-feedback
path, the second without EF (SAGA Option II's convention — the residual
stream belongs to the step gradients). The control-variate table stores the
TRANSMITTED values, and masked-out clients keep their snapshots.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import tree_math as tm
from repro.core.algorithms import base


class SSNMState(NamedTuple):
    x: object
    phi_table: object  # [N, ...] snapshots
    c_table: object  # [N, ...] ∇F̃_i(φ_i)
    c_mean: object
    eta: jnp.ndarray
    r: jnp.ndarray
    comm: Optional[object] = None


@dataclasses.dataclass(frozen=True)
class SSNM(base.FederatedAlgorithm):
    mu_h: float = 0.1  # strong convexity of h
    beta: float = 1.0
    tau: float = -1.0  # <0 => derive via Thm. D.5
    name: str = "ssnm"

    def hyper(self, problem):
        """Thm. D.5 stepsize/momentum: two cases on (N/S)/κ."""
        n = problem.num_clients
        s = self.participation(problem)
        kappa = self.beta / self.mu_h
        ratio = (n / s) / kappa
        if ratio > 0.75:
            eta = 1.0 / (2.0 * self.mu_h * (n / s))
        else:
            eta = (1.0 / (3.0 * self.mu_h * (n / s) * self.beta)) ** 0.5
        tau = self.tau if self.tau >= 0 else ((n / s) * eta * self.mu_h) / (1.0 + eta * self.mu_h)
        return eta, tau

    def _tilde_grad_k(self, problem, y, cid, key):
        ks = jax.random.split(key, self.k)
        gs = jax.vmap(lambda kk: problem.grad_oracle(y, cid, kk))(ks)
        g = tm.tree_mean_leading(gs)
        return jax.tree.map(lambda gg, yy: gg - self.mu_h * yy, g, y)

    def init(self, problem, x0):
        n = problem.num_clients
        eta, _ = self.hyper(problem)
        phi = tm.tree_broadcast_leading(x0, n)

        def c0(i):
            g = jax.grad(problem.client_loss)(x0, i)
            return jax.tree.map(lambda gg, yy: gg - self.mu_h * yy, g, x0)

        c_table = jax.vmap(c0)(jnp.arange(n))
        return SSNMState(
            x=x0, phi_table=phi, c_table=c_table,
            c_mean=tm.tree_mean_leading(c_table),
            eta=jnp.asarray(eta), r=jnp.asarray(0),
        )

    def round(self, problem, state, key):
        k_s1, k_g1, k_s2, k_g2 = jax.random.split(key, 4)
        comm = state.comm
        n = problem.num_clients
        if comm is not None:
            from repro import comm as comm_lib
            from repro.comm import config as comm_cfg

            comm_cfg.reject_algo_participation(self.s, self.name)
            s = n  # all N compute (static shape); the mask decides who ships
        else:
            s = self.participation(problem)
        eta, tau = self.hyper(problem)
        eta = state.eta  # annealable

        cids = base.sample_clients(k_s1, n, s)
        phi_i = jax.tree.map(lambda t: t[cids], state.phi_table)
        c_i = jax.tree.map(lambda t: t[cids], state.c_table)
        x_b = state.x
        if comm is not None:
            # the iterate broadcasts through the downlink-EF chain; clients
            # form y_i at the reconstruction
            x_b, comm = comm_lib.downlink(
                comm, state.x, comm_lib.downlink_key(key))
        y_i = jax.tree.map(lambda p, xx: tau * xx[None] + (1 - tau) * p, phi_i,
                           jax.tree.map(lambda l: l, x_b))
        keys = jax.random.split(k_g1, s)
        g_per = jax.vmap(lambda cid, y, kk: self._tilde_grad_k(problem, y, cid, kk))(
            cids, y_i, keys
        )
        if comm is not None:
            # sampled-negative-momentum gradients ride the MOMENTUM leg
            # through the compressed + error-feedback path
            g_per, comm = comm_lib.uplink(
                comm, g_per, cids, comm_lib.momentum_uplink_key(key),
                leg="mom")
            scale = comm_lib.participation_scale(comm.mask, cids)
            x_lin = base.fused_server_step(state.x, g_per, eta,
                                           c_i=c_i, c_mean=state.c_mean,
                                           weight_scale=scale)
        else:
            # fused x − η·(mean(g−c_i) + c̄), then closed-form prox scaling
            x_lin = base.fused_server_step(state.x, g_per, eta,
                                           c_i=c_i, c_mean=state.c_mean)
        x_new = jax.tree.map(lambda t: t / (1.0 + eta * self.mu_h), x_lin)

        # fresh sample S' for snapshot/control updates
        cids2 = base.sample_clients(k_s2, n, s)
        phi_old2 = jax.tree.map(lambda t: t[cids2], state.phi_table)
        x2 = x_new
        if comm is not None:
            # the snapshot point is the round's second broadcast (stateless
            # downlink — the down_ref chain tracks the iterate broadcasts)
            x2 = comm_lib.downlink_second(
                comm, x_new, comm_lib.second_downlink_key(key))
        phi_new2 = jax.tree.map(lambda p, xx: tau * xx[None] + (1 - tau) * p, phi_old2,
                                jax.tree.map(lambda l: l, x2))
        keys2 = jax.random.split(k_g2, s)
        c_new2 = jax.vmap(lambda cid, p, kk: self._tilde_grad_k(problem, p, cid, kk))(
            cids2, phi_new2, keys2
        )
        c_old2 = jax.tree.map(lambda t: t[cids2], state.c_table)
        if comm is not None:
            # fresh snapshot gradients: second momentum-leg uplink, no EF
            # (SAGA Option II's convention); server tables keep TRANSMITTED
            # values, masked-out clients keep their snapshots
            c_new2, comm = comm_lib.uplink(
                comm, c_new2, cids2, comm_lib.second_uplink_key(key),
                use_ef=False, leg="mom")
            m2 = comm.mask[cids2]
            phi_new2 = comm_cfg.masked_keep(m2, phi_new2, phi_old2)
            c_new2 = comm_cfg.masked_keep(m2, c_new2, c_old2)
        phi_table = tm.tree_scatter_set(state.phi_table, cids2, phi_new2)
        c_table = tm.tree_scatter_set(state.c_table, cids2, c_new2)
        delta = tm.tree_mean_leading(jax.tree.map(jnp.subtract, c_new2, c_old2))
        c_mean = tm.tree_axpy(s / n, delta, state.c_mean)
        if comm is not None:
            comm = comm_lib.account_round(
                comm, state.x, mom_vectors=2, down_vectors=2)

        return SSNMState(
            x=x_new, phi_table=phi_table, c_table=c_table, c_mean=c_mean,
            eta=state.eta, r=state.r + 1, comm=comm,
        )

    def output(self, state):
        return state.x
