"""SSNM (Zhou et al. 2019) — the paper's Algorithm 6: Nesterov-accelerated
SAGA via sampled negative momentum.

The client losses are viewed as F_i(x) = F̃_i(x) + h(x), with h(x) = μ_h/2·||x||²
the strongly-convex part (paper App. D.4: the usual strong-convexity assumption
converts to this form). The oracle returns ∇F_i, so ∇F̃_i(y) = ∇F_i(y) − μ_h·y.

Round r (with per-client snapshots φ_i and control variates c_i = ∇F̃_i(φ_i)):
  sample S:        y_i = τ·x + (1−τ)·φ_i,  i ∈ S
  g = mean_i(∇F̃_i(y_i) − c_i) + c̄
  x⁺ = argmin_x h(x) + ⟨g, x⟩ + 1/(2η)||x − x_r||²  =  (x_r − η·g)/(1 + η·μ_h)
  fresh sample S′: φ_I ← τ·x⁺ + (1−τ)·φ_I,  c_I ← ∇F̃_I(φ_I⁺)

Parameter choices follow Thm. D.5's two cases on (N/S)/κ.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import tree_math as tm
from repro.core.algorithms import base


class SSNMState(NamedTuple):
    x: object
    phi_table: object  # [N, ...] snapshots
    c_table: object  # [N, ...] ∇F̃_i(φ_i)
    c_mean: object
    eta: jnp.ndarray
    r: jnp.ndarray


@dataclasses.dataclass(frozen=True)
class SSNM(base.FederatedAlgorithm):
    mu_h: float = 0.1  # strong convexity of h
    beta: float = 1.0
    tau: float = -1.0  # <0 => derive via Thm. D.5
    name: str = "ssnm"

    def hyper(self, problem):
        """Thm. D.5 stepsize/momentum: two cases on (N/S)/κ."""
        n = problem.num_clients
        s = self.participation(problem)
        kappa = self.beta / self.mu_h
        ratio = (n / s) / kappa
        if ratio > 0.75:
            eta = 1.0 / (2.0 * self.mu_h * (n / s))
        else:
            eta = (1.0 / (3.0 * self.mu_h * (n / s) * self.beta)) ** 0.5
        tau = self.tau if self.tau >= 0 else ((n / s) * eta * self.mu_h) / (1.0 + eta * self.mu_h)
        return eta, tau

    def _tilde_grad_k(self, problem, y, cid, key):
        ks = jax.random.split(key, self.k)
        gs = jax.vmap(lambda kk: problem.grad_oracle(y, cid, kk))(ks)
        g = tm.tree_mean_leading(gs)
        return jax.tree.map(lambda gg, yy: gg - self.mu_h * yy, g, y)

    def init(self, problem, x0):
        n = problem.num_clients
        eta, _ = self.hyper(problem)
        phi = tm.tree_broadcast_leading(x0, n)

        def c0(i):
            g = jax.grad(problem.client_loss)(x0, i)
            return jax.tree.map(lambda gg, yy: gg - self.mu_h * yy, g, x0)

        c_table = jax.vmap(c0)(jnp.arange(n))
        return SSNMState(
            x=x0, phi_table=phi, c_table=c_table,
            c_mean=tm.tree_mean_leading(c_table),
            eta=jnp.asarray(eta), r=jnp.asarray(0),
        )

    def round(self, problem, state, key):
        k_s1, k_g1, k_s2, k_g2 = jax.random.split(key, 4)
        s = self.participation(problem)
        n = problem.num_clients
        eta, tau = self.hyper(problem)
        eta = state.eta  # annealable

        cids = base.sample_clients(k_s1, n, s)
        phi_i = jax.tree.map(lambda t: t[cids], state.phi_table)
        c_i = jax.tree.map(lambda t: t[cids], state.c_table)
        y_i = jax.tree.map(lambda p, xx: tau * xx[None] + (1 - tau) * p, phi_i,
                           jax.tree.map(lambda l: l, state.x))
        keys = jax.random.split(k_g1, s)
        g_per = jax.vmap(lambda cid, y, kk: self._tilde_grad_k(problem, y, cid, kk))(
            cids, y_i, keys
        )
        # fused x − η·(mean(g−c_i) + c̄), then the closed-form prox scaling
        x_lin = base.fused_server_step(state.x, g_per, eta,
                                       c_i=c_i, c_mean=state.c_mean)
        x_new = jax.tree.map(lambda t: t / (1.0 + eta * self.mu_h), x_lin)

        # fresh sample S' for snapshot/control updates
        cids2 = base.sample_clients(k_s2, n, s)
        phi_old2 = jax.tree.map(lambda t: t[cids2], state.phi_table)
        phi_new2 = jax.tree.map(lambda p, xx: tau * xx[None] + (1 - tau) * p, phi_old2,
                                jax.tree.map(lambda l: l, x_new))
        keys2 = jax.random.split(k_g2, s)
        c_new2 = jax.vmap(lambda cid, p, kk: self._tilde_grad_k(problem, p, cid, kk))(
            cids2, phi_new2, keys2
        )
        c_old2 = jax.tree.map(lambda t: t[cids2], state.c_table)
        phi_table = tm.tree_scatter_set(state.phi_table, cids2, phi_new2)
        c_table = tm.tree_scatter_set(state.c_table, cids2, c_new2)
        delta = tm.tree_mean_leading(jax.tree.map(jnp.subtract, c_new2, c_old2))
        c_mean = tm.tree_axpy(s / n, delta, state.c_mean)

        return SSNMState(
            x=x_new, phi_table=phi_table, c_table=c_table, c_mean=c_mean,
            eta=state.eta, r=state.r + 1,
        )

    def output(self, state):
        return state.x
