"""Federated optimization algorithms (the paper's Algos 2–7)."""
from repro.core.algorithms.base import FederatedAlgorithm, grad_k, sample_clients, value_k
from repro.core.algorithms.sgd import SGD
from repro.core.algorithms.asg import ACSA, NesterovSGD, multistage_acsa_schedule
from repro.core.algorithms.fedavg import FedAvg
from repro.core.algorithms.scaffold import FedProx, Scaffold
from repro.core.algorithms.saga import SAGA
from repro.core.algorithms.ssnm import SSNM

__all__ = [
    "FederatedAlgorithm", "grad_k", "sample_clients", "value_k",
    "SGD", "ACSA", "NesterovSGD", "multistage_acsa_schedule",
    "FedAvg", "Scaffold", "FedProx", "SAGA", "SSNM",
]
