"""Common machinery for federated algorithms (Algos 2–7 of the paper).

Conventions
-----------
* An algorithm is a small frozen dataclass of hyperparameters with

    init(problem, x0)            -> state   (a NamedTuple of pytrees)
    round(problem, state, key)   -> state   (ONE communication round, jittable)
    output(state)                -> params  (the returned iterate x̂)

* ``state.x`` is always the current server iterate and ``state.eta`` the
  current stepsize (kept in state so stepsize-decay wrappers can anneal it).
* Client sampling is uniform without replacement (paper §2).
* ``Grad`` (Algo 7): each sampled client averages K stochastic gradient
  queries at the server iterate.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import tree_math as tm


def sample_clients(key, num_clients: int, s: int):
    """S of N uniformly without replacement (paper §2)."""
    return jax.random.choice(key, num_clients, (s,), replace=False)


def grad_k(problem, x, client_ids, key, k: int):
    """Algo 7 ``Grad``: per-client average of K stochastic gradients at x.

    Returns a pytree whose leaves have a leading [S] axis.
    """
    s = client_ids.shape[0]
    keys = jax.random.split(key, s * k).reshape(s, k, -1)

    def per_client(cid, ks):
        gs = jax.vmap(lambda kk: problem.grad_oracle(x, cid, kk))(ks)
        return tm.tree_mean_leading(gs)

    return jax.vmap(per_client)(client_ids, keys)


def value_k(problem, x, client_ids, key, k: int):
    """Average of K stochastic function-value queries per client, then mean."""
    s = client_ids.shape[0]
    keys = jax.random.split(key, s * k).reshape(s, k, -1)

    def per_client(cid, ks):
        vs = jax.vmap(lambda kk: problem.value_oracle(x, cid, kk))(ks)
        return jnp.mean(vs)

    return jnp.mean(jax.vmap(per_client)(client_ids, keys))


class AvgTracker(NamedTuple):
    """Numerically-stable tracker for x̂ = (1/W_R)·Σ w_r x_r, w_r=(1−ημ)^{−r}.

    Normalized recurrence: W'_r = 1 + (1−ημ)·W'_{r−1};
    avg_r = avg_{r−1} + (x_r − avg_{r−1}) / W'_r.
    """

    avg: object
    wprime: jnp.ndarray

    @staticmethod
    def init(x):
        return AvgTracker(avg=x, wprime=jnp.asarray(1.0))

    def update(self, x, decay: jnp.ndarray):
        """decay = (1 − ημ) ∈ (0, 1]; decay=1 gives the uniform average."""
        wprime = 1.0 + decay * self.wprime
        avg = jax.tree.map(lambda a, b: a + (b - a) / wprime, self.avg, x)
        return AvgTracker(avg=avg, wprime=wprime)


@dataclasses.dataclass(frozen=True)
class FederatedAlgorithm:
    """Base class; concrete algorithms override init/round/output."""

    eta: float = 0.1
    k: int = 16  # oracle queries per client per round (paper's K)
    s: int = 0  # sampled clients per round; 0 => full participation (S=N)
    name: str = "base"

    def participation(self, problem):
        return self.s if self.s and self.s > 0 else problem.num_clients

    # --- to be overridden -------------------------------------------------
    def init(self, problem, x0):
        raise NotImplementedError

    def round(self, problem, state, key):
        raise NotImplementedError

    def output(self, state):
        return state.x
