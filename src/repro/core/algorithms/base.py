"""Common machinery for federated algorithms (Algos 2–7 of the paper).

Conventions
-----------
* An algorithm is a small frozen dataclass of hyperparameters with

    init(problem, x0)            -> state   (a NamedTuple of pytrees)
    round(problem, state, key)   -> state   (ONE communication round, jittable)
    output(state)                -> params  (the returned iterate x̂)

* Uniform state protocol (relied on by the single-compile executors in
  ``core.runner``/``core.chain`` and the vmapped sweep engine in
  ``core.sweep``): every state is a NamedTuple carrying ``.x`` (the current
  server iterate), ``.eta`` (the base stepsize — kept in state so decay
  schedules can anneal it and sweeps can batch it) and ``.r`` (the round
  counter). ``round`` must pass ``eta`` through unchanged; the executor owns
  annealing. ``audit_state`` checks the protocol.
* Optional ``comm`` leaf: comm-aware algorithm states additionally carry
  ``comm: Optional[CommState] = None`` (``repro.comm``). ``None`` (the
  default) is an empty pytree — plain runs are untouched. The comm
  executors inject a ``CommState`` (with the round's participation mask)
  before each round; a comm-aware ``round`` compresses its uplinks through
  ``repro.comm.uplink``, aggregates with ``weight_scale`` masks, accounts
  bits via ``repro.comm.account_round`` and returns the updated leaf.
* Client sampling is uniform without replacement (paper §2).
* ``Grad`` (Algo 7): each sampled client averages K stochastic gradient
  queries at the server iterate.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import tree_math as tm


REQUIRED_STATE_FIELDS = ("x", "eta", "r")


def audit_state(state):
    """Assert the uniform state protocol the executors and sweeps rely on."""
    missing = [f for f in REQUIRED_STATE_FIELDS if not hasattr(state, f)]
    if missing:
        raise TypeError(
            f"{type(state).__name__} violates the state protocol: missing "
            f"field(s) {missing}; executors need x/eta/r to schedule and "
            f"batch runs")
    if not hasattr(state, "_replace"):
        raise TypeError(f"{type(state).__name__} must be a NamedTuple")
    return state


def flat_params(x) -> bool:
    """True when params are a single flat [D] vector (the quadratic/theory
    problems) — the layout the fused Pallas aggregation kernels accept."""
    return isinstance(x, jax.Array) and x.ndim == 1


def weighted_client_mean(stacked, weight_scale):
    """meanᵢ(wᵢ·tᵢ) over the leading client axis, leaf-wise through the
    Pallas ``weighted_mean_over_clients`` kernel: each leaf [S, ...] is
    raveled to [S, d_leaf] rows at the kernel boundary
    (``tree_math.tree_ravel_rows``) and unraveled after — a flat [S, D]
    array is the single-leaf no-op-reshape case."""
    from repro.kernels.compress import ops as compress_ops

    means = jax.tree.map(
        lambda rows: compress_ops.weighted_mean_over_clients(
            rows, weight_scale),
        tm.tree_ravel_rows(stacked))
    return jax.tree.map(lambda m, t: m.reshape(t.shape[1:]), means, stacked)


def client_mean(x, stacked, weight_scale=None):
    """Mean over the leading client axis of ``stacked``, routed through the
    Pallas ``mean_over_clients`` kernel when params are flat vectors (``x`` is
    the server iterate used only to pick the layout).

    ``weight_scale`` [S] (comm partial participation) switches to the masked
    aggregate meanᵢ(wᵢ·tᵢ) — leaf-wise on pytree params; callers pass
    ``m_i·S/Σm`` so masked-out clients drop out and the result is the
    participant mean. Under full participation every wᵢ is exactly 1.0,
    keeping the result bitwise equal to the plain mean."""
    from repro.kernels.aggregate import ops as agg_ops

    if weight_scale is not None:
        return weighted_client_mean(stacked, weight_scale)
    if flat_params(x):
        return agg_ops.mean_over_clients(stacked)
    return tm.tree_mean_leading(stacked)


def fused_server_step(x, g_per, eta, *, c_i=None, c_mean=None,
                      weight_scale=None):
    """The (variance-reduced) server update x − η·(meanᵢ(gᵢ − cᵢ) + c̄).

    On flat [D] params this is one fused Pallas ``chain_aggregate`` pass —
    η is folded into the client weights (η/S each) and the server variate so
    the traced stepsize reaches the kernel as data while ``lr`` stays static.
    ``c_i``/``c_mean`` default to zero (plain gradient averaging, Algo 2).
    ``weight_scale`` [S] rescales per-client weights (comm participation
    masks, exactly 1.0 per client under full participation); on pytree
    params the masked mean runs leaf-wise through the weighted-aggregate
    kernel (``weighted_client_mean``).
    """
    from repro.kernels.aggregate import ops as agg_ops

    if flat_params(x):
        s = g_per.shape[0]
        base_w = (jnp.full((s,), 1.0, jnp.float32) if weight_scale is None
                  else weight_scale.astype(jnp.float32))
        w = base_w * (eta / s)
        ci = jnp.zeros_like(g_per) if c_i is None else c_i
        c = jnp.zeros_like(x) if c_mean is None else eta * c_mean
        return agg_ops.chain_aggregate(x, g_per, ci, c, weights=w, lr=1.0)
    if weight_scale is not None:
        diff = (g_per if c_i is None
                else jax.tree.map(jnp.subtract, g_per, c_i))
        g = weighted_client_mean(diff, weight_scale)
        if c_mean is not None:
            g = tm.tree_add(g, c_mean)
        return tm.tree_axpy(-eta, g, x)
    if c_i is None:
        g = tm.tree_mean_leading(g_per)
    else:
        g = jax.tree.map(lambda gp, ci, cm: jnp.mean(gp - ci, axis=0) + cm,
                         g_per, c_i, c_mean)
    return tm.tree_axpy(-eta, g, x)


def sample_clients(key, num_clients: int, s: int):
    """S of N uniformly without replacement (paper §2).

    Implemented as an integer-only Fisher–Yates partial shuffle rather than
    ``jax.random.choice(replace=False)`` (or any argsort-of-randoms): the
    sort-based samplers fuse with the downstream client-data gathers, and
    XLA's single-device and multi-device (SPMD) pipelines lower that fusion
    DIFFERENTLY — the sampled permutation itself then changes between the
    vmapped and device-sharded sweep engines. Integer swaps admit no such
    rewrite, so the draw is bitwise identical under every pipeline, which
    the sharded grid engine (``repro.dist``) relies on for bit-exact
    equality with the single-device path.
    """
    if not 0 < s <= num_clients:
        # jax.random.choice(replace=False) used to reject this at trace
        # time; the partial shuffle below would silently clamp instead
        raise ValueError(
            f"cannot sample {s} of {num_clients} clients without "
            f"replacement")
    idx = jnp.arange(num_clients, dtype=jnp.int32)
    keys = jax.random.split(key, s)

    def swap(i, idx):
        j = jax.random.randint(keys[i], (), i, num_clients, dtype=jnp.int32)
        vi = idx[i]
        vj = idx[j]
        return idx.at[i].set(vj).at[j].set(vi)

    idx = jax.lax.fori_loop(0, s, swap, idx)
    return idx[:s]


def grad_k(problem, x, client_ids, key, k: int, *, keys=None):
    """Algo 7 ``Grad``: per-client average of K stochastic gradients at x.

    Returns a pytree whose leaves have a leading [S] axis. ``keys``
    optionally supplies the [S, k, 2] per-query key rows directly (the
    derivation below, precomputed) — the client-sharded round
    (``repro.dist.client_axis``) passes each shard its rows so the oracle
    streams match the single-device round exactly.
    """
    s = client_ids.shape[0]
    if keys is None:
        keys = jax.random.split(key, s * k).reshape(s, k, -1)

    def per_client(cid, ks):
        gs = jax.vmap(lambda kk: problem.grad_oracle(x, cid, kk))(ks)
        return tm.tree_mean_leading(gs)

    return jax.vmap(per_client)(client_ids, keys)


def value_k(problem, x, client_ids, key, k: int):
    """Average of K stochastic function-value queries per client, then mean."""
    s = client_ids.shape[0]
    keys = jax.random.split(key, s * k).reshape(s, k, -1)

    def per_client(cid, ks):
        vs = jax.vmap(lambda kk: problem.value_oracle(x, cid, kk))(ks)
        return jnp.mean(vs)

    return jnp.mean(jax.vmap(per_client)(client_ids, keys))


class AvgTracker(NamedTuple):
    """Numerically-stable tracker for x̂ = (1/W_R)·Σ w_r x_r, w_r=(1−ημ)^{−r}.

    Normalized recurrence: W'_r = 1 + (1−ημ)·W'_{r−1};
    avg_r = avg_{r−1} + (x_r − avg_{r−1}) / W'_r.
    """

    avg: object
    wprime: jnp.ndarray

    @staticmethod
    def init(x):
        return AvgTracker(avg=x, wprime=jnp.asarray(1.0))

    def update(self, x, decay: jnp.ndarray):
        """decay = (1 − ημ) ∈ (0, 1]; decay=1 gives the uniform average."""
        wprime = 1.0 + decay * self.wprime
        avg = jax.tree.map(lambda a, b: a + (b - a) / wprime, self.avg, x)
        return AvgTracker(avg=avg, wprime=wprime)


@dataclasses.dataclass(frozen=True)
class FederatedAlgorithm:
    """Base class; concrete algorithms override init/round/output."""

    eta: float = 0.1
    k: int = 16  # oracle queries per client per round (paper's K)
    s: int = 0  # sampled clients per round; 0 => full participation (S=N)
    name: str = "base"

    def participation(self, problem):
        return self.s if self.s and self.s > 0 else problem.num_clients

    def init_with_eta(self, problem, x0, eta=None):
        """``init`` with an optional stepsize override written into state —
        the hook the sweep engine batches over."""
        state = self.init(problem, x0)
        if eta is not None:
            state = state._replace(
                eta=jnp.asarray(eta, jnp.result_type(state.eta)))
        return state

    # --- to be overridden -------------------------------------------------
    def init(self, problem, x0):
        raise NotImplementedError

    def round(self, problem, state, key):
        raise NotImplementedError

    def output(self, state):
        return state.x
