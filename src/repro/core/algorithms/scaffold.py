"""SCAFFOLD (Karimireddy et al. 2020b) — local-update baseline with client
control variates. Used by the paper both as a baseline and as A_local in the
SCAFFOLD→SGD chain (§6).

Per sampled client i:
  y ← y − η·(g_i(y) − c_i + c)        (local_steps times)
  c_i⁺ = c_i − c + (x − y_final)/(local_steps·η)      (Option II of the paper)
Server:
  x ← x + server_lr · mean_i (y_i − x)
  c ← c + (S/N) · mean_i (c_i⁺ − c_i)

Comm-aware: clients uplink TWO compressed vectors per round — the iterate
delta (y_i − x) and the control-variate delta (c_i⁺ − c_i); the server
broadcasts two (x and c). Masked-out clients keep their table entries.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import tree_math as tm
from repro.core.algorithms import base


class ScaffoldState(NamedTuple):
    x: object
    c_table: object  # [N, ...] per-client control variates
    c: object  # server control variate
    eta: jnp.ndarray
    r: jnp.ndarray
    comm: Optional[object] = None


@dataclasses.dataclass(frozen=True)
class Scaffold(base.FederatedAlgorithm):
    local_steps: int = 4
    inner_batch: int = 4
    server_lr: float = 1.0
    name: str = "scaffold"

    def init(self, problem, x0):
        n = problem.num_clients
        return ScaffoldState(
            x=x0,
            c_table=tm.tree_broadcast_leading(tm.tree_zeros_like(x0), n),
            c=tm.tree_zeros_like(x0),
            eta=jnp.asarray(self.eta),
            r=jnp.asarray(0),
        )

    def round(self, problem, state, key):
        k_sample, k_local = jax.random.split(key)
        comm = state.comm
        if comm is not None:
            from repro.comm import config as comm_cfg

            comm_cfg.reject_algo_participation(self.s, self.name)
        n = problem.num_clients
        s = n if comm is not None else self.participation(problem)
        cids = base.sample_clients(k_sample, problem.num_clients, s)
        keys = jax.random.split(k_local, s)
        c_i = jax.tree.map(lambda t: t[cids], state.c_table)
        x_start, c_start = state.x, state.c
        if comm is not None:
            from repro import comm as comm_lib

            # both broadcasts ride the downlink leg: the iterate through
            # the bidirectional-EF chain, the server variate stateless —
            # bitwise pass-throughs under an identity downlink leg
            x_start, comm = comm_lib.downlink(
                comm, state.x, comm_lib.downlink_key(key))
            c_start = comm_lib.downlink_second(
                comm, state.c, comm_lib.second_downlink_key(key))

        def local(cid, ci, kk):
            def step(y, k_step):
                ks = jax.random.split(k_step, self.inner_batch)
                gs = jax.vmap(lambda k2: problem.grad_oracle(y, cid, k2))(ks)
                g = tm.tree_mean_leading(gs)
                corr = jax.tree.map(lambda gg, cc, sc: gg - cc + sc, g, ci, c_start)
                return tm.tree_axpy(-state.eta, corr, y), None

            y, _ = jax.lax.scan(step, x_start, jax.random.split(kk, self.local_steps))
            ci_new = jax.tree.map(
                lambda cc, sc, x0_, yf: cc - sc + (x0_ - yf) / (self.local_steps * state.eta),
                ci, c_start, x_start, y,
            )
            return y, ci_new

        y_final, ci_new = jax.vmap(local)(cids, c_i, keys)
        if comm is not None:
            k_comm = comm_lib.comm_key(key)
            y_final, comm = comm_lib.uplink(
                comm, y_final, cids, k_comm, ref=x_start)
            # control deltas ride a second uplink (per-row reference, no EF)
            ci_new, comm = comm_lib.uplink(
                comm, ci_new, cids, comm_lib.second_uplink_key(key),
                ref=c_i, use_ef=False)
            from repro.comm import config as comm_cfg

            m = comm.mask[cids]
            scale = comm_lib.participation_scale(comm.mask, cids)
            y_mean = base.client_mean(state.x, y_final, weight_scale=scale)
            ci_new = comm_cfg.masked_keep(m, ci_new, c_i)
            comm = comm_lib.account_round(
                comm, state.x, up_vectors=2, down_vectors=2)
        else:
            y_mean = base.client_mean(state.x, y_final)
        x = tm.tree_lerp(self.server_lr, state.x, y_mean)
        delta_c = tm.tree_mean_leading(jax.tree.map(jnp.subtract, ci_new, c_i))
        c = tm.tree_axpy(s / n, delta_c, state.c)
        c_table = tm.tree_scatter_set(state.c_table, cids, ci_new)
        return ScaffoldState(x=x, c_table=c_table, c=c, eta=state.eta,
                             r=state.r + 1, comm=comm)

    def output(self, state):
        return state.x


@dataclasses.dataclass(frozen=True)
class FedProx(base.FederatedAlgorithm):
    """FedProx (Li et al. 2018): FedAvg with a proximal term μ_prox/2·||y−x||²
    added to every local objective. Baseline local-update method."""

    local_steps: int = 4
    inner_batch: int = 4
    server_lr: float = 1.0
    prox_mu: float = 0.1
    name: str = "fedprox"

    def init(self, problem, x0):
        from repro.core.algorithms.fedavg import FedAvgState

        return FedAvgState(x=x0, eta=jnp.asarray(self.eta), r=jnp.asarray(0))

    def round(self, problem, state, key):
        from repro.core.algorithms.fedavg import FedAvgState

        k_sample, k_local = jax.random.split(key)
        s = self.participation(problem)
        cids = base.sample_clients(k_sample, problem.num_clients, s)
        keys = jax.random.split(k_local, s)

        def local(cid, kk):
            def step(y, k_step):
                ks = jax.random.split(k_step, self.inner_batch)
                gs = jax.vmap(lambda k2: problem.grad_oracle(y, cid, k2))(ks)
                g = tm.tree_mean_leading(gs)
                g = jax.tree.map(
                    lambda gg, yy, xx: gg + self.prox_mu * (yy - xx), g, y, state.x
                )
                return tm.tree_axpy(-state.eta, g, y), None

            y, _ = jax.lax.scan(step, state.x, jax.random.split(kk, self.local_steps))
            return y

        y_final = jax.vmap(local)(cids, keys)
        y_mean = base.client_mean(state.x, y_final)
        x = tm.tree_lerp(self.server_lr, state.x, y_mean)
        return FedAvgState(x=x, eta=state.eta, r=state.r + 1)

    def output(self, state):
        return state.x
