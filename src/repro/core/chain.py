"""FedChain — the paper's Algorithm 1, plus multi-stage generalizations.

  x̂_1/2 ← A_local(x̂_0)                      (local_fraction · R rounds)
  x̂_1   ← better of {x̂_0, x̂_1/2}            (Lemma H.2 selection, S clients × K samples)
  x̂_2   ← A_global(x̂_1)                     (remaining rounds)

``Chain`` also supports >2 stages (e.g. FedAvg→SCAFFOLD→SGD) and optional
per-stage stepsize decay — the "multistage algorithms" of Fig. 2.

Execution model
---------------
A chain of N stages runs as ONE ``jax.lax.scan`` over a precomputed per-round
schedule: for each round, which stage executes (``stage_id``), whether the
round is a Lemma H.2 selection round (``kind``), whether a stage handoff
(selection + re-init of the incoming stage) happens before it (``hmode``),
and the η decay multiplier (``eta_scale``). Stage switching is a
``lax.switch`` over the per-stage round functions inside the scan body, so a
whole chain — stages, selection rounds, stepsize decay — compiles exactly
once per ``(chain, problem STRUCTURE)``: the problem rides in as a
``ProblemSpec`` operand (see ``repro.data.spec``), so every same-shaped
instance — an entire ζ/σ grid — shares the compile. The executor is cached
at module level (via ``runner``'s cache) and reused across calls, round
budgets and the sweep engine's vmapped grids.

The seed implementation Python-looped over stages with a separate jit per
stage per call; this executor replaces that with schedule data.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import runner as runner_lib
from repro.core import selection
from repro.core import tree_math as tm

# handoff modes (the transition INTO stage j, applied before its first round)
_H_NONE = 0  # no handoff this round
_H_ANCHOR = 1  # init from the anchor (a costed selection round already ran)
_H_SELECT = 2  # inline selection between anchor and previous stage's output
_H_TAKE = 3  # take the previous stage's output unconditionally


@dataclasses.dataclass
class ChainResult:
    x_hat: object
    history: jnp.ndarray  # concatenated per-round suboptimality
    switch_rounds: list  # round indices where a stage switch happened
    selected_initial: list  # per switch: True if selection kept the pre-stage point
    bits_up: Optional[jnp.ndarray] = None  # [R] per-round uplink bits (comm)
    bits_down: Optional[jnp.ndarray] = None  # [R] per-round downlink bits
    diagnostics: Optional[dict] = None  # per-round taps ([R] leaves), obs


@dataclasses.dataclass(frozen=True)
class _Schedule:
    """Static per-round schedule for a chain execution.

    Stepsize decay is NOT part of the schedule: η multipliers are an executor
    *operand* (see ``eta_schedule``), so a decay grid reuses one compile.
    """

    stage_id: np.ndarray  # [R] which stage's round (or whose output, kind=1)
    kind: np.ndarray  # [R] 0 = algorithm round, 1 = selection round
    hmode: np.ndarray  # [R] handoff mode before the round (_H_*)
    round_slot: np.ndarray  # [R] index into the stage's key block
    sel_stage: np.ndarray  # [R] selection key index (stage whose k_sel to use)
    budgets: tuple  # per-stage round budgets
    switch_rounds: tuple  # cumulative totals after each stage
    sel_indices: tuple  # round indices carrying a selection decision


@dataclasses.dataclass(frozen=True)
class Chain:
    """A FedChain instantiation: an ordered list of algorithms + fractions."""

    stages: Sequence[object]  # algorithm instances
    fractions: Sequence[float]  # round fractions per stage (sums to <= 1)
    selection_s: int = 0  # 0 => full participation
    selection_k: int = 16
    select_between_stages: bool = True
    selection_costs_round: bool = True
    name: str = "chain"

    def _key(self):
        # name is part of the key: TRACE_COUNTS entries are per-name, so two
        # same-config chains with different names must not share a counter
        return (tuple(self.stages), tuple(self.fractions), self.selection_s,
                self.selection_k, self.select_between_stages,
                self.selection_costs_round, self.name)

    def _fraction_free_key(self):
        """Cache key WITHOUT the round fractions — the fraction-sweep
        executor takes the whole per-round schedule as operands, so chains
        differing only in ``fractions`` share one compile."""
        return (tuple(self.stages), self.selection_s, self.selection_k,
                self.select_between_stages, self.selection_costs_round,
                self.name)

    def budgets(self, rounds: int):
        assert len(self.stages) == len(self.fractions)
        budgets = [max(1, int(round(f * rounds))) for f in self.fractions]
        # spend any rounding surplus/deficit on the last stage
        budgets[-1] += rounds - sum(budgets) - (
            (len(self.stages) - 1)
            if (self.select_between_stages and self.selection_costs_round) else 0
        )
        budgets[-1] = max(1, budgets[-1])
        return budgets

    def _schedule(self, rounds: int) -> _Schedule:
        budgets = self.budgets(rounds)
        n = len(self.stages)
        stage_id, kind, hmode, round_slot, sel_stage = [], [], [], [], []
        switch_rounds, sel_indices = [], []

        for i, b in enumerate(budgets):
            for j in range(b):
                mode = _H_NONE
                if i > 0 and j == 0:
                    if self.select_between_stages and self.selection_costs_round:
                        mode = _H_ANCHOR
                    elif self.select_between_stages:
                        mode = _H_SELECT
                        sel_indices.append(len(stage_id))
                    else:
                        mode = _H_TAKE
                stage_id.append(i)
                kind.append(0)
                hmode.append(mode)
                round_slot.append(j)
                sel_stage.append(max(i - 1, 0))
            if i + 1 < n and self.select_between_stages and self.selection_costs_round:
                sel_indices.append(len(stage_id))
                stage_id.append(i)
                kind.append(1)
                hmode.append(_H_NONE)
                round_slot.append(0)
                sel_stage.append(i)
            switch_rounds.append(len(stage_id))

        return _Schedule(
            stage_id=np.asarray(stage_id, np.int32),
            kind=np.asarray(kind, np.int32),
            hmode=np.asarray(hmode, np.int32),
            round_slot=np.asarray(round_slot, np.int32),
            sel_stage=np.asarray(sel_stage, np.int32),
            budgets=tuple(budgets),
            switch_rounds=tuple(switch_rounds),
            sel_indices=tuple(sel_indices),
        )

    def eta_schedule(self, rounds: int, decay: Optional[dict] = None):
        """Per-round η multipliers [R] — EXECUTOR OPERAND, not schedule
        structure: the paper's "M-" decay (per stage, selection rounds at
        1.0) is data, so sweeping ``decay_factor`` reuses one compile.

        Derived from ``_schedule``'s round enumeration (stage/slot/kind), so
        the multipliers stay aligned with the executor's rounds by
        construction."""
        sched = self._schedule(rounds)
        if decay is None:
            return jnp.ones((len(sched.stage_id),), jnp.float32)
        d_first = decay.get("decay_first", 0.3)
        d_factor = decay.get("decay_factor", 0.5)
        per_stage = [np.asarray(runner_lib.decay_eta_scale(b, d_first, d_factor))
                     for b in sched.budgets]
        out = np.asarray([
            1.0 if k == 1 else per_stage[s][j]
            for s, j, k in zip(sched.stage_id, sched.round_slot, sched.kind)
        ], np.float32)
        return jnp.asarray(out)

    def schedule_len(self, rounds: int) -> int:
        """Rounds the executor actually scans (algorithm + costed selection
        rounds). Constant across ``fractions`` for a fixed stage count —
        what lets a local-fraction grid ride one executor as operands."""
        return len(self._schedule(rounds).stage_id)

    def _derive_keys(self, sched: _Schedule, key):
        """Per-round and per-selection key streams for one schedule.

        Mirrors the seed's derivation: split(key, 2N), stage i's rounds use
        split(keys[2i], budget_i), selections after stage i use keys[2i+1].
        (With decay the seed split stage keys segment-wise; here rounds
        always split once per stage, so decayed-chain streams differ from
        pre-executor results — equivalent in distribution, not bit-for-bit.)
        Pure jax ops: the executors call it on a traced key, the fraction
        sweep calls it host-side per (fraction, seed) so the streams become
        operands — bit-exact with ``Chain.run`` either way.
        """
        n = len(self.stages)
        stage_keys = jax.random.split(key, 2 * n)
        round_keys = jnp.concatenate([
            jax.random.split(stage_keys[2 * i], b)
            for i, b in enumerate(sched.budgets)
        ])
        sel_keys = jnp.stack([stage_keys[2 * i + 1] for i in range(n)])

        # round_keys is indexed per stage block; build the flat [R] view
        offsets = np.concatenate([[0], np.cumsum(sched.budgets)[:-1]])
        flat_idx = jnp.asarray(
            offsets[sched.stage_id] + sched.round_slot, jnp.int32)
        return round_keys[flat_idx], sel_keys[jnp.asarray(sched.sel_stage)]

    def _round_ops(self, problem):
        """The per-round building blocks every chain executor shares:
        selection, stage output/reinit/round dispatch, and the handoff
        transition. All take the resolved problem ``p`` first; stage
        dispatch is a ``lax.switch`` over the static stage tuple, so these
        are schedule-agnostic (the fraction-sweep executor reuses them with
        the schedule as operands)."""
        import types

        stages = tuple(self.stages)
        n = len(stages)
        sel_s = (self.selection_s if self.selection_s > 0
                 else problem.num_clients)
        sel_k = self.selection_k

        def _select2(p, anchor, cand, k_sel):
            """Lemma H.2 pick between the anchor and a candidate; True = kept
            the anchor (argmin ties resolve to the anchor, as the seed did)."""
            vals = selection.empirical_values(
                p, [anchor, cand], k_sel, s=sel_s, k=sel_k)
            keep = vals[0] <= vals[1]
            return tm.tree_where(keep, anchor, cand), keep

        def _output(j, states):
            return jax.lax.switch(
                j, [lambda s, i=i: stages[i].output(s[i]) for i in range(n)],
                states)

        def _stage_x(j, states):
            # the active stage's current iterate (what the round broadcasts),
            # NOT its averaged output
            return jax.lax.switch(
                j, [lambda s, i=i: s[i].x for i in range(n)], states)

        def _reinit(p, j, states, x_init):
            """states with slot j re-initialized at x_init, base η preserved."""

            def branch(i):
                def init_i(args):
                    states, x = args
                    st = stages[i].init(p, x)
                    st = st._replace(eta=states[i].eta)
                    return states[:i] + (st,) + states[i + 1:]
                return init_i

            return jax.lax.switch(j, [branch(i) for i in range(n)],
                                  (states, x_init))

        def _round(p, j, states, k_round, scale):
            def branch(i):
                def round_i(args):
                    states, k, scale = args
                    st = states[i]
                    run = stages[i].round(p, st._replace(eta=st.eta * scale), k)
                    run = run._replace(eta=st.eta)
                    return states[:i] + (run,) + states[i + 1:]
                return round_i

            return jax.lax.switch(j, [branch(i) for i in range(n)],
                                  (states, k_round, scale))

        def _round_comm(p, j, states, comm_st, k_round, scale, mask):
            """One stage round with the shared CommState injected into (and
            pulled back out of) the active stage's state; every branch
            returns the ``comm=None`` structure the carry uses."""
            from repro.comm import config as comm_cfg

            def branch(i):
                def round_i(args):
                    states, comm_st, k, scale, mask = args
                    st = states[i]
                    st_in = st._replace(eta=st.eta * scale,
                                        comm=comm_st._replace(mask=mask))
                    out = stages[i].round(p, st_in, k)
                    new_comm = comm_cfg.comm_state_or_error(
                        out, stages[i].name)
                    out = out._replace(eta=st.eta, comm=None)
                    return states[:i] + (out,) + states[i + 1:], new_comm
                return round_i

            return jax.lax.switch(j, [branch(i) for i in range(n)],
                                  (states, comm_st, k_round, scale, mask))

        def _handoff(p, states, anchor, sid, hmd, k_sel):
            def do_handoff(args):
                states, anchor = args
                prev_out = _output(jnp.maximum(sid - 1, 0), states)

                def from_anchor(_):
                    return anchor, jnp.asarray(True)

                def with_sel(_):
                    return _select2(p, anchor, prev_out, k_sel)

                def take(_):
                    return prev_out, jnp.asarray(False)

                x_init, kept = jax.lax.switch(
                    hmd - 1, [from_anchor, with_sel, take], None)
                states = _reinit(p, sid, states, x_init)
                return states, x_init, kept

            def no_handoff(args):
                states, anchor = args
                return states, anchor, jnp.asarray(False)

            return jax.lax.cond(
                hmd > 0, do_handoff, no_handoff, (states, anchor))

        return types.SimpleNamespace(
            select2=_select2, output=_output, stage_x=_stage_x,
            reinit=_reinit, round=_round, round_comm=_round_comm,
            handoff=_handoff)

    def _plain_scan_body(self, ops, p, f_star, telemetry=None):
        """The non-comm per-round scan body over operand schedule rows
        ``(k_round, k_sel, sid, knd, hmd, scale)`` — shared by the fixed-
        schedule executor and the fraction-sweep (schedule-as-operand)
        executor. With ``telemetry`` set, the per-round taps dict rides as a
        third scan output (``update_norm`` measures the active stage's own
        movement — the post-handoff iterate before vs after the round)."""
        from repro.obs import telemetry as obs_tel

        def body(carry, xs):
            states, anchor = carry
            k_round, k_sel, sid, knd, hmd, scale = xs
            states, anchor, h_kept = ops.handoff(
                p, states, anchor, sid, hmd, k_sel)
            prev_x = (ops.stage_x(sid, states) if telemetry is not None
                      else None)

            def sel_round(args):
                states, anchor = args
                cand = ops.output(sid, states)
                best, kept = ops.select2(p, anchor, cand, k_sel)
                sub = p.global_loss(best) - f_star
                return states, best, sub, kept

            def alg_round(args):
                states, anchor = args
                states = ops.round(p, sid, states, k_round, scale)
                sub = p.global_loss(ops.output(sid, states)) - f_star
                return states, anchor, sub, jnp.asarray(False)

            states, anchor, sub, s_kept = jax.lax.cond(
                knd == 1, sel_round, alg_round, (states, anchor))
            if telemetry is None:
                return (states, anchor), (sub, h_kept | s_kept)
            x_eval = (ops.output(sid, states) if telemetry.grad_norm
                      else None)
            taps = obs_tel.round_taps(
                telemetry, problem=p, prev_x=prev_x,
                new_x=ops.stage_x(sid, states), x_eval=x_eval, stage=sid)
            return (states, anchor), (sub, h_kept | s_kept, taps)

        return body

    # -- executor ----------------------------------------------------------

    def executor_body(self, problem, rounds: int, comm: bool = False,
                      telemetry=None):
        """Unjitted single-scan chain executor.

        Returns ``fn(spec, x0, states0, key, eta_scale) -> (x_hat, history,
        sel_flags)`` where ``spec`` is the PROBLEM OPERAND (a ``ProblemSpec``
        pytree; None for legacy closure problems, which the executor then
        captures), ``states0`` is the tuple of per-stage initial states
        (their ``.eta`` fields carry any sweep stepsize scaling),
        ``eta_scale`` is the [R] per-round η multiplier operand (see
        ``eta_schedule``) and ``sel_flags`` is a [R] bool vector whose
        entries at ``schedule.sel_indices`` record whether selection kept
        the pre-stage anchor. The cache key is the spec's structural
        identity, so a ζ/σ grid of same-shaped problems shares one compile.

        With ``comm=True`` the signature grows ``(…, masks, comm0)`` — the
        [R, N] participation schedule and the initial ``CommState`` — and the
        executor returns ``(x_hat, history, sel_flags, bits_up, bits_down)``.
        One ``CommState`` is carried through the whole chain (residuals and
        bit meters persist across stage handoffs) and injected into the
        active stage's state each round; selection rounds are billed at the
        Lemma H.2 cost (2 candidates down, 1 scalar per candidate up).

        ``telemetry`` (a ``repro.obs.Telemetry``, part of the cache key)
        appends the per-round taps dict — stage index included — as a
        trailing scan output on either variant; ``None`` traces exactly the
        pre-telemetry jaxpr.
        """
        key = ("chain-body", self._key(), runner_lib.problem_key(problem),
               rounds, comm, telemetry)
        fn = runner_lib._cache_get(key)
        if fn is not None:
            return fn

        _, resolve = runner_lib._bind(problem)

        sched = self._schedule(rounds)
        stages = tuple(self.stages)
        ops = self._round_ops(problem)
        sel_s = (self.selection_s if self.selection_s > 0
                 else problem.num_clients)
        stage_id = jnp.asarray(sched.stage_id)
        kind = jnp.asarray(sched.kind)
        hmode = jnp.asarray(sched.hmode)

        if not comm:

            def executor(spec, x0, states0, key, eta_scale):
                from repro.core.algorithms import base as algo_base
                from repro.obs import events as obs_events

                p = resolve(spec)
                for st in states0:
                    algo_base.audit_state(st)  # protocol check, once per trace
                runner_lib.TRACE_COUNTS[f"chain/{self.name}"] += 1
                obs_events.TRACE_EVENTS[f"chain/{self.name}"] += 1
                f_star = runner_lib.f_star_operand(p)
                keys_r, keys_s = self._derive_keys(sched, key)

                (states, _), ys = jax.lax.scan(
                    self._plain_scan_body(ops, p, f_star, telemetry),
                    (states0, x0),
                    (keys_r, keys_s, stage_id, kind, hmode, eta_scale))
                x_hat = stages[-1].output(states[-1])
                if telemetry is None:
                    history, kept_flags = ys
                    return x_hat, history, kept_flags
                history, kept_flags, taps = ys
                return x_hat, history, kept_flags, taps

        else:

            def executor(spec, x0, states0, key, eta_scale, masks, comm0):
                from repro.comm import config as comm_cfg
                from repro.core.algorithms import base as algo_base
                from repro.obs import events as obs_events
                from repro.obs import telemetry as obs_tel

                p = resolve(spec)
                for st in states0:
                    algo_base.audit_state(st)
                runner_lib.TRACE_COUNTS[f"chain-comm/{self.name}"] += 1
                obs_events.TRACE_EVENTS[f"chain-comm/{self.name}"] += 1
                f_star = runner_lib.f_star_operand(p)
                keys_r, keys_s = self._derive_keys(sched, key)
                # selection broadcasts the whole parameter pytree (leaf dims
                # are static under trace)
                sel_up, sel_down = comm_cfg.selection_round_bits(x0, sel_s)

                def body(carry, xs):
                    states, anchor, comm_st = carry
                    k_round, k_sel, sid, knd, hmd, scale, mask = xs
                    comm_st = comm_cfg.zero_round_bits(comm_st)
                    # error-feedback residuals don't survive a stage
                    # handoff: the incoming stage's uplink payloads have
                    # different semantics (iterate deltas vs gradients), and
                    # the residual mass may belong to a trajectory selection
                    # just discarded; the server-side downlink residual
                    # resets for the same reason (the selection broadcast is
                    # full-precision, so clients hold the handoff point
                    # exactly)
                    comm_st = comm_st._replace(
                        residual=jax.tree.map(
                            lambda r: jnp.where(hmd > 0, 0.0, r),
                            comm_st.residual),
                        down_residual=jax.tree.map(
                            lambda r: jnp.where(hmd > 0, 0.0, r),
                            comm_st.down_residual))
                    states, anchor, h_kept = ops.handoff(
                        p, states, anchor, sid, hmd, k_sel)
                    prev_x = (ops.stage_x(sid, states)
                              if telemetry is not None else None)

                    def sel_round(args):
                        states, anchor, comm_st = args
                        cand = ops.output(sid, states)
                        best, kept = ops.select2(p, anchor, cand, k_sel)
                        sub = p.global_loss(best) - f_star
                        return states, best, comm_st, sub, kept

                    def alg_round(args):
                        states, anchor, comm_st = args
                        states, comm_st = ops.round_comm(
                            p, sid, states, comm_st, k_round, scale, mask)
                        sub = p.global_loss(ops.output(sid, states)) - f_star
                        return states, anchor, comm_st, sub, jnp.asarray(False)

                    states, anchor, comm_st, sub, s_kept = jax.lax.cond(
                        knd == 1, sel_round, alg_round,
                        (states, anchor, comm_st))

                    # Lemma H.2 selections (explicit rounds AND inline
                    # handoffs) bill their candidate broadcasts / value
                    # uplinks on top of whatever the stage round accounted.
                    did_sel = (knd == 1) | (hmd == _H_SELECT)
                    comm_st = comm_st._replace(
                        bits_up=comm_st.bits_up
                        + jnp.where(did_sel, sel_up, 0.0),
                        bits_down=comm_st.bits_down
                        + jnp.where(did_sel, sel_down, 0.0))
                    if telemetry is None:
                        return ((states, anchor, comm_st),
                                (sub, h_kept | s_kept,
                                 comm_st.bits_up, comm_st.bits_down))
                    x_eval = (ops.output(sid, states) if telemetry.grad_norm
                              else None)
                    taps = obs_tel.round_taps(
                        telemetry, problem=p, prev_x=prev_x,
                        new_x=ops.stage_x(sid, states), x_eval=x_eval,
                        comm=comm_st, mask=mask, stage=sid,
                        bits_up=comm_st.bits_up,
                        bits_down=comm_st.bits_down)
                    return ((states, anchor, comm_st),
                            (sub, h_kept | s_kept,
                             comm_st.bits_up, comm_st.bits_down, taps))

                (states, _, _), ys = jax.lax.scan(
                    body, (states0, x0, comm0),
                    (keys_r, keys_s, stage_id, kind, hmode, eta_scale,
                     masks))
                x_hat = stages[-1].output(states[-1])
                if telemetry is None:
                    history, kept_flags, bits_up, bits_down = ys
                    return x_hat, history, kept_flags, bits_up, bits_down
                history, kept_flags, bits_up, bits_down, taps = ys
                return (x_hat, history, kept_flags, bits_up, bits_down,
                        taps)

        return runner_lib._cache_put(key, executor)

    def executor(self, problem, rounds: int, comm: bool = False,
                 telemetry=None):
        """The jitted, module-cached chain executor.

        ``states0`` (argnum 2) is donated — the per-stage scan carry is
        rebuilt fresh by every caller (``init_states``), so its buffers are
        free for the outputs on donation-capable backends. The comm variant
        also donates the initial ``CommState`` (argnum 6, built fresh from
        ``CommConfig.init_state``) but NOT the masks: ``run`` forwards
        user-supplied ``comm_masks`` arrays there. Donated argnums are part
        of the cache key.
        """
        donate = (2, 6) if comm else (2,)
        key = ("chain-jit", self._key(), runner_lib.problem_key(problem),
               rounds, comm, telemetry, donate)
        fn = runner_lib._cache_get(key)
        if fn is not None:
            return fn
        return runner_lib._cache_put(
            key, jax.jit(self.executor_body(problem, rounds, comm, telemetry),
                         donate_argnums=donate))

    def selection_executor_body(self, problem, rounds: int, telemetry=None):
        """The policy-selection chain executor (comm-enabled).

        Returns ``fn(spec, x0, states0, key, eta_scale, sel_keys, pparams,
        pstate0, comm0) -> (x_hat, history, kept_flags, bits_up, bits_down,
        masks, pstate)``.  Like the ``comm=True`` executor but per-round
        participation comes from ``selection.policies.round_select`` instead
        of a precomputed [R, N] mask schedule: the policy (a
        ``PolicyParams`` switch-index operand) sees the ACTIVE stage's
        post-handoff iterate each scheduled round and its ``PolicyState``
        rides the scan carry.  The policy advances on Lemma H.2 selection
        rounds too (one ``sel_keys`` row per scheduled round, so the key
        stream stays aligned with the schedule); probing policies bill
        their value probe every round on top of the stage/selection bits.

        With ``telemetry`` set (part of the cache key) the scan additionally
        emits the per-round taps dict — policy-state summaries and the
        active stage index included — as a trailing output.
        """
        key = ("chain-sel-body", self._key(),
               runner_lib.problem_key(problem), rounds, telemetry)
        fn = runner_lib._cache_get(key)
        if fn is not None:
            return fn

        _, resolve = runner_lib._bind(problem)

        sched = self._schedule(rounds)
        stages = tuple(self.stages)
        ops = self._round_ops(problem)
        sel_s = (self.selection_s if self.selection_s > 0
                 else problem.num_clients)
        stage_id = jnp.asarray(sched.stage_id)
        kind = jnp.asarray(sched.kind)
        hmode = jnp.asarray(sched.hmode)

        def executor(spec, x0, states0, key, eta_scale, sel_keys, pparams,
                     pstate0, comm0):
            from repro.comm import config as comm_cfg
            from repro.core.algorithms import base as algo_base
            from repro.obs import events as obs_events
            from repro.obs import telemetry as obs_tel
            from repro.selection import policies as pol

            p = resolve(spec)
            for st in states0:
                algo_base.audit_state(st)
            runner_lib.TRACE_COUNTS[f"chain-sel/{self.name}"] += 1
            obs_events.TRACE_EVENTS[f"chain-sel/{self.name}"] += 1
            f_star = runner_lib.f_star_operand(p)
            keys_r, keys_s = self._derive_keys(sched, key)
            sel_up, sel_down = comm_cfg.selection_round_bits(x0, sel_s)
            extra_up = pol.probe_bits(pparams, p.num_clients)

            def body(carry, xs):
                states, anchor, comm_st, pstate = carry
                k_round, k_sel, sid, knd, hmd, scale, k_pol = xs
                comm_st = comm_cfg.zero_round_bits(comm_st)
                comm_st = comm_st._replace(
                    residual=jax.tree.map(
                        lambda r: jnp.where(hmd > 0, 0.0, r),
                        comm_st.residual),
                    down_residual=jax.tree.map(
                        lambda r: jnp.where(hmd > 0, 0.0, r),
                        comm_st.down_residual))
                states, anchor, h_kept = ops.handoff(
                    p, states, anchor, sid, hmd, k_sel)
                prev_x = (ops.stage_x(sid, states) if telemetry is not None
                          else None)
                mask, pstate = pol.round_select(
                    p, ops.stage_x(sid, states), pstate, pparams, k_pol)

                def sel_round(args):
                    states, anchor, comm_st = args
                    cand = ops.output(sid, states)
                    best, kept = ops.select2(p, anchor, cand, k_sel)
                    sub = p.global_loss(best) - f_star
                    return states, best, comm_st, sub, kept

                def alg_round(args):
                    states, anchor, comm_st = args
                    states, comm_st = ops.round_comm(
                        p, sid, states, comm_st, k_round, scale, mask)
                    sub = p.global_loss(ops.output(sid, states)) - f_star
                    return states, anchor, comm_st, sub, jnp.asarray(False)

                states, anchor, comm_st, sub, s_kept = jax.lax.cond(
                    knd == 1, sel_round, alg_round,
                    (states, anchor, comm_st))

                did_sel = (knd == 1) | (hmd == _H_SELECT)
                comm_st = comm_st._replace(
                    bits_up=comm_st.bits_up
                    + jnp.where(did_sel, sel_up, 0.0) + extra_up,
                    bits_down=comm_st.bits_down
                    + jnp.where(did_sel, sel_down, 0.0))
                if telemetry is None:
                    return ((states, anchor, comm_st, pstate),
                            (sub, h_kept | s_kept,
                             comm_st.bits_up, comm_st.bits_down, mask))
                x_eval = (ops.output(sid, states) if telemetry.grad_norm
                          else None)
                taps = obs_tel.round_taps(
                    telemetry, problem=p, prev_x=prev_x,
                    new_x=ops.stage_x(sid, states), x_eval=x_eval,
                    comm=comm_st, mask=mask, pstate=pstate, stage=sid,
                    bits_up=comm_st.bits_up, bits_down=comm_st.bits_down)
                return ((states, anchor, comm_st, pstate),
                        (sub, h_kept | s_kept,
                         comm_st.bits_up, comm_st.bits_down, mask, taps))

            (states, _, _, pstate), ys = jax.lax.scan(
                body, (states0, x0, comm0, pstate0),
                (keys_r, keys_s, stage_id, kind, hmode, eta_scale,
                 sel_keys))
            x_hat = stages[-1].output(states[-1])
            if telemetry is None:
                history, kept_flags, bits_up, bits_down, masks = ys
                return (x_hat, history, kept_flags, bits_up, bits_down,
                        masks, pstate)
            history, kept_flags, bits_up, bits_down, masks, taps = ys
            return (x_hat, history, kept_flags, bits_up, bits_down, masks,
                    pstate, taps)

        return runner_lib._cache_put(key, executor)

    def fraction_executor_body(self, problem, rounds: int):
        """The schedule-as-OPERAND chain executor (local-fraction sweeps).

        ``executor_body`` bakes this chain's ``fractions`` into the trace
        twice: the per-stage key derivation and the selection-row indices.
        This variant instead takes the whole per-round schedule as data —

          ``fn(spec, x0, states0, keys_r, keys_s, stage_id, kind, hmode,
          eta_scale) -> (x_hat, history, kept_flags)``

        with ``keys_r``/``keys_s`` the [R, 2] precomputed key streams
        (``_derive_keys`` run host-side) and ``stage_id``/``kind``/``hmode``
        the [R] rows of ``_schedule``. The App. I.2 ``local_fraction``
        tuning grid then rides ONE compile: every fraction of a fixed stage
        tuple has the same schedule LENGTH (``schedule_len``), so a stacked
        fraction axis is just more operand rows — and each row replays the
        exact key streams ``Chain.run``'s executor derives in-trace for the
        corresponding per-fraction chain. Cache key:
        ``_fraction_free_key`` — chains differing only in ``fractions``
        share the compile.
        """
        key = ("chain-frac-body", self._fraction_free_key(),
               runner_lib.problem_key(problem), rounds)
        fn = runner_lib._cache_get(key)
        if fn is not None:
            return fn

        _, resolve = runner_lib._bind(problem)
        stages = tuple(self.stages)
        ops = self._round_ops(problem)

        def executor(spec, x0, states0, keys_r, keys_s, stage_id, kind,
                     hmode, eta_scale):
            from repro.core.algorithms import base as algo_base
            from repro.obs import events as obs_events

            p = resolve(spec)
            for st in states0:
                algo_base.audit_state(st)
            runner_lib.TRACE_COUNTS[f"chain-frac/{self.name}"] += 1
            obs_events.TRACE_EVENTS[f"chain-frac/{self.name}"] += 1
            f_star = runner_lib.f_star_operand(p)

            (states, _), (history, kept_flags) = jax.lax.scan(
                self._plain_scan_body(ops, p, f_star), (states0, x0),
                (keys_r, keys_s, stage_id, kind, hmode, eta_scale))
            x_hat = stages[-1].output(states[-1])
            return x_hat, history, kept_flags

        return runner_lib._cache_put(key, executor)

    def with_local_fraction(self, fraction: float) -> "Chain":
        """This chain with its FIRST stage's round share set to ``fraction``
        (two-stage chains only — the paper's Algo 1 tuning knob)."""
        if len(self.stages) != 2:
            raise ValueError(
                f"local_fraction is the two-stage FedChain knob; this chain "
                f"has {len(self.stages)} stages")
        if not 0.0 < fraction < 1.0:
            raise ValueError(f"local_fraction must be in (0, 1), "
                             f"got {fraction}")
        return dataclasses.replace(
            self, fractions=(fraction, 1.0 - fraction))

    def init_states(self, problem, x0, eta_scale=None):
        """Per-stage initial states; ``eta_scale`` multiplies every stage's
        base stepsize (the sweep engine's batched axis)."""
        states = tuple(a.init(problem, x0) for a in self.stages)
        if eta_scale is not None:
            states = tuple(s._replace(eta=s.eta * eta_scale) for s in states)
        return states

    def run(self, problem, x0, rounds: int, key, *, decay: Optional[dict] = None,
            eta_scale=None, comm=None, comm_masks=None, telemetry=None):
        """Execute the chain for a total budget of ``rounds`` communication
        rounds — a single compiled call regardless of stage count, decay
        schedule, or comm config (decay multipliers, participation masks and
        compressor knobs are all executor operands).

        ``comm`` (a ``repro.comm.CommConfig``) enables compressed uplinks +
        partial participation + bits accounting; ``comm_masks`` overrides the
        config-derived [R, N] schedule. ``telemetry`` (a
        ``repro.obs.Telemetry``) returns the per-round taps in the result's
        ``diagnostics``; ``None`` is bitwise identical to a run without the
        telemetry layer.
        """
        sched = self._schedule(rounds)
        eta_arr = self.eta_schedule(rounds, decay)
        states0 = self.init_states(problem, x0, eta_scale)
        spec = runner_lib.as_spec(problem)
        bits_up = bits_down = taps = None
        if comm is None:
            fn = self.executor(problem, rounds, telemetry=telemetry)
            states0 = runner_lib.dealias_donated(
                states0, spec, x0, key, eta_arr)
            if telemetry is None:
                x_hat, history, kept_flags = fn(
                    spec, x0, states0, key, eta_arr)
            else:
                x_hat, history, kept_flags, taps = fn(
                    spec, x0, states0, key, eta_arr)
        else:
            from repro.comm import config as comm_cfg

            for stage, st in zip(self.stages, states0):
                comm_cfg.require_comm_leaf(st, stage.name)
            n_clients = problem.num_clients
            masks = (comm.round_masks(len(sched.stage_id), n_clients)
                     if comm_masks is None
                     else jnp.asarray(comm_masks, jnp.float32))
            comm0 = comm.init_state(n_clients, x0)
            fn = self.executor(problem, rounds, comm=True,
                               telemetry=telemetry)
            states0 = runner_lib.dealias_donated(
                states0, spec, x0, key, eta_arr, masks)
            comm0 = runner_lib.dealias_donated(
                comm0, spec, x0, states0, key, eta_arr, masks)
            if telemetry is None:
                x_hat, history, kept_flags, bits_up, bits_down = fn(
                    spec, x0, states0, key, eta_arr, masks, comm0)
            else:
                x_hat, history, kept_flags, bits_up, bits_down, taps = fn(
                    spec, x0, states0, key, eta_arr, masks, comm0)
        kept = np.asarray(kept_flags)
        return ChainResult(
            x_hat=x_hat,
            history=history,
            switch_rounds=list(sched.switch_rounds[:-1]),
            selected_initial=[bool(kept[i]) for i in sched.sel_indices],
            bits_up=bits_up,
            bits_down=bits_down,
            diagnostics=taps,
        )


def fedchain(a_local, a_global, *, local_fraction: float = 0.5, **kw) -> Chain:
    """The canonical two-stage FedChain (Algo 1)."""
    name = kw.pop("name", f"{a_local.name}->{a_global.name}")
    return Chain(
        stages=[a_local, a_global],
        fractions=[local_fraction, 1.0 - local_fraction],
        name=name,
        **kw,
    )
