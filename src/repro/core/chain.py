"""FedChain — the paper's Algorithm 1, plus multi-stage generalizations.

  x̂_1/2 ← A_local(x̂_0)                      (local_fraction · R rounds)
  x̂_1   ← better of {x̂_0, x̂_1/2}            (Lemma H.2 selection, S clients × K samples)
  x̂_2   ← A_global(x̂_1)                     (remaining rounds)

``Chain`` also supports >2 stages (e.g. FedAvg→SCAFFOLD→SGD) and optional
per-stage stepsize decay — the "multistage algorithms" of Fig. 2.

Execution model
---------------
A chain of N stages runs as ONE ``jax.lax.scan`` over a precomputed per-round
schedule: for each round, which stage executes (``stage_id``), whether the
round is a Lemma H.2 selection round (``kind``), whether a stage handoff
(selection + re-init of the incoming stage) happens before it (``hmode``),
and the η decay multiplier (``eta_scale``). Stage switching is a
``lax.switch`` over the per-stage round functions inside the scan body, so a
whole chain — stages, selection rounds, stepsize decay — compiles exactly
once per ``(chain, problem)``; the compiled executor is cached at module
level (via ``runner``'s cache) and reused across calls, round budgets and the
sweep engine's vmapped grids.

The seed implementation Python-looped over stages with a separate jit per
stage per call; this executor replaces that with schedule data.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import runner as runner_lib
from repro.core import selection
from repro.core import tree_math as tm

# handoff modes (the transition INTO stage j, applied before its first round)
_H_NONE = 0  # no handoff this round
_H_ANCHOR = 1  # init from the anchor (a costed selection round already ran)
_H_SELECT = 2  # inline selection between anchor and previous stage's output
_H_TAKE = 3  # take the previous stage's output unconditionally


@dataclasses.dataclass
class ChainResult:
    x_hat: object
    history: jnp.ndarray  # concatenated per-round suboptimality
    switch_rounds: list  # round indices where a stage switch happened
    selected_initial: list  # per switch: True if selection kept the pre-stage point


@dataclasses.dataclass(frozen=True)
class _Schedule:
    """Static per-round schedule for a chain execution."""

    stage_id: np.ndarray  # [R] which stage's round (or whose output, kind=1)
    kind: np.ndarray  # [R] 0 = algorithm round, 1 = selection round
    hmode: np.ndarray  # [R] handoff mode before the round (_H_*)
    eta_scale: np.ndarray  # [R] per-round stepsize multiplier
    round_slot: np.ndarray  # [R] index into the stage's key block
    sel_stage: np.ndarray  # [R] selection key index (stage whose k_sel to use)
    budgets: tuple  # per-stage round budgets
    switch_rounds: tuple  # cumulative totals after each stage
    sel_indices: tuple  # round indices carrying a selection decision


@dataclasses.dataclass(frozen=True)
class Chain:
    """A FedChain instantiation: an ordered list of algorithms + fractions."""

    stages: Sequence[object]  # algorithm instances
    fractions: Sequence[float]  # round fractions per stage (sums to <= 1)
    selection_s: int = 0  # 0 => full participation
    selection_k: int = 16
    select_between_stages: bool = True
    selection_costs_round: bool = True
    name: str = "chain"

    def _key(self):
        # name is part of the key: TRACE_COUNTS entries are per-name, so two
        # same-config chains with different names must not share a counter
        return (tuple(self.stages), tuple(self.fractions), self.selection_s,
                self.selection_k, self.select_between_stages,
                self.selection_costs_round, self.name)

    def budgets(self, rounds: int):
        assert len(self.stages) == len(self.fractions)
        budgets = [max(1, int(round(f * rounds))) for f in self.fractions]
        # spend any rounding surplus/deficit on the last stage
        budgets[-1] += rounds - sum(budgets) - (
            (len(self.stages) - 1)
            if (self.select_between_stages and self.selection_costs_round) else 0
        )
        budgets[-1] = max(1, budgets[-1])
        return budgets

    def _schedule(self, rounds: int, decay: Optional[dict] = None) -> _Schedule:
        budgets = self.budgets(rounds)
        n = len(self.stages)
        stage_id, kind, hmode, eta_scale, round_slot, sel_stage = [], [], [], [], [], []
        switch_rounds, sel_indices = [], []
        if decay is not None:
            d_first = decay.get("decay_first", 0.3)
            d_factor = decay.get("decay_factor", 0.5)

        for i, b in enumerate(budgets):
            scales = (np.asarray(runner_lib.decay_eta_scale(b, d_first, d_factor))
                      if decay is not None else np.ones((b,), np.float32))
            for j in range(b):
                mode = _H_NONE
                if i > 0 and j == 0:
                    if self.select_between_stages and self.selection_costs_round:
                        mode = _H_ANCHOR
                    elif self.select_between_stages:
                        mode = _H_SELECT
                        sel_indices.append(len(stage_id))
                    else:
                        mode = _H_TAKE
                stage_id.append(i)
                kind.append(0)
                hmode.append(mode)
                eta_scale.append(scales[j])
                round_slot.append(j)
                sel_stage.append(max(i - 1, 0))
            if i + 1 < n and self.select_between_stages and self.selection_costs_round:
                sel_indices.append(len(stage_id))
                stage_id.append(i)
                kind.append(1)
                hmode.append(_H_NONE)
                eta_scale.append(1.0)
                round_slot.append(0)
                sel_stage.append(i)
            switch_rounds.append(len(stage_id))

        return _Schedule(
            stage_id=np.asarray(stage_id, np.int32),
            kind=np.asarray(kind, np.int32),
            hmode=np.asarray(hmode, np.int32),
            eta_scale=np.asarray(eta_scale, np.float32),
            round_slot=np.asarray(round_slot, np.int32),
            sel_stage=np.asarray(sel_stage, np.int32),
            budgets=tuple(budgets),
            switch_rounds=tuple(switch_rounds),
            sel_indices=tuple(sel_indices),
        )

    # -- executor ----------------------------------------------------------

    def executor_body(self, problem, rounds: int, decay: Optional[dict] = None):
        """Unjitted single-scan chain executor.

        Returns ``fn(x0, states0, key) -> (x_hat, history, sel_flags)`` where
        ``states0`` is the tuple of per-stage initial states (their ``.eta``
        fields carry any sweep stepsize scaling) and ``sel_flags`` is a [R]
        bool vector whose entries at ``schedule.sel_indices`` record whether
        selection kept the pre-stage anchor.
        """
        decay_key = tuple(sorted(decay.items())) if decay is not None else None
        key = ("chain-body", self._key(), id(problem), rounds, decay_key)
        fn = runner_lib._cache_get(key, problem)
        if fn is not None:
            return fn

        sched = self._schedule(rounds, decay)
        stages = tuple(self.stages)
        n = len(stages)
        f_star = problem.f_star if problem.f_star is not None else 0.0
        sel_s = self.selection_s if self.selection_s > 0 else problem.num_clients
        sel_k = self.selection_k
        stage_id = jnp.asarray(sched.stage_id)
        kind = jnp.asarray(sched.kind)
        hmode = jnp.asarray(sched.hmode)
        eta_scale = jnp.asarray(sched.eta_scale)

        def _select2(anchor, cand, k_sel):
            """Lemma H.2 pick between the anchor and a candidate; True = kept
            the anchor (argmin ties resolve to the anchor, as the seed did)."""
            vals = selection.empirical_values(
                problem, [anchor, cand], k_sel, s=sel_s, k=sel_k)
            keep = vals[0] <= vals[1]
            return tm.tree_where(keep, anchor, cand), keep

        def _output(j, states):
            return jax.lax.switch(
                j, [lambda s, i=i: stages[i].output(s[i]) for i in range(n)], states)

        def _reinit(j, states, x_init):
            """states with slot j re-initialized at x_init, base η preserved."""

            def branch(i):
                def init_i(args):
                    states, x = args
                    st = stages[i].init(problem, x)
                    st = st._replace(eta=states[i].eta)
                    return states[:i] + (st,) + states[i + 1:]
                return init_i

            return jax.lax.switch(j, [branch(i) for i in range(n)], (states, x_init))

        def _round(j, states, k_round, scale):
            def branch(i):
                def round_i(args):
                    states, k, scale = args
                    st = states[i]
                    run = stages[i].round(problem, st._replace(eta=st.eta * scale), k)
                    run = run._replace(eta=st.eta)
                    return states[:i] + (run,) + states[i + 1:]
                return round_i

            return jax.lax.switch(j, [branch(i) for i in range(n)],
                                  (states, k_round, scale))

        def executor(x0, states0, key):
            from repro.core.algorithms import base as algo_base

            for st in states0:
                algo_base.audit_state(st)  # protocol check, once per trace
            runner_lib.TRACE_COUNTS[f"chain/{self.name}"] += 1

            # Per-round keys mirror the seed's derivation: split(key, 2N),
            # stage i's rounds use split(keys[2i], budget_i), selections after
            # stage i use keys[2i+1]. (With decay the seed split stage keys
            # segment-wise; here rounds always split once per stage, so
            # decayed-chain streams differ from pre-executor results —
            # equivalent in distribution, not bit-for-bit.)
            stage_keys = jax.random.split(key, 2 * n)
            round_keys = jnp.concatenate([
                jax.random.split(stage_keys[2 * i], b)
                for i, b in enumerate(sched.budgets)
            ])
            sel_keys = jnp.stack([stage_keys[2 * i + 1] for i in range(n)])

            # round_keys is indexed per stage block; build the flat [R] view
            offsets = np.concatenate([[0], np.cumsum(sched.budgets)[:-1]])
            flat_idx = jnp.asarray(
                offsets[sched.stage_id] + sched.round_slot, jnp.int32)
            keys_r = round_keys[flat_idx]  # [R, 2]
            keys_s = sel_keys[jnp.asarray(sched.sel_stage)]  # [R, 2]

            def body(carry, xs):
                states, anchor = carry
                k_round, k_sel, sid, knd, hmd, scale = xs

                def do_handoff(args):
                    states, anchor = args
                    prev_out = _output(jnp.maximum(sid - 1, 0), states)

                    def from_anchor(_):
                        return anchor, jnp.asarray(True)

                    def with_sel(_):
                        return _select2(anchor, prev_out, k_sel)

                    def take(_):
                        return prev_out, jnp.asarray(False)

                    x_init, kept = jax.lax.switch(
                        hmd - 1, [from_anchor, with_sel, take], None)
                    states = _reinit(sid, states, x_init)
                    return states, x_init, kept

                def no_handoff(args):
                    states, anchor = args
                    return states, anchor, jnp.asarray(False)

                states, anchor, h_kept = jax.lax.cond(
                    hmd > 0, do_handoff, no_handoff, (states, anchor))

                def sel_round(args):
                    states, anchor = args
                    cand = _output(sid, states)
                    best, kept = _select2(anchor, cand, k_sel)
                    sub = problem.global_loss(best) - f_star
                    return states, best, sub, kept

                def alg_round(args):
                    states, anchor = args
                    states = _round(sid, states, k_round, scale)
                    sub = problem.global_loss(_output(sid, states)) - f_star
                    return states, anchor, sub, jnp.asarray(False)

                states, anchor, sub, s_kept = jax.lax.cond(
                    knd == 1, sel_round, alg_round, (states, anchor))
                return (states, anchor), (sub, h_kept | s_kept)

            (states, _), (history, kept_flags) = jax.lax.scan(
                body, (states0, x0),
                (keys_r, keys_s, stage_id, kind, hmode, eta_scale))
            x_hat = stages[-1].output(states[-1])
            return x_hat, history, kept_flags

        return runner_lib._cache_put(key, problem, executor)

    def executor(self, problem, rounds: int, decay: Optional[dict] = None):
        """The jitted, module-cached chain executor."""
        decay_key = tuple(sorted(decay.items())) if decay is not None else None
        key = ("chain-jit", self._key(), id(problem), rounds, decay_key)
        fn = runner_lib._cache_get(key, problem)
        if fn is not None:
            return fn
        return runner_lib._cache_put(
            key, problem, jax.jit(self.executor_body(problem, rounds, decay)))

    def init_states(self, problem, x0, eta_scale=None):
        """Per-stage initial states; ``eta_scale`` multiplies every stage's
        base stepsize (the sweep engine's batched axis)."""
        states = tuple(a.init(problem, x0) for a in self.stages)
        if eta_scale is not None:
            states = tuple(s._replace(eta=s.eta * eta_scale) for s in states)
        return states

    def run(self, problem, x0, rounds: int, key, *, decay: Optional[dict] = None,
            eta_scale=None):
        """Execute the chain for a total budget of ``rounds`` communication
        rounds — a single compiled call regardless of stage count."""
        sched = self._schedule(rounds, decay)
        fn = self.executor(problem, rounds, decay)
        states0 = self.init_states(problem, x0, eta_scale)
        x_hat, history, kept_flags = fn(x0, states0, key)
        kept = np.asarray(kept_flags)
        return ChainResult(
            x_hat=x_hat,
            history=history,
            switch_rounds=list(sched.switch_rounds[:-1]),
            selected_initial=[bool(kept[i]) for i in sched.sel_indices],
        )


def fedchain(a_local, a_global, *, local_fraction: float = 0.5, **kw) -> Chain:
    """The canonical two-stage FedChain (Algo 1)."""
    name = kw.pop("name", f"{a_local.name}->{a_global.name}")
    return Chain(
        stages=[a_local, a_global],
        fractions=[local_fraction, 1.0 - local_fraction],
        name=name,
        **kw,
    )
