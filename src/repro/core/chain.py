"""FedChain — the paper's Algorithm 1, plus multi-stage generalizations.

  x̂_1/2 ← A_local(x̂_0)                      (local_fraction · R rounds)
  x̂_1   ← better of {x̂_0, x̂_1/2}            (Lemma H.2 selection, S clients × K samples)
  x̂_2   ← A_global(x̂_1)                     (remaining rounds)

``Chain`` also supports >2 stages (e.g. FedAvg→SCAFFOLD→SGD) and optional
per-stage stepsize decay — the "multistage algorithms" of Fig. 2.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import jax
import jax.numpy as jnp

from repro.core import runner as runner_lib
from repro.core import selection


@dataclasses.dataclass
class ChainResult:
    x_hat: object
    history: jnp.ndarray  # concatenated per-round suboptimality
    switch_rounds: list  # round indices where a stage switch happened
    selected_initial: list  # per switch: True if selection kept the pre-stage point


@dataclasses.dataclass(frozen=True)
class Chain:
    """A FedChain instantiation: an ordered list of algorithms + fractions."""

    stages: Sequence[object]  # algorithm instances
    fractions: Sequence[float]  # round fractions per stage (sums to <= 1)
    selection_s: int = 0  # 0 => full participation
    selection_k: int = 16
    select_between_stages: bool = True
    selection_costs_round: bool = True
    name: str = "chain"

    def run(self, problem, x0, rounds: int, key, *, decay: Optional[dict] = None):
        """Execute the chain for a total budget of ``rounds`` communication rounds."""
        assert len(self.stages) == len(self.fractions)
        budgets = [max(1, int(round(f * rounds))) for f in self.fractions]
        # spend any rounding surplus/deficit on the last stage
        budgets[-1] += rounds - sum(budgets) - (
            (len(self.stages) - 1) if (self.select_between_stages and self.selection_costs_round) else 0
        )
        budgets[-1] = max(1, budgets[-1])

        f_star = problem.f_star if problem.f_star is not None else 0.0
        x = x0
        hist = []
        switch_rounds = []
        selected_initial = []
        total = 0
        sel_s = self.selection_s if self.selection_s > 0 else problem.num_clients
        keys = jax.random.split(key, 2 * len(self.stages))

        for i, (algo, budget) in enumerate(zip(self.stages, budgets)):
            k_run, k_sel = keys[2 * i], keys[2 * i + 1]
            if decay is not None:
                res = runner_lib.run_with_decay(algo, problem, x, budget, k_run, **decay)
            else:
                res = runner_lib.run(algo, problem, x, budget, k_run)
            hist.append(res.history)
            total += budget
            x_candidate = res.x_hat
            if i + 1 < len(self.stages) and self.select_between_stages:
                best, idx, _ = selection.select_better(
                    problem, [x, x_candidate], k_sel, s=sel_s, k=self.selection_k
                )
                selected_initial.append(bool(idx == 0))
                x = best
                if self.selection_costs_round:
                    hist.append(jnp.asarray([problem.global_loss(x) - f_star]))
                    total += 1
            else:
                x = x_candidate
            switch_rounds.append(total)

        return ChainResult(
            x_hat=x,
            history=jnp.concatenate(hist),
            switch_rounds=switch_rounds[:-1],
            selected_initial=selected_initial,
        )


def fedchain(a_local, a_global, *, local_fraction: float = 0.5, **kw) -> Chain:
    """The canonical two-stage FedChain (Algo 1)."""
    name = kw.pop("name", f"{a_local.name}->{a_global.name}")
    return Chain(
        stages=[a_local, a_global],
        fractions=[local_fraction, 1.0 - local_fraction],
        name=name,
        **kw,
    )
