"""Estimators for the paper's problem constants.

ζ² (Assumption B.5) is a sup over x — we estimate it by maximizing over a set
of probe points (trajectory iterates and/or random points in a ball), which
lower-bounds the true ζ and is exact for the constructions in
``repro.data.spec``/``repro.data.problems`` whose gradient differences are
constant in x.

Every estimator takes a *problem* duck-typed as the oracle surface
(``num_clients``, ``client_loss``, ``global_loss``, ``grad_oracle``) — a
``ProblemSpec`` or a legacy ``FederatedProblem`` shim both work.
``with_measured_heterogeneity`` is the spec-native entry point: it returns a
NEW spec whose ζ/ζ_F constant leaves carry the measured values (specs are
immutable pytrees; constants are data, so updating them is a leaf swap that
does not change the executor cache key).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import tree_math as tm


def zeta_at(problem, x):
    """max_i ||∇F_i(x) − ∇F(x)|| at a single point x."""
    g_bar = jax.grad(problem.global_loss)(x)

    def one(i):
        g_i = jax.grad(problem.client_loss)(x, i)
        return tm.tree_sq_norm(tm.tree_sub(g_i, g_bar))

    sq = jax.vmap(one)(jnp.arange(problem.num_clients))
    return jnp.sqrt(jnp.max(sq))


def estimate_zeta(problem, probes):
    """max over probe points of zeta_at — a lower bound on the true ζ."""
    vals = jnp.stack([zeta_at(problem, x) for x in probes])
    return jnp.max(vals)


def zeta_f_at(problem, x):
    """max_i |F_i(x) − F(x)| at a point (Assumption B.8 analogue)."""
    f_bar = problem.global_loss(x)

    def one(i):
        return jnp.abs(problem.client_loss(x, i) - f_bar)

    return jnp.max(jax.vmap(one)(jnp.arange(problem.num_clients)))


def probe_points(x_init, key, *, probes: int = 8, radius: float = 1.0):
    """The init point plus ``probes`` random points in a ``radius`` ball —
    the probe set the logreg builders maximize ζ/ζ_F over."""
    dim = x_init.shape[0]
    keys = jax.random.split(key, max(probes, 1))
    return [x_init] + [
        x_init + radius * jax.random.normal(k, (dim,)) / jnp.sqrt(float(dim))
        for k in keys[:probes]
    ]


def with_measured_heterogeneity(spec, key, *, probes: int = 8,
                                radius: float = 1.0):
    """A copy of ``spec`` whose ζ/ζ_F leaves hold probe-measured values.

    Lower-bounds the Assumption B.5/B.8 sups by maximizing over the init
    point plus ``probes`` random points in a ``radius`` ball — what the
    theory-vs-measured comparisons need to be non-trivial on real data.
    """
    pts = probe_points(spec.x0, key, probes=probes, radius=radius)
    zeta = jnp.asarray(estimate_zeta(spec, pts), jnp.float32)
    zeta_f = jnp.asarray(
        jnp.max(jnp.stack([zeta_f_at(spec, x) for x in pts])), jnp.float32)
    return dataclasses.replace(
        spec, consts={**spec.consts, "zeta": zeta, "zeta_f": zeta_f})


def estimate_sigma(problem, x, key, *, client_id=0, samples: int = 256):
    """Monte-Carlo estimate of the gradient-oracle std at x (Assumption B.6)."""
    keys = jax.random.split(key, samples)
    gs = jax.vmap(lambda k: problem.grad_oracle(x, client_id, k))(keys)
    mean = tm.tree_mean_leading(gs)
    sq = jax.vmap(lambda i: tm.tree_sq_norm(
        tm.tree_sub(jax.tree.map(lambda t: t[i], gs), mean)))(jnp.arange(samples))
    return jnp.sqrt(jnp.mean(sq))
