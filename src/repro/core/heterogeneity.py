"""Estimators for the paper's problem constants.

ζ² (Assumption B.5) is a sup over x — we estimate it by maximizing over a set
of probe points (trajectory iterates and/or random points in a ball), which
lower-bounds the true ζ and is exact for the constructions in
``repro.data.problems`` whose gradient differences are constant in x.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import tree_math as tm


def zeta_at(problem, x):
    """max_i ||∇F_i(x) − ∇F(x)|| at a single point x."""
    g_bar = jax.grad(problem.global_loss)(x)

    def one(i):
        g_i = jax.grad(problem.client_loss)(x, i)
        return tm.tree_sq_norm(tm.tree_sub(g_i, g_bar))

    sq = jax.vmap(one)(jnp.arange(problem.num_clients))
    return jnp.sqrt(jnp.max(sq))


def estimate_zeta(problem, probes):
    """max over probe points of zeta_at — a lower bound on the true ζ."""
    vals = jnp.stack([zeta_at(problem, x) for x in probes])
    return jnp.max(vals)


def zeta_f_at(problem, x):
    """max_i |F_i(x) − F(x)| at a point (Assumption B.8 analogue)."""
    f_bar = problem.global_loss(x)

    def one(i):
        return jnp.abs(problem.client_loss(x, i) - f_bar)

    return jnp.max(jax.vmap(one)(jnp.arange(problem.num_clients)))


def estimate_sigma(problem, x, key, *, client_id=0, samples: int = 256):
    """Monte-Carlo estimate of the gradient-oracle std at x (Assumption B.6)."""
    keys = jax.random.split(key, samples)
    gs = jax.vmap(lambda k: problem.grad_oracle(x, client_id, k))(keys)
    mean = tm.tree_mean_leading(gs)
    sq = jax.vmap(lambda i: tm.tree_sq_norm(
        tm.tree_sub(jax.tree.map(lambda t: t[i], gs), mean)))(jnp.arange(samples))
    return jnp.sqrt(jnp.mean(sq))
