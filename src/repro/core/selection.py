"""Better-point selection (middle step of Algo 1, analyzed in Lemma H.2).

Sample S clients, draw K function-value samples ẑ_{i,k} per client, and keep
the candidate with the smaller empirical average

    x̂_1 = argmin_{x ∈ candidates} (1/SK) Σ_{i∈S} Σ_k f(x; ẑ_{i,k}).

Lemma H.2 guarantees E[F(x̂_1)] ≤ min_x F(x) + 4σ_F/√(SK) + 4√(1−(S−1)/(N−1))·ζ_F/√S.

All candidates are evaluated on the SAME samples (the algorithm draws ẑ once),
which we reproduce by reusing the same PRNG keys across candidates.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.algorithms import base


def empirical_values(problem, candidates, key, *, s: int, k: int):
    """Empirical (1/SK)ΣΣ f(x; ẑ) for every candidate on shared samples.

    The candidates axis is vmapped over their stacked pytree leaves (one
    oracle batch instead of per-candidate trace growth); every per-sample
    op is batch-invariant, so the values are bitwise identical to
    evaluating each candidate in its own pass.
    """
    k_sample, k_vals = jax.random.split(key)
    cids = base.sample_clients(k_sample, problem.num_clients, s)
    keys = jax.random.split(k_vals, s * k).reshape(s, k, -1)

    def value_of(x):
        def per_client(cid, ks):
            vs = jax.vmap(lambda kk: problem.value_oracle(x, cid, kk))(ks)
            return jnp.mean(vs)

        return jnp.mean(jax.vmap(per_client)(cids, keys))

    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *candidates)
    return jax.vmap(value_of)(stacked)


def select_better(problem, candidates, key, *, s: int, k: int):
    """Returns (best_candidate, best_index, empirical_values)."""
    vals = empirical_values(problem, candidates, key, s=s, k=k)
    idx = jnp.argmin(vals)
    # candidates share a pytree structure; gather leafwise
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *candidates)
    best = jax.tree.map(lambda t: t[idx], stacked)
    return best, idx, vals


def selection_error_bound(problem, *, s: int, k: int):
    """The Lemma H.2 additive error term 4σ_F/√(SK) + 4√(1−(S−1)/(N−1))·ζ_F/√S."""
    n = problem.num_clients
    frac = 0.0 if n <= 1 else max(0.0, 1.0 - (s - 1) / (n - 1))
    return 4.0 * problem.sigma_f / (s * k) ** 0.5 + 4.0 * (frac**0.5) * problem.zeta_f / s**0.5
