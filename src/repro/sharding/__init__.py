from repro.sharding.rules import (
    DEFAULT_LOGICAL_RULES, PARAM_RULES, RuleSet, SEQ_SHARDED_RULES, active_rules,
    leading_axis_specs, logical, param_logical_axes, param_shardings,
    param_specs, use_rules,
)

__all__ = [
    "DEFAULT_LOGICAL_RULES", "PARAM_RULES", "RuleSet", "SEQ_SHARDED_RULES",
    "active_rules", "leading_axis_specs", "logical", "param_logical_axes",
    "param_shardings", "param_specs", "use_rules",
]
