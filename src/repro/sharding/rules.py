"""Logical-axis sharding rules.

Model code annotates activations with *logical* axis names
(``logical(x, ("batch", "seq", "embed"))``) and parameter leaves get specs from
a name-keyed rule table. A rule set binds logical names to mesh axes; any
binding whose mesh-axis size does not divide the tensor dimension is dropped
to replication (e.g. gemma3's 8 heads on a 16-way model axis).

When no rule set is active (CPU tests), everything is a no-op.
"""
from __future__ import annotations

import contextlib
import re
import threading
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_STATE = threading.local()


DEFAULT_LOGICAL_RULES = {
    # activation / parameter logical axes -> mesh axis (or tuple of axes)
    "batch": ("pod", "data"),
    "seq": None,  # sequence parallelism is opt-in (see "seq_sharded" profile)
    "cache_seq": "model",  # KV caches shard their time axis over the model axis
    "embed": None,
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "ff": "model",
    "experts": "model",
    "capacity": None,
    "vocab": "model",
    "q_lora": None,
    "kv_lora": None,
    "ssm_inner": "model",
    "ssm_state": None,
    "frontend": None,
    "layers": None,  # scan-stack axis
    # sweep-grid axes (repro.dist): flattened problems x seeds cells shard
    # over the 'grid' mesh axis; intra-cell [N, ...] client rows over
    # 'client'. Absent mesh axes drop to replication as usual, so these
    # rules are inert on model/data meshes.
    "cells": "grid",
    "client_rows": "client",
}

# Profile used by the §Perf sequence-parallel hillclimb.
SEQ_SHARDED_RULES = dict(DEFAULT_LOGICAL_RULES, seq="model", heads=None, kv_heads=None)


class RuleSet:
    def __init__(self, mesh: Mesh, rules: Optional[dict] = None, *,
                 attn_embed_fallback: bool = False, fsdp: bool = False):
        self.mesh = mesh
        self.rules = dict(DEFAULT_LOGICAL_RULES)
        # §Perf iteration 1: when an attention weight's heads axis does not
        # divide the model axis (yi-34b 56H, qwen3 40H, gemma3 8H on 16), the
        # weight would replicate (per-device HBM + full-size gradient
        # all-reduce). Fall back to sharding its embed/lora dim instead.
        self.attn_embed_fallback = attn_embed_fallback
        # §Perf iteration: FSDP/ZeRO-3-style sharding — big weights also shard
        # an unsharded divisible dim over the *data* axis (GSPMD then emits
        # per-layer param all-gathers + gradient reduce-scatters). Pod axis
        # stays replicated: FedChain's local phase relies on per-pod replicas.
        self.fsdp = fsdp
        if rules:
            self.rules.update(rules)

    def _axis_size(self, mesh_axes) -> int:
        if mesh_axes is None:
            return 1
        if isinstance(mesh_axes, str):
            mesh_axes = (mesh_axes,)
        sizes = dict(self.mesh.shape)  # works for Mesh and AbstractMesh
        size = 1
        for a in mesh_axes:
            size *= sizes.get(a, 1)
        return size

    def spec_for(self, logical_axes, shape=None) -> P:
        """PartitionSpec for logical axis names, with divisibility fallback."""
        parts = []
        mesh_axes_present = set(self.mesh.axis_names)
        used = set()  # a mesh axis may appear at most once per spec
        for i, name in enumerate(logical_axes):
            mesh_axes = self.rules.get(name) if name is not None else None
            if mesh_axes is None:
                parts.append(None)
                continue
            if isinstance(mesh_axes, str):
                mesh_axes = (mesh_axes,)
            mesh_axes = tuple(
                a for a in mesh_axes if a in mesh_axes_present and a not in used)
            if not mesh_axes:
                parts.append(None)
                continue
            if shape is not None:
                if shape[i] % max(1, self._axis_size(mesh_axes)) != 0:
                    parts.append(None)
                    continue
            used.update(mesh_axes)
            parts.append(mesh_axes[0] if len(mesh_axes) == 1 else mesh_axes)
        return P(*parts)

    def sharding_for(self, logical_axes, shape=None) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec_for(logical_axes, shape))


@contextlib.contextmanager
def use_rules(ruleset: Optional[RuleSet]):
    prev = getattr(_STATE, "rules", None)
    _STATE.rules = ruleset
    try:
        yield
    finally:
        _STATE.rules = prev


def active_rules() -> Optional[RuleSet]:
    return getattr(_STATE, "rules", None)


def logical(x, logical_axes):
    """Annotate an activation with logical axes (no-op without active rules)."""
    rs = active_rules()
    if rs is None:
        return x
    spec = rs.spec_for(logical_axes, x.shape)
    return jax.lax.with_sharding_constraint(x, NamedSharding(rs.mesh, spec))


# ---------------------------------------------------------------------------
# Parameter specs: leaf-name-keyed rules (left-padded with None for stacked
# scan axes — sharded dims always sit at fixed offsets from the right).
# ---------------------------------------------------------------------------

PARAM_RULES = [
    # (regex on '/'-joined path, logical axes of the *base* (unstacked) leaf)
    (r"embedding$", ("vocab", "embed")),
    (r"lm_head$", ("embed", "vocab")),
    (r"wq$", ("embed", "heads", "head_dim")),
    (r"wk$", ("embed", "kv_heads", "head_dim")),
    (r"wv$", ("embed", "kv_heads", "head_dim")),
    (r"wo$", ("heads", "head_dim", "embed")),
    (r"w_gate$", ("embed", "ff")),
    (r"w_in$", ("embed", "ff")),
    (r"w_out$", ("ff", "embed")),
    # MLA
    (r"wq_a$", ("embed", "q_lora")),
    (r"wq_b$", ("q_lora", "heads", "head_dim")),
    (r"wkv_a$", ("embed", "kv_lora")),
    (r"wk_b$", ("kv_lora", "heads", "head_dim")),
    (r"wv_b$", ("kv_lora", "heads", "head_dim")),
    (r"wo_mla$", ("heads", "head_dim", "embed")),
    # MoE
    (r"router$", ("embed", "experts")),
    (r"we_gate$", ("experts", "embed", "ff")),
    (r"we_in$", ("experts", "embed", "ff")),
    (r"we_out$", ("experts", "ff", "embed")),
    # SSM
    (r"in_proj$", ("embed", "ssm_inner")),
    (r"out_proj$", ("ssm_inner", "embed")),
    (r"conv_w$", (None, "ssm_inner")),
    (r"conv_b$", ("ssm_inner",)),
    (r"a_log$", ("ssm_inner",)),
    (r"ssm_d$", ("ssm_inner",)),
    (r"dt_bias$", ("ssm_inner",)),
    # projections / misc
    (r"proj$", ("frontend", "embed")),
    (r"scale$", (None,)),
    (r"bias$", (None,)),
]


def param_logical_axes(path: str, ndim: int):
    for pat, axes in PARAM_RULES:
        if re.search(pat, path):
            axes = tuple(axes)
            if len(axes) < ndim:  # stacked under scan: left-pad
                axes = ("layers",) * (ndim - len(axes)) + axes
            elif len(axes) > ndim:
                axes = axes[-ndim:]
            return axes
    return (None,) * ndim


CACHE_RULES = [
    (r"/k$", ("layers", "batch", "cache_seq", "kv_heads", "head_dim")),
    (r"/v$", ("layers", "batch", "cache_seq", "kv_heads", "head_dim")),
    (r"c_kv$", ("layers", "batch", "cache_seq", "kv_lora")),
    (r"k_rope$", ("layers", "batch", "cache_seq", "head_dim")),
    (r"ssm$", ("layers", "batch", "heads", "head_dim", "ssm_state")),
    (r"conv$", ("layers", "batch", None, "ssm_inner")),
]


def cache_logical_axes(path: str, ndim: int):
    for pat, axes in CACHE_RULES:
        if re.search(pat, path):
            axes = tuple(axes)
            if len(axes) < ndim:
                axes = ("layers",) * (ndim - len(axes)) + axes
            elif len(axes) > ndim:
                axes = axes[-ndim:]
            return axes
    return (None,) * ndim


def cache_specs_tree(cache_shapes, ruleset: "RuleSet"):
    """PartitionSpec pytree for a (stacked) cache tree of ShapeDtypeStructs."""

    def leaf_spec(path, leaf):
        axes = cache_logical_axes("/" + _path_str(path), len(leaf.shape))
        return ruleset.spec_for(axes, leaf.shape)

    return jax.tree_util.tree_map_with_path(leaf_spec, cache_shapes)


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


_ATTN_WEIGHT_RE = re.compile(r"(wq|wk|wv|wo|wq_b|wk_b|wv_b|wo_mla)$")


def param_specs(params_or_shapes, ruleset: RuleSet):
    """PartitionSpec pytree for a params tree (arrays or ShapeDtypeStructs)."""

    def leaf_spec(path, leaf):
        ps = _path_str(path)
        axes = param_logical_axes(ps, len(leaf.shape))
        spec = ruleset.spec_for(axes, leaf.shape)
        if (ruleset.attn_embed_fallback and _ATTN_WEIGHT_RE.search(ps)
                and all(s is None for s in spec)):
            # heads axis didn't shard: shard a divisible non-head dim instead
            msize = ruleset._axis_size(("model",))
            for i, name in enumerate(axes):
                if name in ("embed", "q_lora", "kv_lora", "head_dim") and \
                        leaf.shape[i] % max(1, msize) == 0:
                    parts = [None] * len(spec)
                    parts[i] = "model"
                    spec = P(*parts)
                    break
        if ruleset.fsdp:
            import math
            if math.prod(leaf.shape) >= (1 << 20) and "data" in ruleset.mesh.axis_names:
                dsize = ruleset._axis_size(("data",))
                parts = list(spec) + [None] * (len(leaf.shape) - len(spec))
                # biggest unsharded divisible dim gets the data axis
                cands = [i for i in range(len(leaf.shape))
                         if parts[i] is None and leaf.shape[i] % max(1, dsize) == 0]
                if cands:
                    i = max(cands, key=lambda j: leaf.shape[j])
                    parts[i] = "data"
                    spec = P(*parts)
        return spec

    return jax.tree_util.tree_map_with_path(leaf_spec, params_or_shapes)


def param_shardings(params_or_shapes, ruleset: RuleSet):
    specs = param_specs(params_or_shapes, ruleset)
    return jax.tree.map(lambda s: NamedSharding(ruleset.mesh, s), specs,
                        is_leaf=lambda s: isinstance(s, P))


def leading_axis_specs(tree, ruleset: RuleSet, logical_name: str = "cells"):
    """PartitionSpec pytree placing every leaf's LEADING axis under one
    logical rule (trailing dims replicated) — how ``repro.dist`` places
    stacked ProblemSpec leaves, per-cell keys and mask schedules on their
    ``grid`` shard. Divisibility fallback applies per leaf (the dist grid
    pads the cells axis so it always divides)."""

    def leaf_spec(leaf):
        shape = tuple(jax.numpy.shape(leaf))
        axes = (logical_name,) + (None,) * (len(shape) - 1)
        return ruleset.spec_for(axes, shape)

    return jax.tree.map(leaf_spec, tree)
