"""Pallas TPU kernel for the Mamba2 SSD chunked scan (forward).

Grid = (B·H, n_chunks); the chunk axis is the minor (sequential) grid
dimension, so the running inter-chunk state [P, N] lives in VMEM scratch and
the recurrence never touches HBM between chunks — the TPU-native layout of
the SSD algorithm (intra-chunk quadratic work feeds the MXU as [cl, cl] and
[cl, P]×[P, N] matmuls; cl = 128 keeps every matmul 128-aligned).

Per (bh, c) step the VMEM working set is
  x [cl, P] + B,C [cl, N] + decay [cl, cl] + state [P, N]
≈ (128·64 + 2·128·128 + 128² + 64·128)·4 B ≈ 260 KB ≪ ~16 MB VMEM.

Validated in interpret mode against the pure-jnp chunked SSD
(`repro.models.layers.ssm.ssd`), which is itself tested against a naive
sequential recurrence.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(a_ref, x_ref, dt_ref, b_ref, c_ref, y_ref, state_scr, *,
                chunk: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    x = x_ref[...].astype(jnp.float32)  # [cl, P]
    dt = dt_ref[...].astype(jnp.float32)  # [cl]
    b = b_ref[...].astype(jnp.float32)  # [cl, N]
    c = c_ref[...].astype(jnp.float32)  # [cl, N]
    a = a_ref[0]  # scalar decay coefficient for this head

    la = dt * a  # [cl] (negative)
    cs = jnp.cumsum(la)  # [cl]
    total = cs[-1]

    # intra-chunk: y_i += Σ_{j<=i} (C_i·B_j)·exp(cs_i − cs_j)·dt_j·x_j
    diff = cs[:, None] - cs[None, :]
    mask = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    decay = jnp.where(mask, jnp.exp(diff), 0.0)
    scores = jnp.dot(c, b.T)  # [cl, cl]
    y = jnp.dot(scores * decay * dt[None, :], x)  # [cl, P]

    # inter-chunk: y_i += exp(cs_i)·(C_i · S_prev)
    s_prev = state_scr[...]  # [P, N]
    y = y + jnp.exp(cs)[:, None] * jnp.dot(c, s_prev.T)

    # state update: S = exp(total)·S_prev + Σ_j exp(total − cs_j)·dt_j·x_j⊗B_j
    w = jnp.exp(total - cs) * dt  # [cl]
    state_scr[...] = jnp.exp(total) * s_prev + jnp.dot(x.T, b * w[:, None])

    y_ref[...] = y.astype(y_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x, dt, a_coef, b_in, c_in, *, chunk: int = 128,
             interpret: bool = False):
    """x: [B, L, H, P]; dt: [B, L, H]; a_coef: [H]; b_in/c_in: [B, L, G, N].

    Returns y [B, L, H, P] (same semantics as models.layers.ssm.ssd, minus
    the final-state output — decode uses the recurrent path).
    """
    bsz, l, h, p = x.shape
    g, n = b_in.shape[2], b_in.shape[3]
    assert l % chunk == 0
    nc = l // chunk
    rep = h // g

    # flatten (B, H) and broadcast groups to heads
    xf = x.transpose(0, 2, 1, 3).reshape(bsz * h, l, p)
    dtf = dt.transpose(0, 2, 1).reshape(bsz * h, l)
    bf = jnp.repeat(b_in.transpose(0, 2, 1, 3), rep, axis=1).reshape(bsz * h, l, n)
    cf = jnp.repeat(c_in.transpose(0, 2, 1, 3), rep, axis=1).reshape(bsz * h, l, n)
    af = jnp.tile(a_coef.astype(jnp.float32), bsz)  # [B*H]

    out = pl.pallas_call(
        functools.partial(_ssd_kernel, chunk=chunk),
        grid=(bsz * h, nc),
        in_specs=[
            pl.BlockSpec((1,), lambda bh, c: (bh,)),
            pl.BlockSpec((None, chunk, p), lambda bh, c: (bh, c, 0)),
            pl.BlockSpec((None, chunk), lambda bh, c: (bh, c)),
            pl.BlockSpec((None, chunk, n), lambda bh, c: (bh, c, 0)),
            pl.BlockSpec((None, chunk, n), lambda bh, c: (bh, c, 0)),
        ],
        out_specs=pl.BlockSpec((None, chunk, p), lambda bh, c: (bh, c, 0)),
        out_shape=jax.ShapeDtypeStruct((bsz * h, l, p), x.dtype),
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        interpret=interpret,
    )(af, xf, dtf, bf, cf)
    return out.reshape(bsz, h, l, p).transpose(0, 2, 1, 3)
