"""Oracle for the ssd_scan kernel: the pure-jnp chunked SSD from the model
layer (itself validated against a naive sequential recurrence in
tests/test_layers.py)."""
from repro.models.layers.ssm import ssd as ssd_reference  # noqa: F401
