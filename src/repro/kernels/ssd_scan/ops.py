"""Backend dispatch for the SSD scan kernel."""
from __future__ import annotations

import jax

from repro.kernels.ssd_scan.ssd_scan import ssd_scan as _kernel
from repro.models.layers.ssm import ssd as _ref


def ssd(x, dt, a_coef, b_in, c_in, *, chunk: int = 128, force_pallas: bool = False):
    """Returns y only (state handled by the recurrent decode path)."""
    if jax.default_backend() == "tpu":
        return _kernel(x, dt, a_coef, b_in, c_in, chunk=chunk)
    if force_pallas:
        return _kernel(x, dt, a_coef, b_in, c_in, chunk=chunk, interpret=True)
    y, _ = _ref(x, dt, a_coef, b_in, c_in, chunk=chunk)
    return y
