"""Backend-dispatching wrapper for the flash-attention kernel.

TPU: the Pallas kernel. CPU: interpret-mode Pallas when ``force_pallas`` or
``REPRO_FORCE_PALLAS=1`` (tests / kernel-path debugging), else the jnp
reference (XLA:CPU can't lower Mosaic) — the same gate every kernel
directory ships (``kernels/aggregate/ops.py`` is the template), so callers
never pick a backend themselves and the executor cache's env key
(``runner._env_key``) stays the single source of dispatch truth.
"""
from __future__ import annotations

from repro.kernels.aggregate.ops import _force_pallas_env, _on_tpu
from repro.kernels.flash_attention import ref
from repro.kernels.flash_attention.flash_attention import (
    flash_attention as _kernel,
)


def attention(q, k, v, *, causal: bool = True, window: int = 0, scale=None,
              force_pallas: bool = False):
    """Dispatched flash attention: q [B, S, H, D]; k, v [B, S, KV, D]
    (GQA: H % KV == 0); returns [B, S, H, D]. The Pallas paths need S to be
    a multiple of the kernel block sizes; the reference has no constraint.
    """
    if _on_tpu():
        return _kernel(q, k, v, causal=causal, window=window, scale=scale)
    if force_pallas or _force_pallas_env():
        return _kernel(q, k, v, causal=causal, window=window, scale=scale,
                       interpret=True)
    return ref.attention_ref(q, k, v, causal=causal, window=window,
                             scale=scale)
