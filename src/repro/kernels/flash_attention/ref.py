"""Pure-jnp oracle for the flash_attention kernel: plain masked softmax
attention (causal / sliding-window / full), GQA via head grouping."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def attention_ref(q, k, v, *, causal: bool = True, window: int = 0, scale=None):
    """q: [B, S, H, D]; k, v: [B, S, KV, D]; window 0 => no window.

    Returns [B, S, H, D] in q.dtype.
    """
    b, s, h, d = q.shape
    kvh = k.shape[2]
    g = h // kvh
    scale = scale if scale is not None else 1.0 / d**0.5
    qg = q.reshape(b, s, kvh, g, d)
    scores = jnp.einsum("bqkgh,btkh->bkgqt", qg, k).astype(jnp.float32) * scale
    qi = jnp.arange(s)[:, None]
    ki = jnp.arange(s)[None, :]
    ok = jnp.ones((s, s), bool)
    if causal:
        ok &= ki <= qi
    if window and window > 0:
        ok &= (qi - ki) < window
    scores = jnp.where(ok[None, None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgqt,btkh->bqkgh", probs, v)
    return out.reshape(b, s, h, d)
