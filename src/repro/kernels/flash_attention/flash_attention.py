"""Pallas TPU flash attention (forward): online-softmax blocked attention
with causal and sliding-window masking, GQA-aware.

Tiling (TPU-native): grid = (B·H, Q_blocks, KV_blocks); the KV dimension is
the minor (sequential) grid axis, so the running max / sum / accumulator
live in VMEM scratch across KV steps of one Q block. Block shapes are
(BLOCK_Q, head_dim) and (BLOCK_KV, head_dim) with BLOCK_* multiples of 128 —
MXU-aligned — giving a VMEM working set of
  q (128·d) + k,v (2·128·d) + acc (128·d) + scores (128·128) floats ≈
  4·128·128·4B + 64KB ≈ 0.3 MB per step, far under the ~16 MB budget, while
never materializing the [S, S] score matrix in HBM.

Causality lets us skip KV blocks entirely above the diagonal; the sliding
window additionally skips blocks left of the window — that block-sparsity is
the reason gemma3's local layers make long_500k feasible.

Validated on CPU via interpret=True against ref.attention_ref.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BLOCK_Q = 128
BLOCK_KV = 128
NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, causal: bool, window: int, block_q: int,
                  block_kv: int, kv_steps: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qi * block_q
    k_start = ki * block_kv

    # skip fully-masked blocks (structural block sparsity)
    below_diag = (not causal) or (k_start <= q_start + block_q - 1)
    in_window = (window <= 0) or (q_start - (k_start + block_kv - 1) < window)

    @pl.when(jnp.asarray(below_diag & in_window))
    def _compute():
        q = q_ref[...].astype(jnp.float32)  # [bq, d]
        k = k_ref[...].astype(jnp.float32)  # [bkv, d]
        v = v_ref[...].astype(jnp.float32)
        s = jnp.dot(q, k.T) * scale  # [bq, bkv]
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_kv), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_kv), 1)
        ok = jnp.ones((block_q, block_kv), bool)
        if causal:
            ok &= kpos <= qpos
        if window > 0:
            ok &= (qpos - kpos) < window
        s = jnp.where(ok, s, NEG_INF)

        m_prev = m_scr[...]
        l_prev = l_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_new = alpha * l_prev + jnp.sum(p, axis=1)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + jnp.dot(p, v)
        m_scr[...] = m_new
        l_scr[...] = l_new

    @pl.when(ki == kv_steps - 1)
    def _finish():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[...] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "scale", "interpret", "block_q", "block_kv"),
)
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0, scale=None,
                    interpret: bool = False, block_q: int = BLOCK_Q,
                    block_kv: int = BLOCK_KV):
    """q: [B, S, H, D]; k, v: [B, S, KV, D] (GQA: H % KV == 0).

    Returns [B, S, H, D]. S must be a multiple of the block sizes.
    """
    b, s, h, d = q.shape
    kvh = k.shape[2]
    g = h // kvh
    scale = scale if scale is not None else 1.0 / d**0.5
    assert s % block_q == 0 and s % block_kv == 0, (s, block_q, block_kv)

    # flatten (B, H) onto the major grid axis; map q head -> kv head
    qf = q.transpose(0, 2, 1, 3).reshape(b * h, s, d)
    kf = jnp.repeat(k.transpose(0, 2, 1, 3), g, axis=1).reshape(b * h, s, d)
    vf = jnp.repeat(v.transpose(0, 2, 1, 3), g, axis=1).reshape(b * h, s, d)

    kv_steps = s // block_kv
    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        block_q=block_q, block_kv=block_kv, kv_steps=kv_steps,
    )
    out = pl.pallas_call(
        kernel,
        grid=(b * h, s // block_q, kv_steps),
        in_specs=[
            pl.BlockSpec((None, block_q, d), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((None, block_kv, d), lambda bh, qi, ki: (bh, ki, 0)),
            pl.BlockSpec((None, block_kv, d), lambda bh, qi, ki: (bh, ki, 0)),
        ],
        out_specs=pl.BlockSpec((None, block_q, d), lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, s, d), q.dtype),
        scratch_shapes=[
            # m, l, acc persist across the sequential KV grid axis (VMEM)
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(b, h, s, d).transpose(0, 2, 1, 3)
