"""Pallas TPU kernel for the fused FedChain server aggregation.

    out = x − lr · ( Σ_i w_i·(g_i − c_i) + c )

One kernel pass fuses the client reduction, control-variate correction and
the server step — on TPU this keeps the [S, D] client buffers in HBM and
streams [S, BLOCK_D] tiles through VMEM exactly once (the XLA default would
materialize the [S, D] difference tensor).

Grid: (D // BLOCK_D,). Per step the BlockSpecs stage
  g, c_i tiles [S, BLOCK_D]  +  x, c, out tiles [BLOCK_D]
into VMEM; with S ≤ 64 and BLOCK_D = 2048 the working set is
~(2·S + 3)·BLOCK_D·4B ≈ 1.1 MB — comfortably inside the ~16 MB VMEM budget,
and BLOCK_D is a multiple of the 128-lane register width.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_D = 2048


def _agg_kernel(w_ref, x_ref, g_ref, ci_ref, c_ref, o_ref, *, lr: float):
    g = g_ref[...].astype(jnp.float32)  # [S, BD]
    ci = ci_ref[...].astype(jnp.float32)
    w = w_ref[...].astype(jnp.float32)  # [S]
    upd = jnp.einsum("sd,s->d", g - ci, w) + c_ref[...].astype(jnp.float32)
    o_ref[...] = (x_ref[...].astype(jnp.float32) - lr * upd).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("lr", "interpret", "block_d"))
def chain_aggregate(x, g, c_i, c, weights, *, lr: float, interpret: bool = False,
                    block_d: int = BLOCK_D):
    """x: [D]; g, c_i: [S, D]; c: [D]; weights: [S]. Returns [D]."""
    d = x.shape[0]
    s = g.shape[0]
    bd = min(block_d, d)
    # pad D to a block multiple
    pad = (-d) % bd
    if pad:
        x = jnp.pad(x, (0, pad))
        g = jnp.pad(g, ((0, 0), (0, pad)))
        c_i = jnp.pad(c_i, ((0, 0), (0, pad)))
        c = jnp.pad(c, (0, pad))
    dp = x.shape[0]

    out = pl.pallas_call(
        functools.partial(_agg_kernel, lr=lr),
        grid=(dp // bd,),
        in_specs=[
            pl.BlockSpec((s,), lambda j: (0,)),  # weights: whole vector
            pl.BlockSpec((bd,), lambda j: (j,)),  # x tile
            pl.BlockSpec((s, bd), lambda j: (0, j)),  # g tile
            pl.BlockSpec((s, bd), lambda j: (0, j)),  # c_i tile
            pl.BlockSpec((bd,), lambda j: (j,)),  # c tile
        ],
        out_specs=pl.BlockSpec((bd,), lambda j: (j,)),
        out_shape=jax.ShapeDtypeStruct((dp,), x.dtype),
        interpret=interpret,
    )(weights, x, g, c_i, c)
    return out[:d] if pad else out


def _mean_kernel(t_ref, o_ref):
    o_ref[...] = jnp.mean(t_ref[...].astype(jnp.float32), axis=0).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret", "block_d"))
def mean_over_clients(t, *, interpret: bool = False, block_d: int = BLOCK_D):
    """Mean over the leading client axis of a [C, ...] tensor."""
    c = t.shape[0]
    flat = t.reshape(c, -1)
    d = flat.shape[1]
    bd = min(block_d, d)
    pad = (-d) % bd
    if pad:
        flat = jnp.pad(flat, ((0, 0), (0, pad)))
    dp = flat.shape[1]
    out = pl.pallas_call(
        _mean_kernel,
        grid=(dp // bd,),
        in_specs=[pl.BlockSpec((c, bd), lambda j: (0, j))],
        out_specs=pl.BlockSpec((bd,), lambda j: (j,)),
        out_shape=jax.ShapeDtypeStruct((dp,), t.dtype),
        interpret=interpret,
    )(flat)
    out = out[:d] if pad else out
    return out.reshape(t.shape[1:])
