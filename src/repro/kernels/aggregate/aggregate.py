"""Pallas TPU kernel for the fused FedChain server aggregation.

    out = x − lr · ( Σ_i w_i·(g_i − c_i) + c )

One kernel pass fuses the client reduction, control-variate correction and
the server step — on TPU this keeps the [S, D] client buffers in HBM and
streams [S, BLOCK_D] tiles through VMEM exactly once (the XLA default would
materialize the [S, D] difference tensor).

Grid: (D // BLOCK_D,). Per step the BlockSpecs stage
  g, c_i tiles [S, BLOCK_D]  +  x, c, out tiles [BLOCK_D]
into VMEM; with S ≤ 64 and BLOCK_D = 2048 the working set is
~(2·S + 3)·BLOCK_D·4B ≈ 1.1 MB — comfortably inside the ~16 MB VMEM budget,
and BLOCK_D is a multiple of the 128-lane register width.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_D = 2048


def _agg_kernel(w_ref, x_ref, g_ref, ci_ref, c_ref, o_ref, *, lr: float):
    g = g_ref[...].astype(jnp.float32)  # [S, BD]
    ci = ci_ref[...].astype(jnp.float32)
    w = w_ref[...].astype(jnp.float32)  # [S]
    upd = jnp.einsum("sd,s->d", g - ci, w) + c_ref[...].astype(jnp.float32)
    o_ref[...] = (x_ref[...].astype(jnp.float32) - lr * upd).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("lr", "interpret", "block_d"))
def chain_aggregate(x, g, c_i, c, weights, *, lr: float, interpret: bool = False,
                    block_d: int = BLOCK_D):
    """x: [D]; g, c_i: [S, D]; c: [D]; weights: [S]. Returns [D]."""
    d = x.shape[0]
    s = g.shape[0]
    bd = min(block_d, d)
    # pad D to a block multiple
    pad = (-d) % bd
    if pad:
        x = jnp.pad(x, (0, pad))
        g = jnp.pad(g, ((0, 0), (0, pad)))
        c_i = jnp.pad(c_i, ((0, 0), (0, pad)))
        c = jnp.pad(c, (0, pad))
    dp = x.shape[0]

    out = pl.pallas_call(
        functools.partial(_agg_kernel, lr=lr),
        grid=(dp // bd,),
        in_specs=[
            pl.BlockSpec((s,), lambda j: (0,)),  # weights: whole vector
            pl.BlockSpec((bd,), lambda j: (j,)),  # x tile
            pl.BlockSpec((s, bd), lambda j: (0, j)),  # g tile
            pl.BlockSpec((s, bd), lambda j: (0, j)),  # c_i tile
            pl.BlockSpec((bd,), lambda j: (j,)),  # c tile
        ],
        out_specs=pl.BlockSpec((bd,), lambda j: (j,)),
        out_shape=jax.ShapeDtypeStruct((dp,), x.dtype),
        interpret=interpret,
    )(weights, x, g, c_i, c)
    return out[:d] if pad else out


def _agg_apply_kernel(w_ref, m_ref, x_ref, a_ref, di_ref, co_ref, rs_ref,
                      xo_ref, ro_ref):
    a = a_ref[...].astype(jnp.float32)  # [S, BD] wire rows
    w = w_ref[...].astype(jnp.float32)  # [S]
    upd = jnp.einsum("sd,s->d", a, w)
    xo_ref[...] = (x_ref[...].astype(jnp.float32) - upd).astype(xo_ref.dtype)
    m = m_ref[...].astype(jnp.float32)[:, None]  # [S, 1]
    di = di_ref[...].astype(jnp.float32)
    co = co_ref[...].astype(jnp.float32)
    rs = rs_ref[...].astype(jnp.float32)
    ro_ref[...] = (m * (di - co) + (1.0 - m) * rs).astype(ro_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret", "block_d"))
def aggregate_apply(x, agg_rows, comp, delta_in, res, m, w, *,
                    interpret: bool = False, block_d: int = BLOCK_D):
    """Fused aggregate + error-feedback + server apply over one round.

        x_new   = x − Σ_i w_i·a_i
        res_new = m·(Δ_in − C(Δ_in)) + (1 − m)·res

    x: [D]; agg_rows (wire rows a_i), comp (C(Δ_in)), delta_in (Δ_in), res:
    [S, D]; m (participation mask rows), w (step-folded aggregation
    weights): [S]. One pass streams the [S, D] client rows through VMEM —
    the per-block working set is 4 [S, BD] tiles + 2 [BD] vectors
    (~(4·S + 2)·BLOCK_D·4B), and XLA never materializes the masked
    residual/update intermediates in HBM. The einsum term matches
    ``chain_aggregate``'s reduction order, so the SGD comm round is bitwise
    identical fused vs unfused; the residual expression is ``uplink``'s,
    term for term. Returns ``(x_new [D], res_new [S, D])``.
    """
    d = x.shape[0]
    s = agg_rows.shape[0]
    bd = min(block_d, d)
    pad = (-d) % bd
    if pad:
        x = jnp.pad(x, (0, pad))
        agg_rows = jnp.pad(agg_rows, ((0, 0), (0, pad)))
        comp = jnp.pad(comp, ((0, 0), (0, pad)))
        delta_in = jnp.pad(delta_in, ((0, 0), (0, pad)))
        res = jnp.pad(res, ((0, 0), (0, pad)))
    dp = x.shape[0]

    x_new, res_new = pl.pallas_call(
        _agg_apply_kernel,
        grid=(dp // bd,),
        in_specs=[
            pl.BlockSpec((s,), lambda j: (0,)),  # w: whole vector
            pl.BlockSpec((s,), lambda j: (0,)),  # m: whole vector
            pl.BlockSpec((bd,), lambda j: (j,)),  # x tile
            pl.BlockSpec((s, bd), lambda j: (0, j)),  # agg_rows tile
            pl.BlockSpec((s, bd), lambda j: (0, j)),  # delta_in tile
            pl.BlockSpec((s, bd), lambda j: (0, j)),  # comp tile
            pl.BlockSpec((s, bd), lambda j: (0, j)),  # res tile
        ],
        out_specs=(
            pl.BlockSpec((bd,), lambda j: (j,)),
            pl.BlockSpec((s, bd), lambda j: (0, j)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((dp,), x.dtype),
            jax.ShapeDtypeStruct((s, dp), res.dtype),
        ),
        interpret=interpret,
    )(w, m, x, agg_rows, delta_in, comp, res)
    if pad:
        return x_new[:d], res_new[:, :d]
    return x_new, res_new


def _mean_kernel(t_ref, o_ref):
    o_ref[...] = jnp.mean(t_ref[...].astype(jnp.float32), axis=0).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret", "block_d"))
def mean_over_clients(t, *, interpret: bool = False, block_d: int = BLOCK_D):
    """Mean over the leading client axis of a [C, ...] tensor."""
    c = t.shape[0]
    flat = t.reshape(c, -1)
    d = flat.shape[1]
    bd = min(block_d, d)
    pad = (-d) % bd
    if pad:
        flat = jnp.pad(flat, ((0, 0), (0, pad)))
    dp = flat.shape[1]
    out = pl.pallas_call(
        _mean_kernel,
        grid=(dp // bd,),
        in_specs=[pl.BlockSpec((c, bd), lambda j: (0, j))],
        out_specs=pl.BlockSpec((bd,), lambda j: (j,)),
        out_shape=jax.ShapeDtypeStruct((dp,), t.dtype),
        interpret=interpret,
    )(flat)
    out = out[:d] if pad else out
    return out.reshape(t.shape[1:])
