"""Pure-jnp oracle for the ``chain_aggregate`` kernel.

The fused FedChain server update (DESIGN.md §2):

    out = x − lr · ( (1/S)·Σ_i w_i·(g_i − c_i) + c )

covering FedAvg (g_i = client deltas, c_i = c = 0, lr = server_lr),
SCAFFOLD/SAGA (control variates), and plain gradient averaging (lr = η).
"""
from __future__ import annotations

import jax.numpy as jnp


def chain_aggregate_ref(x, g, c_i, c, *, lr: float, weights=None):
    """x: [D]; g, c_i: [S, D]; c: [D]; weights: [S] or None (uniform)."""
    s = g.shape[0]
    if weights is None:
        weights = jnp.full((s,), 1.0 / s, jnp.float32)
    else:
        weights = weights.astype(jnp.float32)
    diff = (g.astype(jnp.float32) - c_i.astype(jnp.float32))
    update = jnp.einsum("s,sd->d", weights, diff) + c.astype(jnp.float32)
    return (x.astype(jnp.float32) - lr * update).astype(x.dtype)


def mean_over_clients_ref(t):
    """Mean over a leading client axis, any trailing shape."""
    return jnp.mean(t.astype(jnp.float32), axis=0).astype(t.dtype)


def aggregate_apply_ref(x, agg_rows, comp, delta_in, res, m, w):
    """Oracle for the fused aggregate-apply round kernel.

        x_new   = x − Σ_i w_i·a_i          (a_i = wire rows, w step-folded)
        res_new = m·(Δ_in − C(Δ_in)) + (1 − m)·res

    Same einsum reduction order as ``chain_aggregate_ref`` and the same
    residual expression as ``comm.config.uplink``, so fused and unfused
    rounds agree term for term.
    """
    upd = jnp.einsum("sd,s->d", agg_rows.astype(jnp.float32),
                     w.astype(jnp.float32))
    x_new = (x.astype(jnp.float32) - upd).astype(x.dtype)
    mf = m.astype(jnp.float32)[:, None]
    res_new = (mf * (delta_in.astype(jnp.float32)
                     - comp.astype(jnp.float32))
               + (1.0 - mf) * res.astype(jnp.float32)).astype(res.dtype)
    return x_new, res_new
