"""Backend-dispatching wrappers for the aggregate kernel.

TPU: the Pallas kernel. CPU: interpret-mode Pallas when ``force_pallas`` or
``REPRO_FORCE_PALLAS=1`` (tests / kernel-path debugging), else the jnp
reference (XLA:CPU can't lower Mosaic).

These wrappers are the *fused aggregation path* exercised by the main
experiment loop: the flat-vector algorithms (``core.algorithms.sgd/saga/
ssnm/fedavg/scaffold/asg``) route their server updates here, so the
quadratic/theory benchmarks hit the same kernel entry points as
``benchmarks.kernels_bench``.
"""
from __future__ import annotations

import os

import jax

from repro.kernels.aggregate import ref
from repro.kernels.aggregate.aggregate import aggregate_apply as _fused_kernel
from repro.kernels.aggregate.aggregate import chain_aggregate as _kernel
from repro.kernels.aggregate.aggregate import mean_over_clients as _mean_kernel


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _force_pallas_env() -> bool:
    return os.environ.get("REPRO_FORCE_PALLAS", "0") not in ("0", "", "false")


def chain_aggregate(x, g, c_i, c, weights=None, *, lr: float, force_pallas: bool = False):
    import jax.numpy as jnp

    if weights is None:
        weights = jnp.full((g.shape[0],), 1.0 / g.shape[0], jnp.float32)
    if _on_tpu():
        return _kernel(x, g, c_i, c, weights, lr=lr)
    if force_pallas or _force_pallas_env():
        return _kernel(x, g, c_i, c, weights, lr=lr, interpret=True)
    return ref.chain_aggregate_ref(x, g, c_i, c, lr=lr, weights=weights)


def mean_over_clients(t, *, force_pallas: bool = False):
    if _on_tpu():
        return _mean_kernel(t)
    if force_pallas or _force_pallas_env():
        return _mean_kernel(t, interpret=True)
    return ref.mean_over_clients_ref(t)


def use_fused_aggregate(force_pallas: bool = False) -> bool:
    """Whether comm rounds should take the fused aggregate-apply path —
    kernel backends only (TPU, or forced Pallas interpret mode). The jnp
    reference backend keeps the historical unfused sequence so default CPU
    runs stay bitwise unchanged."""
    return _on_tpu() or force_pallas or _force_pallas_env()


def aggregate_apply(x, agg_rows, comp, delta_in, res, m, w, *,
                    force_pallas: bool = False):
    """Fused aggregate + error-feedback + server apply; see
    ``aggregate.aggregate_apply`` for the math. Returns (x_new, res_new)."""
    if _on_tpu():
        return _fused_kernel(x, agg_rows, comp, delta_in, res, m, w)
    if force_pallas or _force_pallas_env():
        return _fused_kernel(x, agg_rows, comp, delta_in, res, m, w,
                             interpret=True)
    return ref.aggregate_apply_ref(x, agg_rows, comp, delta_in, res, m, w)
