"""Backend-dispatching wrappers for the aggregate kernel.

TPU: the Pallas kernel. CPU: interpret-mode Pallas when ``force_pallas``
(tests), else the jnp reference (XLA:CPU can't lower Mosaic).
"""
from __future__ import annotations

import jax

from repro.kernels.aggregate import ref
from repro.kernels.aggregate.aggregate import chain_aggregate as _kernel
from repro.kernels.aggregate.aggregate import mean_over_clients as _mean_kernel


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def chain_aggregate(x, g, c_i, c, weights=None, *, lr: float, force_pallas: bool = False):
    import jax.numpy as jnp

    if weights is None:
        weights = jnp.full((g.shape[0],), 1.0 / g.shape[0], jnp.float32)
    if _on_tpu():
        return _kernel(x, g, c_i, c, weights, lr=lr)
    if force_pallas:
        return _kernel(x, g, c_i, c, weights, lr=lr, interpret=True)
    return ref.chain_aggregate_ref(x, g, c_i, c, lr=lr, weights=weights)


def mean_over_clients(t, *, force_pallas: bool = False):
    if _on_tpu():
        return _mean_kernel(t)
    if force_pallas:
        return _mean_kernel(t, interpret=True)
    return ref.mean_over_clients_ref(t)
