"""Pure-jnp oracles for the compress kernels.

QSGD (Alistarh et al. 2017) with L quantization levels per half-range:

    q(v)_j = sign(v_j) · ||v||₂ · ξ_j / L,
    ξ_j = ⌊|v_j|/||v||₂ · L⌋ + Bernoulli(frac)   (stochastic rounding)

so E[q(v)] = v. The kernel computes the quantize→dequantize round trip (what
the server reconstructs); the Bernoulli draw is ``u < frac`` on caller-supplied
uniforms so Pallas and reference paths share the randomness bit-for-bit.
"""
from __future__ import annotations

import jax.numpy as jnp


def qsgd_dequantize_ref(v, u, norms, levels):
    """v, u: [S, D]; norms: [S] (ℓ₂ of each row); levels: scalar L ≥ 1."""
    vf = v.astype(jnp.float32)
    lv = jnp.maximum(levels.astype(jnp.float32), 1.0)
    safe = jnp.maximum(norms.astype(jnp.float32), 1e-30)[:, None]
    scaled = jnp.abs(vf) / safe * lv
    lo = jnp.floor(scaled)
    q = lo + jnp.where(u.astype(jnp.float32) < scaled - lo, 1.0, 0.0)
    return (jnp.sign(vf) * safe * (q / lv)).astype(v.dtype)


def weighted_mean_over_clients_ref(t, w):
    """meanᵢ wᵢ·tᵢ over the leading client axis (weights NOT renormalized —
    callers fold the Σw normalization into w)."""
    return jnp.mean(w.astype(jnp.float32)[:, None] * t.astype(jnp.float32),
                    axis=0).astype(t.dtype)
