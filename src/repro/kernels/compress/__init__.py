"""Pallas kernels for the communication subsystem's hot path.

Two fused ops back ``repro.comm``:

* ``qsgd_dequantize`` — QSGD stochastic quantize→dequantize of per-client
  uplink vectors (the simulated wire format: the server-visible value after
  one quantized round trip).
* ``weighted_mean_over_clients`` — mean over the client axis with per-client
  weights, the masked-aggregate primitive behind partial participation.

Dispatch mirrors ``kernels.aggregate``: jnp reference on CPU, interpret-mode
Pallas under ``REPRO_FORCE_PALLAS=1``, real kernels on TPU.
"""
from repro.kernels.compress import ops
from repro.kernels.compress.compress import qsgd_dequantize, weighted_mean_over_clients
from repro.kernels.compress.ref import (
    qsgd_dequantize_ref,
    weighted_mean_over_clients_ref,
)

__all__ = [
    "ops",
    "qsgd_dequantize",
    "weighted_mean_over_clients",
    "qsgd_dequantize_ref",
    "weighted_mean_over_clients_ref",
]
