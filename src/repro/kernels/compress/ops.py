"""Backend-dispatching wrappers for the compress kernels.

TPU: Pallas kernels. CPU: interpret-mode Pallas when ``force_pallas`` or
``REPRO_FORCE_PALLAS=1``, else the jnp reference — the same contract as
``kernels.aggregate.ops`` (and the same env key the executor cache uses,
so a cached executor traced under one dispatch mode is never served under
another).
"""
from __future__ import annotations

import jax

from repro.kernels.aggregate.ops import _force_pallas_env, _on_tpu
from repro.kernels.compress import ref
from repro.kernels.compress.compress import qsgd_dequantize as _qsgd_kernel
from repro.kernels.compress.compress import (
    weighted_mean_over_clients as _wmean_kernel,
)


def qsgd_dequantize(v, u, norms, levels, *, force_pallas: bool = False):
    if _on_tpu():
        return _qsgd_kernel(v, u, norms, levels)
    if force_pallas or _force_pallas_env():
        return _qsgd_kernel(v, u, norms, levels, interpret=True)
    return ref.qsgd_dequantize_ref(v, u, norms, levels)


def weighted_mean_over_clients(t, w, *, force_pallas: bool = False):
    if _on_tpu():
        return _wmean_kernel(t, w)
    if force_pallas or _force_pallas_env():
        return _wmean_kernel(t, w, interpret=True)
    return ref.weighted_mean_over_clients_ref(t, w)
