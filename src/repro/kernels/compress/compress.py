"""Pallas TPU kernels for uplink compression and masked aggregation.

``qsgd_dequantize`` is elementwise over [S, D] with a per-row norm and a
scalar level count; one pass streams [S, BLOCK_D] tiles through VMEM
(quantize and dequantize fused, so the int lattice never hits HBM).
``weighted_mean_over_clients`` is the masked-aggregate primitive: the whole
[S] weight vector is staged per grid step next to each [S, BLOCK_D] tile
(same layout as ``aggregate.chain_aggregate``'s weights).

Both take runtime operands only — levels and weights are data, so comm
config changes never retrace a compiled caller.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_D = 2048


def _qsgd_kernel(lv_ref, n_ref, v_ref, u_ref, o_ref):
    lv = jnp.maximum(lv_ref[0], 1.0)
    safe = jnp.maximum(n_ref[...].astype(jnp.float32), 1e-30)[:, None]
    v = v_ref[...].astype(jnp.float32)  # [S, BD]
    scaled = jnp.abs(v) / safe * lv
    lo = jnp.floor(scaled)
    q = lo + jnp.where(u_ref[...].astype(jnp.float32) < scaled - lo, 1.0, 0.0)
    o_ref[...] = (jnp.sign(v) * safe * (q / lv)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret", "block_d"))
def qsgd_dequantize(v, u, norms, levels, *, interpret: bool = False,
                    block_d: int = BLOCK_D):
    """v, u: [S, D]; norms: [S]; levels: scalar array. Returns [S, D]."""
    s, d = v.shape
    bd = min(block_d, d)
    pad = (-d) % bd
    if pad:  # padded zeros quantize to zero and are sliced off below
        v = jnp.pad(v, ((0, 0), (0, pad)))
        u = jnp.pad(u, ((0, 0), (0, pad)))
    dp = v.shape[1]
    lv = jnp.reshape(levels, (1,)).astype(jnp.float32)

    out = pl.pallas_call(
        _qsgd_kernel,
        grid=(dp // bd,),
        in_specs=[
            pl.BlockSpec((1,), lambda j: (0,)),  # levels: whole scalar
            pl.BlockSpec((s,), lambda j: (0,)),  # norms: whole vector
            pl.BlockSpec((s, bd), lambda j: (0, j)),  # v tile
            pl.BlockSpec((s, bd), lambda j: (0, j)),  # u tile
        ],
        out_specs=pl.BlockSpec((s, bd), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((s, dp), v.dtype),
        interpret=interpret,
    )(lv, norms, v, u)
    return out[:, :d] if pad else out


def _wmean_kernel(w_ref, t_ref, o_ref):
    w = w_ref[...].astype(jnp.float32)
    t = t_ref[...].astype(jnp.float32)
    o_ref[...] = jnp.mean(w[:, None] * t, axis=0).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret", "block_d"))
def weighted_mean_over_clients(t, w, *, interpret: bool = False,
                               block_d: int = BLOCK_D):
    """meanᵢ wᵢ·tᵢ over the leading axis of t: [S, D] × [S] → [D]."""
    s, d = t.shape
    bd = min(block_d, d)
    pad = (-d) % bd
    if pad:
        t = jnp.pad(t, ((0, 0), (0, pad)))
    dp = t.shape[1]
    out = pl.pallas_call(
        _wmean_kernel,
        grid=(dp // bd,),
        in_specs=[
            pl.BlockSpec((s,), lambda j: (0,)),  # weights: whole vector
            pl.BlockSpec((s, bd), lambda j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bd,), lambda j: (j,)),
        out_shape=jax.ShapeDtypeStruct((dp,), t.dtype),
        interpret=interpret,
    )(w, t)
    return out[:d] if pad else out
