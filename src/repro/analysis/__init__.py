"""Trace-discipline analyzer: AST lint + jaxpr const-capture audit.

Every performance number in this reproduction rests on invariants the
compiler cannot check: executors compile ONCE per structure, problems /
comm configs / policies ride as operands (never closures), donated buffers
are de-aliased, and both engines derive identical key streams. This package
makes those invariants machine-checkable:

* **Layer 1 — AST lint** (``repro.analysis.lint``): rules R1–R6 below,
  run over ``src/repro`` and ``benchmarks``.
* **Layer 2 — jaxpr audit** (``repro.analysis.jaxpr_audit``): runs tiny
  workloads through every cached executor family (runner / chain / sweep /
  selection on both the vmapped and sharded engines), re-traces each
  executor on its real operands, and walks the ``ClosedJaxpr`` consts —
  the DYNAMIC proof that operand discipline actually held. Any family
  carrying more than ``CONST_BYTE_CEILING`` bytes of array constants fails.

CLI::

    PYTHONPATH=src python -m repro.analysis --all   [--json BENCH_analysis.json]
    PYTHONPATH=src python -m repro.analysis --lint  [paths ...]
    PYTHONPATH=src python -m repro.analysis --audit

Exit status 0 iff there are zero unsuppressed lint violations and the
audit's const ceilings hold.

The rules
=========

**R1 — no closure-captured or host-materialized arrays in traced code.**
A module-level ``jnp``/``np`` array referenced inside a traced body — or a
``np.array(...)`` materialized there — bakes into the jaxpr as a constant:
it pins host memory for the cache entry's lifetime and silently decouples
the executor from the operand it was supposed to consume (the exact bug
class PR 3 removed by making problems ``ProblemSpec`` operands). Arrays
enter traced code as ARGUMENTS; legacy closure problems ride the registered
weak-token path in ``runner.problem_key``.

**R2 — no Python side effects in traced bodies except TRACE_COUNTS.**
A traced body executes once per TRACE, not once per call: a ``print``, a
``list.append`` on a module global, or a dict write runs zero times on the
warm path. The single whitelisted side effect is the
``runner.TRACE_COUNTS[...] += 1`` bump — it is the repo's trace PROBE and
exploits exactly this semantics.

**R3 — tagged fold_in streams; no key consumed twice.** Both engines must
derive bitwise-identical randomness from the same round key, so every
constant-stream derivation uses a REGISTERED tag
(``REGISTERED_KEY_TAGS`` below) rather than a bare literal — two call sites
independently choosing ``fold_in(key, 1)`` collide silently. Data-dependent
folds (round indices, cell indices) are fine. A key fed to two SAMPLERS
without an intervening ``split``/``fold_in`` replays randomness.

**R4 — donation threads through the executor cache key.** Donation is part
of an executor's identity: two structurally-equal jits that differ only in
``donate_argnums`` must never be served interchangeably from the cache
(PR 6). So every ``donate_argnums=`` is a NAMED tuple that also appears in
the cache key, and caller-owned leaves route through
``runner.dealias_donated`` before the call. Donation sites outside the
cached-executor machinery need an explicit ``allow[R4]``.

**R5 — every kernel ships ref.py + ops.py.** A Pallas kernel without a jnp
reference cannot be tested bitwise, and without an ops dispatch gate
(TPU → kernel, ``REPRO_FORCE_PALLAS`` → interpret, else ref) it is
unreachable from the backend-keyed executor cache.

**R6 — BENCH-writing harnesses are gated.** A harness registered in
``benchmarks/run.py`` that writes a ``BENCH_*.json`` baseline must appear
in ``benchmarks/check_regression.py``, else its baseline rots while CI
stays green. Harnesses with no stable warm metric carry ``allow[R6]`` with
a rationale.

Suppression syntax
==================

``# repro: allow[R1]`` (or ``allow[R1,R4]``) on the violating line or the
line directly above suppresses that rule there. Suppressed findings and
the full per-rule suppression inventory are part of the report
(``BENCH_analysis.json``) — suppressions are visible debt, not deletions.
"""
from __future__ import annotations

# The key-stream tag registry (R3). Every constant fold_in stream in the
# tree derives from one of these names; the VALUES live next to their
# streams (comm/config.py, selection/policies.py) — this registry is the
# single place a reviewer checks for collisions.
REGISTERED_KEY_TAGS = {
    "_COMM_KEY_TAG",         # 0x636D comm/config.py — quantization randomness
    "_PROBE_KEY_TAG",        # 0x736C selection/policies.py — value probes
    "_SECOND_UPLINK_TAG",    # 1 comm/config.py — SAGA/SCAFFOLD second uplink
    "_DOWNLINK_KEY_TAG",     # 2 comm/config.py — downlink-EF broadcasts
    "_MOMENTUM_UPLINK_TAG",  # 3 comm/config.py — compressed-momentum uplinks
}

# Per-executor-family ceiling on TOTAL array-const bytes in the traced
# jaxpr (Layer 2). Spec-path executors carry no array consts at all; the
# ceiling leaves room for stray control scalars, never for a data shard.
CONST_BYTE_CEILING = 4096

from repro.analysis.lint import run_lint  # noqa: E402
from repro.analysis.lint.base import Violation  # noqa: E402

__all__ = [
    "CONST_BYTE_CEILING", "REGISTERED_KEY_TAGS", "Violation", "run_lint",
]
