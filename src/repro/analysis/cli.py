"""CLI entry point: ``python -m repro.analysis``.

  --lint          run the AST lint (R1–R6) over src/repro + benchmarks
  --audit         run the jaxpr const-capture audit (all executor families)
  --all           both layers (what CI runs)
  --json PATH     write the machine-readable report (BENCH_analysis.json)
  --root DIR      repo root (default: auto-detected from this package)
  --verbose       also print suppressed findings
  [paths ...]     override the linted paths (relative to root)

Exit status: 0 iff zero unsuppressed lint violations and zero audit
failures. Tests are deliberately NOT linted by default — fixture snippets
there exist to violate the rules on purpose.
"""
from __future__ import annotations

import argparse
import os
import sys
from typing import List, Optional

DEFAULT_LINT_PATHS = ("src/repro", "benchmarks")


def detect_root(start: Optional[str] = None) -> str:
    """Walk up from this package (or ``start``) to the directory holding
    ``src/repro`` — the repo root the path rules are anchored to."""
    cur = os.path.abspath(start or os.path.dirname(__file__))
    while True:
        if os.path.isdir(os.path.join(cur, "src", "repro")):
            return cur
        parent = os.path.dirname(cur)
        if parent == cur:
            return os.getcwd()
        cur = parent


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.analysis")
    ap.add_argument("--lint", action="store_true")
    ap.add_argument("--audit", action="store_true")
    ap.add_argument("--all", action="store_true", dest="all_layers")
    ap.add_argument("--json", default=None, metavar="PATH")
    ap.add_argument("--root", default=None)
    ap.add_argument("--verbose", action="store_true")
    ap.add_argument("paths", nargs="*", default=[])
    args = ap.parse_args(argv)

    do_lint = args.lint or args.all_layers or not (args.lint or args.audit)
    do_audit = args.audit or args.all_layers
    root = args.root or detect_root()

    from repro.analysis import report as report_lib
    from repro.analysis.lint import run_lint

    violations, inventory = (None, {})
    if do_lint:
        paths = tuple(args.paths) or DEFAULT_LINT_PATHS
        violations, inventory = run_lint(root, paths)

    audit_report, audit_failures = None, []
    if do_audit:
        from repro.analysis import jaxpr_audit

        audit_report, audit_failures = jaxpr_audit.run_audit()

    print(report_lib.format_console(
        violations, inventory, audit_report, audit_failures,
        verbose=args.verbose))
    if args.json:
        doc = report_lib.build_report(violations, inventory, audit_report)
        report_lib.write_json(doc, args.json)
        print(f"report written to {args.json}")

    active, _ = report_lib.split_violations(violations or [])
    return 1 if (active or audit_failures) else 0


if __name__ == "__main__":
    sys.exit(main())
