"""Layer 1 of ``repro.analysis``: the AST lint.

``run_lint(root, paths)`` parses every ``.py`` under the given paths into a
``ModuleContext`` (traced-scope detection + suppression table, see
``lint.base``) and runs the R1–R4 AST checkers plus the R5–R6 repo-structure
checkers over the tree. Returns every finding, suppressed included — the
caller splits them for reporting.
"""
from __future__ import annotations

import os
from typing import Dict, List, Sequence, Tuple

from repro.analysis.lint.base import Checker, ModuleContext, Violation
from repro.analysis.lint.checkers import AST_CHECKERS
from repro.analysis.lint.repo_rules import REPO_CHECKERS

__all__ = [
    "AST_CHECKERS", "Checker", "ModuleContext", "REPO_CHECKERS",
    "Violation", "iter_sources", "run_lint",
]

_SKIP_DIRS = {"__pycache__", ".git", ".github", "node_modules"}


def iter_sources(root: str, paths: Sequence[str]) -> List[str]:
    """All ``.py`` files under ``paths`` (relative to ``root``), sorted."""
    out = []
    for rel in paths:
        top = os.path.join(root, rel)
        if os.path.isfile(top):
            out.append(top)
            continue
        for dirpath, dirnames, filenames in os.walk(top):
            dirnames[:] = [d for d in dirnames if d not in _SKIP_DIRS]
            out.extend(os.path.join(dirpath, f)
                       for f in filenames if f.endswith(".py"))
    return sorted(out)


def run_lint(root: str, paths: Sequence[str]
             ) -> Tuple[List[Violation], Dict[str, int]]:
    """(all findings incl. suppressed, {rule: declared-suppression count}).

    The suppression inventory counts every ``# repro: allow[Rn]`` comment
    found in the linted sources per rule — the report surfaces them so a
    stale suppression can't hide forever.
    """
    violations: List[Violation] = []
    suppression_inventory: Dict[str, int] = {}
    for path in iter_sources(root, paths):
        with open(path) as f:
            source = f.read()
        try:
            ctx = ModuleContext(os.path.relpath(path, root), source)
        except SyntaxError as e:
            violations.append(Violation(
                rule="parse", path=os.path.relpath(path, root),
                line=e.lineno or 1, message=f"syntax error: {e.msg}"))
            continue
        for rules in ctx.suppressions.values():
            for rule in rules:
                suppression_inventory[rule] = (
                    suppression_inventory.get(rule, 0) + 1)
        for checker_cls in AST_CHECKERS:
            violations.extend(checker_cls().check(ctx))
    for checker_cls in REPO_CHECKERS:
        violations.extend(checker_cls().check_repo(root))
    return violations, suppression_inventory
