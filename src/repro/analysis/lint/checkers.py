"""AST checkers R1–R4: the per-module trace-discipline rules.

Each checker emits every finding (suppressed or not); ``repro.analysis.cli``
separates them so suppressions stay visible in the report. See the package
docstring (``repro.analysis``) for the full rule statements and rationale.
"""
from __future__ import annotations

import ast
from typing import List, Set

from repro.analysis.lint import base
from repro.analysis.lint.base import (
    ARRAY_CTORS, Checker, ModuleContext, NUMPY_ROOTS, Violation,
    enclosing_functions, local_bindings, root_name, terminal_name,
)

# jax.random samplers that CONSUME a key (split/fold_in DERIVE streams and
# may take the same parent key any number of times)
RANDOM_SAMPLERS = {
    "normal", "uniform", "bernoulli", "permutation", "choice", "categorical",
    "randint", "truncated_normal", "gumbel", "laplace", "rademacher",
    "exponential", "bits", "poisson", "gamma", "beta", "dirichlet",
    "orthogonal", "ball", "maxwell",
}

# mutating method names on module-level objects (Python side effects a
# traced body must not perform — they run once per TRACE, not per call)
MUTATOR_METHODS = {
    "append", "add", "update", "extend", "insert", "remove", "pop",
    "popitem", "clear", "setdefault", "write", "move_to_end",
}


class ClosureArrayChecker(Checker):
    """R1: traced bodies must not capture module-level arrays by closure or
    materialize host (numpy) arrays — both bake into the jaxpr as consts
    instead of riding as operands, pinning memory and defeating the
    structural executor cache."""

    rule = "R1"
    title = "no closure-captured or host-materialized arrays in traced code"

    def check(self, ctx: ModuleContext) -> List[Violation]:
        out, seen = [], set()
        for fn in ctx.traced_scopes:
            locals_chain: Set[str] = set()
            for scope in [fn] + enclosing_functions(fn):
                locals_chain |= local_bindings(scope)
            for node in ast.walk(fn):
                if (isinstance(node, ast.Name)
                        and isinstance(node.ctx, ast.Load)
                        and node.id in ctx.module_arrays
                        and node.id not in locals_chain):
                    key = ("name", node.lineno, node.id)
                    if key not in seen:
                        seen.add(key)
                        out.append(ctx.violation(
                            self.rule, node,
                            f"module-level array {node.id!r} (defined at "
                            f"line {ctx.module_arrays[node.id]}) captured by "
                            f"closure in a traced body — pass it as an "
                            f"operand argument instead"))
                elif (isinstance(node, ast.Call)
                      and terminal_name(node.func) in ARRAY_CTORS
                      and root_name(node.func) in NUMPY_ROOTS):
                    key = ("ctor", node.lineno, terminal_name(node.func))
                    if key not in seen:
                        seen.add(key)
                        out.append(ctx.violation(
                            self.rule, node,
                            f"host numpy array "
                            f"({root_name(node.func)}."
                            f"{terminal_name(node.func)}) materialized "
                            f"inside a traced body becomes a baked jaxpr "
                            f"const — build it outside the trace and pass "
                            f"it as an operand (or use jnp)"))
        return out


class SideEffectChecker(Checker):
    """R2: no Python side effects in traced bodies — they run once per
    trace, not once per call, so anything but a ``base.TRACE_WHITELIST``
    counter bump (``TRACE_COUNTS``, ``TRACE_EVENTS``) is a silent
    correctness bug."""

    rule = "R2"
    title = "no Python side effects in traced bodies except TRACE_WHITELIST"

    def check(self, ctx: ModuleContext) -> List[Violation]:
        out, seen = [], set()

        def emit(node, msg):
            key = (node.lineno, msg)
            if key not in seen:
                seen.add(key)
                out.append(ctx.violation(self.rule, node, msg))

        for fn in ctx.traced_scopes:
            locals_chain: Set[str] = set()
            for scope in [fn] + enclosing_functions(fn):
                locals_chain |= local_bindings(scope)
            for node in ast.walk(fn):
                if isinstance(node, ast.Global):
                    emit(node, "`global` rebinding inside a traced body "
                               "runs at trace time, not per call")
                elif isinstance(node, ast.Call):
                    name = terminal_name(node.func)
                    if name in ("print", "open") and isinstance(
                            node.func, ast.Name):
                        emit(node, f"{name}() inside a traced body executes "
                                   f"once per TRACE, not per call (use "
                                   f"jax.debug.print for runtime output)")
                    elif (isinstance(node.func, ast.Attribute)
                          and node.func.attr in MUTATOR_METHODS):
                        tgt = node.func.value
                        if base._is_trace_counts_target(node.func):
                            continue
                        root = root_name(tgt)
                        if (root is not None and root in ctx.module_names
                                and root not in locals_chain):
                            emit(node,
                                 f"mutation of module-level {root!r} "
                                 f"(.{node.func.attr}) inside a traced body "
                                 f"is a trace-time side effect")
                elif isinstance(node, (ast.Assign, ast.AugAssign)):
                    targets = (node.targets if isinstance(node, ast.Assign)
                               else [node.target])
                    for t in targets:
                        if base._is_trace_counts_target(t):
                            continue
                        if not isinstance(t, (ast.Subscript, ast.Attribute)):
                            continue
                        root = root_name(t)
                        if (root is not None and root in ctx.module_names
                                and root not in locals_chain):
                            emit(node,
                                 f"assignment into module-level {root!r} "
                                 f"inside a traced body is a trace-time "
                                 f"side effect (only the TRACE_WHITELIST "
                                 f"counter bumps are allowed)")
        return out


class KeyStreamChecker(Checker):
    """R3: ``fold_in`` streams must be tagged with registered constants
    (never bare integer literals), and a PRNG key must not feed two
    samplers without an intervening ``split``/``fold_in``."""

    rule = "R3"
    title = "tagged fold_in streams; no PRNG key consumed twice"

    def check(self, ctx: ModuleContext) -> List[Violation]:
        from repro.analysis import REGISTERED_KEY_TAGS

        out = []
        for node in ast.walk(ctx.tree):
            if not (isinstance(node, ast.Call)
                    and terminal_name(node.func) == "fold_in"):
                continue
            if len(node.args) < 2:
                continue
            tag = node.args[1]
            if isinstance(tag, ast.Constant) and isinstance(tag.value, int):
                out.append(ctx.violation(
                    self.rule, node,
                    f"fold_in stream tagged with the bare literal "
                    f"{tag.value!r} — register a named tag constant in "
                    f"repro.analysis.REGISTERED_KEY_TAGS (both engines "
                    f"must derive identical streams from one registry)"))
            elif (isinstance(tag, ast.Name) and tag.id.endswith("_TAG")
                  and tag.id not in REGISTERED_KEY_TAGS):
                out.append(ctx.violation(
                    self.rule, node,
                    f"fold_in tag {tag.id!r} is not registered in "
                    f"repro.analysis.REGISTERED_KEY_TAGS"))

        seen: Set[tuple] = set()
        self._scan_block(ctx, ctx.tree.body, set(), out, seen)
        return out

    def _scan_block(self, ctx, stmts, consumed: Set[str], out, seen) -> None:
        """Linear key-consumption scan; branch bodies inherit a COPY of the
        consumed set (an if/else legitimately consumes the same key once on
        each path) and never merge back."""
        for stmt in stmts:
            if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.ClassDef)):
                self._scan_block(ctx, stmt.body, set(), out, seen)
            elif isinstance(stmt, (ast.If, ast.While)):
                self._consume_in(ctx, stmt.test, consumed, out, seen)
                self._scan_block(ctx, stmt.body, set(consumed), out, seen)
                self._scan_block(ctx, stmt.orelse, set(consumed), out, seen)
            elif isinstance(stmt, (ast.For, ast.AsyncFor)):
                self._consume_in(ctx, stmt.iter, consumed, out, seen)
                inner = set(consumed)
                for n in ast.walk(stmt.target):
                    if isinstance(n, ast.Name):
                        inner.discard(n.id)
                self._scan_block(ctx, stmt.body, inner, out, seen)
                self._scan_block(ctx, stmt.orelse, set(consumed), out, seen)
            elif isinstance(stmt, (ast.With, ast.AsyncWith)):
                for item in stmt.items:
                    self._consume_in(ctx, item.context_expr, consumed, out,
                                     seen)
                self._scan_block(ctx, stmt.body, consumed, out, seen)
            elif isinstance(stmt, ast.Try):
                for block in (stmt.body, stmt.orelse, stmt.finalbody,
                              *[h.body for h in stmt.handlers]):
                    self._scan_block(ctx, block, set(consumed), out, seen)
            else:
                self._consume_in(ctx, stmt, consumed, out, seen)
                if isinstance(stmt, ast.Assign):
                    for t in stmt.targets:
                        for n in ast.walk(t):
                            if isinstance(n, ast.Name):
                                consumed.discard(n.id)

    def _consume_in(self, ctx, node, consumed: Set[str], out, seen) -> None:
        for sub in ast.walk(node):
            if isinstance(sub, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue  # scanned with their own fresh consumed set
            if not isinstance(sub, ast.Call):
                continue
            name = terminal_name(sub.func)
            if (name in RANDOM_SAMPLERS and sub.args
                    and isinstance(sub.args[0], ast.Name)
                    and self._is_random_call(sub.func)):
                kid = sub.args[0].id
                if kid in consumed and (sub.lineno, kid) not in seen:
                    seen.add((sub.lineno, kid))
                    out.append(ctx.violation(
                        self.rule, sub,
                        f"PRNG key {kid!r} consumed twice without "
                        f"split/fold_in — the second sample REPLAYS the "
                        f"first one's randomness"))
                consumed.add(kid)

    @staticmethod
    def _is_random_call(func) -> bool:
        """Only flag samplers reached through a ``random`` module path
        (``jax.random.normal``, ``jr.normal``) — ``normal`` alone is too
        generic a method name to claim."""
        if isinstance(func, ast.Attribute):
            parent = func.value
            if isinstance(parent, ast.Attribute):
                return parent.attr == "random"
            if isinstance(parent, ast.Name):
                return parent.id in ("random", "jr", "jrandom")
        return False


class DonationChecker(Checker):
    """R4: every ``donate_argnums=`` must be a named tuple threaded through
    the executor cache key — a literal donation (or a name used nowhere
    else) means two structurally-equal executors with different donation
    can be served interchangeably, silently invalidating caller buffers."""

    rule = "R4"
    title = "donate_argnums threaded through the executor cache key"

    def check(self, ctx: ModuleContext) -> List[Violation]:
        out = []
        for node in ast.walk(ctx.tree):
            # only DIRECT jit calls: a literal donate tuple passed to a
            # builder that threads it into the cache key itself (e.g.
            # dist.grid._sharded_grid_fn) is the callee's responsibility
            if not (isinstance(node, ast.Call)
                    and terminal_name(node.func) == "jit"):
                continue
            for kw in node.keywords:
                if kw.arg != "donate_argnums":
                    continue
                names = {n.id for n in ast.walk(kw.value)
                         if isinstance(n, ast.Name)}
                if not names:
                    out.append(ctx.violation(
                        self.rule, node,
                        "literal donate_argnums= — bind the donate tuple to "
                        "a name and thread it through the executor cache "
                        "key (runner._cache_put) so donation is part of "
                        "the executor's identity"))
                    continue
                scopes = enclosing_functions(node)
                search_root = scopes[-1] if scopes else ctx.tree
                loads = sum(
                    1 for n in ast.walk(search_root)
                    if isinstance(n, ast.Name) and n.id in names
                    and isinstance(n.ctx, ast.Load)
                    and n not in set(ast.walk(kw.value)))
                if loads == 0:
                    out.append(ctx.violation(
                        self.rule, node,
                        f"donate tuple {sorted(names)} is used ONLY in "
                        f"donate_argnums= — it must also appear in the "
                        f"executor cache key"))
        return out


AST_CHECKERS = (ClosureArrayChecker, SideEffectChecker, KeyStreamChecker,
                DonationChecker)
