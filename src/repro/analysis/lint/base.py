"""Lint framework: module contexts, traced-scope detection, suppressions.

A *traced scope* is a function whose Python body executes under a JAX trace
— anything passed (by name or as a lambda) to ``jax.jit`` / ``lax.scan`` /
``jax.vmap`` / ``shard_map`` / ``pallas_call`` / control-flow combinators,
anything decorated with ``jit``, anything that bumps a ``TRACE_WHITELIST``
counter (``TRACE_COUNTS``, the repo's trace-time marker, and
``TRACE_EVENTS``, the obs event sink mirrored beside it), and anything
lexically nested inside one of those. The detection over-approximates (a name collision marks an unrelated
same-named def) — acceptable for a lint whose false positives are one
``# repro: allow[Rn]`` away.

Suppressions: ``# repro: allow[R1]`` (or ``allow[R1,R4]``) on the violating
line or on the line directly above it. Every suppression is inventoried in
the report, used or not, so dead suppressions are visible.
"""
from __future__ import annotations

import ast
import dataclasses
import re
from typing import Dict, Iterable, List, Optional, Set

SUPPRESS_RE = re.compile(r"#\s*repro:\s*allow\[([^\]]+)\]")

# call names whose function-valued arguments are traced
TRACE_ENTRY_NAMES = {
    "jit", "scan", "vmap", "pmap", "shard_map", "pallas_call", "make_jaxpr",
    "switch", "cond", "while_loop", "fori_loop", "checkpoint", "remat",
    "grad", "value_and_grad", "custom_vjp", "custom_jvp", "eval_shape",
}

# array-materializing constructors (terminal attribute names)
ARRAY_CTORS = {
    "array", "asarray", "zeros", "ones", "arange", "linspace", "eye",
    "full", "stack", "concatenate", "tile",
}

NUMPY_ROOTS = {"np", "numpy"}
JNP_ROOTS = {"jnp", "np", "numpy"} | {"jax"}


@dataclasses.dataclass(frozen=True)
class Violation:
    rule: str
    path: str
    line: int
    message: str
    suppressed: bool = False

    def format(self) -> str:
        mark = " (suppressed)" if self.suppressed else ""
        return f"{self.path}:{self.line}: {self.rule}{mark}: {self.message}"


def terminal_name(node) -> Optional[str]:
    """The last attribute segment of a call target: ``jax.lax.scan`` →
    ``scan``, ``fold_in`` → ``fold_in``."""
    if isinstance(node, ast.Attribute):
        return node.attr
    if isinstance(node, ast.Name):
        return node.id
    return None


def root_name(node) -> Optional[str]:
    """The leftmost name of an attribute chain: ``jnp.zeros`` → ``jnp``."""
    while isinstance(node, ast.Attribute):
        node = node.value
    return node.id if isinstance(node, ast.Name) else None


def _set_parents(tree: ast.AST) -> None:
    for parent in ast.walk(tree):
        for child in ast.iter_child_nodes(parent):
            child._repro_parent = parent  # type: ignore[attr-defined]


def parent_of(node: ast.AST) -> Optional[ast.AST]:
    return getattr(node, "_repro_parent", None)


def enclosing_functions(node: ast.AST) -> List[ast.AST]:
    """Innermost-first chain of enclosing function/lambda nodes."""
    out = []
    cur = parent_of(node)
    while cur is not None:
        if isinstance(cur, (ast.FunctionDef, ast.AsyncFunctionDef,
                            ast.Lambda)):
            out.append(cur)
        cur = parent_of(cur)
    return out


def local_bindings(fn_node) -> Set[str]:
    """Names bound inside a function (params + assignment/for/with/
    comprehension targets), EXCLUDING bindings of nested defs."""
    out: Set[str] = set()
    if isinstance(fn_node, (ast.FunctionDef, ast.AsyncFunctionDef,
                            ast.Lambda)):
        a = fn_node.args
        for arg in (a.posonlyargs + a.args + a.kwonlyargs
                    + ([a.vararg] if a.vararg else [])
                    + ([a.kwarg] if a.kwarg else [])):
            out.add(arg.arg)
    body = fn_node.body if isinstance(fn_node.body, list) else [fn_node.body]
    for stmt in body:
        for sub in ast.walk(stmt):
            if isinstance(sub, (ast.FunctionDef, ast.AsyncFunctionDef)):
                out.add(sub.name)
            elif isinstance(sub, ast.Name) and isinstance(
                    sub.ctx, (ast.Store, ast.Del)):
                out.add(sub.id)
    return out


# the ONLY module-level objects a traced body may mutate: the trace-time
# bookkeeping counters. TRACE_COUNTS is the retrace-discipline marker
# (repro.core.runner); TRACE_EVENTS is the obs event sink bumped beside it
# (repro.obs.events) — both record "this body traced", never per-call state.
TRACE_WHITELIST = {"TRACE_COUNTS", "TRACE_EVENTS"}


def _is_trace_counts_target(node) -> bool:
    """True when an expression's attribute/subscript chain ends at one of
    the ``TRACE_WHITELIST`` counters (the whitelisted trace-time side
    effects)."""
    while isinstance(node, (ast.Subscript, ast.Attribute)):
        if isinstance(node, ast.Attribute) and node.attr in TRACE_WHITELIST:
            return True
        node = node.value
    return isinstance(node, ast.Name) and node.id in TRACE_WHITELIST


def module_array_bindings(tree: ast.Module) -> Dict[str, int]:
    """Module-level ``NAME = jnp/np.<ctor>(...)`` bindings: name → line.
    These are exactly the arrays a traced body must NOT capture by closure
    (they bake into the jaxpr as consts instead of riding as operands)."""
    out: Dict[str, int] = {}
    for stmt in tree.body:
        targets = []
        if isinstance(stmt, ast.Assign):
            targets = stmt.targets
            value = stmt.value
        elif isinstance(stmt, ast.AnnAssign) and stmt.value is not None:
            targets = [stmt.target]
            value = stmt.value
        else:
            continue
        if not (isinstance(value, ast.Call)
                and terminal_name(value.func) in ARRAY_CTORS
                and root_name(value.func) in JNP_ROOTS):
            continue
        for t in targets:
            if isinstance(t, ast.Name):
                out[t.id] = stmt.lineno
    return out


def module_level_names(tree: ast.Module) -> Set[str]:
    out: Set[str] = set()
    for stmt in tree.body:
        if isinstance(stmt, (ast.Assign, ast.AnnAssign, ast.AugAssign)):
            targets = (stmt.targets if isinstance(stmt, ast.Assign)
                       else [stmt.target])
            for t in targets:
                if isinstance(t, ast.Name):
                    out.add(t.id)
    return out


def find_traced_scopes(tree: ast.Module) -> Set[ast.AST]:
    """All function/lambda nodes whose bodies run under a JAX trace (see
    module docstring for the heuristic)."""
    traced: Set[ast.AST] = set()
    defs_by_name: Dict[str, List[ast.AST]] = {}
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            defs_by_name.setdefault(node.name, []).append(node)

    for node in ast.walk(tree):
        if isinstance(node, ast.Call) and (
                terminal_name(node.func) in TRACE_ENTRY_NAMES):
            for arg in list(node.args) + [kw.value for kw in node.keywords]:
                if isinstance(arg, ast.Name):
                    traced.update(defs_by_name.get(arg.id, ()))
                elif isinstance(arg, ast.Lambda):
                    traced.add(arg)
        elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            for dec in node.decorator_list:
                names = {terminal_name(dec)}
                if isinstance(dec, ast.Call):
                    names.add(terminal_name(dec.func))
                    names.update(terminal_name(a) for a in dec.args)
                if names & TRACE_ENTRY_NAMES:
                    traced.add(node)
            for sub in ast.walk(node):
                if isinstance(sub, (ast.AugAssign, ast.Assign)):
                    tgt = (sub.target if isinstance(sub, ast.AugAssign)
                           else sub.targets[0])
                    if _is_trace_counts_target(tgt):
                        traced.add(node)

    # nesting closure: a def inside a traced def is traced too
    changed = True
    while changed:
        changed = False
        for node in ast.walk(tree):
            if (isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                                  ast.Lambda))
                    and node not in traced
                    and any(fn in traced for fn in enclosing_functions(node))):
                traced.add(node)
                changed = True
    return traced


def parse_suppressions(lines: Iterable[str]) -> Dict[int, Set[str]]:
    """``{line: rules}`` from REAL ``# repro: allow[...]`` comments only —
    tokenized, so rule syntax quoted in docstrings never counts."""
    import io
    import tokenize

    source = "\n".join(lines) if not isinstance(lines, str) else lines
    out: Dict[int, Set[str]] = {}
    try:
        tokens = tokenize.generate_tokens(io.StringIO(source).readline)
        for tok in tokens:
            if tok.type != tokenize.COMMENT:
                continue
            m = SUPPRESS_RE.search(tok.string)
            if m:
                out[tok.start[0]] = {
                    r.strip() for r in m.group(1).split(",") if r.strip()}
    except (tokenize.TokenError, IndentationError, SyntaxError):
        # fall back to the line regex on untokenizable sources
        for i, line in enumerate(source.splitlines(), start=1):
            m = SUPPRESS_RE.search(line)
            if m:
                out[i] = {r.strip() for r in m.group(1).split(",")
                          if r.strip()}
    return out


class ModuleContext:
    """Everything a checker needs about one source file, parsed once."""

    def __init__(self, path: str, source: str):
        self.path = path
        self.source = source
        self.tree = ast.parse(source, filename=path)
        _set_parents(self.tree)
        self.lines = source.splitlines()
        self.suppressions = parse_suppressions(self.lines)
        self.used_suppressions: Dict[int, Set[str]] = {}
        self.module_arrays = module_array_bindings(self.tree)
        self.module_names = module_level_names(self.tree)
        self.traced_scopes = find_traced_scopes(self.tree)

    def in_traced_scope(self, node: ast.AST) -> bool:
        return any(fn in self.traced_scopes
                   for fn in enclosing_functions(node))

    def violation(self, rule: str, node: ast.AST, message: str) -> Violation:
        line = getattr(node, "lineno", 1)
        suppressed = False
        for at in (line, line - 1):
            if rule in self.suppressions.get(at, set()):
                suppressed = True
                self.used_suppressions.setdefault(at, set()).add(rule)
                break
        return Violation(rule=rule, path=self.path, line=line,
                         message=message, suppressed=suppressed)


class Checker:
    """A single lint rule. ``check`` returns ALL findings, suppressed ones
    included — the reporter splits them so the suppression inventory stays
    honest."""

    rule = "R?"
    title = ""

    def check(self, ctx: ModuleContext) -> List[Violation]:
        raise NotImplementedError
