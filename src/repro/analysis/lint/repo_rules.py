"""Repo-structure checkers R5–R6: rules about the TREE, not one module.

R5 walks ``src/repro/kernels/`` directly; R6 cross-references the harness
registry in ``benchmarks/run.py`` against ``benchmarks/check_regression.py``.
Both return the same ``Violation`` records as the AST checkers so the CLI
reports them uniformly.
"""
from __future__ import annotations

import ast
import os
from typing import List

from repro.analysis.lint.base import (
    Violation, parse_suppressions, terminal_name,
)


class KernelPairingChecker:
    """R5: every kernel directory ships a ``ref.py`` (the jnp reference the
    Pallas kernel is tested bitwise against) and an ``ops.py`` dispatch gate
    (TPU → kernel, ``REPRO_FORCE_PALLAS`` → interpret mode, else ref) — a
    kernel without them is unverifiable off-TPU and unreachable from the
    executors' backend-keyed cache."""

    rule = "R5"
    title = "every kernel has a ref.py counterpart and an ops.py gate"

    def check_repo(self, root: str) -> List[Violation]:
        out = []
        kdir = os.path.join(root, "src", "repro", "kernels")
        if not os.path.isdir(kdir):
            return out
        for name in sorted(os.listdir(kdir)):
            sub = os.path.join(kdir, name)
            if not os.path.isdir(sub) or name.startswith("__"):
                continue
            files = {f for f in os.listdir(sub) if f.endswith(".py")}
            if not (files - {"__init__.py"}):
                continue
            for required, why in (
                    ("ref.py", "a jnp reference implementation to test the "
                               "kernel bitwise against"),
                    ("ops.py", "a dispatch gate (TPU/interpret/ref) keyed "
                               "by the executor cache's backend env")):
                if required not in files:
                    out.append(Violation(
                        rule=self.rule,
                        path=os.path.relpath(sub, root),
                        line=1,
                        message=f"kernel {name!r} has no {required}: every "
                                f"kernel needs {why}"))
        return out


class BenchGateChecker:
    """R6: every harness registered in ``benchmarks/run.py`` that WRITES a
    ``BENCH_*.json`` baseline must be gated by
    ``benchmarks/check_regression.py`` — an ungated baseline silently rots
    while CI stays green. Suppress with ``# repro: allow[R6]`` on the
    registry line for harnesses whose output has no stable warm metric."""

    rule = "R6"
    title = "BENCH-writing harnesses in run.py are gated in check_regression"

    def check_repo(self, root: str) -> List[Violation]:
        out = []
        run_path = os.path.join(root, "benchmarks", "run.py")
        gate_path = os.path.join(root, "benchmarks", "check_regression.py")
        if not (os.path.exists(run_path) and os.path.exists(gate_path)):
            return out
        with open(run_path) as f:
            run_src = f.read()
        with open(gate_path) as f:
            gate_src = f.read()
        suppressions = parse_suppressions(run_src.splitlines())
        tree = ast.parse(run_src, filename=run_path)
        for node in ast.walk(tree):
            if not (isinstance(node, ast.Assign) and node.targets
                    and isinstance(node.targets[0], ast.Name)
                    and node.targets[0].id == "harnesses"
                    and isinstance(node.value, ast.Dict)):
                continue
            for key_node, val_node in zip(node.value.keys,
                                          node.value.values):
                module = self._module_of(val_node)
                if module is None:
                    continue
                mod_path = os.path.join(root, "benchmarks", f"{module}.py")
                if not os.path.exists(mod_path):
                    continue
                with open(mod_path) as f:
                    if "BENCH_" not in f.read():
                        continue  # writes no baseline: nothing to gate
                if module in gate_src:
                    continue
                line = key_node.lineno
                suppressed = any(
                    self.rule in suppressions.get(at, set())
                    for at in (line, line - 1))
                out.append(Violation(
                    rule=self.rule,
                    path=os.path.relpath(run_path, root),
                    line=line,
                    message=f"harness {module!r} writes a BENCH_*.json "
                            f"baseline but is not gated in "
                            f"check_regression.py",
                    suppressed=suppressed))
        return out

    @staticmethod
    def _module_of(val_node):
        """``table1_strongly_convex.main`` → ``table1_strongly_convex``."""
        if isinstance(val_node, ast.Attribute):
            base = val_node.value
            if isinstance(base, ast.Name):
                return base.id
            if isinstance(base, ast.Attribute):
                return terminal_name(base)
        return None


REPO_CHECKERS = (KernelPairingChecker, BenchGateChecker)
