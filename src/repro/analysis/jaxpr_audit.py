"""Layer 2: jaxpr const-capture audit of the cached executor stack.

The lint (Layer 1) argues operand discipline from source; this module
PROVES it dynamically. It runs tiny spec-backed workloads through every
cached executor family — runner / chain / sweep (indexed layout) /
selection, on BOTH the vmapped and sharded engines, plus the
telemetry-enabled (``repro.obs.Telemetry``) sweep variants — with
``runner.AUDIT_SINK`` armed, so each top-level executor call records
``(cache_key, fn, args)``. Each recorded executor is then re-traced on its
REAL operands with ``jax.make_jaxpr`` and the ``ClosedJaxpr`` consts are
walked recursively (pjit / scan / cond sub-jaxprs included). An executor
whose operands all arrived as arguments closes over (almost) nothing; any
family whose total array-const bytes exceed
``repro.analysis.CONST_BYTE_CEILING`` fails the audit — that is exactly a
data shard, key stack, or schedule baked in by closure.

The audit must run on a host backend (CPU / interpret): donation is a
no-op there, so the recorded argument arrays stay valid for the re-trace.

``run_audit(only=...)`` restricts to named workloads (the unit test runs
just the indexed sweep; CI and ``benchmarks/analysis_audit.py`` run all).
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.analysis import CONST_BYTE_CEILING
from repro.core import runner


def _tiny_context():
    """One tiny spec-backed problem family + methods, shared by all
    workloads (4 clients × dim 8 × 4 rounds keeps every compile cheap)."""
    from repro.comm import CommConfig
    from repro.core import algorithms as A, chain as chain_lib
    from repro.data import spec as spec_lib

    spec = spec_lib.quadratic_spec(
        jax.random.PRNGKey(0), num_clients=4, dim=8, mu=0.1, beta=1.0,
        zeta=1.0, sigma=0.1)
    spec2 = spec_lib.quadratic_spec(
        jax.random.PRNGKey(1), num_clients=4, dim=8, mu=0.1, beta=1.0,
        zeta=2.0, sigma=0.2)
    algo = A.SGD(eta=0.4, k=4, mu_avg=0.1)
    ch = chain_lib.fedchain(
        A.FedAvg(eta=0.3, local_steps=2, inner_batch=2),
        A.SGD(eta=0.4, k=4, mu_avg=0.1))
    comm = CommConfig(compressor="qsgd", qsgd_bits=4, participation=0.5)
    return spec, spec2, algo, ch, comm


ROUNDS = 4
_SEEDS = (0, 1)
_ETAS = (0.5, 1.0)


def _workloads() -> Dict[str, callable]:
    """name → thunk exercising one executor family on tiny operands."""
    spec, spec2, algo, ch, comm = _tiny_context()
    from repro.core import sweep
    from repro.obs import Telemetry
    from repro.selection import SelectionPolicy, run_selection_sweep

    key = jax.random.PRNGKey(7)
    pols = (SelectionPolicy("uniform", participation=0.5),
            SelectionPolicy("ucb", participation=0.5, ucb_c=0.5))
    tel = Telemetry(grad_norm=True)  # every tap channel on

    def _mesh():
        from repro.dist import make_grid_mesh

        return make_grid_mesh(1)

    return {
        "runner": lambda: runner.run(algo, spec, spec.x0, ROUNDS, key),
        "runner-comm": lambda: runner.run(algo, spec, spec.x0, ROUNDS, key,
                                          comm=comm),
        "chain": lambda: ch.run(spec, spec.x0, ROUNDS, key),
        "chain-comm": lambda: ch.run(spec, spec.x0, ROUNDS, key, comm=comm),
        "sweep": lambda: sweep.run_sweep(
            algo, None, None, ROUNDS, seeds=_SEEDS, etas=_ETAS,
            problems=[spec, spec2]),
        "sweep-comm": lambda: sweep.run_sweep(
            algo, None, None, ROUNDS, seeds=_SEEDS, etas=_ETAS,
            problems=[spec, spec2], comm=comm),
        "sweep-chain": lambda: sweep.run_sweep(
            ch, None, None, ROUNDS, seeds=_SEEDS, etas=_ETAS,
            problems=[spec, spec2]),
        "sweep-chain-comm": lambda: sweep.run_sweep(
            ch, None, None, ROUNDS, seeds=_SEEDS, etas=_ETAS,
            problems=[spec, spec2], comm=comm),
        "fraction": lambda: sweep.run_fraction_sweep(
            ch, spec, spec.x0, ROUNDS, seeds=_SEEDS, fractions=(0.3, 0.6)),
        "decay": lambda: sweep.run_decay_sweep(
            ch, spec, spec.x0, ROUNDS, seeds=_SEEDS, decay_factors=(0.5,)),
        "methods": lambda: sweep.run_method_sweep(
            (type(algo)(eta=0.4, k=4, mu_avg=0.1),
             type(algo)(eta=0.4, k=4, mu_avg=0.2)),
            spec, spec.x0, ROUNDS, seeds=_SEEDS),
        "selection": lambda: run_selection_sweep(
            algo, None, None, ROUNDS, policies=pols, problems=[spec],
            seeds=_SEEDS, etas=(1.0,)),
        "selection-chain": lambda: run_selection_sweep(
            ch, None, None, ROUNDS, policies=pols, problems=[spec],
            seeds=_SEEDS, etas=(1.0,)),
        "dist": lambda: sweep.run_sweep(
            algo, None, None, ROUNDS, seeds=_SEEDS, etas=_ETAS,
            problems=[spec, spec2], mesh=_mesh()),
        "dist-chain-comm": lambda: sweep.run_sweep(
            ch, None, None, ROUNDS, seeds=_SEEDS, etas=_ETAS,
            problems=[spec, spec2], comm=comm, mesh=_mesh()),
        "dist-fraction": lambda: sweep.run_fraction_sweep(
            ch, spec, spec.x0, ROUNDS, seeds=_SEEDS, fractions=(0.3, 0.6),
            mesh=_mesh()),
        "dist-selection": lambda: run_selection_sweep(
            ch, None, None, ROUNDS, policies=pols, problems=[spec],
            seeds=_SEEDS, etas=(1.0,), mesh=_mesh()),
        # telemetry-enabled variants: the round taps ride the scan as extra
        # outputs and MUST NOT smuggle operands in as consts either
        "sweep-telemetry": lambda: sweep.run_sweep(
            algo, None, None, ROUNDS, seeds=_SEEDS, etas=_ETAS,
            problems=[spec, spec2], comm=comm, telemetry=tel),
        "sweep-chain-telemetry": lambda: sweep.run_sweep(
            ch, None, None, ROUNDS, seeds=_SEEDS, etas=_ETAS,
            problems=[spec, spec2], comm=comm, telemetry=tel),
        "selection-telemetry": lambda: run_selection_sweep(
            algo, None, None, ROUNDS, policies=pols, problems=[spec],
            seeds=_SEEDS, etas=(1.0,), telemetry=tel),
        "dist-telemetry": lambda: sweep.run_sweep(
            algo, None, None, ROUNDS, seeds=_SEEDS, etas=_ETAS,
            problems=[spec, spec2], comm=comm, mesh=_mesh(), telemetry=tel),
    }


def collect_executor_records(only: Optional[Sequence[str]] = None
                             ) -> Dict[str, list]:
    """Run the workloads with the audit sink armed; returns
    workload-name → [(cache_key, fn, args, kwargs), ...] with one record
    per distinct cache key (the first top-level call of each executor)."""
    workloads = _workloads()
    unknown = set(only or ()) - set(workloads)
    if unknown:
        raise ValueError(f"unknown audit workload(s): {sorted(unknown)}; "
                         f"valid: {sorted(workloads)}")
    out: Dict[str, list] = {}
    runner.clear_executor_cache()
    for name, thunk in workloads.items():
        if only is not None and name not in only:
            continue
        sink: list = []
        runner.AUDIT_SINK = sink
        try:
            thunk()
        finally:
            runner.AUDIT_SINK = None
        seen_keys = set()
        records = []
        for key, fn, args, kwargs in sink:
            kid = id(fn)
            if kid not in seen_keys:
                seen_keys.add(kid)
                records.append((key, fn, args, kwargs))
        out[name] = records
    return out


def _sub_jaxprs(value):
    if isinstance(value, jax.core.ClosedJaxpr):
        yield value
    elif isinstance(value, (tuple, list)):
        for v in value:
            yield from _sub_jaxprs(v)


def collect_consts(closed_jaxpr) -> List[object]:
    """Every array const reachable from the jaxpr, including inside pjit /
    scan / cond sub-jaxprs, deduplicated by object identity."""
    seen, out = set(), []

    def walk(cj):
        if id(cj) in seen:
            return
        seen.add(id(cj))
        for c in cj.consts:
            if hasattr(c, "shape") and hasattr(c, "dtype") \
                    and id(c) not in seen:
                seen.add(id(c))
                out.append(c)
        for eqn in cj.jaxpr.eqns:
            for v in eqn.params.values():
                for sub in _sub_jaxprs(v):
                    walk(sub)

    walk(closed_jaxpr)
    return out


def _const_bytes(c) -> int:
    try:
        return int(c.size) * int(jnp.dtype(c.dtype).itemsize)
    except (TypeError, ValueError):
        return 0


def audit_record(fn, args, kwargs) -> dict:
    """Re-trace one executor on its recorded operands; summarize consts."""
    closed = jax.make_jaxpr(lambda *a: fn(*a, **kwargs))(*args)
    consts = collect_consts(closed)
    sizes = sorted((_const_bytes(c) for c in consts), reverse=True)
    return {
        "n_consts": len(consts),
        "const_bytes": int(sum(sizes)),
        "max_const_bytes": int(sizes[0]) if sizes else 0,
    }


def run_audit(only: Optional[Sequence[str]] = None,
              ceiling: int = CONST_BYTE_CEILING
              ) -> Tuple[dict, List[str]]:
    """(report, failures). ``report['families']`` maps each audited
    executor family to its const summary; a family fails when its TOTAL
    array-const bytes exceed ``ceiling``."""
    records = collect_executor_records(only=only)
    families: Dict[str, dict] = {}
    failures: List[str] = []
    for workload, recs in records.items():
        if not recs:
            failures.append(
                f"{workload}: no executor call recorded — the audit sink "
                f"saw nothing (workload bypassed the executor cache?)")
            continue
        for i, (key, fn, args, kwargs) in enumerate(recs):
            name = f"{workload}/{key[0]}" if isinstance(
                key, tuple) and key else workload
            if name in families:
                name = f"{name}#{i}"
            summary = audit_record(fn, args, kwargs)
            families[name] = summary
            if summary["const_bytes"] > ceiling:
                failures.append(
                    f"{name}: {summary['const_bytes']} bytes of array "
                    f"consts baked into the traced executor (ceiling "
                    f"{ceiling}) — an operand is being captured by closure")
    report = {
        "const_ceiling_bytes": int(ceiling),
        "rounds": ROUNDS,
        "families": families,
        "total_const_bytes": int(sum(
            f["const_bytes"] for f in families.values())),
    }
    return report, failures
