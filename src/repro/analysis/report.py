"""Report assembly for the analyzer: console text + BENCH_analysis.json.

The JSON document is the machine-readable artifact the bench-regression
gate consumes: per-family const bytes (Layer 2), per-rule violation counts
(unsuppressed — must all be zero for the tree to pass), and the per-rule
suppression inventory (visible debt)."""
from __future__ import annotations

import json
from typing import Dict, List, Optional

from repro.analysis.lint.base import Violation


def split_violations(violations: List[Violation]):
    """(unsuppressed, suppressed)."""
    active = [v for v in violations if not v.suppressed]
    suppressed = [v for v in violations if v.suppressed]
    return active, suppressed


def rule_counts(violations: List[Violation]) -> Dict[str, int]:
    out: Dict[str, int] = {}
    for v in violations:
        out[v.rule] = out.get(v.rule, 0) + 1
    return out


def build_report(violations: Optional[List[Violation]],
                 suppression_inventory: Dict[str, int],
                 audit_report: Optional[dict]) -> dict:
    active, suppressed = split_violations(violations or [])
    doc = {
        "lint": {
            "violations": rule_counts(active),
            "suppressed": rule_counts(suppressed),
            "suppression_inventory": dict(sorted(
                suppression_inventory.items())),
        },
    }
    if audit_report is not None:
        doc["audit"] = audit_report
    return doc


def write_json(doc: dict, path: str) -> None:
    with open(path, "w") as f:
        json.dump(doc, f, indent=1, sort_keys=True)
        f.write("\n")


def format_console(violations: Optional[List[Violation]],
                   suppression_inventory: Dict[str, int],
                   audit_report: Optional[dict],
                   audit_failures: Optional[List[str]],
                   verbose: bool = False) -> str:
    lint_ran = violations is not None
    active, suppressed = split_violations(violations or [])
    lines = [v.format() for v in sorted(
        active, key=lambda v: (v.path, v.line, v.rule))]
    if verbose:
        lines += [v.format() for v in sorted(
            suppressed, key=lambda v: (v.path, v.line, v.rule))]
    if audit_failures:
        lines += [f"audit: {f}" for f in audit_failures]
    summary = []
    if lint_ran:
        summary.append(f"lint: {len(active)} unsuppressed violation(s), "
                       f"{len(suppressed)} suppressed")
    if suppression_inventory:
        inv = ", ".join(f"{r}×{n}" for r, n in sorted(
            suppression_inventory.items()))
        summary.append(f"suppression inventory: {inv}")
    if audit_report is not None:
        fams = audit_report["families"]
        summary.append(
            f"audit: {len(fams)} executor families, "
            f"{audit_report['total_const_bytes']} total const bytes "
            f"(ceiling {audit_report['const_ceiling_bytes']}/family), "
            f"{len(audit_failures or [])} failure(s)")
    return "\n".join(lines + summary)
