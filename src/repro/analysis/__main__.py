"""``python -m repro.analysis`` → the analyzer CLI (see ``cli.py``)."""
import sys

from repro.analysis.cli import main

sys.exit(main())
