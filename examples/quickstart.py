"""Quickstart: FedChain (Algo 1) on an exactly-ζ-controlled federated
quadratic — reproduces the paper's core claim in ~30 seconds on CPU.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax

from repro.core import algorithms as A, chain, runner, theory
from repro.data import problems


def main():
    # a strongly convex federated problem with moderate heterogeneity
    p = problems.quadratic_problem(
        jax.random.PRNGKey(0), num_clients=8, dim=16, mu=0.1, beta=1.0,
        zeta=2.0, sigma=0.5, sigma_f=0.05)
    x0 = p.init_params(jax.random.PRNGKey(0))
    rounds, k = 60, 32
    print(f"problem: {p.name}  Δ={p.delta(x0):.2f}  κ={p.kappa():.0f}  R={rounds}")

    fedavg = A.FedAvg.from_k(k, eta=0.3)
    sgd = A.SGD(eta=0.3, k=k, mu_avg=p.mu)
    asg = A.NesterovSGD(eta=0.2, mu=p.mu, beta=p.beta, k=k)

    results = {}
    for name, algo in [("FedAvg", fedavg), ("SGD", sgd), ("ASG", asg)]:
        res = runner.run(algo, p, x0, rounds, jax.random.PRNGKey(1))
        results[name] = float(res.history[-1])

    for name, glob in [("FedAvg->SGD", sgd), ("FedAvg->ASG", asg)]:
        ch = chain.fedchain(fedavg, glob, selection_k=k)
        res = ch.run(p, x0, rounds, jax.random.PRNGKey(1))
        results[name] = float(p.suboptimality(res.x_hat))

    c = theory.Constants(delta=p.delta(x0), d=p.dist_sq(x0) ** 0.5, mu=p.mu,
                         beta=p.beta, zeta=p.zeta, sigma=p.sigma, n=8, s=8, k=k)
    print(f"\n{'method':>14s} {'F(x̂)−F*':>12s}")
    for name, sub in sorted(results.items(), key=lambda kv: kv[1]):
        print(f"{name:>14s} {sub:12.3e}")
    print(f"\nalgorithm-independent lower bound (Thm 5.4): "
          f"{theory.lower_bound_strongly_convex(c, rounds):.3e}")
    best_chain = min(results["FedAvg->SGD"], results["FedAvg->ASG"])
    best_base = min(results["FedAvg"], results["SGD"], results["ASG"])
    print(f"chaining gain vs best single method: {best_base / best_chain:.1f}x")


if __name__ == "__main__":
    main()
