"""Batched serving example: prefill + autoregressive decode with KV caches /
SSM states, across architecture families (dense GQA, MLA, SSM, hybrid,
enc-dec, VLM).

  PYTHONPATH=src python examples/serve_batch.py
"""
from repro.configs import registry
from repro.launch.serve import serve


def main():
    for arch in ["qwen3-14b", "minicpm3-4b", "mamba2-1.3b", "zamba2-1.2b",
                 "seamless-m4t-medium", "paligemma-3b"]:
        cfg = registry.get_config(arch, smoke=True)
        res = serve(cfg, batch=2, prompt_len=32, gen=8)
        print(f"{arch:24s} generated {tuple(res['tokens'].shape)} tokens, "
              f"prefill {res['prefill_s']*1e3:.0f} ms, "
              f"{res['decode_tok_per_s']:.0f} tok/s (CPU, reduced config)")


if __name__ == "__main__":
    main()
