"""Nonconvex federated image classification (the paper's §6 EMNIST-style
experiment on the offline synthetic stand-in): FedChain vs FedAvg vs SGD with
partial participation.

  PYTHONPATH=src python examples/federated_vision.py [--rounds 40]
"""
import argparse

import jax

from repro.core import algorithms as A, chain, runner
from repro.data.vision_problem import make_vision_problem


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=40)
    ap.add_argument("--clients", type=int, default=10)
    ap.add_argument("--sampled", type=int, default=3)
    ap.add_argument("--homogeneous", type=float, default=0.3)
    args = ap.parse_args(argv)

    p, accuracy, init = make_vision_problem(
        jax.random.PRNGKey(0), num_clients=args.clients,
        homogeneous_frac=args.homogeneous, num_classes=2 * args.clients,
        per_class=80, hidden=32)
    x0 = init(jax.random.PRNGKey(1))
    s = args.sampled
    fa = A.FedAvg(eta=0.2, local_steps=5, inner_batch=4, s=s)
    sgd = A.SGD(eta=0.2, k=20, output_mode="last", s=s)
    print(f"{args.clients} clients (S={s}/round), "
          f"{args.homogeneous:.0%} homogeneous, R={args.rounds}")

    rows = {}
    for name, algo in [("SGD", sgd), ("FedAvg", fa)]:
        res = runner.run(algo, p, x0, args.rounds, jax.random.PRNGKey(2))
        rows[name] = float(accuracy(algo.output(res.state)))
    ch = chain.fedchain(fa, sgd, selection_k=20, selection_s=s)
    res = ch.run(p, x0, args.rounds, jax.random.PRNGKey(2))
    rows["FedAvg->SGD"] = float(accuracy(res.x_hat))

    print(f"\n{'method':>12s} {'accuracy':>9s}")
    for name, acc in rows.items():
        print(f"{name:>12s} {acc:9.4f}")


if __name__ == "__main__":
    main()
