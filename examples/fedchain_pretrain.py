"""End-to-end driver (deliverable b): pretrain a small LM for a few hundred
steps with FedChain as the distributed-training schedule — local rounds with
per-client replicas, Lemma H.2 selection, then synchronous steps.

Any of the 10 assigned architectures is selectable via --arch (reduced
variant). On CPU this runs in a few minutes at the default size.

  PYTHONPATH=src python examples/fedchain_pretrain.py --arch qwen3-14b --steps 200
"""
import argparse

from repro.launch import train as train_lib


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-14b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--clients", type=int, default=4)
    args = ap.parse_args(argv)
    local_budget = args.steps // 2
    local_steps = 8
    return train_lib.main([
        "--arch", args.arch, "--smoke", "--steps", str(args.steps),
        "--batch", "4", "--seq", "128", "--lr", "0.3",
        "--fl-mode", "fedchain", "--clients", str(args.clients),
        "--local-steps", str(local_steps),
        "--local-rounds", str(max(1, local_budget // local_steps)),
        "--heterogeneity", "1.0", "--log-every", "20",
    ])


if __name__ == "__main__":
    main()
