"""A ζ-grid in one compile: problems as executor operands.

FedChain's experiments sweep the same methods over heterogeneity levels ζ.
With the ProblemSpec API (``repro.data.spec``) a problem is a pytree of
arrays, so a whole ζ-grid is just a stacked spec riding into ONE compiled
``run_sweep`` call — seeds × stepsizes × ζ, with ``runner.TRACE_COUNTS``
proving each executor traced exactly once.

  PYTHONPATH=src python examples/problem_sweep.py
"""
import jax
import numpy as np

from repro.core import algorithms as A, chain, runner, sweep
from repro.data import problems


def main():
    zetas = (0.2, 1.0, 5.0)
    seeds = (0, 1, 2)
    eta_mults = (0.5, 1.0, 2.0)
    rounds = 60

    # one spec per heterogeneity level — same family, same shapes, so they
    # stack into a single batched problem operand
    specs = [problems.quadratic_spec(
        jax.random.PRNGKey(0), num_clients=8, dim=16, mu=0.1, beta=1.0,
        zeta=z, sigma=0.2, sigma_f=0.05) for z in zetas]

    k = 32
    fedavg = A.FedAvg.from_k(k, eta=0.5)
    sgd = A.SGD(eta=0.5, k=k, mu_avg=0.1)
    fedchain = chain.fedchain(fedavg, sgd, selection_k=k)

    print(f"grid: {len(zetas)} ζ × {len(seeds)} seeds × {len(eta_mults)} η "
          f"multipliers, {rounds} rounds\n")
    for name, algo in (("SGD", sgd), ("FedAvg->SGD", fedchain)):
        before = dict(runner.TRACE_COUNTS)
        res = sweep.run_sweep(algo, None, None, rounds, seeds=seeds,
                              etas=eta_mults, eta_mode="scale",
                              problems=specs)  # x0=None: each spec's own x0
        traces = {key: v - before.get(key, 0)
                  for key, v in runner.TRACE_COUNTS.items()
                  if v != before.get(key, 0)}
        final = np.asarray(res.final_sub)  # [ζ, seed, η]
        print(f"{name}: executor traces for the whole grid = {traces}")
        for i, z in enumerate(zetas):
            med = np.median(final[i], axis=0)  # [η]
            best = int(np.argmin(med))
            print(f"  ζ={z:<4}  best η-mult={eta_mults[best]:<4} "
                  f" median F(x̂)−F* = {med[best]:.3e}")
        print()

    # a second, fresh grid (new instances, same shapes) reuses the compiles
    before = dict(runner.TRACE_COUNTS)
    specs2 = [problems.quadratic_spec(
        jax.random.PRNGKey(9), num_clients=8, dim=16, mu=0.1, beta=1.0,
        zeta=z, sigma=0.2, sigma_f=0.05) for z in zetas]
    sweep.run_sweep(sgd, None, None, rounds, seeds=seeds, etas=eta_mults,
                    eta_mode="scale", problems=specs2)
    assert dict(runner.TRACE_COUNTS) == before, "fresh instances re-traced!"
    print("fresh same-shaped instances: 0 new traces (operand problems)")


if __name__ == "__main__":
    main()
