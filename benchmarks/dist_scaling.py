"""Distributed sweep scaling: the sharded grid engine on 1/2/4/8 devices.

XLA locks the host device count at first JAX init, so each device count
runs in its OWN subprocess (``--worker-devices``) with
``--xla_force_host_platform_device_count=N``; the parent collects one JSON
record per count into ``BENCH_dist.json``:

* ``warm_s`` / ``cell_rounds_per_s`` — warm-path time (min over reps) and
  throughput of ``run_sweep(..., mesh=...)`` on a ≥32-cell problems × seeds
  grid (cells × stepsizes × rounds per second);
* ``speedup_vs_1`` and ``efficiency`` — speedup over the 1-device sharded
  run, and that speedup normalized by min(devices, host cores): fake host
  devices beyond the physical core count cannot add compute, so efficiency
  is reported against what the HOST can deliver (``host_cores`` is in the
  record — judge 8-device numbers on ≥8-core machines);
* every worker also asserts the dist invariants: bitwise equality with the
  vmapped single-device engine, exactly one trace per sharded executor,
  and zero warm re-traces — a scaling number from a silently re-tracing or
  numerically divergent run would be worthless.

  PYTHONPATH=src python -m benchmarks.dist_scaling            # parent
  PYTHONPATH=src python -m benchmarks.run --only dist_scaling
"""
from __future__ import annotations

import argparse
import json
import os
import re
import subprocess
import sys
import time

ROOT = os.path.join(os.path.dirname(__file__), "..")
DEVICE_COUNTS = (1, 2, 4, 8)


def _grid_config(quick: bool):
    return {
        "n_problems": 8, "n_seeds": 4,  # 32 cells (acceptance floor)
        "etas": (0.3, 0.5),
        "rounds": 40 if quick else 160,
        "num_clients": 10, "dim": 64, "k": 8,
        "reps": 3 if quick else 5,
    }


def _worker(devices: int, quick: bool) -> None:
    """Runs inside the subprocess: measure one device count, print JSON."""
    import jax
    import numpy as np

    from repro.core import algorithms as A, runner, sweep
    from repro.data import spec as spec_lib
    from repro.dist import make_grid_mesh

    cfg = _grid_config(quick)
    assert len(jax.devices()) == devices, (jax.devices(), devices)
    mesh = make_grid_mesh(devices)
    specs = [
        spec_lib.quadratic_spec(
            jax.random.PRNGKey(7), num_clients=cfg["num_clients"],
            dim=cfg["dim"], mu=0.1, beta=1.0, zeta=0.25 * i, sigma=0.2,
            sigma_f=0.05)
        for i in range(cfg["n_problems"])
    ]
    seeds = tuple(range(cfg["n_seeds"]))
    algo = A.SGD(eta=0.4, k=cfg["k"], mu_avg=0.1)
    kw = dict(seeds=seeds, etas=cfg["etas"], problems=specs)
    rounds = cfg["rounds"]

    def block(res):
        jax.block_until_ready(res.history)
        return res

    # vmapped reference: cold + warm (and the bitwise parity target)
    t0 = time.perf_counter()
    ref = block(sweep.run_sweep(algo, None, None, rounds, **kw))
    vmapped_cold = time.perf_counter() - t0
    vmapped_warm = min(
        _timed(lambda: block(sweep.run_sweep(algo, None, None, rounds, **kw)))
        for _ in range(cfg["reps"]))

    before = runner.snapshot_traces()
    t0 = time.perf_counter()
    res = block(sweep.run_sweep(algo, None, None, rounds, mesh=mesh, **kw))
    cold_s = time.perf_counter() - t0
    deltas = runner.trace_deltas(before)
    if deltas.get("dist-probs/sgd") != 1:
        raise AssertionError(f"sharded executor traced != once: {deltas}")
    if not np.array_equal(np.asarray(ref.history), np.asarray(res.history)):
        raise AssertionError("sharded sweep diverged from vmapped engine")

    with runner.assert_no_retrace(what="warm sharded re-runs"):
        warm_s = min(
            _timed(lambda: block(
                sweep.run_sweep(algo, None, None, rounds, mesh=mesh, **kw)))
            for _ in range(cfg["reps"]))

    n_cells = cfg["n_problems"] * cfg["n_seeds"]
    lanes = n_cells * len(cfg["etas"])
    print(json.dumps({
        "devices": devices,
        "cold_s": cold_s, "warm_s": warm_s,
        "vmapped_cold_s": vmapped_cold, "vmapped_warm_s": vmapped_warm,
        "cell_rounds_per_s": lanes * rounds / warm_s,
    }))


def _timed(fn) -> float:
    t0 = time.perf_counter()
    fn()
    return time.perf_counter() - t0


def _spawn(devices: int, quick: bool) -> dict:
    env = dict(os.environ)
    flags = re.sub(r"--xla_force_host_platform_device_count=\d+", "",
                   env.get("XLA_FLAGS", "")).strip()
    env["XLA_FLAGS"] = (
        f"{flags} --xla_force_host_platform_device_count={devices}".strip())
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(ROOT, "src"), ROOT, env.get("PYTHONPATH", "")])
    env.pop("REPRO_DIST_DEVICES", None)  # the worker builds its own mesh
    cmd = [sys.executable, "-m", "benchmarks.dist_scaling",
           "--worker-devices", str(devices)]
    if not quick:
        cmd.append("--full")
    out = subprocess.run(cmd, capture_output=True, text=True, env=env,
                         timeout=1800, cwd=ROOT)
    if out.returncode != 0:
        raise RuntimeError(
            f"dist_scaling worker (devices={devices}) failed:\n"
            f"{out.stderr[-3000:]}")
    return json.loads(out.stdout.strip().splitlines()[-1])


def main(quick: bool = True):
    from benchmarks.common import emit

    cfg = _grid_config(quick)
    cores = os.cpu_count() or 1
    records = {d: _spawn(d, quick) for d in DEVICE_COUNTS}
    base = records[1]["warm_s"]
    report = {
        "grid": {k: v for k, v in cfg.items()},
        "host_cores": cores,
        "devices": {},
    }
    rows = []
    for d, rec in records.items():
        speedup = base / rec["warm_s"]
        # fake host devices beyond physical cores cannot add compute
        efficiency = speedup / min(d, cores)
        report["devices"][str(d)] = {
            **rec, "speedup_vs_1": speedup, "efficiency": efficiency}
        rows.append(emit(
            f"dist_scaling/devices={d}", rec["warm_s"] * 1e6,
            f"speedup={speedup:.2f}x;eff={efficiency:.2f};"
            f"cell_rounds_per_s={rec['cell_rounds_per_s']:.0f}"))
    report["speedup_at_max_devices"] = (
        base / records[max(DEVICE_COUNTS)]["warm_s"])
    with open(os.path.join(ROOT, "BENCH_dist.json"), "w") as f:
        json.dump(report, f, indent=2)
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--worker-devices", type=int, default=0)
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    if args.worker_devices:
        _worker(args.worker_devices, quick=not args.full)
    else:
        main(quick=not args.full)
