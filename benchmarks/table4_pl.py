"""Paper Table 4 — PL-condition rates, on the nonconvex-but-PL perturbed
problem (x² + 3sin²x base). Derived: final F(x̂) − F*.

The full-participation ζ values ride the problem axis — one vmapped
``run_sweep(problems=...)`` call per method; the S < N regime keeps its own
per-call grid (participation is a method hyperparameter there)."""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import emit, timed
from repro.core import algorithms as A, chain, sweep, theory
from repro.data import problems

ZETAS_FULL = (0.5, 2.0)


def _methods(s):
    k = 32
    fa = A.FedAvg.from_k(k, eta=0.05, s=s)
    sgd = A.SGD(eta=0.05, k=k, output_mode="last", s=s)
    saga = A.SAGA(eta=0.05, k=k, output_mode="last", s=s)
    return k, {
        "sgd": sgd,
        "fedavg": fa,
        "fedavg->sgd": chain.fedchain(fa, sgd, selection_k=k, selection_s=s),
        "fedavg->saga": chain.fedchain(fa, saga, selection_k=k, selection_s=s),
    }


def _constants(p, x0, k, s):
    return theory.Constants(
        delta=p.delta(x0), d=3.0, mu=float(p.mu), beta=float(p.beta),
        zeta=float(p.zeta), sigma=float(p.sigma), n=8, s=s or 8, k=k)


def main(quick: bool = True):
    rounds = 80 if quick else 250
    seeds = (0, 1, 2)
    rows = []

    # full participation: the ζ grid is one problems-axis sweep per method
    specs = [problems.pl_spec(jax.random.PRNGKey(0), num_clients=8,
                              zeta=z, sigma=0.1, dim=8) for z in ZETAS_FULL]
    x0 = specs[0].x0
    k, algos = _methods(0)
    consts = [_constants(p, x0, k, 0) for p in specs]
    for name, algo in algos.items():
        res, us = timed(lambda: sweep.run_sweep(
            algo, None, x0, rounds, seeds=seeds, etas=(1.0,),
            eta_mode="scale", problems=specs))
        final = np.asarray(res.final_sub)  # [P, S, 1]
        bound = theory.TABLE4.get(name)
        for i, zeta in enumerate(ZETAS_FULL):
            med = float(np.median(final[i, :, 0]))
            bound_s = f"{bound(consts[i], rounds):.3e}" if bound else ""
            rows.append(emit(f"table4/{name}/zeta={zeta},S=8",
                             us / len(ZETAS_FULL),
                             f"sub={med:.3e};bound={bound_s}"))
    for i, zeta in enumerate(ZETAS_FULL):
        rows.append(emit(f"table4/lower_bound/zeta={zeta},S=8", 0.0,
                         f"bound={theory.lower_bound_pl(consts[i], rounds):.3e}"))

    # partial participation (S = 2 of 8)
    zeta, s = 0.5, 2
    p = problems.pl_spec(jax.random.PRNGKey(0), num_clients=8, zeta=zeta,
                         sigma=0.1, dim=8)
    x0 = p.x0
    k, algos = _methods(s)
    c = _constants(p, x0, k, s)
    for name, algo in algos.items():
        res, us = timed(lambda: sweep.run_sweep(
            algo, p, x0, rounds, seeds=seeds, etas=(1.0,), eta_mode="scale"))
        med = float(np.median(np.asarray(res.final_sub)[:, 0]))
        bound = theory.TABLE4.get(name)
        bound_s = f"{bound(c, rounds):.3e}" if bound else ""
        rows.append(emit(f"table4/{name}/zeta={zeta},S={s}", us,
                         f"sub={med:.3e};bound={bound_s}"))
    rows.append(emit(f"table4/lower_bound/zeta={zeta},S={s}", 0.0,
                     f"bound={theory.lower_bound_pl(c, rounds):.3e}"))
    return rows


if __name__ == "__main__":
    main()
