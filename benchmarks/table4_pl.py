"""Paper Table 4 — PL-condition rates, on the nonconvex-but-PL perturbed
problem (x² + 3sin²x base). Derived: final F(x̂) − F*.

Seeds run as one vmapped ``run_sweep`` call per method."""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import emit, timed
from repro.core import algorithms as A, chain, sweep, theory
from repro.data import problems


def main(quick: bool = True):
    rounds = 80 if quick else 250
    seeds = (0, 1, 2)
    rows = []
    for zeta, s in ((0.5, 0), (2.0, 0), (0.5, 2)):
        p = problems.pl_problem(jax.random.PRNGKey(0), num_clients=8,
                                zeta=zeta, sigma=0.1, dim=8)
        x0 = p.init_params(jax.random.PRNGKey(0))
        k = 32
        fa = A.FedAvg.from_k(k, eta=0.05, s=s)
        sgd = A.SGD(eta=0.05, k=k, output_mode="last", s=s)
        saga = A.SAGA(eta=0.05, k=k, output_mode="last", s=s)
        algos = {
            "sgd": sgd,
            "fedavg": fa,
            "fedavg->sgd": chain.fedchain(fa, sgd, selection_k=k, selection_s=s),
            "fedavg->saga": chain.fedchain(fa, saga, selection_k=k, selection_s=s),
        }
        c = theory.Constants(
            delta=p.delta(x0), d=3.0, mu=p.mu, beta=p.beta, zeta=zeta,
            sigma=p.sigma, n=8, s=s or 8, k=k)
        tag = f"zeta={zeta},S={s or 8}"
        for name, algo in algos.items():
            res, us = timed(lambda: sweep.run_sweep(
                algo, p, x0, rounds, seeds=seeds, etas=(1.0,),
                eta_mode="scale"))
            med = float(np.median(np.asarray(res.final_sub)[:, 0]))
            bound = theory.TABLE4.get(name)
            bound_s = f"{bound(c, rounds):.3e}" if bound else ""
            rows.append(emit(f"table4/{name}/{tag}", us,
                             f"sub={med:.3e};bound={bound_s}"))
        rows.append(emit(f"table4/lower_bound/{tag}", 0.0,
                         f"bound={theory.lower_bound_pl(c, rounds):.3e}"))
    return rows


if __name__ == "__main__":
    main()
