"""Paper Figure 2 — convex logistic regression convergence across
heterogeneity levels (0% / 50% / 100% homogeneous shuffling), R=100 rounds,
all clients participating, K=20 (paper §6 setup).

The heterogeneity axis is a PROBLEM OPERAND: the three shuffling levels are
same-shaped ``logreg_spec``s, so ALL levels × the stepsize-tuning grid run
as ONE vmapped ``run_sweep(problems=...)`` call per method (per the paper's
App. I.1 protocol every method's stepsize is tuned over a small grid; the
best-final curve per level is kept). Logreg F* is Newton-solved, so curves
are TRUE suboptimality F(x) − F*, not raw loss.

Writes per-round curves to experiments/fig2_curves.csv; derived column:
final suboptimality + gradient norm of the tuned run."""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, timed
from repro.core import algorithms as A, chain, sweep, tree_math as tm
from repro.data import partition, problems, synthetic_vision

OUT = os.path.join(os.path.dirname(__file__), "..", "experiments")

HOMS = (0.0, 0.5, 1.0)


def build_logreg(homogeneous_frac: float, seed: int = 0):
    data = synthetic_vision.make_prototype_images(
        num_classes=10, per_class=100, side=12, seed=seed)
    cx, cy = partition.shuffled_heterogeneity(
        data, homogeneous_frac=homogeneous_frac, num_clients=5, seed=seed)
    labels = synthetic_vision.binary_labels_even_odd(cy)
    return problems.logreg_spec(
        jax.random.PRNGKey(seed), features=jnp.asarray(cx),
        labels=jnp.asarray(labels), l2=0.1, oracle_batch_frac=0.01)


ETAS = (0.1, 0.5, 2.0)  # stepsize multipliers on each method's base η


def method_specs(p, k):
    """Methods at base stepsizes chosen so the ETAS multipliers reproduce the
    seed grid (e.g. ASG ran at η/2 → base 0.5)."""
    mu, beta = float(p.mu), float(p.beta)
    fa = A.FedAvg(eta=1.0, local_steps=4, inner_batch=5)
    sgd = A.SGD(eta=1.0, k=k, mu_avg=mu, output_mode="last")
    asg = A.NesterovSGD(eta=0.5, mu=mu, beta=beta, k=k)
    scaffold = A.Scaffold(eta=1.0, local_steps=4, inner_batch=5)
    return {
        "sgd": sgd,
        "asg": asg,
        "fedavg": fa,
        "scaffold": scaffold,
        "fedavg->sgd": chain.fedchain(fa, sgd, selection_k=k),
        "fedavg->asg": chain.fedchain(fa, asg, selection_k=k),
        "scaffold->sgd": chain.fedchain(scaffold, sgd, selection_k=k),
    }


def main(quick: bool = True):
    rounds = 40 if quick else 100
    k = 20
    rows = []
    curves = {}
    specs = [build_logreg(hom) for hom in HOMS]
    x0 = specs[0].x0  # logreg initializes at 0 for every level
    for name, algo in method_specs(specs[0], k).items():
        res, us = timed(lambda: sweep.run_sweep(
            algo, None, x0, rounds, seeds=(5,), etas=ETAS,
            eta_mode="scale", problems=specs))
        hist_all = np.asarray(res.history)  # [P, 1, E, R]
        final_all = np.asarray(res.final_sub)
        for i, hom in enumerate(HOMS):
            p = specs[i]
            finite = np.where(np.isfinite(final_all[i, 0]),
                              final_all[i, 0], np.inf)
            if not np.isfinite(finite).any():
                # mirror sweep.best_cell's guard: a nan/inf run must never
                # be mistaken for a tuned result
                raise ValueError(
                    f"fig2/{name}/hom={hom}: every stepsize multiplier "
                    f"diverged over etas={ETAS}")
            ei = int(np.argmin(finite))
            hist = hist_all[i, 0, ei]
            final = float(hist[-1])
            x_hat = jax.tree.map(lambda t: t[i, 0, ei], res.x_hat)
            gnorm = float(tm.tree_norm(p.global_grad(x_hat)))
            curves[f"hom={hom}/{name}"] = hist
            rows.append(emit(f"fig2/{name}/hom={hom}", us / len(HOMS),
                             f"sub={final:.4f};gnorm={gnorm:.3e}"))

    os.makedirs(OUT, exist_ok=True)
    path = os.path.join(OUT, "fig2_curves.csv")
    with open(path, "w") as f:
        f.write("curve,round,sub\n")
        for name, hist in curves.items():
            for r, v in enumerate(hist):
                f.write(f"{name},{r},{v}\n")
    return rows


if __name__ == "__main__":
    main()
