"""Paper Table 3 — nonconvex neural-network classification accuracy
(EMNIST/CIFAR stand-in: synthetic prototype images, MLP classifier,
partial participation S=3 of 10 clients at quick scale).

Mirrors the paper's protocol (App. I.2): every method's stepsize — and for
chains the switch fraction — is tuned on a small grid, and the best
configuration's accuracy is reported. Derived: tuned final accuracy.
"""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import emit, timed
from repro.core import algorithms as A, chain, runner
from repro.data.vision_problem import make_vision_problem

ETAS = (0.2, 0.5)
FRACTIONS = (0.5, 0.8)


def _acc_of(algo_or_chain, p, accuracy, x0, rounds, seeds=2):
    accs, us = [], 0.0
    for seed in range(seeds):
        if isinstance(algo_or_chain, chain.Chain):
            res, us = timed(lambda sd=seed: algo_or_chain.run(
                p, x0, rounds, jax.random.PRNGKey(10 + sd)))
            accs.append(float(accuracy(res.x_hat)))
        else:
            res, us = timed(lambda sd=seed: runner.run(
                algo_or_chain, p, x0, rounds, jax.random.PRNGKey(10 + sd)))
            accs.append(float(accuracy(algo_or_chain.output(res.state))))
    return float(np.median(accs)), us


def main(quick: bool = True):
    rounds = 60 if quick else 200
    num_clients, s = 10, 3
    rows = []
    p, accuracy, init = make_vision_problem(
        jax.random.PRNGKey(0), num_clients=num_clients, homogeneous_frac=0.3,
        num_classes=2 * num_clients, per_class=80, hidden=32, batch=32)
    x0 = init(jax.random.PRNGKey(1))

    def fa(eta):
        return A.FedAvg(eta=eta, local_steps=5, inner_batch=4, s=s)

    def sgd(eta):
        return A.SGD(eta=eta, k=20, output_mode="last", s=s)

    def scaffold(eta):
        return A.Scaffold(eta=eta, local_steps=5, inner_batch=4, s=s)

    def tune(builders):
        best = (-1.0, 0.0, None)
        for cand in builders:
            acc, us = _acc_of(cand, p, accuracy, x0, rounds)
            if acc > best[0]:
                best = (acc, us, cand)
        return best

    singles = {
        "sgd": [sgd(e) for e in ETAS],
        "fedavg": [fa(e) for e in ETAS],
        "scaffold": [scaffold(e) for e in ETAS],
    }
    for name, cands in singles.items():
        acc, us, _ = tune(cands)
        rows.append(emit(f"table3/{name}", us, f"acc={acc:.4f}"))

    chains = {
        "fedavg->sgd": [
            chain.fedchain(fa(e), sgd(e2), local_fraction=f,
                           selection_k=20, selection_s=s)
            for e in ETAS for e2 in ETAS for f in FRACTIONS],
        "scaffold->sgd": [
            chain.fedchain(scaffold(e), sgd(e2), local_fraction=f,
                           selection_k=20, selection_s=s)
            for e in ETAS for e2 in ETAS for f in FRACTIONS],
    }
    for name, cands in chains.items():
        acc, us, _ = tune(cands)
        rows.append(emit(f"table3/{name}", us, f"acc={acc:.4f}"))
    return rows


if __name__ == "__main__":
    main()
