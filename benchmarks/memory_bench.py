"""Operand-memory benchmark: indexed vs stacked problem-operand layouts.

The sweep engine's problems × seeds grid used to repeat every ProblemSpec
data leaf once per seed (O(P·S) operand memory). The indexed layout carries
ONE O(P) stacked spec plus a per-cell int32 problem index and gathers spec
leaves in-cell — bitwise identical results (asserted here and in
``tests/test_memory_layout.py``). This harness measures, on a data-heavy
problem grid:

* spec-operand live bytes under each layout (``sum(leaf.nbytes)`` over the
  exact arrays the executor call carries) and their ratio — the ISSUE-6
  acceptance bar is a ≥ S× reduction,
* warm grid wall time per layout (the indexed gather must not cost the warm
  path anything past the regression gate's 2.5× threshold),
* zero warm re-traces under the indexed layout (``runner.TRACE_COUNTS``).

Writes ``BENCH_memory.json`` at the repo root. ``--check`` asserts the
backend-robust invariants (byte reduction, warm ratio, retrace count,
bitwise identity) without absolute-time gates — the CI miniature.

  PYTHONPATH=src python -m benchmarks.memory_bench [--check]
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.core import algorithms as A, runner, sweep
from repro.data import problems

ROOT = os.path.join(os.path.dirname(__file__), "..")

SEEDS = tuple(range(6))  # S=6: the reduction bar scales with the seed count
ETAS = (0.3, 0.5)


def _specs(quick: bool):
    """A data-heavy ζ grid: quadratic specs whose per-client data leaves
    ([N, d, d] Hessians) dominate the operand footprint."""
    dim = 48 if quick else 96
    return [
        problems.quadratic_spec(
            jax.random.PRNGKey(17 + i), num_clients=8, dim=dim, mu=0.1,
            beta=1.0, zeta=0.5 * i, sigma=0.2)
        for i in range(4)
    ]


def operand_bytes(stacked, x0_stack, keys, n_probs, n_seeds, layout):
    """(spec-operand bytes, index-overhead bytes) of one grid call's
    per-problem operands: the spec stack + x0 stack (whose every leaf the
    stacked layout repeats exactly S×), and the int32 problem-index rows the
    indexed layout adds (4 bytes per cell — the price of the gather). Key
    rows are identical across layouts and excluded."""
    spec_op, x0_op, pidx, _ = sweep.build_problem_operands(
        stacked, x0_stack, keys, n_probs, n_seeds, layout)
    spec_bytes = sum(l.nbytes for l in jax.tree.leaves((spec_op, x0_op)))
    return int(spec_bytes), int(pidx.nbytes if pidx is not None else 0)


def _walled(fn):
    t0 = time.perf_counter()
    out = fn()
    jax.block_until_ready(out.history)
    return out, time.perf_counter() - t0


def main(quick: bool = True, check: bool = False):
    rounds = 20 if quick else 80
    specs = _specs(quick)
    algo = A.SGD(eta=0.4, k=8, mu_avg=0.1)
    n_probs, n_seeds = len(specs), len(SEEDS)

    stacked, _ = sweep._as_stacked_specs(specs)
    x0_stack = sweep._normalize_x0_stack(None, stacked, n_probs)
    keys = jnp.stack([jax.random.PRNGKey(s) for s in SEEDS])
    bytes_by_layout, idx_bytes = {}, 0
    for layout in sweep._OPERAND_LAYOUTS:
        bytes_by_layout[layout], pb = operand_bytes(
            stacked, x0_stack, keys, n_probs, n_seeds, layout)
        idx_bytes = max(idx_bytes, pb)
    reduction = bytes_by_layout["stacked"] / bytes_by_layout["indexed"]

    def grid(layout):
        return sweep.run_sweep(
            algo, specs[0], None, rounds, seeds=SEEDS, etas=ETAS,
            eta_mode="absolute", problems=specs, operand_layout=layout)

    results, warm = {}, {}
    runner.clear_executor_cache()  # each layout pays its own cold compile
    for layout in sweep._OPERAND_LAYOUTS:
        _walled(lambda: grid(layout))  # compile
        results[layout], warm[layout] = _walled(lambda: grid(layout))

    match = bool(np.array_equal(np.asarray(results["indexed"].history),
                                np.asarray(results["stacked"].history)))
    if not match:
        raise AssertionError(
            "indexed-layout sweep results diverged bitwise from the stacked "
            "reference layout")

    # warm re-trace discipline: repeating the indexed grid must not move
    # TRACE_COUNTS by a single trace
    with runner.assert_no_retrace(what="the warm indexed-layout re-run"):
        _walled(lambda: grid("indexed"))

    report = {
        "grid": {"problems": n_probs, "seeds": list(SEEDS),
                 "etas": list(ETAS), "rounds": rounds,
                 "dim": int(jax.tree.leaves(stacked)[0].shape[-1])},
        "operand_bytes": {
            "stacked": bytes_by_layout["stacked"],
            "indexed": bytes_by_layout["indexed"],
            "index_overhead": idx_bytes,
            "reduction_x": reduction,
        },
        "warm": {"indexed_s": warm["indexed"], "stacked_s": warm["stacked"]},
        "match_bitwise": match,
        "warm_retraces": 0,
    }
    with open(os.path.join(ROOT, "BENCH_memory.json"), "w") as f:
        json.dump(report, f, indent=2)

    rows = [
        emit("memory/operand_bytes/indexed", 0.0,
             f"bytes={bytes_by_layout['indexed']};"
             f"reduction={reduction:.2f}x"),
        emit("memory/warm/indexed", warm["indexed"] * 1e6,
             f"vs_stacked={warm['indexed'] / warm['stacked']:.2f}x;"
             f"match={match}"),
    ]

    if check:
        # backend-robust invariants only (no absolute-time gates): these
        # hold on cpu-ref AND pallas-interpret CI legs
        if reduction < n_seeds:
            raise AssertionError(
                f"memory/reduction_x: {reduction:.2f}x < S={n_seeds} — the "
                f"indexed layout must shrink spec-operand bytes by at least "
                f"the seed count")
        ratio = warm["indexed"] / warm["stacked"]
        if ratio > 2.5:
            raise AssertionError(
                f"memory/warm_ratio: indexed warm path {ratio:.2f}x slower "
                f"than stacked (gate 2.5x)")
        print(f"memory-bench check OK: reduction={reduction:.2f}x >= "
              f"S={n_seeds}, warm ratio={ratio:.2f}x <= 2.5x, "
              f"0 warm re-traces, bitwise match")
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="paper-scale rounds")
    ap.add_argument("--check", action="store_true",
                    help="assert the backend-robust invariants (CI leg)")
    args = ap.parse_args()
    main(quick=not args.full, check=args.check)
