"""Thm. 5.4 / App. G — the algorithm-independent lower bound, empirically.

Runs zero-respecting algorithms on the two-client worst-case instance and
checks measured suboptimality ≥ the analytic floor q^{2R}·const, at several R.
Derived: measured/floor ratio (must be ≥ ~1)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, timed
from repro.core import algorithms as A, lower_bound as lb, runner


def main(quick: bool = True):
    rows = []
    problem, inst = lb.make_lower_bound_problem(
        dim=64, beta=1.0, mu=0.01, zeta_hat=1.0)
    x0 = jnp.zeros(inst.dim)
    algos = {
        "sgd": A.SGD(eta=1.8, k=1, output_mode="last"),
        "asg": A.NesterovSGD(eta=0.9, mu=0.01, beta=1.0, k=1),
        "fedavg": A.FedAvg(eta=1.0, local_steps=8, inner_batch=1),
        "fedavg->asg": None,  # built per-R below
    }
    for rounds in ((4, 8, 16) if quick else (4, 8, 16, 32)):
        floor = float(inst.suboptimality_lb(rounds))
        for name, algo in algos.items():
            if name == "fedavg->asg":
                from repro.core import chain
                ch = chain.fedchain(algos["fedavg"], algos["asg"], selection_k=2,
                                    selection_costs_round=False)
                res, us = timed(lambda: ch.run(problem, x0, rounds, jax.random.PRNGKey(0)))
                sub = float(problem.suboptimality(res.x_hat))
            else:
                res, us = timed(lambda a=algo: runner.run(
                    a, problem, x0, rounds, jax.random.PRNGKey(0)))
                sub = float(res.history[-1])
            ratio = sub / floor if floor > 0 else float("inf")
            rows.append(emit(f"lower_bound/{name}/R={rounds}", us,
                             f"sub={sub:.3e};floor={floor:.3e};ratio={ratio:.2f}"))
    return rows


if __name__ == "__main__":
    main()
