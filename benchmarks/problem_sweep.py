"""Problem-operand sweep benchmark: a ζ × σ grid in ONE compile.

The headline claim of the ProblemSpec redesign (``repro.data.spec``): the
executors take problems as operands, so

  (a) a stacked ζ × σ grid of quadratic instances runs seeds × stepsizes ×
      problems in one vmapped call with ONE trace per executor,
  (b) a Python loop over the same instances (one ``run_sweep`` per problem)
      also reuses that single compile (cache key = family + shapes), and
  (c) the LEGACY closure path re-traces per instance — the compile tax this
      redesign removes, measured here for contrast.

Also demos multi-method stacking (SGD at several ``mu_avg`` through one
``lax.switch``-dispatched executor) and the comm × problems composition:
``run_sweep(problems=..., comm=...)`` runs the bits-accounted QSGD +
partial-participation frontier over the SAME ζ × σ grid in one compile
(per-(problem, seed) mask schedules are scan data). Asserts
``runner.TRACE_COUNTS`` stays at one compile per executor across the whole
grid — the CI ``problem-sweep`` leg runs this in miniature and fails on any
re-trace. Everything lands in ``BENCH_problem_sweep.json`` at the repo root.
"""
from __future__ import annotations

import json
import os
import time

import jax
import numpy as np

from benchmarks.common import assert_single_compile, emit, trace_deltas, walled
from repro.core import algorithms as A, chain, runner, sweep
from repro.data import problems

ROOT = os.path.join(os.path.dirname(__file__), "..")


def build_grid(zetas, sigmas):
    """Same-shaped quadratic specs over the ζ × σ product grid."""
    return [
        problems.quadratic_spec(
            jax.random.PRNGKey(0), num_clients=8, dim=16, mu=0.1, beta=1.0,
            zeta=z, sigma=s, sigma_f=0.05)
        for z in zetas for s in sigmas
    ], [f"zeta={z},sigma={s}" for z in zetas for s in sigmas]


def main(quick: bool = True):
    zetas = (0.2, 1.0, 5.0)
    sigmas = (0.0, 0.2) if quick else (0.0, 0.2, 0.5)
    rounds = 30 if quick else 100
    seeds = (0, 1) if quick else (0, 1, 2)
    etas = (0.5, 1.0)
    closure_instances = 2 if quick else 4

    specs, labels = build_grid(zetas, sigmas)
    x0 = specs[0].x0
    mu = float(specs[0].mu)
    k = 16
    sgd = A.SGD(eta=0.5, k=k, mu_avg=mu)
    fa = A.FedAvg.from_k(k, eta=0.5)
    ch = chain.fedchain(fa, sgd, selection_k=k, name="fedavg->sgd")

    rows = []
    report = {
        "grid": {"zetas": list(zetas), "sigmas": list(sigmas),
                 "problems": len(specs), "seeds": list(seeds),
                 "etas": list(etas), "rounds": rounds},
        "methods": {},
    }

    for name, algo in (("sgd", sgd), ("fedavg->sgd", ch)):
        eta_mode = None if isinstance(algo, chain.Chain) else "scale"
        before = runner.snapshot_traces()

        def grid_call():
            return sweep.run_sweep(
                algo, None, x0, rounds, seeds=seeds, etas=etas,
                eta_mode=eta_mode or "scale", problems=specs)

        res_cold, us_cold = walled(grid_call)
        res_warm, us_warm = walled(grid_call)
        grid_deltas = trace_deltas(before)
        exec_key = (f"chain/{algo.name}" if isinstance(algo, chain.Chain)
                    else f"runner/{algo.name}")
        assert_single_compile(grid_deltas,
                              [f"sweep-probs/{algo.name}", exec_key],
                              what="problem grid")

        # per-problem loop (warm): each call reuses ONE compiled executor
        def loop_call():
            return [sweep.run_sweep(algo, p, x0, rounds, seeds=seeds,
                                    etas=etas, eta_mode=eta_mode or "scale")
                    for p in specs]

        loop_res, _ = walled(lambda: loop_call()[-1])  # warm the loop path
        with runner.assert_no_retrace(
                what="the warm per-problem loop (specs as operands must "
                     "share one compile across instances)"):
            loop_res, us_loop = walled(lambda: loop_call()[-1])

        # grid vs loop equivalence on the final grid cell
        last = sweep.run_sweep(algo, specs[-1], x0, rounds, seeds=seeds,
                               etas=etas, eta_mode=eta_mode or "scale")
        np.testing.assert_allclose(
            np.asarray(res_warm.history[-1]), np.asarray(last.history),
            rtol=2e-4, atol=1e-6)

        # legacy closure path: per-instance re-trace (the removed tax)
        closure_probs = [problems.without_spec(problems.problem_from_spec(p))
                         for p in specs[:closure_instances]]
        t0 = time.perf_counter()
        for p in closure_probs:
            r = sweep.run_sweep(algo, p, x0, rounds, seeds=seeds, etas=etas,
                                eta_mode=eta_mode or "scale")
            jax.block_until_ready(r.history)
        us_closure_per = (time.perf_counter() - t0) * 1e6 / closure_instances

        speedup = us_loop / us_warm if us_warm > 0 else float("inf")
        # the headline: the closure path pays a fresh trace PER INSTANCE;
        # the spec grid (and the warm spec loop) pays zero
        retrace_tax = us_closure_per / (us_warm / len(specs))
        report["methods"][name] = {
            "grid_cold_us": us_cold,
            "grid_warm_us": us_warm,
            "per_problem_loop_warm_us": us_loop,
            "warm_speedup_grid_vs_loop": speedup,
            "closure_path_us_per_instance": us_closure_per,
            "retrace_tax_vs_grid_x": retrace_tax,
            "trace_deltas_grid": grid_deltas,
        }
        rows.append(emit(
            f"problem_sweep/{name}", us_warm,
            f"problems={len(specs)};grid_vs_loop={speedup:.2f}x;"
            f"closure_retrace_tax={retrace_tax:.0f}x"))

    # multi-method stacking: SGD at several mu_avg, one compiled call
    methods = [A.SGD(eta=0.5, k=k, mu_avg=m, name="sgd") for m in
               (0.0, 0.5 * mu, mu)]
    before = runner.snapshot_traces()
    res_m, us_m_cold = walled(lambda: sweep.run_method_sweep(
        methods, specs[0], x0, rounds, seeds=seeds))
    res_m, us_m_warm = walled(lambda: sweep.run_method_sweep(
        methods, specs[0], x0, rounds, seeds=seeds))
    m_deltas = trace_deltas(before)
    tag = "+".join(m.name for m in methods)
    assert_single_compile(
        m_deltas, [f"sweep-methods/{tag}", f"runner-methods/{tag}"],
        what="method stack")
    report["method_stacking"] = {
        "methods": len(methods), "cold_us": us_m_cold, "warm_us": us_m_warm,
        "trace_deltas": m_deltas,
    }
    rows.append(emit(f"problem_sweep/method_stack[{len(methods)}xsgd]",
                     us_m_warm, f"cold={us_m_cold:.0f}us"))

    # comm × problems: the bits-accounted frontier rides the ζ × σ grid in
    # one compile (the PR-2 → PR-3 gap this engine closes)
    from repro.comm import CommConfig

    cfg = CommConfig(compressor="qsgd", qsgd_bits=4, participation=0.5)
    before = runner.snapshot_traces()

    def comm_grid_call():
        return sweep.run_sweep(sgd, None, x0, rounds, seeds=seeds, etas=etas,
                               eta_mode="scale", problems=specs, comm=cfg)

    res_cc, us_cc_cold = walled(comm_grid_call)
    res_cc, us_cc_warm = walled(comm_grid_call)
    cc_deltas = trace_deltas(before)
    assert_single_compile(
        cc_deltas, [f"sweep-comm-probs/{sgd.name}",
                    f"runner-comm/{sgd.name}"], what="comm problem grid")
    total_bits = float(np.asarray(res_cc.cumulative_bits())[..., -1].sum())
    report["comm_problems"] = {
        "config": cfg.name, "cold_us": us_cc_cold, "warm_us": us_cc_warm,
        "trace_deltas": cc_deltas, "grid_total_bits": total_bits,
    }
    rows.append(emit(
        f"problem_sweep/comm[{cfg.name}]", us_cc_warm,
        f"problems={len(specs)};total_bits={total_bits:.3e}"))

    report["trace_counts"] = dict(runner.TRACE_COUNTS)
    with open(os.path.join(ROOT, "BENCH_problem_sweep.json"), "w") as f:
        json.dump(report, f, indent=2)
    return rows


if __name__ == "__main__":
    main()
