"""Paper Table 3 on the sweep engine — the vision family as a problems axis.

The nonconvex vision experiment (synthetic prototype images, MLP classifier,
"X% homogeneous" partition) used to run per-call: pytree params kept it off
the vmapped sweep engine. With the ``vision`` ProblemSpec family the whole
heterogeneity grid — every ``homogeneous_frac`` × seeds × stepsizes — runs
through ONE compiled executor per method (asserted via
``runner.TRACE_COUNTS``), and the comm subsystem rides along leaf-wise:
the QSGD + partial-participation leg reports exact bits next to accuracy.

Mirrors the paper's protocol (App. I.2): stepsizes are tuned on a small
grid; the tuned configuration's accuracy is reported per heterogeneity
level. Everything lands in ``BENCH_table3.json`` at the repo root.
"""
from __future__ import annotations

import json
import os

import jax
import numpy as np

from benchmarks.common import assert_single_compile, emit, trace_deltas, walled
from repro.comm import CommConfig
from repro.core import algorithms as A, chain, runner, sweep
from repro.data.vision_problem import vision_accuracy, vision_spec

ROOT = os.path.join(os.path.dirname(__file__), "..")


def build_grid(fracs, *, num_clients, per_class, side, hidden, batch):
    """Same-arch vision specs over the homogeneous-fraction grid (only ARRAY
    leaves vary, so the stack shares one treedef/compiled executor)."""
    return [
        vision_spec(
            jax.random.PRNGKey(0), num_clients=num_clients,
            homogeneous_frac=f, num_classes=2 * num_clients,
            per_class=per_class, side=side, hidden=hidden, batch=batch)
        for f in fracs
    ]


def _tuned_accuracies(res, specs, seeds, etas):
    """Per-problem: tune η by median-over-seeds accuracy; return the tuned
    accuracy (and the winning η) for each heterogeneity level."""
    out = []
    for pi, spec in enumerate(specs):
        acc_fn = vision_accuracy(spec)
        acc = np.zeros((len(seeds), len(etas)))
        for si in range(len(seeds)):
            for ei in range(len(etas)):
                params = jax.tree.map(lambda l: l[pi, si, ei], res.x_hat)
                acc[si, ei] = float(acc_fn(params))
        med = np.median(acc, axis=0)  # [E]
        best = int(np.argmax(med))
        out.append({"acc": float(med[best]), "eta": float(etas[best])})
    return out


def main(quick: bool = True):
    rounds = 30 if quick else 120
    num_clients = 5
    per_class = 40 if quick else 150
    side = 8 if quick else 14
    hidden = 16 if quick else 64
    batch = 16 if quick else 32
    fracs = (0.1, 0.5, 0.9)
    seeds = (0, 1)
    etas = (0.2, 0.5)
    chain_mults = (0.5, 1.0)
    s = 3  # sampled clients per round (paper: partial participation)

    specs = build_grid(fracs, num_clients=num_clients, per_class=per_class,
                       side=side, hidden=hidden, batch=batch)

    sgd = A.SGD(eta=0.5, k=20, output_mode="last", s=s)
    fedavg = A.FedAvg(eta=0.5, local_steps=5, inner_batch=4, s=s)
    scaffold = A.Scaffold(eta=0.3, local_steps=5, inner_batch=4, s=s)
    methods = {
        "sgd": (sgd, etas, "absolute"),
        "fedavg": (fedavg, etas, "absolute"),
        "scaffold": (scaffold, etas, "absolute"),
        "fedavg->sgd": (chain.fedchain(
            fedavg, sgd, selection_k=20, selection_s=s,
            name="fedavg->sgd"), chain_mults, "scale"),
        "scaffold->sgd": (chain.fedchain(
            scaffold, sgd, selection_k=20, selection_s=s,
            name="scaffold->sgd"), chain_mults, "scale"),
    }

    rows = []
    report = {
        "grid": {"fracs": list(fracs), "num_clients": num_clients,
                 "arch": list(specs[0].arch), "per_class": per_class,
                 "rounds": rounds, "seeds": list(seeds)},
        "methods": {},
    }
    for name, (algo, grid_etas, mode) in methods.items():
        is_chain = isinstance(algo, chain.Chain)
        before = runner.snapshot_traces()

        def grid_call(a=algo, ge=grid_etas, m=mode):
            return sweep.run_sweep(
                a, None, None, rounds, seeds=seeds, etas=ge,
                eta_mode=m if not isinstance(a, chain.Chain) else None,
                problems=specs)

        res, us_cold = walled(grid_call)
        res, us_warm = walled(grid_call)
        deltas = trace_deltas(before)
        exec_key = (f"chain/{algo.name}" if is_chain
                    else f"runner/{algo.name}")
        assert_single_compile(deltas, [f"sweep-probs/{algo.name}", exec_key],
                              what="vision grid")

        tuned = _tuned_accuracies(res, specs, seeds, grid_etas)
        report["methods"][name] = {
            "grid_cold_us": us_cold, "grid_warm_us": us_warm,
            "trace_deltas": deltas,
            "per_frac": {f"hom={f}": t for f, t in zip(fracs, tuned)},
        }
        accs = ";".join(f"hom={f}:acc={t['acc']:.4f}"
                        for f, t in zip(fracs, tuned))
        rows.append(emit(f"table3_vision/{name}", us_warm, accs))

    # comm on the vision problems axis: QSGD(4) uplinks + 60% participation,
    # bits accounted leaf-wise over the MLP pytree — one compiled executor
    # for the whole heterogeneity grid (partial participation now lives in
    # the comm mask schedule, so the algorithm's own s must be 0)
    cfg = CommConfig(compressor="qsgd", qsgd_bits=4, participation=0.6)
    comm_sgd = A.SGD(eta=0.5, k=20, output_mode="last", name="sgd")
    before = runner.snapshot_traces()

    def comm_call():
        return sweep.run_sweep(comm_sgd, None, None, rounds, seeds=seeds,
                               etas=etas, eta_mode="absolute", problems=specs,
                               comm=cfg)

    res_c, _ = walled(comm_call)
    res_c, us_comm = walled(comm_call)
    deltas = trace_deltas(before)
    assert_single_compile(
        deltas, ["sweep-comm-probs/sgd", "runner-comm/sgd"],
        what="vision comm grid")
    tuned_c = _tuned_accuracies(res_c, specs, seeds, etas)
    total_bits = np.asarray(res_c.cumulative_bits())[..., -1]  # [P, S, E]
    report["comm_qsgd4_part60"] = {
        "uplink_bits_per_client_per_round": cfg.uplink_bits(specs[0].x0),
        "trace_deltas": deltas,
        "per_frac": {
            f"hom={f}": {**t, "median_total_bits": float(
                np.median(total_bits[pi]))}
            for pi, (f, t) in enumerate(zip(fracs, tuned_c))},
    }
    rows.append(emit(
        "table3_vision/sgd+qsgd4+part60", us_comm,
        ";".join(f"hom={f}:acc={t['acc']:.4f}"
                 for f, t in zip(fracs, tuned_c))))

    # local_fraction tuning axis (App. I.2): the chain's round split is a
    # stacked schedule OPERAND (core.sweep.run_fraction_sweep), so the whole
    # fraction grid rides ONE compiled executor — and on a multi-device host
    # (benchmarks/run.py --devices N) the seeds × fractions cells shard over
    # the grid mesh axis via repro.dist (bitwise identical either way)
    from repro.dist import auto_grid_mesh

    mesh = auto_grid_mesh()
    fractions = (0.25, 0.5, 0.75)
    frac_chain = chain.fedchain(
        A.FedAvg(eta=0.5, local_steps=5, inner_batch=4),
        A.SGD(eta=0.5, k=20, output_mode="last"),
        selection_k=20, selection_s=s, name="fedavg->sgd-frac")
    mid_spec = specs[len(specs) // 2]
    before = runner.snapshot_traces()

    def frac_call():
        return sweep.run_fraction_sweep(
            frac_chain, mid_spec, None, rounds, seeds=seeds,
            fractions=fractions, mesh=mesh)

    res_f, _ = walled(frac_call)
    res_f, us_frac = walled(frac_call)
    deltas = trace_deltas(before)
    frac_tag = ("dist-frac" if mesh is not None else "sweep-frac")
    assert_single_compile(
        deltas, [f"{frac_tag}/{frac_chain.name}",
                 f"chain-frac/{frac_chain.name}"],
        what="local_fraction grid")

    acc_fn = vision_accuracy(mid_spec)
    acc = np.zeros((len(seeds), len(fractions)))
    for si in range(len(seeds)):
        for fi in range(len(fractions)):
            params = jax.tree.map(lambda l: l[si, fi], res_f.x_hat)
            acc[si, fi] = float(acc_fn(params))
    med = np.median(acc, axis=0)
    best = int(np.argmax(med))
    report["local_fraction"] = {
        "fractions": list(fractions),
        "sharded_over_devices": (0 if mesh is None
                                 else len(jax.devices())),
        "trace_deltas": deltas,
        "per_fraction_median_acc": {
            f"frac={f}": float(m) for f, m in zip(fractions, med)},
        "tuned": {"fraction": fractions[best], "acc": float(med[best])},
    }
    rows.append(emit(
        "table3_vision/fedavg->sgd+frac_axis", us_frac,
        ";".join(f"frac={f}:acc={m:.4f}" for f, m in zip(fractions, med))))

    report["trace_counts"] = dict(runner.TRACE_COUNTS)
    with open(os.path.join(ROOT, "BENCH_table3.json"), "w") as f:
        json.dump(report, f, indent=2)
    return rows


if __name__ == "__main__":
    main()
