"""Roofline reporter (deliverable g): reads the dry-run artifacts from
experiments/dryrun/*.json and emits the per-(arch × shape × mesh) roofline
table (markdown + CSV rows).

Derived column: dominant-term seconds.
"""
from __future__ import annotations

import glob
import json
import os

from benchmarks.common import emit

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments", "dryrun")


def load_records(pattern: str = "*.json"):
    recs = []
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, pattern))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def markdown_table(recs, *, mesh_filter: str = "single_pod_16x16") -> str:
    lines = [
        "| arch | shape | T_comp (s) | T_mem (s) | T_coll (s) | dominant | "
        "MODEL_FLOPS | useful | HBM GB/dev |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        if r.get("status") != "ok" or r.get("mesh") != mesh_filter or "roofline" not in r:
            continue
        if r.get("mode") == "fedchain":
            continue
        roof = r["roofline"]
        mem_gb = (r["memory"]["argument_bytes"] + r["memory"]["temp_bytes"]) / 1e9
        lines.append(
            f"| {r['arch']} | {r['shape']} | {roof['compute_s']:.3e} | "
            f"{roof['memory_s']:.3e} | {roof['collective_s']:.3e} | "
            f"{roof['dominant']} | {roof['model_flops']:.2e} | "
            f"{roof['useful_ratio']:.2f} | {mem_gb:.1f} |")
    return "\n".join(lines)


def main(quick: bool = True):
    rows = []
    recs = load_records()
    if not recs:
        rows.append(emit("roofline/missing", 0.0,
                         "run repro.launch.dryrun first"))
        return rows
    for r in recs:
        if r.get("status") != "ok" or "roofline" not in r:
            continue
        roof = r["roofline"]
        dom_s = {"compute": roof["compute_s"], "memory": roof["memory_s"],
                 "collective": roof["collective_s"]}[roof["dominant"]]
        rows.append(emit(
            f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}",
            r.get("compile_s", 0.0) * 1e6,
            f"dom={roof['dominant']};dom_s={dom_s:.3e};useful={roof['useful_ratio']:.2f}"))
    return rows


if __name__ == "__main__":
    main()
