"""Bench regression gate: warm-path timings vs the committed baselines.

Re-runs the sweep and problem-sweep smokes (``benchmarks/sweep_bench.py``,
``benchmarks/problem_sweep.py`` — both rewrite their ``BENCH_*.json``) and
fails if any WARM-path metric regresses more than ``--threshold`` (default
2.5×) against the baselines committed at the repo root. Cold/compile times
are machine- and cache-noisy, so only warm metrics gate:

* ``BENCH_sweep.json``:          ``methods[*].sweep_warm_s``
* ``BENCH_problem_sweep.json``:  ``methods[*].grid_warm_us``,
                                 ``method_stacking.warm_us``,
                                 ``comm_problems.warm_us``
* ``BENCH_dist.json`` (with ``--dist``): ``devices[*].warm_s`` — the
  sharded sweep's warm path per device count (the harness itself asserts
  bitwise parity, single-trace, and zero warm re-traces before timing)
* ``BENCH_memory.json``: ``warm.indexed_s`` through the standard warm gate,
  PLUS two named byte gates — the indexed spec-operand bytes must not grow
  past 1.05× the committed baseline (``memory/indexed/operand_bytes``) and
  the stacked/indexed reduction must stay ≥ the seed count
  (``memory/reduction_x``) — each failing with its metric name, never a
  bare assert
* ``BENCH_selection.json``: ``warm.selection_s`` — the chained policy grid's
  warm path (the harness itself raises on any warm re-trace or any re-trace
  across a full policy switch before timing)
* ``BENCH_analysis.json``: named const-byte gates, not timings — every
  executor family in the committed jaxpr audit must still trace with const
  bytes under the per-family ceiling, and the tree must lint clean (the
  analyzer harness raises on any unsuppressed violation)
* ``BENCH_comm.json``: ``bidirectional.plans[*].warm_us`` — the chained
  FedAvg→ASG plan grid's warm sweep times (compressed momentum + downlink
  EF vs the unidirectional baselines), plus a named zero-retrace gate on
  ``bidirectional.warm_retraces`` (the harness itself raises if any leg
  swap re-traces)
* ``BENCH_obs.json``: ``warm.taps_off_s`` / ``warm.taps_on_s`` through the
  standard warm gate, PLUS the named telemetry-overhead gate — the
  taps-on/taps-off warm ratio (recomputed from the min-of-samples warm
  times) must stay ≤ 1.15× and the harness's recorded warm re-trace count
  must be exactly 0 (the harness itself also asserts the taps-off run is
  bitwise identical to the taps-on history before timing)

The warm metrics are tens of milliseconds, where a noisy-neighbor scheduler
blip alone can exceed the threshold — so each harness runs ``--samples``
times (default 2) and the per-metric MINIMUM gates (the minimum of a warm
timing estimates the true cost; the mean estimates the machine's load).

Re-trace discipline is part of the gate: ``problem_sweep`` raises internally
if any executor traces more than once across its grids, and this script
re-runs one warm sweep afterwards and fails if ``runner.TRACE_COUNTS`` moved
at all (warm re-trace count must be exactly 0).

The baseline files are restored afterwards (the gate must be idempotent —
it compares against the COMMITTED numbers, not its own output); pass
``--keep-new`` to keep the fresh results on disk instead, e.g. when
intentionally re-baselining.

  PYTHONPATH=src python -m benchmarks.check_regression [--threshold X]
"""
from __future__ import annotations

import argparse
import json
import os
import sys

from repro.core import runner

ROOT = os.path.join(os.path.dirname(__file__), "..")
SWEEP_JSON = os.path.join(ROOT, "BENCH_sweep.json")
PROBLEM_JSON = os.path.join(ROOT, "BENCH_problem_sweep.json")
DIST_JSON = os.path.join(ROOT, "BENCH_dist.json")
MEMORY_JSON = os.path.join(ROOT, "BENCH_memory.json")
SELECTION_JSON = os.path.join(ROOT, "BENCH_selection.json")
ANALYSIS_JSON = os.path.join(ROOT, "BENCH_analysis.json")
COMM_JSON = os.path.join(ROOT, "BENCH_comm.json")
OBS_JSON = os.path.join(ROOT, "BENCH_obs.json")

# the acceptance bound on the telemetry round taps: a taps-on warm grid may
# cost at most this multiple of the taps-off one (O(N·d) tap reductions vs
# an O(N·d²) round body — parity-ish, with headroom for scheduler noise)
OBS_TAPS_CEILING = 1.15


def _load(path):
    with open(path) as f:
        raw = f.read()
    return raw, json.loads(raw)


def _warm_metrics_sweep(doc):
    return {f"sweep/{m}/sweep_warm_s": v["sweep_warm_s"]
            for m, v in doc["methods"].items()}


def _warm_metrics_dist(doc):
    """Warm sharded-sweep timings per device count. The dist harness runs
    its own correctness gate in-process (bitwise parity + single trace +
    zero warm re-traces), so timing regressions are all this compares."""
    return {f"dist/devices={d}/warm_s": v["warm_s"]
            for d, v in doc["devices"].items()}


def _warm_metrics_memory(doc):
    """The indexed-layout warm grid time (compared at the standard warm
    threshold; the byte gates are separate named checks)."""
    return {"memory/indexed/warm_s": doc["warm"]["indexed_s"]}


def _memory_byte_failures(base_doc, fresh_doc):
    """The named live-bytes gates on BENCH_memory.json. Byte counts are
    deterministic (array shapes, not timings), so the ceiling is tight:
    1.05× headroom for benign layout tweaks, while an accidental return to
    per-seed spec repetition (S× the bytes) can never pass."""
    failures = []
    base_b = base_doc["operand_bytes"]
    fresh_b = fresh_doc["operand_bytes"]
    ceiling = base_b["indexed"] * 1.05
    if fresh_b["indexed"] > ceiling:
        failures.append(
            f"memory/indexed/operand_bytes: {fresh_b['indexed']} bytes > "
            f"ceiling {ceiling:.0f} (1.05x committed {base_b['indexed']})")
    n_seeds = len(fresh_doc["grid"]["seeds"])
    if fresh_b["reduction_x"] < n_seeds:
        failures.append(
            f"memory/reduction_x: {fresh_b['reduction_x']:.2f}x < "
            f"S={n_seeds} (indexed layout must shrink spec-operand bytes "
            f"by at least the seed count)")
    return failures


def _analysis_const_failures(base_doc, fresh_doc):
    """Named gates on BENCH_analysis.json. Const bytes are deterministic
    (jaxpr structure, not timings), so there is no slack: every executor
    family present in the committed baseline must still trace, stay under
    the per-family byte ceiling, and the tree must lint clean."""
    failures = []
    ceiling = fresh_doc["audit"]["const_ceiling_bytes"]
    fresh_fams = fresh_doc["audit"]["families"]
    for fam in sorted(base_doc["audit"]["families"]):
        if fam not in fresh_fams:
            failures.append(
                f"analysis/{fam}: executor family missing from fresh audit")
            continue
        bytes_ = fresh_fams[fam]["const_bytes"]
        if bytes_ > ceiling:
            failures.append(
                f"analysis/{fam}: {bytes_} jaxpr const bytes > per-family "
                f"ceiling {ceiling}")
    if fresh_doc["lint"]["violations"]:
        failures.append(
            f"analysis/lint: unsuppressed violations "
            f"{fresh_doc['lint']['violations']}")
    return failures


def _warm_metrics_selection(doc):
    """The chained policy-selection grid's warm time. The selection harness
    asserts the retrace discipline in-process (0 warm re-traces, 0 re-traces
    across a full policy switch), so only the timing gates here."""
    return {"selection/warm_s": doc["warm"]["selection_s"]}


def _warm_metrics_comm(doc):
    """The bidirectional plan grid's warm sweep times. The comm_frontier
    harness asserts the leg-swap trace discipline in-process (exactly one
    compile per executor across the plan grid, zero warm re-traces), so the
    timings — plus the named ``warm_retraces`` gate below — are what
    compares here."""
    return {f"comm/bidirectional/{m}/warm_us": v["warm_us"]
            for m, v in doc["bidirectional"]["plans"].items()}


def _warm_metrics_obs(doc):
    """Both legs of the telemetry benchmark through the standard warm gate;
    the on/off RATIO gets its own named gate below."""
    return {"obs/warm/taps_off_s": doc["warm"]["taps_off_s"],
            "obs/warm/taps_on_s": doc["warm"]["taps_on_s"]}


def _obs_overhead_failures(fresh_metrics, fresh_doc):
    """Named telemetry-overhead gates on BENCH_obs.json. The ratio is
    recomputed from the min-of-samples warm times (each min estimates the
    true cost of its own path, so their quotient is the cleanest overhead
    estimate this machine can produce)."""
    failures = []
    off = fresh_metrics.get("obs/warm/taps_off_s")
    on = fresh_metrics.get("obs/warm/taps_on_s")
    if off and on is not None:
        ratio = on / off
        if ratio > OBS_TAPS_CEILING:
            failures.append(
                f"obs/taps_ratio: taps-on warm grid {ratio:.3f}x the "
                f"taps-off one > ceiling {OBS_TAPS_CEILING}x (the round "
                f"taps must stay in-scan, not host callbacks)")
    warm = fresh_doc.get("warm_retraces")
    if warm != 0:
        failures.append(
            f"obs/warm_retraces: {warm} != 0 (toggling telemetry must land "
            f"on a cached executor after the first compile of each variant)")
    return failures


def _comm_retrace_failures(fresh_doc):
    """Named zero-retrace gate on the recorded bidirectional counters."""
    warm = fresh_doc["bidirectional"].get("warm_retraces")
    if warm != 0:
        return [f"comm/bidirectional/warm_retraces: {warm} != 0 (every "
                f"uplink/downlink/momentum leg swap must be operand data)"]
    return []


def _warm_metrics_problem(doc):
    out = {f"problem_sweep/{m}/grid_warm_us": v["grid_warm_us"]
           for m, v in doc["methods"].items()}
    if "method_stacking" in doc:
        out["problem_sweep/method_stacking/warm_us"] = (
            doc["method_stacking"]["warm_us"])
    if "comm_problems" in doc:
        out["problem_sweep/comm_problems/warm_us"] = (
            doc["comm_problems"]["warm_us"])
    return out


def _compare(base, fresh, threshold):
    failures, rows = [], []
    for key, base_v in sorted(base.items()):
        fresh_v = fresh.get(key)
        if fresh_v is None:
            # a metric vanished from the harness output — that's a harness
            # change, surface it rather than silently shrinking the gate
            failures.append(f"{key}: missing from fresh run")
            continue
        ratio = fresh_v / base_v if base_v > 0 else float("inf")
        status = "OK" if ratio <= threshold else "REGRESSED"
        rows.append(f"{status:9s} {key}: base={base_v:.4g} "
                    f"fresh={fresh_v:.4g} ratio={ratio:.2f}x")
        if ratio > threshold:
            failures.append(
                f"{key}: {ratio:.2f}x slower than baseline "
                f"(threshold {threshold}x)")
    return failures, rows


def _assert_zero_warm_retrace():
    """One more warm sweep after everything compiled: TRACE_COUNTS must not
    move by a single trace."""
    import jax

    from repro.core import algorithms as A, sweep
    from repro.data import problems

    p = problems.quadratic_spec(jax.random.PRNGKey(0), num_clients=8, dim=16,
                                mu=0.1, beta=1.0, zeta=1.0, sigma=0.2)
    algo = A.SGD(eta=0.5, k=16, mu_avg=0.1)
    run = lambda: sweep.run_sweep(  # noqa: E731
        algo, p, p.x0, 10, seeds=(0, 1), etas=(0.5, 1.0), eta_mode="scale")
    run()  # compile (or reuse problem_sweep's compile)
    with runner.assert_no_retrace(what="the post-bench warm sweep"):
        run()


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--threshold", type=float, default=2.5,
                    help="max allowed warm-path slowdown vs baseline")
    ap.add_argument("--samples", type=int, default=2,
                    help="harness runs per gate; the per-metric minimum "
                    "gates (damps scheduler noise on shared runners)")
    ap.add_argument("--keep-new", action="store_true",
                    help="keep the freshly-recorded BENCH files on disk "
                    "(re-baselining) instead of restoring the committed ones")
    ap.add_argument("--dist", action="store_true",
                    help="ALSO gate the sharded-sweep timings against the "
                    "committed BENCH_dist.json (spawns 1/2/4/8-device "
                    "subprocess workers — needs nothing from the parent's "
                    "device count)")
    args = ap.parse_args(argv)

    baselines = [SWEEP_JSON, PROBLEM_JSON, MEMORY_JSON, SELECTION_JSON,
                 ANALYSIS_JSON, COMM_JSON, OBS_JSON] + ([DIST_JSON]
                                                        if args.dist else [])
    missing = [p for p in baselines if not os.path.exists(p)]
    if missing:
        print(f"no committed baseline(s): {missing}", file=sys.stderr)
        sys.exit(2)
    sweep_raw, sweep_base = _load(SWEEP_JSON)
    prob_raw, prob_base = _load(PROBLEM_JSON)
    mem_raw, mem_base = _load(MEMORY_JSON)
    sel_raw, sel_base = _load(SELECTION_JSON)
    analysis_raw, analysis_base = _load(ANALYSIS_JSON)
    comm_raw, comm_base = _load(COMM_JSON)
    obs_raw, obs_base = _load(OBS_JSON)
    base = {**_warm_metrics_sweep(sweep_base),
            **_warm_metrics_problem(prob_base),
            **_warm_metrics_memory(mem_base),
            **_warm_metrics_selection(sel_base),
            **_warm_metrics_comm(comm_base),
            **_warm_metrics_obs(obs_base)}
    dist_raw = None
    if args.dist:
        dist_raw, dist_base = _load(DIST_JSON)
        base.update(_warm_metrics_dist(dist_base))

    from benchmarks import (
        comm_frontier, memory_bench, obs_bench, problem_sweep,
        selection_sweep, sweep_bench)

    fresh: dict = {}
    mem_fresh: dict = {}
    comm_fresh: dict = {}
    obs_fresh: dict = {}
    try:
        for _ in range(max(1, args.samples)):
            # each sample must pay its own cold trace: problem_sweep asserts
            # EXACTLY one compile per executor, which a warm module-level
            # cache from the previous sample would turn into zero
            runner.clear_executor_cache()
            sweep_bench.main(quick=True)
            problem_sweep.main(quick=True)  # raises on any grid re-trace
            memory_bench.main(quick=True)  # asserts bitwise + 0 re-traces
            selection_sweep.main(quick=True)  # raises on any policy retrace
            comm_frontier.main(quick=True)  # raises on any leg-swap retrace
            obs_bench.main(quick=True)  # asserts bitwise taps-off parity
            _, sweep_fresh = _load(SWEEP_JSON)
            _, prob_fresh = _load(PROBLEM_JSON)
            _, mem_fresh = _load(MEMORY_JSON)
            _, sel_fresh = _load(SELECTION_JSON)
            _, comm_fresh = _load(COMM_JSON)
            _, obs_fresh = _load(OBS_JSON)
            sample = {**_warm_metrics_sweep(sweep_fresh),
                      **_warm_metrics_problem(prob_fresh),
                      **_warm_metrics_memory(mem_fresh),
                      **_warm_metrics_selection(sel_fresh),
                      **_warm_metrics_comm(comm_fresh),
                      **_warm_metrics_obs(obs_fresh)}
            if args.dist:
                from benchmarks import dist_scaling

                dist_scaling.main(quick=True)  # asserts its own invariants
                _, dist_fresh = _load(DIST_JSON)
                sample.update(_warm_metrics_dist(dist_fresh))
            fresh = {k: min(v, fresh.get(k, v)) for k, v in sample.items()}
        _assert_zero_warm_retrace()
        # the analyzer runs AFTER the timing samples: its jaxpr audit clears
        # and re-traces the executor cache, which would otherwise feed the
        # next sample's cold-trace accounting
        from benchmarks import analysis_audit

        analysis_audit.main(quick=True)  # raises on lint/audit failure
        _, analysis_fresh = _load(ANALYSIS_JSON)
    finally:
        if not args.keep_new:
            with open(SWEEP_JSON, "w") as f:
                f.write(sweep_raw)
            with open(PROBLEM_JSON, "w") as f:
                f.write(prob_raw)
            with open(MEMORY_JSON, "w") as f:
                f.write(mem_raw)
            with open(SELECTION_JSON, "w") as f:
                f.write(sel_raw)
            with open(ANALYSIS_JSON, "w") as f:
                f.write(analysis_raw)
            with open(COMM_JSON, "w") as f:
                f.write(comm_raw)
            with open(OBS_JSON, "w") as f:
                f.write(obs_raw)
            if dist_raw is not None:
                with open(DIST_JSON, "w") as f:
                    f.write(dist_raw)
    failures, rows = _compare(base, fresh, args.threshold)
    failures += _memory_byte_failures(mem_base, mem_fresh)
    failures += _analysis_const_failures(analysis_base, analysis_fresh)
    failures += _comm_retrace_failures(comm_fresh)
    failures += _obs_overhead_failures(fresh, obs_fresh)
    print("\n".join(rows))
    if failures:
        print("\nbench-gate FAILED:", file=sys.stderr)
        for f_ in failures:
            print(f"  {f_}", file=sys.stderr)
        sys.exit(1)
    print(f"\nbench-gate OK: {len(rows)} warm metrics within "
          f"{args.threshold}x of baseline, 0 warm re-traces")


if __name__ == "__main__":
    main()
