"""Telemetry round-tap overhead benchmark: taps-on vs taps-off warm grids.

The obs subsystem's Layer-1 round taps (``repro.obs.Telemetry``) ride the
compiled sweep executors as extra ``lax.scan`` outputs. This harness prices
them on a comm-enabled quadratic grid whose taps exercise every channel —
update/gradient norms, all three ``CommPlan`` error-feedback residual legs,
participation counts and the per-leg bits passthrough:

* warm wall time of the taps-off grid vs the taps-on grid (min over
  repeats; the per-round taps are O(N·d) reductions against an O(N·d²)
  round body, so the ratio must stay inside the 1.15× regression gate),
* zero warm re-traces on BOTH paths (``runner.TRACE_COUNTS``),
* taps-off results bitwise identical to a run without telemetry threading
  (``telemetry=None`` reuses the pre-obs cache keys, so this is the same
  executor — asserted via the taps-on/off history comparison),
* an executor event log (``repro.obs.events``) recorded around the cold
  compiles — the JSONL artifact the CI observability job uploads.

Writes ``BENCH_obs.json`` at the repo root. ``--check`` asserts the
backend-robust invariants (bitwise parity, zero warm retraces, a loose
overhead bound) without absolute-time gates — the CI miniature; the tight
1.15× gate runs against committed baselines in
``benchmarks/check_regression.py``.

  PYTHONPATH=src python -m benchmarks.obs_bench [--check]
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import numpy as np

from benchmarks.common import emit
from repro.core import algorithms as A, runner, sweep
from repro.data import problems
from repro.obs import Telemetry, events as obs_events

ROOT = os.path.join(os.path.dirname(__file__), "..")

SEEDS = (0, 1, 2)
ETAS = (0.3, 0.5)
REPEATS = 5
CHECK_RATIO = 1.5  # loose CI bound; the 1.15x gate lives in check_regression


def _plan():
    """All three legs compressed with error feedback plus partial
    participation — every tap channel is nonzero."""
    from repro.comm.config import CommPlan, Leg

    leg = Leg(compressor="qsgd", qsgd_bits=4, error_feedback=True)
    return CommPlan(uplink=leg, downlink=leg, participation=0.5)


def _walled(fn):
    t0 = time.perf_counter()
    out = fn()
    jax.block_until_ready(out.history)
    return out, time.perf_counter() - t0


def main(quick: bool = True, check: bool = False):
    rounds = 20 if quick else 80
    dim = 48 if quick else 96
    spec = problems.quadratic_spec(
        jax.random.PRNGKey(5), num_clients=8, dim=dim, mu=0.1, beta=1.0,
        zeta=1.0, sigma=0.2)
    algo = A.SGD(eta=0.4, k=8, mu_avg=0.1)
    tel = Telemetry(grad_norm=True)
    plan = _plan()

    def grid(telemetry):
        return sweep.run_sweep(algo, spec, spec.x0, rounds, seeds=SEEDS,
                               etas=ETAS, comm=plan, telemetry=telemetry)

    runner.clear_executor_cache()  # both variants pay their own cold compile
    log_path = os.path.join(ROOT, "obs_events.jsonl")
    if os.path.exists(log_path):
        os.remove(log_path)
    with obs_events.recording(log_path):
        base, _ = _walled(lambda: grid(None))
        tapped, _ = _walled(lambda: grid(tel))
        compile_events = [r for r in obs_events.RECORDER.records
                          if r["kind"] == "compile"]

    match = bool(np.array_equal(np.asarray(base.history),
                                np.asarray(tapped.history))
                 and np.array_equal(np.asarray(base.bits_up),
                                    np.asarray(tapped.bits_up)))
    if not match:
        raise AssertionError(
            "taps-on sweep results diverged bitwise from the taps-off run")

    warm_off = warm_on = float("inf")
    with runner.assert_no_retrace(what="the warm taps-on/off re-runs"):
        for _ in range(REPEATS):
            _, dt = _walled(lambda: grid(None))
            warm_off = min(warm_off, dt)
            _, dt = _walled(lambda: grid(tel))
            warm_on = min(warm_on, dt)
    ratio = warm_on / warm_off

    taps = tapped.diagnostics
    report = {
        "grid": {"seeds": list(SEEDS), "etas": list(ETAS), "rounds": rounds,
                 "dim": dim, "comm": plan.name},
        "warm": {"taps_off_s": warm_off, "taps_on_s": warm_on},
        "overhead": {"taps_ratio": ratio},
        "taps": sorted(taps),
        "compile_events": len(compile_events),
        "match_bitwise": match,
        "warm_retraces": 0,
    }
    with open(os.path.join(ROOT, "BENCH_obs.json"), "w") as f:
        json.dump(report, f, indent=2)

    rows = [
        emit("obs/warm/taps_off", warm_off * 1e6, f"rounds={rounds}"),
        emit("obs/warm/taps_on", warm_on * 1e6,
             f"ratio={ratio:.3f}x;taps={len(taps)}"),
    ]

    if check:
        # backend-robust invariants only — these hold on cpu-ref AND
        # pallas-interpret CI legs; the tight 1.15x gate needs committed
        # baselines (check_regression.py)
        expected = {"update_norm", "grad_norm", "participation", "bits_up",
                    "bits_down", "residual_up_norm", "residual_down_norm",
                    "residual_mom_norm"}
        missing = expected - set(taps)
        if missing:
            raise AssertionError(f"obs/taps: missing channels {missing}")
        for k in ("residual_up_norm", "residual_down_norm"):
            if not np.any(np.asarray(taps[k]) > 0.0):
                raise AssertionError(
                    f"obs/taps: {k} is identically zero under an "
                    f"error-feedback plan — the EF leg is not being tapped")
        if ratio > CHECK_RATIO:
            raise AssertionError(
                f"obs/warm_ratio: taps-on warm path {ratio:.2f}x slower "
                f"than taps-off (loose CI gate {CHECK_RATIO}x)")
        if not compile_events:
            raise AssertionError(
                "obs/events: the cold compiles emitted no compile events — "
                "the recorder hook is dead")
        print(f"obs-bench check OK: ratio={ratio:.2f}x <= {CHECK_RATIO}x, "
              f"{len(taps)} tap channels, {len(compile_events)} compile "
              f"events, 0 warm re-traces, bitwise match")
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="paper-scale rounds")
    ap.add_argument("--check", action="store_true",
                    help="assert the backend-robust invariants (CI leg)")
    args = ap.parse_args()
    main(quick=not args.full, check=args.check)
