"""Paper Table 1 — strongly convex rates.

Runs every Table-1 method on the exact-ζ federated quadratic and reports the
measured suboptimality after R rounds next to the theory bound from
``repro.core.theory``. The derived column is the final E[F(x̂)] − F*.

The ζ axis is now a PROBLEM OPERAND (``repro.data.spec``): the whole
ζ-grid × seeds runs as ONE vmapped ``run_sweep(problems=...)`` call per
method — one compile covers every heterogeneity level, and the reported
time is that single grid call divided by the number of ζ values.
"""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import emit, timed
from repro.core import algorithms as A, chain, sweep, theory
from repro.data import problems

ZETAS = (0.2, 1.0, 5.0)


def build(zeta=1.0, sigma=0.2, mu=0.1, beta=1.0, s=0):
    return problems.quadratic_spec(
        jax.random.PRNGKey(0), num_clients=8, dim=16, mu=mu, beta=beta,
        zeta=zeta, sigma=sigma, sigma_f=0.05)


def methods(p, s):
    mu, beta = float(p.mu), float(p.beta)
    eta = 0.5
    k = 32
    fa = A.FedAvg.from_k(k, eta=eta, s=s)
    sgd = A.SGD(eta=eta, k=k, mu_avg=mu, s=s)
    asg = A.NesterovSGD(eta=0.3, mu=mu, beta=beta, k=k, s=s)
    saga = A.SAGA(eta=eta, k=k, mu_avg=mu, s=s)
    ssnm = A.SSNM(mu_h=mu, beta=beta, k=k, s=s)
    scaffold = A.Scaffold(eta=0.3, local_steps=6, inner_batch=5, s=s)
    sel = dict(selection_k=k, selection_s=s)
    return {
        "sgd": sgd,
        "asg": asg,
        "fedavg": fa,
        "scaffold": scaffold,
        "fedavg->sgd": chain.fedchain(fa, sgd, **sel),
        "fedavg->asg": chain.fedchain(fa, asg, **sel),
        "fedavg->saga": chain.fedchain(fa, saga, **sel),
        "fedavg->ssnm": chain.fedchain(fa, ssnm, **sel),
        "scaffold->sgd": chain.fedchain(scaffold, sgd, **sel),
    }


def constants(p, x0, rounds, s):
    return theory.Constants(
        delta=p.delta(x0), d=p.dist_sq(x0) ** 0.5, mu=float(p.mu),
        beta=float(p.beta), zeta=float(p.zeta), sigma=float(p.sigma),
        n=p.num_clients, s=s or p.num_clients, k=32)


def run_zeta_grid(quick: bool = True, *, zetas=ZETAS, seeds=3):
    """All ζ values × seeds in one compiled call per method."""
    rounds = 60 if quick else 150
    specs = [build(zeta=z) for z in zetas]
    x0 = specs[0].x0  # identical across ζ (b̄, A are ζ-independent)
    seed_list = tuple(100 + sd for sd in range(seeds))
    consts = [constants(p, x0, rounds, 0) for p in specs]
    rows = []
    for name, algo in methods(specs[0], 0).items():
        res, us = timed(lambda: sweep.run_sweep(
            algo, None, x0, rounds, seeds=seed_list, etas=(1.0,),
            eta_mode="scale", problems=specs))
        final = np.asarray(res.final_sub)  # [P, S, 1]
        bound = theory.TABLE1.get(name)
        for i, zeta in enumerate(zetas):
            med = float(np.median(final[i, :, 0]))
            bound_s = f"{bound(consts[i], rounds):.3e}" if bound else ""
            rows.append(emit(f"table1/{name}/zeta={zeta}", us / len(zetas),
                             f"sub={med:.3e};bound={bound_s}"))
    for i, zeta in enumerate(zetas):
        lb = theory.lower_bound_strongly_convex(consts[i], rounds)
        rows.append(emit(f"table1/lower_bound/zeta={zeta}", 0.0,
                         f"bound={lb:.3e}"))
    return rows


def run(quick: bool = True, *, zeta=1.0, s=0, seeds=3):
    """Single-ζ grid (kept for regimes with per-method participation s)."""
    rounds = 60 if quick else 150
    p = build(zeta=zeta)
    x0 = p.x0
    seed_list = tuple(100 + sd for sd in range(seeds))
    c = constants(p, x0, rounds, s)
    rows = []
    for name, algo in methods(p, s).items():
        res, us = timed(lambda: sweep.run_sweep(
            algo, p, x0, rounds, seeds=seed_list, etas=(1.0,),
            eta_mode="scale"))
        med = float(np.median(np.asarray(res.final_sub)[:, 0]))
        bound = theory.TABLE1.get(name)
        bound_s = f"{bound(c, rounds):.3e}" if bound else ""
        rows.append(emit(f"table1/{name}/zeta={zeta}", us,
                         f"sub={med:.3e};bound={bound_s}"))
    lb = theory.lower_bound_strongly_convex(c, rounds)
    rows.append(emit(f"table1/lower_bound/zeta={zeta}", 0.0, f"bound={lb:.3e}"))
    return rows


def main(quick: bool = True):
    rows = run_zeta_grid(quick)
    # partial participation regime (S < N): variance reduction should win
    rows += run(quick, zeta=1.0, s=2)
    return rows


if __name__ == "__main__":
    main()
