"""Ablation — the Lemma H.2 selection step (Algo 1's middle step).

The paper's safety argument: at high heterogeneity A_local can END UP WORSE
than the initial point; selection caps the handoff at min{F(x̂_0), F(x̂_1/2)}.
This harness removes the selection (always hand A_local's output to A_global)
and measures the damage across ζ. Derived: final suboptimality (median over
seeds, all seeds in one sweep call).

Rebased onto ``selection.run_selection_sweep`` (uniform policy, full
participation): the H.2 ablation now runs through the SAME policy-selection
executors as the policy frontier (``benchmarks/selection_sweep.py``), and
the ζ grid rides the problems OPERAND axis — every same-stepsize ζ shares
one compiled executor per chain instead of re-tracing per problem closure.
"""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import emit, timed
from repro.core import algorithms as A, chain
from repro.data import spec as spec_lib
from repro.selection import SelectionPolicy, run_selection_sweep


def main(quick: bool = True):
    rounds = 16 if quick else 40  # short global phase: damage must be caught
    seeds = (0, 1, 2)
    uniform = (SelectionPolicy("uniform"),)
    rows = []
    # Selection is a SAFETY property: it matters when A_local *damages* the
    # iterate (here: client curvatures up to 2β make the local stepsize
    # unstable on stiff clients) and the global phase is too short to
    # recover. The ζ values sharing a local stepsize batch through ONE
    # executor via the problems axis.
    groups = ((0.5, ((1.0, 0.0),)), (2.5, ((5.0, 1.5), (20.0, 1.5))))
    for eta_local, zeta_grid in groups:
        specs = [spec_lib.quadratic_spec(
            jax.random.PRNGKey(0), num_clients=8, dim=16, mu=0.1, beta=1.0,
            zeta=zeta, sigma=0.2, sigma_f=0.05, curvature_spread=spread)
            for zeta, spread in zeta_grid]
        fa = A.FedAvg(eta=eta_local, local_steps=8, inner_batch=4)
        sgd = A.SGD(eta=0.4, k=32, mu_avg=0.1)
        for sel in (True, False):
            ch = chain.fedchain(fa, sgd, selection_k=32,
                                select_between_stages=sel)
            res, us = timed(lambda: run_selection_sweep(
                ch, None, None, rounds, policies=uniform, problems=specs,
                seeds=seeds, etas=(1.0,)))
            tag = "with_selection" if sel else "no_selection"
            final = np.asarray(res.final_sub)  # [1, P, S, 1]
            for pi, (zeta, _) in enumerate(zeta_grid):
                med = float(np.median(final[0, pi, :, 0]))
                rows.append(emit(f"ablation_selection/{tag}/zeta={zeta}", us,
                                 f"sub={med:.3e}"))
    return rows


if __name__ == "__main__":
    main()
