"""Selection-policy benchmark: bits-to-target frontiers per policy.

Runs the policies × problems × seeds grid through the policy-selection
executors — the four policies (uniform / power_of_choice / ucb / shapley)
as ONE switch-index operand per grid — for both the headline chained
FedAvg→SGD and a plain SGD leg, and reports:

* suboptimality-vs-cumulative-bits frontiers per policy: the bits spent
  until the run first reaches per-problem targets derived from the uniform
  baseline's trajectory (the UCB-vs-uniform ratio on the chained grid is
  the headline figure),
* warm wall time of the whole chained grid (gated by
  ``benchmarks/check_regression.py`` at the standard 2.5× threshold),
* zero warm re-traces AND zero re-traces across a full policy SWITCH
  (every policy permuted, every hyperparameter changed — raises if
  ``runner.TRACE_COUNTS`` moves at all: the subsystem's core guarantee).

Writes ``BENCH_selection.json`` at the repo root. ``--check`` adds the
backend-robust CI miniature: vmapped vs sharded (1-device mesh) bitwise
parity on top of the retrace assertions, no absolute-time gates.

  PYTHONPATH=src python -m benchmarks.selection_sweep [--check]
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import numpy as np

from benchmarks.common import emit
from repro.core import algorithms as A, chain, runner
from repro.data import spec as spec_lib
from repro.selection import SelectionPolicy, run_selection_sweep

ROOT = os.path.join(os.path.dirname(__file__), "..")

SEEDS = (0, 1, 2)
PARTICIPATION = 0.5
#: per-problem (zeta, curvature_spread): moderate and high heterogeneity —
#: adaptive selection has something to learn when clients differ
PROBLEM_GRID = ((1.0, 0.0), (5.0, 1.5))


def _policies():
    return (
        SelectionPolicy("uniform", participation=PARTICIPATION),
        SelectionPolicy("power_of_choice", participation=PARTICIPATION),
        SelectionPolicy("ucb", participation=PARTICIPATION, ucb_c=0.5),
        SelectionPolicy("shapley", participation=PARTICIPATION, ema=0.3),
    )


def _policies_switched():
    """Same grid SHAPE, every operand different: permuted policy order,
    changed participation/hyperparameters/seeds — must not re-trace."""
    return (
        SelectionPolicy("shapley", participation=0.25, ema=0.9, sel_seed=5),
        SelectionPolicy("ucb", participation=0.75, ucb_c=2.0, sel_seed=5),
        SelectionPolicy("uniform", participation=0.25, sel_seed=5),
        SelectionPolicy("power_of_choice", participation=0.75, sel_seed=5),
    )


def _specs(quick: bool):
    dim = 16 if quick else 32
    return [spec_lib.quadratic_spec(
        jax.random.PRNGKey(11 + i), num_clients=8, dim=dim, mu=0.1,
        beta=1.0, zeta=zeta, sigma=0.2, sigma_f=0.05,
        curvature_spread=spread)
        for i, (zeta, spread) in enumerate(PROBLEM_GRID)]


def _walled(fn):
    t0 = time.perf_counter()
    out = fn()
    jax.block_until_ready(out.history)
    return out, time.perf_counter() - t0


def _frontier(res, uniform_q: int):
    """Per-problem bits-to-target table: targets are the uniform policy's
    median-over-seeds suboptimality at mid-run and at the end (so the
    baseline reaches both by construction); bits are medians over seeds,
    None where a policy never reaches the target."""
    hist = np.asarray(res.history, np.float64)  # [Q, P, S, E, R]
    n_rounds = hist.shape[-1]
    out = {}
    for pi, name in enumerate(res.problems):
        med_u = np.median(hist[uniform_q, pi, :, 0, :], axis=0)
        targets = [float(med_u[n_rounds // 2]), float(med_u[-1])]
        rows = {}
        for qi, pol in enumerate(res.policies):
            bits = []
            for t in targets:
                b = res.bits_to_target(t)[qi, pi, :, 0]
                med = float(np.median(b))
                bits.append(None if not np.isfinite(med) else med)
            rows[pol] = bits
        out[f"{name}/zeta={PROBLEM_GRID[pi][0]:g}"] = {
            "targets": targets, "bits": rows}
    return out


def _assert_no_switch_retrace(run_fn):
    """Re-running with every policy operand changed must keep TRACE_COUNTS
    frozen — the switch-index/no-retrace guarantee."""
    with runner.assert_no_retrace(what="the policy switch (operand data)"):
        _walled(run_fn)


def main(quick: bool = True, check: bool = False):
    rounds = 24 if quick else 64
    specs = _specs(quick)
    policies = _policies()
    uniform_q = 0  # _policies() leads with the uniform baseline

    ch = chain.fedchain(
        A.FedAvg(eta=0.3, local_steps=4, inner_batch=4),
        A.SGD(eta=0.3, k=8, mu_avg=0.1),
        selection_k=16, select_between_stages=True)
    algo = A.SGD(eta=0.3, k=8, mu_avg=0.1)

    def chain_grid(pols):
        return run_selection_sweep(ch, None, None, rounds, policies=pols,
                                   problems=specs, seeds=SEEDS, etas=(1.0,))

    def algo_grid(pols):
        return run_selection_sweep(algo, None, None, rounds, policies=pols,
                                   problems=specs, seeds=SEEDS, etas=(1.0,))

    runner.clear_executor_cache()
    _walled(lambda: chain_grid(policies))  # compile
    res_chain, warm_chain = _walled(lambda: chain_grid(policies))
    _walled(lambda: algo_grid(policies))  # compile
    res_algo, warm_algo = _walled(lambda: algo_grid(policies))

    # warm re-trace discipline, then the policy-switch guarantee (same
    # shapes, all-new policy operands) — both raise on any trace movement
    with runner.assert_no_retrace(what="the warm selection re-run"):
        _walled(lambda: chain_grid(policies))
    _assert_no_switch_retrace(lambda: chain_grid(_policies_switched()))
    _assert_no_switch_retrace(lambda: algo_grid(_policies_switched()))

    frontier_chain = _frontier(res_chain, uniform_q)
    frontier_algo = _frontier(res_algo, uniform_q)

    # headline: chained FedAvg→SGD, bits to the uniform baseline's MID-RUN
    # suboptimality (the target every policy has a fair shot at) — UCB
    # relative to uniform, per problem (None: UCB never got there; < 1:
    # smart selection reached the target on fewer bits)
    uniform_name = res_chain.policies[uniform_q]
    ucb_name = policies[2].name
    headline = {}
    for prob_key, table in frontier_chain.items():
        u_bits = table["bits"][uniform_name][0]
        ucb_bits = table["bits"][ucb_name][0]
        headline[prob_key] = (None if (u_bits is None or ucb_bits is None)
                              else ucb_bits / u_bits)

    report = {
        "grid": {
            "policies": [q.name for q in policies],
            "problems": [f"zeta={z:g}/spread={c:g}" for z, c in PROBLEM_GRID],
            "seeds": list(SEEDS), "rounds": rounds,
            "participation": PARTICIPATION,
            "dim": int(specs[0].dim), "num_clients": int(specs[0].num_clients),
        },
        "warm": {"selection_s": warm_chain, "selection_algo_s": warm_algo},
        "frontier": {"chain_fedavg_sgd": frontier_chain, "sgd": frontier_algo},
        "headline": {"ucb_vs_uniform_bits_ratio": headline},
        "policy_switch_retraces": 0,
        "warm_retraces": 0,
    }
    with open(os.path.join(ROOT, "BENCH_selection.json"), "w") as f:
        json.dump(report, f, indent=2)

    rows = [
        emit("selection/warm/chain_grid", warm_chain * 1e6,
             f"cells={len(policies) * len(specs) * len(SEEDS)}"),
        emit("selection/headline/ucb_vs_uniform", 0.0,
             ";".join(f"{k.split('/')[-1]}="
                      f"{'unreached' if v is None else round(v, 3)}"
                      for k, v in headline.items())),
    ]

    if check:
        # backend-robust CI miniature: the sharded engine must agree
        # bitwise with the vmapped results above, cell for cell
        from repro.dist import make_grid_mesh

        mesh = make_grid_mesh(1)
        shd = run_selection_sweep(ch, None, None, rounds, policies=policies,
                                  problems=specs, seeds=SEEDS, etas=(1.0,),
                                  mesh=mesh)
        for field in ("history", "final_sub", "bits_up", "bits_down",
                      "masks"):
            a = np.asarray(getattr(res_chain, field))
            b = np.asarray(getattr(shd, field))
            if not np.array_equal(a, b):
                raise AssertionError(
                    f"sharded selection sweep diverged bitwise from the "
                    f"vmapped engine on {field}")
        print("selection-bench check OK: 0 re-traces across policy switch, "
              "sharded == vmapped bitwise (incl. bits ledgers)")
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="paper-scale rounds")
    ap.add_argument("--check", action="store_true",
                    help="assert the backend-robust invariants (CI leg)")
    args = ap.parse_args()
    main(quick=not args.full, check=args.check)
