"""Trace-discipline analyzer harness: the AST lint (R1–R6) plus the jaxpr
const-capture audit over every cached executor family, landed in
``BENCH_analysis.json`` at the repo root.

The JSON is the machine-readable artifact the bench-regression gate
consumes (``check_regression._analysis_const_failures``): per-family const
bytes must stay under the per-executor ceiling, the per-rule suppression
inventory is visible debt, and the unsuppressed-violation count must be
zero. The harness RAISES on any unsuppressed lint violation or audit
failure — an analyzer red is a correctness bug, not a slow benchmark.
"""
from __future__ import annotations

import os
import time

from benchmarks.common import emit

ROOT = os.path.join(os.path.dirname(__file__), "..")
OUT = os.path.join(ROOT, "BENCH_analysis.json")


def main(quick: bool = True):
    from repro.analysis import jaxpr_audit
    from repro.analysis import report as report_lib
    from repro.analysis.cli import DEFAULT_LINT_PATHS, detect_root
    from repro.analysis.lint import run_lint

    rows = []
    root = detect_root()

    t0 = time.perf_counter()
    violations, inventory = run_lint(root, DEFAULT_LINT_PATHS)
    lint_us = (time.perf_counter() - t0) * 1e6
    active, suppressed = report_lib.split_violations(violations)
    if active:
        raise AssertionError(
            "unsuppressed lint violations:\n"
            + "\n".join(v.format() for v in active))

    t0 = time.perf_counter()
    audit_report, audit_failures = jaxpr_audit.run_audit()
    audit_us = (time.perf_counter() - t0) * 1e6
    if audit_failures:
        raise AssertionError(
            "jaxpr const audit failed:\n" + "\n".join(audit_failures))

    doc = report_lib.build_report(violations, inventory, audit_report)
    report_lib.write_json(doc, OUT)

    rows.append(emit("analysis/lint", lint_us,
                     f"active=0;suppressed={len(suppressed)}"))
    rows.append(emit(
        "analysis/audit", audit_us,
        f"families={len(audit_report['families'])};"
        f"const_bytes={audit_report['total_const_bytes']}"))
    return rows


if __name__ == "__main__":
    main()
