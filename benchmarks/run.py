"""Benchmark orchestrator — one harness per paper table/figure plus the
roofline/kernel reports. Prints ``name,us_per_call,derived`` CSV.

  PYTHONPATH=src python -m benchmarks.run [--full] [--only table1,...]
                                          [--devices N] [--profile]

``--devices N`` forces N fake XLA host devices (CPU) BEFORE the first JAX
import, so the sharded sweep paths (``repro.dist``) are runnable on
CPU-only machines and CI; harnesses pick the debug mesh up via
``repro.dist.auto_grid_mesh``.

``--profile`` runs the obs-instrumented variant (``repro.obs``): a run
manifest (backend, devices, XLA flags, config hash) is written to
``BENCH_manifest.json`` next to the ``BENCH_*.json`` numbers, an event
recorder captures every executor compile and cache op to
``obs_events.jsonl``, and each harness runs TWICE inside profiler-annotated
phases — cold (carries the compiles) then warm (only what survives the
executor cache; harnesses that clear it re-pay theirs) — the uniform
compile-vs-warm breakdown. ``--profile-dir DIR`` additionally
captures a ``jax.profiler`` trace. Summarize the event log afterwards with
``python -m repro.obs report``.
"""
from __future__ import annotations

import argparse
import contextlib
import os
import sys
import time


def _force_host_devices(n: int) -> None:
    """Set the XLA device-count flag — only valid before JAX initializes."""
    if "jax" in sys.modules:
        print("--devices must be handled before JAX is imported; run via "
              "`python -m benchmarks.run`, not from a live JAX process",
              file=sys.stderr)
        sys.exit(2)
    flags = os.environ.get("XLA_FLAGS", "")
    os.environ["XLA_FLAGS"] = (
        f"{flags} --xla_force_host_platform_device_count={n}".strip())
    os.environ["JAX_PLATFORMS"] = "cpu"  # host devices are a CPU feature
    # literal name of repro.dist.mesh.DEVICES_ENV — importing it here would
    # initialize JAX before the flag lands
    os.environ["REPRO_DIST_DEVICES"] = str(n)


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="paper-scale rounds")
    ap.add_argument("--only", default="", help="comma-separated harness names")
    ap.add_argument("--devices", type=int, default=0,
                    help="force N fake XLA host devices (before JAX import) "
                    "so sharded benchmarks run on CPU-only machines")
    ap.add_argument("--profile", action="store_true",
                    help="obs-instrumented run: BENCH_manifest.json, event "
                    "log, and a cold/warm phase per harness (each runs twice)")
    ap.add_argument("--profile-dir", default="",
                    help="with --profile: capture a jax.profiler trace here")
    args = ap.parse_args(argv)
    if args.devices:
        _force_host_devices(args.devices)

    from benchmarks import (
        ablation_selection, analysis_audit, appj1_large_k, comm_frontier,
        dist_scaling, fig2_convergence, kernels_bench, lower_bound_bench,
        memory_bench, obs_bench, problem_sweep, roofline, selection_sweep,
        sweep_bench, table1_strongly_convex, table2_general_convex,
        table3_nonconvex, table3_vision, table4_pl,
    )

    harnesses = {
        "table1": table1_strongly_convex.main,  # Table 1 (strongly convex)
        "table2": table2_general_convex.main,  # Table 2 (general convex)
        "table3": table3_nonconvex.main,  # Table 3 (per-call tuning loop)
        # repro: allow[R6] BENCH_vision has no stable warm-timing metric to gate
        "table3_vision": table3_vision.main,  # Table 3 on the sweep engine
        "table4": table4_pl.main,  # Table 4 (PL)
        "fig2": fig2_convergence.main,  # Figure 2 (heterogeneity sweep)
        "lower_bound": lower_bound_bench.main,  # Thm 5.4 / App G
        "appj1": appj1_large_k.main,  # App J.1 (large K)
        "ablation_selection": ablation_selection.main,  # Lemma H.2 on/off
        "selection": selection_sweep.main,  # policy bits-to-target frontiers
        "comm_frontier": comm_frontier.main,  # suboptimality-vs-bits frontier
        "dist_scaling": dist_scaling.main,  # sharded sweep, 1/2/4/8 devices
        "memory": memory_bench.main,  # indexed vs stacked operand layouts
        "sweep": sweep_bench.main,  # vmapped grid vs per-call loop
        "problem_sweep": problem_sweep.main,  # ζ×σ problem grid, one compile
        "kernels": kernels_bench.main,  # Pallas kernels
        "obs": obs_bench.main,  # telemetry round-tap overhead
        "analysis_audit": analysis_audit.main,  # lint + jaxpr const audit
        "roofline": roofline.main,  # deliverable (g) report
    }
    only = [s for s in args.only.split(",") if s]
    unknown = sorted(set(only) - set(harnesses))
    if unknown:
        # a typo'd --only used to match nothing and exit 0 — a CI leg would
        # then pass without running anything
        print(f"unknown benchmark name(s): {', '.join(unknown)}\n"
              f"valid names: {', '.join(sorted(harnesses))}", file=sys.stderr)
        sys.exit(2)
    profile_ctx = contextlib.nullcontext()
    if args.profile:
        from repro.obs import events as obs_events
        from repro.obs import profile as obs_profile

        manifest = obs_profile.write_manifest()
        print(f"# manifest {obs_profile.MANIFEST_PATH} "
              f"config_hash={manifest['config_hash']}", file=sys.stderr)
        obs_events.install(obs_events.EventRecorder(obs_events.DEFAULT_PATH))
        if args.profile_dir:
            import jax

            profile_ctx = jax.profiler.trace(args.profile_dir)

    print("name,us_per_call,derived")
    failures = 0
    with profile_ctx:
        for name, fn in harnesses.items():
            if only and name not in only:
                continue
            t0 = time.time()
            try:
                if args.profile:
                    from repro.obs import profile as obs_profile

                    # cold carries the harness's compiles; the warm repeat
                    # shows what survives the executor cache — the uniform
                    # compile-vs-warm split
                    with obs_profile.phase(f"{name}/cold") as cold:
                        fn(quick=not args.full)
                    with obs_profile.phase(f"{name}/warm") as warm:
                        fn(quick=not args.full)
                    print(f"# {name} cold {cold['seconds']:.1f}s "
                          f"({cold['traces']} traces), warm "
                          f"{warm['seconds']:.1f}s ({warm['traces']} traces)",
                          file=sys.stderr)
                else:
                    fn(quick=not args.full)
                    print(f"# {name} done in {time.time()-t0:.1f}s",
                          file=sys.stderr)
            except Exception as e:  # noqa: BLE001
                failures += 1
                print(f"{name}/ERROR,0,{type(e).__name__}:{e}")
    if args.profile:
        from repro.obs import events as obs_events

        rec = obs_events.RECORDER
        obs_events.uninstall()
        if rec is not None:
            rec.close()
            print(f"# event log: {rec.path} ({len(rec.records)} events); "
                  f"summarize with `python -m repro.obs report`",
                  file=sys.stderr)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
