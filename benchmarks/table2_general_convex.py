"""Paper Table 2 — general convex (μ = 0) rates, on the log-cosh perturbed
problem with exact ζ. Derived column: final F(x̂) − F*.

Seeds run as one vmapped ``run_sweep`` call per method; the time column is
that single grid call (median-free: one call covers all seeds)."""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import emit, timed
from repro.core import algorithms as A, chain, sweep, theory
from repro.data import problems


def main(quick: bool = True):
    rounds = 60 if quick else 200
    seeds = (0, 1, 2)
    rows = []
    for zeta in (0.05, 0.5):
        p = problems.general_convex_problem(
            jax.random.PRNGKey(0), num_clients=8, zeta=zeta, sigma=0.1, dim=16)
        x0 = p.init_params(jax.random.PRNGKey(0))
        k = 32
        fa = A.FedAvg.from_k(k, eta=0.3)
        sgd = A.SGD(eta=0.3, k=k, mu_avg=0.0, output_mode="uniform_avg")
        asg = A.NesterovSGD(eta=0.2, mu=0.0, beta=p.beta, k=k, momentum=0.9)
        algos = {
            "sgd": sgd,
            "asg": asg,
            "fedavg": fa,
            "fedavg->sgd": chain.fedchain(fa, sgd, selection_k=k),
            "fedavg->asg": chain.fedchain(fa, asg, selection_k=k),
        }
        c = theory.Constants(
            delta=p.delta(x0), d=p.dist_sq(x0) ** 0.5, mu=0.0, beta=p.beta,
            zeta=zeta, sigma=p.sigma, n=8, s=8, k=k)
        for name, algo in algos.items():
            res, us = timed(lambda: sweep.run_sweep(
                algo, p, x0, rounds, seeds=seeds, etas=(1.0,),
                eta_mode="scale"))
            med = float(np.median(np.asarray(res.final_sub)[:, 0]))
            bound = theory.TABLE2.get(name)
            bound_s = f"{bound(c, rounds):.3e}" if bound else ""
            rows.append(emit(f"table2/{name}/zeta={zeta}", us,
                             f"sub={med:.3e};bound={bound_s}"))
        lb = theory.lower_bound_convex(c, rounds)
        rows.append(emit(f"table2/lower_bound/zeta={zeta}", 0.0, f"bound={lb:.3e}"))
    return rows


if __name__ == "__main__":
    main()
