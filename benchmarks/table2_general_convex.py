"""Paper Table 2 — general convex (μ = 0) rates, on the log-cosh perturbed
problem with exact ζ. Derived column: final F(x̂) − F*.

The ζ axis is a problem OPERAND: both heterogeneity levels × seeds run as
ONE vmapped ``run_sweep(problems=...)`` call per method (the reported time
is that grid call divided by the ζ count)."""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import emit, timed
from repro.core import algorithms as A, chain, sweep, theory
from repro.data import problems

ZETAS = (0.05, 0.5)


def main(quick: bool = True):
    rounds = 60 if quick else 200
    seeds = (0, 1, 2)
    rows = []
    specs = [problems.general_convex_spec(
        jax.random.PRNGKey(0), num_clients=8, zeta=z, sigma=0.1, dim=16)
        for z in ZETAS]
    p = specs[0]
    x0 = p.x0  # ζ only tilts the clients; the base (and x0) is shared
    k = 32
    fa = A.FedAvg.from_k(k, eta=0.3)
    sgd = A.SGD(eta=0.3, k=k, mu_avg=0.0, output_mode="uniform_avg")
    asg = A.NesterovSGD(eta=0.2, mu=0.0, beta=float(p.beta), k=k, momentum=0.9)
    algos = {
        "sgd": sgd,
        "asg": asg,
        "fedavg": fa,
        "fedavg->sgd": chain.fedchain(fa, sgd, selection_k=k),
        "fedavg->asg": chain.fedchain(fa, asg, selection_k=k),
    }
    consts = [theory.Constants(
        delta=s.delta(x0), d=s.dist_sq(x0) ** 0.5, mu=0.0,
        beta=float(s.beta), zeta=float(s.zeta), sigma=float(s.sigma),
        n=8, s=8, k=k) for s in specs]
    for name, algo in algos.items():
        res, us = timed(lambda: sweep.run_sweep(
            algo, None, x0, rounds, seeds=seeds, etas=(1.0,),
            eta_mode="scale", problems=specs))
        final = np.asarray(res.final_sub)  # [P, S, 1]
        bound = theory.TABLE2.get(name)
        for i, zeta in enumerate(ZETAS):
            med = float(np.median(final[i, :, 0]))
            bound_s = f"{bound(consts[i], rounds):.3e}" if bound else ""
            rows.append(emit(f"table2/{name}/zeta={zeta}", us / len(ZETAS),
                             f"sub={med:.3e};bound={bound_s}"))
    for i, zeta in enumerate(ZETAS):
        lb = theory.lower_bound_convex(consts[i], rounds)
        rows.append(emit(f"table2/lower_bound/zeta={zeta}", 0.0,
                         f"bound={lb:.3e}"))
    return rows


if __name__ == "__main__":
    main()
