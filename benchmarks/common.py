"""Shared benchmark scaffolding: timing + CSV emission.

Every harness prints ``name,us_per_call,derived`` rows (derived = the
benchmark's headline quantity, e.g. final suboptimality or accuracy).
"""
from __future__ import annotations

import dataclasses
import time

import jax


def _block(out):
    """block_until_ready that also descends into result dataclasses
    (RunResult/ChainResult/SweepResult are plain dataclasses, which
    ``jax.block_until_ready`` would treat as opaque leaves — timing would
    then measure async dispatch, not compute)."""
    if dataclasses.is_dataclass(out) and not isinstance(out, type):
        for f in dataclasses.fields(out):
            _block(getattr(out, f.name))
    else:
        jax.block_until_ready(out)


def timed(fn, *args, repeats: int = 1):
    """(result, us_per_call). jit-warm before timing."""
    out = fn(*args)
    _block(out)
    t0 = time.perf_counter()
    for _ in range(repeats):
        out = fn(*args)
    _block(out)
    us = (time.perf_counter() - t0) / repeats * 1e6
    return out, us


def emit(name: str, us_per_call: float, derived) -> str:
    row = f"{name},{us_per_call:.1f},{derived}"
    print(row)
    return row
