"""Shared benchmark scaffolding: timing + CSV emission.

Every harness prints ``name,us_per_call,derived`` rows (derived = the
benchmark's headline quantity, e.g. final suboptimality or accuracy).
"""
from __future__ import annotations

import dataclasses
import time

import jax


def _block(out):
    """block_until_ready that also descends into result dataclasses
    (RunResult/ChainResult/SweepResult are plain dataclasses, which
    ``jax.block_until_ready`` would treat as opaque leaves — timing would
    then measure async dispatch, not compute)."""
    if dataclasses.is_dataclass(out) and not isinstance(out, type):
        for f in dataclasses.fields(out):
            _block(getattr(out, f.name))
    else:
        jax.block_until_ready(out)


def timed(fn, *args, repeats: int = 1):
    """(result, us_per_call). jit-warm before timing."""
    out = fn(*args)
    _block(out)
    t0 = time.perf_counter()
    for _ in range(repeats):
        out = fn(*args)
    _block(out)
    us = (time.perf_counter() - t0) / repeats * 1e6
    return out, us


def emit(name: str, us_per_call: float, derived) -> str:
    row = f"{name},{us_per_call:.1f},{derived}"
    print(row)
    return row


def walled(fn):
    """(result, wall_us) of one call, blocking on the result's ``history``
    (or the result itself) so compile + compute are both inside the wall."""
    t0 = time.perf_counter()
    out = fn()
    jax.block_until_ready(getattr(out, "history", out))
    return out, (time.perf_counter() - t0) * 1e6


def trace_deltas(before: dict) -> dict:
    """TRACE_COUNTS movement since the ``before`` snapshot (only nonzero).
    Thin alias for ``runner.trace_deltas`` — kept so harnesses keep one
    import surface for timing + trace accounting."""
    from repro.core import runner

    return runner.trace_deltas(before)


def assert_single_compile(deltas: dict, keys, what: str = "grid") -> None:
    """Every named executor must have traced EXACTLY once across the grid —
    the single-compile contract the sweep harnesses (and their CI legs)
    enforce."""
    for k in keys:
        if deltas.get(k, 0) != 1:
            raise AssertionError(
                f"executor {k!r} traced {deltas.get(k, 0)} times across the "
                f"{what} (expected exactly 1): counts={deltas}")
