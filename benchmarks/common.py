"""Shared benchmark scaffolding: timing + CSV emission.

Every harness prints ``name,us_per_call,derived`` rows (derived = the
benchmark's headline quantity, e.g. final suboptimality or accuracy).
"""
from __future__ import annotations

import time

import jax


def timed(fn, *args, repeats: int = 1):
    """(result, us_per_call). jit-warm before timing."""
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(repeats):
        out = fn(*args)
    jax.block_until_ready(out)
    us = (time.perf_counter() - t0) / repeats * 1e6
    return out, us


def emit(name: str, us_per_call: float, derived) -> str:
    row = f"{name},{us_per_call:.1f},{derived}"
    print(row)
    return row
