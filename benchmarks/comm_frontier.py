"""Communication-cost frontier: suboptimality vs *bits*, not rounds.

Runs the Table-1 strongly convex grid through the comm subsystem — the
chained FedAvg→SGD method against compressed / partial-participation
baselines — and reports, per method, the exact cumulative uplink+downlink
bits next to the reached suboptimality. The headline metric is
``bits_to_target``: total wire bits until the median suboptimality first
drops below a fixed target (the paper's cost-vs-accuracy question, asked
in bits). Everything lands in ``BENCH_comm.json`` at the repo root.

All methods share compiled executors: comm config is operand data, so the
whole frontier (compressors × participation × methods) costs one trace per
(algorithm, problem) pair. The ``problems_axis`` section rides the bits
frontier over a whole ζ heterogeneity grid in ONE compiled call —
``run_sweep(problems=..., comm=...)`` with per-(problem, seed) mask
schedules — and asserts the single compile via ``runner.TRACE_COUNTS``.
"""
from __future__ import annotations

import json
import os

import jax
import numpy as np

from benchmarks.common import assert_single_compile, emit, timed, trace_deltas
from repro.comm import CommConfig
from repro.core import algorithms as A, chain, runner, sweep
from repro.data import problems

ROOT = os.path.join(os.path.dirname(__file__), "..")


def build(zeta=1.0, sigma=0.2, mu=0.1, beta=1.0):
    # the comm executors take the problem as a ProblemSpec operand: every
    # comm config below shares ONE compiled executor per (algorithm, shape)
    return problems.quadratic_spec(
        jax.random.PRNGKey(0), num_clients=8, dim=16, mu=mu, beta=beta,
        zeta=zeta, sigma=sigma, sigma_f=0.05)


def methods(p):
    k = 32
    fa = A.FedAvg.from_k(k, eta=0.5)
    sgd = A.SGD(eta=0.5, k=k, mu_avg=float(p.mu))
    saga = A.SAGA(eta=0.5, k=k, mu_avg=float(p.mu))
    chained = chain.fedchain(fa, sgd, selection_k=k, name="fedavg->sgd")

    full = CommConfig()
    qsgd4 = CommConfig(compressor="qsgd", qsgd_bits=4)
    qsgd8 = CommConfig(compressor="qsgd", qsgd_bits=8)
    randk4 = CommConfig(compressor="randk", spars_k=4)
    topk4_ef = CommConfig(compressor="topk", spars_k=4, error_feedback=True)
    part50 = CommConfig(compressor="qsgd", qsgd_bits=4, participation=0.5)

    return {
        "fedavg->sgd/full32": (chained, full),
        "fedavg->sgd/qsgd4": (chained, qsgd4),
        "sgd/full32": (sgd, full),
        "sgd/qsgd4": (sgd, qsgd4),
        "sgd/qsgd8": (sgd, qsgd8),
        "sgd/randk4": (sgd, randk4),
        "sgd/qsgd4+part50": (sgd, part50),
        "fedavg/topk4+ef": (fa, topk4_ef),
        "saga/qsgd4": (saga, qsgd4),  # compressed variance reduction
    }


def _bits_to_target(cum_bits, med_sub, target):
    """Total bits when the median suboptimality first reaches the target."""
    hit = np.flatnonzero(med_sub <= target)
    return float(cum_bits[hit[0]]) if hit.size else None


def main(quick: bool = True):
    rounds = 40 if quick else 120
    seeds = tuple(100 + s for s in range(3))
    p = build()
    x0 = p.init_params(jax.random.PRNGKey(0))
    target = 1e-2 * float(p.suboptimality(x0))  # 100× below the init gap

    rows = []
    report = {
        "problem": {"name": p.name, "num_clients": p.num_clients,
                    "dim": int(x0.shape[0]), "rounds": rounds,
                    "seeds": list(seeds), "target_sub": target},
        "methods": {},
    }
    for name, (algo, cfg) in methods(p).items():
        res, us = timed(lambda a=algo, c=cfg: sweep.run_sweep(
            a, p, x0, rounds, seeds=seeds, etas=(1.0,), eta_mode="scale",
            comm=c))
        med = np.median(np.asarray(res.history)[:, 0, :], axis=0)  # [R]
        cum = np.median(res.cumulative_bits()[:, 0, :], axis=0)  # [R]
        final = float(med[-1])
        total_bits = float(cum[-1])
        to_target = _bits_to_target(cum, med, target)
        report["methods"][name] = {
            "config": {"compressor": cfg.compressor,
                       "qsgd_bits": cfg.qsgd_bits, "spars_k": cfg.spars_k,
                       "participation": cfg.participation,
                       "error_feedback": cfg.error_feedback},
            "us_per_sweep": us,
            "final_sub": final,
            "total_bits": total_bits,
            "uplink_bits_per_vector": cfg.uplink_bits(int(x0.shape[0])),
            "bits_to_target": to_target,
            "sub_curve": [float(v) for v in med],
            "cum_bits_curve": [float(v) for v in cum],
        }
        to_s = f"{to_target:.3e}" if to_target is not None else "miss"
        rows.append(emit(f"comm/{name}", us,
                         f"sub={final:.3e};bits={total_bits:.3e};"
                         f"bits_to_target={to_s}"))

    # -- comm on the problems axis: the ζ grid through ONE compiled call ----
    zetas = (0.2, 1.0, 5.0)
    specs = [build(zeta=z) for z in zetas]
    cfg = CommConfig(compressor="qsgd", qsgd_bits=4, participation=0.5)
    frontier_methods = {
        "sgd": A.SGD(eta=0.5, k=32, mu_avg=float(p.mu), name="sgd"),
        "fedavg->sgd": chain.fedchain(
            A.FedAvg.from_k(32, eta=0.5),
            A.SGD(eta=0.5, k=32, mu_avg=float(p.mu)),
            selection_k=32, name="fedavg->sgd"),
    }
    report["problems_axis"] = {
        "zetas": list(zetas),
        "config": cfg.name,
        "methods": {},
    }
    for name, algo in frontier_methods.items():
        before = runner.snapshot_traces()
        res, us = timed(lambda a=algo: sweep.run_sweep(
            a, None, x0, rounds, seeds=seeds, etas=(1.0,), eta_mode="scale",
            comm=cfg, problems=specs))
        deltas = trace_deltas(before)
        # warm second call (timed warms before timing) must add nothing;
        # the cold call exactly one trace — comm config AND problem
        # instances are operands
        assert_single_compile(deltas, [f"sweep-comm-probs/{algo.name}"],
                              what="comm problems axis")
        per_zeta = {}
        for pi, z in enumerate(zetas):
            med = np.median(np.asarray(res.history)[pi, :, 0, :], axis=0)
            cum = np.median(res.cumulative_bits()[pi, :, 0, :], axis=0)
            per_zeta[f"zeta={z}"] = {
                "final_sub": float(med[-1]),
                "total_bits": float(cum[-1]),
                "bits_to_target": _bits_to_target(cum, med, target),
            }
        report["problems_axis"]["methods"][name] = {
            "us_per_grid": us, "trace_deltas": deltas, "per_zeta": per_zeta}
        rows.append(emit(
            f"comm/problems_axis/{name}", us,
            ";".join(f"z={z}:sub={v['final_sub']:.2e}"
                     for z, v in zip(zetas, per_zeta.values()))))

    # -- bidirectional: compressed momentum + downlink EF in one plan -------
    # Chained FedAvg→ASG: the accelerated stage ships its gradients on the
    # momentum leg and receives lookahead broadcasts through the downlink-EF
    # chain. Every plan keeps uplink error_feedback=True (a bitwise no-op
    # under identity legs) so the residual-table shape — the ONE trace-time
    # comm choice — is fixed and the whole plan grid shares its compiles.
    from repro.comm import CommPlan, Leg

    asg = A.NesterovSGD(mu=float(p.mu), beta=float(p.beta), k=32,
                        name="asg")
    ch_asg = chain.fedchain(A.FedAvg.from_k(32, eta=0.5), asg,
                            selection_k=32, name="fedavg->asg")
    plans = {
        "full32": CommPlan(uplink=Leg(error_feedback=True)),
        "up-qsgd4": CommPlan(
            uplink=Leg("qsgd", qsgd_bits=4, error_feedback=True),
            momentum=Leg("qsgd", qsgd_bits=4)),
        "bidir-qsgd4": CommPlan(
            uplink=Leg("qsgd", qsgd_bits=4, error_feedback=True),
            downlink=Leg("qsgd", qsgd_bits=4),
            momentum=Leg("qsgd", qsgd_bits=4)),
    }
    report["bidirectional"] = {"method": "fedavg->asg", "plans": {}}
    before = runner.snapshot_traces()
    run_plan = lambda pl: sweep.run_sweep(  # noqa: E731
        ch_asg, p, x0, rounds, seeds=seeds, etas=(1.0,), eta_mode="scale",
        comm=pl)
    for name, plan in plans.items():
        res, us = timed(lambda pl=plan: run_plan(pl))
        med = np.median(np.asarray(res.history)[:, 0, :], axis=0)
        cum = np.median(res.cumulative_bits()[:, 0, :], axis=0)
        report["bidirectional"]["plans"][name] = {
            "plan": plan.name,
            "warm_us": us,
            "final_sub": float(med[-1]),
            "total_bits": float(cum[-1]),
            "bits_to_target": _bits_to_target(cum, med, target),
        }
        to_t = report["bidirectional"]["plans"][name]["bits_to_target"]
        to_s = f"{to_t:.3e}" if to_t is not None else "miss"
        rows.append(emit(f"comm/bidir/{name}", us,
                         f"sub={med[-1]:.3e};bits={cum[-1]:.3e};"
                         f"bits_to_target={to_s}"))
    deltas = trace_deltas(before)
    multi = {k: v for k, v in deltas.items() if v != 1}
    if multi:
        raise AssertionError(
            f"bidirectional plan grid re-traced: {multi} — uplink/downlink/"
            f"momentum legs must be operand data at a fixed residual shape")
    with runner.assert_no_retrace(what="the warm bidirectional plan grid"):
        for plan in plans.values():
            run_plan(plan)
    report["bidirectional"]["trace_deltas"] = deltas
    report["bidirectional"]["warm_retraces"] = 0  # assert_no_retrace passed

    with open(os.path.join(ROOT, "BENCH_comm.json"), "w") as f:
        json.dump(report, f, indent=2)
    return rows


if __name__ == "__main__":
    main()
