"""Paper App. J.1 — the large-K regime: ONE round of A_local with big K
followed by A_global matches/beats multi-round local phases, and accelerated
A_global wins once K suppresses the variance.

Derived: final suboptimality."""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import emit, timed
from repro.core import algorithms as A, chain
from repro.data import problems


def main(quick: bool = True):
    rounds = 40 if quick else 100
    rows = []
    p = problems.quadratic_problem(
        jax.random.PRNGKey(0), num_clients=8, dim=16, mu=0.1, beta=1.0,
        zeta=1.0, sigma=1.0, sigma_f=0.1)
    x0 = p.init_params(jax.random.PRNGKey(0))
    big_k = 100
    for label, (local_steps, inner, frac) in {
        "1-fedavg->sgd": ((big_k, 1, 1.0 / rounds)),
        "1-fedavg->asg": ((big_k, 1, 1.0 / rounds)),
        "half-fedavg->sgd": ((10, 10, 0.5)),
    }.items():
        fa = A.FedAvg(eta=0.4, local_steps=local_steps, inner_batch=inner)
        if "asg" in label:
            glob = A.NesterovSGD(eta=0.25, mu=p.mu, beta=p.beta, k=big_k)
        else:
            glob = A.SGD(eta=0.4, k=big_k, mu_avg=p.mu)
        ch = chain.fedchain(fa, glob, local_fraction=frac, selection_k=big_k)
        subs = []
        for seed in range(3):
            res, us = timed(lambda sd=seed: ch.run(
                p, x0, rounds, jax.random.PRNGKey(sd)))
            subs.append(float(p.suboptimality(res.x_hat)))
        rows.append(emit(f"appj1/{label}/K={big_k}", us,
                         f"sub={np.median(subs):.3e}"))
    return rows


if __name__ == "__main__":
    main()
