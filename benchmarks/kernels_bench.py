"""Kernel micro-benchmarks: Pallas (interpret on CPU; compiled on TPU) vs the
jnp reference, plus the step-function wall times at smoke scale.

Derived: max |Δ| vs reference (correctness) — wall numbers are CPU-only and
indicative, the TPU perf story lives in §Roofline."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, timed
from repro.kernels.aggregate.aggregate import chain_aggregate
from repro.kernels.aggregate.ref import chain_aggregate_ref
from repro.kernels.flash_attention.flash_attention import flash_attention
from repro.kernels.flash_attention.ref import attention_ref


def main(quick: bool = True):
    rows = []
    key = jax.random.PRNGKey(0)

    # aggregate
    s, d = 8, 1 << 16
    x = jax.random.normal(key, (d,))
    g = jax.random.normal(key, (s, d))
    ci = jax.random.normal(key, (s, d))
    c = jax.random.normal(key, (d,))
    w = jnp.full((s,), 1.0 / s)
    ref, us_ref = timed(lambda: chain_aggregate_ref(x, g, ci, c, lr=0.1, weights=w))
    out, us_k = timed(lambda: chain_aggregate(x, g, ci, c, w, lr=0.1, interpret=True))
    err = float(jnp.max(jnp.abs(out - ref)))
    rows.append(emit("kernels/chain_aggregate/ref", us_ref, f"d={d}"))
    rows.append(emit("kernels/chain_aggregate/pallas_interpret", us_k, f"err={err:.1e}"))

    # flash attention
    b, s2, h, kv, hd = 1, 512, 4, 2, 64
    q = jax.random.normal(key, (b, s2, h, hd), jnp.float32)
    k2 = jax.random.normal(key, (b, s2, kv, hd), jnp.float32)
    v2 = jax.random.normal(key, (b, s2, kv, hd), jnp.float32)
    ref2, us_ref2 = timed(lambda: attention_ref(q, k2, v2, causal=True))
    out2, us_k2 = timed(lambda: flash_attention(q, k2, v2, causal=True,
                                                interpret=True))
    err2 = float(jnp.max(jnp.abs(out2 - ref2)))
    rows.append(emit("kernels/flash_attention/ref", us_ref2, f"s={s2}"))
    rows.append(emit("kernels/flash_attention/pallas_interpret", us_k2,
                     f"err={err2:.1e}"))
    return rows


if __name__ == "__main__":
    main()
