"""Kernel micro-benchmarks: Pallas (interpret on CPU; compiled on TPU) vs the
jnp reference, plus the step-function wall times at smoke scale.

Derived: max |Δ| vs reference (correctness) — wall numbers are CPU-only and
indicative, the TPU perf story lives in §Roofline."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, timed
from repro.kernels.aggregate.aggregate import aggregate_apply, chain_aggregate
from repro.kernels.aggregate.ref import (aggregate_apply_ref,
                                         chain_aggregate_ref)
from repro.kernels.flash_attention.flash_attention import flash_attention
from repro.kernels.flash_attention.ref import attention_ref


def main(quick: bool = True):
    rows = []
    key = jax.random.PRNGKey(0)

    # aggregate
    s, d = 8, 1 << 16
    k_x, k_g, k_ci, k_c = jax.random.split(key, 4)
    x = jax.random.normal(k_x, (d,))
    g = jax.random.normal(k_g, (s, d))
    ci = jax.random.normal(k_ci, (s, d))
    c = jax.random.normal(k_c, (d,))
    w = jnp.full((s,), 1.0 / s)
    ref, us_ref = timed(lambda: chain_aggregate_ref(x, g, ci, c, lr=0.1, weights=w))
    out, us_k = timed(lambda: chain_aggregate(x, g, ci, c, w, lr=0.1, interpret=True))
    err = float(jnp.max(jnp.abs(out - ref)))
    rows.append(emit("kernels/chain_aggregate/ref", us_ref, f"d={d}"))
    rows.append(emit("kernels/chain_aggregate/pallas_interpret", us_k, f"err={err:.1e}"))

    # fused aggregate-apply (EF round: masked weighted mean + residual
    # update + server step in one pass)
    keys = jax.random.split(key, 6)
    agg = jax.random.normal(keys[0], (s, d))
    delta_in = jax.random.normal(keys[1], (s, d))
    comp = jax.random.normal(keys[2], (s, d))
    res = jax.random.normal(keys[3], (s, d))
    m = (jax.random.uniform(keys[4], (s,)) < 0.5).astype(jnp.float32)
    wf = jax.random.uniform(keys[5], (s,)) / s
    ref_xr, us_ref3 = timed(
        lambda: aggregate_apply_ref(x, agg, comp, delta_in, res, m, wf))
    out_xr, us_k3 = timed(
        lambda: aggregate_apply(x, agg, comp, delta_in, res, m, wf,
                                interpret=True))
    err3 = max(float(jnp.max(jnp.abs(o - r)))
               for o, r in zip(out_xr, ref_xr))
    rows.append(emit("kernels/aggregate_apply/ref", us_ref3, f"d={d}"))
    rows.append(emit("kernels/aggregate_apply/pallas_interpret", us_k3,
                     f"err={err3:.1e}"))

    # flash attention
    b, s2, h, kv, hd = 1, 512, 4, 2, 64
    k_q, k_k, k_v = jax.random.split(key, 3)
    q = jax.random.normal(k_q, (b, s2, h, hd), jnp.float32)
    k2 = jax.random.normal(k_k, (b, s2, kv, hd), jnp.float32)
    v2 = jax.random.normal(k_v, (b, s2, kv, hd), jnp.float32)
    ref2, us_ref2 = timed(lambda: attention_ref(q, k2, v2, causal=True))
    out2, us_k2 = timed(lambda: flash_attention(q, k2, v2, causal=True,
                                                interpret=True))
    err2 = float(jnp.max(jnp.abs(out2 - ref2)))
    rows.append(emit("kernels/flash_attention/ref", us_ref2, f"s={s2}"))
    rows.append(emit("kernels/flash_attention/pallas_interpret", us_k2,
                     f"err={err2:.1e}"))
    return rows


if __name__ == "__main__":
    main()
