"""Sweep-engine benchmark: vmapped grid vs per-call loop on the Table-1 grid.

Measures, for representative Table-1 methods on the exact-ζ quadratic, the
wall time of a seeds × stepsizes grid executed (a) as a Python loop of
per-call ``runner.run``/``Chain.run`` invocations and (b) as one vmapped
``run_sweep`` call. Asserts the two paths agree numerically and records
everything in ``BENCH_sweep.json`` at the repo root.

Compile cost and steady-state cost are reported SEPARATELY: the old single
"cold" number folded trace+compile into the first execution, which made the
vmapped sweep look like a regression (one big XLA program compiles slower
than nine tiny cached ones — expected, paid once, and irrelevant to the
steady state the sweep exists for). Per method and path this reports

* ``*_first_s``  — the first call, compile included,
* ``*_warm_s``   — a second call against the warm executor cache,
* ``*_compile_est_s`` — their difference, the one-off trace+compile price,
* ``speedup_first`` / ``speedup_warm`` — loop/sweep ratios of the above.

Only the warm numbers gate in ``check_regression``; a global JAX warmup
before any timing keeps backend/PRNG init out of the first method's bill.
"""
from __future__ import annotations

import json
import os
import time

import jax
import numpy as np

from benchmarks.common import emit
from repro.core import algorithms as A, chain, runner, sweep
from repro.data import problems

ROOT = os.path.join(os.path.dirname(__file__), "..")

SEEDS = (0, 1, 2)
MULTS = (0.5, 1.0, 1.5)


def _grid_loop(algo, p, x0, rounds):
    """The per-call path: one run per (seed, η) cell."""
    out = np.zeros((len(SEEDS), len(MULTS)))
    for i, sd in enumerate(SEEDS):
        for j, m in enumerate(MULTS):
            key = jax.random.PRNGKey(sd)
            if isinstance(algo, chain.Chain):
                res = algo.run(p, x0, rounds, key, eta_scale=m)
                final = res.history[-1]
            else:
                res = runner.run(algo, p, x0, rounds, key,
                                 eta=float(algo.eta) * m)
                final = res.history[-1]
            out[i, j] = float(final)
    return out


def _grid_sweep(algo, p, x0, rounds):
    # chains take stepsize multipliers; plain algorithms absolute stepsizes
    if isinstance(algo, chain.Chain):
        res = sweep.run_sweep(algo, p, x0, rounds, seeds=SEEDS, etas=MULTS)
    else:
        res = sweep.run_sweep(algo, p, x0, rounds, seeds=SEEDS,
                              etas=tuple(float(algo.eta) * m for m in MULTS),
                              eta_mode="absolute")
    jax.block_until_ready(res.history)
    return np.asarray(res.history[:, :, -1])


def _walled(fn):
    t0 = time.perf_counter()
    out = fn()
    return out, time.perf_counter() - t0


def _global_warmup(p, x0):
    """Pay JAX backend init, PRNG-impl lowering, and dispatch-path warmup
    ONCE, against a throwaway executor — so the first timed method's compile
    numbers measure its own program, not process-global one-offs."""
    algo = A.SGD(eta=0.3, k=2, mu_avg=0.0)
    runner.run(algo, p, x0, 2, jax.random.PRNGKey(0))
    sweep.run_sweep(algo, p, x0, 2, seeds=(0,), etas=(0.3,),
                    eta_mode="absolute")
    runner.clear_executor_cache()


def main(quick: bool = True):
    rounds = 60 if quick else 150
    p = problems.quadratic_problem(
        jax.random.PRNGKey(0), num_clients=8, dim=16, mu=0.1, beta=1.0,
        zeta=1.0, sigma=0.2, sigma_f=0.05)
    x0 = p.init_params(jax.random.PRNGKey(0))
    k = 32
    methods = {
        "sgd": A.SGD(eta=0.5, k=k, mu_avg=p.mu),
        "fedavg": A.FedAvg.from_k(k, eta=0.5),
        "fedavg->sgd": chain.fedchain(
            A.FedAvg.from_k(k, eta=0.5), A.SGD(eta=0.5, k=k, mu_avg=p.mu),
            selection_k=k),
    }

    _global_warmup(p, x0)
    rows = []
    report = {"grid": {"seeds": list(SEEDS), "etas": list(MULTS),
                       "rounds": rounds}, "methods": {}}
    for name, algo in methods.items():
        runner.clear_executor_cache()
        loop_res, loop_first = _walled(lambda: _grid_loop(algo, p, x0, rounds))
        _, loop_warm = _walled(lambda: _grid_loop(algo, p, x0, rounds))
        runner.clear_executor_cache()
        sweep_res, sweep_first = _walled(
            lambda: _grid_sweep(algo, p, x0, rounds))
        _, sweep_warm = _walled(lambda: _grid_sweep(algo, p, x0, rounds))
        match = bool(np.allclose(loop_res, sweep_res, rtol=5e-3, atol=1e-5))
        report["methods"][name] = {
            "loop_first_s": loop_first, "loop_warm_s": loop_warm,
            "loop_compile_est_s": max(0.0, loop_first - loop_warm),
            "sweep_first_s": sweep_first, "sweep_warm_s": sweep_warm,
            "sweep_compile_est_s": max(0.0, sweep_first - sweep_warm),
            "speedup_first": loop_first / sweep_first,
            "speedup_warm": loop_warm / sweep_warm,
            "results_match": match,
        }
        rows.append(emit(
            f"sweep/{name}/grid={len(SEEDS)}x{len(MULTS)}",
            sweep_warm * 1e6,
            f"speedup_warm={loop_warm / sweep_warm:.2f}x;"
            f"compile_est={max(0.0, sweep_first - sweep_warm):.2f}s;"
            f"match={match}"))

    with open(os.path.join(ROOT, "BENCH_sweep.json"), "w") as f:
        json.dump(report, f, indent=2)
    return rows


if __name__ == "__main__":
    main()
