"""Communication subsystem: bit-exactness, bits accounting, compile budget.

The three load-bearing guarantees of ``repro.comm``:

(a) the identity compressor + full participation reproduces the plain
    (PR-1) executors' trajectories BIT-exactly — comm is a superset, not a
    fork, of the uncompressed path;
(b) comm config (participation fraction, compressor choice, bit-width,
    sparsity) is operand/schedule data: switching it never adds a compile
    (``runner.TRACE_COUNTS`` stays flat);
(c) per-round bit counts equal their closed forms (e.g. rand-k uplink =
    S·k·(32+⌈log₂d⌉)).

Plus the PR-2 satellites: decay grids reusing one executor and logreg ζ
estimation.
"""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.comm import CommConfig
from repro.core import algorithms as A, chain, runner, sweep
from repro.data import problems

N_CLIENTS, DIM = 8, 16


@pytest.fixture(scope="module")
def quad():
    return problems.quadratic_problem(
        jax.random.PRNGKey(0), num_clients=N_CLIENTS, dim=DIM, mu=0.1,
        beta=1.0, zeta=1.0, sigma=0.2, sigma_f=0.05)


@pytest.fixture(scope="module")
def x0(quad):
    return quad.init_params(jax.random.PRNGKey(0))


def _algos(mu):
    return {
        "sgd": A.SGD(eta=0.4, k=4, mu_avg=mu),
        "fedavg": A.FedAvg(eta=0.3, local_steps=3, inner_batch=2),
        "saga": A.SAGA(eta=0.4, k=4, mu_avg=mu),
        "saga2": A.SAGA(eta=0.4, k=4, mu_avg=mu, option="II", name="saga2"),
        "scaffold": A.Scaffold(eta=0.3),
    }


# ------------------------- bit-exactness (a) --------------------------------

@pytest.mark.parametrize("name", ["sgd", "fedavg", "saga", "saga2", "scaffold"])
def test_identity_full_participation_bitexact(quad, x0, name):
    algo = _algos(quad.mu)[name]
    plain = runner.run(algo, quad, x0, 12, jax.random.PRNGKey(3))
    comm = runner.run(algo, quad, x0, 12, jax.random.PRNGKey(3),
                      comm=CommConfig())
    assert np.array_equal(np.asarray(plain.history), np.asarray(comm.history))
    assert np.array_equal(np.asarray(plain.x_hat), np.asarray(comm.x_hat))


def test_identity_bitexact_chain_and_sweep(quad, x0):
    ch = chain.fedchain(
        A.FedAvg(eta=0.3, local_steps=3, inner_batch=2),
        A.SGD(eta=0.3, k=4, mu_avg=quad.mu), selection_k=4,
        name="comm-eq-chain")
    plain = sweep.run_sweep(ch, quad, x0, 16, seeds=(0, 1), etas=(0.5, 1.0))
    comm = sweep.run_sweep(ch, quad, x0, 16, seeds=(0, 1), etas=(0.5, 1.0),
                           comm=CommConfig())
    assert np.array_equal(np.asarray(plain.history), np.asarray(comm.history))
    assert np.array_equal(np.asarray(plain.selected_initial),
                          np.asarray(comm.selected_initial))
    assert comm.bits_up.shape == (2, 2, 16)


# ------------------------- compile budget (b) -------------------------------

def test_comm_config_is_not_a_trace_trigger(quad, x0):
    algo = A.SGD(eta=0.4, k=4, mu_avg=quad.mu, name="cc-comm-sgd")
    sweep.run_sweep(algo, quad, x0, 8, seeds=(0, 1), etas=(0.3, 0.5),
                    comm=CommConfig())
    assert runner.TRACE_COUNTS["sweep-comm/cc-comm-sgd"] == 1
    # participation fraction, compressor choice, bit-width, sparsity: all
    # operand/schedule data — NONE may add a compile
    with runner.assert_no_retrace(what="comm-config grid"):
        for cfg in [
            CommConfig(participation=0.5),
            CommConfig(compressor="qsgd", qsgd_bits=4),
            CommConfig(compressor="qsgd", qsgd_bits=8, participation=0.25),
            CommConfig(compressor="topk", spars_k=2),
            CommConfig(compressor="randk", spars_k=6, participation=0.5),
        ]:
            sweep.run_sweep(algo, quad, x0, 8, seeds=(0, 1), etas=(0.3, 0.5),
                            comm=cfg)


def test_comm_runner_single_compile(quad, x0):
    algo = A.SGD(eta=0.4, k=4, mu_avg=quad.mu, name="cc-comm-run")
    runner.run(algo, quad, x0, 6, jax.random.PRNGKey(0), comm=CommConfig())
    assert runner.TRACE_COUNTS["runner-comm/cc-comm-run"] >= 1
    with runner.assert_no_retrace(what="warm comm runner re-runs"):
        for s in range(1, 3):
            runner.run(algo, quad, x0, 6, jax.random.PRNGKey(s),
                       comm=CommConfig(compressor="qsgd", participation=0.5))


# ------------------------- bits accounting (c) ------------------------------

def test_bits_closed_forms(quad, x0):
    algo = A.SGD(eta=0.4, k=4, mu_avg=quad.mu)
    idx_bits = math.ceil(math.log2(DIM))
    cases = [
        (CommConfig(), N_CLIENTS * 32 * DIM),
        (CommConfig(compressor="qsgd", qsgd_bits=4),
         N_CLIENTS * (32 + DIM * 5)),
        (CommConfig(compressor="randk", spars_k=4, participation=0.5),
         (N_CLIENTS // 2) * 4 * (32 + idx_bits)),
        (CommConfig(compressor="topk", spars_k=2, participation=0.25),
         (N_CLIENTS // 4) * 2 * (32 + idx_bits)),
    ]
    for cfg, expect_up in cases:
        res = runner.run(algo, quad, x0, 5, jax.random.PRNGKey(0), comm=cfg)
        s_r = cfg.clients_per_round(N_CLIENTS)
        np.testing.assert_array_equal(
            np.asarray(res.bits_up), np.full(5, float(expect_up)),
            err_msg=cfg.name)
        np.testing.assert_array_equal(
            np.asarray(res.bits_down), np.full(5, float(s_r * 32 * DIM)),
            err_msg=cfg.name)


def test_scaffold_bills_two_vectors_each_way(quad, x0):
    res = runner.run(A.Scaffold(eta=0.3), quad, x0, 4, jax.random.PRNGKey(0),
                     comm=CommConfig())
    np.testing.assert_array_equal(
        np.asarray(res.bits_up), np.full(4, float(2 * N_CLIENTS * 32 * DIM)))
    np.testing.assert_array_equal(
        np.asarray(res.bits_down), np.full(4, float(2 * N_CLIENTS * 32 * DIM)))


def test_chain_selection_round_bits(quad, x0):
    ch = chain.fedchain(
        A.FedAvg(eta=0.3, local_steps=2, inner_batch=2),
        A.SGD(eta=0.3, k=4, mu_avg=quad.mu), selection_k=4,
        name="bits-chain")
    res = ch.run(quad, x0, 12, jax.random.PRNGKey(0), comm=CommConfig())
    bits_up = np.asarray(res.bits_up)
    sel = res.switch_rounds[0] - 1  # the costed selection round
    # selection: both candidates broadcast, one scalar per candidate back
    assert bits_up[sel] == 2 * 32 * N_CLIENTS
    assert np.asarray(res.bits_down)[sel] == 2 * 32 * DIM * N_CLIENTS
    # algorithm rounds bill the standard uplink on top of nothing else
    assert bits_up[0] == N_CLIENTS * 32 * DIM


def test_sweep_reports_bits_frontier(quad, x0):
    cfg = CommConfig(compressor="qsgd", qsgd_bits=4, participation=0.5)
    res = sweep.run_sweep(A.SGD(eta=0.4, k=4, mu_avg=quad.mu), quad, x0, 10,
                          seeds=(0, 1), etas=(0.4,), comm=cfg)
    assert res.bits_up.shape == (2, 1, 10)
    cum = res.cumulative_bits()
    assert cum.shape == (2, 1, 10)
    assert (np.diff(cum, axis=-1) > 0).all()
    # per-cell reproducibility: the sweep's per-seed masks are fold=s
    rr = runner.run(A.SGD(eta=0.4, k=4, mu_avg=quad.mu), quad, x0, 10,
                    jax.random.PRNGKey(1), eta=0.4, comm=cfg,
                    comm_masks=cfg.round_masks(10, N_CLIENTS, fold=1))
    np.testing.assert_array_equal(np.asarray(res.bits_up[1, 0]),
                                  np.asarray(rr.bits_up))
    np.testing.assert_allclose(np.asarray(res.history[1, 0]),
                               np.asarray(rr.history), rtol=2e-4, atol=1e-6)


# ------------------------- participation schedule ---------------------------

def test_round_masks_schedule(quad):
    cfg = CommConfig(participation=0.5, mask_seed=7)
    masks = cfg.round_masks(20, N_CLIENTS)
    assert masks.shape == (20, N_CLIENTS)
    np.testing.assert_array_equal(np.asarray(masks.sum(axis=1)),
                                  np.full(20, 4.0))
    # deterministic per fold, independent across folds
    again = cfg.round_masks(20, N_CLIENTS)
    np.testing.assert_array_equal(np.asarray(masks), np.asarray(again))
    other = cfg.round_masks(20, N_CLIENTS, fold=1)
    assert not np.array_equal(np.asarray(masks), np.asarray(other))
    full = CommConfig().round_masks(3, N_CLIENTS)
    np.testing.assert_array_equal(np.asarray(full), np.ones((3, N_CLIENTS)))


def test_partial_participation_converges(quad, x0):
    algo = A.SGD(eta=0.4, k=4, mu_avg=quad.mu)
    res = runner.run(algo, quad, x0, 30, jax.random.PRNGKey(0),
                     comm=CommConfig(participation=0.5))
    h = np.asarray(res.history)
    assert np.isfinite(h).all()
    assert h[-1] < h[0]


# ------------------------- guard rails --------------------------------------

def test_comm_accepts_pytree_state_layout():
    """Pytree params are first-class comm citizens now (the flat-[D] guard
    is gone): init_state sizes per-leaf EF residual tables from the params
    pytree and bits helpers sum leaf-wise closed forms. End-to-end pytree
    runs live in tests/test_comm_pytree.py (vision family)."""
    from repro.comm import config as comm_cfg

    params = {"w": jnp.zeros((4, 3)), "b": jnp.zeros((3,))}
    st = CommConfig(error_feedback=True).init_state(5, params)
    assert jax.tree.leaves(st.residual)[0].shape[0] == 5
    assert {l.shape for l in jax.tree.leaves(st.residual)} == {
        (5, 3), (5, 4, 3)}
    assert comm_cfg.leaf_dims(params) == (3, 12)  # dict order: b, w
    assert comm_cfg.total_dim(params) == 15
    st_off = CommConfig().init_state(5, params)
    assert not comm_cfg.ef_enabled(st_off)
    assert comm_cfg.ef_enabled(st)


def test_comm_unaware_algorithm_raises(quad, x0):
    # FedProx HAS the comm field (shared FedAvgState) but drops it in round()
    with pytest.raises(TypeError, match="not comm-aware"):
        runner.run(A.FedProx(eta=0.3), quad, x0, 3, jax.random.PRNGKey(0),
                   comm=CommConfig())
    # ACSA's state has no comm field at all — same friendly error, not a
    # cryptic NamedTuple._replace crash
    with pytest.raises(TypeError, match="not comm-aware"):
        runner.run(A.ACSA(mu=quad.mu, beta=quad.beta, k=2), quad, x0, 3,
                   jax.random.PRNGKey(0), comm=CommConfig())
    # ... and the same check fires through a chain stage (ACSA again — ASG
    # and SSNM graduated to comm-aware, so ACSA is the remaining fixture)
    with pytest.raises(TypeError, match="not comm-aware"):
        ch = chain.fedchain(A.FedAvg(eta=0.3), A.ACSA(mu=quad.mu,
                                                      beta=quad.beta, k=2),
                            name="unaware-chain")
        ch.run(quad, x0, 6, jax.random.PRNGKey(0), comm=CommConfig())


def test_algo_participation_conflicts_with_comm(quad, x0):
    """An algorithm-level s would be silently ignored under comm — the round
    refuses instead of running a different regime than configured."""
    with pytest.raises(ValueError, match="owned by CommConfig"):
        runner.run(A.SGD(eta=0.4, k=4, s=4), quad, x0, 3,
                   jax.random.PRNGKey(0), comm=CommConfig())


def test_uplink_bits_report_matches_billed_form():
    for cfg in [CommConfig(), CommConfig(compressor="qsgd", qsgd_bits=6),
                CommConfig(compressor="randk", spars_k=3),
                CommConfig(compressor="topk", spars_k=5)]:
        from repro.comm.config import uplink_bits_per_client

        assert cfg.uplink_bits(DIM) == float(
            uplink_bits_per_client(cfg.params(), DIM))


def test_bad_config_rejected():
    with pytest.raises(ValueError, match="compressor"):
        CommConfig(compressor="gzip")
    with pytest.raises(ValueError, match="participation"):
        CommConfig(participation=0.0)
    with pytest.raises(ValueError, match="qsgd_bits"):
        CommConfig(compressor="qsgd", qsgd_bits=0)
    with pytest.raises(ValueError, match="spars_k"):
        CommConfig(compressor="topk", spars_k=0)
    # k > d would keep everything while billing more than identity
    with pytest.raises(ValueError, match="exceeds the parameter dimension"):
        CommConfig(compressor="randk", spars_k=DIM + 1).init_state(
            N_CLIENTS, DIM)


def test_chain_error_feedback_runs_across_handoffs(quad, x0):
    """EF residuals reset at stage handoffs (payload semantics change
    between stages); the chained run stays finite and converges."""
    ch = chain.fedchain(
        A.FedAvg(eta=0.3, local_steps=2, inner_batch=2),
        A.SGD(eta=0.3, k=4, mu_avg=quad.mu), selection_k=4,
        name="ef-chain")
    res = ch.run(quad, x0, 20, jax.random.PRNGKey(0),
                 comm=CommConfig(compressor="topk", spars_k=4,
                                 error_feedback=True))
    h = np.asarray(res.history)
    assert np.isfinite(h).all()
    assert h[-1] < h[0]


# ------------------------- PR-2 satellites ----------------------------------

def test_decay_grid_reuses_one_executor(quad, x0):
    """decay_factor is an executor operand: a whole decay grid — per-call and
    vmapped — compiles the chain exactly once."""
    ch = chain.Chain(
        stages=[A.FedAvg(eta=0.3), A.SGD(eta=0.3, k=4, mu_avg=quad.mu)],
        fractions=[0.5, 0.5], selection_k=4, name="decay-grid-chain")
    ch.run(quad, x0, 12, jax.random.PRNGKey(0),
           decay={"decay_first": 0.3, "decay_factor": 0.5})
    assert runner.TRACE_COUNTS["chain/decay-grid-chain"] == 1
    with runner.assert_no_retrace(what="decay grid re-runs"):
        for f in (0.3, 0.7, 0.9):
            ch.run(quad, x0, 12, jax.random.PRNGKey(0),
                   decay={"decay_first": 0.3, "decay_factor": f})
        ch.run(quad, x0, 12, jax.random.PRNGKey(0))  # no decay: same executor


def test_run_decay_sweep_matches_per_call(quad, x0):
    ch = chain.fedchain(
        A.FedAvg(eta=0.3, local_steps=3, inner_batch=2),
        A.SGD(eta=0.3, k=4, mu_avg=quad.mu), selection_k=4,
        name="decay-sweep-chain")
    factors = (0.5, 0.7)
    res = sweep.run_decay_sweep(ch, quad, x0, 16, seeds=(0, 1),
                                decay_factors=factors)
    assert res.history.shape == (2, 2, 16)
    for i, sd in enumerate((0, 1)):
        for j, f in enumerate(factors):
            r = ch.run(quad, x0, 16, jax.random.PRNGKey(sd),
                       decay={"decay_first": 0.3, "decay_factor": f})
            np.testing.assert_allclose(
                np.asarray(res.history[i, j]), np.asarray(r.history),
                rtol=2e-4, atol=1e-6)


# ----------------- direction-symmetric CommPlan (PR 9) ----------------------

def test_commplan_identity_legs_bitexact_vs_commconfig(quad, x0):
    """An all-identity CommPlan (and the CommConfig shim's plan()) bitwise-
    reproduces the CommConfig trajectories AND bits ledgers — the plan API
    is a superset, not a fork, of the uplink-only config."""
    from repro.comm import CommPlan, Leg

    assert CommConfig().plan() == CommPlan()
    for algo in [A.SGD(eta=0.4, k=4, mu_avg=quad.mu),
                 A.FedAvg(eta=0.3, local_steps=3, inner_batch=2),
                 A.Scaffold(eta=0.3)]:
        ref = runner.run(algo, quad, x0, 8, jax.random.PRNGKey(3),
                         comm=CommConfig())
        res = runner.run(algo, quad, x0, 8, jax.random.PRNGKey(3),
                         comm=CommPlan())
        for fld in ("history", "x_hat", "bits_up", "bits_down"):
            np.testing.assert_array_equal(
                np.asarray(getattr(ref, fld)), np.asarray(getattr(res, fld)),
                err_msg=f"{algo.name}.{fld}")
    # the equivalence also holds leg-for-leg under a LOSSY uplink: the shim
    # maps compressor/bits/k/EF onto the uplink leg verbatim
    cfg = CommConfig(compressor="qsgd", qsgd_bits=4, error_feedback=True,
                     participation=0.5)
    plan = CommPlan(uplink=Leg("qsgd", qsgd_bits=4, error_feedback=True),
                    participation=0.5)
    algo = A.SGD(eta=0.4, k=4, mu_avg=quad.mu)
    ref = runner.run(algo, quad, x0, 8, jax.random.PRNGKey(3), comm=cfg)
    res = runner.run(algo, quad, x0, 8, jax.random.PRNGKey(3), comm=plan)
    np.testing.assert_array_equal(np.asarray(ref.history),
                                  np.asarray(res.history))
    np.testing.assert_array_equal(np.asarray(ref.bits_up),
                                  np.asarray(res.bits_up))


def test_commplan_identity_bitexact_on_sharded_engine():
    """CommConfig vs identity CommPlan on BOTH engines: the vmapped sweep
    and the 1-device shard_map mesh agree bitwise, ledgers included."""
    from repro.comm import CommPlan
    from repro.data import spec as spec_lib
    from repro.dist import make_grid_mesh

    specs = [spec_lib.quadratic_spec(
        jax.random.PRNGKey(0), num_clients=N_CLIENTS, dim=DIM, mu=0.1,
        beta=1.0, zeta=z, sigma=0.2, sigma_f=0.05) for z in (0.0, 1.0)]
    algo = A.SGD(eta=0.4, k=3, mu_avg=0.1)
    runs = {}
    for tag, kw in [("cfg-vmap", dict(comm=CommConfig())),
                    ("plan-vmap", dict(comm=CommPlan())),
                    ("cfg-mesh", dict(comm=CommConfig(),
                                      mesh=make_grid_mesh(1))),
                    ("plan-mesh", dict(comm=CommPlan(),
                                       mesh=make_grid_mesh(1)))]:
        runs[tag] = sweep.run_sweep(algo, None, None, 6, seeds=(0, 1),
                                    etas=(0.3,), problems=specs, **kw)
    ref = runs.pop("cfg-vmap")
    for tag, res in runs.items():
        for fld in ("history", "bits_up", "bits_down"):
            np.testing.assert_array_equal(
                np.asarray(getattr(ref, fld)), np.asarray(getattr(res, fld)),
                err_msg=f"{tag}.{fld}")


def test_commplan_leg_swap_is_operand_only(quad, x0):
    """Swapping uplink/downlink compressor pairs or the momentum leg at
    fixed shapes re-traces NOTHING: every leg's params ride the scanned
    CommState as operand data (only the uplink-EF residual table's shape is
    trace-time, held fixed here via error_feedback=True throughout)."""
    from repro.comm import CommPlan, Leg

    algo = A.NesterovSGD(mu=quad.mu, beta=quad.beta, k=2, name="cp-asg")
    runner.run(algo, quad, x0, 6, jax.random.PRNGKey(0),
               comm=CommPlan(uplink=Leg(error_feedback=True)))
    assert runner.TRACE_COUNTS["runner-comm/cp-asg"] >= 1
    with runner.assert_no_retrace(what="CommPlan leg grid"):
        for plan in [
            CommPlan(uplink=Leg("qsgd", qsgd_bits=4, error_feedback=True)),
            CommPlan(uplink=Leg("topk", spars_k=2, error_feedback=True),
                     downlink=Leg("qsgd", qsgd_bits=8)),
            CommPlan(uplink=Leg("qsgd", qsgd_bits=6, error_feedback=True),
                     downlink=Leg("randk", spars_k=4),
                     momentum=Leg("qsgd", qsgd_bits=2)),
            CommPlan(uplink=Leg("randk", spars_k=6, error_feedback=True),
                     downlink=Leg("topk", spars_k=2),
                     momentum=Leg("topk", spars_k=4), participation=0.5),
        ]:
            runner.run(algo, quad, x0, 6, jax.random.PRNGKey(0), comm=plan)


def test_asg_ssnm_identity_comm_matches_plain(quad, x0):
    """The newly comm-aware accelerated methods keep guarantee (a): identity
    legs + full participation reproduce the plain executors. ASG is bitwise;
    SSNM's round math short-circuits bitwise too (every wire op is an
    identity ``where``), but its gradient producer gains the compressor as a
    second consumer, which changes XLA's fusion of the SHARED subgraph by an
    ulp — so SSNM compares at float tolerance. The parity this PR actually
    guarantees — CommPlan vs CommConfig on one executor — stays bitwise
    (test_commplan_identity_legs_bitexact_vs_commconfig)."""
    asg = A.NesterovSGD(mu=quad.mu, beta=quad.beta, k=2)
    plain = runner.run(asg, quad, x0, 10, jax.random.PRNGKey(3))
    comm = runner.run(asg, quad, x0, 10, jax.random.PRNGKey(3),
                      comm=CommConfig())
    np.testing.assert_array_equal(np.asarray(plain.history),
                                  np.asarray(comm.history))
    np.testing.assert_array_equal(np.asarray(plain.x_hat),
                                  np.asarray(comm.x_hat))

    ssnm = A.SSNM(mu_h=quad.mu, beta=quad.beta, k=2)
    plain = runner.run(ssnm, quad, x0, 10, jax.random.PRNGKey(3))
    comm = runner.run(ssnm, quad, x0, 10, jax.random.PRNGKey(3),
                      comm=CommConfig())
    np.testing.assert_allclose(np.asarray(plain.history),
                               np.asarray(comm.history),
                               rtol=1e-4, atol=1e-6)
    np.testing.assert_allclose(np.asarray(plain.x_hat),
                               np.asarray(comm.x_hat),
                               rtol=1e-4, atol=1e-6)


def test_commplan_bidirectional_bits_closed_forms(quad, x0):
    """Downlinks bill the SAME per-leaf closed forms as uplinks, evaluated
    at the downlink leg's params; momentum uplinks bill at the momentum
    leg's params (ASG: 1 each way, SSNM: 2 each way)."""
    from repro.comm import CommPlan, Leg

    idx_bits = math.ceil(math.log2(DIM))
    qsgd4 = 32.0 + DIM * 5.0
    plan = CommPlan(uplink=Leg("qsgd", qsgd_bits=4),
                    downlink=Leg("topk", spars_k=2), participation=0.5)
    res = runner.run(A.SGD(eta=0.4, k=4, mu_avg=quad.mu), quad, x0, 5,
                     jax.random.PRNGKey(0), comm=plan)
    s_r = plan.clients_per_round(N_CLIENTS)
    np.testing.assert_array_equal(np.asarray(res.bits_up),
                                  np.full(5, s_r * qsgd4))
    np.testing.assert_array_equal(np.asarray(res.bits_down),
                                  np.full(5, s_r * 2.0 * (32 + idx_bits)))

    asg = runner.run(A.NesterovSGD(mu=quad.mu, beta=quad.beta, k=2), quad,
                     x0, 5, jax.random.PRNGKey(0),
                     comm=CommPlan(momentum=Leg("qsgd", qsgd_bits=4),
                                   downlink=Leg("qsgd", qsgd_bits=4)))
    np.testing.assert_array_equal(np.asarray(asg.bits_up),
                                  np.full(5, N_CLIENTS * qsgd4))
    np.testing.assert_array_equal(np.asarray(asg.bits_down),
                                  np.full(5, N_CLIENTS * qsgd4))

    ssnm = runner.run(A.SSNM(mu_h=quad.mu, beta=quad.beta, k=2), quad, x0, 5,
                      jax.random.PRNGKey(0),
                      comm=CommPlan(momentum=Leg("qsgd", qsgd_bits=4)))
    np.testing.assert_array_equal(np.asarray(ssnm.bits_up),
                                  np.full(5, N_CLIENTS * 2.0 * qsgd4))
    np.testing.assert_array_equal(np.asarray(ssnm.bits_down),
                                  np.full(5, N_CLIENTS * 2.0 * 32.0 * DIM))


def test_bidirectional_ef_converges_across_chain(quad, x0):
    """Lossy BOTH ways (uplink EF + the always-on downlink EF chain) across
    a chained handoff stays finite and converges — both residual streams
    reset at the stage boundary."""
    from repro.comm import CommPlan, Leg

    ch = chain.fedchain(
        A.FedAvg(eta=0.3, local_steps=2, inner_batch=2),
        A.SGD(eta=0.3, k=4, mu_avg=quad.mu), selection_k=4,
        name="bidir-ef-chain")
    plan = CommPlan(uplink=Leg("topk", spars_k=4, error_feedback=True),
                    downlink=Leg("topk", spars_k=4))
    res = ch.run(quad, x0, 20, jax.random.PRNGKey(0), comm=plan)
    h = np.asarray(res.history)
    assert np.isfinite(h).all()
    assert h[-1] < h[0]


def test_commplan_validation():
    from repro.comm import CommPlan, Leg

    with pytest.raises(ValueError, match="compressor"):
        Leg("gzip")
    with pytest.raises(ValueError, match="participation"):
        CommPlan(participation=0.0)
    # every leg's sparsifier is dimension-checked, with the leg named
    with pytest.raises(ValueError, match=r"exceeds the parameter.*downlink"):
        CommPlan(downlink=Leg("topk", spars_k=DIM + 1)).init_state(
            N_CLIENTS, DIM)
    with pytest.raises(ValueError, match=r"exceeds the parameter.*momentum"):
        CommPlan(momentum=Leg("randk", spars_k=DIM + 1)).init_state(
            N_CLIENTS, DIM)


def test_logreg_zeta_estimation():
    key = jax.random.PRNGKey(0)
    kf, kl = jax.random.split(key)
    base = jax.random.normal(kf, (4, 64, 8))
    shift = jnp.arange(4.0)[:, None, None] * 0.5  # heterogeneous clients
    X = base + shift
    w_true = jax.random.normal(kl, (8,))
    y = (jax.vmap(lambda Xi: Xi @ w_true)(X) > 0).astype(jnp.float32)
    p_off = problems.logreg_problem(key, features=X, labels=y)
    assert p_off.zeta == 0.0  # the documented vacuous default
    p_on = problems.logreg_problem(key, features=X, labels=y,
                                   estimate_zeta=True)
    assert p_on.zeta > 0.0 and p_on.zeta_f > 0.0
    # estimates are deterministic in the problem key
    p_again = problems.logreg_problem(key, features=X, labels=y,
                                      estimate_zeta=True)
    assert p_again.zeta == p_on.zeta
