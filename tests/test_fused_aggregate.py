"""The fused aggregate-apply round kernel: interpret-mode Pallas vs the jnp
reference, and ``comm.uplink_fused_apply`` vs the unfused
uplink → participation-scale → server-step sequence it replaces."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import comm as comm_lib
from repro.comm import CommConfig
from repro.core import algorithms as A, runner, tree_math as tm
from repro.core.algorithms import base
from repro.kernels.aggregate import ops as agg_ops
from repro.kernels.aggregate.aggregate import aggregate_apply
from repro.kernels.aggregate.ref import aggregate_apply_ref


def _round_inputs(key, s, d):
    ks = jax.random.split(key, 7)
    x = jax.random.normal(ks[0], (d,))
    agg = jax.random.normal(ks[1], (s, d))
    comp = jax.random.normal(ks[2], (s, d))
    delta_in = jax.random.normal(ks[3], (s, d))
    res = jax.random.normal(ks[4], (s, d))
    m = (jax.random.uniform(ks[5], (s,)) < 0.5).astype(jnp.float32)
    w = jax.random.uniform(ks[6], (s,)) / s
    return x, agg, comp, delta_in, res, m, w


@pytest.mark.parametrize("s,d,block_d", [
    (8, 33, 8),   # multi-block grid with a padded tail block
    (8, 32, 8),   # exact block multiple
    (1, 5, 8),    # single client row, d smaller than one block
    (4, 1, 8),    # scalar-leaf rows ([S, 1] after ravel)
])
def test_aggregate_apply_interpret_matches_ref(s, d, block_d):
    args = _round_inputs(jax.random.PRNGKey(s * 100 + d), s, d)
    x_ref, r_ref = aggregate_apply_ref(*args)
    x_k, r_k = aggregate_apply(*args, interpret=True, block_d=block_d)
    np.testing.assert_allclose(np.asarray(x_k), np.asarray(x_ref),
                               rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(np.asarray(r_k), np.asarray(r_ref),
                               rtol=1e-6, atol=1e-6)
    assert x_k.shape == (d,) and r_k.shape == (s, d)


def test_aggregate_apply_masked_rows_keep_residual():
    """m=0 rows must leave their residual untouched and contribute only via
    their (already weighted) aggregate row."""
    s, d = 4, 6
    x, agg, comp, delta_in, res, _, w = _round_inputs(
        jax.random.PRNGKey(3), s, d)
    m = jnp.asarray([1.0, 0.0, 1.0, 0.0])
    _, r_out = aggregate_apply_ref(x, agg, comp, delta_in, res, m, w)
    np.testing.assert_array_equal(np.asarray(r_out[1]), np.asarray(res[1]))
    np.testing.assert_array_equal(np.asarray(r_out[3]), np.asarray(res[3]))
    np.testing.assert_allclose(np.asarray(r_out[0]),
                               np.asarray(delta_in[0] - comp[0]), rtol=1e-6)


def test_ops_dispatch_matches_kernel_and_ref():
    args = _round_inputs(jax.random.PRNGKey(7), 8, 17)
    via_ref = agg_ops.aggregate_apply(*args)
    via_kernel = agg_ops.aggregate_apply(*args, force_pallas=True)
    expect = aggregate_apply_ref(*args)
    for got, want in zip(via_ref, expect):
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    for got, want in zip(via_kernel, expect):
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-6, atol=1e-6)


def _ef_comm(n, d, participation_mask=None):
    cfg = CommConfig(compressor="topk", spars_k=2, error_feedback=True)
    comm = cfg.init_state(n, d)
    if participation_mask is not None:
        comm = comm._replace(mask=jnp.asarray(participation_mask, jnp.float32))
    # a warm, nonzero residual table so the EF fold actually matters
    comm = comm._replace(residual=jax.random.normal(
        jax.random.PRNGKey(99), comm.residual.shape) * 0.1)
    return comm


def _unfused_sgd(comm, g_per, cids, key, x, eta):
    g_hat, comm2 = comm_lib.uplink(comm, g_per, cids, key)
    scale = comm_lib.participation_scale(comm2.mask, cids)
    x2 = base.fused_server_step(x, g_hat, eta, weight_scale=scale)
    return x2, comm2


@pytest.mark.parametrize("mask", [None, (1.0, 0.0, 1.0, 1.0, 0.0, 1.0)])
def test_uplink_fused_apply_matches_unfused_sgd_bitwise(mask):
    """The SGD wire format (payload = per-client gradient, no ref): the
    fused round reproduces uplink + participation scale + fused_server_step
    BITWISE — same compression randomness, same einsum fold order."""
    n, d = 6, 24
    comm = _ef_comm(n, d, mask)
    key = jax.random.PRNGKey(1)
    g_per = jax.random.normal(jax.random.PRNGKey(2), (n, d))
    cids = jnp.arange(n)
    x = jax.random.normal(jax.random.PRNGKey(4), (d,))
    eta = jnp.asarray(0.3)
    x_ref, comm_ref = _unfused_sgd(comm, g_per, cids, key, x, eta)
    x_f, comm_f = comm_lib.uplink_fused_apply(comm, g_per, cids, key, x, eta)
    np.testing.assert_array_equal(np.asarray(x_f), np.asarray(x_ref))
    np.testing.assert_array_equal(np.asarray(comm_f.residual),
                                  np.asarray(comm_ref.residual))
    # the interpret-mode kernel path agrees to float tolerance
    x_k, comm_k = comm_lib.uplink_fused_apply(comm, g_per, cids, key, x, eta,
                                              force_pallas=True)
    np.testing.assert_allclose(np.asarray(x_k), np.asarray(x_ref),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(comm_k.residual),
                               np.asarray(comm_ref.residual),
                               rtol=1e-5, atol=1e-6)


def test_uplink_fused_apply_matches_unfused_fedavg():
    """The local-update wire format (ref=x, delta payload, negative η for
    the lerp): fused vs reconstruct-then-lerp to float tolerance."""
    n, d = 6, 24
    comm = _ef_comm(n, d)
    key = jax.random.PRNGKey(5)
    y_final = jax.random.normal(jax.random.PRNGKey(6), (n, d))
    cids = jnp.arange(n)
    x = jax.random.normal(jax.random.PRNGKey(7), (d,))
    server_lr = 0.8
    y_hat, comm_ref = comm_lib.uplink(comm, y_final, cids, key, ref=x)
    scale = comm_lib.participation_scale(comm_ref.mask, cids)
    y_mean = base.client_mean(x, y_hat, weight_scale=scale)
    x_ref = tm.tree_lerp(server_lr, x, y_mean)
    x_f, comm_f = comm_lib.uplink_fused_apply(
        comm, y_final, cids, key, x, -server_lr, ref=x)
    np.testing.assert_allclose(np.asarray(x_f), np.asarray(x_ref),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_array_equal(np.asarray(comm_f.residual),
                                  np.asarray(comm_ref.residual))


def test_uplink_fused_apply_rejects_non_ef():
    comm = CommConfig(compressor="qsgd", qsgd_bits=4).init_state(4, 8)
    with pytest.raises(ValueError, match="error-feedback"):
        comm_lib.uplink_fused_apply(
            comm, jnp.zeros((4, 8)), jnp.arange(4), jax.random.PRNGKey(0),
            jnp.zeros((8,)), jnp.asarray(0.1))


def test_fused_round_end_to_end_matches_ref_path(monkeypatch):
    """REPRO_FORCE_PALLAS=1 routes SGD's EF round through the fused kernel;
    the full runner history must match the default ref path to float
    tolerance (the env var keys the executor cache, so no stale reuse)."""
    from repro.data import problems

    p = problems.quadratic_problem(
        jax.random.PRNGKey(0), num_clients=8, dim=16, mu=0.1, beta=1.0,
        zeta=1.0, sigma=0.2, sigma_f=0.05)
    cfg = CommConfig(compressor="topk", spars_k=2, error_feedback=True,
                     participation=0.5)
    algo = A.SGD(eta=0.2, k=2, mu_avg=0.1, output_mode="last")
    x0 = p.init_params(jax.random.PRNGKey(0))
    run = lambda: runner.run(  # noqa: E731
        algo, p, x0, 6, jax.random.PRNGKey(0), comm=cfg)
    monkeypatch.delenv("REPRO_FORCE_PALLAS", raising=False)
    ref = run()
    monkeypatch.setenv("REPRO_FORCE_PALLAS", "1")
    fused = run()
    assert agg_ops.use_fused_aggregate()  # the env gate is actually on
    np.testing.assert_allclose(np.asarray(fused.history),
                               np.asarray(ref.history), rtol=1e-4, atol=1e-6)
    np.testing.assert_allclose(
        np.asarray(fused.state.comm.residual),
        np.asarray(ref.state.comm.residual), rtol=1e-4, atol=1e-6)
    np.testing.assert_array_equal(np.asarray(fused.state.comm.bits_up),
                                  np.asarray(ref.state.comm.bits_up))
