"""ProblemSpec API guarantees.

(a) spec-built problems reproduce closure-built trajectories BIT-EXACTLY —
    plain, under identity comm, and under QSGD comm (the spec rides in as an
    executor operand; the closure path bakes the same arrays as constants);
(b) a seeds × stepsizes × ζ problem grid compiles each executor exactly once
    (``runner.TRACE_COUNTS``), for a flat algorithm and a FedAvg→SGD chain,
    and matches per-problem sweeps cell-for-cell;
(c) fresh same-shaped instances reuse compiled executors (structural cache
    keys) and the executor cache holds no problem references;
(d) multi-method stacking matches per-method runs through one compile;
(e) logreg F*/x* come from the high-precision Newton solve and unknown-F*
    suboptimality is an explicit (warning) fallback, not a silent 0.
"""
import gc
import weakref

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.comm import CommConfig
from repro.core import algorithms as A, chain, runner, sweep
from repro.data import problems
from repro.data import spec as spec_lib

ZETAS = (0.2, 1.0, 5.0)


def quad_problem(zeta=1.0, sigma=0.2, seed=0):
    return problems.quadratic_problem(
        jax.random.PRNGKey(seed), num_clients=6, dim=12, mu=0.1, beta=1.0,
        zeta=zeta, sigma=sigma, sigma_f=0.05)


def logreg_shim(seed=0):
    rng = np.random.default_rng(seed)
    feats = rng.normal(size=(4, 50, 8)).astype(np.float32)
    labels = (rng.random((4, 50)) > 0.5).astype(np.float32)
    return problems.logreg_problem(
        jax.random.PRNGKey(seed), features=jnp.asarray(feats),
        labels=jnp.asarray(labels), l2=0.1)


# ---------------------------------------------------------------------------
# (a) spec ↔ closure bit-exactness
# ---------------------------------------------------------------------------

# The spec-operand and constant-baked-closure programs are SEPARATE
# compiles: XLA may contract a multiply-add into an FMA in one and not the
# other (the perturbed family's ζ·u + ∇base, logreg's minibatch-gathered
# logits), so those trajectories agree to a few contraction ulps — which
# compound through the iterate over the run — rather than bitwise. The
# pure elementwise quadratic family is bitwise identical.
_ULP = dict(rtol=5e-6, atol=0.0)


@pytest.mark.parametrize("build,exact", [
    (lambda: quad_problem(), True),
    (lambda: problems.general_convex_problem(
        jax.random.PRNGKey(1), num_clients=5, zeta=2.0, sigma=0.1, dim=10),
     False),
    (lambda: logreg_shim(), False),
], ids=["quadratic", "perturbed", "logreg"])
def test_spec_matches_closure_bitexact(build, exact):
    p = build()
    legacy = problems.without_spec(p)
    x0 = p.init_params(jax.random.PRNGKey(0))
    algo = A.SGD(eta=0.3, k=3, mu_avg=p.mu)
    r_spec = runner.run(algo, p.spec, x0, 8, jax.random.PRNGKey(2))
    r_shim = runner.run(algo, p, x0, 8, jax.random.PRNGKey(2))
    check = (np.testing.assert_array_equal if exact
             else lambda a, b: np.testing.assert_allclose(a, b, **_ULP))
    r_clos = runner.run(algo, legacy, x0, 8, jax.random.PRNGKey(2))
    check(np.asarray(r_spec.history), np.asarray(r_clos.history))
    np.testing.assert_array_equal(np.asarray(r_spec.history),
                                  np.asarray(r_shim.history))


@pytest.mark.parametrize("cfg", [
    CommConfig(),  # identity, full participation
    CommConfig(compressor="qsgd", qsgd_bits=4),
], ids=["identity", "qsgd4"])
def test_spec_matches_closure_under_comm(cfg):
    p = quad_problem()
    legacy = problems.without_spec(p)
    x0 = p.init_params(jax.random.PRNGKey(0))
    algo = A.SGD(eta=0.3, k=3, mu_avg=p.mu)
    r_spec = runner.run(algo, p.spec, x0, 6, jax.random.PRNGKey(2), comm=cfg)
    r_clos = runner.run(algo, legacy, x0, 6, jax.random.PRNGKey(2), comm=cfg)
    np.testing.assert_array_equal(np.asarray(r_spec.history),
                                  np.asarray(r_clos.history))
    np.testing.assert_array_equal(np.asarray(r_spec.bits_up),
                                  np.asarray(r_clos.bits_up))


def test_chain_spec_matches_closure_bitexact():
    p = quad_problem()
    legacy = problems.without_spec(p)
    x0 = p.init_params(jax.random.PRNGKey(0))
    ch = chain.fedchain(
        A.FedAvg(eta=0.3, local_steps=3, inner_batch=2),
        A.SGD(eta=0.3, k=3, mu_avg=p.mu), selection_k=4, name="spec-eq-chain")
    r_spec = ch.run(p.spec, x0, 10, jax.random.PRNGKey(3))
    r_clos = ch.run(legacy, x0, 10, jax.random.PRNGKey(3))
    np.testing.assert_array_equal(np.asarray(r_spec.history),
                                  np.asarray(r_clos.history))
    assert r_spec.selected_initial == r_clos.selected_initial


# ---------------------------------------------------------------------------
# (b) the ζ grid: one compile, per-problem equivalence
# ---------------------------------------------------------------------------

def _zeta_specs():
    return [spec_lib.quadratic_spec(
        jax.random.PRNGKey(0), num_clients=6, dim=12, mu=0.1, beta=1.0,
        zeta=z, sigma=0.2, sigma_f=0.05) for z in ZETAS]


def test_zeta_grid_single_compile_flat_algo():
    specs = _zeta_specs()
    algo = A.SGD(eta=0.4, k=3, mu_avg=0.1, name="cc-spec-sgd")
    res = sweep.run_sweep(algo, None, None, 10, seeds=(0, 1),
                          etas=(0.5, 1.0), eta_mode="scale", problems=specs)
    assert res.history.shape == (len(ZETAS), 2, 2, 10)
    assert runner.TRACE_COUNTS["sweep-probs/cc-spec-sgd"] == 1
    assert runner.TRACE_COUNTS["runner/cc-spec-sgd"] == 1
    # repeated grid call and FRESH same-shaped instances: still one compile
    specs2 = [spec_lib.quadratic_spec(
        jax.random.PRNGKey(5), num_clients=6, dim=12, mu=0.1, beta=1.0,
        zeta=z, sigma=0.2, sigma_f=0.05) for z in ZETAS]
    with runner.assert_no_retrace(what="fresh same-shaped problem instances"):
        sweep.run_sweep(algo, None, None, 10, seeds=(0, 1), etas=(0.5, 1.0),
                        eta_mode="scale", problems=specs2)
    # grid cells match per-problem sweeps
    for i, s in enumerate(specs):
        per = sweep.run_sweep(algo, s, s.x0, 10, seeds=(0, 1),
                              etas=(0.5, 1.0), eta_mode="scale")
        np.testing.assert_allclose(np.asarray(res.history[i]),
                                   np.asarray(per.history),
                                   rtol=2e-4, atol=1e-6)


def test_zeta_grid_single_compile_chain():
    specs = _zeta_specs()
    ch = chain.fedchain(
        A.FedAvg(eta=0.3, local_steps=3, inner_batch=2),
        A.SGD(eta=0.3, k=3, mu_avg=0.1), selection_k=4, name="cc-spec-chain")
    res = sweep.run_sweep(ch, None, None, 12, seeds=(0, 1), etas=(0.5, 1.0),
                          problems=specs)
    assert res.history.shape == (len(ZETAS), 2, 2, 12)
    assert res.selected_initial.shape == (len(ZETAS), 2, 2, 1)
    assert runner.TRACE_COUNTS["sweep-probs/cc-spec-chain"] == 1
    assert runner.TRACE_COUNTS["chain/cc-spec-chain"] == 1
    with runner.assert_no_retrace(what="warm chain problems grid"):
        sweep.run_sweep(ch, None, None, 12, seeds=(2, 3), etas=(0.5, 1.0),
                        problems=specs)
    for i, s in enumerate(specs):
        per = sweep.run_sweep(ch, s, s.x0, 12, seeds=(0, 1), etas=(0.5, 1.0))
        np.testing.assert_allclose(np.asarray(res.history[i]),
                                   np.asarray(per.history),
                                   rtol=2e-4, atol=1e-6)


def test_run_no_retrace_across_instances():
    algo = A.SGD(eta=0.35, k=3, mu_avg=0.1, name="cc-spec-fresh")
    p1 = quad_problem(zeta=0.5, seed=0)
    x0 = p1.init_params(None)
    runner.run(algo, p1, x0, 6, jax.random.PRNGKey(0))
    with runner.assert_no_retrace(what="fresh same-shaped problem instances"):
        for seed, zeta in ((1, 1.0), (2, 4.0)):
            p = quad_problem(zeta=zeta, seed=seed)
            runner.run(algo, p, x0, 6, jax.random.PRNGKey(0))


def test_stack_specs_rejects_structural_mismatch():
    a = spec_lib.quadratic_spec(jax.random.PRNGKey(0), dim=8)
    b = spec_lib.quadratic_spec(jax.random.PRNGKey(0), dim=10)
    with pytest.raises(ValueError, match="stack"):
        spec_lib.stack_specs([a, b])
    c = spec_lib.pl_spec(jax.random.PRNGKey(0), dim=8)
    with pytest.raises(ValueError, match="stack"):
        spec_lib.stack_specs([a, c])


def test_base_id_distinguishes_closure_values():
    """Auto-registered bases fingerprint captured values, not just bytecode:
    a parameterized base built in a loop must not silently resolve to the
    first registration."""
    def make(scale):
        def base(x):
            return scale * jnp.sum(x**2)
        return base

    a = spec_lib.base_id_for(make(1.0))
    b = spec_lib.base_id_for(make(2.0))
    assert a != b
    assert spec_lib.base_id_for(make(1.0)) == a  # same value dedupes
    x = jnp.ones((3,))
    assert float(spec_lib._BASE_REGISTRY[b](x)) == pytest.approx(6.0)


def test_problems_axis_rejects_closure_problems():
    p = problems.without_spec(quad_problem())
    algo = A.SGD(eta=0.3, k=2)
    with pytest.raises(TypeError, match="closure"):
        sweep.run_sweep(algo, None, None, 4, seeds=(0,), etas=(0.3,),
                        problems=[p])


# ---------------------------------------------------------------------------
# (c) cache hygiene: structural keys, no pinned problems
# ---------------------------------------------------------------------------

def test_executor_cache_does_not_pin_specs():
    spec = spec_lib.quadratic_spec(jax.random.PRNGKey(3), num_clients=6,
                                   dim=12, zeta=1.0)
    x0 = np.asarray(spec.x0)
    algo = A.SGD(eta=0.3, k=2, name="cc-spec-leak")
    runner.run(algo, spec, jnp.asarray(x0), 4, jax.random.PRNGKey(0))
    ref = weakref.ref(spec)
    del spec
    gc.collect()
    assert ref() is None, ("executor cache (or executors) kept the spec "
                           "alive: problems must be operands, not captures")


def test_legacy_problem_token_is_weak():
    p = problems.without_spec(quad_problem(zeta=0.7, seed=9))
    token_key = runner.problem_key(p)
    assert token_key[0] == "closure"
    pid = id(p)
    assert pid in runner._PROBLEM_TOKENS
    del p
    gc.collect()
    assert pid not in runner._PROBLEM_TOKENS  # entry died with the problem


# ---------------------------------------------------------------------------
# (d) multi-method stacking
# ---------------------------------------------------------------------------

def test_method_sweep_matches_per_method_runs():
    p = quad_problem()
    x0 = p.init_params(None)
    methods = [A.SGD(eta=0.4, k=3, mu_avg=m, name="cc-msgd")
               for m in (0.0, 0.05, 0.1)]
    res = sweep.run_method_sweep(methods, p, x0, 8, seeds=(0, 1))
    assert res.history.shape == (3, 2, 1, 8)
    assert res.methods == ("cc-msgd",) * 3
    assert runner.TRACE_COUNTS["runner-methods/cc-msgd+cc-msgd+cc-msgd"] == 1
    for i, m in enumerate(methods):
        for j, sd in enumerate((0, 1)):
            r = runner.run(m, p, x0, 8, jax.random.PRNGKey(sd))
            np.testing.assert_allclose(np.asarray(res.history[i, j, 0]),
                                       np.asarray(r.history),
                                       rtol=2e-4, atol=1e-6)
    # warm call (same grid shape): no new traces
    with runner.assert_no_retrace(what="warm method grid"):
        sweep.run_method_sweep(methods, p, x0, 8, seeds=(2, 3))


def test_method_sweep_fedavg_local_steps():
    """Different local-step counts are different TRACED loops, but the state
    structure matches — exactly what the lax.switch stacking covers."""
    p = quad_problem()
    x0 = p.init_params(None)
    methods = [A.FedAvg(eta=0.3, local_steps=ls, inner_batch=2,
                        name="cc-mfa") for ls in (2, 5)]
    res = sweep.run_method_sweep(methods, p, x0, 6, seeds=(0,))
    for i, m in enumerate(methods):
        r = runner.run(m, p, x0, 6, jax.random.PRNGKey(0))
        np.testing.assert_allclose(np.asarray(res.history[i, 0, 0]),
                                   np.asarray(r.history),
                                   rtol=2e-4, atol=1e-6)


def test_method_sweep_rejects_mismatched_states():
    p = quad_problem()
    x0 = p.init_params(None)
    with pytest.raises(TypeError, match="state structure"):
        sweep.run_method_sweep(
            [A.SGD(eta=0.3, k=2), A.Scaffold(eta=0.3)], p, x0, 4, seeds=(0,))


# ---------------------------------------------------------------------------
# (e) F*: Newton solve + explicit unknown fallback
# ---------------------------------------------------------------------------

def test_logreg_newton_fstar():
    p = logreg_shim()
    assert p.f_star is not None and p.x_star is not None
    # x* is a stationary point of the exact global objective
    g = p.global_grad(p.x_star)
    assert float(jnp.linalg.norm(g)) < 1e-5
    # F* is the minimum (float32 evaluation may undershoot by ~1e-6)
    assert float(p.global_loss(p.x_star)) == pytest.approx(p.f_star, abs=1e-5)
    w = p.init_params(None)
    assert p.suboptimality(w) > 0
    gd = w - 0.5 * p.global_grad(w)  # one gradient step stays above F*
    assert float(p.suboptimality(gd)) > -1e-5


def test_logreg_suboptimality_reporting_true_gap():
    """Table-2-style reporting: histories are F − F*, not raw loss."""
    p = logreg_shim()
    x0 = p.init_params(None)
    algo = A.SGD(eta=0.5, k=2, mu_avg=p.mu)
    res = runner.run(algo, p, x0, 6, jax.random.PRNGKey(0))
    raw = float(p.global_loss(res.x_hat))
    assert float(res.history[-1]) == pytest.approx(raw - p.f_star, abs=1e-5)


def test_unknown_fstar_warns_not_silent():
    spec = spec_lib.perturbed_spec(
        jax.random.PRNGKey(0), "logcosh", dim=6, zeta=0.5)  # f_star=None
    assert spec.f_star is None
    x = jnp.ones((6,))
    with pytest.warns(UserWarning, match="no known F\\*"):
        spec.suboptimality(x)
    shim = problems.problem_from_spec(spec)
    with pytest.warns(UserWarning, match="no known F\\*"):
        shim.suboptimality(x)


def test_spec_constants_are_leaves():
    """ζ/σ/F* ride as operand leaves: a stacked grid batches them."""
    stacked = spec_lib.stack_specs(_zeta_specs())
    assert stacked.consts["zeta"].shape == (len(ZETAS),)
    np.testing.assert_allclose(np.asarray(stacked.consts["zeta"]),
                               np.asarray(ZETAS), rtol=1e-6)
    assert stacked.x0.shape == (len(ZETAS), 12)
    assert spec_lib.spec_count(stacked) == len(ZETAS)
