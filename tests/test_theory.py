"""Executable rate tables (Tables 1/2/4, Thm. 5.4): regime and ordering checks
that mirror the paper's §4–5 discussion."""
import math

import pytest

from repro.core import theory as T


@pytest.fixture
def c():
    return T.Constants(delta=10.0, d=3.0, mu=0.1, beta=1.0, zeta=0.5,
                       sigma=0.0, n=8, s=8, k=64)


def test_chain_improves_on_asg_when_zeta_small(c):
    """Thm 4.2 discussion: FedAvg→ASG beats ASG when ζ²/μ < Δ."""
    r = 20
    assert T.fedavg_asg_strongly_convex(c, r) < T.asg_strongly_convex(c, r)


def test_chain_exponentially_beats_fedavg(c):
    """min{Δ,ζ²/μ}·exp(−R/√κ) ≪ κζ²/μ·R⁻² at large R."""
    r = 200
    assert T.fedavg_asg_strongly_convex(c, r) < 1e-3 * T.fedavg_strongly_convex(c, r)


def test_lower_bound_below_upper_bounds(c):
    """Thm. 5.4 must lower-bound every achievable rate in the table."""
    for r in (5, 20, 80):
        lo = T.lower_bound_strongly_convex(c, r)
        for name, fn in T.TABLE1.items():
            if name == "lower_bound":
                continue
            assert lo <= fn(c, r) * 1.0001, (name, r)


def test_rates_monotone_in_r(c):
    for table in (T.TABLE1, T.TABLE2, T.TABLE4):
        for name, fn in table.items():
            vals = [fn(c, r) for r in (4, 16, 64, 256)]
            assert all(a >= b - 1e-12 for a, b in zip(vals, vals[1:])), name


def test_sampling_term_vanishes_at_full_participation():
    c_full = T.Constants(delta=10, d=3, mu=0.1, beta=1.0, zeta=1.0, n=8, s=8)
    c_part = T.Constants(delta=10, d=3, mu=0.1, beta=1.0, zeta=1.0, n=8, s=2)
    r = 1_000_000  # head terms gone; sampling term remains for s<n
    assert T.fedavg_sgd_strongly_convex(c_full, r) < \
        T.fedavg_sgd_strongly_convex(c_part, r)


def test_variance_reduction_tradeoff():
    """§4: SAGA drops the sampling-heterogeneity term but slows the linear
    rate to min{1/κ, S/N}."""
    c = T.Constants(delta=10, d=3, mu=0.5, beta=1.0, zeta=2.0, n=16, s=2)
    r_big = 4000
    assert T.fedavg_saga_strongly_convex(c, r_big) < \
        T.fedavg_sgd_strongly_convex(c, r_big)


def test_general_convex_chain_regime():
    """Table 2 discussion (β=D=1): FedAvg→ASG beats ASG iff ζ < 1/R²-ish."""
    r = 10
    small = T.Constants(delta=1, d=1, mu=0.0, beta=1.0, zeta=1.0 / r**2 / 4, n=8, s=8)
    assert T.fedavg_asg_convex(small, r) <= T.asg_convex(small, r) * 1.01


def test_pl_lower_bound_matches_cor55(c):
    assert T.lower_bound_pl(c, 10) == T.lower_bound_strongly_convex(c, 10)


def test_kappa_and_inf_handling():
    c0 = T.Constants(delta=1, d=1, mu=0.0, beta=1.0, zeta=1.0)
    assert math.isinf(c0.kappa)
    assert T.sgd_convex(c0, 10) > 0
