"""Sweep engine + single-compile executor guarantees.

(a) ``run_sweep`` over a seeds × η grid matches the per-call
    ``runner.run``/``Chain.run`` loop cell-for-cell;
(b) repeated executor calls never re-trace (``runner.TRACE_COUNTS`` is bumped
    by a Python side effect inside the traced bodies, so a cache hit leaves
    it unchanged);
(c) every algorithm honors the uniform state protocol the executors and the
    vmapped sweeps rely on.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import algorithms as A, chain, runner, sweep
from repro.core.algorithms import base
from repro.data import problems


@pytest.fixture(scope="module")
def quad():
    return problems.quadratic_problem(
        jax.random.PRNGKey(0), num_clients=8, dim=16, mu=0.1, beta=1.0,
        zeta=1.0, sigma=0.2, sigma_f=0.05)


SEEDS = (0, 1)
ETAS = (0.2, 0.5)


def test_sweep_matches_per_run_loop_algo(quad):
    algo = A.SGD(eta=0.4, k=4, mu_avg=quad.mu)
    x0 = quad.init_params(jax.random.PRNGKey(0))
    res = sweep.run_sweep(algo, quad, x0, 20, seeds=SEEDS, etas=ETAS)
    assert res.history.shape == (2, 2, 20)
    for i, sd in enumerate(SEEDS):
        for j, eta in enumerate(ETAS):
            r = runner.run(algo, quad, x0, 20, jax.random.PRNGKey(sd), eta=eta)
            np.testing.assert_allclose(
                np.asarray(res.history[i, j]), np.asarray(r.history),
                rtol=2e-4, atol=1e-6)
            np.testing.assert_allclose(
                float(res.final_sub[i, j]), float(r.history[-1]),
                rtol=2e-4, atol=1e-6)


def test_sweep_matches_per_run_loop_chain(quad):
    ch = chain.fedchain(
        A.FedAvg(eta=0.3, local_steps=3, inner_batch=2),
        A.SGD(eta=0.3, k=4, mu_avg=quad.mu), selection_k=4,
        name="sweep-eq-chain")
    x0 = quad.init_params(jax.random.PRNGKey(0))
    mults = (0.5, 1.0)
    res = sweep.run_sweep(ch, quad, x0, 16, seeds=SEEDS, etas=mults)
    assert res.history.shape == (2, 2, 16)
    assert res.selected_initial.shape == (2, 2, 1)
    for i, sd in enumerate(SEEDS):
        for j, m in enumerate(mults):
            r = ch.run(quad, x0, 16, jax.random.PRNGKey(sd), eta_scale=m)
            np.testing.assert_allclose(
                np.asarray(res.history[i, j]), np.asarray(r.history),
                rtol=2e-4, atol=1e-6)
            assert bool(res.selected_initial[i, j, 0]) == r.selected_initial[0]


def test_runner_single_compile(quad):
    algo = A.SGD(eta=0.35, k=3, mu_avg=quad.mu, name="cc-sgd")
    x0 = quad.init_params(jax.random.PRNGKey(0))
    runner.run(algo, quad, x0, 10, jax.random.PRNGKey(0))
    assert runner.TRACE_COUNTS["runner/cc-sgd"] >= 1
    with runner.assert_no_retrace(what="warm runner.run re-runs"):
        for s in range(1, 4):
            runner.run(algo, quad, x0, 10, jax.random.PRNGKey(s))


def test_chain_single_compile_with_selection_and_decay(quad):
    """A chain of N stages — selection rounds and stepsize decay included —
    executes in a single jit compile across repeated calls."""
    ch = chain.Chain(
        stages=[A.FedAvg(eta=0.3), A.Scaffold(eta=0.3),
                A.SGD(eta=0.3, k=4, mu_avg=quad.mu)],
        fractions=[0.3, 0.3, 0.4], selection_k=4, name="cc-chain")
    x0 = quad.init_params(jax.random.PRNGKey(0))
    decay = {"decay_first": 0.4, "decay_factor": 0.5}
    ch.run(quad, x0, 24, jax.random.PRNGKey(0), decay=decay)
    assert runner.TRACE_COUNTS["chain/cc-chain"] == 1  # one trace, whole chain
    with runner.assert_no_retrace(what="warm chain re-runs"):
        for s in range(1, 4):
            res = ch.run(quad, x0, 24, jax.random.PRNGKey(s), decay=decay)
    assert res.history.shape == (24,)
    assert len(res.selected_initial) == 2


def test_sweep_single_compile(quad):
    algo = A.SGD(eta=0.35, k=3, mu_avg=quad.mu, name="cc-sweep")
    x0 = quad.init_params(jax.random.PRNGKey(0))
    sweep.run_sweep(algo, quad, x0, 8, seeds=SEEDS, etas=ETAS)
    # vmap traces the cell once for the whole grid
    assert runner.TRACE_COUNTS["sweep/cc-sweep"] == 1
    with runner.assert_no_retrace(what="second sweep grid"):
        sweep.run_sweep(algo, quad, x0, 8, seeds=(2, 3), etas=(0.1, 0.3))


def test_sweep_eta_scale_mode(quad):
    """scale mode multiplies the state's own stepsize — the hook for
    algorithms that derive η from problem constants (SSNM)."""
    algo = A.SSNM(mu_h=quad.mu, beta=quad.beta, k=2)
    x0 = quad.init_params(jax.random.PRNGKey(0))
    res = sweep.run_sweep(algo, quad, x0, 6, seeds=(0,), etas=(1.0,),
                          eta_mode="scale")
    r = runner.run(algo, quad, x0, 6, jax.random.PRNGKey(0))
    np.testing.assert_allclose(np.asarray(res.history[0, 0]),
                               np.asarray(r.history), rtol=2e-4, atol=1e-6)


def test_state_protocol_all_algorithms(quad):
    x0 = quad.init_params(jax.random.PRNGKey(0))
    algos = [
        A.SGD(eta=0.3, k=2), A.NesterovSGD(eta=0.3, mu=0.1, beta=1.0, k=2),
        A.ACSA(mu=0.1, beta=1.0, k=2), A.FedAvg(eta=0.3),
        A.Scaffold(eta=0.3), A.SAGA(eta=0.3, k=2),
        A.SSNM(mu_h=0.1, beta=1.0, k=2), A.FedProx(eta=0.3),
    ]
    for algo in algos:
        state = base.audit_state(algo.init(quad, x0))
        # the executor relies on round() passing eta through unchanged
        out = algo.round(quad, state, jax.random.PRNGKey(1))
        assert float(out.eta) == float(state.eta), algo.name
        # stepsize override is a pure state edit (what sweeps batch over)
        st2 = algo.init_with_eta(quad, x0, eta=0.123)
        assert float(st2.eta) == pytest.approx(0.123), algo.name


def test_best_cell_skips_nonfinite():
    res = sweep.SweepResult(
        history=jnp.zeros((2, 2, 1)),
        final_sub=jnp.asarray([[jnp.inf, 3.0], [jnp.nan, 2.0]]),
        x_hat=None, seeds=(0, 1), etas=(0.1, 0.2))
    assert sweep.best_cell(res) == (1, 1)
