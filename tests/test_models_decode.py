"""Serving-path correctness: prefill + single-token decode must reproduce the
full-forward logits for EVERY architecture (KV caches, MLA latents, SSM
states, hybrid shared-block caches, enc-dec cross attention)."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import INPUT_SHAPES, registry
from repro.models import model_zoo, transformer

SHAPE = dataclasses.replace(INPUT_SHAPES["train_4k"], seq_len=32, global_batch=2)


def _decode_setup(arch):
    cfg = registry.get_config(arch, smoke=True)
    if cfg.moe is not None:  # avoid capacity-drop divergence between modes
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    key = jax.random.PRNGKey(0)
    params = transformer.init_model(cfg, key)
    batch = model_zoo.concrete_batch(cfg, SHAPE, key)
    return cfg, params, batch


@pytest.mark.parametrize("arch", registry.ARCH_IDS)
def test_prefill_decode_matches_forward(arch):
    cfg, params, batch = _decode_setup(arch)
    tlen = batch["tokens"].shape[1]
    s_pre = tlen // 2
    off = cfg.frontend.seq if (cfg.frontend is not None and
                               cfg.frontend.kind == "vision") else 0
    logits_full, _, _, _ = transformer.forward(params, cfg, batch, mode="train")

    cross = None
    if cfg.encoder is not None:
        enc = transformer._encode(params, cfg, batch["frames"].astype(jnp.float32))
        cross = transformer._cross_kv_from_encoder(params, cfg, enc)

    pre = dict(batch)
    pre["tokens"] = batch["tokens"][:, :s_pre]
    caches = transformer.init_caches(cfg, 2, tlen + off + 8)
    last, caches = transformer.prefill(params, cfg, pre, caches)
    # prefill's last logits match the full forward at position s_pre-1
    ref_last = logits_full[:, off + s_pre - 1, :]
    assert float(jnp.max(jnp.abs(last[:, 0] - ref_last))) < 2e-3

    tok = batch["tokens"][:, s_pre: s_pre + 1]
    if cfg.encoder is not None:
        dl, _ = transformer.decode_step(params, cfg, tok, caches, s_pre + off,
                                        cross_kv=cross)
    else:
        dl, _ = transformer.decode_step(params, cfg, tok, caches, s_pre + off)
    ref = logits_full[:, off + s_pre, :]
    assert float(jnp.max(jnp.abs(dl[:, 0] - ref))) < 2e-3


def test_mla_absorb_decode_equivalence():
    """§Perf optimization: absorbed MLA decode == naive MLA decode."""
    cfg, params, batch = _decode_setup("minicpm3-4b")
    cfg_abs = dataclasses.replace(
        cfg, mla=dataclasses.replace(cfg.mla, absorb_decode=True))
    tlen = batch["tokens"].shape[1]
    s_pre = tlen // 2
    pre = dict(batch)
    pre["tokens"] = batch["tokens"][:, :s_pre]
    caches = transformer.init_caches(cfg, 2, tlen + 8)
    _, caches = transformer.prefill(params, cfg, pre, caches)
    tok = batch["tokens"][:, s_pre: s_pre + 1]
    d_naive, _ = transformer.decode_step(params, cfg, tok, caches, s_pre)
    d_abs, _ = transformer.decode_step(params, cfg_abs, tok, caches, s_pre)
    assert float(jnp.max(jnp.abs(d_naive - d_abs))) < 2e-3


def test_multi_token_decode_chain():
    """Decode 8 tokens sequentially == slices of one long forward (mamba2)."""
    cfg, params, batch = _decode_setup("mamba2-1.3b")
    tlen = batch["tokens"].shape[1]
    s_pre = 16
    logits_full, _, _, _ = transformer.forward(params, cfg, batch, mode="train")
    pre = {"tokens": batch["tokens"][:, :s_pre]}
    caches = transformer.init_caches(cfg, 2, tlen + 8)
    _, caches = transformer.prefill(params, cfg, pre, caches)
    for j in range(8):
        tok = batch["tokens"][:, s_pre + j: s_pre + j + 1]
        dl, caches = transformer.decode_step(params, cfg, tok, caches, s_pre + j)
        ref = logits_full[:, s_pre + j, :]
        assert float(jnp.max(jnp.abs(dl[:, 0] - ref))) < 2e-3, f"token {j}"
