"""REQUIRED per-arch smoke tests (deliverable f): reduced variant of every
assigned architecture runs one forward + one train step on CPU, asserting
output shapes and no NaNs."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import INPUT_SHAPES, registry
from repro.models import model_zoo, transformer
from repro.optim import sgd

SHAPE = dataclasses.replace(INPUT_SHAPES["train_4k"], seq_len=64, global_batch=2)


@pytest.mark.parametrize("arch", registry.ARCH_IDS)
def test_smoke_forward_and_train_step(arch):
    cfg = registry.get_config(arch, smoke=True)
    assert cfg.num_layers <= 2 and cfg.d_model <= 512
    if cfg.moe is not None:
        assert cfg.moe.num_experts <= 4
    key = jax.random.PRNGKey(0)
    params = transformer.init_model(cfg, key)
    batch = model_zoo.concrete_batch(cfg, SHAPE, key)

    logits, _, aux, _ = transformer.forward(params, cfg, batch, mode="train")
    expect_seq = batch["tokens"].shape[1] + (
        cfg.frontend.seq if cfg.frontend is not None and cfg.frontend.kind == "vision" else 0)
    assert logits.shape == (2, expect_seq, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())

    opt = sgd(0.1)
    step = jax.jit(model_zoo.make_train_step(cfg, opt))
    params2, _, metrics = step(params, opt.init(params), batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    # parameters actually moved
    moved = any(
        float(jnp.max(jnp.abs(a - b))) > 0
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(params2)))
    assert moved


@pytest.mark.parametrize("arch", registry.ARCH_IDS)
def test_smoke_loss_decreases(arch):
    """A few steps of SGD on a fixed batch must reduce the loss."""
    cfg = registry.get_config(arch, smoke=True)
    key = jax.random.PRNGKey(1)
    params = transformer.init_model(cfg, key)
    batch = model_zoo.concrete_batch(cfg, SHAPE, key)
    opt = sgd(0.5 if cfg.tie_embeddings else 0.2)
    step = jax.jit(model_zoo.make_train_step(cfg, opt))
    state = opt.init(params)
    losses = []
    for _ in range(5):
        params, state, m = step(params, state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0]
