"""Run-telemetry subsystem (``repro.obs``) guarantees.

(a) ``telemetry=None`` is bitwise identical to a run without the obs layer
    on BOTH engines (vmapped and 1-device mesh), including the comm bits
    ledgers and the selection participation masks;
(b) enabling taps costs at most ONE extra compile per executor family and
    re-runs of either variant stay warm (``runner.TRACE_COUNTS``);
(c) the round taps satisfy their closed forms: ``update_norm`` is the norm
    of the server-iterate step, ``participation`` is the mask row-sum,
    ``policy_t`` counts rounds, EF-off residual norms are exactly 0.0;
(d) the event recorder writes the JSONL schema, closes under the context
    manager, turns executor traces into ``compile`` events, and the
    ``python -m repro.obs report`` CLI summarizes a log.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.comm import CommConfig
from repro.core import algorithms as A, chain as chain_lib, runner, sweep
from repro.core import tree_math as tm
from repro.data import problems
from repro.obs import Telemetry, events as obs_events
from repro.selection import SelectionPolicy, run_selection_sweep

SEEDS = (0, 1)
ETAS = (0.3, 0.6)
R = 6


@pytest.fixture(scope="module")
def spec():
    return problems.quadratic_spec(
        jax.random.PRNGKey(3), num_clients=6, dim=10, mu=0.1, beta=1.0,
        zeta=1.0, sigma=0.2)


@pytest.fixture(scope="module")
def algo(spec):
    return A.SGD(eta=0.4, k=4, mu_avg=0.1)


@pytest.fixture(scope="module")
def comm_cfg():
    return CommConfig(compressor="qsgd", qsgd_bits=4, participation=0.5,
                      error_feedback=True)


def _assert_bitwise(a, b):
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# --------------- (a) telemetry=None bitwise parity --------------------------

def test_telemetry_off_bitwise_parity_vmapped(spec, algo, comm_cfg):
    off = sweep.run_sweep(algo, spec, spec.x0, R, seeds=SEEDS, etas=ETAS,
                          comm=comm_cfg)
    on = sweep.run_sweep(algo, spec, spec.x0, R, seeds=SEEDS, etas=ETAS,
                         comm=comm_cfg, telemetry=Telemetry())
    assert off.diagnostics is None
    _assert_bitwise(off.history, on.history)
    _assert_bitwise(off.bits_up, on.bits_up)
    _assert_bitwise(off.bits_down, on.bits_down)
    _assert_bitwise(off.final_sub, on.final_sub)
    taps = on.diagnostics
    assert {"update_norm", "participation", "bits_up", "bits_down",
            "residual_up_norm", "residual_mom_norm",
            "residual_down_norm"} <= set(taps)
    for leaf in taps.values():
        assert leaf.shape == (len(SEEDS), len(ETAS), R)
    # the bits taps are the ledgers themselves, re-emitted per round
    _assert_bitwise(taps["bits_up"], on.bits_up)
    _assert_bitwise(taps["bits_down"], on.bits_down)


def test_telemetry_one_device_mesh_bitwise(spec, algo, comm_cfg):
    from repro.dist import make_grid_mesh

    tel = Telemetry()
    vm = sweep.run_sweep(algo, spec, spec.x0, R, seeds=SEEDS, etas=ETAS,
                         comm=comm_cfg, telemetry=tel)
    mesh = sweep.run_sweep(algo, spec, spec.x0, R, seeds=SEEDS, etas=ETAS,
                           comm=comm_cfg, telemetry=tel,
                           mesh=make_grid_mesh(1))
    _assert_bitwise(vm.history, mesh.history)
    _assert_bitwise(vm.bits_up, mesh.bits_up)
    for k in vm.diagnostics:
        _assert_bitwise(vm.diagnostics[k], mesh.diagnostics[k])
    off = sweep.run_sweep(algo, spec, spec.x0, R, seeds=SEEDS, etas=ETAS,
                          comm=comm_cfg, mesh=make_grid_mesh(1))
    assert off.diagnostics is None
    _assert_bitwise(off.history, mesh.history)


def test_telemetry_selection_parity_and_policy_taps(spec, algo):
    pols = (SelectionPolicy("uniform", participation=0.5),
            SelectionPolicy("ucb", participation=0.5, ucb_c=0.5))
    off = run_selection_sweep(algo, None, None, R, policies=pols,
                              problems=[spec], seeds=SEEDS, etas=(1.0,))
    on = run_selection_sweep(algo, None, None, R, policies=pols,
                             problems=[spec], seeds=SEEDS, etas=(1.0,),
                             telemetry=Telemetry())
    assert off.diagnostics is None
    _assert_bitwise(off.history, on.history)
    _assert_bitwise(off.masks, on.masks)
    _assert_bitwise(off.bits_up, on.bits_up)
    taps = on.diagnostics
    # closed form: round_select advances t by 1.0 per round from 0
    _assert_bitwise(
        taps["policy_t"],
        jnp.broadcast_to(jnp.arange(1.0, R + 1.0), taps["policy_t"].shape))
    # participation tap is the mask row-sum the masks record also carries
    _assert_bitwise(taps["participation"],
                    np.asarray(on.masks).sum(axis=-1))


# --------------- (b) compile budget ------------------------------------------

def test_telemetry_adds_at_most_one_compile_per_family(spec, algo, comm_cfg):
    runner.clear_executor_cache()
    run_off = lambda: sweep.run_sweep(  # noqa: E731
        algo, spec, spec.x0, R, seeds=SEEDS, etas=ETAS, comm=comm_cfg)
    run_on = lambda: sweep.run_sweep(  # noqa: E731
        algo, spec, spec.x0, R, seeds=SEEDS, etas=ETAS, comm=comm_cfg,
        telemetry=Telemetry())
    run_off()  # cold compile of the taps-off executors
    before = runner.snapshot_traces()
    run_on()  # the taps-on variant may compile each family ONCE
    deltas = runner.trace_deltas(before)
    assert deltas, "enabling telemetry must compile a distinct executor"
    assert all(v == 1 for v in deltas.values()), deltas
    with runner.assert_no_retrace(what="warm taps-on/off sweep re-runs"):
        run_off()
        run_on()


# --------------- (c) closed forms --------------------------------------------

def test_update_norm_closed_form(spec, algo):
    tel = Telemetry()
    key = jax.random.PRNGKey(11)
    # one round: the tap IS ‖x_1 − x_0‖ of the final server iterate (the
    # key stream folds the round count, so prefixes of longer runs differ)
    r1 = runner.run(algo, spec, spec.x0, 1, key, telemetry=tel)
    _assert_bitwise(r1.diagnostics["update_norm"][0],
                    tm.tree_norm(tm.tree_sub(r1.state.x, spec.x0)))
    r2 = runner.run(algo, spec, spec.x0, 2, key, telemetry=tel)
    assert r2.diagnostics["update_norm"].shape == (2,)
    assert np.all(np.asarray(r2.diagnostics["update_norm"]) > 0.0)
    # taps are deterministic: an identical warm call reproduces them bitwise
    again = runner.run(algo, spec, spec.x0, 2, key, telemetry=tel)
    _assert_bitwise(r2.diagnostics["update_norm"],
                    again.diagnostics["update_norm"])


def test_participation_and_ef_off_residual_closed_forms(spec, algo):
    cfg = CommConfig(compressor="qsgd", qsgd_bits=4, participation=0.5)
    masks = cfg.plan().round_masks(R, spec.num_clients, fold=0)
    res = runner.run(algo, spec, spec.x0, R, jax.random.PRNGKey(0), comm=cfg,
                     comm_masks=masks, telemetry=Telemetry())
    taps = res.diagnostics
    _assert_bitwise(taps["participation"], np.asarray(masks).sum(axis=-1))
    # error feedback off → the residual tables are [N, 0] → norms exactly 0.0
    assert np.all(np.asarray(taps["residual_up_norm"]) == 0.0)
    assert np.all(np.asarray(taps["residual_mom_norm"]) == 0.0)
    assert np.all(np.asarray(taps["residual_down_norm"]) == 0.0)


def test_grad_norm_opt_in_and_stage_tap(spec, algo):
    res = sweep.run_sweep(algo, spec, spec.x0, R, seeds=SEEDS, etas=ETAS,
                          telemetry=Telemetry())
    assert "grad_norm" not in res.diagnostics  # costs a gradient: opt-in
    withg = sweep.run_sweep(algo, spec, spec.x0, R, seeds=SEEDS, etas=ETAS,
                            telemetry=Telemetry(grad_norm=True))
    assert np.all(np.asarray(withg.diagnostics["grad_norm"]) > 0.0)

    ch = chain_lib.fedchain(
        A.FedAvg(eta=0.3, local_steps=2, inner_batch=2),
        A.SGD(eta=0.4, k=4, mu_avg=0.1))
    cres = sweep.run_sweep(ch, spec, spec.x0, R, seeds=SEEDS, etas=ETAS,
                           eta_mode="scale", telemetry=Telemetry())
    stage = np.asarray(cres.diagnostics["stage"])
    assert stage.dtype == np.int32
    assert np.all(stage[..., 0] == 0) and np.all(stage[..., -1] == 1)
    assert np.all(np.diff(stage, axis=-1) >= 0)  # stages never rewind


def test_run_rejects_telemetry_for_decay_and_fraction_families(spec):
    ch = chain_lib.fedchain(
        A.FedAvg(eta=0.3, local_steps=2, inner_batch=2),
        A.SGD(eta=0.4, k=4, mu_avg=0.1))
    for axis in ({"fractions": (0.5,)}, {"decay_factors": (0.5,)}):
        with pytest.raises(ValueError, match="telemetry"):
            sweep.run(sweep.SweepRequest(
                algo_or_chain=ch, problem=spec, x0=spec.x0, rounds=4,
                seeds=(0,), telemetry=Telemetry(), **axis))


# --------------- (d) event recorder + report ---------------------------------

def test_event_recorder_jsonl_and_context_close(tmp_path):
    path = str(tmp_path / "events.jsonl")
    with obs_events.EventRecorder(path, window=2) as rec:
        rec.event("phase", name="x")
        rec.metric(0, loss=2.0)
        rec.metric(1, loss=4.0)
        rec.metric(2, loss=6.0)
        assert rec.mean("loss") == pytest.approx(5.0)  # window=2 keeps last 2
    assert rec._fh is None  # context manager closed the handle
    import json

    with open(path) as f:
        recs = [json.loads(line) for line in f]
    assert [r["kind"] for r in recs] == ["phase", "metric", "metric", "metric"]
    assert recs[1]["loss"] == 2.0 and recs[1]["step"] == 0


def test_recording_emits_compile_events_per_trace(spec, algo):
    runner.clear_executor_cache()
    with obs_events.recording() as rec:
        runner.run(algo, spec, spec.x0, 4, jax.random.PRNGKey(0))
        compiles = [r for r in rec.records if r["kind"] == "compile"]
        assert compiles, "a cold executor call must emit a compile event"
        assert all(r["compile_s"] > 0 and r["trace_tags"] for r in compiles)
        n = len(rec.records)
        runner.run(algo, spec, spec.x0, 4, jax.random.PRNGKey(0))
        warm = [r for r in rec.records[n:] if r["kind"] == "compile"]
        assert warm == [], "a warm cache hit must not emit compile events"
    assert obs_events.RECORDER is None  # recording() uninstalls on exit


def test_metrics_logger_is_obs_schema_and_report_cli(tmp_path, capsys):
    from repro.launch.metrics import MetricsLogger, read_jsonl
    from repro.obs.__main__ import main as obs_main

    path = str(tmp_path / "metrics.jsonl")
    with MetricsLogger(path) as m:
        for step in range(4):
            m.log(step, loss=float(step))
    recs = read_jsonl(path)
    assert all(r["kind"] == "metric" for r in recs)
    assert obs_main(["report", path]) == 0
    out = capsys.readouterr().out
    assert "metrics: 4 record(s)" in out and "loss" in out
    assert obs_main(["report", str(tmp_path / "missing.jsonl")]) == 2
