"""FedChain (Algo 1) behaviour: selection, chaining gains, multistage."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import algorithms as A, chain, runner, selection
from repro.data import problems


@pytest.fixture(scope="module")
def het_problem():
    # moderate heterogeneity + gradient noise: the regime where chaining wins
    return problems.quadratic_problem(
        jax.random.PRNGKey(0), num_clients=8, dim=16, mu=0.05, beta=1.0,
        zeta=2.0, sigma=0.5, sigma_f=0.0)


def test_selection_noiseless_exact(het_problem):
    p = het_problem
    good = p.x_star
    bad = p.x_star + 5.0
    best, idx, vals = selection.select_better(
        p, [bad, good], jax.random.PRNGKey(1), s=8, k=4)
    assert int(idx) == 1
    np.testing.assert_allclose(best, good)
    assert float(vals[1]) < float(vals[0])


def test_selection_uses_shared_samples(het_problem):
    """Identical candidates must tie exactly (same ẑ samples for both)."""
    p = problems.quadratic_problem(
        jax.random.PRNGKey(0), dim=8, sigma_f=1.0)
    x = p.init_params(jax.random.PRNGKey(0))
    vals = selection.empirical_values(p, [x, x], jax.random.PRNGKey(2), s=4, k=4)
    assert float(jnp.abs(vals[0] - vals[1])) == 0.0


def test_fedchain_caps_error_at_min(het_problem):
    """With huge ζ, A_local diverges from x*; selection must keep x̂_0's
    quality: chain final ≤ FedAvg-only final."""
    p = problems.quadratic_problem(
        jax.random.PRNGKey(1), num_clients=8, dim=12, mu=0.1, beta=1.0,
        zeta=20.0, sigma=0.0)
    x0 = p.init_params(jax.random.PRNGKey(0))
    fa = A.FedAvg(eta=0.5, local_steps=8, inner_batch=2)
    sgd = A.SGD(eta=0.5, k=4, mu_avg=0.1)
    ch = chain.fedchain(fa, sgd, selection_k=8)
    cres = ch.run(p, x0, 40, jax.random.PRNGKey(2))
    fres = runner.run(fa, p, x0, 40, jax.random.PRNGKey(3))
    tol = 1e-4 * float(p.delta(x0))  # f32 noise floor near the optimum
    assert float(p.suboptimality(cres.x_hat)) <= float(fres.history[-1]) + tol


def test_fedchain_beats_both_moderate_heterogeneity(het_problem):
    """Fig. 2's qualitative claim: chain ≤ both phases alone (same R)."""
    p = het_problem
    x0 = p.init_params(jax.random.PRNGKey(0))
    rounds = 60
    fa = A.FedAvg(eta=0.3, local_steps=8, inner_batch=4)
    sgd = A.SGD(eta=0.3, k=16, mu_avg=p.mu)
    ch = chain.fedchain(fa, sgd, selection_k=16)

    def med(run_fn, n=5):
        return float(np.median([run_fn(s) for s in range(n)]))

    sub_chain = med(lambda s: float(p.suboptimality(
        ch.run(p, x0, rounds, jax.random.PRNGKey(10 + s)).x_hat)))
    sub_fa = med(lambda s: float(runner.run(
        fa, p, x0, rounds, jax.random.PRNGKey(20 + s)).history[-1]))
    sub_sgd = med(lambda s: float(runner.run(
        sgd, p, x0, rounds, jax.random.PRNGKey(30 + s)).history[-1]))
    assert sub_chain <= 1.5 * min(sub_fa, sub_sgd)
    assert sub_chain < max(sub_fa, sub_sgd)


def test_chain_history_length(het_problem):
    p = het_problem
    x0 = p.init_params(jax.random.PRNGKey(0))
    ch = chain.fedchain(
        A.FedAvg(eta=0.3), A.SGD(eta=0.3, k=4), selection_k=4)
    res = ch.run(p, x0, 30, jax.random.PRNGKey(1))
    assert res.history.shape == (30,)  # selection costs one round
    assert len(res.switch_rounds) == 1


def test_three_stage_chain(het_problem):
    p = het_problem
    x0 = p.init_params(jax.random.PRNGKey(0))
    ch = chain.Chain(
        stages=[A.FedAvg(eta=0.3), A.Scaffold(eta=0.3), A.SGD(eta=0.3, k=8, mu_avg=p.mu)],
        fractions=[0.3, 0.3, 0.4], selection_k=8)
    res = ch.run(p, x0, 40, jax.random.PRNGKey(1))
    assert jnp.isfinite(res.history).all()
    assert float(p.suboptimality(res.x_hat)) < float(res.history[0])


def test_selection_error_bound_formula():
    p = problems.quadratic_problem(jax.random.PRNGKey(0), num_clients=10,
                                   dim=4, zeta=1.0, sigma_f=0.5)
    full = selection.selection_error_bound(p, s=10, k=16)
    partial = selection.selection_error_bound(p, s=2, k=16)
    assert full < partial  # full participation kills the ζ_F term
