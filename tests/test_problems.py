import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import heterogeneity
from repro.data import problems


def test_quadratic_zeta_exact(rng):
    """ζ is exact by construction: ∇F_i − ∇F = ζ·u_i, max ||u_i|| = 1."""
    for zeta in (0.0, 0.5, 3.0):
        p = problems.quadratic_problem(rng, num_clients=6, dim=10, zeta=zeta)
        x = jax.random.normal(jax.random.PRNGKey(3), (10,))
        measured = float(heterogeneity.zeta_at(p, x))
        assert abs(measured - zeta) < 1e-4


def test_quadratic_fstar_is_min(rng):
    p = problems.quadratic_problem(rng, dim=8, mu=0.2, beta=2.0, zeta=1.0)
    g = jax.grad(p.global_loss)(p.x_star)
    assert float(jnp.linalg.norm(g)) < 1e-4
    assert float(p.global_loss(p.x_star)) == pytest.approx(p.f_star, abs=1e-4)


def test_gradient_oracle_unbiased_and_bounded_variance(rng):
    p = problems.quadratic_problem(rng, dim=6, sigma=0.7)
    x = p.init_params(rng)
    keys = jax.random.split(jax.random.PRNGKey(9), 4096)
    gs = jax.vmap(lambda k: p.grad_oracle(x, 0, k))(keys)
    exact = jax.grad(p.client_loss)(x, 0)
    err = jnp.linalg.norm(jnp.mean(gs, 0) - exact)
    assert float(err) < 0.1
    var = float(jnp.mean(jnp.sum((gs - exact) ** 2, -1)))
    assert var == pytest.approx(0.7**2, rel=0.2)


def test_perturbed_global_equals_base(rng):
    p = problems.general_convex_problem(rng, num_clients=5, zeta=2.0)
    x = jax.random.normal(rng, (16,))
    # global loss must equal the base (Σ u_i = 0)
    mean_client = jnp.mean(jnp.stack(
        [p.client_loss(x, i) for i in range(5)]))
    assert float(jnp.abs(mean_client - p.global_loss(x))) < 1e-4


def test_pl_problem_satisfies_pl(rng):
    """2μ(F−F*) ≤ ||∇F||² at random points for the PL base."""
    p = problems.pl_problem(rng, num_clients=4, zeta=1.0)
    xs = jax.random.normal(rng, (64, 8)) * 3
    for x in xs[:16]:
        lhs = 2 * p.mu * (p.global_loss(x) - p.f_star)
        rhs = float(jnp.sum(jax.grad(p.global_loss)(x) ** 2))
        assert float(lhs) <= rhs + 1e-5


def test_logreg_problem(rng):
    feats = np.random.default_rng(0).normal(size=(4, 50, 8)).astype(np.float32)
    labels = (np.random.default_rng(1).random((4, 50)) > 0.5).astype(np.float32)
    p = problems.logreg_problem(rng, features=jnp.asarray(feats),
                                labels=jnp.asarray(labels), l2=0.1)
    w = p.init_params(rng)
    assert w.shape == (8,)
    loss = float(p.global_loss(w))
    assert loss == pytest.approx(np.log(2), rel=0.01)  # w=0 => ln 2
    g = p.grad_oracle(w, 0, jax.random.PRNGKey(5))
    assert g.shape == (8,)


@given(zeta=st.floats(0.0, 5.0), seed=st.integers(0, 100))
@settings(max_examples=15, deadline=None)
def test_zeta_constant_in_x(zeta, seed):
    """Heterogeneity of the shared-curvature quadratic is position-free."""
    p = problems.quadratic_problem(jax.random.PRNGKey(seed), dim=6, zeta=zeta)
    x1 = jax.random.normal(jax.random.PRNGKey(seed + 1), (6,))
    x2 = 10 * jax.random.normal(jax.random.PRNGKey(seed + 2), (6,))
    z1 = float(heterogeneity.zeta_at(p, x1))
    z2 = float(heterogeneity.zeta_at(p, x2))
    assert abs(z1 - z2) < 1e-3


def test_curvature_spread_biases_fedavg(rng):
    """With heterogeneous curvature FedAvg's fixed point moves off x*
    (the drift no longer cancels by symmetry) — the regime motivating the
    selection step; with spread=0 the drift cancels exactly."""
    from repro.core import algorithms as A, runner

    for spread, expect_bias in ((0.0, False), (1.5, True)):
        p = problems.quadratic_problem(
            jax.random.PRNGKey(2), num_clients=8, dim=12, mu=0.1, beta=1.0,
            zeta=5.0, sigma=0.0, curvature_spread=spread)
        fa = A.FedAvg(eta=0.5, local_steps=8, inner_batch=1)
        res = runner.run(fa, p, p.x_star, 30, jax.random.PRNGKey(3))
        sub = float(res.history[-1])  # starting AT x*: any growth is drift bias
        if expect_bias:
            assert sub > 1e-4, sub
        else:
            assert sub < 1e-4, sub


def test_curvature_spread_reports_ball_zeta(rng):
    p0 = problems.quadratic_problem(jax.random.PRNGKey(0), zeta=1.0)
    p1 = problems.quadratic_problem(jax.random.PRNGKey(0), zeta=1.0,
                                    curvature_spread=1.0)
    assert p1.zeta > p0.zeta  # position-dependent part included
