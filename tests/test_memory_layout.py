"""Operand-layout invariants: the O(P) indexed layout is bitwise identical
to the O(P·S) stacked reference layout, on the vmapped AND the sharded
engine, including comm bits accounting — and actually shrinks the
spec-operand bytes by ≥ the seed count with zero warm re-traces."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.comm import CommConfig
from repro.core import algorithms as A, chain, runner, sweep
from repro.data import spec as spec_lib

SEEDS = (0, 1, 2)
ETAS = (0.3, 0.5)


def _zeta_specs(n=3, dim=12):
    return [spec_lib.quadratic_spec(
        jax.random.PRNGKey(11 + i), num_clients=8, dim=dim, mu=0.1, beta=1.0,
        zeta=0.5 * i, sigma=0.2, sigma_f=0.05) for i in range(n)]


def _assert_bitwise(ref, res, *, bits=False):
    np.testing.assert_array_equal(np.asarray(ref.history),
                                  np.asarray(res.history))
    np.testing.assert_array_equal(np.asarray(ref.final_sub),
                                  np.asarray(res.final_sub))
    if bits:
        np.testing.assert_array_equal(np.asarray(ref.bits_up),
                                      np.asarray(res.bits_up))
        np.testing.assert_array_equal(np.asarray(ref.bits_down),
                                      np.asarray(res.bits_down))


def _grid(algo, specs, layout, *, comm=None, mesh=None, rounds=6):
    return sweep.run_sweep(algo, None, None, rounds, seeds=SEEDS, etas=ETAS,
                           problems=specs, comm=comm, mesh=mesh,
                           operand_layout=layout)


def test_indexed_matches_stacked_bitwise_algo():
    specs = _zeta_specs()
    algo = A.SGD(eta=0.4, k=3, mu_avg=0.1)
    ref = _grid(algo, specs, "stacked")
    res = _grid(algo, specs, "indexed")
    _assert_bitwise(ref, res)


def test_indexed_matches_stacked_bitwise_chain():
    specs = _zeta_specs()
    ch = chain.fedchain(A.FedAvg.from_k(4, eta=0.4),
                        A.SGD(eta=0.4, k=4, mu_avg=0.1), selection_k=4)
    ref = sweep.run_sweep(ch, None, None, 6, seeds=SEEDS, etas=(0.5, 1.0),
                          problems=specs, operand_layout="stacked")
    res = sweep.run_sweep(ch, None, None, 6, seeds=SEEDS, etas=(0.5, 1.0),
                          problems=specs, operand_layout="indexed")
    _assert_bitwise(ref, res)


@pytest.mark.parametrize("cfg", [
    CommConfig(compressor="qsgd", qsgd_bits=4, participation=0.5),
    CommConfig(compressor="topk", spars_k=2, error_feedback=True),
])
def test_indexed_matches_stacked_comm_bits(cfg):
    """Comm sweeps: same results AND the same per-round bits accounting —
    the per-cell mask schedules key off the cell index, which both layouts
    must derive identically."""
    specs = _zeta_specs()
    algo = A.SGD(eta=0.3, k=3, mu_avg=0.1)
    ref = _grid(algo, specs, "stacked", comm=cfg)
    res = _grid(algo, specs, "indexed", comm=cfg)
    _assert_bitwise(ref, res, bits=True)


def test_indexed_matches_stacked_sharded_one_device():
    """The shard_mapped engine under both layouts, on a 1-device ('grid',)
    mesh, against the vmapped indexed reference — all three bitwise equal
    (multi-device parity lives in test_dist_sweep's subprocess tests)."""
    from repro.dist import make_grid_mesh

    mesh = make_grid_mesh(1)
    specs = _zeta_specs()
    algo = A.SGD(eta=0.4, k=3, mu_avg=0.1)
    ref = _grid(algo, specs, "indexed")
    for layout in sweep._OPERAND_LAYOUTS:
        res = _grid(algo, specs, layout, mesh=mesh)
        _assert_bitwise(ref, res)


def test_indexed_operand_bytes_reduction():
    """The point of the layout: spec-operand bytes shrink by ≥ S× (the
    stacked layout repeats every spec/x0 leaf exactly once per seed)."""
    specs = _zeta_specs()
    stacked, _ = sweep._as_stacked_specs(specs)
    x0_stack = sweep._normalize_x0_stack(None, stacked, len(specs))
    keys = jnp.stack([jax.random.PRNGKey(s) for s in SEEDS])

    def spec_bytes(layout):
        spec_op, x0_op, _, _ = sweep.build_problem_operands(
            stacked, x0_stack, keys, len(specs), len(SEEDS), layout)
        return sum(l.nbytes for l in jax.tree.leaves((spec_op, x0_op)))

    assert spec_bytes("stacked") >= len(SEEDS) * spec_bytes("indexed")


def test_indexed_pidx_maps_cells_to_problems():
    pidx = sweep.problem_index_operand(3, 4)
    assert pidx.dtype == jnp.int32 and pidx.shape == (12,)
    np.testing.assert_array_equal(np.asarray(pidx), np.arange(12) // 4)


def test_indexed_zero_warm_retraces():
    """Re-running an indexed grid must not move TRACE_COUNTS at all — the
    gather cannot leak fresh trace keys into the executor cache."""
    specs = _zeta_specs()
    algo = A.SGD(eta=0.4, k=3, mu_avg=0.1)
    _grid(algo, specs, "indexed")  # compile
    with runner.assert_no_retrace(what="warm indexed re-run"):
        out = _grid(algo, specs, "indexed")
        jax.block_until_ready(out.history)


def test_operand_layout_rejects_unknown():
    specs = _zeta_specs(n=2)
    with pytest.raises(ValueError, match="operand_layout"):
        _grid(A.SGD(eta=0.4, k=2), specs, "repeated")
