import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import tree_math as tm


def _tree(key, shapes=((3,), (2, 4))):
    ks = jax.random.split(key, len(shapes))
    return {f"p{i}": jax.random.normal(k, s) for i, (k, s) in enumerate(zip(ks, shapes))}


def test_add_sub_roundtrip(rng):
    a, b = _tree(rng), _tree(jax.random.PRNGKey(1))
    c = tm.tree_sub(tm.tree_add(a, b), b)
    for l1, l2 in zip(jax.tree.leaves(a), jax.tree.leaves(c)):
        np.testing.assert_allclose(l1, l2, rtol=1e-6)


def test_axpy_matches_scale_add(rng):
    a, b = _tree(rng), _tree(jax.random.PRNGKey(1))
    c1 = tm.tree_axpy(0.7, a, b)
    c2 = tm.tree_add(tm.tree_scale(0.7, a), b)
    for l1, l2 in zip(jax.tree.leaves(c1), jax.tree.leaves(c2)):
        np.testing.assert_allclose(l1, l2, rtol=1e-6)


@given(t=st.floats(0.0, 1.0))
@settings(max_examples=20, deadline=None)
def test_lerp_endpoints(t):
    a = {"x": jnp.asarray([1.0, 2.0])}
    b = {"x": jnp.asarray([3.0, -2.0])}
    out = tm.tree_lerp(t, a, b)["x"]
    expect = (1 - t) * a["x"] + t * b["x"]
    np.testing.assert_allclose(out, expect, rtol=1e-6)


def test_dot_norm_consistency(rng):
    a = _tree(rng)
    assert abs(float(tm.tree_dot(a, a)) - float(tm.tree_sq_norm(a))) < 1e-5
    assert abs(float(tm.tree_norm(a)) ** 2 - float(tm.tree_sq_norm(a))) < 1e-3


def test_stack_index_mean(rng):
    trees = [_tree(jax.random.PRNGKey(i)) for i in range(4)]
    stacked = tm.tree_stack(trees)
    t2 = tm.tree_index(stacked, 2)
    for l1, l2 in zip(jax.tree.leaves(trees[2]), jax.tree.leaves(t2)):
        np.testing.assert_allclose(l1, l2)
    mean = tm.tree_mean_leading(stacked)
    expect = jax.tree.map(lambda *xs: sum(xs) / 4, *trees)
    for l1, l2 in zip(jax.tree.leaves(expect), jax.tree.leaves(mean)):
        np.testing.assert_allclose(l1, l2, rtol=1e-6)


def test_scatter_set(rng):
    table = {"w": jnp.zeros((5, 3))}
    vals = {"w": jnp.ones((2, 3))}
    out = tm.tree_scatter_set(table, jnp.asarray([1, 3]), vals)
    assert float(out["w"][1].sum()) == 3.0
    assert float(out["w"][0].sum()) == 0.0


def test_size_and_ravel(rng):
    a = _tree(rng)
    assert tm.tree_size(a) == 3 + 8
    assert tm.ravel(a).shape == (11,)


# trailing (per-row) leaf shapes for the kernel-boundary layout: scalar rows
# ([S] leaves), empty dims ([S, 0] / [S, 3, 0] leaves — zero elements but a
# real shape the reshape must preserve), and higher-rank tensors
_TRAILING = st.lists(
    st.one_of(
        st.just(()),  # scalar per row
        st.lists(st.integers(0, 4), min_size=1, max_size=3).map(tuple),
    ),
    min_size=1, max_size=4)


@given(s=st.integers(1, 5), trailing=_TRAILING, data=st.data())
@settings(max_examples=40, deadline=None)
def test_ravel_rows_roundtrip(s, trailing, data):
    """tree_unravel_rows ∘ tree_ravel_rows is the identity — bitwise, for any
    [S, ...] pytree including scalar-row and empty-dim leaves."""
    tree = {}
    for i, tr in enumerate(trailing):
        n = s * int(np.prod(tr)) if tr else s
        vals = data.draw(st.lists(
            st.floats(-1e6, 1e6, allow_nan=False, width=32),
            min_size=n, max_size=n))
        tree[f"p{i}"] = jnp.asarray(vals, jnp.float32).reshape((s,) + tr)
    rows = tm.tree_ravel_rows(tree)
    for leaf, orig in zip(jax.tree.leaves(rows), jax.tree.leaves(tree)):
        assert leaf.ndim == 2 and leaf.shape[0] == s
        assert leaf.size == orig.size
    back = tm.tree_unravel_rows(rows, tree)
    assert jax.tree.structure(back) == jax.tree.structure(tree)
    for l1, l2 in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        assert l1.shape == l2.shape and l1.dtype == l2.dtype
        assert np.array_equal(np.asarray(l1), np.asarray(l2))


@given(s=st.integers(1, 4), d=st.integers(0, 7))
@settings(max_examples=20, deadline=None)
def test_ravel_rows_flat_leaf_is_noop(s, d):
    """On a single already-2D [S, D] leaf the ravel is the identity object-
    level reshape — the flat-vector comm paths must stay bitwise untouched."""
    x = jnp.arange(s * d, dtype=jnp.float32).reshape(s, d)
    tree = {"w": x}
    rows = tm.tree_ravel_rows(tree)
    assert rows["w"].shape == (s, d)
    assert np.array_equal(np.asarray(rows["w"]), np.asarray(x))
    back = tm.tree_unravel_rows(rows, tree)
    assert np.array_equal(np.asarray(back["w"]), np.asarray(x))
