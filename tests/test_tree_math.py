import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import tree_math as tm


def _tree(key, shapes=((3,), (2, 4))):
    ks = jax.random.split(key, len(shapes))
    return {f"p{i}": jax.random.normal(k, s) for i, (k, s) in enumerate(zip(ks, shapes))}


def test_add_sub_roundtrip(rng):
    a, b = _tree(rng), _tree(jax.random.PRNGKey(1))
    c = tm.tree_sub(tm.tree_add(a, b), b)
    for l1, l2 in zip(jax.tree.leaves(a), jax.tree.leaves(c)):
        np.testing.assert_allclose(l1, l2, rtol=1e-6)


def test_axpy_matches_scale_add(rng):
    a, b = _tree(rng), _tree(jax.random.PRNGKey(1))
    c1 = tm.tree_axpy(0.7, a, b)
    c2 = tm.tree_add(tm.tree_scale(0.7, a), b)
    for l1, l2 in zip(jax.tree.leaves(c1), jax.tree.leaves(c2)):
        np.testing.assert_allclose(l1, l2, rtol=1e-6)


@given(t=st.floats(0.0, 1.0))
@settings(max_examples=20, deadline=None)
def test_lerp_endpoints(t):
    a = {"x": jnp.asarray([1.0, 2.0])}
    b = {"x": jnp.asarray([3.0, -2.0])}
    out = tm.tree_lerp(t, a, b)["x"]
    expect = (1 - t) * a["x"] + t * b["x"]
    np.testing.assert_allclose(out, expect, rtol=1e-6)


def test_dot_norm_consistency(rng):
    a = _tree(rng)
    assert abs(float(tm.tree_dot(a, a)) - float(tm.tree_sq_norm(a))) < 1e-5
    assert abs(float(tm.tree_norm(a)) ** 2 - float(tm.tree_sq_norm(a))) < 1e-3


def test_stack_index_mean(rng):
    trees = [_tree(jax.random.PRNGKey(i)) for i in range(4)]
    stacked = tm.tree_stack(trees)
    t2 = tm.tree_index(stacked, 2)
    for l1, l2 in zip(jax.tree.leaves(trees[2]), jax.tree.leaves(t2)):
        np.testing.assert_allclose(l1, l2)
    mean = tm.tree_mean_leading(stacked)
    expect = jax.tree.map(lambda *xs: sum(xs) / 4, *trees)
    for l1, l2 in zip(jax.tree.leaves(expect), jax.tree.leaves(mean)):
        np.testing.assert_allclose(l1, l2, rtol=1e-6)


def test_scatter_set(rng):
    table = {"w": jnp.zeros((5, 3))}
    vals = {"w": jnp.ones((2, 3))}
    out = tm.tree_scatter_set(table, jnp.asarray([1, 3]), vals)
    assert float(out["w"][1].sum()) == 3.0
    assert float(out["w"][0].sum()) == 0.0


def test_size_and_ravel(rng):
    a = _tree(rng)
    assert tm.tree_size(a) == 3 + 8
    assert tm.ravel(a).shape == (11,)
