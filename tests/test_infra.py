"""Infrastructure tests: sharding rules, checkpointing, optimizers, data."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import adamw, momentum, sgd, Schedule
from repro.sharding.rules import param_logical_axes, cache_logical_axes


# --------------------------- sharding rules ---------------------------------

def test_param_rules_match_names():
    assert param_logical_axes("seg0/attn/wq", 3) == ("embed", "heads", "head_dim")
    assert param_logical_axes("seg0/attn/wq", 4) == ("layers", "embed", "heads", "head_dim")
    assert param_logical_axes("embed/embedding", 2) == ("vocab", "embed")
    assert param_logical_axes("seg1/moe/we_gate", 4) == ("layers", "experts", "embed", "ff")
    assert param_logical_axes("unknown/leaf", 2) == (None, None)


def test_cache_rules():
    assert cache_logical_axes("/seg0/k", 5) == (
        "layers", "batch", "cache_seq", "kv_heads", "head_dim")
    assert cache_logical_axes("/seg0/c_kv", 4) == (
        "layers", "batch", "cache_seq", "kv_lora")
    assert cache_logical_axes("/mamba/ssm", 5) == (
        "layers", "batch", "heads", "head_dim", "ssm_state")


def test_ruleset_divisibility_and_dedup():
    from repro.dist import compat
    from repro.sharding import RuleSet

    mesh = compat.make_mesh((1,), ("model",))
    rs = RuleSet(mesh)
    # axis size 1 always divides
    spec = rs.spec_for(("experts", "embed", "ff"), (4, 8, 16))
    # 'model' must appear at most once
    used = [s for s in spec if s is not None]
    assert len(used) <= 1


# --------------------------- checkpoint --------------------------------------

def test_checkpoint_roundtrip(tmp_path):
    from repro.checkpoint import latest_step, restore, save_checkpoint

    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": {"c": jnp.ones((4,), jnp.bfloat16)}}
    d = str(tmp_path / "ckpt")
    save_checkpoint(d, 10, tree)
    save_checkpoint(d, 20, jax.tree.map(lambda t: t + 1, tree))
    assert latest_step(d) == 20
    back = restore(d, 20, tree)
    np.testing.assert_allclose(np.asarray(back["a"]), np.asarray(tree["a"]) + 1)
    assert back["b"]["c"].dtype == jnp.bfloat16


def test_checkpoint_rotation(tmp_path):
    from repro.checkpoint import latest_steps, save_checkpoint

    tree = {"x": jnp.zeros((2,))}
    d = str(tmp_path / "ck")
    for s in range(6):
        save_checkpoint(d, s, tree, keep=3)
    assert latest_steps(d) == [3, 4, 5]


def test_checkpoint_shape_mismatch_raises(tmp_path):
    from repro.checkpoint import restore, save_checkpoint

    d = str(tmp_path / "ck2")
    save_checkpoint(d, 1, {"x": jnp.zeros((2,))})
    with pytest.raises(ValueError):
        restore(d, 1, {"x": jnp.zeros((3,))})


# --------------------------- optimizers --------------------------------------

def _quad_grad(p):
    return jax.tree.map(lambda t: 2 * t, p)


@pytest.mark.parametrize("opt", [sgd(0.1), momentum(0.1), adamw(0.1)],
                         ids=["sgd", "momentum", "adamw"])
def test_optimizers_descend(opt):
    params = {"w": jnp.asarray([4.0, -2.0])}
    state = opt.init(params)
    for _ in range(50):
        params, state = opt.update(_quad_grad(params), state, params)
    assert float(jnp.abs(params["w"]).max()) < 0.3


def test_momentum_dtype_preserved():
    opt = momentum(0.1)
    params = {"w": jnp.ones((3,), jnp.bfloat16)}
    state = opt.init(params)
    p2, _ = opt.update({"w": jnp.ones((3,), jnp.bfloat16)}, state, params)
    assert p2["w"].dtype == jnp.bfloat16


def test_schedule():
    s = Schedule(base_lr=1.0, warmup_steps=10, decay_every=100, decay_factor=0.5)
    assert float(s(0)) == pytest.approx(0.1)
    assert float(s(9)) == pytest.approx(1.0)
    assert float(s(150)) == pytest.approx(0.5)


# --------------------------- data --------------------------------------------

def test_shuffled_heterogeneity_partition():
    from repro.data.partition import shuffled_heterogeneity

    feats = np.random.default_rng(0).normal(size=(10, 40, 7)).astype(np.float32)
    for frac in (0.0, 0.5, 1.0):
        cx, cy = shuffled_heterogeneity(
            feats, homogeneous_frac=frac, num_clients=5, seed=1)
        assert cx.shape[0] == 5 and cx.shape[2] == 7
        assert cy.shape[:2] == cx.shape[:2]
    # 0% homogeneous: client i holds only classes 2i, 2i+1
    cx, cy = shuffled_heterogeneity(feats, homogeneous_frac=0.0, num_clients=5)
    assert set(np.unique(cy[0])) == {0, 1}
    assert set(np.unique(cy[4])) == {8, 9}


def test_token_stream_deterministic():
    from repro.data.tokens import SyntheticTokenStream, TokenStreamConfig

    cfg = TokenStreamConfig(vocab_size=64, seq_len=16, batch_size=2,
                            num_clients=3, heterogeneity=0.5)
    s1 = SyntheticTokenStream(cfg)
    s2 = SyntheticTokenStream(cfg)
    b1 = s1.batch(1, 7)["tokens"]
    b2 = s2.batch(1, 7)["tokens"]
    np.testing.assert_array_equal(np.asarray(b1), np.asarray(b2))
    b3 = s1.batch(2, 7)["tokens"]
    assert not np.array_equal(np.asarray(b1), np.asarray(b3))


def test_synthetic_vision_shapes():
    from repro.data.synthetic_vision import binary_labels_even_odd, make_prototype_images

    data = make_prototype_images(num_classes=4, per_class=10, side=8)
    assert data.shape == (4, 10, 64)
    assert data.min() >= 0 and data.max() <= 1
    labels = binary_labels_even_odd(np.asarray([0, 1, 2, 3]))
    np.testing.assert_array_equal(labels, [0, 1, 0, 1])
