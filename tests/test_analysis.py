"""Trace-discipline analyzer tests: each rule R1–R6 fires on a minimal
violating fixture and stays silent on the idiomatic counterpart, suppression
comments downgrade (never delete) findings, and the Layer-2 jaxpr audit
proves the sweep executor carries no array consts above the byte ceiling.

The fixture snippets VIOLATE the rules on purpose — which is why ``tests/``
is excluded from the default lint paths (``repro.analysis.cli``).
"""
import textwrap

import pytest

from repro.analysis import CONST_BYTE_CEILING
from repro.analysis.lint.base import ModuleContext
from repro.analysis.lint.checkers import (
    ClosureArrayChecker, DonationChecker, KeyStreamChecker, SideEffectChecker,
)
from repro.analysis.lint.repo_rules import BenchGateChecker, KernelPairingChecker
from repro.core import runner


def _lint(checker_cls, src):
    ctx = ModuleContext("fixture.py", textwrap.dedent(src))
    return checker_cls().check(ctx)


def _active(violations):
    return [v for v in violations if not v.suppressed]


# ------------------------------ R1 ------------------------------------------

def test_r1_flags_module_array_closure():
    vs = _lint(ClosureArrayChecker, """
        import jax
        import jax.numpy as jnp

        W = jnp.ones((4, 4))

        @jax.jit
        def apply(x):
            return x @ W
    """)
    assert [v.rule for v in vs] == ["R1"]
    assert "captured by closure" in vs[0].message


def test_r1_flags_numpy_ctor_in_traced_body():
    vs = _lint(ClosureArrayChecker, """
        import jax
        import numpy as np

        @jax.jit
        def f(x):
            return x + np.zeros(3)
    """)
    assert [v.rule for v in vs] == ["R1"]
    assert "jaxpr const" in vs[0].message


def test_r1_passes_operand_argument():
    vs = _lint(ClosureArrayChecker, """
        import jax
        import jax.numpy as jnp

        W = jnp.ones((4, 4))

        @jax.jit
        def apply(x, w):
            return x @ w

        def call(x):
            return apply(x, W)  # host call site: not a traced scope
    """)
    assert vs == []


# ------------------------------ R2 ------------------------------------------

def test_r2_flags_module_mutation_in_traced_body():
    vs = _lint(SideEffectChecker, """
        import jax

        LOG = []

        @jax.jit
        def f(x):
            LOG.append(1)
            return x
    """)
    assert [v.rule for v in vs] == ["R2"]
    assert "trace-time side effect" in vs[0].message


def test_r2_passes_trace_counts_bump():
    vs = _lint(SideEffectChecker, """
        import collections
        import jax

        TRACE_COUNTS = collections.Counter()

        @jax.jit
        def f(x):
            TRACE_COUNTS["f"] += 1
            return x
    """)
    assert vs == []


# ------------------------------ R3 ------------------------------------------

def test_r3_flags_bare_literal_fold_in_tag():
    vs = _lint(KeyStreamChecker, """
        import jax

        def stream(key):
            return jax.random.fold_in(key, 7)
    """)
    assert [v.rule for v in vs] == ["R3"]
    assert "bare literal" in vs[0].message


def test_r3_flags_unregistered_tag_name():
    vs = _lint(KeyStreamChecker, """
        import jax

        _ROGUE_TAG = 99

        def stream(key):
            return jax.random.fold_in(key, _ROGUE_TAG)
    """)
    assert [v.rule for v in vs] == ["R3"]
    assert "not registered" in vs[0].message


def test_r3_flags_unregistered_downlink_stream():
    # A new broadcast stream must REGISTER its tag — deriving a downlink
    # key from a homegrown name is exactly the collision R3 exists to catch.
    vs = _lint(KeyStreamChecker, """
        import jax

        _MY_DOWNLINK_TAG = 2

        def downlink_key(key):
            return jax.random.fold_in(key, _MY_DOWNLINK_TAG)
    """)
    assert [v.rule for v in vs] == ["R3"]
    assert "not registered" in vs[0].message


def test_r3_passes_registered_downlink_and_momentum_tags():
    vs = _lint(KeyStreamChecker, """
        import jax

        _DOWNLINK_KEY_TAG = 2  # registered in REGISTERED_KEY_TAGS
        _MOMENTUM_UPLINK_TAG = 3  # registered in REGISTERED_KEY_TAGS

        def downlink_key(key):
            return jax.random.fold_in(key, _DOWNLINK_KEY_TAG)

        def momentum_uplink_key(key):
            return jax.random.fold_in(key, _MOMENTUM_UPLINK_TAG)
    """)
    assert vs == []


def test_r3_flags_key_consumed_twice():
    vs = _lint(KeyStreamChecker, """
        import jax

        def sample(key):
            a = jax.random.normal(key, (2,))
            b = jax.random.normal(key, (2,))
            return a + b
    """)
    assert [v.rule for v in vs] == ["R3"]
    assert "consumed twice" in vs[0].message


def test_r3_passes_split_and_registered_tag():
    vs = _lint(KeyStreamChecker, """
        import jax

        _COMM_KEY_TAG = 0x636D  # registered in REGISTERED_KEY_TAGS

        def sample(key):
            k1, k2 = jax.random.split(jax.random.fold_in(key, _COMM_KEY_TAG))
            a = jax.random.normal(k1, (2,))
            b = jax.random.normal(k2, (2,))
            return a + b
    """)
    assert vs == []


def test_r3_allows_same_key_on_exclusive_branches():
    vs = _lint(KeyStreamChecker, """
        import jax

        def sample(key, flip):
            if flip:
                return jax.random.normal(key, (2,))
            else:
                return jax.random.uniform(key, (2,))
    """)
    assert vs == []


# ------------------------------ R4 ------------------------------------------

def test_r4_flags_literal_donate_argnums():
    vs = _lint(DonationChecker, """
        import jax

        def build(fn):
            return jax.jit(fn, donate_argnums=(0, 1))
    """)
    assert [v.rule for v in vs] == ["R4"]
    assert "literal donate_argnums" in vs[0].message


def test_r4_flags_donate_name_absent_from_cache_key():
    vs = _lint(DonationChecker, """
        import jax

        def build(fn):
            donate = (0, 1)
            return jax.jit(fn, donate_argnums=donate)
    """)
    assert [v.rule for v in vs] == ["R4"]
    assert "cache key" in vs[0].message


def test_r4_passes_donate_threaded_through_cache_key():
    vs = _lint(DonationChecker, """
        import jax

        CACHE = {}

        def build(name, fn):
            donate = (0, 1)
            key = (name, donate)
            if key not in CACHE:
                CACHE[key] = jax.jit(fn, donate_argnums=donate)
            return CACHE[key]
    """)
    assert vs == []


# --------------------------- suppressions -----------------------------------

def test_suppression_downgrades_but_keeps_finding():
    vs = _lint(DonationChecker, """
        import jax

        def build(fn):
            # repro: allow[R4] fixture: one-shot jit
            return jax.jit(fn, donate_argnums=(0,))
    """)
    assert len(vs) == 1 and vs[0].suppressed
    assert _active(vs) == []


def test_suppression_is_rule_specific():
    vs = _lint(DonationChecker, """
        import jax

        def build(fn):
            # repro: allow[R1] wrong rule: does not cover R4
            return jax.jit(fn, donate_argnums=(0,))
    """)
    assert len(vs) == 1 and not vs[0].suppressed


def test_rule_syntax_in_docstrings_is_not_a_suppression():
    vs = _lint(DonationChecker, '''
        import jax

        def build(fn):
            """Docstrings quoting `# repro: allow[R4]` must not suppress."""
            return jax.jit(fn, donate_argnums=(0,))
    ''')
    assert len(vs) == 1 and not vs[0].suppressed


# ------------------------------ R5 ------------------------------------------

def _kernel_dir(tmp_path, name, files):
    d = tmp_path / "src" / "repro" / "kernels" / name
    d.mkdir(parents=True)
    for fname, body in files.items():
        (d / fname).write_text(body)
    return tmp_path


def test_r5_flags_kernel_missing_ref_and_ops(tmp_path):
    root = _kernel_dir(tmp_path, "mykernel", {"kernel.py": "x = 1\n"})
    vs = KernelPairingChecker().check_repo(str(root))
    assert sorted(v.rule for v in vs) == ["R5", "R5"]
    assert {m for v in vs for m in ("ref.py", "ops.py") if m in v.message} \
        == {"ref.py", "ops.py"}


def test_r5_passes_paired_kernel(tmp_path):
    root = _kernel_dir(tmp_path, "mykernel", {
        "kernel.py": "x = 1\n", "ref.py": "x = 1\n", "ops.py": "x = 1\n"})
    assert KernelPairingChecker().check_repo(str(root)) == []


# ------------------------------ R6 ------------------------------------------

def _bench_repo(tmp_path, gate_src):
    b = tmp_path / "benchmarks"
    b.mkdir()
    (b / "run.py").write_text(textwrap.dedent("""
        from benchmarks import writer_bench

        harnesses = {
            "writer": writer_bench.main,
        }
    """))
    (b / "writer_bench.py").write_text(
        'PATH = "BENCH_writer.json"\n\ndef main(quick=True):\n    return []\n')
    (b / "check_regression.py").write_text(gate_src)
    return tmp_path


def test_r6_flags_ungated_bench_writer(tmp_path):
    root = _bench_repo(tmp_path, "def main():\n    pass\n")
    vs = BenchGateChecker().check_repo(str(root))
    assert [v.rule for v in vs] == ["R6"]
    assert "writer_bench" in vs[0].message


def test_r6_passes_gated_bench_writer(tmp_path):
    root = _bench_repo(
        tmp_path, "from benchmarks import writer_bench  # gated\n")
    assert BenchGateChecker().check_repo(str(root)) == []


# --------------------- assert_no_retrace helper ------------------------------

def test_assert_no_retrace_warm_contract_flags_movement():
    with pytest.raises(AssertionError, match="unexpected re-traces"):
        with runner.assert_no_retrace(what="a manual counter bump"):
            runner.TRACE_COUNTS["fake/executor"] += 1
    del runner.TRACE_COUNTS["fake/executor"]


def test_assert_no_retrace_traced_names_must_move_exactly_once():
    with runner.assert_no_retrace(traced=("fake/cold",)) as probe:
        runner.TRACE_COUNTS["fake/cold"] += 1
    assert probe.deltas == {"fake/cold": 1}
    with pytest.raises(AssertionError, match="expected exactly 1"):
        with runner.assert_no_retrace(traced=("fake/cold",),
                                      what="a block that never traced"):
            pass
    del runner.TRACE_COUNTS["fake/cold"]


# --------------------------- Layer 2: jaxpr audit ----------------------------

def test_jaxpr_audit_sweep_executor_has_no_large_consts():
    """The indexed-layout sweep executor must trace with ZERO array consts
    above the per-executor byte ceiling — operands (problems, seeds, etas)
    ride as arguments, never baked into the jaxpr."""
    from repro.analysis import jaxpr_audit

    report, failures = jaxpr_audit.run_audit(only=["sweep"])
    assert failures == []
    fams = {k: v for k, v in report["families"].items()
            if k.startswith("sweep/")}
    assert fams, f"sweep workload recorded no executors: {report['families']}"
    for fam, summary in fams.items():
        assert summary["max_const_bytes"] <= CONST_BYTE_CEILING, (
            f"{fam} bakes an array const of {summary['max_const_bytes']} "
            f"bytes into its jaxpr (ceiling {CONST_BYTE_CEILING})")
