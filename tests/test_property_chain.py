"""Hypothesis property tests on FedChain-level invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import algorithms as A, chain, selection
from repro.data import problems


@given(zeta=st.floats(0.0, 10.0), seed=st.integers(0, 50))
@settings(max_examples=10, deadline=None)
def test_selection_never_worse_than_both_noiseless(zeta, seed):
    """With noiseless value oracles, the selected point's TRUE loss equals
    min of the candidates' true losses (Lemma H.2, σ_F = ζ_F sampling = 0
    because all clients are evaluated)."""
    p = problems.quadratic_problem(
        jax.random.PRNGKey(seed), num_clients=4, dim=8, zeta=zeta, sigma_f=0.0)
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed + 1))
    xa = jax.random.normal(k1, (8,)) * 3
    xb = jax.random.normal(k2, (8,)) * 3
    best, idx, _ = selection.select_better(p, [xa, xb], jax.random.PRNGKey(2),
                                           s=4, k=2)
    fa, fb = float(p.global_loss(xa)), float(p.global_loss(xb))
    fbest = float(p.global_loss(best))
    assert fbest <= min(fa, fb) + 1e-4


@given(frac=st.floats(0.2, 0.8), seed=st.integers(0, 20))
@settings(max_examples=8, deadline=None)
def test_chain_budget_conservation(frac, seed):
    """A chain spends exactly its round budget (local + selection + global)."""
    p = problems.quadratic_problem(jax.random.PRNGKey(seed), dim=6, zeta=1.0)
    x0 = p.init_params(jax.random.PRNGKey(0))
    rounds = 20
    ch = chain.fedchain(
        A.FedAvg(eta=0.3, local_steps=2, inner_batch=2),
        A.SGD(eta=0.3, k=4, mu_avg=p.mu),
        local_fraction=frac, selection_k=4)
    res = ch.run(p, x0, rounds, jax.random.PRNGKey(seed))
    assert res.history.shape == (rounds,)


@given(seed=st.integers(0, 30))
@settings(max_examples=8, deadline=None)
def test_homogeneous_selection_prefers_local_output(seed):
    """ζ=0, noiseless: FedAvg strictly improves, so selection must keep x̂_1/2."""
    p = problems.quadratic_problem(
        jax.random.PRNGKey(seed), num_clients=4, dim=8, zeta=0.0, sigma=0.0)
    x0 = p.init_params(jax.random.PRNGKey(0))
    ch = chain.fedchain(
        A.FedAvg(eta=0.3, local_steps=4, inner_batch=1),
        A.SGD(eta=0.3, k=2, mu_avg=p.mu), selection_k=2)
    res = ch.run(p, x0, 12, jax.random.PRNGKey(seed + 1))
    assert res.selected_initial == [False]


@given(lr=st.floats(0.05, 0.5), s=st.integers(2, 6), d=st.integers(4, 64))
@settings(max_examples=10, deadline=None)
def test_aggregate_kernel_linear_in_lr(lr, s, d):
    """chain_aggregate is affine in lr: out(lr) = x − lr·u."""
    from repro.kernels.aggregate.aggregate import chain_aggregate

    key = jax.random.PRNGKey(d)
    x = jax.random.normal(key, (d,))
    g = jax.random.normal(jax.random.PRNGKey(1), (s, d))
    ci = jax.random.normal(jax.random.PRNGKey(2), (s, d))
    c = jax.random.normal(jax.random.PRNGKey(3), (d,))
    w = jnp.full((s,), 1.0 / s)
    o1 = chain_aggregate(x, g, ci, c, w, lr=lr, interpret=True, block_d=32)
    o2 = chain_aggregate(x, g, ci, c, w, lr=2 * lr, interpret=True, block_d=32)
    # (x - o2) == 2 (x - o1)
    np.testing.assert_allclose(np.asarray(x - o2), 2 * np.asarray(x - o1),
                               rtol=1e-4, atol=1e-5)
