"""Property tests for the uplink compressors (hypothesis-driven).

* QSGD and rand-k are UNBIASED: averaging the quantize→dequantize round trip
  over many independent keys recovers the input within Monte-Carlo error.
* top-k error feedback CONTRACTS: the residual obeys the standard
  ‖e⁺‖² ≤ (1 − k/d)·‖v + e‖² inequality every step, so residual norms stay
  bounded on a constant stream.
* The compressor switch is jit-stable: comp_id/bits/k are operands.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.comm import CommConfig, CommParams, compress_rows
from repro.comm.compressors import COMP_IDS


def _params(compressor, bits=4, k=4):
    return CommParams(
        comp_id=jnp.asarray(COMP_IDS[compressor], jnp.int32),
        qsgd_bits=jnp.asarray(bits, jnp.float32),
        spars_k=jnp.asarray(k, jnp.int32),
    )


def _mc_mean(v, params, n_keys, seed=0):
    """Average the compressor output over ``n_keys`` independent keys."""
    keys = jax.random.split(jax.random.PRNGKey(seed), n_keys)

    @jax.jit
    def one(k):
        return compress_rows(v, k, params)

    return jnp.mean(jax.vmap(one)(keys), axis=0)


@given(seed=st.integers(0, 2**30), bits=st.integers(2, 6))
@settings(max_examples=10, deadline=None)
def test_qsgd_unbiased(seed, bits):
    v = jax.random.normal(jax.random.PRNGKey(seed), (3, 32))
    n_keys = 3000
    mean = _mc_mean(v, _params("qsgd", bits=bits), n_keys, seed=seed + 1)
    # per-coordinate MC error ≤ 5·(quantization step)/√n_keys
    step = jnp.linalg.norm(v, axis=1, keepdims=True) / (2.0**bits - 1.0)
    tol = 5.0 * np.asarray(step) / np.sqrt(n_keys) + 1e-6
    np.testing.assert_array_less(np.abs(np.asarray(mean - v)), tol)


@given(seed=st.integers(0, 2**30), k=st.integers(1, 16))
@settings(max_examples=10, deadline=None)
def test_randk_unbiased(seed, k):
    d = 16
    v = jax.random.normal(jax.random.PRNGKey(seed), (2, d))
    n_keys = 4000
    mean = _mc_mean(v, _params("randk", k=k), n_keys, seed=seed + 1)
    # Var[randk_j] = v_j²·(d/k − 1); 5σ Monte-Carlo band (+ small abs floor)
    sigma = np.abs(np.asarray(v)) * np.sqrt(max(d / k - 1.0, 0.0))
    tol = 5.0 * sigma / np.sqrt(n_keys) + 1e-5
    np.testing.assert_array_less(np.abs(np.asarray(mean - v)), tol)


@given(seed=st.integers(0, 2**30), k=st.integers(1, 15))
@settings(max_examples=15, deadline=None)
def test_topk_error_feedback_contracts(seed, k):
    """Iterate EF compression of a fixed uplink stream and check the top-k
    contraction ‖e⁺‖² ≤ (1 − k/d)·‖v + e‖² at every step."""
    d = 16
    v = jax.random.normal(jax.random.PRNGKey(seed), (1, d))
    params = _params("topk", k=k)
    key = jax.random.PRNGKey(0)  # top-k is deterministic; key is unused
    e = jnp.zeros_like(v)
    factor = 1.0 - k / d
    for _ in range(12):
        comp = compress_rows(v + e, key, params)
        e_next = v + e - comp
        lhs = float(jnp.sum(e_next**2))
        rhs = factor * float(jnp.sum((v + e) ** 2))
        assert lhs <= rhs + 1e-5
        e = e_next
    # bounded residual on a constant stream: ‖e‖² ≤ (1−k/d)/(1−√(1−k/d))²·‖v‖²
    # (standard EF bound); check a loose version
    bound = (factor / max(1.0 - np.sqrt(factor), 1e-3) ** 2 + 1.0)
    assert float(jnp.sum(e**2)) <= bound * float(jnp.sum(v**2)) + 1e-5


@given(seed=st.integers(0, 2**30))
@settings(max_examples=10, deadline=None)
def test_topk_keeps_exactly_k_largest(seed):
    v = jax.random.normal(jax.random.PRNGKey(seed), (2, 32))
    k = 5
    out = np.asarray(
        compress_rows(v, jax.random.PRNGKey(0), _params("topk", k=k)))
    vv = np.asarray(v)
    for i in range(v.shape[0]):
        nz = np.flatnonzero(out[i])
        assert nz.size == k
        kept = set(nz.tolist())
        top = set(np.argsort(-np.abs(vv[i]))[:k].tolist())
        assert kept == top
        np.testing.assert_array_equal(out[i][nz], vv[i][nz])


def test_identity_is_bitwise_noop():
    v = jax.random.normal(jax.random.PRNGKey(0), (4, 64))
    out = compress_rows(v, jax.random.PRNGKey(1), _params("identity"))
    assert bool(jnp.all(out == v))


@given(dims=st.lists(st.integers(1, 64), min_size=1, max_size=5),
       bits=st.integers(1, 8), k=st.integers(1, 64),
       compressor=st.sampled_from(["identity", "qsgd", "topk", "randk"]))
@settings(max_examples=50, deadline=None)
def test_downlink_bits_per_leaf_closed_form(dims, bits, k, compressor):
    """Downlink bits equal the sum of per-leaf closed forms evaluated at the
    downlink leg's params — written out here INDEPENDENTLY of the library's
    arithmetic, on degenerate pytrees (1-element leaves, repeated dims):

      identity: 32·d     qsgd_b: 32 + d·(b+1)     top/rand-k: k·(32+⌈log₂d⌉)

    and an identity leg reduces to the full-precision 32·Σ_l d_l broadcast
    exactly (the pre-plan hardcoded form) — exact integers in float32."""
    import math

    from repro.comm.config import downlink_bits_per_client

    params = _params(compressor, bits=bits, k=min(k, min(dims)))
    kk = min(k, min(dims))

    def leaf_bits(d):
        if compressor == "identity":
            return 32.0 * d
        if compressor == "qsgd":
            return 32.0 + d * (bits + 1.0)
        idx = float(max(1, math.ceil(math.log2(d)))) if d > 1 else 1.0
        return kk * (32.0 + idx)

    expect = sum(leaf_bits(d) for d in dims)
    # a pytree with one [d] leaf per entry — dict keys keep insertion order
    tree = {f"l{i}": jnp.zeros((d,), jnp.float32)
            for i, d in enumerate(dims)}
    got = float(downlink_bits_per_client(params, tree))
    assert got == expect
    # tuple-of-dims and int (single-leaf) signatures agree with the pytree
    assert float(downlink_bits_per_client(params, tuple(dims))) == expect
    if len(dims) == 1:
        assert float(downlink_bits_per_client(params, dims[0])) == expect


def test_compressor_switch_is_operand_data():
    """One jitted function serves all four compressors: comp_id is data."""
    v = jax.random.normal(jax.random.PRNGKey(0), (2, 32))
    key = jax.random.PRNGKey(1)
    traces = []

    @jax.jit
    def f(params):
        traces.append(1)  # python side effect: counts traces
        return compress_rows(v, key, params)

    outs = {name: np.asarray(f(_params(name))) for name in COMP_IDS}
    assert len(traces) == 1
    assert np.array_equal(outs["identity"], np.asarray(v))
    assert not np.array_equal(outs["qsgd"], outs["identity"])
    assert (outs["topk"] != 0).sum() == 2 * 4
