"""Client-selection policy subsystem (``repro.selection``) contracts.

The policy protocol's load-bearing guarantees:

(a) the UNIFORM policy is bitwise identical — history, bits_up, bits_down —
    to the pre-existing mask-schedule path (``CommConfig.participation`` +
    ``mask_seed``): the uniform branch consumes the raw per-round selection
    key exactly the way ``CommConfig.round_masks`` does, so rebasing a
    harness onto the policy executors can never move a published number;
(b) policy choice is OPERAND DATA: swapping every policy and every
    hyperparameter at a fixed grid shape re-traces nothing
    (``runner.TRACE_COUNTS``-asserted) — one ``lax.switch`` executor serves
    all four policies;
(c) every policy emits valid masks (0/1 entries, exactly S per round) and a
    consistent ``PolicyState`` round-trip (counts == column sums of the
    mask history, t == rounds, last_mask == final mask);
(d) bits ledgers follow the closed forms: S·32·D uplink/downlink per round
    for identity compression, plus one f32 probe per client (32·N uplink)
    for probing policies and exactly zero probe bits for uniform;
(e) the sharded engine (1-device debug mesh) agrees bitwise with the
    vmapped engine, including the bits ledgers and every PolicyState leaf;
(f) ``core.selection.empirical_values`` (now vmapped over the stacked
    candidates) is bitwise identical to the per-candidate loop it replaced.

Hypothesis property tests ride behind per-function ``importorskip`` so the
deterministic tier stays runnable without hypothesis installed.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.comm import CommConfig
from repro.core import algorithms as A, chain, runner, selection, sweep
from repro.data import spec as spec_lib
from repro.selection import (
    POLICY_IDS, PROBING_POLICIES, SelectionPolicy, probe_bits,
    run_selection_sweep, top_s_mask,
)
from repro.selection.state import make_params

N, DIM, ROUNDS = 8, 12, 10
SEEDS = (0, 1)


@pytest.fixture(scope="module")
def spec():
    return spec_lib.quadratic_spec(
        jax.random.PRNGKey(7), num_clients=N, dim=DIM, mu=0.1, beta=1.0,
        zeta=2.0, sigma=0.2, sigma_f=0.05, curvature_spread=0.5)


def _algo():
    return A.SGD(eta=0.4, k=8, mu_avg=0.1)


def _chain():
    return chain.fedchain(
        A.FedAvg(eta=0.3, local_steps=3, inner_batch=4),
        A.SGD(eta=0.4, k=8, mu_avg=0.1),
        selection_k=8, select_between_stages=True)


def _all_policies(participation=0.5):
    return tuple(SelectionPolicy(p, participation=participation,
                                 ucb_c=0.5, ema=0.3)
                 for p in sorted(POLICY_IDS, key=POLICY_IDS.get))


# ---------------- (a) uniform == mask-schedule path, bitwise ----------------

def test_uniform_bitwise_matches_mask_schedule(spec):
    algo = _algo()
    pol = SelectionPolicy("uniform", participation=0.5, sel_seed=3)
    res = run_selection_sweep(algo, None, None, ROUNDS, policies=(pol,),
                              problems=[spec], seeds=SEEDS, etas=(1.0,))
    ref = sweep.run_sweep(algo, spec, spec.x0, ROUNDS, seeds=SEEDS,
                          etas=(1.0,),
                          comm=CommConfig(participation=0.5, mask_seed=3))
    # selection axes are [Q, P, S, E, ...]; the reference has [S, E, ...]
    for sel_v, ref_v in ((res.history[0, 0], ref.history),
                         (res.bits_up[0, 0], ref.bits_up),
                         (res.bits_down[0, 0], ref.bits_down)):
        np.testing.assert_array_equal(np.asarray(sel_v), np.asarray(ref_v))


# ---------------- (b) policy switch is data, not a re-trace -----------------

def test_policy_switch_retraces_nothing(spec):
    ch = _chain()

    def grid(pols):
        out = run_selection_sweep(ch, None, None, ROUNDS, policies=pols,
                                  problems=[spec], seeds=SEEDS, etas=(1.0,))
        jax.block_until_ready(out.history)
        return out

    grid(_all_policies(0.5))
    # every operand changed: policy order permuted, participation +
    # hyperparameters + selection seed all different, same grid SHAPE
    switched = (
        SelectionPolicy("shapley", participation=0.25, ema=0.9, sel_seed=9),
        SelectionPolicy("ucb", participation=0.75, ucb_c=2.0, sel_seed=9),
        SelectionPolicy("power_of_choice", participation=0.25, sel_seed=9),
        SelectionPolicy("uniform", participation=0.75, sel_seed=9),
    )
    with runner.assert_no_retrace(what="policy operand switch"):
        grid(switched)


# ---------------- (c) mask validity + state round-trip ----------------------

@pytest.mark.parametrize("method", ["algo", "chain"])
def test_masks_valid_and_state_consistent(spec, method):
    m = _algo() if method == "algo" else _chain()
    pols = _all_policies(0.5)
    res = run_selection_sweep(m, None, None, ROUNDS, policies=pols,
                              problems=[spec], seeds=SEEDS, etas=(1.0,))
    masks = np.asarray(res.masks)  # [Q, P, S, E, R, N]
    n_sched = masks.shape[-2]  # chains add Lemma H.2 selection rounds
    s_sel = pols[0].clients_per_round(N)
    assert set(np.unique(masks)) <= {0.0, 1.0}
    np.testing.assert_array_equal(masks.sum(axis=-1),
                                  np.full(masks.shape[:-1], s_sel))
    st = res.policy_state
    np.testing.assert_array_equal(np.asarray(st.t),
                                  np.full(np.asarray(st.t).shape, n_sched))
    np.testing.assert_array_equal(np.asarray(st.counts),
                                  masks.sum(axis=-2))
    np.testing.assert_array_equal(np.asarray(st.last_mask),
                                  masks[..., -1, :])


# ---------------- (d) bits closed forms -------------------------------------

def test_bits_closed_forms(spec):
    pols = _all_policies(0.5)
    res = run_selection_sweep(_algo(), None, None, ROUNDS, policies=pols,
                              problems=[spec], seeds=SEEDS, etas=(1.0,))
    bits_up = np.asarray(res.bits_up)  # [Q, P, S, E, R]
    bits_down = np.asarray(res.bits_down)
    s_sel = pols[0].clients_per_round(N)
    base = float(s_sel * 32 * DIM)  # identity compression, S transmitters
    for qi, pol in enumerate(pols):
        probe = float(32 * N) if pol.probing else 0.0
        assert pol.probing == (pol.policy in PROBING_POLICIES)
        np.testing.assert_array_equal(
            bits_up[qi], np.full(bits_up[qi].shape, base + probe))
        np.testing.assert_array_equal(
            bits_down[qi], np.full(bits_down[qi].shape, base))
    # probe_bits itself: uniform bills zero, probing policies one f32/client
    assert float(probe_bits(make_params("uniform", s_sel), N)) == 0.0
    assert float(probe_bits(make_params("ucb", s_sel), N)) == 32.0 * N


# ---------------- (e) sharded engine bitwise parity -------------------------

def test_sharded_matches_vmapped_bitwise(spec):
    from repro.dist import make_grid_mesh

    pols = _all_policies(0.5)
    kw = dict(policies=pols, problems=[spec], seeds=SEEDS, etas=(1.0,))
    ch = _chain()
    ref = run_selection_sweep(ch, None, None, ROUNDS, **kw)
    shd = run_selection_sweep(ch, None, None, ROUNDS, mesh=make_grid_mesh(1),
                              **kw)
    for field in ("history", "final_sub", "bits_up", "bits_down", "masks"):
        np.testing.assert_array_equal(np.asarray(getattr(ref, field)),
                                      np.asarray(getattr(shd, field)),
                                      err_msg=field)
    for leaf_a, leaf_b in zip(jax.tree.leaves(ref.policy_state),
                              jax.tree.leaves(shd.policy_state)):
        np.testing.assert_array_equal(np.asarray(leaf_a), np.asarray(leaf_b))


# ---------------- (f) empirical_values vectorization is bitwise -------------

def test_empirical_values_vmap_matches_loop(spec):
    key = jax.random.PRNGKey(21)
    k1, k2 = jax.random.split(key)
    candidates = [spec.x0, jax.tree.map(
        lambda t: t + 0.1 * jax.random.normal(k1, t.shape), spec.x0)]

    def loop_reference(problem, cands, k, *, s, k_samples):
        k_sample, k_vals = jax.random.split(k)
        from repro.core.algorithms import base
        cids = base.sample_clients(k_sample, problem.num_clients, s)
        keys = jax.random.split(k_vals, s * k_samples).reshape(
            s, k_samples, -1)

        def value_of(x):
            def per_client(cid, ks):
                vs = jax.vmap(
                    lambda kk: problem.value_oracle(x, cid, kk))(ks)
                return jnp.mean(vs)

            return jnp.mean(jax.vmap(per_client)(cids, keys))

        return jnp.stack([value_of(x) for x in cands])

    got = selection.empirical_values(spec, candidates, k2, s=4, k=3)
    want = loop_reference(spec, candidates, k2, s=4, k_samples=3)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# ---------------- hypothesis properties -------------------------------------

def test_prop_top_s_mask_valid():
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(deadline=None, max_examples=40)
    @given(seed=st.integers(0, 2**30), n=st.integers(2, 24),
           data=st.data())
    def prop(seed, n, data):
        s = data.draw(st.integers(1, n))
        score = jax.random.normal(jax.random.PRNGKey(seed), (n,))
        mask = np.asarray(top_s_mask(score, s))
        assert set(np.unique(mask)) <= {0.0, 1.0}
        assert mask.sum() == s
        # the S selected entries are exactly the S largest scores
        kept = np.sort(np.asarray(score)[mask > 0])
        assert np.array_equal(kept, np.sort(np.asarray(score))[n - s:])

    prop()


def test_prop_probe_bits_closed_form():
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(deadline=None, max_examples=40)
    @given(n=st.integers(1, 64),
           policy=st.sampled_from(sorted(POLICY_IDS)))
    def prop(n, policy):
        expect = 0.0 if policy == "uniform" else 32.0 * n
        assert float(probe_bits(make_params(policy, 1), n)) == expect

    prop()


def test_prop_params_round_trip():
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(deadline=None, max_examples=40)
    @given(policy=st.sampled_from(sorted(POLICY_IDS)),
           s=st.integers(1, 32),
           c=st.floats(0.0, 8.0, allow_nan=False),
           ema=st.floats(0.01, 1.0, allow_nan=False))
    def prop(policy, s, c, ema):
        p = make_params(policy, s, ucb_c=c, ema=ema)
        assert int(p.policy_id) == POLICY_IDS[policy]
        assert int(p.s_sel) == s
        assert float(p.ucb_c) == pytest.approx(c, rel=1e-6)
        assert float(p.ema) == pytest.approx(ema, rel=1e-6)

    prop()
