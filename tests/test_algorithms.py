"""Unit tests for Algos 2–7: convergence on strongly convex quadratics and
structural equivalences (FedAvg(K=1) ≡ SGD, etc.)."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.core import algorithms as A, runner
from repro.data import problems


@pytest.fixture(scope="module")
def quad():
    return problems.quadratic_problem(
        jax.random.PRNGKey(0), num_clients=8, dim=12, mu=0.1, beta=1.0,
        zeta=1.0, sigma=0.0)


def _final_sub(algo, p, rounds=80, seed=1):
    x0 = p.init_params(jax.random.PRNGKey(0))
    res = runner.run(algo, p, x0, rounds, jax.random.PRNGKey(seed))
    return float(res.history[-1]), res


@pytest.mark.parametrize("algo", [
    A.SGD(eta=0.5, k=2, mu_avg=0.1),
    A.NesterovSGD(eta=0.3, mu=0.1, beta=1.0, k=2),
    A.ACSA(mu=0.1, beta=1.0, k=2),
    A.FedAvg(eta=0.3, local_steps=4, inner_batch=2),
    A.Scaffold(eta=0.3, local_steps=4, inner_batch=2),
    A.SAGA(eta=0.5, k=2, mu_avg=0.1),
    A.SSNM(mu_h=0.1, beta=1.0, k=2, s=4),
    A.FedProx(eta=0.3, local_steps=4, inner_batch=2, prox_mu=0.05),
], ids=lambda a: a.name)
def test_converges_on_strongly_convex(quad, algo):
    start = float(quad.suboptimality(quad.init_params(jax.random.PRNGKey(0))))
    final, _ = _final_sub(algo, quad)
    assert final < 0.05 * start, f"{algo.name}: {final} vs start {start}"


def test_fedavg_k1_equals_sgd(quad):
    """One local step with server_lr=1 IS one SGD step (noiseless, S=N)."""
    x0 = quad.init_params(jax.random.PRNGKey(0))
    fa = A.FedAvg(eta=0.4, local_steps=1, inner_batch=1)
    sgd = A.SGD(eta=0.4, k=1, output_mode="last")
    key = jax.random.PRNGKey(7)
    sa = fa.round(quad, fa.init(quad, x0), key)
    sb = sgd.round(quad, sgd.init(quad, x0), key)
    assert float(jnp.max(jnp.abs(sa.x - sb.x))) < 1e-5


def test_fedavg_homogeneous_matches_gd(quad):
    """ζ=0 ⇒ every client's local trajectory equals centralized GD."""
    p = problems.quadratic_problem(
        jax.random.PRNGKey(0), num_clients=4, dim=8, mu=0.1, beta=1.0, zeta=0.0)
    x0 = p.init_params(jax.random.PRNGKey(0))
    fa = A.FedAvg(eta=0.3, local_steps=5, inner_batch=1)
    state = fa.round(p, fa.init(p, x0), jax.random.PRNGKey(1))
    # centralized GD, 5 steps
    x = x0
    for _ in range(5):
        x = x - 0.3 * jax.grad(p.global_loss)(x)
    assert float(jnp.max(jnp.abs(state.x - x))) < 1e-5


def test_saga_unbiased_update(quad):
    """E[g] = ∇F(x): SAGA's control variates cancel in expectation."""
    saga = A.SAGA(eta=0.1, k=1, s=3)
    state = saga.init(quad, quad.init_params(jax.random.PRNGKey(0)))
    # one-round expected update direction over many samplings
    xs = []
    for seed in range(300):
        s2 = saga.round(quad, state, jax.random.PRNGKey(seed))
        xs.append((state.x - s2.x) / 0.1)  # implied gradient estimate
    g_mean = jnp.mean(jnp.stack(xs), 0)
    g_true = jax.grad(quad.global_loss)(state.x)
    rel = float(jnp.linalg.norm(g_mean - g_true) / jnp.linalg.norm(g_true))
    assert rel < 0.15


def test_partial_participation_runs(quad):
    for algo in [A.SGD(eta=0.3, k=2, s=3), A.FedAvg(eta=0.3, s=3),
                 A.SAGA(eta=0.3, k=2, s=3), A.Scaffold(eta=0.3, s=3)]:
        final, _ = _final_sub(algo, quad, rounds=60)
        assert jnp.isfinite(final)


def test_weighted_average_tracker():
    """AvgTracker reproduces the explicit Thm. D.1 weighted average."""
    from repro.core.algorithms.base import AvgTracker

    xs = [jnp.asarray([float(i)]) for i in range(6)]
    decay = 0.9  # = 1 - eta*mu
    tr = AvgTracker.init(xs[0])
    for x in xs[1:]:
        tr = tr.update(x, jnp.asarray(decay))
    # explicit: w_r = decay^{-r}
    ws = [decay ** (-r) for r in range(6)]
    expect = sum(w * float(x[0]) for w, x in zip(ws, xs)) / sum(ws)
    assert float(tr.avg[0]) == pytest.approx(expect, rel=1e-5)


def test_stepsize_decay_runner(quad):
    sgd = A.SGD(eta=0.5, k=2, mu_avg=0.1)
    x0 = quad.init_params(jax.random.PRNGKey(0))
    res = runner.run_with_decay(sgd, quad, x0, 40, jax.random.PRNGKey(3))
    assert res.history.shape == (40,)
    assert float(res.history[-1]) < float(res.history[0])


def test_acsa_beats_sgd_rate(quad):
    """Acceleration: ASG reaches lower error than SGD in few rounds (κ=10)."""
    p = problems.quadratic_problem(
        jax.random.PRNGKey(2), num_clients=4, dim=16, mu=0.02, beta=1.0, zeta=0.0)
    sub_sgd, _ = _final_sub(A.SGD(eta=1.0, k=1, mu_avg=0.02, output_mode="last"), p, rounds=30)
    sub_asg, _ = _final_sub(A.NesterovSGD(eta=0.9, mu=0.02, beta=1.0, k=1), p, rounds=30)
    assert sub_asg < sub_sgd


def test_multistage_acsa_schedule():
    stages = A.multistage_acsa_schedule(
        mu=0.1, beta=1.0, delta=5.0, c_var=0.01, total_rounds=64)
    assert sum(r for r, _ in stages) == 64
    assert all(phi >= 2.0 for _, phi in stages)
